#!/usr/bin/env python
"""CI incremental-capture stage: delta-replay speedup + byte-identity.

Gates the block-evidence cache (core/block_cache.py, docs/artifacts.md) on
the PR's acceptance bounds:

1. **Delta-replay speedup** — warm ``Session.rank`` over 8 single-block
   rewrite candidates of a >=512-node layered model must spend at least
   3x less capture+pricing wall time (``stats_s + price_s`` from each
   artifact's timing meta; tracing is identical either way) than the same
   captures with the cache disabled.
2. **Byte-identity** — every warm capture must be indistinguishable from
   its cold twin: same content address, same priced profile payload
   (which embeds the per-op cost table), and the warm N-way rank must
   reproduce the cold rank's energies and waste matrix exactly.  Reuse
   that changes a single byte of evidence is a correctness bug, not a
   perf bug.
3. **Block-cache hit rate** — the candidate captures must actually run
   incrementally: >= 90% of their block probes hit (each candidate
   replays only its rewritten block plus boundary windows).

Emits BENCH_incremental.json for the perf trajectory.

Run from the repo root (scripts/ci.sh does):
    PYTHONPATH=src python scripts/incremental_check.py
"""

from __future__ import annotations

import sys
import tempfile
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "benchmarks"))
from common import emit_json  # noqa: E402

import jax.numpy as jnp                                     # noqa: E402

from repro.core.artifact import _profile_payload            # noqa: E402
from repro.core.session import Session                      # noqa: E402

LAYERS = 103
N_CANDIDATES = 8
TWISTS = tuple(12 * (i + 1) for i in range(N_CANDIDATES))   # 12 .. 96


def build_model(twist: int | None = None):
    """A 103-layer matmul+tanh stack (~516 nodes); ``twist`` inserts a
    transpose round-trip into exactly one layer — the single-block rewrite
    whose verification a warm session should pay for incrementally."""
    rng = np.random.default_rng(7)
    w = jnp.asarray((rng.standard_normal((24, 24)) / np.sqrt(24))
                    .astype(np.float32))
    x0 = jnp.asarray(rng.standard_normal((4, 24)).astype(np.float32))

    def fn(x):
        for i in range(LAYERS):
            h = x @ w
            if i == twist:
                h = jnp.transpose(jnp.transpose(h))
            x = (jnp.tanh(h) + 0.5 * x) * 1.01
        return x

    fn.__name__ = "target" if twist is None else f"cand_twist{twist}"
    return fn, (x0,)


def run_phase(root: str, *, cache: bool):
    """Capture target + all candidates and rank them; return artifacts,
    per-capture capture+price seconds, and the rank result."""
    session = Session(store=root, block_cache=None if cache else False)
    fn, args = build_model()
    target = session.capture(fn, args, name="target")
    cand_cost = 0.0
    arts = [target]
    for t in TWISTS:
        cfn, _ = build_model(twist=t)
        art = session.capture(cfn, args, name=cfn.__name__)
        timings = art.meta["timings"]
        cand_cost += timings["stats_s"] + timings["price_s"]
        arts.append(art)
    rank = session.rank(arts)
    return session, arts, cand_cost, rank


def main() -> int:
    with tempfile.TemporaryDirectory() as tmp:
        cold_sess, cold_arts, cold_s, cold_rank = run_phase(
            str(Path(tmp) / "cold"), cache=False)
        warm_sess, warm_arts, warm_s, warm_rank = run_phase(
            str(Path(tmp) / "warm"), cache=True)

    assert cold_sess.block_cache_counters == {}, \
        "cold phase must run with the block cache disabled"
    nodes = len(cold_arts[0].graph.nodes)
    assert nodes >= 512, f"model too small for the gate: {nodes} nodes"

    # -- byte-identity: warm captures are indistinguishable from cold ----
    mismatches = []
    for c, w in zip(cold_arts, warm_arts):
        if c.key != w.key:
            mismatches.append(f"{c.name}: content address diverged")
        if _profile_payload(c.profile) != _profile_payload(w.profile):
            mismatches.append(f"{c.name}: profile payload diverged")
    if cold_rank.total_energy_j != warm_rank.total_energy_j:
        mismatches.append("rank energies diverged")
    if cold_rank.waste_matrix != warm_rank.waste_matrix:
        mismatches.append("rank waste matrix diverged")
    if cold_rank.names != warm_rank.names:
        mismatches.append("rank names diverged")
    assert not mismatches, "warm capture is not byte-identical to cold:\n  " \
        + "\n  ".join(mismatches)

    # -- hit rate over the candidate captures (target is the cold fill) --
    hits = sum(a.meta["block_cache"].get("block_hits", 0)
               for a in warm_arts[1:])
    misses = sum(a.meta["block_cache"].get("block_misses", 0)
                 for a in warm_arts[1:])
    hit_rate = hits / max(hits + misses, 1)

    speedup = cold_s / max(warm_s, 1e-9)
    payload = {
        "model_nodes": nodes,
        "n_candidates": N_CANDIDATES,
        "cold_capture_price_s": cold_s,
        "warm_capture_price_s": warm_s,
        "speedup": speedup,
        "block_hit_rate": hit_rate,
        "candidate_block_hits": hits,
        "candidate_block_misses": misses,
        "session_counters": dict(warm_sess.block_cache_counters),
        "byte_identical": not mismatches,
        "identical_pairs": warm_rank.meta.get("identical_pairs", 0),
    }
    emit_json("BENCH_incremental.json", payload)
    print(f"incremental: {nodes}-node model, {N_CANDIDATES} single-block "
          f"rewrites: cold {cold_s:.2f}s -> warm {warm_s:.2f}s capture+price "
          f"({speedup:.1f}x), block hit rate {hit_rate:.1%}")

    assert speedup >= 3.0, (
        f"warm rank({N_CANDIDATES}) capture+price is only {speedup:.2f}x "
        "faster than cold (acceptance bound: >=3x)")
    assert hit_rate >= 0.9, (
        f"candidate block-cache hit rate {hit_rate:.1%} < 90% — candidates "
        "are not being captured incrementally")
    return 0


if __name__ == "__main__":
    sys.exit(main())
