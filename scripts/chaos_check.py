#!/usr/bin/env python
"""CI chaos stage: the offline drift gate replayed under injected faults.

Records the 4-case fast lane into a golden store, pushes it to a file://
mirror, then replays the offline baseline check from a read-through local
cache that has been corrupted at rest (every chunk bit-flipped, one
manifest garbled) behind a seeded flaky mirror (transient I/O errors and
timeouts injected by a deterministic :class:`FaultPlan`).

The gate is the no-silent-wrong-answer invariant (docs/robustness.md):
every case must end

* byte-identical to the fault-free replay (retry + quarantine + verified
  re-fetch absorbed everything — what this deterministic schedule is
  designed to allow), or
* declared (``Drift`` in the ``store``/``offline_replay`` fields), or
* a typed failure (the ``StoreError`` family),

and the schedule must demonstrably have *fired* (plan log, quarantine and
retry counters) — a chaos stage whose faults never trigger gates nothing.

A second scenario (``run_audit_chaos``) points the seeded ``FaultyStore``
at the *writable* remote path: a live-audit drift check whose conditional
puts flake must degrade per the ladder (in-memory artifact, ``[degraded]``
provenance, flush retried later) without ever dropping a sample or
corrupting ``index.json``.  See docs/serving.md.

Run from the repo root (scripts/ci.sh does):
    PYTHONPATH=src python scripts/chaos_check.py
"""

from __future__ import annotations

import hashlib
import shutil
import sys
import tempfile
from pathlib import Path

from repro.core.artifact import ArtifactValueError
from repro.core.faults import FaultPlan, FaultSpec, FaultyStore
from repro.core.store import (LocalStore, RemoteStore, RetryPolicy,
                              StoreError)
from repro.testing.baselines import BaselineError, BaselineStore
from repro.zoo import cases

# same structurally-varied subset as the ci.sh baseline gate
CASES = ["c6-matpow", "c15-expm", "c12-ln-layout", "c9-join-psum"]

# deterministic, recoverable schedule: every fault count sits inside the
# retry policy's per-call attempt limit and upstream of the verification
# layer (wrapping the local cache itself would inject *above* digest
# verification, which no store can defend against)
FLAKY_SPECS = [
    FaultSpec("read_chunk", "io_error", times=2),
    FaultSpec("read_manifest", "timeout", times=1),
    FaultSpec("has_chunk", "io_error", times=1),
]

# live-audit write path (repro.audit over the writable http remote): the
# first drift check's artifact save, golden election and log flush all hit
# injected write faults, then the schedule exhausts and the retaken check
# must deliver everything — per the ladder, never by raising into serving
AUDIT_FLAKY_SPECS = [
    FaultSpec("write_chunk", "io_error", times=1),
    FaultSpec("write_manifest", "io_error", times=2),
]


def _fingerprint(root: Path) -> dict:
    out = {}
    for p in sorted(root.rglob("*")):
        rel = p.relative_to(root)
        if not p.is_file() or rel.parts[0] == "quarantine":
            continue
        out[str(rel)] = hashlib.sha256(p.read_bytes()).hexdigest()
    return out


def _corrupt_at_rest(cache: Path) -> int:
    """Bit-flip every cached chunk and garble one cached manifest."""
    n = 0
    for p in sorted((cache / "chunks").rglob("*")):
        if p.is_file():
            blob = bytearray(p.read_bytes())
            blob[0] ^= 0xFF
            p.write_bytes(bytes(blob))
            n += 1
    manifests = sorted((cache / "manifests").glob("*.json"))
    manifests[0].write_text("{torn mid-write")
    return n


def _replay(bdir: Path, cache: Path, upstream) -> tuple:
    """Offline baseline check for all CASES through a read-through cache.

    Returns (local_store, {case_id: (outcome, detail)}) with outcome one of
    'clean' | 'declared' | 'typed' | 'WRONG'.
    """
    local = LocalStore(cache, upstream=upstream,
                       retry=RetryPolicy(sleep=lambda s: None, seed=1))
    bs = BaselineStore(bdir)
    bs.artifacts.backend = local
    outcomes = {}
    for cid in CASES:
        case = cases.get_case(cid)
        try:
            drifts = bs.check(case, offline=True)
        except (StoreError, ArtifactValueError, BaselineError) as e:
            outcomes[cid] = ("typed", f"{type(e).__name__}: {e}")
            continue
        if not drifts:
            outcomes[cid] = ("clean", None)
        elif all(d.field in ("store", "offline_replay") for d in drifts):
            outcomes[cid] = ("declared",
                             "; ".join(f"{d.field}: {d.actual}"
                                       for d in drifts))
        else:
            # detector fields drifted under faults: a wrong answer that a
            # fault-free replay would not produce
            outcomes[cid] = ("WRONG",
                             "; ".join(f"{d.field}: {d.expected!r} -> "
                                       f"{d.actual!r}" for d in drifts))
    return local, outcomes


def run_audit_chaos(tmp: Path) -> int:
    """Flaky conditional puts under the live-audit sampled path.

    A seeded :class:`FaultyStore` wraps the *writable* http remote that an
    :class:`EngineAuditor` flushes into.  The gate is the graceful-
    degradation ladder (docs/serving.md): the faulted drift check must
    complete with an in-memory artifact carrying ``[degraded]``
    provenance, the failed log flush must keep every event for the next
    attempt (no lost samples), and once the schedule exhausts the retaken
    check must persist goldens + logs while ``index.json`` stays exactly
    the manifest listing (never torn by a failed CAS)."""
    import json

    import jax.numpy as jnp
    import numpy as np

    from repro.audit import AuditConfig, EngineAuditor, classify, log_key
    from repro.core.artifact import ArtifactStore
    from repro.core.session import Session
    from repro.testing.httpstore import serve_store

    def probe(rc):
        x = np.linspace(0.0, 1.0, 64, dtype=np.float32).reshape(8, 8)
        return (lambda x: jnp.tanh(x @ x)), (x,), {"chaos_class": rc.key}

    with serve_store(tmp / "fleet") as srv:
        plan = FaultPlan(AUDIT_FLAKY_SPECS, seed=7)
        remote = RemoteStore(srv.url, writable=True,
                             retry=RetryPolicy(sleep=lambda s: None, seed=2))
        store = ArtifactStore(backend=FaultyStore(remote, plan))
        auditor = EngineAuditor(
            probe, "chaos-fingerprint",
            AuditConfig(engine_id="chaos-engine", recheck_every=1),
            session=Session(store=store))
        rc = classify("decode", 2, 12)

        # sample 1: every write op faults — the ladder must absorb all of it
        ev1 = auditor.sample(rc, "every_n", latency_s=0.001)
        if not (ev1.kind == "check" and ev1.degraded):
            print(f"audit-chaos: faulted check not degraded-clean: "
                  f"{ev1.to_payload()}")
            return 1
        if auditor.flush_failures < 1 or len(auditor.log) != 1:
            print(f"audit-chaos: flush failure not declared or event lost "
                  f"(failures={auditor.flush_failures}, "
                  f"log={len(auditor.log)})")
            return 1

        # sample 2: schedule exhausted — everything must now be delivered
        ev2 = auditor.sample(rc, "every_n", latency_s=0.001)
        reader = RemoteStore(srv.url,
                             retry=RetryPolicy(sleep=lambda s: None))
        flushed = reader.read_manifest(log_key("chaos-engine"))
        if ev2.kind != "check" or ev2.degraded:
            print(f"audit-chaos: retaken check still degraded: "
                  f"{ev2.to_payload()}")
            return 1
        if len(flushed["log"]["events"]) != 2 \
                or flushed["flush_failures"] != 1:
            print(f"audit-chaos: delivered log lost samples or history: "
                  f"{flushed['log']['events']} / "
                  f"failures={flushed['flush_failures']}")
            return 1

        # index.json survived the failed CAS byte-valid and complete
        index = json.loads((srv.root / "index.json").read_text())
        listed = sorted(p.stem
                        for p in (srv.root / "manifests").glob("*.json"))
        if index["manifests"] != listed:
            print(f"audit-chaos: index.json diverged from manifest "
                  f"listing: {index['manifests']} vs {listed}")
            return 1
        if plan.injected < sum(s.times for s in AUDIT_FLAKY_SPECS):
            print(f"audit-chaos: write-fault schedule did not fire "
                  f"(injected={plan.injected}, log={plan.log})")
            return 1
    print(f"audit-chaos OK: {plan.injected} write faults absorbed "
          f"({plan.log}), degraded provenance declared, no lost samples, "
          "index intact")
    return 0


def main() -> int:
    tmp = Path(tempfile.mkdtemp(prefix="magneton-chaos-"))
    try:
        rc = run(tmp)
        if rc != 0:
            return rc
        return run_audit_chaos(tmp)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def run(tmp: Path) -> int:
    bdir = tmp / "baselines"
    bs = BaselineStore(bdir)
    for cid in CASES:
        bs.record(cases.get_case(cid))
    mirror = tmp / "mirror"
    bs.artifacts.push(f"file://{mirror}")
    print(f"chaos: recorded {len(CASES)} golden cases, pushed to mirror")

    # fault-free reference replay through a fresh read-through cache
    _, ref = _replay(bdir, tmp / "cache-ref",
                     RemoteStore(f"file://{mirror}"))
    bad_ref = {c: o for c, o in ref.items() if o[0] != "clean"}
    if bad_ref:
        print(f"chaos: fault-free reference replay not clean: {bad_ref}")
        return 1

    # chaos replay: warm an identical cache, corrupt it at rest, then
    # replay behind the seeded flaky mirror
    chaos_cache = tmp / "cache-chaos"
    _replay(bdir, chaos_cache, RemoteStore(f"file://{mirror}"))
    n_corrupted = _corrupt_at_rest(chaos_cache)
    plan = FaultPlan(FLAKY_SPECS, seed=11)
    local, outcomes = _replay(
        bdir, chaos_cache,
        FaultyStore(RemoteStore(f"file://{mirror}"), plan))

    c = local.counters
    print(f"chaos: corrupted {n_corrupted} chunks + 1 manifest at rest; "
          f"injected {plan.injected} transport faults {plan.log}; "
          f"quarantined {c['chunks_quarantined']}, retries {c['retries']}, "
          f"verify failures {c['verify_failures']}")
    for cid, (outcome, detail) in outcomes.items():
        print(f"chaos: {cid}: {outcome}"
              + (f" ({detail})" if detail else ""))

    wrong = {cid: d for cid, (o, d) in outcomes.items() if o == "WRONG"}
    if wrong:
        print(f"chaos: SILENT WRONG ANSWER under faults: {wrong}")
        return 1
    # this schedule is deterministic and fully recoverable by design, so
    # the stronger gate holds: every case byte-identical to fault-free
    not_clean = {c: o for c, o in outcomes.items() if o[0] != "clean"}
    if not_clean:
        print(f"chaos: recoverable schedule did not fully recover: "
              f"{not_clean}")
        return 1
    # and the healed cache converged byte-for-byte to the reference cache
    if _fingerprint(chaos_cache) != _fingerprint(tmp / "cache-ref"):
        print("chaos: healed cache is not byte-identical to the "
              "fault-free cache")
        return 1
    # the faults must actually have fired, or the stage gates nothing
    if plan.injected < len(FLAKY_SPECS) or c["chunks_quarantined"] < 1 \
            or c["retries"] < 1:
        print("chaos: fault schedule did not fire "
              f"(injected={plan.injected}, "
              f"quarantined={c['chunks_quarantined']}, "
              f"retries={c['retries']})")
        return 1
    print("chaos OK: faults absorbed, results byte-identical, "
          "store state converged")
    return 0


if __name__ == "__main__":
    sys.exit(main())
