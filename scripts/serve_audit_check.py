#!/usr/bin/env python
"""CI serving-audit stage: overhead, drift alarms, fleet-store convergence.

Gates the always-on sampled auditing subsystem (repro.audit,
docs/serving.md) on three acceptance bounds:

1. **Amortized overhead** — serving the same deterministic traffic with
   sampled auditing on (warm steady state: goldens elected, every sample a
   lightweight log event) must cost < 5% wall-clock vs auditing off.
2. **Mutated-config alarm** — an engine whose decode probe carries a
   planted waste mutation must raise a drift alarm against the healthy
   fleet golden, naming the planted diagnosis kind.
3. **Conditional-put convergence** — two engines racing on one writable
   http store (the loopback S3/GCS stand-in) must converge to a
   byte-identical store regardless of interleaving: index.json equals the
   manifest listing, every chunk digest-verifies, no samples are lost.

Emits BENCH_serve_audit.json for the perf trajectory.

Run from the repo root (scripts/ci.sh does):
    PYTHONPATH=src python scripts/serve_audit_check.py
"""

from __future__ import annotations

import hashlib
import json
import shutil
import sys
import tempfile
import threading
import time
from pathlib import Path

import jax
import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "benchmarks"))
from common import emit_json  # noqa: E402

from repro import configs                                   # noqa: E402
from repro.audit import fleet_status                        # noqa: E402
from repro.models import transformer as tf                  # noqa: E402
from repro.serve.engine import (EngineConfig, Request,      # noqa: E402
                                ServeEngine)
from repro.testing.httpstore import serve_store             # noqa: E402

N_REQS = 16
MAX_NEW = 6
PROMPT_LEN = 12
TIMED_RUNS = 5
OVERHEAD_BOUND = 0.05


def _mkreqs(vocab: int, seed: int = 0) -> list[Request]:
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab, PROMPT_LEN,
                                        dtype=np.int32).astype(np.int32),
                    max_new_tokens=MAX_NEW)
            for i in range(N_REQS)]


def _timed_serves(eng: ServeEngine, vocab: int) -> float:
    """Median wall-clock of TIMED_RUNS identical serve rounds (warm)."""
    times = []
    for _ in range(TIMED_RUNS):
        reqs = _mkreqs(vocab)
        t0 = time.perf_counter()
        eng.generate(reqs)
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


def _ecfg(**kw) -> EngineConfig:
    return EngineConfig(batch_size=2, max_len=48, audit_timeout_s=300.0, **kw)


def check_overhead(cfg, params, tmp: Path) -> dict:
    eng_off = ServeEngine(cfg, params, ecfg=_ecfg())
    eng_off.generate(_mkreqs(cfg.vocab_size))               # jit warm-up
    t_off = _timed_serves(eng_off, cfg.vocab_size)

    eng_on = ServeEngine(cfg, params, ecfg=_ecfg(
        audit_sample_every=8, store=str(tmp / "overhead-store"),
        engine_id="bench"))
    # warm-up: jit + the one-time per-class full captures / golden election
    eng_on.generate(_mkreqs(cfg.vocab_size))
    sampled_before = eng_on.stats["audit_sampled"]
    t_on = _timed_serves(eng_on, cfg.vocab_size)
    sampled_during = eng_on.stats["audit_sampled"] - sampled_before

    overhead = (t_on - t_off) / t_off
    print(f"serve-audit: steady-state serve {t_off*1e3:.1f} ms audit-off vs "
          f"{t_on*1e3:.1f} ms audit-on ({sampled_during} samples taken "
          f"during timed runs) -> amortized overhead {overhead:+.2%}")
    assert sampled_during > 0, \
        "timed runs took no samples; the overhead measurement is vacuous"
    assert overhead < OVERHEAD_BOUND, (
        f"amortized audit overhead {overhead:+.2%} exceeds the "
        f"{OVERHEAD_BOUND:.0%} acceptance bound")
    return {"serve_s_audit_off": t_off, "serve_s_audit_on": t_on,
            "amortized_overhead": overhead,
            "samples_during_timed_runs": sampled_during,
            "alarms": eng_on.stats["audit_alarms"]}


def check_mutated_alarm(cfg, params, tmp: Path) -> dict:
    store = str(tmp / "alarm-store")
    healthy = ServeEngine(cfg, params, ecfg=_ecfg(
        audit_sample_every=4, store=store, engine_id="healthy"))
    healthy.generate(_mkreqs(cfg.vocab_size))
    assert healthy.stats["audit_alarms"] == 0, \
        "healthy engine must not alarm against its own goldens"

    mutated = ServeEngine(cfg, params, ecfg=_ecfg(
        audit_sample_every=4, store=store, engine_id="mutated",
        audit_mutate_decode="redundant_recompute"))
    mutated.generate(_mkreqs(cfg.vocab_size))
    alarms = mutated.auditor.alarms
    print(f"serve-audit: mutated engine raised {len(alarms)} alarms: "
          + "; ".join(f"{a.class_key} {a.energy_delta:+.1%} "
                      f"kind={a.diagnosis_kind}" for a in alarms))
    assert alarms, "mutated decode step must raise a drift alarm"
    assert any(a.diagnosis_kind == "api_difference" for a in alarms), (
        "redundant_recompute plants an api_difference; alarms carried "
        f"{[a.diagnosis_kind for a in alarms]}")
    status = fleet_status(store)
    assert status["total_alarms"] >= len(alarms)
    return {"alarms": len(alarms),
            "diagnosis_kinds": sorted({a.diagnosis_kind for a in alarms
                                       if a.diagnosis_kind}),
            "max_energy_delta": max(a.energy_delta for a in alarms)}


def _store_fingerprint(root: Path) -> dict:
    """Byte fingerprint of the store, excluding per-engine ``audit--*``
    logs (they carry real latencies, the one nondeterministic input)."""
    out = {}
    for p in sorted(root.rglob("*")):
        rel = p.relative_to(root)
        if not p.is_file() or p.name.startswith("audit--"):
            continue
        out[str(rel)] = hashlib.sha256(p.read_bytes()).hexdigest()
    return out


def _race_once(cfg, params, root: Path, order: tuple[str, str]) -> dict:
    """Two engines serve concurrently into one writable http store."""
    with serve_store(root) as srv:
        engines = {eid: ServeEngine(cfg, params, ecfg=_ecfg(
            audit_sample_every=4, store=srv.url, engine_id=eid))
            for eid in order}
        threads = [threading.Thread(
            target=lambda e=engines[eid]: e.generate(_mkreqs(cfg.vocab_size)))
            for eid in order]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        status = fleet_status(str(root))
    # no lost samples: every engine's flushed log agrees with its sampler
    for eid in order:
        eng = engines[eid]
        summary = eng.auditor.summary()
        flushed = next(e for e in status["engines"]
                       if e["engine_id"] == eid)
        assert flushed["sampled"] == summary["sampled"] > 0, (
            f"{eid}: flushed {flushed['sampled']} samples vs "
            f"{summary['sampled']} taken")
        assert eng.auditor.flush_failures == 0
    return {"status": status,
            "fingerprint": _store_fingerprint(root),
            "sampled": {eid: engines[eid].auditor.summary()["sampled"]
                        for eid in order}}


def check_convergence(cfg, params, tmp: Path) -> dict:
    a = _race_once(cfg, params, tmp / "race-a", ("engine-a", "engine-b"))
    b = _race_once(cfg, params, tmp / "race-b", ("engine-b", "engine-a"))

    # byte-identical convergence regardless of interleaving/start order
    assert a["fingerprint"] == b["fingerprint"], (
        "two racing writers left different store bytes: "
        f"{sorted(set(a['fingerprint']) ^ set(b['fingerprint']))[:6]}")
    assert a["sampled"] == b["sampled"], "sample schedules must be seeded"

    # index.json is exactly the manifest listing (no lost index updates)
    for root in (tmp / "race-a", tmp / "race-b"):
        index = json.loads((root / "index.json").read_text())
        listed = sorted(p.stem for p in (root / "manifests").glob("*.json"))
        assert index["manifests"] == listed, (
            f"{root}: index {len(index['manifests'])} keys vs "
            f"{len(listed)} manifest files")
        # every chunk digest-verifies: no torn/orphan conditional puts
        n_chunks = 0
        for p in (root / "chunks").rglob("*"):
            if p.is_file():
                n_chunks += 1
                assert hashlib.sha256(
                    p.read_bytes()).hexdigest() == p.name, \
                    f"chunk {p.name} fails digest verification"
        assert n_chunks > 0
    print(f"serve-audit: two racing engines converged byte-identically "
          f"({len(a['fingerprint'])} store objects, "
          f"{a['sampled']} samples per engine, "
          f"{a['status']['total_alarms']} alarms)")
    assert a["status"]["total_alarms"] == 0
    return {"store_objects": len(a["fingerprint"]),
            "engines": len(a["status"]["engines"]),
            "sampled": a["sampled"]}


def main() -> int:
    tmp = Path(tempfile.mkdtemp(prefix="magneton-serve-audit-"))
    try:
        cfg = configs.get_config("gpt2-small").reduced(num_layers=2)
        params = tf.model_init(cfg, jax.random.key(0))
        overhead = check_overhead(cfg, params, tmp)
        alarm = check_mutated_alarm(cfg, params, tmp)
        convergence = check_convergence(cfg, params, tmp)
        emit_json("BENCH_serve_audit.json", {
            "arch": cfg.name, "requests": N_REQS, "max_new": MAX_NEW,
            "timed_runs": TIMED_RUNS, "overhead_bound": OVERHEAD_BOUND,
            "overhead": overhead, "mutated_alarm": alarm,
            "convergence": convergence})
        print("serve-audit OK: overhead bounded, mutated config alarms, "
              "racing writers converge")
        return 0
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    sys.exit(main())
