#!/usr/bin/env bash
# CI entry point: tier-1 tests + CLI smoke + baseline drift gate + benches.
#
#   scripts/ci.sh          # fast tests + CLI smoke + baseline-check (subset)
#   scripts/ci.sh --full   # everything: slow tests, all 20 baselines, bench
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

FULL=0
[[ "${1:-}" == "--full" ]] && FULL=1

# fast-lane subset for the baseline drift gate (cheap, structurally varied:
# matmul algorithm, redundant recompute, layout, collective); --full replays
# every committed baseline
BASELINE_CASES=(c6-matpow c15-expm c12-ln-layout c9-join-psum)

echo "== tier-1 tests =="
if [[ "$FULL" == 1 ]]; then
    python -m pytest -x -q
else
    python -m pytest -x -q -m "not slow"
fi

echo "== CLI smoke =="
STORE="$(mktemp -d)"
trap 'rm -rf "$STORE"' EXIT
export MAGNETON_STORE="$STORE"
python -m repro.cli cases > /dev/null
python -m repro.cli capture c6-matpow:ineff c6-matpow:eff
python -m repro.cli compare c6-matpow:ineff c6-matpow:eff \
    --json "$STORE/rep.json" --expect-waste > /dev/null
# compare by bare artifact key (zoo provenance re-attach path)
mapfile -t KEYS < <(cd "$STORE" && ls ./*.npz | sed 's|^\./||; s|\.npz$||')
python -m repro.cli compare "${KEYS[0]}" "${KEYS[1]}" \
    --output-rtol 0.05 > /dev/null
python -m repro.cli report "$STORE/rep.json" > /dev/null
python -m repro.cli rank c6-matpow:ineff c6-matpow:eff \
    --json "$STORE/rank.json" > /dev/null
python -m repro.cli report "$STORE/rank.json" > /dev/null
python -m repro.cli artifacts > /dev/null
python -m repro.cli artifacts prune --keep-latest 2 > /dev/null
echo "CLI smoke OK"

echo "== backend parity (per-op HLO vs analytic waste sign) =="
# the parity suite runs in the default lane on the same structurally-varied
# subset as the baseline gate (lazy golden fixture: only these four cases
# are recorded); --full covers every detect case via the slow test lane
if [[ "$FULL" != 1 ]]; then
    # ledger sanity + zoo subset + the full generated-case parity matrix
    PARITY_K="ledger or mutation_parity"
    for c in "${BASELINE_CASES[@]}"; do PARITY_K+=" or $c"; done
    python -m pytest -q tests/test_backend_parity.py -k "$PARITY_K"
fi
echo "backend-parity OK"

echo "== baseline-check (golden artifact replay) =="
# Copy the COMMITTED expectations aside, record fresh golden artifacts next
# to them, then (1) the live check diffs fresh findings against the
# committed JSONs and (2) the offline check replays matching+classification+
# diagnosis purely from the persisted artifacts — zero instrumented
# execution — and must also be drift-free.
BDIR="$(mktemp -d)"
trap 'rm -rf "$STORE" "$BDIR"' EXIT
cp tests/baselines/*.json "$BDIR"/
ARGS=()
[[ "$FULL" == 1 ]] || ARGS=("${BASELINE_CASES[@]}")
python -m repro.cli baseline check --dir "$BDIR" "${ARGS[@]}"
python -m repro.cli baseline check --dir "$BDIR" --offline "${ARGS[@]}"

# HLO-backend lane: record one case under the per-op HLO backend, then
# prove the per-op attribution round-trips the store by replaying it
# offline bit-identically (artifact schema v2 gate)
BHLO="$(mktemp -d)"
trap 'rm -rf "$STORE" "$BDIR" "$BHLO"' EXIT
python -m repro.cli baseline record --dir "$BHLO" --backend hlo c6-matpow
python -m repro.cli baseline check --dir "$BHLO" --backend hlo --offline c6-matpow
echo "baseline-check OK"

if [[ "$FULL" == 1 ]]; then
    echo "== overhead benchmark (BENCH_overhead.json) =="
    PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
        python benchmarks/fig10_overhead.py
fi

echo "CI OK"
