#!/usr/bin/env bash
# CI entry point: tier-1 tests + CLI smoke + baseline drift gate + benches.
#
#   scripts/ci.sh          # fast tests + CLI smoke + baseline-check (subset)
#   scripts/ci.sh --full   # everything: slow tests, all 20 baselines, bench
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

FULL=0
[[ "${1:-}" == "--full" ]] && FULL=1

# fast-lane subset for the baseline drift gate (cheap, structurally varied:
# matmul algorithm, redundant recompute, layout, collective); --full replays
# every committed baseline
BASELINE_CASES=(c6-matpow c15-expm c12-ln-layout c9-join-psum)

echo "== tier-1 tests =="
if [[ "$FULL" == 1 ]]; then
    python -m pytest -x -q
else
    python -m pytest -x -q -m "not slow"
fi

echo "== CLI smoke =="
STORE="$(mktemp -d)"
trap 'rm -rf "$STORE"' EXIT
export MAGNETON_STORE="$STORE"
python -m repro.cli cases > /dev/null
python -m repro.cli capture c6-matpow:ineff c6-matpow:eff
python -m repro.cli compare c6-matpow:ineff c6-matpow:eff \
    --json "$STORE/rep.json" --expect-waste > /dev/null
# compare by bare artifact key (zoo provenance re-attach path)
mapfile -t KEYS < <(cd "$STORE/manifests" && ls ./*.json \
    | sed 's|^\./||; s|\.json$||')
python -m repro.cli compare "${KEYS[0]}" "${KEYS[1]}" \
    --output-rtol 0.05 > /dev/null
python -m repro.cli report "$STORE/rep.json" > /dev/null
python -m repro.cli rank c6-matpow:ineff c6-matpow:eff \
    --json "$STORE/rank.json" > /dev/null
python -m repro.cli report "$STORE/rank.json" > /dev/null
python -m repro.cli artifacts > /dev/null
python -m repro.cli artifacts prune --keep-latest 2 > /dev/null
echo "CLI smoke OK"

echo "== backend parity (per-op HLO vs analytic waste sign) =="
# the parity suite runs in the default lane on the same structurally-varied
# subset as the baseline gate (lazy golden fixture: only these four cases
# are recorded); --full covers every detect case via the slow test lane
if [[ "$FULL" != 1 ]]; then
    # ledger sanity + zoo subset + the full generated-case parity matrix
    PARITY_K="ledger or mutation_parity"
    for c in "${BASELINE_CASES[@]}"; do PARITY_K+=" or $c"; done
    python -m pytest -q tests/test_backend_parity.py -k "$PARITY_K"
fi
echo "backend-parity OK"

echo "== optimize (detect -> transform -> verify loop) =="
# Full generated scenario matrix: every waste class must be invertible —
# the diagnosed mutant's inverse rewrite yields a candidate verified
# EQUIVALENT (the detector's own gate) and strictly cheaper, per scenario,
# plus one all-rewrites N-way rank demo.  Emits BENCH_optimize.json with
# per-class win margins.  See docs/optimizer.md.
python scripts/optimize_check.py
echo "optimize OK"

echo "== baseline-check (golden artifact replay) =="
# Copy the COMMITTED expectations aside, record fresh golden artifacts next
# to them, then (1) the live check diffs fresh findings against the
# committed JSONs and (2) the offline check replays matching+classification+
# diagnosis purely from the persisted artifacts — zero instrumented
# execution — and must also be drift-free.
BDIR="$(mktemp -d)"
trap 'rm -rf "$STORE" "$BDIR"' EXIT
cp tests/baselines/*.json "$BDIR"/
ARGS=()
[[ "$FULL" == 1 ]] || ARGS=("${BASELINE_CASES[@]}")
python -m repro.cli baseline check --dir "$BDIR" "${ARGS[@]}"
python -m repro.cli baseline check --dir "$BDIR" --offline "${ARGS[@]}"

# HLO-backend lane: record one case under the per-op HLO backend, then
# prove the per-op attribution round-trips the store by replaying it
# offline bit-identically (artifact schema gate)
BHLO="$(mktemp -d)"
trap 'rm -rf "$STORE" "$BDIR" "$BHLO"' EXIT
python -m repro.cli baseline record --dir "$BHLO" --backend hlo c6-matpow
python -m repro.cli baseline check --dir "$BHLO" --backend hlo --offline c6-matpow
echo "baseline-check OK"

echo "== store round-trip (chunked v3: dedup + sketch-only replay) =="
# Record the fast lane into a fresh sketch-only golden store, report the
# dedup ratio (monolithic-equivalent bytes / physical chunked bytes) and
# the sketch-only coverage, gate the >=3x shrink acceptance bound, then
# push to a file:// mirror and run the offline drift check entirely from
# that RemoteStore (zero instrumented execution, zero raw-value chunks).
SDIR="$(mktemp -d)"
trap 'rm -rf "$STORE" "$BDIR" "$BHLO" "$SDIR"' EXIT
python -m repro.cli baseline record --dir "$SDIR" "${BASELINE_CASES[@]}"
python -m repro.cli artifacts stats --store "$SDIR/store" \
    --json "$SDIR/stats.json"
python - "$SDIR/stats.json" <<'PY'
import json, sys
s = json.load(open(sys.argv[1]))
ratio = s["dedup_ratio"]
cov = s["sketch_only_fraction"]
print(f"store round-trip: dedup ratio {ratio:.2f}x vs monolithic layout, "
      f"sketch-only coverage {cov:.1%} "
      f"({s['values_sketch_only']}/{s['values_total']} values, "
      f"{s['spectra_entries']} spectra entries)")
assert ratio >= 3.0, (
    f"regenerated golden store is only {ratio:.2f}x smaller than the "
    "monolithic layout (acceptance bound: >=3x)")
assert cov == 1.0, f"sketch-only coverage {cov:.1%} < 100%"
PY
MIRROR="$SDIR/mirror"
python -m repro.cli artifacts push --store "$SDIR/store" --to "file://$MIRROR"
python -m repro.cli baseline check --dir "$SDIR" --offline \
    --store "file://$MIRROR" "${BASELINE_CASES[@]}"
echo "store round-trip OK"

echo "== serving-audit (sampled live auditing + writable fleet store) =="
# Gates the repro.audit subsystem (docs/serving.md): amortized sampled-
# audit overhead < 5% vs audit-off on warm steady-state traffic, a
# planted decode mutation must raise a drift alarm naming its diagnosis
# kind against the healthy fleet golden, and two engines racing on one
# writable http store must converge byte-identically under the
# conditional-put dialect with no lost samples.  Emits
# BENCH_serve_audit.json.
python scripts/serve_audit_check.py
echo "serving-audit OK"

echo "== chaos (offline replay under seeded faults) =="
# Replays the same 4-case offline drift gate through a read-through cache
# corrupted at rest (bit-flipped chunks, one garbled manifest) behind a
# flaky mirror driven by a fixed seeded FaultPlan.  Gates on the
# no-silent-wrong-answer invariant: byte-identical recovery for this
# deterministic schedule, quarantine/retry counters proving the faults
# fired.  See docs/robustness.md.
python scripts/chaos_check.py
echo "chaos OK"

echo "== incremental-capture (delta-replay rank + block-evidence cache) =="
# Captures a >=512-node layered model plus 8 single-block rewrite
# candidates twice — block cache off, then on — and gates the warm
# rank's capture+pricing time at >=3x faster with every warm artifact
# byte-identical to its cold twin (content address, profile payload,
# rank energies/waste matrix).  Emits BENCH_incremental.json.  See
# docs/artifacts.md (block-evidence schema) and docs/optimizer.md
# (delta-verification cost model).
python scripts/incremental_check.py
python - <<'PY'
import json
d = json.load(open("BENCH_incremental.json"))
print(f"incremental-capture: {d['speedup']:.1f}x warm speedup, "
      f"{d['block_hit_rate']:.1%} candidate hit rate "
      f"({d['model_nodes']} nodes, {d['n_candidates']} candidates)")
assert d["byte_identical"] is True, "warm capture diverged from cold"
assert d["speedup"] >= 3.0, (
    f"delta-replay speedup {d['speedup']:.2f}x below the 3x bound")
assert d["block_hit_rate"] >= 0.9, (
    f"candidate block hit rate {d['block_hit_rate']:.1%} < 90%")
PY
echo "incremental-capture OK"

echo "== matcher-scaling (fig9: hierarchical matcher to 5k+ nodes) =="
# Runs the fig9 harness (which itself asserts streaming capture <= eager
# capture at every config >= 161 nodes, stamped == exhaustive/streamed pair
# parity, >= 10x over the N^2 eager extrapolation at 5k nodes, and no
# throughput cliff), then gates the emitted BENCH_matcher.json on the
# headline scaling bound: nodes/sec at the 5121-node config must be at
# least the 41-node config's rate — hierarchical matching may not decay
# toward the quadratic baseline as graphs grow.
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/fig9_scalability.py
python - <<'PY'
import json
d = json.load(open("BENCH_matcher.json"))
cfg = d["configs"]
small, big = cfg["41"], cfg["5121"]
r_small, r_big = small["nodes_per_sec"], big["nodes_per_sec"]
print(f"matcher-scaling: {r_small:.0f} nodes/sec @41 -> "
      f"{r_big:.0f} nodes/sec @5121 "
      f"(speedup vs N^2 extrapolation: {big['speedup']:.0f}x)")
assert r_big >= r_small, (
    f"matcher throughput decayed with size: {r_big:.0f} nodes/sec at 5121 "
    f"nodes < {r_small:.0f} at 41 (quadratic cliff)")
for nodes, c in sorted(cfg.items(), key=lambda kv: int(kv[0])):
    if int(nodes) >= 161:
        assert c["capture_s_streaming"] <= c["capture_s_eager"], (
            f"streaming capture slower than eager at {nodes} nodes")
PY
echo "matcher-scaling OK"

if [[ "$FULL" == 1 ]]; then
    echo "== overhead benchmark (BENCH_overhead.json) =="
    PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
        python benchmarks/fig10_overhead.py
fi

echo "CI OK"
