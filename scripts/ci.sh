#!/usr/bin/env bash
# CI entry point: tier-1 test suite + CLI smoke + overhead benchmark.
#
#   scripts/ci.sh          # tier-1 (fast) tests + CLI smoke
#   scripts/ci.sh --full   # also the slow zoo cases and the overhead bench
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

FULL=0
[[ "${1:-}" == "--full" ]] && FULL=1

echo "== tier-1 tests =="
if [[ "$FULL" == 1 ]]; then
    python -m pytest -x -q
else
    python -m pytest -x -q -m "not slow"
fi

echo "== CLI smoke =="
STORE="$(mktemp -d)"
trap 'rm -rf "$STORE"' EXIT
export MAGNETON_STORE="$STORE"
python -m repro.cli cases > /dev/null
python -m repro.cli capture c6-matpow:ineff c6-matpow:eff
python -m repro.cli compare c6-matpow:ineff c6-matpow:eff \
    --json "$STORE/rep.json" --expect-waste > /dev/null
# compare by bare artifact key (zoo provenance re-attach path)
mapfile -t KEYS < <(cd "$STORE" && ls ./*.npz | sed 's|^\./||; s|\.npz$||')
python -m repro.cli compare "${KEYS[0]}" "${KEYS[1]}" \
    --output-rtol 0.05 > /dev/null
python -m repro.cli report "$STORE/rep.json" > /dev/null
python -m repro.cli rank c6-matpow:ineff c6-matpow:eff \
    --json "$STORE/rank.json" > /dev/null
python -m repro.cli report "$STORE/rank.json" > /dev/null
python -m repro.cli artifacts > /dev/null
echo "CLI smoke OK"

if [[ "$FULL" == 1 ]]; then
    echo "== overhead benchmark (BENCH_overhead.json) =="
    PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
        python benchmarks/fig10_overhead.py
fi

echo "CI OK"
