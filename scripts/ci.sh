#!/usr/bin/env bash
# CI entry point: tier-1 tests + CLI smoke + baseline drift gate + benches.
#
#   scripts/ci.sh          # fast tests + CLI smoke + baseline-check (subset)
#   scripts/ci.sh --full   # everything: slow tests, all 20 baselines, bench
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

FULL=0
[[ "${1:-}" == "--full" ]] && FULL=1

# fast-lane subset for the baseline drift gate (cheap, structurally varied:
# matmul algorithm, redundant recompute, layout, collective); --full replays
# every committed baseline
BASELINE_CASES=(c6-matpow c15-expm c12-ln-layout c9-join-psum)

echo "== tier-1 tests =="
if [[ "$FULL" == 1 ]]; then
    python -m pytest -x -q
else
    python -m pytest -x -q -m "not slow"
fi

echo "== CLI smoke =="
STORE="$(mktemp -d)"
trap 'rm -rf "$STORE"' EXIT
export MAGNETON_STORE="$STORE"
python -m repro.cli cases > /dev/null
python -m repro.cli capture c6-matpow:ineff c6-matpow:eff
python -m repro.cli compare c6-matpow:ineff c6-matpow:eff \
    --json "$STORE/rep.json" --expect-waste > /dev/null
# compare by bare artifact key (zoo provenance re-attach path)
mapfile -t KEYS < <(cd "$STORE" && ls ./*.npz | sed 's|^\./||; s|\.npz$||')
python -m repro.cli compare "${KEYS[0]}" "${KEYS[1]}" \
    --output-rtol 0.05 > /dev/null
python -m repro.cli report "$STORE/rep.json" > /dev/null
python -m repro.cli rank c6-matpow:ineff c6-matpow:eff \
    --json "$STORE/rank.json" > /dev/null
python -m repro.cli report "$STORE/rank.json" > /dev/null
python -m repro.cli artifacts > /dev/null
python -m repro.cli artifacts prune --keep-latest 2 > /dev/null
echo "CLI smoke OK"

echo "== baseline-check (golden artifact replay) =="
# Copy the COMMITTED expectations aside, record fresh golden artifacts next
# to them, then (1) the live check diffs fresh findings against the
# committed JSONs and (2) the offline check replays matching+classification+
# diagnosis purely from the persisted artifacts — zero instrumented
# execution — and must also be drift-free.
BDIR="$(mktemp -d)"
trap 'rm -rf "$STORE" "$BDIR"' EXIT
cp tests/baselines/*.json "$BDIR"/
ARGS=()
[[ "$FULL" == 1 ]] || ARGS=("${BASELINE_CASES[@]}")
python -m repro.cli baseline check --dir "$BDIR" "${ARGS[@]}"
python -m repro.cli baseline check --dir "$BDIR" --offline "${ARGS[@]}"
echo "baseline-check OK"

if [[ "$FULL" == 1 ]]; then
    echo "== overhead benchmark (BENCH_overhead.json) =="
    PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
        python benchmarks/fig10_overhead.py
fi

echo "CI OK"
