"""CI gate for the detect→transform→verify loop (repro.optimize).

Runs the FULL generated scenario matrix (every clean program x every
applicable mutation): each mutant is captured, compared against its clean
twin, diagnosed (the subkind must name the planted class), and optimized
with the diagnosed inverse rewrite.  Gates:

  * every one of the 8 waste classes is invertible on every scenario where
    the mutation applies: the diagnosed inverse yields a candidate that is
    verified EQUIVALENT (detector's own gate) and STRICTLY cheaper,
  * the diagnosed subkind matches the planted mutation class on every
    scenario,
  * one N>>2 demo: a mutant optimized under ALL rewrites ranks target +
    survivors in a single waste matrix.

Emits BENCH_optimize.json with per-class win margins (min/mean/max % win
and the per-scenario table) for trend tracking.
"""

import json
import statistics
import sys
import time

sys.path.insert(0, "src")

from repro.core.session import Session                       # noqa: E402
from repro.optimize import optimize                          # noqa: E402
from repro.testing.mutate import (MUTATIONS,                 # noqa: E402
                                  generate_scenarios)


def main() -> int:
    t0 = time.time()
    session = Session()
    scenarios = generate_scenarios()
    assert len(scenarios) >= 20, \
        f"scenario matrix shrank to {len(scenarios)} pairs"
    covered = {sc.mutation.name for sc in scenarios}
    assert covered == set(MUTATIONS), \
        f"classes with no applicable scenario: {set(MUTATIONS) - covered}"

    clean_arts, clean_args = {}, {}
    rows = []
    failures = []
    for sc in scenarios:
        pname = sc.program.name
        if pname not in clean_arts:
            clean_args[pname] = sc.program.make_args()
            clean_arts[pname] = session.capture(
                sc.program.fn, clean_args[pname], name=pname)
        args = clean_args[pname]
        clean = clean_arts[pname]
        row = {"scenario": sc.id, "class": sc.mutation.name,
               "program": pname, "sites": sc.sites}
        rows.append(row)
        try:
            mut_art = session.capture(sc.mutant, args,
                                      name=sc.mutant.__name__)
            rep = session.compare(mut_art, clean, output_rtol=1e-2)
            waste = [f for f in rep.waste_findings
                     if f.wasteful_side == "A"]
            diag = next((f.diagnosis for f in waste
                         if f.diagnosis
                         and f.diagnosis.subkind == sc.mutation.name), None)
            if diag is None:
                got = sorted({f.diagnosis.subkind for f in waste
                              if f.diagnosis})
                row["error"] = f"diagnosed subkinds {got}, " \
                               f"expected {sc.mutation.name!r}"
                failures.append(row)
                continue
            patch = optimize(sc.mutant, args, session=session,
                             name=sc.mutant.__name__, diagnosis=diag,
                             rewrite_names=[sc.mutation.name])
            best = patch.best
            if best is None:
                c = patch.candidates[0] if patch.candidates else None
                row["error"] = ("no verified-cheaper candidate: "
                                f"{c.status if c else '?'} "
                                f"({c.reason if c else 'no candidate'})")
                failures.append(row)
                continue
            row.update(win_pct=best.win_pct, win_j=best.win_j,
                       energy_target_j=patch.target_energy_j,
                       energy_patched_j=best.energy_j)
        except Exception as e:                 # scenario-level isolation
            row["error"] = f"{type(e).__name__}: {e}"
            failures.append(row)

    by_class = {}
    for row in rows:
        by_class.setdefault(row["class"], []).append(row)
    print("=== optimize: diagnosed-inverse verification matrix ===")
    class_margins = {}
    for cls in sorted(by_class):
        wins = [r["win_pct"] for r in by_class[cls] if "win_pct" in r]
        n = len(by_class[cls])
        if wins:
            class_margins[cls] = {
                "scenarios": n, "verified": len(wins),
                "win_pct_min": min(wins), "win_pct_max": max(wins),
                "win_pct_mean": statistics.fmean(wins)}
            print(f"{cls:22} {len(wins)}/{n} scenarios verified cheaper; "
                  f"win {min(wins):5.1f}% .. {max(wins):5.1f}% "
                  f"(mean {statistics.fmean(wins):5.1f}%)")
        else:
            class_margins[cls] = {"scenarios": n, "verified": 0}
            print(f"{cls:22} 0/{n} scenarios verified")
    for row in failures:
        print(f"    FAIL {row['scenario']}: {row['error']}")

    # N>>2 demo: one mutant under ALL rewrites, ranked in a single matrix
    demo_sc = next(sc for sc in scenarios
                   if sc.id == "layout_thrash:rmsnorm_linear")
    demo = optimize(demo_sc.mutant, clean_args[demo_sc.program.name],
                    session=session, name=demo_sc.mutant.__name__,
                    subkind="layout_thrash")
    assert demo.best is not None \
        and demo.best.inverts == "layout_thrash", "N-way demo lost its win"
    assert "rank_matrix" in demo.meta, "N-way demo produced no rank matrix"
    n_ranked = len(demo.meta["rank_matrix"]["names"])
    print(f"N-way demo: {len(demo.candidates)} rewrites proposed, "
          f"{n_ranked} candidates ranked, best "
          f"{demo.best.rewrite} (+{demo.best.win_pct:.1f}%)")

    bench = {"bench": "optimize",
             "scenarios": len(rows),
             "verified": sum(1 for r in rows if "win_pct" in r),
             "failures": len(failures),
             "per_class": class_margins,
             "rows": rows,
             "nway_demo": {"target": demo.target,
                           "candidates": len(demo.candidates),
                           "ranked": n_ranked,
                           "best_win_pct": demo.best.win_pct},
             "elapsed_s": round(time.time() - t0, 2)}
    with open("BENCH_optimize.json", "w") as f:
        json.dump(bench, f, indent=2)
    print(f"wrote BENCH_optimize.json ({bench['verified']}/"
          f"{bench['scenarios']} scenarios verified, "
          f"{bench['elapsed_s']}s)")

    if failures:
        print(f"optimize check FAILED: {len(failures)} scenarios did not "
              "verify")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
