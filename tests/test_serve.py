"""Serve engine: continuous batching, slot reuse, stats, decode parity."""

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as tf
from repro.serve.engine import EngineConfig, Request, ServeEngine


@pytest.fixture(scope="module")
def engine():
    cfg = configs.get_config("gpt2-small").reduced(num_layers=2)
    params = tf.model_init(cfg, jax.random.key(0))
    return cfg, ServeEngine(cfg, params,
                            ecfg=EngineConfig(batch_size=2, max_len=48))


def test_generate_fills_all_requests(engine):
    _, eng = engine
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, 256, 8, dtype=np.int32),
                    max_new_tokens=4) for i in range(5)]
    eng.generate(reqs)
    for r in reqs:
        assert len(r.generated) == 4
    # 5 requests at batch 2 -> 3 prefill waves
    assert eng.stats["prefill_calls"] >= 3
    assert eng.stats["tokens_generated"] >= 20


def test_variable_prompt_lengths_left_padded(engine):
    _, eng = engine
    rng = np.random.default_rng(1)
    reqs = [Request(rid=0, prompt=rng.integers(0, 256, 4, dtype=np.int32),
                    max_new_tokens=3),
            Request(rid=1, prompt=rng.integers(0, 256, 9, dtype=np.int32),
                    max_new_tokens=3)]
    eng.generate(reqs)
    assert all(len(r.generated) == 3 for r in reqs)


def test_greedy_determinism(engine):
    cfg, eng = engine
    prompt = np.arange(8, dtype=np.int32)
    a = Request(rid=0, prompt=prompt, max_new_tokens=5)
    b = Request(rid=1, prompt=prompt.copy(), max_new_tokens=5)
    eng.generate([a])
    eng.generate([b])
    assert a.generated == b.generated
