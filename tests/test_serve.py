"""Serve engine: continuous batching, slot reuse, stats, decode parity."""

import time

import jax
import numpy as np
import pytest

from repro import configs
from repro.core.faults import SimulatedCrash
from repro.models import transformer as tf
from repro.serve.engine import EngineConfig, Request, ServeEngine


@pytest.fixture(scope="module")
def engine():
    cfg = configs.get_config("gpt2-small").reduced(num_layers=2)
    params = tf.model_init(cfg, jax.random.key(0))
    return cfg, ServeEngine(cfg, params,
                            ecfg=EngineConfig(batch_size=2, max_len=48))


def test_generate_fills_all_requests(engine):
    _, eng = engine
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, 256, 8, dtype=np.int32),
                    max_new_tokens=4) for i in range(5)]
    eng.generate(reqs)
    for r in reqs:
        assert len(r.generated) == 4
    # 5 requests at batch 2 -> 3 prefill waves
    assert eng.stats["prefill_calls"] >= 3
    assert eng.stats["tokens_generated"] >= 20


def test_variable_prompt_lengths_left_padded(engine):
    _, eng = engine
    rng = np.random.default_rng(1)
    reqs = [Request(rid=0, prompt=rng.integers(0, 256, 4, dtype=np.int32),
                    max_new_tokens=3),
            Request(rid=1, prompt=rng.integers(0, 256, 9, dtype=np.int32),
                    max_new_tokens=3)]
    eng.generate(reqs)
    assert all(len(r.generated) == 3 for r in reqs)


def test_greedy_determinism(engine):
    cfg, eng = engine
    prompt = np.arange(8, dtype=np.int32)
    a = Request(rid=0, prompt=prompt, max_new_tokens=5)
    b = Request(rid=1, prompt=prompt.copy(), max_new_tokens=5)
    eng.generate([a])
    eng.generate([b])
    assert a.generated == b.generated


# -- audit error boundary -----------------------------------------------------

def _small_ecfg(**kw):
    return EngineConfig(batch_size=2, max_len=48, **kw)


def test_engine_default_configs_are_independent(engine):
    """Regression: a mutable `ecfg=EngineConfig()` dataclass-style default
    aliased one config object across every engine construction."""
    cfg, eng = engine
    e1 = ServeEngine(cfg, eng.params)
    e2 = ServeEngine(cfg, eng.params)
    assert e1.ecfg is not e2.ecfg
    e1.ecfg.batch_size = 99
    assert e2.ecfg.batch_size == EngineConfig().batch_size
    assert EngineConfig().batch_size != 99
    assert eng.ecfg.batch_size == 2            # module engine untouched


def test_audit_crash_counts_and_opens_breaker(engine, monkeypatch):
    cfg, base = engine
    eng = ServeEngine(cfg, base.params,
                      ecfg=_small_ecfg(audit_breaker_threshold=2))

    def boom(**kw):
        raise SimulatedCrash("audit process died")

    monkeypatch.setattr(eng, "energy_report", boom)
    assert eng.audit() is None                 # never raises
    assert eng.stats["audit_failures"] == 1
    assert not eng.stats["audit_breaker_open"]
    assert eng.audit() is None
    assert eng.stats["audit_breaker_open"]     # threshold reached
    assert "SimulatedCrash" in eng.stats["audit_last_error"]

    assert eng.audit() is None                 # breaker open: not attempted
    assert eng.stats["audit_calls"] == 2
    assert eng.stats["audit_skipped"] == 1

    eng.reset_audit_breaker()
    monkeypatch.setattr(eng, "energy_report", lambda **kw: None)
    eng.audit()
    assert eng.stats["audit_ok"] == 1
    assert eng.stats["audit_consecutive_failures"] == 0


def test_audit_watchdog_timeout(engine, monkeypatch):
    cfg, base = engine
    eng = ServeEngine(cfg, base.params, ecfg=_small_ecfg())
    monkeypatch.setattr(eng, "energy_report",
                        lambda **kw: time.sleep(2.0))
    assert eng.audit(timeout_s=0.05) is None
    assert eng.stats["audit_timeouts"] == 1
    assert "watchdog" in eng.stats["audit_last_error"]


def test_serving_survives_force_killed_audit(engine, monkeypatch):
    """Smoke test from the issue: the audit force-killed out from under the
    engine; every request still completes and the breaker is open."""
    cfg, base = engine
    eng = ServeEngine(cfg, base.params,
                      ecfg=_small_ecfg(audit_breaker_threshold=1))

    def killed(**kw):
        raise SimulatedCrash("audit force-killed")

    monkeypatch.setattr(eng, "energy_report", killed)
    assert eng.audit() is None
    assert eng.stats["audit_breaker_open"]

    rng = np.random.default_rng(2)
    reqs = [Request(rid=i, prompt=rng.integers(0, 256, 6, dtype=np.int32),
                    max_new_tokens=4) for i in range(4)]
    eng.generate(reqs)
    assert all(len(r.generated) == 4 for r in reqs)
    assert eng.stats["audit_breaker_open"]     # still open, serving unharmed
    assert eng.audit() is None
    assert eng.stats["audit_skipped"] == 1
