"""Energy-regression harness: golden baselines, the pytest plugin, and
mutation-based detector validation (repro.testing).

Acceptance properties:
  * every recorded zoo baseline replays OFFLINE (no instrumented execution)
    with zero drift,
  * the committed expectations under tests/baselines/ agree with a fresh
    record of the same cases,
  * >= 4 mutation classes are each detected AND correctly classified on
    >= 2 distinct clean programs (>= 8 generated scenarios), with
    misclassifications reported per class,
  * assert_no_energy_regression records, passes clean re-captures, and
    fails mutated candidates with an actionable message.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.interp as interp
from repro.core.diagnose import DIAGNOSIS_KINDS
from repro.testing.baselines import (Baseline, BaselineStore, diff_baselines)
from repro.testing.mutate import (MUTATIONS, InapplicableMutationError,
                                  clean_programs, generate_scenarios,
                                  make_mutant, validate_detector)
from repro.testing.pytest_plugin import assert_no_energy_regression
from repro.zoo import cases as zoo

COMMITTED_DIR = Path(__file__).parent / "baselines"


# ---------------------------------------------------------------------------
# golden baselines: offline replay with zero drift
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_all_zoo_baselines_replay_offline_with_zero_drift(golden, monkeypatch):
    """Every recorded case re-compares bit-identically from its golden
    artifacts — with the instrumented interpreter provably never invoked."""
    golden["records"].record_all()            # lazy fixture: force full zoo
    def forbid(*a, **k):
        raise AssertionError("offline replay executed a candidate")

    monkeypatch.setattr(interp, "run_instrumented", forbid)
    store = BaselineStore(golden["root"])     # fresh store: disk only
    drifts = store.check_all(zoo.list_cases(), offline=True)
    bad = {cid: [str(d) for d in ds] for cid, ds in drifts.items() if ds}
    assert not bad, f"golden replay drifted: {json.dumps(bad, indent=2)}"


@pytest.mark.slow
def test_committed_baselines_match_fresh_record(golden):
    """The expectations committed under tests/baselines/ are what recording
    produces today — i.e. the detector has not drifted since they were
    blessed.  A legitimate behavior change re-records via
    `python -m repro.cli baseline record`."""
    problems = []
    for case in zoo.list_cases():
        path = COMMITTED_DIR / f"{case.id}.json"
        if not path.exists():
            problems.append(f"{case.id}: no committed baseline at {path}")
            continue
        committed = Baseline.from_json(path.read_text())
        fresh = golden["records"][case.id]["baseline"]
        problems.extend(str(d) for d in diff_baselines(committed, fresh))
    assert not problems, "committed baselines drifted:\n  " + \
        "\n  ".join(problems)


def test_baseline_detects_planted_drift(tmp_path):
    """A baseline records the EXPECTED findings: swapping a case's twins
    (so the efficient side is captured as A) must show up as drift."""
    import dataclasses

    case = zoo.get_case("c6-matpow")
    store = BaselineStore(tmp_path)
    store.record(case)
    assert store.check(case, offline=True) == []
    swapped = dataclasses.replace(case, inefficient=case.efficient,
                                  efficient=case.inefficient)
    drifts = store.check(swapped)             # live re-capture of the swap
    fields = {d.field for d in drifts}
    assert fields & {"detected", "waste_findings", "waste[0].wasteful_side"}, \
        f"swapped twins produced no structural drift: {fields}"


def test_offline_check_reports_unmaterialized_fetch_as_drift(tmp_path):
    """A replay that needs phase-2 evidence the golden store never recorded
    is changed matcher behavior — reported as drift, never as advice to
    re-record (which would bless the change unseen)."""
    import json as _json

    case = zoo.get_case("c6-matpow")
    store = BaselineStore(tmp_path)
    store.record(case)
    idx = _json.loads(store.index_path.read_text())
    key = idx[case.id]["a"]
    art = store.artifacts.load(key)
    # the record-time compare persisted its phase-2 decisions (sketch-only:
    # value digests + unfolding spectra, no raw chunks)
    assert art.value_index and not art.values
    # simulate a widened fetch set: strip every recorded decision, so the
    # replay must fetch raw values that were never persisted
    art.value_index.clear()
    art.spectra_memo.clear()
    store.artifacts.save(art)
    drifts = store.check(case, offline=True)
    assert [d.field for d in drifts] == ["offline_replay"]


def test_missing_baseline_raises_with_instructions(tmp_path):
    from repro.testing.baselines import MissingBaselineError

    store = BaselineStore(tmp_path)
    with pytest.raises(MissingBaselineError, match="baseline record"):
        store.check(zoo.get_case("c6-matpow"))


# ---------------------------------------------------------------------------
# mutation-based detector validation
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mutation_validation():
    return validate_detector(generate_scenarios())


def test_scenario_space_breadth(mutation_validation):
    """The generated scenario space covers the full taxonomy: all 8
    mutation classes generate scenarios, each on >= 2 distinct clean
    programs, >= 20 scenarios overall — and every expected kind is a real
    taxonomy member."""
    res = mutation_validation
    assert len(MUTATIONS) == 8
    assert len(res.results) >= 20
    per_class = res.by_class()
    assert set(per_class) == set(MUTATIONS), \
        f"classes with no generated scenario: {set(MUTATIONS) - set(per_class)}"
    narrow = {cls for cls, rs in per_class.items()
              if len({r.program for r in rs}) < 2}
    assert not narrow, f"classes with <2 programs: {sorted(narrow)}"
    for cls in MUTATIONS.values():
        assert cls.expected_kinds
        assert set(cls.expected_kinds) <= set(DIAGNOSIS_KINDS)


def test_mutants_detected_and_correctly_classified(mutation_validation):
    """All 8 classes detected AND correctly root-caused on >= 2 programs
    each; misclassified scenarios (if any) are reported per class in the
    failure message."""
    res = mutation_validation
    assert res.validated_classes(min_programs=2) == set(MUTATIONS), \
        res.summary()
    # this repo's detector currently clears the whole matrix — hold the line
    assert not res.misclassified(), res.summary()


def test_new_waste_classes_target_the_planted_constructs():
    """The PR-4 taxonomy additions hit their intended sites: scan_body only
    rewrites scans with body matmuls, layout_thrash round-trips matmul
    operands, storage_upcast only fires on bf16 non-matmul ops."""
    progs = {p.name: p for p in clean_programs()}

    scan_prog = progs["scan_mlp"]
    args = scan_prog.make_args()
    mutant, sites = make_mutant(scan_prog.fn, MUTATIONS["scan_body"](), args)
    assert sites == 1                          # one scan super-node
    want = np.asarray(scan_prog.fn(*args))
    np.testing.assert_array_equal(np.asarray(mutant(*args)), want)

    # no scan -> no site, and the refusal says why
    mlp = progs["mlp_swiglu"]
    with pytest.raises(InapplicableMutationError,
                       match="no applicable site"):
        make_mutant(mlp.fn, MUTATIONS["scan_body"](), mlp.make_args())

    # layout_thrash: bitwise-identical values, one site per dot
    args = mlp.make_args()
    mutant, sites = make_mutant(mlp.fn, MUTATIONS["layout_thrash"](), args)
    assert sites == 3
    np.testing.assert_array_equal(np.asarray(mutant(*args)),
                                  np.asarray(mlp.fn(*args)))

    # storage_upcast: fires on the bf16 program, never on f32 ones
    bf16 = progs["act_chain_bf16"]
    args = bf16.make_args()
    mutant, sites = make_mutant(bf16.fn, MUTATIONS["storage_upcast"](), args)
    assert sites >= 2
    got = np.asarray(mutant(*args), dtype=np.float32)
    want = np.asarray(bf16.fn(*args), dtype=np.float32)
    np.testing.assert_allclose(got, want, rtol=1e-2, atol=1e-2)
    with pytest.raises(InapplicableMutationError,
                       match="not uniformly.*bf16|bf16"):
        make_mutant(mlp.fn, MUTATIONS["storage_upcast"](), mlp.make_args())


def test_mutants_preserve_semantics():
    """One scenario per class: the mutant computes the same function (it
    must pass the equivalence gate, not dodge it) and rewrites >= 1 site."""
    prog = clean_programs()[3]                # gelu_dense: dot + tanh
    args = prog.make_args()
    want = np.asarray(prog.fn(*args))
    seen = set()
    for name, cls in MUTATIONS.items():
        mutant, sites = make_mutant(prog.fn, cls(), args,
                                    allow_zero_sites=True)
        if sites == 0:
            continue
        seen.add(name)
        got = np.asarray(mutant(*args))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5,
                                   err_msg=name)
    assert len(seen) >= 4


def test_mutation_max_sites_bounds_rewrites():
    prog = clean_programs()[0]                # mlp: 3 dot sites
    args = prog.make_args()
    _, all_sites = make_mutant(prog.fn, MUTATIONS["redundant_recompute"](),
                               args)
    assert all_sites == 3
    _, capped = make_mutant(
        prog.fn, MUTATIONS["redundant_recompute"](max_sites=1), args)
    assert capped == 1


# ---------------------------------------------------------------------------
# pytest plugin: assert_no_energy_regression + energy_regression marker
# ---------------------------------------------------------------------------

def _norm_prog():
    from repro.models import layers

    k1, k2 = jax.random.split(jax.random.key(42))
    x = jax.random.normal(k1, (64, 128), jnp.float32)
    scale = jax.random.normal(k2, (128,), jnp.float32) * 0.1 + 1.0

    def rms_norm_candidate(x, scale):
        return layers.rms_norm(x, scale)

    return rms_norm_candidate, (x, scale)


def test_energy_gate_records_then_passes(tmp_path):
    fn, args = _norm_prog()
    path = tmp_path / "norm.npz"
    assert assert_no_energy_regression(fn, args, path, record=True) is None
    assert path.exists()
    # identical re-capture: bit-identical content address, clean pass
    assert assert_no_energy_regression(fn, args, path) is None


def test_energy_gate_fails_on_injected_regression(tmp_path):
    fn, args = _norm_prog()
    path = tmp_path / "norm.npz"
    assert_no_energy_regression(fn, args, path, record=True)
    # inapplicable mutations refuse loudly instead of minting a clean twin
    for inapplicable in ("oversized_padding",   # no matmul in rms_norm
                         "op_split",            # rsqrt is not split
                         "sync_in_loop"):
        with pytest.raises(InapplicableMutationError):
            make_mutant(fn, MUTATIONS[inapplicable](), args)
    # recompute has no dot either -> plant the waste by hand: double work
    def regressed(x, scale):
        a = fn(x, scale)
        b = fn(x + 0.0, scale)
        return a * 0.5 + b * 0.5

    with pytest.raises(pytest.fail.Exception, match="energy regression"):
        assert_no_energy_regression(regressed, args, path, name="regressed")


def test_energy_gate_passes_on_improvement(tmp_path):
    fn, args = _norm_prog()

    def wasteful(x, scale):
        a = fn(x, scale)
        b = fn(x + 0.0, scale)
        return a * 0.5 + b * 0.5

    path = tmp_path / "wasteful.npz"
    assert_no_energy_regression(wasteful, args, path, record=True)
    report = assert_no_energy_regression(fn, args, path, name="improved")
    assert report is not None                 # compared, and came out cheaper
    assert all(f.wasteful_side != "A" for f in report.waste_findings)


def test_energy_gate_missing_baseline_instructs(tmp_path):
    fn, args = _norm_prog()
    with pytest.raises(pytest.fail.Exception,
                       match="MAGNETON_RECORD_BASELINES"):
        assert_no_energy_regression(fn, args, tmp_path / "nope.npz",
                                    record=False)


@pytest.mark.energy_regression
def test_energy_gate_marker_and_fixture(energy_gate, tmp_path):
    """In-suite usage shape: a marked test gating a src/repro kernel via the
    `energy_gate` fixture (redirected to a tmp baseline dir here)."""
    fn, args = _norm_prog()
    energy_gate(fn, args, baseline="rms_norm_gate", record=True,
                baseline_dir=tmp_path)
    assert (tmp_path / "kernels" / "rms_norm_gate.npz").exists()
    energy_gate(fn, args, baseline="rms_norm_gate", baseline_dir=tmp_path)


def test_energy_regression_marker_registered(request):
    assert any("energy_regression" in m
               for m in request.config.getini("markers"))


# ---------------------------------------------------------------------------
# CLI: baseline record / check --offline
# ---------------------------------------------------------------------------

def _cli(tmp_path, *argv):
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env["MAGNETON_STORE"] = str(tmp_path / "store")
    return subprocess.run([sys.executable, "-m", "repro.cli", *argv],
                          capture_output=True, text=True, env=env,
                          cwd=root, timeout=600)


@pytest.mark.slow
def test_cli_baseline_record_and_offline_check(tmp_path):
    bdir = tmp_path / "baselines"
    r = _cli(tmp_path, "baseline", "record", "--dir", str(bdir), "c6-matpow")
    assert r.returncode == 0, r.stderr
    assert "recorded c6-matpow" in r.stdout
    assert (bdir / "c6-matpow.json").exists()

    r = _cli(tmp_path, "baseline", "check", "--dir", str(bdir), "--offline",
             "c6-matpow")
    assert r.returncode == 0, r.stderr
    assert "ok    c6-matpow" in r.stdout
    assert "1/1 cases clean" in r.stdout

    # checking a case that was never recorded exits 2 with instructions
    r = _cli(tmp_path, "baseline", "check", "--dir", str(bdir), "--offline",
             "c15-expm")
    assert r.returncode == 2
    assert "baseline record" in r.stderr
