"""OpGraph extraction: inlining, dataflow, call paths, between-sets."""

import jax
import jax.numpy as jnp
import pytest

from repro.core.graph import trace


def test_basic_extraction():
    def f(x, y):
        return jnp.tanh(x @ y) + 1.0

    g = trace(f, jnp.ones((4, 8)), jnp.ones((8, 4)))
    prims = [n.primitive for n in g.nodes]
    assert "dot_general" in prims
    assert "tanh" in prims
    assert len(g.inputs) == 2
    assert len(g.outputs) == 1


def test_jit_calls_are_inlined():
    """jax.nn helpers wrap bodies in `jit` eqns; the graph must inline them."""
    def f(x):
        return jax.nn.one_hot(jnp.argmax(x, -1), 7)

    g = trace(f, jnp.ones((3, 7)))
    prims = {n.primitive for n in g.nodes}
    assert "jit" not in prims and "pjit" not in prims
    assert "argmax" in prims


def test_dataflow_producer_consumer():
    def f(x):
        a = x * 2.0
        b = a + 1.0
        return b

    g = trace(f, jnp.ones((4,)))
    mul = next(n for n in g.nodes if n.primitive == "mul")
    add = next(n for n in g.nodes if n.primitive == "add")
    assert g.successors(mul.idx) == [add.idx]
    assert g.predecessors(add.idx) == [mul.idx]


def test_call_paths_recorded():
    def inner(x):
        return jnp.exp(x)

    def f(x):
        return inner(x) + 1

    g = trace(f, jnp.ones((3,)))
    exp = next(n for n in g.nodes if n.primitive == "exp")
    assert any("inner" in frame for frame in exp.call_path)


def test_between_set_with_multi_output():
    """A sink tensor with downstream consumers must not orphan nodes."""
    def f(x):
        a = jnp.tanh(x)          # output 1, also consumed below
        b = (a * a).sum()        # output 2
        return a, b

    g = trace(f, jnp.ones((4,)))
    nodes = g.subgraph_nodes_between(set(g.inputs), set(g.outputs))
    prims = {g.nodes[n].primitive for n in nodes}
    assert "mul" in prims and "reduce_sum" in prims and "tanh" in prims


def test_scan_is_supernode():
    def f(x):
        def body(c, _):
            return c * 1.1, c
        return jax.lax.scan(body, x, None, length=5)

    g = trace(f, jnp.ones((3,)))
    assert any(n.primitive == "scan" for n in g.nodes)


def test_constants_marked():
    def f(x):
        return x + jnp.arange(4.0)

    g = trace(f, jnp.ones((4,)))
    assert any(t.is_const or g.nodes[t.producer].primitive == "iota"
               for t in g.tensors.values() if t.producer is not None
               or t.is_const)


def test_block_structure_detects_layer_family():
    """A deep stack of identical layers must be found as one repeated-block
    family covering (almost) the whole graph."""
    from repro.core.graph import block_structure

    def fn(x):
        for _ in range(12):
            x = jnp.tanh(x * 1.01) + 0.5 * x
        return x.sum()

    g = trace(fn, jnp.arange(16.0).reshape(4, 4) / 10.0)
    bs = block_structure(g)
    assert bs.families, "no repeated-block family detected"
    best = max(bs.families, key=lambda f: f.period * f.count)
    assert best.count >= 10
    assert best.period * best.count >= 0.7 * len(g.nodes)


def test_block_digests_stable_under_jaxpr_roundtrip():
    """Canonical per-node digests and family spans must be identical when
    the same program is re-extracted from its closed jaxpr — the stamper's
    cross-graph induction depends on digest stability across traces."""
    from repro.core.graph import block_structure, extract_graph

    def fn(x, w):
        for _ in range(8):
            x = (jnp.tanh(x @ w) + 0.5 * x) * 1.01
        return x.sum()

    x = jnp.arange(32.0).reshape(4, 8) / 10.0
    w = jnp.eye(8) * 0.9
    g1 = trace(fn, x, w)
    g2 = extract_graph(g1.closed_jaxpr)
    g3 = trace(fn, x, w)               # independent re-trace
    bs1, bs2, bs3 = (block_structure(g) for g in (g1, g2, g3))
    assert bs1.struct_digests == bs2.struct_digests == bs3.struct_digests
    assert bs1.op_digests == bs2.op_digests == bs3.op_digests
    fams = lambda bs: [(f.start, f.period, f.count) for f in bs.families]
    assert fams(bs1) == fams(bs2) == fams(bs3)


def test_between_sparse_matches_python_reference(monkeypatch):
    """The scipy-BFS between-set fast path must return exactly the python
    reference's node list on assorted frontiers (the subgraph matcher's
    region growth is built on this set)."""
    import repro.core.graph as G

    if G._bfs_order is None:
        pytest.skip("scipy unavailable")

    def fn(x, w):
        for _ in range(40):
            x = (jnp.tanh(x @ w) + 0.5 * x) * 1.01
        return x.sum()

    x = jnp.arange(32.0).reshape(4, 8) / 10.0
    w = jnp.eye(8) * 0.9
    g = trace(fn, x, w)
    mid1 = g.nodes[len(g.nodes) // 3].outvars[0]
    mid2 = g.nodes[2 * len(g.nodes) // 3].outvars[0]
    frontiers = [
        (set(g.inputs), set(g.outputs)),
        ({g.inputs[0]}, set(g.outputs)),
        (set(g.inputs), {mid2}),
        ({mid1}, {mid2}),
        ({mid2}, {mid1}),                  # empty: sink upstream of source
    ]
    for src, dst in frontiers:
        fast = g._between_sparse(src, dst)
        monkeypatch.setattr(G, "_bfs_order", None)   # force python path
        slow = g.subgraph_nodes_between(src, dst)
        monkeypatch.undo()
        assert fast == slow, (src, dst)
