"""OpGraph extraction: inlining, dataflow, call paths, between-sets."""

import jax
import jax.numpy as jnp
import pytest

from repro.core.graph import trace


def test_basic_extraction():
    def f(x, y):
        return jnp.tanh(x @ y) + 1.0

    g = trace(f, jnp.ones((4, 8)), jnp.ones((8, 4)))
    prims = [n.primitive for n in g.nodes]
    assert "dot_general" in prims
    assert "tanh" in prims
    assert len(g.inputs) == 2
    assert len(g.outputs) == 1


def test_jit_calls_are_inlined():
    """jax.nn helpers wrap bodies in `jit` eqns; the graph must inline them."""
    def f(x):
        return jax.nn.one_hot(jnp.argmax(x, -1), 7)

    g = trace(f, jnp.ones((3, 7)))
    prims = {n.primitive for n in g.nodes}
    assert "jit" not in prims and "pjit" not in prims
    assert "argmax" in prims


def test_dataflow_producer_consumer():
    def f(x):
        a = x * 2.0
        b = a + 1.0
        return b

    g = trace(f, jnp.ones((4,)))
    mul = next(n for n in g.nodes if n.primitive == "mul")
    add = next(n for n in g.nodes if n.primitive == "add")
    assert g.successors(mul.idx) == [add.idx]
    assert g.predecessors(add.idx) == [mul.idx]


def test_call_paths_recorded():
    def inner(x):
        return jnp.exp(x)

    def f(x):
        return inner(x) + 1

    g = trace(f, jnp.ones((3,)))
    exp = next(n for n in g.nodes if n.primitive == "exp")
    assert any("inner" in frame for frame in exp.call_path)


def test_between_set_with_multi_output():
    """A sink tensor with downstream consumers must not orphan nodes."""
    def f(x):
        a = jnp.tanh(x)          # output 1, also consumed below
        b = (a * a).sum()        # output 2
        return a, b

    g = trace(f, jnp.ones((4,)))
    nodes = g.subgraph_nodes_between(set(g.inputs), set(g.outputs))
    prims = {g.nodes[n].primitive for n in nodes}
    assert "mul" in prims and "reduce_sum" in prims and "tanh" in prims


def test_scan_is_supernode():
    def f(x):
        def body(c, _):
            return c * 1.1, c
        return jax.lax.scan(body, x, None, length=5)

    g = trace(f, jnp.ones((3,)))
    assert any(n.primitive == "scan" for n in g.nodes)


def test_constants_marked():
    def f(x):
        return x + jnp.arange(4.0)

    g = trace(f, jnp.ones((4,)))
    assert any(t.is_const or g.nodes[t.producer].primitive == "iota"
               for t in g.tensors.values() if t.producer is not None
               or t.is_const)
