"""Sharding rules: divisibility fallbacks, axis collision handling, and the
production mesh contract (without forcing 512 devices here)."""

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.sharding.rules import GLOBAL_RULES, ShardingRules


class FakeMesh:
    """Duck-typed stand-in so rules can be tested against a 16x16 mesh
    without 512 host devices (rules only read axis_names and shape)."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


SINGLE = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_batch_shards_on_data():
    assert GLOBAL_RULES.spec(SINGLE, ("batch", None, None),
                             (256, 4096, 1024)) == P("data")


def test_batch_shards_on_pod_data_multi():
    spec = GLOBAL_RULES.spec(MULTI, ("batch", None, None), (256, 64, 8))
    assert spec == P(("pod", "data"))


def test_indivisible_batch_falls_back():
    # batch=1 (long_500k): cannot shard 1 over 16 -> replicate
    assert GLOBAL_RULES.spec(SINGLE, ("batch", None, None),
                             (1, 8, 8)) == P()


def test_kv_heads_indivisible_falls_back():
    # kv=8 heads cannot shard over model=16 -> replicated head dim
    spec = GLOBAL_RULES.spec(SINGLE, ("batch", "kv_seq", "kv_heads", None),
                             (128, 32768, 8, 128))
    assert spec[0] == "data"
    # kv_seq rule: ('data','model') blocked (data taken) -> ('model',)
    assert spec[1] == "model"
    assert len(spec) == 2 or spec[2] is None


def test_axis_never_used_twice():
    spec = GLOBAL_RULES.spec(SINGLE, ("vocab", "embed"), (152064, 8192))
    used = [s for s in spec if s is not None]
    flat = []
    for s in used:
        flat.extend(s if isinstance(s, tuple) else (s,))
    assert len(flat) == len(set(flat))


def test_ffn_on_model_embed_on_data():
    spec = GLOBAL_RULES.spec(SINGLE, ("embed", "ffn"), (8192, 49152))
    assert spec == P("data", "model")


def test_moe_experts_shard_on_model():
    spec = GLOBAL_RULES.spec(SINGLE, ("experts", "embed", "expert_ffn"),
                             (160, 5120, 1536))
    assert spec[0] == "model"


def test_real_single_device_mesh_constrain_noop():
    """constrain() must be a no-op on the 1-device CPU mesh."""
    from repro.sharding.rules import constrain
    import jax.numpy as jnp
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    x = jnp.ones((4, 4))
    y = constrain(x, mesh, ("batch", None))
    np.testing.assert_array_equal(x, y)


def test_custom_rules_override():
    rules = ShardingRules(rules={"batch": [("model",), ()], None: [()]})
    assert rules.spec(SINGLE, ("batch",), (32,)) == P("model")
