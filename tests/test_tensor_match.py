"""Tensor semantic-equivalence matching: SVD-invariant properties.

Property-based (hypothesis): the paper's §4.2 invariant — layout
transformations (permute / reshape / transposed unfoldings) must never break
equivalence, and genuinely different tensors must not match.
"""

import numpy as np
import pytest

from _hyp import given, settings, st  # skips property tests w/o hypothesis

from repro.core.tensor_match import (TensorMatcher, bijective_pairs,
                                     signature, signatures_match)


def _rand(shape, seed):
    return np.random.default_rng(seed).standard_normal(shape).astype(np.float32)


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------

@st.composite
def shaped_array(draw):
    rank = draw(st.integers(2, 4))
    dims = [draw(st.integers(2, 6)) for _ in range(rank)]
    seed = draw(st.integers(0, 2**31 - 1))
    return _rand(tuple(dims), seed)


@settings(max_examples=40, deadline=None)
@given(shaped_array(), st.permutations(list(range(4))))
def test_permute_invariance(a, perm4):
    """Axis permutation preserves the signature match (paper Hypothesis 1)."""
    perm = [p for p in perm4 if p < a.ndim]
    b = np.transpose(a, perm)
    assert signatures_match(signature(a), signature(b))


@settings(max_examples=40, deadline=None)
@given(shaped_array())
def test_reshape_invariance(a):
    """Flattening/reshaping preserves the symmetric invariants and at least
    one common unfolding spectrum."""
    b = a.reshape(-1)
    c = a.reshape(a.shape[0], -1)
    assert signatures_match(signature(a), signature(c))
    # rank-1 has only the trivial spectrum; symmetric invariants carry it
    assert signature(a).numel == signature(b).numel


@settings(max_examples=40, deadline=None)
@given(shaped_array(), st.floats(0.2, 3.0))
def test_scaled_tensor_does_not_match(a, scale):
    """A genuinely different tensor (scaled by != 1) must not match."""
    b = a * (1.0 + scale)
    assert not signatures_match(signature(a), signature(b))


@settings(max_examples=25, deadline=None)
@given(shaped_array())
def test_noise_does_not_match(a):
    b = a + np.random.default_rng(1).standard_normal(a.shape).astype(np.float32)
    assert not signatures_match(signature(a), signature(b))


# ---------------------------------------------------------------------------
# deterministic cases from the paper
# ---------------------------------------------------------------------------

def test_hnd_vs_nhd_layout():
    """Paper's example: HuggingFace HND vs SGLang NHD attention layouts
    differ only by a permute and must be declared equivalent."""
    hnd = _rand((8, 128, 64), 0)             # (H, N, D)
    nhd = np.transpose(hnd, (1, 0, 2))       # (N, H, D)
    assert signatures_match(signature(hnd), signature(nhd))


def test_qkv_split_halves_differ():
    """Q and K projections are mathematically similar ops but different
    values; they must NOT match (the paper's context-awareness argument)."""
    q = _rand((4, 64), 1)
    k = _rand((4, 64), 2)
    assert not signatures_match(signature(q), signature(k))


def test_matcher_multi_sample_consistency():
    """Hypothesis 1: equivalence must hold across ALL input samples.
    A pair equal on sample 1 but different on sample 2 is rejected."""
    a1, b1 = _rand((4, 8), 3), None
    b1 = np.transpose(a1.reshape(4, 8))
    a2 = _rand((4, 8), 4)
    b2 = _rand((8, 4), 5)                    # different on sample 2
    m = TensorMatcher()
    pairs = m.match([{0: a1}, {0: a2}], [{0: b1}, {0: b2}])
    assert pairs == []
    pairs = m.match([{0: a1}, {0: a2}],
                    [{0: b1}, {0: np.transpose(a2)}])
    assert pairs == [(0, 0)]


def test_bijective_filter():
    assert bijective_pairs([(0, 0), (0, 1), (2, 2)]) == [(2, 2)]
    assert bijective_pairs([(0, 0), (1, 0)]) == []


def test_large_tensor_fallback():
    """Tensors above the SVD budget fall back to symmetric invariants."""
    a = _rand((1024, 1100), 6)
    sig = signature(a, max_svd_numel=1000)
    assert sig.spectra is None
    b = np.transpose(a)
    assert signatures_match(sig, signature(b, max_svd_numel=1000))


def test_integer_tensors():
    a = np.arange(24, dtype=np.int32).reshape(4, 6)
    b = np.transpose(a)
    assert signatures_match(signature(a), signature(b))
