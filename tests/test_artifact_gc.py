"""ArtifactStore garbage collection (prune) — the ROADMAP store-size-cap
follow-up.  The load-bearing property: pruning is always *safe* under
content addressing — surviving keys keep serving cache hits, pruned keys
simply re-capture."""

import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.interp as interp
from repro.core.artifact import ArtifactStore
from repro.core.session import Session


def _capture_n(session, n_fns):
    """n distinct single-op candidates -> n distinct store keys."""
    arts = []
    for i in range(n_fns):
        c = float(i + 1)
        x = jnp.asarray(np.arange(16, dtype=np.float32).reshape(4, 4))
        arts.append(session.capture(lambda x, c=c: x * c, (x,), name=f"f{i}"))
    return arts


def test_cache_hits_survive_pruning_of_unrelated_keys(tmp_path, monkeypatch):
    store = ArtifactStore(tmp_path)
    session = Session(store=store)
    arts = _capture_n(session, 3)
    assert len(store.keys()) == 3

    deleted = store.prune(keep_latest=2)
    assert deleted == [arts[0].key]           # oldest unprotected key only

    calls = {"n": 0}
    orig = interp.run_instrumented

    def spy(*a, **k):
        calls["n"] += 1
        return orig(*a, **k)

    monkeypatch.setattr(interp, "run_instrumented", spy)
    # the surviving (unrelated) keys still serve cache hits: zero execution
    x = jnp.asarray(np.arange(16, dtype=np.float32).reshape(4, 4))
    hit = session.capture(lambda x, c=3.0: x * c, (x,), name="f2")
    assert hit.meta.get("cache_hit") and calls["n"] == 0
    # the pruned key re-captures transparently
    miss = session.capture(lambda x, c=1.0: x * c, (x,), name="f0")
    assert not miss.meta.get("cache_hit") and calls["n"] > 0
    assert miss.key == arts[0].key            # same content address as before


def test_prune_max_bytes_deletes_oldest_first(tmp_path):
    store = ArtifactStore(tmp_path)
    session = Session(store=store)
    arts = _capture_n(session, 4)
    per = store.path_for(arts[0].key).stat().st_size
    deleted = store.prune(max_bytes=int(per * 2.5))
    assert deleted == [arts[0].key, arts[1].key]
    assert store.total_bytes() <= per * 2.5
    assert set(store.keys()) == {arts[2].key, arts[3].key}


def test_prune_keep_and_dry_run(tmp_path):
    store = ArtifactStore(tmp_path)
    session = Session(store=store)
    arts = _capture_n(session, 3)

    would = store.prune(max_bytes=0, keep=[arts[1].key], keep_latest=1,
                        dry_run=True)
    assert would == [arts[0].key]             # 1 protected by keep, 1 by latest
    assert len(store.keys()) == 3             # dry run deleted nothing

    deleted = store.prune(max_bytes=0, keep=[arts[1].key], keep_latest=1)
    assert deleted == [arts[0].key]
    assert set(store.keys()) == {arts[1].key, arts[2].key}


def test_prune_requires_a_bound(tmp_path):
    with pytest.raises(ValueError, match="max_bytes and/or keep_latest"):
        ArtifactStore(tmp_path).prune()


def test_cli_prune_store_flag_survives_either_position():
    """`artifacts --store X prune` must GC store X, not let the prune
    subparser's default clobber the parent-parsed value (a silent
    wrong-store deletion)."""
    from repro.cli import build_parser

    p = build_parser()
    assert p.parse_args(["artifacts", "--store", "/X", "prune"]).store == "/X"
    assert p.parse_args(["artifacts", "prune", "--store", "/Y"]).store == "/Y"
    assert p.parse_args(["artifacts", "prune"]).store is None
