"""Session/artifact API: capture-once semantics, store round-trips, N-way
ranking, pluggable backends, report JSON round-trips, and the CLI.

The acceptance-critical properties:
  * artifact save -> load -> compare reproduces the direct (legacy one-shot)
    comparison bit-identically on zoo cases,
  * a store cache hit skips every instrumented execution (spy-verified),
  * rank() over N candidates runs exactly N captures and agrees with the
    pairwise compares.
"""

import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.interp as interp
from repro.core.artifact import (ArtifactStore, ArtifactValueError,
                                 CandidateArtifact)
from repro.core.diff import DifferentialEnergyDebugger
from repro.core.energy import (AnalyticalBackend, HloCostBackend,
                               ReplayBackend, backend_from_name)
from repro.core.report import Finding, Report
from repro.core.session import RankResult, Session, _perturb
from repro.zoo import cases

ROUNDTRIP_CASES = ["c6-matpow", "c15-expm", "c12-ln-layout"]


def _count_runs(monkeypatch):
    """Spy on every instrumented execution (stats + value captures)."""
    calls = {"n": 0}
    orig = interp.run_instrumented

    def spy(*a, **k):
        calls["n"] += 1
        return orig(*a, **k)

    monkeypatch.setattr(interp, "run_instrumented", spy)
    return calls


# ---------------------------------------------------------------------------
# store round-trip == direct compare
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cid", ROUNDTRIP_CASES)
def test_artifact_roundtrip_matches_direct_compare(cid, tmp_path):
    case = cases.get_case(cid)
    direct = DifferentialEnergyDebugger().compare(
        case.inefficient, case.efficient, case.make_args(),
        name_a="ineff", name_b="eff",
        config_a=case.config_a, config_b=case.config_b,
        output_rtol=case.output_rtol)

    session = Session(store=str(tmp_path))
    art_a = session.capture(case.inefficient, case.make_args(),
                            name="ineff", config=case.config_a)
    art_b = session.capture(case.efficient, case.make_args(),
                            name="eff", config=case.config_b)
    live = session.compare(art_a, art_b, output_rtol=case.output_rtol)
    assert live.to_json() == direct.to_json()

    # fresh session, artifacts loaded from disk, NO live program attached:
    # matching replays from persisted invariants + memoized phase-2 values
    session2 = Session(store=str(tmp_path))
    la, lb = session2.load(art_a.key), session2.load(art_b.key)
    assert not la.is_live and not lb.is_live
    offline = session2.compare(la, lb, output_rtol=case.output_rtol)
    assert offline.to_json() == direct.to_json()


def test_loaded_artifact_without_values_raises(tmp_path):
    case = cases.get_case("c6-matpow")
    session = Session(store=str(tmp_path))
    art = session.capture(case.inefficient, case.make_args(), name="x")
    loaded = session.load(art.key)          # saved before any compare
    assert not loaded.is_live
    with pytest.raises(ArtifactValueError, match="re-capture"):
        loaded.fetcher()(0, sorted(loaded.graph.tensors)[:3])


# ---------------------------------------------------------------------------
# cache-hit capture skips re-execution
# ---------------------------------------------------------------------------

def test_cache_hit_skips_reexecution(tmp_path, monkeypatch):
    case = cases.get_case("c6-matpow")
    session = Session(store=str(tmp_path))

    calls = _count_runs(monkeypatch)
    art = session.capture(case.inefficient, case.make_args(), name="x")
    assert calls["n"] == session.num_input_samples      # one run per sample
    assert not art.meta.get("cache_hit")

    calls["n"] = 0
    art2 = session.capture(case.inefficient, case.make_args(), name="x")
    assert art2.meta.get("cache_hit")
    assert calls["n"] == 0                  # no instrumented execution at all
    assert art2.key == art.key
    assert art2.is_live                     # re-attached for lazy fetches

    # different sample seeds -> different content address -> full capture
    calls["n"] = 0
    art3 = session.capture(case.inefficient, case.make_args(), name="x",
                           sample_seeds=(99,))
    assert art3.key != art.key
    assert art3.sample_seeds == (99,)
    assert calls["n"] == session.num_input_samples


def test_cache_never_aliases_across_input_values(tmp_path, monkeypatch):
    """Same program + shapes but different input VALUES must re-capture:
    outputs and per-sample invariants are value-dependent."""
    import jax.numpy as jnp

    def f(x):
        return x @ x

    x1 = jnp.asarray(np.random.default_rng(0).standard_normal((16, 16)),
                     jnp.float32)
    x2 = jnp.asarray(np.random.default_rng(1).standard_normal((16, 16)),
                     jnp.float32)
    session = Session(store=str(tmp_path))
    a1 = session.capture(f, (x1,), name="f")
    calls = _count_runs(monkeypatch)
    a2 = session.capture(f, (x2,), name="f")
    assert a2.key != a1.key
    assert not a2.meta.get("cache_hit")
    assert calls["n"] == session.num_input_samples


def test_cache_never_aliases_across_closure_constants(tmp_path, monkeypatch):
    """Functions differing only in closed-over constant values (e.g. model
    weights captured via a lambda) must not collide in the store —
    str(jaxpr) prints constvars by name only."""
    import jax.numpy as jnp

    w1 = jnp.asarray(np.random.default_rng(0).standard_normal((16, 16)),
                     jnp.float32)
    w2 = jnp.asarray(np.random.default_rng(1).standard_normal((16, 16)),
                     jnp.float32)
    x = jnp.ones((4, 16), jnp.float32)
    session = Session(store=str(tmp_path))
    a1 = session.capture(lambda x: x @ w1, (x,), name="m1")
    calls = _count_runs(monkeypatch)
    a2 = session.capture(lambda x: x @ w2, (x,), name="m2")
    assert a2.key != a1.key
    assert not a2.meta.get("cache_hit")
    assert calls["n"] == session.num_input_samples


def test_capture_gate_against_fails_fast(monkeypatch):
    """gate_against raises on the sample-0 mismatch BEFORE further samples
    are captured or the graph is priced (the legacy fail-fast ordering)."""
    import jax.numpy as jnp

    x = (jnp.ones((4, 4), jnp.float32),)
    session = Session()
    art_a = session.capture(lambda x: x * 2.0, x, name="a")
    calls = _count_runs(monkeypatch)
    with pytest.raises(ValueError, match="not the same task"):
        session.capture(lambda x: x * 3.0, x, name="b", gate_against=art_a)
    assert calls["n"] == 1          # sample 0 only; samples 1.. never ran


def test_backend_id_partitions_cache(tmp_path):
    case = cases.get_case("c6-matpow")
    s_analytic = Session(store=str(tmp_path))
    s_replay = Session(backend=ReplayBackend(max_replay_iters=2),
                       store=str(tmp_path))
    a1 = s_analytic.capture(case.inefficient, case.make_args(), name="x")
    a2 = s_replay.capture(case.inefficient, case.make_args(), name="x")
    assert a1.key != a2.key
    with pytest.raises(ValueError, match="different energy backends"):
        s_analytic.compare(a1, a2)


# ---------------------------------------------------------------------------
# rank: N captures, agreement with pairwise compares
# ---------------------------------------------------------------------------

def _matpow_candidates():
    """Four candidate implementations of the zoo c6 task (a^8)."""
    case = cases.get_case("c6-matpow")

    def pow8_naive(a):
        out = a
        for _ in range(7):
            out = out @ a
        return out

    def pow8_binary(a):
        a2 = a @ a
        a4 = a2 @ a2
        return a4 @ a4

    def pow8_mixed(a):
        a2 = a @ a
        return ((a2 @ a2) @ a2) @ a2

    def pow8_semi(a):
        a2 = a @ a
        a4 = a2 @ a2
        return (a4 @ a2) @ a2

    return case.make_args(), [pow8_naive, pow8_binary, pow8_mixed, pow8_semi]


def test_rank_runs_exactly_n_captures(monkeypatch):
    args, fns = _matpow_candidates()
    session = Session()
    calls = _count_runs(monkeypatch)
    arts = [session.capture(fn, args, name=fn.__name__) for fn in fns]
    capture_runs = calls["n"]
    assert capture_runs == len(fns) * session.num_input_samples

    calls["n"] = 0
    result = session.rank(arts, output_rtol=5e-2)
    # ranking performs no additional *capture* executions: any instrumented
    # run during rank is a selective phase-2 value fetch, which retains only
    # the requested tensors — assert nothing re-captured signatures
    assert len(result.reports) == len(fns) * (len(fns) - 1) // 2
    stats_calls = {"n": 0}
    orig_stats = interp.capture_tensor_stats

    def stats_spy(*a, **k):
        stats_calls["n"] += 1
        return orig_stats(*a, **k)

    monkeypatch.setattr(interp, "capture_tensor_stats", stats_spy)
    session.rank(arts, output_rtol=5e-2)
    assert stats_calls["n"] == 0

    # the cheapest implementation wins
    assert result.best == "pow8_binary"
    assert result.total_energy_j[0] == max(result.total_energy_j)


def test_rank_agrees_with_pairwise_compares():
    args, fns = _matpow_candidates()
    fns = fns[:3]
    session = Session()
    arts = [session.capture(fn, args, name=fn.__name__) for fn in fns]
    result = session.rank(arts, output_rtol=5e-2)
    for (i, j), rep in result.reports.items():
        direct = session.compare(arts[i], arts[j], output_rtol=5e-2)
        assert rep.to_json() == direct.to_json()
    # waste matrix entries reproduce the pairwise waste findings
    for (i, j), rep in result.reports.items():
        w_ij = sum(f.energy_a_j - f.energy_b_j for f in rep.waste_findings
                   if f.wasteful_side == "A")
        w_ji = sum(f.energy_b_j - f.energy_a_j for f in rep.waste_findings
                   if f.wasteful_side == "B")
        assert result.waste_matrix[i][j] == pytest.approx(w_ij)
        assert result.waste_matrix[j][i] == pytest.approx(w_ji)


def test_rank_saves_each_artifact_at_most_once(tmp_path):
    """Store-backed rank persists dirty artifacts ONCE at rank exit, not
    after every pairwise compare (O(N²) full .npz rewrites before the fix).
    N captures -> exactly N saves; rank of N candidates -> <= N more."""
    saves: list[str] = []

    class SpyStore(ArtifactStore):
        def save(self, artifact):
            saves.append(artifact.key)
            return super().save(artifact)

    args, fns = _matpow_candidates()
    fns = fns[:3]                  # pow8_mixed/semi share a jaxpr (cache hit)
    session = Session(store=None)
    session.store = SpyStore(tmp_path)
    arts = [session.capture(fn, args, name=fn.__name__) for fn in fns]
    assert len(saves) == len(fns)             # one save per capture

    saves.clear()
    session.rank(arts, output_rtol=5e-2)
    assert len(saves) <= len(fns), \
        f"rank re-saved artifacts per compare: {saves}"
    assert len(set(saves)) == len(saves)      # no artifact written twice
    # the deferred saves persisted the phase-2 memo: offline replay works
    session2 = Session(store=None)
    session2.store = ArtifactStore(tmp_path)
    loaded = [session2.store.load(a.key) for a in arts]
    session2.rank(loaded, output_rtol=5e-2)   # would raise on missing values


def test_rank_result_json_roundtrip():
    args, fns = _matpow_candidates()
    session = Session()
    arts = [session.capture(fn, args, name=fn.__name__) for fn in fns[:3]]
    result = session.rank(arts, output_rtol=5e-2)
    again = RankResult.from_json(result.to_json())
    assert again.to_json() == result.to_json()
    assert "waste matrix" in result.render()
    summary = result.summary_report()
    assert "rank_matrix" in summary.meta
    assert "waste matrix" in summary.render()


# ---------------------------------------------------------------------------
# report JSON round-trip
# ---------------------------------------------------------------------------

def test_report_from_json_roundtrip():
    case = cases.get_case("c6-matpow")
    rep = DifferentialEnergyDebugger().compare(
        case.inefficient, case.efficient, case.make_args(),
        config_a=case.config_a, config_b=case.config_b,
        output_rtol=case.output_rtol)
    again = Report.from_json(rep.to_json())
    assert again.to_json() == rep.to_json()
    assert again.render() == rep.render()
    f = rep.findings[0]
    assert Finding.from_json(json.dumps(
        json.loads(rep.to_json())["findings"][0])) == f


# ---------------------------------------------------------------------------
# artifact schema: v2 per-op HLO costs + v1 backward compatibility
# ---------------------------------------------------------------------------

def test_artifact_persists_per_op_hlo_costs(tmp_path):
    """An HLO-backend capture round-trips its per-op attribution through the
    store: the loaded profile carries the same per-node cost columns."""
    case = cases.get_case("c6-matpow")
    session = Session(backend=HloCostBackend(), store=str(tmp_path))
    art = session.capture(case.inefficient, case.make_args(), name="x")
    assert art.profile.hlo is not None
    assert art.profile.hlo.num_nodes == len(art.graph.nodes)
    loaded = session.load(art.key)
    assert loaded.profile.hlo is not None
    np.testing.assert_array_equal(loaded.profile.hlo.flops,
                                  art.profile.hlo.flops)
    np.testing.assert_array_equal(loaded.profile.hlo.hbm_bytes,
                                  art.profile.hlo.hbm_bytes)
    # JSON round-trip preserves floats exactly
    assert loaded.profile.hlo.module == art.profile.hlo.module


def test_v1_artifact_loads_with_hlo_costs_marked_absent(tmp_path):
    """Old (format v1) monolithic .npz artifacts still load; their per-op
    HLO costs are marked absent (profile.hlo is None) rather than erroring."""
    import json as _json

    from repro.core import artifact as artifact_mod

    case = cases.get_case("c6-matpow")
    session = Session(store=str(tmp_path))
    art = session.capture(case.inefficient, case.make_args(), name="x")
    path = tmp_path / "legacy.npz"
    art.save(path)                  # the monolithic (legacy v2) container

    # rewrite the saved npz's meta block as a v1 payload (no 'hlo' field)
    with np.load(path, allow_pickle=False) as z:
        arrays = {k: z[k] for k in z.files}
    meta = _json.loads(arrays["meta"].tobytes().decode())
    meta["format_version"] = 1
    meta["profile"].pop("hlo", None)
    arrays["meta"] = np.frombuffer(_json.dumps(meta).encode(), dtype=np.uint8)
    np.savez(path, **arrays)

    loaded = CandidateArtifact.load(path)
    assert loaded.profile.hlo is None
    assert loaded.profile.total_energy_j == pytest.approx(
        art.profile.total_energy_j)

    # an unknown future version still refuses loudly
    meta["format_version"] = 99
    arrays["meta"] = np.frombuffer(_json.dumps(meta).encode(), dtype=np.uint8)
    np.savez(path, **arrays)
    with pytest.raises(ValueError, match="format v99"):
        CandidateArtifact.load(path)
    assert artifact_mod.ARTIFACT_FORMAT_VERSION == 3


def test_cache_hit_from_remote_store_skips_reexecution(tmp_path, monkeypatch):
    """A capture recorded on one machine and mirrored is a cache hit on
    another: the read-through local store pulls the manifest from the
    remote, skips every instrumented execution, and re-attaches for lazy
    phase-2 fetches."""
    case = cases.get_case("c6-matpow")
    recorder = Session(store=str(tmp_path / "recorder"))
    art = recorder.capture(case.inefficient, case.make_args(), name="x")
    mirror = tmp_path / "mirror"
    recorder.store.push(f"file://{mirror}")

    fleet = Session(store=ArtifactStore(tmp_path / "fleet",
                                        remote=f"file://{mirror}"))
    calls = _count_runs(monkeypatch)
    hit = fleet.capture(case.inefficient, case.make_args(), name="x")
    assert hit.meta.get("cache_hit")
    assert calls["n"] == 0              # no instrumented execution at all
    assert hit.key == art.key
    assert hit.is_live                  # re-attached for lazy fetches
    assert fleet.store.counters["upstream_manifest_reads"] == 1
    # second hit is served from the local read-through cache
    fleet2 = Session(store=ArtifactStore(tmp_path / "fleet",
                                         remote=f"file://{mirror}"))
    hit2 = fleet2.capture(case.inefficient, case.make_args(), name="x")
    assert hit2.meta.get("cache_hit") and calls["n"] == 0
    assert fleet2.store.counters["upstream_manifest_reads"] == 0


# ---------------------------------------------------------------------------
# CLI zoo re-attach: rejected provenance must not orphan store entries
# ---------------------------------------------------------------------------

def test_maybe_attach_zoo_rejection_leaves_store_clean(tmp_path):
    """A loaded artifact whose zoo provenance fails the key check must NOT
    persist its probe re-capture: before the fix the rejected capture
    stayed behind as an orphan store entry."""
    from repro.cli import _maybe_attach_zoo

    case = cases.get_case("c6-matpow")
    session = Session(store=str(tmp_path))
    art = session.capture(case.inefficient, case.make_args(), name="x",
                          extra_meta={"zoo_case": case.id,
                                      "zoo_side": "ineff"})
    session.store.save(art)
    keys_before = set(session.store.keys())

    stale = session.store.load(art.key)
    assert not stale.is_live
    # tamper the provenance: claims to be the OTHER twin, so the re-capture
    # key cannot match the recorded one
    stale.meta["zoo_side"] = "eff"
    out = _maybe_attach_zoo(stale, session)
    assert out is stale                      # rejected: artifact unchanged
    assert not out.is_live
    assert set(session.store.keys()) == keys_before, \
        "rejected zoo re-attach orphaned an entry in the store"

    # intact provenance still re-attaches (and stays clean: cache hit)
    good = session.store.load(art.key)
    attached = _maybe_attach_zoo(good, session)
    assert attached.is_live
    assert attached.key == art.key
    assert set(session.store.keys()) == keys_before


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------

def test_hlo_cost_backend_profiles_and_detects():
    case = cases.get_case("c6-matpow")
    session = Session(backend=HloCostBackend())
    a = session.capture(case.inefficient, case.make_args(), name="ineff")
    b = session.capture(case.efficient, case.make_args(), name="eff")
    rep = session.compare(a, b, output_rtol=case.output_rtol)
    assert rep.meta["energy_model"].startswith("hlo+")
    assert rep.waste_findings
    assert a.profile.total_energy_j > b.profile.total_energy_j


def test_backend_from_name():
    assert isinstance(backend_from_name("analytic"), AnalyticalBackend)
    assert isinstance(backend_from_name("replay"), ReplayBackend)
    assert isinstance(backend_from_name("hlo"), HloCostBackend)
    with pytest.raises(ValueError):
        backend_from_name("nope")


# ---------------------------------------------------------------------------
# zoo registry
# ---------------------------------------------------------------------------

def test_zoo_registry_lookup_and_filters():
    assert cases.get_case("c6-matpow").id == "c6-matpow"
    assert cases.get_case("hf-34570").id == "c6-matpow"     # paper id
    assert cases.by_id("c6-matpow") is cases.get_case("c6-matpow")
    with pytest.raises(KeyError):
        cases.get_case("not-a-case")
    assert len(cases.list_cases()) == len(cases.CASES) == 20
    assert all(c.known for c in cases.list_cases(known=True))
    assert all(c.category == "redundant"
               for c in cases.list_cases(category="redundant"))
    # the decorator-registered case is present like any other
    assert cases.get_case("n1-gelu-backend").known is False


def test_register_case_rejects_duplicates_and_junk():
    with pytest.raises(ValueError, match="duplicate"):
        cases.register_case(cases.get_case("c6-matpow"))
    with pytest.raises(TypeError):
        cases.register_case(lambda: "not a case")


# ---------------------------------------------------------------------------
# _perturb hardening (satellite fix)
# ---------------------------------------------------------------------------

def test_perturb_handles_degenerate_integer_leaves():
    empty = np.zeros((0, 4), np.int32)
    constant = np.full((3, 3), 7, np.int64)
    varied = np.arange(12, dtype=np.int32).reshape(3, 4)
    floats = np.ones((2, 2), np.float32)
    out = _perturb((empty, constant, varied, floats), seed=0)
    assert out[0].shape == (0, 4) and out[0].dtype == np.int32
    assert np.array_equal(out[1], constant)       # constant: passthrough
    assert out[2].min() >= 0 and out[2].max() <= 11
    assert out[2].dtype == np.int32
    assert out[3].dtype == np.float32


# ---------------------------------------------------------------------------
# capture input validation (satellite fix): clear errors, not deep tracebacks
# ---------------------------------------------------------------------------

def test_capture_rejects_generator_with_clear_error():
    import jax.numpy as jnp

    def streaming(x):
        return (x * i for i in range(3))      # classic mistake: a genexpr

    with pytest.raises(TypeError, match="generator.*arrays"):
        Session().capture(streaming, (jnp.ones((2, 2)),))


def test_capture_rejects_non_array_leaves_with_clear_error():
    import jax.numpy as jnp

    def labelled(x):
        return {"out": x * 2.0, "label": "fast-path"}

    with pytest.raises(TypeError, match="non-array leaves.*str"):
        Session().capture(labelled, (jnp.ones((2, 2)),))


def test_capture_preserves_genuine_candidate_errors():
    """A candidate that raises keeps its own exception — the validation
    probe must not swallow or rewrap real failures."""
    import jax.numpy as jnp

    def boom(x):
        raise RuntimeError("kaboom inside candidate")

    with pytest.raises(RuntimeError, match="kaboom inside candidate"):
        Session().capture(boom, (jnp.ones((2, 2)),))


# ---------------------------------------------------------------------------
# CLI smoke (subprocess)
# ---------------------------------------------------------------------------

def _cli(tmp_path, *argv):
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env["MAGNETON_STORE"] = str(tmp_path / "store")
    return subprocess.run([sys.executable, "-m", "repro.cli", *argv],
                          capture_output=True, text=True, env=env,
                          cwd=root, timeout=300)


def test_cli_smoke(tmp_path):
    r = _cli(tmp_path, "cases")
    assert r.returncode == 0, r.stderr
    assert "c6-matpow" in r.stdout and "20 cases" in r.stdout

    rep_json = tmp_path / "rep.json"
    r = _cli(tmp_path, "compare", "c6-matpow:ineff", "c6-matpow:eff",
             "--json", str(rep_json), "--expect-waste")
    assert r.returncode == 0, r.stderr
    assert "energy-waste findings: 1" in r.stdout
    assert rep_json.exists()

    r = _cli(tmp_path, "report", str(rep_json))
    assert r.returncode == 0, r.stderr
    assert "Magneton differential energy report" in r.stdout

    # second capture of the same case must be a store cache hit
    r = _cli(tmp_path, "capture", "c6-matpow:ineff")
    assert r.returncode == 0, r.stderr
    assert "cache-hit" in r.stdout

    r = _cli(tmp_path, "artifacts")
    assert r.returncode == 0, r.stderr
    assert "c6-matpow" in r.stdout
    keys = [line.split()[0] for line in r.stdout.splitlines()
            if line.startswith(tuple("0123456789abcdef")) and "c6" in line]
    assert len(keys) == 2

    # compare by bare artifact key: zoo-born artifacts re-attach via their
    # recorded provenance, so the lazy phase-2 fetches still work
    r = _cli(tmp_path, "compare", keys[0], keys[1], "--output-rtol", "0.05")
    assert r.returncode == 0, r.stderr
    assert "energy-waste findings: 1" in r.stdout


# ---------------------------------------------------------------------------
# parallel per-sample capture
# ---------------------------------------------------------------------------

def _deep_parallel_model():
    def fn(x, w):
        for _ in range(30):           # 151 nodes: parallel auto-threshold hit
            x = (jnp.tanh(x @ w) + 0.5 * x) * 1.01
        return x.sum()
    return fn


def test_parallel_sample_capture_byte_identical_to_serial(monkeypatch):
    """parallel_samples must change wall-clock only: identical store key,
    identical per-sample signatures in identical order, and exactly
    num_samples instrumented executions (spy-visible through the module
    attribute, which the thread pool resolves at submit time)."""
    fn = _deep_parallel_model()
    w = jnp.eye(8) * 0.9
    x = jnp.arange(32.0, dtype=jnp.float32).reshape(4, 8) / 10.0

    art_ser = Session(parallel_samples=False,
                      num_input_samples=4).capture(fn, (x, w), name="m")
    calls = _count_runs(monkeypatch)
    sess = Session(parallel_samples=True, num_input_samples=4)
    art_par = sess.capture(fn, (x, w), name="m")
    assert calls["n"] == 4            # one instrumented run per sample
    assert art_par.key == art_ser.key
    assert len(art_par.sample_stats) == len(art_ser.sample_stats) == 4
    for ks, kp in zip(art_ser.sample_stats, art_par.sample_stats):
        assert sorted(ks) == sorted(kp)
        for t in ks:
            assert repr(ks[t]) == repr(kp[t])   # bitwise-equal invariants


def test_parallel_capture_gate_against_still_fails_fast(monkeypatch):
    """Sample 0 runs serially first, so the functional-equivalence gate
    rejects a different task BEFORE samples 1..n-1 are captured."""
    fn = _deep_parallel_model()

    def other(x, w):
        return (x @ w).sum() * 3.0

    w = jnp.eye(8) * 0.9
    x = jnp.arange(32.0, dtype=jnp.float32).reshape(4, 8) / 10.0
    sess = Session(parallel_samples=True, num_input_samples=4)
    art = sess.capture(fn, (x, w), name="m")
    calls = _count_runs(monkeypatch)
    with pytest.raises(ValueError, match="not the same task"):
        sess.capture(other, (x, w), name="other", gate_against=art)
    assert calls["n"] == 1            # only sample 0 ever executed


def test_compare_stamps_twins_on_live_artifacts():
    """Live-captured artifacts carry their graphs and samples, so compare()
    attaches a BlockStamper: repeated-block pairs are stamped (declared in
    report meta) and the findings still match a stamper-less session's."""
    fn = _deep_parallel_model()
    w = jnp.eye(8) * 0.9
    x = jnp.arange(32.0, dtype=jnp.float32).reshape(4, 8) / 10.0
    sess = Session(num_input_samples=2)
    art_a = sess.capture(fn, (x, w), name="a")
    art_b = sess.capture(fn, (x, w), name="b")
    rep = sess.compare(art_a, art_b)
    assert rep.meta["stamped_pairs"] > 0
    assert rep.meta["eq_tensor_pairs"] >= rep.meta["stamped_pairs"]
