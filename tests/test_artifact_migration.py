"""Schema migration: v1/v2 monolithic .npz artifacts under the v3 store.

Acceptance-critical properties:
  * v1 and v2 fixtures load through ArtifactStore (per-op HLO costs marked
    absent for v1, value digests/spectra absent for both — recomputed from
    the eagerly-stored values on demand),
  * offline checks replay byte-identically before and after
    ``artifacts migrate``,
  * migration converts in place (npz gone, manifest + chunks in) and is
    idempotent.
"""

import json

import numpy as np
import pytest

from repro.core.artifact import ArtifactStore, CandidateArtifact
from repro.core.session import Session
from repro.testing.baselines import BaselineStore
from repro.zoo import cases

CASE_ID = "c6-matpow"


def _legacy_golden_store(tmp_path, *, strip_to_v1=False):
    """A golden baseline dir whose artifact store holds only legacy
    monolithic .npz entries (what a pre-v3 checkout recorded)."""
    case = cases.get_case(CASE_ID)
    root = tmp_path / "baselines"
    store = BaselineStore(root, sketch_only=False)
    res = store.record(case)
    arts = store.artifacts

    idx = json.loads(store.index_path.read_text())[case.id]
    for key in (idx["a"], idx["b"]):
        art = arts.load(key)
        # the monolithic v2 container: values inline, no digests/spectra
        # (CandidateArtifact.save does not serialize the v3-only evidence)
        art.save(arts.root / f"{key}.npz")
        arts.backend.delete_manifest(key)
    for d in list(arts.backend.chunk_keys()):
        arts.backend.delete_chunk(d)
    if strip_to_v1:
        for key in (idx["a"], idx["b"]):
            path = arts.root / f"{key}.npz"
            with np.load(path, allow_pickle=False) as z:
                arrays = {k: z[k] for k in z.files}
            meta = json.loads(arrays["meta"].tobytes().decode())
            meta["format_version"] = 1
            meta["profile"].pop("hlo", None)
            arrays["meta"] = np.frombuffer(json.dumps(meta).encode(),
                                           dtype=np.uint8)
            np.savez(path, **arrays)
    return case, store, res


def test_legacy_npz_entries_load_through_v3_store(tmp_path):
    case, store, _ = _legacy_golden_store(tmp_path)
    arts = store.artifacts
    assert arts.backend.manifest_keys() == []
    assert len(arts.legacy_keys()) == 2
    for key in arts.keys():
        assert arts.has(key)
        art = arts.load(key)
        assert art.values                     # npz values loaded eagerly
        assert not art.value_index            # digests: v3-only, absent
    listed = arts.entries()
    assert {e["name"] for e in listed} == {f"{case.id}-ineff",
                                           f"{case.id}-eff"}


def test_v1_fixture_loads_with_per_op_costs_absent(tmp_path):
    _, store, _ = _legacy_golden_store(tmp_path, strip_to_v1=True)
    for key in store.artifacts.keys():
        art = store.artifacts.load(key)
        assert art.profile.hlo is None        # per-op costs marked absent
        assert art.profile.total_energy_j > 0


@pytest.mark.parametrize("strip_to_v1", [False, True])
def test_offline_check_is_byte_identical_across_migration(tmp_path,
                                                          strip_to_v1):
    case, store, res = _legacy_golden_store(tmp_path,
                                            strip_to_v1=strip_to_v1)
    arts = store.artifacts
    idx = json.loads(store.index_path.read_text())[case.id]

    def offline_report():
        la, lb = arts.load(idx["a"]), arts.load(idx["b"])
        return Session().compare(la, lb, output_rtol=case.output_rtol,
                                 persist=False).to_json()

    legacy_json = offline_report()
    assert legacy_json == res.report.to_json()          # v2 replay == live

    migrated = arts.migrate()
    assert migrated == {"migrated": 2, "skipped": 0}
    assert arts.legacy_keys() == []                     # npz gone
    assert sorted(arts.backend.manifest_keys()) == sorted([idx["a"],
                                                           idx["b"]])
    assert offline_report() == legacy_json              # byte-identical
    assert store.check(case, offline=True) == []

    # idempotent: nothing left to migrate
    assert arts.migrate() == {"migrated": 0, "skipped": 0}


def test_offline_check_on_legacy_store_upgrades_evidence(tmp_path):
    """An offline check against a still-unmigrated store passes drift-free
    AND persists the phase-2 evidence it derived (digests + spectra land in
    a fresh v3 manifest next to the npz), so `migrate` afterwards only has
    the already-converted entries to skip."""
    case, store, _ = _legacy_golden_store(tmp_path)
    arts = store.artifacts
    assert arts.backend.manifest_keys() == []
    assert store.check(case, offline=True) == []
    assert len(arts.backend.manifest_keys()) == 2       # evidence persisted
    assert arts.migrate() == {"migrated": 0, "skipped": 2}
    assert store.check(case, offline=True) == []


def test_migrate_carries_values_into_chunks(tmp_path):
    """Migrated artifacts keep their raw values (chunked + deduplicated),
    so even comparisons the record never ran stay servable offline."""
    case, store, _ = _legacy_golden_store(tmp_path)
    arts = store.artifacts
    logical = sum(v.nbytes
                  for key in arts.keys()
                  for v in arts.load(key).values.values())
    arts.migrate()
    st = arts.stats()
    assert st["values_total"] > 0 and st["values_sketch_only"] == 0
    assert st["chunk_bytes"] > 0
    # dedup: twins share inputs/matched values, so chunks < logical bytes
    assert st["chunk_bytes"] < logical + st["logical_output_bytes"]
    for key in arts.keys():
        art = arts.load(key)
        assert not art.values                 # lazily chunk-backed now
        k, tid = sorted(art.value_index)[0]
        got = art.fetcher()(k, [tid])
        assert got[tid].size >= 0             # raw fetch via chunk store


def test_push_refuses_unmigrated_legacy_entries(tmp_path):
    _, store, _ = _legacy_golden_store(tmp_path)
    with pytest.raises(ValueError, match="migrate"):
        store.artifacts.push(f"file://{tmp_path / 'mirror'}")
    store.artifacts.migrate()
    res = store.artifacts.push(f"file://{tmp_path / 'mirror'}")
    assert res["manifests"] == 2


def test_push_accepts_keys_migrated_with_keep_legacy(tmp_path):
    """`migrate --keep-legacy` leaves the npz next to the new manifest; a
    key with a manifest is migrated and must push (by name and in bulk)."""
    _, store, _ = _legacy_golden_store(tmp_path)
    arts = store.artifacts
    arts.migrate(delete_legacy=False)
    keys = arts.keys()
    assert arts.legacy_keys() == keys         # npz still present
    res = arts.push(f"file://{tmp_path / 'mirror'}", keys=keys[:1])
    assert res["manifests"] == 1
    res = arts.push(f"file://{tmp_path / 'mirror2'}")
    assert res["manifests"] == 2
