"""Per-kernel allclose vs the pure-jnp oracle, swept over shapes and dtypes.

Every Pallas kernel runs in interpret mode (CPU container); the oracle in
kernels/ref.py is ground truth.  Property tests assert the kernels'
numerical invariants on hypothesis-generated shapes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st  # skips property tests w/o hypothesis

from repro.kernels import ops, ref

KEYS = jax.random.split(jax.random.key(42), 8)


def _tol(dt):
    return 2e-2 if dt == jnp.bfloat16 else 2e-5


def _rand(key, shape, dt):
    return jax.random.normal(key, shape, jnp.float32).astype(dt)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,KV,S,D,dtype,causal", [
    (1, 4, 4, 64, 32, jnp.float32, True),       # MHA causal
    (1, 4, 4, 64, 32, jnp.float32, False),      # MHA full
    (2, 8, 2, 128, 64, jnp.bfloat16, True),     # GQA 4:1 bf16
    (1, 8, 1, 256, 16, jnp.float32, True),      # MQA long
    (2, 4, 4, 96, 48, jnp.float32, True),       # non-pow2 seq (block fallback)
    (1, 4, 2, 64, 32, jnp.bfloat16, False),     # GQA bf16 full
])
def test_flash_attention_matches_oracle(B, H, KV, S, D, dtype, causal):
    q = _rand(KEYS[0], (B, H, S, D), dtype)
    k = _rand(KEYS[1], (B, KV, S, D), dtype)
    v = _rand(KEYS[2], (B, KV, S, D), dtype)
    out = ops.flash_attention(q, k, v, causal=causal)
    want = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_flash_attention_cached_decode_shape():
    """Sq=1 against a longer KV cache (causal offset path)."""
    q = _rand(KEYS[0], (2, 4, 1, 32), jnp.float32)
    k = _rand(KEYS[1], (2, 2, 128, 32), jnp.float32)
    v = _rand(KEYS[2], (2, 2, 128, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_flash_attention_grads_match_reference():
    q = _rand(KEYS[0], (1, 2, 64, 16), jnp.float32)
    k = _rand(KEYS[1], (1, 2, 64, 16), jnp.float32)
    v = _rand(KEYS[2], (1, 2, 64, 16), jnp.float32)
    g1 = jax.grad(lambda q, k, v: ops.flash_attention(q, k, v).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda q, k, v: ref.attention(q, k, v).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


@settings(max_examples=5, deadline=None)
@given(st.integers(1, 2), st.integers(0, 2), st.integers(4, 6),
       st.booleans())
def test_flash_attention_property(b, kv_pow, s_pow, causal):
    """Softmax rows sum to 1 => output is a convex combination of V rows:
    max|out| <= max|v| for every hypothesis-generated shape."""
    H = 4
    KV = 2 ** kv_pow
    if H % KV:
        KV = 1
    S = 2 ** s_pow
    D = 16
    q = _rand(KEYS[3], (b, H, S, D), jnp.float32)
    k = _rand(KEYS[4], (b, KV, S, D), jnp.float32)
    v = _rand(KEYS[5], (b, KV, S, D), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal)
    assert float(jnp.max(jnp.abs(out))) <= float(jnp.max(jnp.abs(v))) + 1e-4
    assert bool(jnp.all(jnp.isfinite(out)))


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 64), (3, 5, 128), (2, 7, 9, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_oracle(shape, dtype):
    x = _rand(KEYS[0], shape, dtype)
    w = _rand(KEYS[1], shape[-1:], jnp.float32)
    out = ops.fused_rmsnorm(x, w)
    want = ref.rmsnorm(x, w)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 64), st.sampled_from([32, 64, 128]))
def test_rmsnorm_property_unit_rms(rows, d):
    """With w=1, output rows have RMS ~ 1."""
    x = _rand(KEYS[2], (rows, d), jnp.float32) * 3.0 + 1.0
    out = ops.fused_rmsnorm(x, jnp.ones((d,)))
    rms = jnp.sqrt(jnp.mean(out.astype(jnp.float32) ** 2, axis=-1))
    np.testing.assert_allclose(rms, np.ones(rows), atol=1e-3)


# ---------------------------------------------------------------------------
# fused activations
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(16, 64), (4, 100, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swiglu_matches_oracle(shape, dtype):
    g = _rand(KEYS[0], shape, dtype)
    u = _rand(KEYS[1], shape, dtype)
    np.testing.assert_allclose(
        ops.fused_swiglu(g, u).astype(jnp.float32),
        ref.swiglu(g, u).astype(jnp.float32),
        atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("shape", [(16, 64), (4, 100, 256), (1, 1, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gelu_matches_oracle(shape, dtype):
    x = _rand(KEYS[2], shape, dtype)
    np.testing.assert_allclose(
        ops.fused_gelu(x).astype(jnp.float32),
        ref.gelu_tanh(x).astype(jnp.float32),
        atol=_tol(dtype), rtol=_tol(dtype))


@settings(max_examples=8, deadline=None)
@given(st.floats(-10, 10))
def test_gelu_property_bounds(v):
    """GELU(x) in [min(0,x)-0.2, max(0,x)] and monotone asymptotics."""
    x = jnp.full((8, 128), v, jnp.float32)
    out = float(ops.fused_gelu(x)[0, 0])
    assert out <= max(0.0, v) + 1e-4
    assert out >= min(0.0, v) - 0.2


# ---------------------------------------------------------------------------
# ssm scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,di,n,chunk", [
    (1, 64, 32, 8, 16),
    (2, 128, 64, 16, 64),
    (1, 32, 128, 4, 32),     # chunk == S
])
def test_ssm_scan_matches_oracle(B, S, di, n, chunk):
    a = jax.nn.sigmoid(_rand(KEYS[0], (B, S, di, n), jnp.float32)) * 0.95
    b = _rand(KEYS[1], (B, S, di, n), jnp.float32) * 0.1
    c = _rand(KEYS[2], (B, S, n), jnp.float32)
    h0 = _rand(KEYS[3], (B, di, n), jnp.float32) * 0.1
    y1, h1 = ops.fused_ssm_scan(a, b, c, h0, chunk=chunk)
    y2, h2 = ref.ssm_scan(a, b, c, h0, chunk=chunk)
    np.testing.assert_allclose(y1, y2, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(h1, h2, atol=1e-5, rtol=1e-4)


def test_ssm_scan_state_carrying_across_chunks():
    """Splitting the sequence in two and chaining h must equal one pass."""
    B, S, di, n = 1, 64, 32, 8
    a = jax.nn.sigmoid(_rand(KEYS[4], (B, S, di, n), jnp.float32)) * 0.9
    b = _rand(KEYS[5], (B, S, di, n), jnp.float32) * 0.1
    c = _rand(KEYS[6], (B, S, n), jnp.float32)
    h0 = jnp.zeros((B, di, n))
    y_full, h_full = ops.fused_ssm_scan(a, b, c, h0)
    half = S // 2
    y1, h_mid = ops.fused_ssm_scan(a[:, :half], b[:, :half], c[:, :half], h0)
    y2, h_end = ops.fused_ssm_scan(a[:, half:], b[:, half:], c[:, half:], h_mid)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], axis=1), y_full,
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(h_end, h_full, atol=1e-5, rtol=1e-4)


@settings(max_examples=5, deadline=None)
@given(st.integers(1, 2), st.sampled_from([16, 32, 64]),
       st.sampled_from([8, 16]))
def test_ssm_scan_property_decay_bound(B, S, n):
    """With |a|<1 and b=0, the state can only shrink."""
    di = 16
    a = jnp.full((B, S, di, n), 0.5, jnp.float32)
    b = jnp.zeros((B, S, di, n), jnp.float32)
    c = jnp.ones((B, S, n), jnp.float32)
    h0 = jnp.ones((B, di, n), jnp.float32)
    _, h_last = ops.fused_ssm_scan(a, b, c, h0)
    assert float(jnp.max(jnp.abs(h_last))) <= 1.0
