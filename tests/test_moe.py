"""MoE: sort/gather dispatch vs GShard one-hot twin vs dropless oracle."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import moe
from repro.models.layers import init_params


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(
        configs.get_config("llama4-scout-17b-a16e").reduced(),
        moe_num_experts=4, moe_top_k=2, moe_d_ff=16, moe_num_shared=1,
        capacity_factor=8.0)          # high capacity => no token drops
    params = init_params(moe.moe_schema(cfg), jax.random.key(0))
    params = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32), params)
    cfg = dataclasses.replace(cfg, dtype="float32")
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model), jnp.float32)
    return cfg, params, x


def test_sort_dispatch_matches_dropless_oracle(setup):
    cfg, params, x = setup
    y1, aux1 = moe.moe_apply(cfg, params, x)
    y2, aux2 = moe.moe_reference(cfg, params, x)
    np.testing.assert_allclose(y1, y2, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(aux1, aux2, atol=1e-5)


def test_einsum_twin_matches_oracle(setup):
    cfg, params, x = setup
    y1, _ = moe.moe_apply_einsum(cfg, params, x)
    y2, _ = moe.moe_reference(cfg, params, x)
    np.testing.assert_allclose(y1, y2, atol=1e-4, rtol=1e-3)


def test_capacity_drops_tokens_when_tight(setup):
    cfg, params, x = setup
    tight = dataclasses.replace(cfg, capacity_factor=0.25)
    y_tight, _ = moe.moe_apply(tight, params, x)
    y_full, _ = moe.moe_apply(cfg, params, x)
    # some tokens must differ (dropped -> only shared-expert output)
    assert not np.allclose(y_tight, y_full, atol=1e-5)


def test_aux_loss_balanced_is_near_one(setup):
    cfg, params, x = setup
    # uniform router -> aux loss ~ 1 (E * sum(1/E * 1/E) * E = 1)
    p2 = dict(params)
    p2["router"] = jnp.zeros_like(params["router"])
    _, aux = moe.moe_apply(cfg, p2, x)
    assert 0.5 < float(aux) < 2.0


def test_grad_flows_through_dispatch(setup):
    cfg, params, x = setup

    def loss(p):
        y, aux = moe.moe_apply(cfg, p, x)
        return (y ** 2).mean() + 0.01 * aux

    g = jax.grad(loss)(params)
    gnorm = sum(float(jnp.abs(l).sum()) for l in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0
