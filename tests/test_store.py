"""Chunked content-addressed store: dedup, atomicity/concurrency, remote
mirrors, sketch-only offline replay with zero raw-value chunk reads.

Acceptance-critical properties:
  * identical values shared across candidates are stored once (chunk dedup),
  * a crash mid-save leaves a clean load-or-miss, never a torn entry, and
    two processes capturing the same key converge,
  * ``baseline check --offline`` replays the fast-lane zoo cases
    bit-identically from sketch-only manifests with ZERO raw-value chunk
    reads (store read counters),
  * push/pull mirrors round-trip manifests + chunks, and a read-through
    remote serves cache hits (spy test lives in test_session.py).
"""

import json
import os

import numpy as np
import pytest

from repro.core.artifact import ArtifactStore, CandidateArtifact
from repro.core.session import Session
from repro.core.store import (CHUNK_BYTES, LocalStore, RemoteStore,
                              StoreReadOnlyError, chunk_digest, open_store,
                              split_chunks)
from repro.testing.baselines import BaselineStore
from repro.zoo import cases

# the CI fast-lane subset: structurally varied, cheap enough for tier-1
SKETCH_CASES = ["c6-matpow", "c15-expm", "c12-ln-layout", "c9-join-psum"]


# ---------------------------------------------------------------------------
# chunk-level transport
# ---------------------------------------------------------------------------

def test_chunking_roundtrip_and_dedup(tmp_path):
    store = LocalStore(tmp_path)
    big = os.urandom(CHUNK_BYTES + 1024)      # spans two chunks
    digests = []
    for c in split_chunks(big):
        d = chunk_digest(c)
        store.write_chunk(d, c)
        digests.append(d)
    assert len(digests) == 2
    assert b"".join(store.read_chunk(d) for d in digests) == big

    # identical content re-written is a dedup hit, not a second file
    writes_before = store.counters["chunk_writes"]
    for c in split_chunks(big):
        store.write_chunk(chunk_digest(c), c)
    assert store.counters["chunk_writes"] == writes_before
    assert store.counters["chunk_dedup_hits"] >= 2
    assert sorted(store.chunk_keys()) == sorted(set(digests))


def test_identical_values_across_artifacts_stored_once(tmp_path):
    """Twin captures fetch bitwise-identical phase-2 values (shared inputs,
    matched activations); a full-values store must hold each exactly once."""
    case = cases.get_case("c6-matpow")
    store = ArtifactStore(tmp_path, persist_raw_values=True)
    session = Session(store=store)
    a = session.capture(case.inefficient, case.make_args(), name="ineff")
    b = session.capture(case.efficient, case.make_args(), name="eff")
    session.compare(a, b, output_rtol=case.output_rtol)

    st = store.stats()
    assert st["values_total"] > 0 and st["values_sketch_only"] == 0
    # logical value bytes exceed the deduplicated chunk bytes: at least the
    # shared model input appears under both artifacts
    assert st["dedup_ratio"] > 1.0
    digests_a = [r["digest"]
                 for r in store.backend.read_manifest(a.key)["values"]]
    digests_b = [r["digest"]
                 for r in store.backend.read_manifest(b.key)["values"]]
    shared = set(digests_a) & set(digests_b)
    assert shared, "twins share no value content?"
    assert store.counters["chunk_dedup_hits"] > 0


# ---------------------------------------------------------------------------
# atomicity / concurrency
# ---------------------------------------------------------------------------

def _capture_one(session):
    case = cases.get_case("c6-matpow")
    return session.capture(case.inefficient, case.make_args(), name="x")


def test_crash_mid_save_leaves_clean_miss(tmp_path, monkeypatch):
    """Kill the save after chunks land but before the manifest rename:
    the store must answer a clean miss (and a later save must succeed)."""
    store = ArtifactStore(tmp_path)
    session = Session(store=None)
    art = _capture_one(session)

    boom = RuntimeError("simulated crash before manifest publish")
    orig = LocalStore.write_manifest
    monkeypatch.setattr(LocalStore, "write_manifest",
                        lambda *a, **k: (_ for _ in ()).throw(boom))
    with pytest.raises(RuntimeError, match="simulated crash"):
        store.save(art)
    assert not store.has(art.key)             # miss, not a torn entry
    with pytest.raises(KeyError):
        store.load(art.key)

    monkeypatch.setattr(LocalStore, "write_manifest", orig)
    store.save(art)
    assert store.has(art.key)
    loaded = store.load(art.key)
    assert loaded.key == art.key


def test_torn_manifest_write_never_visible(tmp_path, monkeypatch):
    """A crash inside the manifest write itself (before os.replace) leaves
    no file at the destination — the tmp-file dance is load-bearing."""
    store = ArtifactStore(tmp_path)
    session = Session(store=None)
    art = _capture_one(session)

    real_replace = os.replace
    state = {"armed": True}

    def exploding_replace(src, dst):
        if state["armed"] and str(dst).endswith(".json"):
            state["armed"] = False
            raise OSError("simulated crash during rename")
        return real_replace(src, dst)

    monkeypatch.setattr(os, "replace", exploding_replace)
    with pytest.raises(OSError, match="simulated crash"):
        store.save(art)
    assert not store.path_for(art.key).exists()
    assert not store.has(art.key)
    # no stray tmp files left in the manifests dir
    leftovers = list((tmp_path / "manifests").glob("*.tmp"))
    assert not leftovers
    store.save(art)                           # recovery save works
    assert store.load(art.key).key == art.key


def test_concurrent_captures_of_same_key_converge(tmp_path):
    """Two processes capturing the same key must not corrupt or duplicate
    entries: chunk writes are idempotent by content address and the
    manifest rename is last-wins over identical content."""
    case = cases.get_case("c6-matpow")
    s1 = Session(store=ArtifactStore(tmp_path))
    s2 = Session(store=ArtifactStore(tmp_path))
    a1 = s1.capture(case.inefficient, case.make_args(), name="x",
                    use_cache=False)
    a2 = s2.capture(case.inefficient, case.make_args(), name="x",
                    use_cache=False)
    assert a1.key == a2.key
    store = ArtifactStore(tmp_path)
    assert store.keys().count(a1.key) == 1
    # every chunk file exists exactly once; loading is clean
    chunks = store.backend.chunk_keys()
    assert len(chunks) == len(set(chunks))
    loaded = store.load(a1.key)
    np.testing.assert_array_equal(loaded.outputs[0], a1.outputs[0])


# ---------------------------------------------------------------------------
# sketch-only offline replay: zero raw-value chunk reads
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cid", SKETCH_CASES)
def test_sketch_only_offline_replay_zero_value_reads(cid, tmp_path):
    """The golden store is sketch-only by default: offline replay decides
    every recorded match from manifest digests + spectra, reading ZERO
    chunks (outputs are materialized at load, before the compare)."""
    case = cases.get_case(cid)
    store = BaselineStore(tmp_path)           # sketch_only=True default
    res = store.record(case)
    live_json = res.report.to_json()

    idx = json.loads(store.index_path.read_text())
    arts = ArtifactStore(tmp_path / "store")
    la = arts.load(idx[case.id]["a"])
    lb = arts.load(idx[case.id]["b"])
    assert not la.is_live and not lb.is_live
    assert la.value_index and not la.values   # digests yes, raw values no

    before = dict(arts.counters)
    session = Session()
    report = session.compare(la, lb, output_rtol=case.output_rtol)
    reads = arts.counters["chunk_reads"] - before["chunk_reads"]
    assert reads == 0, f"{cid}: sketch-only replay read {reads} chunks"
    assert report.to_json() == live_json      # bit-identical to record time

    # the store holds no value chunks at all — only sample-0 outputs
    st = arts.stats()
    assert st["values_sketch_only"] == st["values_total"] > 0


def test_offline_check_passes_from_sketch_only_store(tmp_path):
    case = cases.get_case("c6-matpow")
    store = BaselineStore(tmp_path)
    store.record(case)
    assert store.check(case, offline=True) == []


# ---------------------------------------------------------------------------
# push / pull / remote mirrors
# ---------------------------------------------------------------------------

def test_push_pull_roundtrip_file_uri(tmp_path):
    case = cases.get_case("c6-matpow")
    src = ArtifactStore(tmp_path / "src")
    session = Session(store=src)
    a = session.capture(case.inefficient, case.make_args(), name="ineff")
    b = session.capture(case.efficient, case.make_args(), name="eff")
    session.compare(a, b, output_rtol=case.output_rtol)

    mirror = f"file://{tmp_path / 'mirror'}"
    res = src.push(mirror)
    assert res["manifests"] == 2 and res["chunks_copied"] > 0
    # second push is a no-op on chunks (dedup-aware)
    res2 = src.push(mirror)
    assert res2["chunks_copied"] == 0
    # every unique chunk lands exactly once even when shared across
    # manifests (the first push already skips cross-manifest repeats)
    assert res2["chunks_skipped"] >= res["chunks_copied"]

    dst = ArtifactStore(tmp_path / "dst")
    pulled = dst.pull(mirror)
    assert pulled["manifests"] == 2
    assert sorted(dst.keys()) == sorted(src.keys())
    for key in src.keys():
        assert dst.backend.read_manifest(key) == \
            src.backend.read_manifest(key)
    # offline compare from the pulled store is bit-identical
    la, lb = dst.load(a.key), dst.load(b.key)
    rep = Session().compare(la, lb, output_rtol=case.output_rtol)
    assert rep.meta["eq_tensor_pairs"] >= 1


def test_offline_baseline_check_from_remote_mirror(tmp_path):
    """`baseline check --offline --store file://mirror`: the golden
    artifacts live only on the mirror; the check must pass drift-free."""
    case = cases.get_case("c6-matpow")
    store = BaselineStore(tmp_path / "baselines")
    store.record(case)
    mirror = tmp_path / "mirror"
    store.artifacts.push(f"file://{mirror}")

    remote = BaselineStore(tmp_path / "baselines",
                           artifact_store=f"file://{mirror}")
    assert remote.check(case, offline=True) == []
    assert remote.artifacts.counters["manifest_reads"] >= 2


def test_http_remote_store_is_readonly(tmp_path):
    store = RemoteStore("http://127.0.0.1:1/never-contacted")
    assert store.readonly
    with pytest.raises(StoreReadOnlyError):
        store.write_chunk("00" * 32, b"x")
    with pytest.raises(StoreReadOnlyError):
        store.write_manifest("k", {})


def test_http_remote_store_serves_mirror(tmp_path):
    """End-to-end http mirror: push to a dir, serve it with http.server,
    list + load through RemoteStore."""
    import http.server
    import socketserver
    import threading

    case = cases.get_case("c6-matpow")
    src = ArtifactStore(tmp_path / "src")
    session = Session(store=src)
    art = session.capture(case.inefficient, case.make_args(), name="x")
    mirror = tmp_path / "mirror"
    src.push(f"file://{mirror}")

    import functools

    quiet = type("H", (http.server.SimpleHTTPRequestHandler,), {
        "log_message": lambda *a, **k: None})
    handler = functools.partial(quiet, directory=str(mirror))
    try:
        httpd = socketserver.TCPServer(("127.0.0.1", 0), handler)
    except OSError as e:
        pytest.skip(f"cannot bind a localhost socket: {e}")
    port = httpd.server_address[1]
    thread = threading.Thread(target=httpd.serve_forever, daemon=True)
    thread.start()
    try:
        remote = ArtifactStore.from_uri(f"http://127.0.0.1:{port}")
        assert remote.readonly
        assert art.key in remote.keys()       # via the pushed index.json
        loaded = remote.load(art.key)
        np.testing.assert_array_equal(loaded.outputs[0], art.outputs[0])
        with pytest.raises(PermissionError):
            remote.save(art)
    finally:
        httpd.shutdown()
        httpd.server_close()


def test_open_store_scheme_resolution(tmp_path):
    assert isinstance(open_store(tmp_path), LocalStore)
    assert isinstance(open_store(f"file://{tmp_path}"), RemoteStore)
    assert isinstance(open_store("http://example.invalid/x"), RemoteStore)
    with pytest.raises(ValueError, match="unsupported store scheme"):
        RemoteStore("s3://bucket/prefix")


# ---------------------------------------------------------------------------
# refcount-aware GC
# ---------------------------------------------------------------------------

def test_prune_keeps_chunks_referenced_by_survivors(tmp_path):
    """Deleting one artifact must not free chunks another still references
    (shared inputs / matched values), and must free its exclusive ones."""
    case = cases.get_case("c6-matpow")
    store = ArtifactStore(tmp_path, persist_raw_values=True)
    session = Session(store=store)
    a = session.capture(case.inefficient, case.make_args(), name="ineff")
    b = session.capture(case.efficient, case.make_args(), name="eff")
    session.compare(a, b, output_rtol=case.output_rtol)

    man_a = store.backend.read_manifest(a.key)
    man_b = store.backend.read_manifest(b.key)
    refs_a = {c for r in man_a["outputs"] + man_a["values"]
              for c in (r["chunks"] or ())}
    refs_b = {c for r in man_b["outputs"] + man_b["values"]
              for c in (r["chunks"] or ())}
    shared = refs_a & refs_b
    exclusive_a = refs_a - refs_b
    assert shared and exclusive_a

    deleted = store.prune(keep=[b.key], keep_latest=0, max_bytes=0)
    assert deleted == [a.key]
    present = set(store.backend.chunk_keys())
    assert shared <= present                  # survivor's chunks intact
    assert not (exclusive_a & present)        # pruned artifact's freed
    # the survivor still loads and serves values
    lb = store.load(b.key)
    fetched = lb.fetcher()(0, sorted(lb.value_index)[0][1:2] or [])
    assert isinstance(fetched, dict)


def test_cross_store_save_never_advertises_missing_chunks(tmp_path):
    """Saving an artifact loaded from store A into store B must leave B's
    manifest honest: chunk lists only when B can serve the bytes (copied
    from A), digest-only records otherwise — never dangling references."""
    case = cases.get_case("c6-matpow")
    a_store = ArtifactStore(tmp_path / "a", persist_raw_values=True)
    session = Session(store=a_store)
    x = session.capture(case.inefficient, case.make_args(), name="ineff")
    y = session.capture(case.efficient, case.make_args(), name="eff")
    session.compare(x, y, output_rtol=case.output_rtol)

    # full target: chunks are pulled across from A on save
    loaded = a_store.load(x.key)              # values chunk-backed, not live
    assert loaded.value_index and not loaded.values
    b_store = ArtifactStore(tmp_path / "b", persist_raw_values=True)
    b_store.save(loaded)
    for rec in b_store.backend.read_manifest(x.key)["values"]:
        assert rec["chunks"], "full save dropped a value's chunks"
        for d in rec["chunks"]:
            assert b_store.backend.has_chunk(d), f"dangling chunk ref {d}"

    # sketch-only target: digest-only records — except where the bytes are
    # already resident anyway (a value bitwise-equal to a sample-0 output
    # shares its content-addressed chunk), never a dangling advertisement
    c_store = ArtifactStore(tmp_path / "c", persist_raw_values=False)
    c_store.save(a_store.load(x.key))
    recs = c_store.backend.read_manifest(x.key)["values"]
    assert any(rec["chunks"] is None for rec in recs)
    for rec in recs:
        assert rec["digest"]
        for d in rec["chunks"] or ():
            assert c_store.backend.has_chunk(d), f"dangling chunk ref {d}"


def test_gc_chunks_drops_unreferenced(tmp_path):
    store = ArtifactStore(tmp_path)
    orphan = os.urandom(128)
    d = chunk_digest(orphan)
    store.backend.write_chunk(d, orphan)
    assert store.gc_chunks(dry_run=True) == [d]
    assert store.backend.has_chunk(d)         # dry run deletes nothing
    assert store.gc_chunks() == [d]
    assert not store.backend.has_chunk(d)
