"""End-to-end differential energy debugging over the paper case zoo.

This is the system-level acceptance test (Table 2 analogue): every known
case must be detected AND attributed to the inefficient side, except c11 —
the paper's own documented miss (host-side waste, invisible at operator
granularity).
"""

import pytest

from repro.core.diff import DifferentialEnergyDebugger
from repro.zoo import cases

FAST_CASES = ["c1-precision-prefill", "c3-topk-sort", "c6-matpow",
              "c12-ln-layout", "c15-expm", "c16-count-nonzero",
              "c11-busywait", "n1-gelu-backend"]


def _run(case):
    dbg = DifferentialEnergyDebugger()
    rep = dbg.compare(case.inefficient, case.efficient, case.make_args(),
                      name_a=case.id + "-ineff", name_b=case.id + "-eff",
                      config_a=case.config_a, config_b=case.config_b,
                      output_rtol=case.output_rtol)
    waste = [f for f in rep.findings if f.classification == "energy_waste"]
    detected = any(f.wasteful_side == "A" for f in waste)
    return rep, detected


@pytest.mark.parametrize("cid", FAST_CASES)
def test_case_detection(cid):
    case = cases.by_id(cid)
    rep, detected = _run(case)
    assert detected == case.expect_detect, (
        f"{cid}: detected={detected}, expected={case.expect_detect}\n"
        + rep.render())


def test_c1_diagnosis_surfaces_precision_param():
    """Misconfiguration diagnosis must name the differing eqn param/config."""
    case = cases.by_id("c1-precision-prefill")
    rep, detected = _run(case)
    assert detected
    diag = next(f.diagnosis for f in rep.findings
                if f.classification == "energy_waste")
    text = str(diag.__dict__).lower()
    assert "precision" in text or "highest" in text


def test_gelu_diagnosis_is_api_difference():
    case = cases.by_id("n1-gelu-backend")
    rep, detected = _run(case)
    assert detected
    diag = next(f.diagnosis for f in rep.findings
                if f.classification == "energy_waste")
    assert diag.kind in ("api_difference", "kernel_difference")


def test_report_renders():
    case = cases.by_id("c6-matpow")
    rep, _ = _run(case)
    text = rep.render()
    assert "energy" in text.lower()
    assert case.id + "-ineff" in text


def test_tradeoff_not_flagged_as_waste():
    """A cheaper-but-slower implementation is a trade-off, not waste
    (paper's 1% perf tolerance gate)."""
    import jax.numpy as jnp

    def fast_hungry(x):      # more energy, less (modeled) time
        return (x @ x) @ x

    def slow_thrifty(x):     # 'checkpointing' style recompute: fewer bytes
        y = x @ x
        return (y * 0.5) @ x + (y * 0.5) @ x

    # The pair disagrees in outputs only to within fp error; if energies
    # differ but the efficient side is >1% slower, class must be tradeoff.
    import numpy as np
    x = jnp.asarray(np.random.default_rng(0).standard_normal((64, 64)),
                    jnp.float32) / 8.0
    dbg = DifferentialEnergyDebugger()
    rep = dbg.compare(fast_hungry, slow_thrifty, (x,), output_rtol=5e-2)
    for f in rep.findings:
        if f.classification == "energy_waste":
            # permitted only if the efficient side is not slower
            t_w, t_e = ((f.time_a_s, f.time_b_s) if f.wasteful_side == "A"
                        else (f.time_b_s, f.time_a_s))
            assert t_e <= t_w * 1.01


@pytest.mark.slow
@pytest.mark.parametrize("cid", [c.id for c in cases.CASES
                                 if c.id not in FAST_CASES])
def test_case_detection_slow(cid):
    case = cases.by_id(cid)
    rep, detected = _run(case)
    assert detected == case.expect_detect, rep.render()
