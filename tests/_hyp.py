"""Optional-dependency shim for ``hypothesis``.

The container image does not ship hypothesis; importing it unguarded used to
abort collection of every test in the file (the seed suite's tier-1 failure).
Property-based tests import ``given/settings/st`` from here instead: when the
real package is absent they are individually skipped while the deterministic
tests in the same file keep running.
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Anything:
        """Stands in for any strategy object/decorator at collection time."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _Anything()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():
                pass
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco
