"""repro.audit: sampling policies, request classes, drift detection, fleet.

Unit coverage for the deterministic sampler, request-class keying, and the
audit log; integration coverage for the live-audit loop — a seeded-noise
soak on an unchanged engine must never alarm, a mutated engine sharing the
same fleet store must alarm with the planted diagnosis kind, and
``ServeEngine.health()`` must round-trip through JSON (the adversarial
report-harness idiom).
"""

import json

import jax
import numpy as np
import pytest

from repro import configs
from repro.audit import (AuditConfig, AuditEvent, AuditLog, EngineAuditor,
                         RequestClass, SampleDecision, Sampler, classify,
                         fleet_status, golden_key, log_key, pow2_bucket,
                         render_fleet_status, sanitize_id)
from repro.models import transformer as tf
from repro.serve.engine import EngineConfig, Request, ServeEngine


# -- request classes ----------------------------------------------------------

def test_pow2_buckets():
    assert pow2_bucket(1) == (1, 1)
    assert pow2_bucket(2) == (2, 3)
    assert pow2_bucket(3) == (2, 3)
    assert pow2_bucket(17) == (16, 31)
    assert pow2_bucket(0) == (1, 1)            # clamped


def test_class_key_roundtrip():
    rc = classify("decode", batch=5, seq_len=40)
    assert rc.key == "decode/b4/s32-63"
    assert RequestClass.from_key(rc.key) == rc
    assert rc.probe_batch == 4 and rc.probe_seq_len == 32


def test_class_rejects_bad_inputs():
    with pytest.raises(ValueError):
        RequestClass("encode", 1, 1, 1)
    with pytest.raises(ValueError):
        RequestClass.from_key("decode/4/32")


def test_reserved_key_helpers():
    assert golden_key("a", "f", "b").startswith("audit-class--")
    assert log_key("eng/1 *x").startswith("audit--")
    assert "/" not in log_key("eng/1")[len("audit--"):]
    assert sanitize_id("///") == "engine"


# -- sampler ------------------------------------------------------------------

def test_every_n_fires_n_times_out_of_n_squared():
    s = Sampler(every=5, seed=3)
    decisions = [s.observe("c") for _ in range(25)]
    fired = [d for d in decisions if d.sample]
    assert len(fired) == 5
    assert all(d.reason == "every_n" for d in fired)
    assert s.counts["c"] == 25 and s.sampled["c"] == 5


def test_sampler_is_deterministic_and_phase_offset_varies_by_class():
    a = Sampler(every=8, seed=1)
    b = Sampler(every=8, seed=1)
    trace_a = [a.observe("x").sample for _ in range(32)]
    trace_b = [b.observe("x").sample for _ in range(32)]
    assert trace_a == trace_b
    assert a._phase("prefill/b2/s8-15") != a._phase("decode/b2/s8-15") or \
        a._phase("prefill/b2/s8-15") != a._phase("decode/b4/s32-63")


def test_slo_headroom_skips_pressured_firings():
    s = Sampler(every=2, slo_ms=10.0, headroom=0.5, seed=0)
    # every firing arrives at 9ms latency: over the 5ms headroom -> skipped
    fired = [s.observe("c", latency_s=0.009).sample for _ in range(10)]
    assert sum(fired) == 0
    assert s.slo_skipped == 5
    # quiet traffic (1ms) samples normally
    fired = [s.observe("c", latency_s=0.001).sample for _ in range(10)]
    assert sum(fired) == 5


def test_slo_only_trigger_has_refractory_gap():
    s = Sampler(every=0, slo_ms=10.0, headroom=0.5, slo_gap=4, seed=0)
    fired = [s.observe("c", latency_s=0.001).sample for _ in range(12)]
    assert fired[0] is True
    assert sum(fired) == 3                      # one per 4-observation gap


def test_config_change_forces_sample():
    s = Sampler(every=1000, seed=0)
    s.observe("c", fingerprint="v1")
    dec = s.observe("c", fingerprint="v2")
    assert dec.sample and dec.reason == "config_change"


# -- audit log ----------------------------------------------------------------

def test_log_ring_rolls_but_counts_are_monotonic():
    log = AuditLog(capacity=4)
    for i in range(10):
        log.record("c", "every_n", "alarm" if i % 2 else "check")
    assert len(log) == 4
    assert log.dropped == 6
    assert log.alarm_count() == 5               # survives the ring
    assert log.counts["c"]["check"] == 5


def test_log_payload_roundtrip():
    log = AuditLog(capacity=8)
    log.record("a", "every_n", "check", energy_delta=0.01, latency_s=0.002)
    log.record("b", "config_change", "alarm", diagnosis_kind="api_difference",
               detail="x", degraded=True)
    payload = json.loads(json.dumps(log.to_payload()))
    again = AuditLog.from_payload(payload)
    assert again.to_payload() == log.to_payload()
    assert list(again)[1].diagnosis_kind == "api_difference"


def test_event_ignores_unknown_payload_fields():
    ev = AuditEvent.from_payload({"seq": 0, "class_key": "c", "reason": "r",
                                  "kind": "check", "future_field": 1})
    assert ev.class_key == "c"


# -- live integration ---------------------------------------------------------

N_SOAK = 6


@pytest.fixture(scope="module")
def served(tmp_path_factory):
    """One healthy audited engine that has served traffic into a store."""
    root = tmp_path_factory.mktemp("fleet")
    cfg = configs.get_config("gpt2-small").reduced(num_layers=2)
    params = tf.model_init(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, ecfg=EngineConfig(
        batch_size=2, max_len=48, audit_sample_every=4, store=str(root),
        engine_id="healthy", audit_timeout_s=300.0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 12,
                                               dtype=np.int32).astype(np.int32),
                    max_new_tokens=6) for i in range(4)]
    eng.generate(reqs)
    return cfg, params, eng, root


def test_live_audit_records_multiple_classes(served):
    _, _, eng, _ = served
    a = eng.auditor.summary()
    assert len(a["classes"]) >= 2               # prefill + decode buckets
    assert a["sampled"] >= 2
    assert eng.stats["audit_sampled"] == a["sampled"]
    assert eng.stats["audit_alarms"] == 0


def test_soak_unchanged_engine_never_alarms(served):
    """Seeded-noise soak: N full drift checks of an unchanged engine class
    (recheck_every=1 disables the once-per-process shortcut) must produce
    zero alarms at the declared rtol."""
    cfg, params, _, root = served
    eng = ServeEngine(cfg, params, ecfg=EngineConfig(
        batch_size=2, max_len=48, audit_sample_every=1, store=str(root),
        engine_id="soak", audit_recheck_every=1, audit_timeout_s=300.0))
    rc = classify("decode", 2, 12)
    for i in range(N_SOAK):
        ev = eng.auditor.sample(rc, "every_n", latency_s=0.001)
        assert ev.kind == "check", ev.to_payload()
        assert (ev.energy_delta or 0.0) == 0.0
    assert eng.auditor.alarms == []
    assert eng.auditor.log.alarm_count() == 0


def test_mutated_engine_alarms_with_diagnosis_kind(served):
    """An engine whose decode step regressed must alarm against the healthy
    fleet golden and name the planted diagnosis kind."""
    cfg, params, _, root = served
    eng = ServeEngine(cfg, params, ecfg=EngineConfig(
        batch_size=2, max_len=48, audit_sample_every=1, store=str(root),
        engine_id="mutated", audit_timeout_s=300.0,
        audit_mutate_decode="redundant_recompute"))
    rc = classify("decode", 2, 12)
    ev = eng.auditor.sample(rc, "every_n")
    assert ev.kind == "alarm"
    assert eng.auditor.alarms, "mutated decode step must raise a drift alarm"
    alarm = eng.auditor.alarms[0]
    # redundant_recompute plants the c15 recomputation -> api_difference
    assert alarm.diagnosis_kind == "api_difference"
    assert alarm.energy_delta > 0.0
    assert alarm.class_key == rc.key


def test_inapplicable_decode_mutation_fails_loudly(served):
    """dtype_upcast has no site on a bf16 serving model (every dot runs on
    bf16 storage).  The audit must surface that as an explicit probe error
    in ``audit_last_error`` — not sample a silently-unmutated clean twin
    that can never alarm (the PR 7 vacuous-green failure mode)."""
    cfg, params, _, root = served
    assert str(cfg.dtype) == "bfloat16"
    eng = ServeEngine(cfg, params, ecfg=EngineConfig(
        batch_size=2, max_len=48, audit_sample_every=1, store=str(root),
        engine_id="inapplicable", audit_timeout_s=300.0,
        audit_mutate_decode="dtype_upcast"))
    eng._observe_audit("decode", 2, 12, latency_s=0.001)
    assert eng.stats["audit_failures"] >= 1
    err = eng.stats["audit_last_error"] or ""
    assert "dtype_upcast" in err and "no applicable site" in err
    assert eng.auditor.alarms == []             # no fake alarm either


def test_fleet_status_aggregates_engines_and_alarms(served):
    _, _, _, root = served
    status = fleet_status(str(root))
    ids = [e["engine_id"] for e in status["engines"]]
    assert "healthy" in ids and "soak" in ids and "mutated" in ids
    assert status["total_alarms"] >= 1
    dec = status["classes"]["decode/b2/s8-15"]
    assert dec["alarms"] >= 1
    assert "api_difference" in dec["diagnosis_kinds"]
    assert dec["energy_j"] is not None and dec["energy_j"] > 0
    text = render_fleet_status(status)
    assert "api_difference" in text and "mutated" in text


def test_health_json_roundtrip(served):
    """Adversarial-harness idiom: health() must survive dumps/loads
    unchanged — it is served verbatim from a /healthz endpoint."""
    _, _, eng, _ = served
    h = eng.health()
    again = json.loads(json.dumps(h))
    assert again == h
    assert "audit_breaker_open" in h and "audit_last_error" in h
    assert h["audit"]["sampled"] >= 2


def test_auditor_without_store_still_checks(served):
    cfg, params, _, _ = served
    eng = ServeEngine(cfg, params, ecfg=EngineConfig(
        batch_size=2, max_len=48, audit_sample_every=1,
        audit_timeout_s=300.0))
    rc = classify("decode", 2, 12)
    ev = eng.auditor.sample(rc, "every_n")
    assert ev.kind == "check"                   # in-memory golden election
    assert eng.auditor.flush() is False         # nothing to flush into


def test_flush_failure_keeps_events(served, monkeypatch):
    """A store that rejects audit-log writes must not lose samples or make
    the sampled path raise — flush fails typed, events stay in memory."""
    cfg, params, _, root = served
    eng = ServeEngine(cfg, params, ecfg=EngineConfig(
        batch_size=2, max_len=48, audit_sample_every=1, store=str(root),
        engine_id="flaky-flush", audit_timeout_s=300.0))
    auditor = eng.auditor
    from repro.core.store import TransientStoreError
    backend = auditor.session.store.backend
    real_write = backend.write_manifest

    def flaky(key, payload):
        if key.startswith("audit--"):           # only the log flush fails
            raise TransientStoreError("mirror down")
        return real_write(key, payload)

    monkeypatch.setattr(backend, "write_manifest", flaky)
    rc = classify("decode", 2, 12)
    ev = auditor.sample(rc, "every_n")          # must not raise
    assert ev.kind in ("check", "alarm")
    assert auditor.flush_failures >= 1
    assert len(auditor.log) >= 1                # event retained in memory
    monkeypatch.setattr(backend, "write_manifest", real_write)
    assert auditor.flush() is True              # next flush delivers it
