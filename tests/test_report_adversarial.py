"""Report / RankResult JSON round-trips under adversarial inputs.

Stored reports are the regression-harness's currency (baseline JSON, CLI
--json, offline re-render), so serialization must survive the hostile
corners: empty finding lists, NaN/inf energies (a replay backend on a
zero-time op), unicode case ids.  Property-based versions run when
hypothesis is installed (tests/_hyp.py shim skips them otherwise).
"""

import json
import math

import pytest

from _hyp import HAVE_HYPOTHESIS, given, settings, st
from repro.core.diagnose import DIAGNOSIS_KINDS, Diagnosis
from repro.core.report import Finding, Report
from repro.core.session import RankResult

UNICODE_IDS = ["cas-Δ✓", "日本語-case", "naïve—twin", "c6‮growtham",
               "emoji-🔥🐍", ""]


def _finding(e_a=1.0, e_b=0.5, cls="energy_waste", diag=True):
    return Finding(
        region_idx=0, energy_a_j=e_a, energy_b_j=e_b,
        time_a_s=1e-3, time_b_s=2e-3, nodes_a=[0, 1], nodes_b=[2],
        classification=cls, wasteful_side="A",
        diagnosis=Diagnosis(kind=DIAGNOSIS_KINDS[0],
                            deviation_point="f.py:1:fn", detail="d",
                            key_variables=["precision"], ops_a=["dot"],
                            ops_b=["dot"]) if diag else None)


def _roundtrip_report(rep: Report) -> Report:
    again = Report.from_json(rep.to_json())
    assert again.to_json() == rep.to_json()
    return again


def test_report_roundtrip_empty_findings():
    rep = Report(name_a="a", name_b="b", findings=[],
                 total_energy_a_j=0.0, total_energy_b_j=0.0, meta={})
    again = _roundtrip_report(rep)
    assert again.findings == [] and again.waste_findings == []
    assert "energy-waste findings: 0" in again.render()


@pytest.mark.parametrize("val", [float("nan"), float("inf"), float("-inf"),
                                 -0.0, 5e-324])
def test_report_roundtrip_non_finite_energies(val):
    rep = Report(name_a="a", name_b="b",
                 findings=[_finding(e_a=val, e_b=val)],
                 total_energy_a_j=val, total_energy_b_j=val, meta={})
    again = _roundtrip_report(rep)
    got = again.findings[0].energy_a_j
    assert (math.isnan(got) if math.isnan(val) else got == val)
    again.render()                            # must not raise on NaN/inf
    # derived percentages stay well-defined objects, never raise
    _ = again.findings[0].energy_delta_pct
    _ = again.findings[0].perf_delta_pct


@pytest.mark.parametrize("cid", UNICODE_IDS)
def test_report_roundtrip_unicode_case_ids(cid):
    rep = Report(name_a=cid, name_b=cid[::-1] or "b",
                 findings=[_finding()],
                 total_energy_a_j=1.0, total_energy_b_j=0.5,
                 meta={"case": cid, "energy_model": cid})
    again = _roundtrip_report(rep)
    assert again.name_a == cid and again.meta["case"] == cid
    assert cid in again.render() or not cid


def test_rank_result_roundtrip_adversarial():
    rep = Report(name_a=UNICODE_IDS[0], name_b=UNICODE_IDS[1],
                 findings=[], total_energy_a_j=float("nan"),
                 total_energy_b_j=float("inf"), meta={})
    rr = RankResult(names=[UNICODE_IDS[0], UNICODE_IDS[1]],
                    keys=["k0", "k1"],
                    total_energy_j=[float("nan"), float("inf")],
                    waste_matrix=[[0.0, float("nan")], [float("inf"), 0.0]],
                    reports={(0, 1): rep})
    again = RankResult.from_json(rr.to_json())
    assert again.to_json() == rr.to_json()
    assert again.names == rr.names
    assert math.isnan(again.waste_matrix[0][1])
    again.render()


def test_rank_result_roundtrip_no_reports():
    rr = RankResult(names=["a", "b"], keys=["x", "y"],
                    total_energy_j=[1.0, 2.0],
                    waste_matrix=[[0.0, 0.0], [1.0, 0.0]], reports={})
    again = RankResult.from_json(json.loads(rr.to_json()))
    assert again.to_json() == rr.to_json() and again.reports == {}


def test_finding_roundtrip_without_diagnosis():
    f = _finding(diag=False)
    assert Finding.from_json(json.dumps(
        json.loads(Report(name_a="a", name_b="b", findings=[f],
                          total_energy_a_j=1, total_energy_b_j=1,
                          meta={}).to_json())["findings"][0])) == f


# ---------------------------------------------------------------------------
# property-based versions (skipped when hypothesis is absent)
# ---------------------------------------------------------------------------

_energy = st.floats(allow_nan=True, allow_infinity=True)
_ids = st.text(max_size=24)


@settings(max_examples=30, deadline=None)
@given(name_a=_ids, name_b=_ids, e_a=_energy, e_b=_energy,
       t_a=_energy, t_b=_energy)
def test_report_roundtrip_property(name_a, name_b, e_a, e_b, t_a, t_b):
    f = Finding(region_idx=0, energy_a_j=e_a, energy_b_j=e_b,
                time_a_s=t_a, time_b_s=t_b, nodes_a=[], nodes_b=[],
                classification="comparable", wasteful_side="-",
                diagnosis=None)
    rep = Report(name_a=name_a, name_b=name_b, findings=[f],
                 total_energy_a_j=e_a, total_energy_b_j=e_b,
                 meta={"case": name_a})
    again = Report.from_json(rep.to_json())
    assert again.to_json() == rep.to_json()
    again.render()


@settings(max_examples=20, deadline=None)
@given(names=st.lists(_ids, min_size=2, max_size=4, unique=True),
       fill=_energy)
def test_rank_matrix_roundtrip_property(names, fill):
    n = len(names)
    rr = RankResult(names=names, keys=[f"k{i}" for i in range(n)],
                    total_energy_j=[fill] * n,
                    waste_matrix=[[fill] * n for _ in range(n)], reports={})
    again = RankResult.from_json(rr.to_json())
    assert again.to_json() == rr.to_json()


def test_hypothesis_shim_reports_availability():
    # the shim must always expose the four names the suite imports
    assert HAVE_HYPOTHESIS in (True, False)
