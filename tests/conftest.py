"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the single real CPU device; only launch/dryrun.py forces 512."""

import warnings

import jax
import pytest

warnings.filterwarnings("ignore")

# Energy-baseline gates (assert_no_energy_regression / energy_gate /
# the `energy_regression` marker) come from the in-tree plugin.
pytest_plugins = ["repro.testing.pytest_plugin"]


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)


class _LazyGoldenRecords:
    """Per-case lazily recorded golden baselines (mapping-like).

    Each case is recorded on first access and cached for the session, so a
    ``-k``-selected subset (the CI fast lane runs backend parity on four
    cases) only pays for the cases it touches, while whole-zoo consumers
    iterate every id and force a full record.
    """

    def __init__(self, store):
        from repro.zoo import cases as zoo
        self._store = store
        self._zoo = zoo
        self._cache = {}

    def __getitem__(self, case_id):
        if case_id not in self._cache:
            res = self._store.record(self._zoo.get_case(case_id))
            self._cache[case_id] = {
                "baseline": res.baseline,
                "report": res.report,
                "graph_a": res.art_a.graph,
                "graph_b": res.art_b.graph,
            }
        return self._cache[case_id]

    def __iter__(self):
        return (c.id for c in self._zoo.list_cases())

    def __len__(self):
        return len(self._zoo.list_cases())

    def record_all(self):
        for case_id in self:
            self[case_id]


@pytest.fixture(scope="session")
def golden(tmp_path_factory):
    """Golden baselines for the zoo, recorded lazily per case.

    Cases are recorded into a fresh BaselineStore (artifacts +
    committed-style JSON under a session tmp dir) on first access through
    ``golden["records"][case_id]``; the lightweight record-time products —
    baseline, report, both traced graphs — are kept for downstream suites
    (offline drift replay, backend parity).  Whole-zoo consumers call
    ``golden["records"].record_all()`` first.  The heavy CandidateArtifacts
    are dropped; their bytes live in the store on disk.
    """
    from repro.testing.baselines import BaselineStore

    import shutil

    root = tmp_path_factory.mktemp("golden-baselines")
    store = BaselineStore(root)
    yield {"root": root, "records": _LazyGoldenRecords(store)}
    # the artifact store is multi-GB; don't let pytest's retained tmp dirs
    # (default: last 3 sessions) accumulate it in /tmp
    shutil.rmtree(root / "store", ignore_errors=True)
