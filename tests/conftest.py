"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the single real CPU device; only launch/dryrun.py forces 512."""

import warnings

import jax
import pytest

warnings.filterwarnings("ignore")


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)
