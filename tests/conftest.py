"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the single real CPU device; only launch/dryrun.py forces 512."""

import warnings

import jax
import pytest

warnings.filterwarnings("ignore")

# Energy-baseline gates (assert_no_energy_regression / energy_gate /
# the `energy_regression` marker) come from the in-tree plugin.
pytest_plugins = ["repro.testing.pytest_plugin"]


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)


@pytest.fixture(scope="session")
def golden(tmp_path_factory):
    """Golden baselines for the whole zoo, recorded once per test session.

    Records every registered case into a fresh BaselineStore (artifacts +
    committed-style JSON under a session tmp dir) and keeps the lightweight
    record-time products — baseline, report, both traced graphs — for
    downstream suites (offline drift replay, backend parity).  The heavy
    CandidateArtifacts are dropped; their bytes live in the store on disk.
    """
    from repro.testing.baselines import BaselineStore
    from repro.zoo import cases as zoo

    import shutil

    root = tmp_path_factory.mktemp("golden-baselines")
    store = BaselineStore(root)
    records = {}
    for case in zoo.list_cases():
        res = store.record(case)
        records[case.id] = {
            "baseline": res.baseline,
            "report": res.report,
            "graph_a": res.art_a.graph,
            "graph_b": res.art_b.graph,
        }
    yield {"root": root, "records": records}
    # the artifact store is multi-GB; don't let pytest's retained tmp dirs
    # (default: last 3 sessions) accumulate it in /tmp
    shutil.rmtree(root / "store", ignore_errors=True)
