"""Dry-run integration: one small cell lowers + compiles on the forced
512-device mesh in a subprocess (the deliverable-(e) contract), and the
collective-bytes parser handles both replica-group formats."""

import json
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_dryrun_cell_compiles_on_512_devices():
    # XLA's 512-device compile time varies by an order of magnitude across
    # hosts; a fixed deadline flakes tier-1 on slow CI shards.  The budget
    # comes from the environment (override upward on known-slow machines)
    # and exhausting it skips rather than fails — a timeout says nothing
    # about the dryrun contract, only about this host's compile throughput.
    budget_s = float(os.environ.get("MAGNETON_DRYRUN_BUDGET_S", "560"))
    with tempfile.TemporaryDirectory() as out:
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(REPO, "src"))
        env.pop("XLA_FLAGS", None)          # dryrun must set it itself
        try:
            r = subprocess.run(
                [sys.executable, "-m", "repro.launch.dryrun",
                 "--arch", "gpt2-small", "--shape", "decode_32k",
                 "--mesh", "both", "--out", out],
                env=env, capture_output=True, text=True, timeout=budget_s)
        except subprocess.TimeoutExpired:
            pytest.skip(f"dryrun exceeded the {budget_s:g}s compile budget "
                        "(set MAGNETON_DRYRUN_BUDGET_S to raise it)")
        assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
        cells = sorted(os.listdir(out))
        assert len(cells) == 2
        for c in cells:
            rec = json.load(open(os.path.join(out, c)))
            assert rec["status"] == "ok", rec.get("error")
            assert rec["devices"] in (256, 512)
            assert rec["per_device"]["flops"] > 0
            assert rec["roofline"]["dominant"] in (
                "compute_s", "memory_s", "collective_s")


def test_collective_parser_explicit_groups():
    from repro.launch.dryrun import collective_bytes
    hlo = ("%ar = f32[1024,256]{1,0} all-reduce(%x), "
           "replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add")
    out = collective_bytes(hlo, pod_size=256)
    want = 2 * 1024 * 256 * 4 * 3 / 4
    assert out["ici"] == pytest.approx(want)
    assert out["dcn"] == 0


def test_collective_parser_iota_groups_pod_crossing():
    from repro.launch.dryrun import collective_bytes
    # 16 groups of 32, iota over [2,16,16] transposed so groups span pods
    hlo = ("%ag = bf16[64,64]{1,0} all-gather(%x), "
           "replica_groups=[16,32]<=[2,16,16]T(1,0,2), dimensions={0}")
    out = collective_bytes(hlo, pod_size=256)
    assert out["dcn"] > 0          # groups mix pod 0 and pod 1 ids
    assert out["ici"] == 0


def test_collective_parser_variadic_tuple_result():
    from repro.launch.dryrun import collective_bytes
    hlo = ("%ar = (f32[128]{0}, f32[256]{0}) all-reduce(%a, %b), "
           "replica_groups={{0,1}}, to_apply=%add")
    out = collective_bytes(hlo, pod_size=256)
    want = 2 * (128 + 256) * 4 * 1 / 2
    assert out["ici"] == pytest.approx(want)
