"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and finiteness (deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T
from repro.train.optimizer import OptimizerConfig, init_opt_state
from repro.train.train_step import TrainConfig, make_train_step

ARCHS = configs.list_archs()


def _batch(cfg, key, B=2, S=16):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "audio":
        batch = {"frames": jax.random.normal(key, (B, S, cfg.d_model),
                                             jnp.float32).astype(cfg.dtype),
                 "labels": tokens}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.d_model)).astype(cfg.dtype)
    return batch


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = configs.get_config(arch).reduced()
            params = T.model_init(cfg, jax.random.key(0))
            cache[arch] = (cfg, params)
        return cache[arch]
    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, arch_state):
    cfg, params = arch_state(arch)
    key = jax.random.key(1)
    B, S = 2, 16
    batch = _batch(cfg, key, B, S)
    logits, aux = T.forward(cfg, params, batch.get("tokens"),
                            inputs_embeds=batch.get("frames"),
                            image_embeds=batch.get("image_embeds"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch, arch_state):
    cfg, params = arch_state(arch)
    key = jax.random.key(2)
    batch = _batch(cfg, key)
    ocfg = OptimizerConfig()
    step = make_train_step(cfg, None, ocfg, TrainConfig(remat=False))
    opt = init_opt_state(params, ocfg)
    p2, o2, metrics = jax.jit(step)(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually changed (some leaves move by only ~lr*1e-2; exact
    # inequality on any leaf is the right check)
    changed = any(
        not np.array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(p2)))
    assert changed


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if configs.get_config(a).is_causal])
def test_prefill_decode_parity(arch, arch_state):
    """decode_step(prefill(prompt)) logits == forward(prompt+token) logits."""
    cfg, params = arch_state(arch)
    key = jax.random.key(3)
    B, S = 1, 8
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    img = None
    if cfg.family == "vlm":
        img = jax.random.normal(key, (B, cfg.num_image_tokens,
                                      cfg.d_model)).astype(cfg.dtype)
    # full forward over S+1 tokens
    full_logits, _ = T.forward(cfg, params, tokens, image_embeds=img,
                               remat=False)
    # prefill on S tokens, decode 1
    _, caches = T.prefill(cfg, params, tokens[:, :S], max_len=S + 1,
                          image_embeds=img)
    dec_logits, _ = T.decode_step(cfg, params, caches, tokens[:, S:S + 1],
                                  jnp.int32(S))
    a = np.asarray(full_logits[:, -1, :], np.float32)
    b = np.asarray(dec_logits[:, -1, :], np.float32)
    # bf16 accumulation differences across the two paths
    np.testing.assert_allclose(a, b, atol=0.15, rtol=0.15)
    assert np.argmax(a) == np.argmax(b)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_matches_schema(arch):
    cfg = configs.get_config(arch)
    n = cfg.param_count()
    assert n > 0
    na = cfg.active_param_count()
    assert 0 < na <= n
    if cfg.moe_num_experts:
        assert na < n


def test_supported_shapes_skips():
    """DESIGN.md §4 skip table: encoder-only has no decode; full-attention
    archs skip long_500k; SSM/hybrid run it."""
    assert "decode_32k" not in configs.supported_shapes(
        configs.get_config("hubert-xlarge"))
    assert "long_500k" not in configs.supported_shapes(
        configs.get_config("qwen1.5-110b"))
    assert "long_500k" in configs.supported_shapes(
        configs.get_config("xlstm-1.3b"))
    assert "long_500k" in configs.supported_shapes(
        configs.get_config("jamba-1.5-large-398b"))
