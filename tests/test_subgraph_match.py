"""Algorithm 1: topology-aware subgraph matching on constructed graphs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import trace
from repro.core.interp import capture_tensor_values
from repro.core.subgraph_match import match_subgraphs
from repro.core.tensor_match import TensorMatcher


def _match(fn_a, fn_b, args, rtol=1e-3):
    ga = trace(fn_a, *args, name="a")
    gb = trace(fn_b, *args, name="b")
    va = [capture_tensor_values(ga, *args)]
    vb = [capture_tensor_values(gb, *args)]
    pairs = TensorMatcher(rtol=rtol).match(va, vb)
    return ga, gb, match_subgraphs(ga, gb, pairs)


def test_identical_graphs_fully_matched():
    def f(x, w):
        return jnp.tanh(x @ w) @ w.T

    x, w = np.random.default_rng(0).standard_normal((2, 16, 16)).astype(np.float32)
    ga, gb, regions = _match(f, f, (x, w))
    covered_a = {n for r in regions for n in r.nodes_a}
    assert covered_a == set(range(len(ga.nodes)))
    # every region should pair identical node multisets
    for r in regions:
        prims_a = sorted(ga.nodes[n].primitive for n in r.nodes_a)
        prims_b = sorted(gb.nodes[n].primitive for n in r.nodes_b)
        assert prims_a == prims_b


def test_figure7_fused_vs_split_qkv():
    """The paper's Figure 7: separate Q,K,V projections vs fused QKV+split
    must match as one equivalent region (cut at the attention output)."""
    rng = np.random.default_rng(1)
    x = rng.standard_normal((8, 32)).astype(np.float32)
    wq = rng.standard_normal((32, 16)).astype(np.float32)
    wk = rng.standard_normal((32, 16)).astype(np.float32)
    wv = rng.standard_normal((32, 16)).astype(np.float32)

    def split_qkv(x, wq, wk, wv):
        q, k, v = x @ wq, x @ wk, x @ wv
        s = jax.nn.softmax(q @ k.T / 4.0, axis=-1)
        o = s @ v
        return jnp.tanh(o)

    def fused_qkv(x, wq, wk, wv):
        w = jnp.concatenate([wq, wk, wv], axis=1)
        qkv = x @ w
        q, k, v = jnp.split(qkv, 3, axis=1)
        s = jax.nn.softmax(q @ k.T / 4.0, axis=-1)
        o = s @ v
        return jnp.tanh(o)

    ga, gb, regions = _match(split_qkv, fused_qkv, (x, wq, wk, wv))
    assert regions, "no regions matched"
    # find the region containing the projection stage on both sides
    proj = next(r for r in regions
                if any(ga.nodes[n].primitive == "dot_general"
                       for n in r.nodes_a)
                and any(gb.nodes[n].primitive == "concatenate"
                        for n in r.nodes_b))
    # side A has 3 projection dots, side B has concat+1 dot+split
    dots_a = sum(ga.nodes[n].primitive == "dot_general" for n in proj.nodes_a)
    assert dots_a >= 3


def test_recursion_depth_produces_multiple_regions():
    """A chain with k matched intermediates must split into k+1 regions."""
    def f(x):
        a = jnp.tanh(x)
        b = a * 2.0
        c = jnp.exp(b)
        return c.sum()

    x = np.random.default_rng(2).standard_normal((16, 16)).astype(np.float32)
    ga, gb, regions = _match(f, f, (x,))
    assert len(regions) >= 3


def test_vectorized_dominator_solve_matches_reference():
    """The array-based single-pass dominator solve must return the exact
    path of the seed dict-based CHK fixpoint on real traced flow graphs and
    on adversarial random DAGs (the existing matcher tests above are the
    end-to-end oracle; this pins the solver itself)."""
    from repro.core.subgraph_match import (_SRC, _build_flow,
                                           _dominator_path,
                                           _dominator_path_reference)

    # real flow graphs from traced candidates
    def f(x, w):
        return jnp.tanh(x @ w) @ w.T

    x, w = np.random.default_rng(0).standard_normal((2, 16, 16)).astype(
        np.float32)
    g = trace(f, x, w, name="g")
    flow, _ = _build_flow(g, list(g.inputs), list(g.outputs))
    assert _dominator_path(flow) == _dominator_path_reference(flow)
    assert len(_dominator_path(flow)) >= 2       # src .. snk at minimum

    # random layered DAGs wired into the same succ-dict encoding, including
    # diamonds, skip edges, and vertices unreachable from the sink
    from repro.core.subgraph_match import _SNK
    rng = np.random.default_rng(7)
    for trial in range(25):
        n = int(rng.integers(2, 40))
        succ = {_SRC: [0], _SNK: []}
        for v in range(n):
            succ[v] = []
            for u in range(v + 1, n):
                if rng.random() < 0.15:
                    succ[v].append(u)
        succ[n - 1].append(_SNK)
        if rng.random() < 0.5:               # extra source fan-out
            succ[_SRC].append(int(rng.integers(0, n)))
        assert _dominator_path(succ) == _dominator_path_reference(succ), \
            f"trial {trial}: vectorized dominator solve diverged"


def test_o_n_squared_scalability():
    """Matching a ~200-node pair completes quickly (paper Fig. 9 analogue is
    in benchmarks; here we just guard the complexity class)."""
    import time

    def deep(x):
        for i in range(60):
            x = jnp.tanh(x * 1.01 + 0.01)
        return x

    x = np.random.default_rng(3).standard_normal((8, 8)).astype(np.float32)
    t0 = time.time()
    ga, gb, regions = _match(deep, deep, (x,))
    assert time.time() - t0 < 60
    assert len(regions) >= 30


def test_block_memo_regions_identical_to_full_recursion():
    """Hierarchical region matching (template memo + piecewise dominator
    decomposition + sparse between-sets, all active at this size) must
    reproduce the unmemoized recursion's region list EXACTLY — same order,
    same node sets, same cut pairs, same depths."""
    from repro.core.block_match import BlockStamper
    from repro.core.interp import capture_tensor_stats
    from repro.core.subgraph_match import (_PIECEWISE_MIN_NODES,
                                           match_subgraphs)

    def deep(x, w):
        for _ in range(110):          # 551 nodes: piecewise + sparse paths on
            x = (jnp.tanh(x @ w) + 0.5 * x) * 1.01
        return x.sum()

    # block-diagonal rotation weight: keeps every layer's activation
    # distinct (an eye-like weight converges to a fixed point and collapses
    # the stack into a handful of duplicate-tensor regions)
    w0 = np.zeros((8, 8), np.float32)
    for i in range(0, 8, 2):
        c, s = np.cos(1.0 + i * 0.1), np.sin(1.0 + i * 0.1)
        w0[i, i], w0[i, i + 1], w0[i + 1, i], w0[i + 1, i + 1] = c, s, -s, c
    w = jnp.asarray(0.99 * w0)
    x = jnp.arange(32.0, dtype=jnp.float32).reshape(4, 8) / 10.0
    ga = trace(deep, x, w, name="a")
    gb = trace(deep, x, w, name="b")
    assert len(ga.nodes) >= max(_PIECEWISE_MIN_NODES, 512)
    samples = [(x, w)]
    _, sa = capture_tensor_stats(ga, x, w)
    _, sb = capture_tensor_stats(gb, x, w)
    m = TensorMatcher()
    pairs = m.match_streamed(
        [sa], [sb],
        lambda k, tids: capture_tensor_values(ga, x, w, only_tids=tids),
        lambda k, tids: capture_tensor_values(gb, x, w, only_tids=tids),
        stamper=BlockStamper(ga, gb, samples, samples))
    fast = match_subgraphs(ga, gb, pairs)
    full = match_subgraphs(ga, gb, pairs, block_memo=False)

    def key(r):
        return (tuple(r.nodes_a), tuple(r.nodes_b), r.in_pair, r.out_pair,
                r.depth)

    assert [key(r) for r in fast] == [key(r) for r in full]
    assert len(fast) >= 100           # the stack actually decomposed
