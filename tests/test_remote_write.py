"""Writable http(s) RemoteStore: the conditional-put dialect.

Exercises the loopback S3/GCS stand-in (repro.testing.httpstore) against
the opt-in writable remote: idempotent-by-address chunk puts, last-writer-
wins manifests, ETag-CAS index.json merges under real thread contention,
transient-503 retry absorption, and the readonly default staying intact.
"""

import json
import threading

import pytest

from repro.core.artifact import ArtifactStore
from repro.core.session import Session
from repro.core.store import (RemoteStore, RetryPolicy,
                              StorePreconditionError, StoreReadOnlyError,
                              TransientStoreError, chunk_digest, open_store)
from repro.testing.httpstore import serve_store


def _fast_retry(**kw):
    return RetryPolicy(base_delay_s=0.001, max_delay_s=0.01,
                       sleep=lambda s: None, **kw)


@pytest.fixture()
def srv(tmp_path):
    with serve_store(tmp_path / "fleet") as server:
        yield server


def _wstore(srv) -> RemoteStore:
    return RemoteStore(srv.url, writable=True, retry=_fast_retry())


# -- defaults and denial ------------------------------------------------------

def test_http_store_stays_readonly_by_default(srv):
    store = RemoteStore(srv.url, retry=_fast_retry())
    assert store.readonly
    with pytest.raises(StoreReadOnlyError):
        store.write_manifest("k", {"v": 1})
    with pytest.raises(StoreReadOnlyError):
        store.write_chunk(chunk_digest(b"x"), b"x")


def test_open_store_writable_flag(srv):
    assert open_store(srv.url).readonly
    assert not open_store(srv.url, writable=True).readonly


def test_server_405_maps_to_readonly_error(srv):
    store = _wstore(srv)
    srv.reject_writes = True
    with pytest.raises(StoreReadOnlyError):
        store.write_manifest("k", {"v": 1})


# -- round-trip ---------------------------------------------------------------

def test_manifest_and_chunk_roundtrip(srv):
    w = _wstore(srv)
    digest = chunk_digest(b"payload")
    w.write_chunk(digest, b"payload")
    w.write_manifest("m1", {"hello": "world"})

    r = RemoteStore(srv.url, retry=_fast_retry())   # independent reader
    assert r.read_manifest("m1") == {"hello": "world"}
    assert r.read_chunk(digest) == b"payload"
    assert r.manifest_keys() == ["m1"]
    assert r.has_manifest("m1") and not r.has_manifest("nope")


def test_delete_manifest_updates_index(srv):
    w = _wstore(srv)
    w.write_manifest("a", {})
    w.write_manifest("b", {})
    w.delete_manifest("a")
    assert RemoteStore(srv.url, retry=_fast_retry()).manifest_keys() == ["b"]


# -- conditional puts ---------------------------------------------------------

def test_chunk_put_is_idempotent_by_address(srv):
    w1, w2 = _wstore(srv), _wstore(srv)
    digest = chunk_digest(b"shared-bytes")
    w1.write_chunk(digest, b"shared-bytes")
    before = srv.puts
    w2.write_chunk(digest, b"shared-bytes")    # 412 -> dedup hit, no write
    assert srv.puts == before
    assert w2.counters["chunk_dedup_hits"] == 1
    assert w2.read_chunk(digest) == b"shared-bytes"


def test_stale_if_match_raises_precondition(srv):
    w = _wstore(srv)
    w.write_manifest("m", {"v": 1})
    with pytest.raises(StorePreconditionError):
        w._request_once("PUT", "manifests/m.json", data=b"{}",
                        headers={"If-Match": '"not-the-etag"'})


def test_index_cas_merges_concurrent_writers(srv):
    """Two stores interleave writes; neither may clobber the other's keys."""
    w1, w2 = _wstore(srv), _wstore(srv)
    w1.write_manifest("from-w1-a", {})
    w2.write_manifest("from-w2-a", {})
    w1.write_manifest("from-w1-b", {})
    w2.write_manifest("from-w2-b", {})
    keys = RemoteStore(srv.url, retry=_fast_retry()).manifest_keys()
    assert keys == ["from-w1-a", "from-w1-b", "from-w2-a", "from-w2-b"]


def test_index_cas_under_thread_contention(srv):
    """N threads, one store each, racing on index.json: the CAS loop must
    converge on the union with no lost updates."""
    n_threads, per = 4, 6
    errors = []

    def writer(t):
        try:
            w = _wstore(srv)
            for i in range(per):
                w.write_manifest(f"t{t}-m{i}", {"t": t, "i": i})
        except Exception as e:                 # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    keys = RemoteStore(srv.url, retry=_fast_retry()).manifest_keys()
    assert keys == sorted(f"t{t}-m{i}" for t in range(n_threads)
                          for i in range(per))
    # index.json on disk is the same sorted union (byte-determinism)
    index = json.loads((srv.root / "index.json").read_text())
    assert index["manifests"] == keys


def test_bulk_defers_index_to_one_cas_update(srv):
    w = _wstore(srv)
    with w.bulk():
        for i in range(5):
            w.write_manifest(f"bulk-{i}", {"i": i})
        puts_during = srv.puts
    # 5 manifest PUTs inside the bulk, index.json PUT only at exit
    assert srv.puts == puts_during + 1
    assert len(RemoteStore(srv.url,
                           retry=_fast_retry()).manifest_keys()) == 5


# -- transient faults ---------------------------------------------------------

def test_503_put_absorbed_by_retry(srv):
    w = _wstore(srv)
    srv.fail_puts = 2
    w.write_manifest("m", {"ok": True})
    assert w.counters["retries"] >= 2
    assert RemoteStore(srv.url,
                       retry=_fast_retry()).read_manifest("m") == {"ok": True}


def test_exhausted_retries_surface_transient(srv):
    w = RemoteStore(srv.url, writable=True,
                    retry=_fast_retry(max_attempts=2))
    srv.fail_puts = 99
    with pytest.raises(TransientStoreError):
        w.write_manifest("m", {})
    srv.fail_puts = 0


# -- full artifact stack over the writable remote -----------------------------

def _square(x):
    return x * x


def test_session_capture_persists_to_writable_http(srv):
    import numpy as np
    args = (np.arange(6, dtype=np.float32).reshape(2, 3),)
    s1 = Session(store=srv.url, store_writable=True)
    art = s1.capture(_square, args, name="sq")
    assert not art.meta.get("degraded")

    # a second engine (fresh session, same remote) gets a pure cache hit
    s2 = Session(store=srv.url, store_writable=True)
    art2 = s2.capture(_square, args, name="sq")
    assert art2.meta.get("cache_hit")
    assert art2.key == art.key


def test_artifact_store_push_to_http(tmp_path, srv):
    import numpy as np
    local = ArtifactStore(tmp_path / "local")
    s = Session(store=local)
    s.capture(_square, (np.ones((3, 3), np.float32),), name="sq")
    res = local.push(srv.url)
    assert res["manifests"] == 1
    mirror = ArtifactStore.from_uri(srv.url)
    assert mirror.keys() == local.keys()
