"""Equivalence of the bucketed+lazy matcher with the exhaustive oracle.

The fast two-phase matcher (phase 1: (numel, quantized-l2) buckets + cheap
symmetric gate; phase 2: lazy memoized unfolding SVDs on survivors) must
return the identical (tid_a, tid_b) pair set as the seed's eager exhaustive
matcher on the pipeline workloads, whether it is fed materialized values or
streamed signatures with selective re-capture.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.diff import DifferentialEnergyDebugger, _perturb
from repro.core.graph import trace
from repro.core.interp import capture_tensor_stats, capture_tensor_values
from repro.core.tensor_match import TensorMatcher, signature, stats_signature
from repro.zoo import cases

PARITY_CASES = ["c1-precision-prefill", "c6-matpow", "n1-gelu-backend"]


def _captures(case, n_samples=2):
    args = tuple(case.make_args())
    ga = trace(case.inefficient, *args, name="a")
    gb = trace(case.efficient, *args, name="b")
    samples = [args] + [_perturb(args, seed=17 + k)
                        for k in range(n_samples - 1)]
    vals_a = [capture_tensor_values(ga, *s) for s in samples]
    vals_b = [capture_tensor_values(gb, *s) for s in samples]
    return ga, gb, samples, vals_a, vals_b


@pytest.mark.parametrize("cid", PARITY_CASES)
def test_fast_matcher_matches_oracle_on_pipeline_workloads(cid):
    case = cases.by_id(cid)
    _, _, _, vals_a, vals_b = _captures(case)
    m = TensorMatcher()
    fast = m.match(vals_a, vals_b)
    oracle = m.match_exhaustive(vals_a, vals_b)
    assert set(fast) == set(oracle)


@pytest.mark.parametrize("cid", PARITY_CASES)
def test_streamed_matcher_matches_oracle(cid):
    case = cases.by_id(cid)
    ga, gb, samples, vals_a, vals_b = _captures(case)
    stats_a = [capture_tensor_stats(ga, *s)[1] for s in samples]
    stats_b = [capture_tensor_stats(gb, *s)[1] for s in samples]
    m = TensorMatcher()
    streamed = m.match_streamed(
        stats_a, stats_b,
        lambda k, tids: capture_tensor_values(ga, *samples[k], only_tids=tids),
        lambda k, tids: capture_tensor_values(gb, *samples[k], only_tids=tids))
    oracle = m.match_exhaustive(vals_a, vals_b)
    assert set(streamed) == set(oracle)


def test_streaming_capture_parity_with_materialized():
    """Streamed invariants agree with signatures of materialized values."""
    def fn(x, w):
        y = jnp.tanh(x @ w)
        return (y * 1.01 + x).sum(axis=0)

    x = jax.random.normal(jax.random.key(0), (32, 128))
    w = jax.random.normal(jax.random.key(1), (128, 128)) * 0.2
    g = trace(fn, x, w)
    values = capture_tensor_values(g, x, w)
    _, stats = capture_tensor_stats(g, x, w)
    assert set(stats) == set(values)
    for tid, sig in stats.items():
        ref = signature(values[tid])
        assert sig.numel == ref.numel
        assert sig.shape == tuple(values[tid].shape)
        for a, b in ((sig.l1, ref.l1), (sig.l2, ref.l2), (sig.mean, ref.mean),
                     (sig.amax, ref.amax), (sig.amin, ref.amin)):
            assert a == pytest.approx(b, rel=1e-5, abs=1e-12)


def test_streamed_capture_returns_graph_outputs():
    """capture_tensor_stats's outputs equal a direct execution (the reuse
    that lets diff.compare skip the third full run)."""
    def fn(x):
        return jnp.tanh(x) * 2.0, x.sum()

    x = jax.random.normal(jax.random.key(2), (8, 8))
    g = trace(fn, x)
    outs, _ = capture_tensor_stats(g, x)
    want = jax.tree_util.tree_leaves(fn(x))
    for o, wv in zip(outs, want):
        np.testing.assert_allclose(np.asarray(o), np.asarray(wv), rtol=1e-6)


def test_selective_capture_only_tids():
    def fn(x):
        return jnp.tanh(x @ x) + 1.0

    x = jax.random.normal(jax.random.key(3), (16, 16))
    g = trace(fn, x)
    full = capture_tensor_values(g, x)
    want = sorted(full)[:3]
    part = capture_tensor_values(g, x, only_tids=want)
    assert sorted(part) == want
    for t in want:
        np.testing.assert_array_equal(part[t], full[t])


def test_sketch_rejects_shuffled_large_tensor():
    """Tensors above max_svd_numel get a randomized-sketch spectral test:
    an entry permutation preserves every symmetric invariant but destroys
    the spectrum, so the fast matcher must reject it (the seed's
    invariants-only fallback could not)."""
    rng = np.random.default_rng(0)
    u = rng.standard_normal((40, 1)).astype(np.float32)
    v = rng.standard_normal((1, 30)).astype(np.float32)
    a = (u @ v)                       # rank-1, numel 1200
    b = np.ascontiguousarray(a.T)     # layout transform: must match
    c = rng.permutation(a.ravel()).reshape(a.shape)  # same multiset: reject
    m = TensorMatcher(max_svd_numel=1000)
    assert m.match([{0: a}], [{0: b}]) == [(0, 0)]
    assert m.match([{0: a}], [{0: c}]) == []
    # the invariants-only oracle cannot tell the shuffle apart
    assert m.match_exhaustive([{0: a}], [{0: c}]) == [(0, 0)]


def test_stats_signature_jit_path_matches_numpy():
    x = jax.random.normal(jax.random.key(4), (64, 128))  # numel >= 4096
    jit_sig = stats_signature(x)
    np_sig = stats_signature(np.asarray(x), use_jit=False)
    for a, b in ((jit_sig.l1, np_sig.l1), (jit_sig.l2, np_sig.l2),
                 (jit_sig.mean, np_sig.mean), (jit_sig.amax, np_sig.amax),
                 (jit_sig.amin, np_sig.amin)):
        assert a == pytest.approx(b, rel=1e-5)


def test_diff_gate_handles_scalar_and_empty_outputs():
    """The functional-equivalence gate must not raise on zero-size or scalar
    output leaves (np.max on an empty array raises)."""
    def fa(x):
        return x.sum(), jnp.zeros((0,)), x * 2.0

    def fb(x):
        return x.sum(), jnp.zeros((0,)), (x + x)

    x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)),
                    jnp.float32)
    rep = DifferentialEnergyDebugger().compare(fa, fb, (x,))
    assert rep.findings is not None


def test_diff_gate_rejects_different_tasks():
    def fa(x):
        return x * 2.0

    def fb(x):
        return x * 3.0

    x = jnp.ones((4, 4))
    with pytest.raises(ValueError, match="not the same task"):
        DifferentialEnergyDebugger().compare(fa, fb, (x,))


def test_energy_profile_indexed_queries():
    from repro.core.energy import (AnalyticalEnergyModel, subgraph_energy,
                                   subgraph_time)
    g = trace(lambda a, b: jnp.tanh(a @ b) + 1.0,
              jnp.ones((32, 32)), jnp.ones((32, 32)))
    p = AnalyticalEnergyModel().profile(g)
    idxs = [0, 1, 1, 2]   # duplicates must count once (set semantics)
    want_e = sum(o.energy_j for o in p.ops if o.node_idx in set(idxs))
    want_t = sum(o.time_s for o in p.ops if o.node_idx in set(idxs))
    assert subgraph_energy(p, idxs) == pytest.approx(want_e)
    assert subgraph_time(p, idxs) == pytest.approx(want_t)
    assert subgraph_energy(p, []) == 0.0
    assert p.total_energy_j == pytest.approx(sum(o.energy_j for o in p.ops))


# ---------------------------------------------------------------------------
# hierarchical block-stamped matching (block_match.BlockStamper)
# ---------------------------------------------------------------------------

def _rotation_stack(layers, mutate_at=None, mutate_fn=None):
    """Deep repeated-block stack with non-degenerate per-layer activations.

    Layer ``mutate_at`` (if given) is replaced by ``mutate_fn`` — used to
    plant rewrites mid-stack.  The rotation weight keeps every layer's
    tensor distinct (no bitwise duplicates), so matching is non-trivial.
    """
    def layer(x, w):
        return (jnp.tanh(x @ w) + 0.5 * x) * 1.01

    def fn(x, w):
        for i in range(layers):
            if i == mutate_at:
                x = mutate_fn(x, w)
            else:
                x = layer(x, w)
        return x.sum()
    return fn


def _rotation_inputs(rng, width=8, rows=4, scale=0.99):
    w = np.zeros((width, width), np.float32)
    for i in range(0, width, 2):
        th = float(rng.uniform(0.3, 1.5)) + i * 0.1
        c, s = np.cos(th), np.sin(th)
        w[i, i], w[i, i + 1], w[i + 1, i], w[i + 1, i + 1] = c, s, -s, c
    x = rng.standard_normal((rows, width)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(scale * w)


def _stamped_match(ga, gb, samples):
    from repro.core.block_match import BlockStamper

    stats_a = [capture_tensor_stats(ga, *s)[1] for s in samples]
    stats_b = [capture_tensor_stats(gb, *s)[1] for s in samples]
    m = TensorMatcher()
    stamper = BlockStamper(ga, gb, samples, samples)
    pairs = m.match_streamed(
        stats_a, stats_b,
        lambda k, tids: capture_tensor_values(ga, *samples[k], only_tids=tids),
        lambda k, tids: capture_tensor_values(gb, *samples[k], only_tids=tids),
        stamper=stamper)
    return m, stamper, pairs


@pytest.mark.parametrize("cid", PARITY_CASES)
def test_stamped_matcher_byte_identical_on_oracle_cases(cid):
    """With a BlockStamper attached, the streamed matcher must return the
    byte-identical pair list of the stamper-less run AND the exhaustive
    oracle's pair set on every seed oracle case — stamping is a shortcut,
    never a semantic change."""
    case = cases.by_id(cid)
    ga, gb, samples, vals_a, vals_b = _captures(case)
    m_plain = TensorMatcher()
    plain = m_plain.match_streamed(
        [capture_tensor_stats(ga, *s)[1] for s in samples],
        [capture_tensor_stats(gb, *s)[1] for s in samples],
        lambda k, tids: capture_tensor_values(ga, *samples[k], only_tids=tids),
        lambda k, tids: capture_tensor_values(gb, *samples[k], only_tids=tids))
    m, stamper, stamped = _stamped_match(ga, gb, samples)
    assert stamped == plain                       # byte-identical result
    oracle = TensorMatcher().match_exhaustive(vals_a, vals_b)
    assert set(stamped) == set(oracle)


@pytest.mark.parametrize("trial", range(4))
def test_stamped_matching_equals_exhaustive_on_random_block_stacks(trial):
    """Property: on randomized repeated-block graphs the stamped pipeline
    returns the exact pair set of match_exhaustive, while actually stamping
    (not silently falling back to the full pipeline)."""
    rng = np.random.default_rng(100 + trial)
    layers = int(rng.integers(5, 11))
    fn = _rotation_stack(layers)
    x, w = _rotation_inputs(rng)
    ga = trace(fn, x, w, name="a")
    gb = trace(fn, x, w, name="b")
    samples = [(x, w), (x * 1.1, w)]
    m, stamper, pairs = _stamped_match(ga, gb, samples)
    vals_a = [capture_tensor_values(ga, *s) for s in samples]
    vals_b = [capture_tensor_values(gb, *s) for s in samples]
    oracle = TensorMatcher().match_exhaustive(vals_a, vals_b)
    assert set(pairs) == set(oracle)
    assert m.last_stats.stamped_pairs > 0
    # identical programs: every diagonal pair is provable, zero demotions
    assert stamper.demoted == 0


@pytest.mark.parametrize("trial", range(3))
def test_bitwise_preserving_rewrite_reseeds_via_resolve_pending(trial):
    """A mid-stack rewrite that preserves bytes (float add is commutative
    bitwise) breaks digest induction at its boundary; resolve_pending must
    digest-verify the boundary pair and re-seed it so stamping resumes for
    the whole suffix instead of degrading to the full pipeline."""
    rng = np.random.default_rng(200 + trial)
    layers = int(rng.integers(6, 10))
    mut = int(rng.integers(2, layers - 2))

    def reassociated(x, w):   # operands swapped: same bytes, new digests
        return (0.5 * x + jnp.tanh(x @ w)) * 1.01

    fa = _rotation_stack(layers)
    fb = _rotation_stack(layers, mutate_at=mut, mutate_fn=reassociated)
    x, w = _rotation_inputs(rng)
    ga = trace(fa, x, w, name="a")
    gb = trace(fb, x, w, name="b")
    samples = [(x, w), (x * 1.1, w)]
    m, stamper, pairs = _stamped_match(ga, gb, samples)
    vals_a = [capture_tensor_values(ga, *s) for s in samples]
    vals_b = [capture_tensor_values(gb, *s) for s in samples]
    oracle = TensorMatcher().match_exhaustive(vals_a, vals_b)
    assert set(pairs) == set(oracle)
    assert stamper.reseeded >= 1                # boundary re-proven by value
    # (stamper.demoted counts every refuted candidate, including cross-layer
    # junk pairs from consumer enumeration — it is not asserted zero here)
    # stamping crossed the rewrite: suffix layers are twins again
    assert m.last_stats.stamped_pairs > 5 * (layers - mut)


def test_mutated_layer_demotes_only_its_own_pairs():
    """The digest-demotion invariant: a value-changing mid-stack mutation
    demotes only its own boundary pairs — every layer above the mutation
    still stamps, the demoted boundary is refuted by value digests, and the
    overall result stays exhaustive-equivalent (the suffix falls through to
    the full two-phase pipeline, which still accepts within rtol)."""
    layers, mut = 9, 4

    def perturbed(x, w):      # ~1e-7 relative change: NOT bitwise-preserving
        return (jnp.tanh(x @ w) + np.float32(0.5000001) * x) * 1.01

    fa = _rotation_stack(layers)
    fb = _rotation_stack(layers, mutate_at=mut, mutate_fn=perturbed)
    rng = np.random.default_rng(42)
    x, w = _rotation_inputs(rng)
    ga = trace(fa, x, w, name="a")
    gb = trace(fb, x, w, name="b")
    samples = [(x, w), (x * 1.1, w)]
    m, stamper, pairs = _stamped_match(ga, gb, samples)
    vals_a = [capture_tensor_values(ga, *s) for s in samples]
    vals_b = [capture_tensor_values(gb, *s) for s in samples]
    oracle = TensorMatcher().match_exhaustive(vals_a, vals_b)
    assert set(pairs) == set(oracle)
    # layers BEFORE the mutation stamp normally (5 nodes per layer)
    assert m.last_stats.stamped_pairs >= 5 * mut - 2
    # the boundary was examined and refuted by value digests, not guessed
    assert stamper.demoted >= 1
    # demotion is local: the non-bitwise suffix pairs are decided by the
    # full pipeline, and the diagonal is still fully matched
    diag = {p for p in oracle if p[0] == p[1]}
    assert diag <= set(pairs)
