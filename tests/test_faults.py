"""Chaos suite: seeded fault injection against the artifact-store stack.

The invariant under test (docs/robustness.md): every operation run under a
fault schedule ends in exactly one of

  * byte-identical success — retry/quarantine/re-fetch absorbed the fault,
  * a declared degraded result — ``meta['degraded']`` + the ``[degraded]``
    pricing mark say exactly what was downgraded,
  * a clean typed failure — the ``StoreError`` family, ``BaselineError``,
    or a ``Drift`` record,

and never a silent wrong answer, never orphan store state.  Fault schedules
are seeded (:class:`~repro.core.faults.FaultPlan`), so each scenario diffs a
faulted run against a fault-free run of the same workload.
"""

import errno
import hashlib
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core.artifact import ArtifactStore
from repro.core.energy import AnalyticalBackend
from repro.core.faults import (FAULT_KINDS, FaultPlan, FaultSpec, FaultyStore,
                               SimulatedCrash)
from repro.core.session import DEGRADED_MARK, Session
from repro.core.store import (ChunkCorruptionError, LocalStore, RemoteStore,
                              RetryPolicy, StoreError, StoreReadOnlyError,
                              StoreTimeoutError, TransientStoreError,
                              chunk_digest, is_transient_error)
from repro.testing.baselines import BaselineStore
from repro.zoo import cases


def _policy(**kw):
    """A RetryPolicy that never actually sleeps (tests stay fast)."""
    kw.setdefault("sleep", lambda s: None)
    return RetryPolicy(**kw)


def _fingerprint(root: Path) -> dict[str, str]:
    """relative path -> sha256 for every file under root (quarantine and
    tmp files excluded): the byte-identical-store comparator."""
    out = {}
    for p in sorted(root.rglob("*")):
        rel = p.relative_to(root)
        if not p.is_file() or rel.parts[0] == "quarantine" \
                or p.suffix == ".tmp":
            continue
        out[str(rel)] = hashlib.sha256(p.read_bytes()).hexdigest()
    return out


@pytest.fixture(scope="module")
def captured():
    """One live capture of the fast-lane twin pair, shared by the suite."""
    case = cases.get_case("c6-matpow")
    session = Session(store=None)
    art = session.capture(case.inefficient, case.make_args(), name="x")
    return case, art


# ---------------------------------------------------------------------------
# RetryPolicy
# ---------------------------------------------------------------------------

def test_retry_recovers_and_counts():
    sleeps = []
    policy = RetryPolicy(sleep=sleeps.append, seed=7)
    state = {"n": 0}

    def flaky():
        state["n"] += 1
        if state["n"] < 3:
            raise TransientStoreError("blip")
        return "ok"

    counters = {"retries": 0}
    assert policy.call(flaky, what="x", counters=counters) == "ok"
    assert state["n"] == 3
    assert policy.retries_spent == 2
    assert counters["retries"] == 2
    assert len(sleeps) == 2
    # exponential backoff with jitter, bounded by max_delay * (1 + jitter)
    assert all(0 < s <= policy.max_delay_s * (1 + policy.jitter)
               for s in sleeps)


def test_retry_gives_up_with_typed_error():
    policy = _policy(max_attempts=3)
    calls = {"n": 0}

    def dead():
        calls["n"] += 1
        raise OSError(errno.EIO, "disk hiccup")

    with pytest.raises(TransientStoreError, match="after 3 attempt"):
        policy.call(dead, what="read")
    assert calls["n"] == 3


def test_retry_never_masks_permanent_errors():
    policy = _policy()
    calls = {"n": 0}

    def denied():
        calls["n"] += 1
        raise StoreReadOnlyError("no")

    with pytest.raises(StoreReadOnlyError):
        policy.call(denied)
    assert calls["n"] == 1                     # zero retries on permanent


def test_retry_budget_bounds_lifetime_retries():
    policy = _policy(max_attempts=4, budget=1)

    def count_attempts():
        calls = {"n": 0}

        def dead():
            calls["n"] += 1
            raise TransientStoreError("down")

        with pytest.raises(TransientStoreError):
            policy.call(dead)
        return calls["n"]

    assert count_attempts() == 2               # 1 try + the whole budget
    assert count_attempts() == 1               # budget spent: fail fast


def test_transient_classification():
    from urllib.error import HTTPError
    assert is_transient_error(TransientStoreError("x"))
    assert is_transient_error(StoreTimeoutError("x"))
    assert is_transient_error(OSError(errno.EIO, "io"))
    assert is_transient_error(ConnectionResetError())
    assert is_transient_error(HTTPError("u", 503, "unavailable", {}, None))
    assert not is_transient_error(HTTPError("u", 403, "forbidden", {}, None))
    assert not is_transient_error(FileNotFoundError(2, "gone"))
    assert not is_transient_error(ChunkCorruptionError("ab" * 32, "bad"))
    assert not is_transient_error(StoreReadOnlyError("ro"))
    assert not is_transient_error(KeyError("k"))
    assert not is_transient_error(ValueError("v"))


# ---------------------------------------------------------------------------
# FaultPlan / FaultyStore mechanics
# ---------------------------------------------------------------------------

def test_fault_plan_is_deterministic():
    def run(seed):
        plan = FaultPlan([FaultSpec("read_chunk", "io_error",
                                    probability=0.4)], seed=seed)
        return [plan.draw("read_chunk", f"k{i}") is not None
                for i in range(64)]

    assert run(3) == run(3)                    # same seed, same schedule
    fired = sum(run(3))
    assert 0 < fired < 64                      # probability actually applied


def test_fault_spec_after_times_and_op_matching():
    plan = FaultPlan([FaultSpec("write_manifest", "crash", after=2, times=1)])
    assert plan.draw("write_manifest", "a") is None     # matching call 1
    assert plan.draw("read_chunk", "a") is None         # other op: uncounted
    assert plan.draw("write_manifest", "b") is None     # matching call 2
    spec = plan.draw("write_manifest", "c")             # call 3 fires
    assert spec is not None and spec.kind == "crash"
    assert plan.draw("write_manifest", "d") is None     # times=1 exhausted
    assert plan.log == [("write_manifest", "c", "crash")]
    assert plan.injected == 1


def test_unknown_fault_kind_rejected():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("read_chunk", "gremlin")
    assert "io_error" in FAULT_KINDS


def test_faulty_store_with_empty_plan_is_transparent(tmp_path):
    plan = FaultPlan([])
    store = FaultyStore(LocalStore(tmp_path), plan)
    data = b"payload" * 100
    d = chunk_digest(data)
    store.write_chunk(d, data)
    store.write_manifest("k", {"v": 1})
    assert store.read_chunk(d) == data
    assert store.read_manifest("k") == {"v": 1}
    assert store.has_chunk(d) and store.has_manifest("k")
    assert plan.injected == 0
    assert store.counters["chunk_writes"] == 1      # __getattr__ delegation
    assert not store.readonly


def test_stale_manifest_serves_prior_payload(tmp_path):
    plan = FaultPlan([FaultSpec("read_manifest", "stale_manifest", times=1)])
    store = FaultyStore(LocalStore(tmp_path), plan)
    store.write_manifest("k", {"v": 1})
    store.write_manifest("k", {"v": 2})
    assert store.read_manifest("k") == {"v": 1}     # lagging replica
    assert store.read_manifest("k") == {"v": 2}     # caught up


# ---------------------------------------------------------------------------
# chaos schedule 1: transient I/O on a flaky file:// mirror
# ---------------------------------------------------------------------------

def _seed_mirror(root: Path, n: int = 3) -> dict[str, bytes]:
    mirror = RemoteStore(f"file://{root}")
    rng = np.random.default_rng(0)
    chunks = {}
    for _ in range(n):
        data = rng.integers(0, 256, 512, dtype=np.uint8).tobytes()
        d = chunk_digest(data)
        mirror.write_chunk(d, data)
        chunks[d] = data
    mirror.write_manifest("golden", {"chunks": sorted(chunks)})
    return chunks


def _flaky_specs():
    # every rung deterministic: each read path faulted, all within the
    # retry layer's per-call attempt limit
    return [FaultSpec("read_chunk", "io_error", times=2),
            FaultSpec("read_manifest", "timeout", times=1),
            FaultSpec("has_manifest", "io_error", times=1)]


def test_schedule_transient_io_recovers_byte_identical(tmp_path):
    """Schedule #1: transient I/O faults on every upstream read path are
    absorbed by retry/backoff — results byte-identical to a fault-free run
    of the exact same workload."""
    chunks = _seed_mirror(tmp_path / "mirror")

    def run(faulty: bool, tag: str):
        plan = FaultPlan(_flaky_specs(), seed=11)
        upstream = RemoteStore(f"file://{tmp_path / 'mirror'}")
        if faulty:
            upstream = FaultyStore(upstream, plan)
        local = LocalStore(tmp_path / tag, upstream=upstream,
                           retry=_policy(seed=1))
        man = local.read_manifest("golden")
        data = {d: local.read_chunk(d) for d in man["chunks"]}
        return plan, local, man, data

    plan, local, man, data = run(True, "cache-faulty")
    _, _, man0, data0 = run(False, "cache-clean")
    assert (man, data) == (man0, data0)        # byte-identical under faults
    assert data == chunks
    assert plan.injected == 4                  # the schedule actually fired
    assert local.counters["retries"] >= plan.injected
    # the caches themselves converged byte-for-byte too
    assert _fingerprint(tmp_path / "cache-faulty") == \
        _fingerprint(tmp_path / "cache-clean")

    # determinism: replaying the same plan over the same workload injects
    # the identical fault sequence
    plan2, _, _, _ = run(True, "cache-faulty-2")
    assert plan2.log == plan.log


def test_hard_error_is_not_retried(tmp_path):
    _seed_mirror(tmp_path / "mirror")
    plan = FaultPlan([FaultSpec("read_manifest", "hard_error")])
    local = LocalStore(tmp_path / "local",
                       upstream=FaultyStore(
                           RemoteStore(f"file://{tmp_path / 'mirror'}"), plan),
                       retry=_policy())
    with pytest.raises(StoreError, match="injected hard_error"):
        local.read_manifest("golden")
    assert plan.injected == 1                  # one raise, zero retries
    assert local.counters["retries"] == 0


# ---------------------------------------------------------------------------
# chaos schedule 2: corruption -> quarantine -> verified re-fetch
# ---------------------------------------------------------------------------

def test_schedule_corruption_quarantined_and_healed(tmp_path):
    """Schedule #2: at-rest corruption in the local cache.  The read
    quarantines the bad copy, re-fetches a verified replacement from the
    upstream, heals the cache, and returns byte-identical data."""
    chunks = _seed_mirror(tmp_path / "mirror")
    local = LocalStore(tmp_path / "local",
                       upstream=RemoteStore(f"file://{tmp_path / 'mirror'}"),
                       retry=_policy())
    d = sorted(chunks)[0]
    assert local.read_chunk(d) == chunks[d]    # warm the cache
    path = local._fs.chunk_path(d)
    blob = bytearray(path.read_bytes())
    blob[0] ^= 0xFF
    path.write_bytes(bytes(blob))              # flip one byte at rest

    assert local.read_chunk(d) == chunks[d]            # byte-identical
    assert chunk_digest(path.read_bytes()) == d        # cache healed
    assert (local._fs.quarantine_dir() / d).exists()   # forensics kept
    assert local.counters["chunks_quarantined"] == 1
    assert local.counters["verify_failures"] >= 1


def test_corrupt_chunk_without_upstream_is_typed_failure(tmp_path):
    local = LocalStore(tmp_path)
    data = b"x" * 64
    d = chunk_digest(data)
    local.write_chunk(d, data)
    local._fs.chunk_path(d).write_bytes(b"y" * 64)
    with pytest.raises(ChunkCorruptionError) as ei:
        local.read_chunk(d)
    assert ei.value.digest == d
    assert (local._fs.quarantine_dir() / d).exists()
    with pytest.raises(KeyError):              # quarantined: clean miss now,
        local.read_chunk(d)                    # never wrong bytes


def test_bitflip_in_flight_absorbed_by_verified_refetch(tmp_path):
    chunks = _seed_mirror(tmp_path / "mirror")
    d = sorted(chunks)[0]
    plan = FaultPlan([FaultSpec("read_chunk", "bit_flip", times=1)], seed=5)
    local = LocalStore(tmp_path / "local",
                       upstream=FaultyStore(
                           RemoteStore(f"file://{tmp_path / 'mirror'}"), plan),
                       retry=_policy())
    assert local.read_chunk(d) == chunks[d]    # second fetch verified clean
    assert plan.injected == 1
    assert local.counters["verify_failures"] == 1


def test_torn_and_bitflipped_writes_never_served(tmp_path):
    """Data faults on the write path land corrupt bytes under a correct
    content address; read-side digest verification refuses to serve them."""
    plan = FaultPlan([FaultSpec("write_chunk", "torn_write", times=1),
                      FaultSpec("write_chunk", "bit_flip", times=1)], seed=2)
    store = FaultyStore(LocalStore(tmp_path), plan)
    torn, flipped = b"t" * 300, b"f" * 300
    d_torn, d_flip = chunk_digest(torn), chunk_digest(flipped)
    store.write_chunk(d_torn, torn)            # first write drawn torn
    store.write_chunk(d_flip, flipped)         # second drawn bit_flip
    assert plan.injected == 2
    for d in (d_torn, d_flip):
        with pytest.raises(ChunkCorruptionError):
            store.read_chunk(d)


def test_garbled_manifest_quarantined_then_clean_miss(tmp_path):
    local = LocalStore(tmp_path)
    local.write_manifest("k", {"v": 1})
    local._fs.manifest_path("k").write_text("{not json")
    from repro.core.store import StoreCorruptionError
    with pytest.raises(StoreCorruptionError, match="quarantined"):
        local.read_manifest("k")
    assert (local._fs.quarantine_dir() / "k.json").exists()
    with pytest.raises(KeyError):
        local.read_manifest("k")               # clean miss afterwards


# ---------------------------------------------------------------------------
# chaos schedule 3: mid-save crash points
# ---------------------------------------------------------------------------

def test_schedule_crash_mid_save_converges(tmp_path, captured):
    """Schedule #3: process death between the chunk writes and the manifest
    publish.  The interrupted store answers a clean miss; the re-run
    converges to a store byte-identical to one never interrupted."""
    case, art = captured
    plan = FaultPlan([FaultSpec("write_manifest", "crash", times=1)])
    store = ArtifactStore(backend=FaultyStore(
        LocalStore(tmp_path / "faulty"), plan))
    with pytest.raises(SimulatedCrash):
        store.save(art)
    assert plan.injected == 1
    assert not store.has(art.key)              # clean miss, never torn
    with pytest.raises(KeyError):
        store.load(art.key)

    store.save(art)                            # crash point exhausted
    assert store.has(art.key)

    clean = ArtifactStore(tmp_path / "clean")
    clean.save(art)
    assert _fingerprint(tmp_path / "faulty") == _fingerprint(tmp_path / "clean")


def test_interrupted_push_converges_without_orphans(tmp_path):
    """Satellite: `artifacts push` killed mid-transfer (crash-point hook on
    the 2nd chunk write).  The re-run converges to a mirror byte-identical
    to an uninterrupted push — no duplicate chunks, no orphans."""
    case = cases.get_case("c6-matpow")
    src = ArtifactStore(tmp_path / "src", persist_raw_values=True)
    session = Session(store=src)
    a = session.capture(case.inefficient, case.make_args(), name="ineff")
    b = session.capture(case.efficient, case.make_args(), name="eff")
    session.compare(a, b, output_rtol=case.output_rtol)

    ref = tmp_path / "ref-mirror"
    src.push(f"file://{ref}")                  # uninterrupted reference

    plan = FaultPlan([FaultSpec("write_chunk", "crash", after=1, times=1)])
    mirror_root = tmp_path / "mirror"
    dst = FaultyStore(RemoteStore(f"file://{mirror_root}"), plan)
    with pytest.raises(SimulatedCrash):
        src.push(dst)
    assert plan.injected == 1

    res = src.push(dst)                        # re-run converges
    assert res["manifests"] == 2
    assert res["chunks_skipped"] >= 1          # survivor chunk not re-sent
    keys = RemoteStore(f"file://{mirror_root}").chunk_keys()
    assert len(keys) == len(set(keys))         # no duplicates
    assert _fingerprint(mirror_root) == _fingerprint(ref)


def test_interrupted_migrate_converges(tmp_path, captured):
    """Satellite: `artifacts migrate` killed before the manifest publish.
    The legacy npz survives (nothing lost), and the re-run converges
    byte-identically to an uninterrupted migration."""
    case, art = captured

    def seed_legacy(root: Path):
        root.mkdir(parents=True, exist_ok=True)
        art.save(root / f"{art.key}.npz")

    seed_legacy(tmp_path / "clean")
    ArtifactStore(tmp_path / "clean").migrate()

    seed_legacy(tmp_path / "faulty")
    plan = FaultPlan([FaultSpec("write_manifest", "crash", times=1)])
    store = ArtifactStore(backend=FaultyStore(
        LocalStore(tmp_path / "faulty"), plan))
    with pytest.raises(SimulatedCrash):
        store.migrate()
    assert store.legacy_keys() == [art.key]    # npz intact: nothing lost
    assert not store.backend.has_manifest(art.key)

    res = store.migrate()
    assert res["migrated"] == 1
    assert store.legacy_keys() == []
    assert _fingerprint(tmp_path / "faulty") == _fingerprint(tmp_path / "clean")


# ---------------------------------------------------------------------------
# graceful-degradation ladder
# ---------------------------------------------------------------------------

class _BoomBackend:
    id = "boom-v1"
    label = "boom"

    def profile(self, graph, args):
        raise RuntimeError("profiler exploded")


def test_backend_failure_falls_back_and_declares(tmp_path):
    case = cases.get_case("c6-matpow")
    session = Session(backend=_BoomBackend())
    art_a = session.capture(case.inefficient, case.make_args(), name="ineff")
    art_b = session.capture(case.efficient, case.make_args(), name="eff")
    assert art_a.backend_id == AnalyticalBackend().id    # bottom rung
    assert any("fallback" in n for n in art_a.meta["degraded"])

    rep = session.compare(art_a, art_b, output_rtol=case.output_rtol)
    assert rep.is_degraded
    assert DEGRADED_MARK in rep.meta["energy_model"]
    assert any(n.startswith("A:") for n in rep.meta["degraded"])
    assert "!!! DEGRADED" in rep.render()
    for f in rep.waste_findings:               # provenance reaches diagnoses
        if f.diagnosis is not None:
            assert f.diagnosis.degraded
            assert DEGRADED_MARK in f.diagnosis.priced_by


def test_backend_failure_strict_mode_raises():
    case = cases.get_case("c6-matpow")
    session = Session(backend=_BoomBackend(), allow_degraded=False)
    with pytest.raises(RuntimeError, match="profiler exploded"):
        session.capture(case.inefficient, case.make_args(), name="x")


def test_unreachable_values_degrade_to_sketch_only(tmp_path, monkeypatch):
    """Raw phase-2 values unreachable mid-compare: the session retries the
    match sketch-only and declares the downgrade instead of failing (or
    worse, guessing)."""
    from repro.core import tensor_match
    case = cases.get_case("c6-matpow")
    session = Session(store=str(tmp_path))
    a = session.capture(case.inefficient, case.make_args(), name="ineff")
    b = session.capture(case.efficient, case.make_args(), name="eff")

    orig = tensor_match.TensorMatcher.match_streamed
    state = {"calls": 0}

    def flaky(self, *args, **kw):
        state["calls"] += 1
        if state["calls"] == 1 and not kw.get("dry_only"):
            raise TransientStoreError("chunk store unreachable")
        return orig(self, *args, **kw)

    monkeypatch.setattr(tensor_match.TensorMatcher, "match_streamed", flaky)
    rep = session.compare(a, b, output_rtol=case.output_rtol)
    assert state["calls"] == 2                 # full, then sketch-only retry
    assert rep.is_degraded
    assert any("sketch-only" in n for n in rep.meta["degraded"])
    assert DEGRADED_MARK in rep.meta["energy_model"]

    # strict mode: same fault propagates typed instead
    state["calls"] = 0
    with pytest.raises(TransientStoreError, match="unreachable"):
        session.compare(a, b, output_rtol=case.output_rtol,
                        allow_degraded=False)


def test_cache_probe_failure_degrades_to_live_capture(tmp_path):
    case = cases.get_case("c6-matpow")
    plan = FaultPlan([FaultSpec("has_manifest", "hard_error", times=1)])
    session = Session(store=ArtifactStore(backend=FaultyStore(
        LocalStore(tmp_path), plan)))
    art = session.capture(case.inefficient, case.make_args(), name="x")
    assert any("cache probe failed" in w
               for w in art.meta["store_warnings"])
    assert "degraded" not in art.meta          # full fidelity: just no cache
    assert session.store.has(art.key)          # and it was persisted after


def test_unpersistable_capture_is_declared(tmp_path):
    case = cases.get_case("c6-matpow")
    plan = FaultPlan([FaultSpec("write_manifest", "hard_error", times=1)])
    session = Session(store=ArtifactStore(backend=FaultyStore(
        LocalStore(tmp_path), plan)))
    art = session.capture(case.inefficient, case.make_args(), name="x")
    assert any("not persisted" in n for n in art.meta["degraded"])


# ---------------------------------------------------------------------------
# golden baselines stay strict
# ---------------------------------------------------------------------------

def test_baseline_store_forces_strict_session(tmp_path):
    bs = BaselineStore(tmp_path)
    assert bs.session.allow_degraded is False


def test_baseline_check_reports_store_failure_as_drift(tmp_path):
    case = cases.get_case("c6-matpow")
    bs = BaselineStore(tmp_path)
    bs.record(case)
    assert bs.check(case, offline=True) == []  # healthy store: no drift

    plan = FaultPlan([FaultSpec("read_manifest", "hard_error")])
    bs.artifacts.backend = FaultyStore(bs.artifacts.backend, plan)
    drifts = bs.check(case, offline=True)
    assert [d.field for d in drifts] == ["store"]
    assert "hard_error" in str(drifts[0].actual)


def test_baseline_live_check_store_failure_is_drift_not_degraded(tmp_path):
    case = cases.get_case("c6-matpow")
    bs = BaselineStore(tmp_path)
    bs.record(case)
    plan = FaultPlan([FaultSpec("has_manifest", "hard_error")])
    bs.artifacts.backend = FaultyStore(bs.artifacts.backend, plan)
    drifts = bs.check(case, offline=False)
    assert [d.field for d in drifts] == ["store"]


# ---------------------------------------------------------------------------
# pytest-plugin energy gate: skip vs --energy-strict
# ---------------------------------------------------------------------------

def _double(x):
    return x * 2.0


def test_energy_gate_skips_when_baseline_unreadable(tmp_path):
    from repro.testing.pytest_plugin import assert_no_energy_regression
    baseline = tmp_path / "g.npz"
    baseline.mkdir(parents=True)               # a directory where the npz
    args = (np.ones((4,), np.float32),)        # should be -> IsADirectoryError
    with pytest.raises(pytest.skip.Exception,
                       match="store unavailable.*--energy-strict"):
        assert_no_energy_regression(_double, args, baseline, strict=False)
    with pytest.raises(pytest.fail.Exception, match="store unavailable"):
        assert_no_energy_regression(_double, args, baseline, strict=True)


def test_energy_gate_store_failure_during_capture(tmp_path):
    from repro.testing.pytest_plugin import assert_no_energy_regression
    baseline = tmp_path / "k.npz"
    args = (np.ones((8,), np.float32),)
    assert assert_no_energy_regression(_double, args, baseline,
                                       record=True) is None
    plan = FaultPlan([FaultSpec("has_manifest", "hard_error")])
    sess = Session(store=ArtifactStore(backend=FaultyStore(
        LocalStore(tmp_path / "store"), plan)), allow_degraded=False)
    with pytest.raises(pytest.skip.Exception, match="capturing candidate"):
        assert_no_energy_regression(_double, args, baseline, session=sess,
                                    strict=False)
    with pytest.raises(pytest.fail.Exception, match="capturing candidate"):
        assert_no_energy_regression(_double, args, baseline, session=sess,
                                    strict=True)


def test_energy_gate_healthy_path_still_gates(tmp_path):
    """Strict flag changes only the unreachable-store behavior: a healthy
    store still records and passes."""
    from repro.testing.pytest_plugin import assert_no_energy_regression
    baseline = tmp_path / "k.npz"
    args = (np.ones((8,), np.float32),)
    assert_no_energy_regression(_double, args, baseline, record=True)
    report = assert_no_energy_regression(_double, args, baseline,
                                         strict=True)
    assert report is None                      # bit-identical capture
