"""Backend parity: AnalyticalBackend vs per-op HloCostBackend waste signs.

Regions come from matching and are backend-independent, so parity is tested
on the pricing alone: for every zoo case the analytic pipeline detects, the
per-op HLO backend must price the SAME matched regions (and the module
totals) with the same waste sign.  Divergences are not silently tolerated
and not silently trusted either — they are pinned in one of two ledgers
with the responsible XLA transformation, and the test fails if an entry
appears, disappears, or changes category, forcing the ledgers to stay
current.

PR 4 replaced the module-total redistribution with true per-instruction
attribution (eqn ids threaded through the lowering as name scopes,
core/hlo_costs.py), which split the old 5-entry disagreement ledger by
actual cause:

* ``KNOWN_SIGN_DISAGREEMENTS`` (2 entries) — the compiled module prices the
  analytically-wasteful side strictly CHEAPER: a true sign flip;
* ``KNOWN_COMPILER_ERASED`` (4 entries) — XLA compiles both twins to
  (near-)identical traffic, so the operator-level waste vanishes as a tie.
  The old redistribution could never produce a tie (it preserved analytic
  ratios), which is exactly why two of its five documented "disagreements"
  were attribution artifacts: c9-join-psum (scan-body collectives now get
  per-iteration attribution via XLA's known_trip_count) and n1-gelu-backend
  (the Pallas interpret-mode emulation no longer pollutes the pricing —
  pallas_call nodes are opaque and take their analytic single-pass rule).

Measured on this container (jax CPU, TPU-v5e spec): 13/19 detect cases
agree; 2 flip; 4 erase.
"""

import pytest

from repro.core.energy import HloCostBackend, subgraph_energy
from repro.zoo import cases as zoo

# case id -> the XLA transformation that makes the compiled module price the
# analytically-wasteful side strictly CHEAPER (true sign flip).
KNOWN_SIGN_DISAGREEMENTS = {
    "c15-expm": "XLA CSE merges the recomputed Taylor powers, so the "
                "redundant twin compiles to FEWER flops than the "
                "shared-power one",
    "c7-concat-split": "the direct-projection twin re-reads the activations "
                       "once per projection while the concat twin reads the "
                       "concatenated weights once; compiled byte totals "
                       "flip the analytic sign by ~5%",
}

# case id -> the XLA transformation that compiles both twins to
# (near-)identical traffic, erasing the operator-level waste as a tie.
KNOWN_COMPILER_ERASED = {
    "c2-cache-copy": "copy elision + loop fusion: the concat cache-copy and "
                     "the dynamic-update-slice lower to fusions with "
                     "identical operand/result traffic",
    "c5-layout": "algebraic simplification deletes the inverse transpose "
                 "pair entirely — both twins compile to the identical "
                 "bitcast + dot module",
    "c10-addmm": "both twins compile to the same f32-accumulating dot with "
                 "an add/convert epilogue fusion; module byte totals tie to "
                 "within 0.03% (the matched region still agrees)",
    "c16-count-nonzero": "XLA materializes a full-width 4-byte indicator on "
                         "BOTH twins (f32 select vs s32 convert of the "
                         "pred) before the partitioned reduce, so the "
                         "implicit-copy waste ties out",
}

# ties must sit well below the smallest documented flip (c7, ~4.5%)
ERASED_REL_TOL = 1e-2

DETECT_CASES = [c.id for c in zoo.list_cases() if c.expect_detect]
pytestmark = pytest.mark.slow


def _parity(cid, golden):
    """(waste, regions_agree, total_a, total_b) for one zoo case."""
    case = zoo.get_case(cid)
    rec = golden["records"][cid]
    waste = [f for f in rec["report"].waste_findings
             if f.wasteful_side == "A"]
    assert waste, f"{cid}: analytic pipeline no longer detects the waste"
    hlo = HloCostBackend()
    args = case.make_args()
    prof_a = hlo.profile(rec["graph_a"], args)
    prof_b = hlo.profile(rec["graph_b"], args)
    regions_agree = all(
        subgraph_energy(prof_a, f.nodes_a) > subgraph_energy(prof_b,
                                                             f.nodes_b)
        for f in waste)
    return waste, regions_agree, prof_a.total_energy_j, prof_b.total_energy_j


@pytest.mark.parametrize("cid", DETECT_CASES)
def test_backends_agree_on_waste_sign(cid, golden):
    _, regions_agree, ta, tb = _parity(cid, golden)
    agree = regions_agree and ta > tb
    rel = abs(ta - tb) / max(ta, tb, 1e-30)

    if cid in KNOWN_SIGN_DISAGREEMENTS:
        assert not agree, (
            f"{cid}: backends now AGREE — the documented sign flip "
            f"({KNOWN_SIGN_DISAGREEMENTS[cid]}) is resolved; remove it from "
            "KNOWN_SIGN_DISAGREEMENTS")
        assert tb > ta and rel > ERASED_REL_TOL, (
            f"{cid}: documented as a true sign flip but the compiled totals "
            f"no longer flip (A={ta:.3e} J vs B={tb:.3e} J); move it to "
            "KNOWN_COMPILER_ERASED or remove it")
        pytest.xfail(f"documented sign flip: {KNOWN_SIGN_DISAGREEMENTS[cid]}")
    if cid in KNOWN_COMPILER_ERASED:
        # an epsilon-sized lean toward A is still a tie — only genuine
        # (> tolerance) agreement resolves an erasure entry
        assert not (agree and rel > ERASED_REL_TOL), (
            f"{cid}: backends now genuinely AGREE — the documented erasure "
            f"({KNOWN_COMPILER_ERASED[cid]}) is resolved; remove it from "
            "KNOWN_COMPILER_ERASED")
        assert rel <= ERASED_REL_TOL, (
            f"{cid}: documented as compiler-erased but the compiled totals "
            f"no longer tie (A={ta:.3e} J vs B={tb:.3e} J, rel={rel:.2e}); "
            "re-classify it")
        pytest.xfail(f"compiler-erased waste: {KNOWN_COMPILER_ERASED[cid]}")
    assert agree, (
        f"{cid}: analytic and per-op HLO backends disagree on the waste "
        f"sign (regions_agree={regions_agree}, hlo A={ta:.3e} J vs "
        f"B={tb:.3e} J) — understand and either fix the attribution or "
        "document it in the appropriate ledger")


def test_disagreement_ledgers_name_real_cases():
    assert len(KNOWN_SIGN_DISAGREEMENTS) <= 2, \
        "the sign-disagreement ledger must stay <= 2 entries (ISSUE 4)"
    assert not set(KNOWN_SIGN_DISAGREEMENTS) & set(KNOWN_COMPILER_ERASED)
    for cid in (*KNOWN_SIGN_DISAGREEMENTS, *KNOWN_COMPILER_ERASED):
        assert zoo.get_case(cid).expect_detect, cid


def test_sign_disagreement_ledger_only_shrinks():
    """The two residual sign flips are pinned BY NAME: a new entry means a
    new attribution defect (fix it, don't ledger it), while an entry
    dropping out is progress (the paired test above forces its removal)."""
    assert set(KNOWN_SIGN_DISAGREEMENTS) <= {"c15-expm", "c7-concat-split"}, (
        "the sign-disagreement ledger grew beyond the two documented flips "
        f"({sorted(set(KNOWN_SIGN_DISAGREEMENTS) - {'c15-expm', 'c7-concat-split'})}); "
        "new backend disagreements must be fixed, not added to the ledger")


# ---------------------------------------------------------------------------
# parity matrix on GENERATED cases: attribution quality is gated on the
# mutation engine's scenarios, not just the hand-written zoo twins
# ---------------------------------------------------------------------------

# mutation class -> (representative clean program, expected HLO verdict):
# 'agree'  — the compiled module preserves the injected waste's sign;
# 'erased' — XLA removes the injected waste at compile time (the documented
#            transformation), so compiled totals tie.
MUTATION_PARITY = {
    "dtype_upcast": ("mlp_swiglu", "agree"),         # precision attr survives
    "redundant_recompute": ("mlp_swiglu", "agree"),  # twin dots both lowered
    "sync_in_loop": ("mlp_swiglu", "agree"),         # shard_map region costed
    "oversized_padding": ("mlp_swiglu", "agree"),    # pad+slice materialize
    "op_split": ("mlp_swiglu", "erased"),            # re-fused into one loop
    "scan_body": ("scan_mlp", "agree"),              # known_trip_count attrib
    "layout_thrash": ("mlp_swiglu", "erased"),       # algsimp deletes t∘t
    "storage_upcast": ("act_chain_bf16", "erased"),  # converts fused away
}


@pytest.fixture(scope="module")
def mutation_parity_session():
    from repro.core.session import Session
    return Session(), {}


@pytest.mark.parametrize("mclass", sorted(MUTATION_PARITY))
def test_mutation_parity_matrix(mclass, mutation_parity_session):
    from repro.testing.mutate import MUTATIONS, clean_programs, make_mutant

    session, clean_cache = mutation_parity_session
    prog_name, expected = MUTATION_PARITY[mclass]
    prog = {p.name: p for p in clean_programs()}[prog_name]
    if prog_name not in clean_cache:
        clean_cache[prog_name] = (prog.make_args(), None)
        args = clean_cache[prog_name][0]
        clean_cache[prog_name] = (args, session.capture(prog.fn, args,
                                                        name=prog_name))
    args, clean = clean_cache[prog_name]
    mutant, sites = make_mutant(prog.fn, MUTATIONS[mclass](), args)
    assert sites > 0, f"{mclass} found no site in {prog_name}"

    mut_art = session.capture(mutant, args, name=mutant.__name__)
    rep = session.compare(mut_art, clean)
    waste = [f for f in rep.waste_findings if f.wasteful_side == "A"]
    assert waste, f"{mclass}:{prog_name} not detected analytically"

    hlo = HloCostBackend()
    prof_a = hlo.profile(mut_art.graph, args)
    prof_b = hlo.profile(clean.graph, args)
    regions_agree = all(
        subgraph_energy(prof_a, f.nodes_a) > subgraph_energy(prof_b,
                                                             f.nodes_b)
        for f in waste)
    ta, tb = prof_a.total_energy_j, prof_b.total_energy_j
    rel = abs(ta - tb) / max(ta, tb, 1e-30)
    verdict = ("agree" if (regions_agree and ta > tb)
               else ("erased" if rel <= ERASED_REL_TOL else "flip"))
    assert verdict == expected, (
        f"{mclass}:{prog_name}: expected HLO parity {expected!r}, measured "
        f"{verdict!r} (regions_agree={regions_agree}, A={ta:.3e} J, "
        f"B={tb:.3e} J) — per-op attribution behavior changed; re-verify "
        "and update MUTATION_PARITY")
