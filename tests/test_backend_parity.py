"""Backend parity: AnalyticalBackend vs HloCostBackend waste-sign agreement.

Regions come from matching and are backend-independent, so parity is tested
on the pricing alone: for every zoo case the analytic pipeline detects, the
HLO-calibrated backend must price the SAME matched regions (and the module
totals) with the same waste sign.  Disagreements are not silently tolerated
and not silently trusted either — they are pinned in
KNOWN_SIGN_DISAGREEMENTS with the reason, and the test fails if one
appears, disappears, or flips, forcing the ledger to stay current.

Measured on this container (jax CPU, TPU-v5e spec): 14/19 cases agree; the
5 exceptions are exactly the cases whose waste the XLA optimizer can erase
at compile time, which the analytic operator-level model (deliberately,
matching the paper's pre-fusion execution model) still charges for.
"""

import pytest

from repro.core.energy import HloCostBackend, subgraph_energy
from repro.zoo import cases as zoo

# case id -> why compiled-cost accounting disagrees with the operator model.
KNOWN_SIGN_DISAGREEMENTS = {
    "c2-cache-copy": "XLA lowers the concat cache-copy to the same bytes as "
                     "the dynamic-update-slice (copy elision): module totals "
                     "come out equal, so the HLO-rescaled sign vanishes",
    "c9-join-psum": "whole-module HLO totals are redistributed over the "
                    "analytic breakdown; the scan-body collectives have no "
                    "per-iteration attribution post-compilation and the "
                    "accumulate-then-reduce twin prices higher",
    "c15-expm": "XLA CSEs the recomputed Taylor powers, so the redundant "
                "twin compiles to FEWER flops than the shared-power one",
    "c16-count-nonzero": "the materialized f32 indicator copy is fused away "
                         "by XLA; compiled byte totals for both twins are "
                         "identical",
    "n1-gelu-backend": "the Pallas fused-GELU runs via interpret-mode "
                       "callbacks on CPU whose HLO is far larger than the "
                       "5-op eager form, inverting the compiled totals",
}

DETECT_CASES = [c.id for c in zoo.list_cases() if c.expect_detect]
pytestmark = pytest.mark.slow


@pytest.mark.parametrize("cid", DETECT_CASES)
def test_backends_agree_on_waste_sign(cid, golden):
    case = zoo.get_case(cid)
    rec = golden["records"][cid]
    waste = [f for f in rec["report"].waste_findings
             if f.wasteful_side == "A"]
    assert waste, f"{cid}: analytic pipeline no longer detects the waste"

    hlo = HloCostBackend()
    args = case.make_args()
    prof_a = hlo.profile(rec["graph_a"], args)
    prof_b = hlo.profile(rec["graph_b"], args)
    regions_agree = all(
        subgraph_energy(prof_a, f.nodes_a) > subgraph_energy(prof_b,
                                                             f.nodes_b)
        for f in waste)
    totals_agree = prof_a.total_energy_j > prof_b.total_energy_j
    agree = regions_agree and totals_agree

    if cid in KNOWN_SIGN_DISAGREEMENTS:
        assert not agree, (
            f"{cid}: backends now AGREE — the documented disagreement "
            f"({KNOWN_SIGN_DISAGREEMENTS[cid]}) is resolved; remove it from "
            "KNOWN_SIGN_DISAGREEMENTS")
        pytest.xfail(f"documented sign disagreement: "
                     f"{KNOWN_SIGN_DISAGREEMENTS[cid]}")
    assert agree, (
        f"{cid}: analytic and HLO-calibrated backends disagree on the waste "
        f"sign (regions_agree={regions_agree}, totals_agree={totals_agree}, "
        f"hlo A={prof_a.total_energy_j:.3e} J vs "
        f"B={prof_b.total_energy_j:.3e} J) — understand and either fix the "
        "pricing or document it in KNOWN_SIGN_DISAGREEMENTS")


def test_disagreement_ledger_names_real_cases():
    for cid in KNOWN_SIGN_DISAGREEMENTS:
        assert zoo.get_case(cid).expect_detect, cid
