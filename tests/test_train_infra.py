"""Fault-tolerance infrastructure: checkpoint atomicity & elasticity, data
determinism, straggler detection, loop resume/preemption."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ShapeConfig
from repro.train.checkpoint import CheckpointManager, PreemptionGuard
from repro.train.data import DataConfig, SyntheticLM, make_batch_fn
from repro.train.loop import LoopConfig, run_training
from repro.train.optimizer import (OptimizerConfig, adamw_update,
                                   init_opt_state)
from repro.train.straggler import StragglerMonitor


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _state():
    return {"w": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
            "m": jnp.ones((3, 4), jnp.float32),
            "step": jnp.int32(7)}


def test_checkpoint_roundtrip_bf16():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(5, _state())
        step, restored = mgr.restore()
        assert step == 5
        assert restored["w"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(restored["w"], np.float32),
                                      np.asarray(_state()["w"], np.float32))
        assert int(restored["step"]) == 7


def test_checkpoint_atomicity_no_partial_dirs():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, _state())
        # a crashed write leaves only tmp dirs, which all_steps must ignore
        os.makedirs(os.path.join(d, "step_00000002.tmp-deadbeef"))
        assert mgr.all_steps() == [1]
        step, _ = mgr.restore()
        assert step == 1


def test_checkpoint_gc_keeps_latest():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, _state())
        assert mgr.all_steps() == [3, 4]


def test_checkpoint_async_then_wait():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save_async(9, _state(), metadata={"loss": 1.5})
        mgr.wait()
        assert mgr.latest_step() == 9
        assert mgr.metadata(9)["loss"] == 1.5


def test_checkpoint_elastic_restore_onto_sharding():
    """Restore re-shards onto whatever mesh exists now (device count 1)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, _state())
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        sh = {"w": NamedSharding(mesh, P()), "m": NamedSharding(mesh, P()),
              "step": NamedSharding(mesh, P())}
        _, restored = mgr.restore(shardings=sh)
        assert restored["w"].sharding == sh["w"]


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_across_restarts():
    cfg = DataConfig(vocab_size=128, seq_len=32, global_batch=8, seed=3)
    a = SyntheticLM(cfg).batch(step=17)
    b = SyntheticLM(cfg).batch(step=17)      # fresh pipeline, same step
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_data_host_slices_partition_global_batch():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=8, seed=0)
    data = SyntheticLM(cfg)
    full = data.batch(step=3)
    h0 = data.batch(step=3, host_index=0, host_count=2)
    h1 = data.batch(step=3, host_index=1, host_count=2)
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), full["tokens"])


def test_data_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=64, seq_len=24, global_batch=2, seed=1)
    b = SyntheticLM(cfg).batch(0)
    assert b["tokens"].shape == b["labels"].shape == (2, 24)


# ---------------------------------------------------------------------------
# straggler monitor
# ---------------------------------------------------------------------------

def test_straggler_flags_persistent_outlier():
    mon = StragglerMonitor(patience=2, warmup=3)
    for _ in range(30):
        mon.observe(1.0 + np.random.default_rng(0).normal(0, 0.01))
    flagged = False
    for _ in range(3):
        flagged = mon.observe(3.0, source="host7") or flagged
    assert flagged
    assert "host7" in mon.exclusion_list


def test_straggler_ignores_transient_spike():
    mon = StragglerMonitor(patience=3, warmup=3)
    for _ in range(20):
        mon.observe(1.0)
    assert not mon.observe(5.0, source="host1")   # single spike: not flagged
    for _ in range(5):
        mon.observe(1.0)
    assert "host1" not in mon.exclusion_list


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_decreases_quadratic_loss():
    w = {"w": jnp.ones((8,)) * 5.0}
    ocfg = OptimizerConfig(lr=0.1, warmup_steps=0, weight_decay=0.0)
    state = init_opt_state(w, ocfg)
    for _ in range(50):
        g = {"w": 2 * w["w"]}
        w, state, _ = adamw_update(w, g, state, ocfg)
    assert float(jnp.abs(w["w"]).max()) < 2.0


def test_grad_compression_error_feedback():
    from repro.train.optimizer import compress_decompress
    g = {"w": jnp.full((4,), 1e-3, jnp.float32) * (1 + 2 ** -10)}
    err = {"w": jnp.zeros((4,), jnp.float32)}
    total = jnp.zeros((4,), jnp.float32)
    for _ in range(64):
        cg, err = compress_decompress(g, err)
        total = total + cg["w"].astype(jnp.float32)
    # error feedback keeps the accumulated bias tiny
    want = 64 * g["w"]
    np.testing.assert_allclose(total, want, rtol=5e-3)


# ---------------------------------------------------------------------------
# loop resume / preemption (integration)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_setup():
    cfg = configs.get_config("gpt2-small").reduced(num_layers=2)
    shape = ShapeConfig("tiny", seq_len=16, global_batch=4, kind="train")
    return cfg, shape


def test_loop_resumes_from_checkpoint(tiny_setup):
    cfg, shape = tiny_setup
    with tempfile.TemporaryDirectory() as d:
        r1 = run_training(cfg, shape, opt_cfg=OptimizerConfig(),
                          loop=LoopConfig(num_steps=4, checkpoint_every=2,
                                          checkpoint_dir=d, log_every=0,
                                          async_checkpoint=False))
        assert r1["final_step"] == 4
        r2 = run_training(cfg, shape, opt_cfg=OptimizerConfig(),
                          loop=LoopConfig(num_steps=6, checkpoint_every=2,
                                          checkpoint_dir=d, log_every=0,
                                          async_checkpoint=False))
        assert r2["history"][0]["step"] == 4     # resumed, no replay
        assert r2["final_step"] == 6


def test_loop_preemption_checkpoints_and_exits(tiny_setup):
    cfg, shape = tiny_setup
    with tempfile.TemporaryDirectory() as d:
        guard = PreemptionGuard(signals=())
        guard.trigger()
        r = run_training(cfg, shape, opt_cfg=OptimizerConfig(),
                         loop=LoopConfig(num_steps=50, checkpoint_every=999,
                                         checkpoint_dir=d, log_every=0),
                         guard=guard)
        assert r["exited_early"]
        mgr = CheckpointManager(d)
        assert mgr.latest_step() == r["final_step"]
