"""Block-evidence cache: invalidation, byte-identity, and persistence.

The acceptance-critical properties of the incremental capture engine
(core/block_cache.py):

  * for EVERY mutation class, capturing the mutant against a cache
    populated by the clean program reuses only entries that are provably
    sound — the mutated block's clean entries are never served, and the
    cached capture stays byte-identical to an uncached capture of the
    same mutant,
  * a warm ``Session.capture`` of a single-block rewrite is byte-identical
    to a cold one (same content address, same stats payload, same profile
    payload),
  * evidence survives the store round-trip: a fresh Session on the same
    store gets block hits, ``gc_chunks``/``prune`` never collect chunks an
    evidence entry references,
  * ``Session.rank`` short-circuits pairs whose artifacts share a content
    address and reports the count in ``RankResult.meta``,
  * ``hlo_costs.per_op_costs`` memoizes per (jaxpr, consts, avals).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.graph as graph_mod
import repro.core.hlo_costs as hlo_costs
import repro.core.interp as interp
from repro.core.artifact import _profile_payload, _stats_payload
from repro.core.block_cache import BlockEvidenceCache, is_block_evidence
from repro.core.session import RankResult, Session
from repro.models.blockstack import transformer_block_stack
from repro.testing.mutate import MUTATIONS, make_mutant


# ---------------------------------------------------------------------------
# clean layered programs (>= 128 nodes so the fused/block path engages,
# tied consts so block families form)
# ---------------------------------------------------------------------------

def _dot_tanh_model(layers=40, n=16, seed=0, twist=None):
    """f32 matmul+tanh stack: dot_general and tanh site per layer."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray((rng.standard_normal((n, n)) / np.sqrt(n))
                    .astype(np.float32))
    x0 = jnp.asarray(rng.standard_normal((4, n)).astype(np.float32))

    def fn(x):
        for i in range(layers):
            h = x @ w
            if i == twist:
                h = jnp.transpose(jnp.transpose(h))
            x = (jnp.tanh(h) + 0.5 * x) * 1.01
        return x

    return fn, (x0,)


def _scan_model(layers=48, n=8, seed=1):
    """One scan-with-dot per layer (the scan_body mutation's target)."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray((rng.standard_normal((n, n)) / np.sqrt(n))
                    .astype(np.float32))
    x0 = jnp.asarray(rng.standard_normal((2, n)).astype(np.float32))

    def fn(x):
        for _ in range(layers):
            def body(c, _):
                return jnp.tanh(c @ w) * 0.99 + c * 0.01, None
            y, _ = jax.lax.scan(body, x, None, length=2)
            x = (y + 0.1 * x) * 1.001
        return x

    return fn, (x0,)


def _bf16_model(layers=48, n=16, seed=2):
    """Uniformly-bf16 elementwise stack (the storage_upcast target)."""
    rng = np.random.default_rng(seed)
    c = jnp.asarray(rng.standard_normal(n), dtype=jnp.bfloat16)
    x0 = jnp.asarray(rng.standard_normal((4, n)), dtype=jnp.bfloat16)

    def fn(x):
        for _ in range(layers):
            x = jnp.tanh(x * c) + x
        return x

    return fn, (x0,)


# model builder + number of applicable sites to SKIP so the mutation lands
# mid-graph (inside the block family, not on the boundary layer 0)
_CASES = {
    "dtype_upcast": (_dot_tanh_model, 20),
    "redundant_recompute": (_dot_tanh_model, 20),
    "sync_in_loop": (_dot_tanh_model, 20),
    "oversized_padding": (_dot_tanh_model, 20),
    "op_split": (_dot_tanh_model, 20),
    "layout_thrash": (_dot_tanh_model, 20),
    "scan_body": (_scan_model, 20),
    "storage_upcast": (_bf16_model, 60),   # 3 sites/layer -> layer 20
}


def _nth_site(mutation_cls, skip):
    """A mutation instance that declines its first ``skip`` applicable
    sites and mutates exactly the next one (mid-graph, single block)."""

    class NthSite(mutation_cls):
        def __init__(self):
            super().__init__(max_sites=1)
            self._passed = 0

        def reset(self):
            super().reset()
            self._passed = 0

        def _take(self):
            if self._passed < self._skip_n:
                self._passed += 1
                return False
            return super()._take()

    NthSite._skip_n = skip
    NthSite.__name__ = f"NthSite_{mutation_cls.__name__}"
    return NthSite()


def _out_bytes(outs):
    return tuple((np.asarray(o).dtype.str, np.asarray(o).shape,
                  np.asarray(o).tobytes()) for o in outs)


def _sig_tuple(s):
    spectra = (None if s.spectra is None
               else tuple(np.asarray(a).tobytes() for a in s.spectra))
    return (s.numel, s.dtype, s.l1, s.l2, s.mean, s.amax, s.amin,
            tuple(s.shape or ()), spectra)


def _stats_equal(a, b):
    if set(a) != set(b):
        return False
    return all(_sig_tuple(a[t]) == _sig_tuple(b[t]) for t in a)


# ---------------------------------------------------------------------------
# per-mutation-class invalidation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mname", sorted(MUTATIONS))
def test_mutation_invalidates_only_its_block(mname):
    model, skip = _CASES[mname]
    fn, args = model()
    clean_graph = graph_mod.trace(fn, *args, name="clean")

    cache = BlockEvidenceCache()
    clean_outs, _ = interp.capture_tensor_stats(clean_graph, *args,
                                                block_cache=cache)
    clean_probes = [t for t in cache.trace if t[0] == "block"]
    clean_keys = {t[1] for t in clean_probes}
    n_blocks = len(clean_keys)
    # the model must actually exercise the block path, all cold
    assert n_blocks >= 8
    assert all(t[4] == "miss" for t in clean_probes)

    mutant, sites = make_mutant(fn, _nth_site(MUTATIONS[mname], skip), args)
    assert sites == 1
    mutant_graph = graph_mod.trace(mutant, *args, name="mutant")

    # uncached mutant capture: the byte-identity reference
    ref_outs, ref_stats = interp.capture_tensor_stats(mutant_graph, *args)
    preserving = _out_bytes(ref_outs) == _out_bytes(clean_outs)

    before = cache.snapshot()
    cache.trace.clear()
    warm_outs, warm_stats = interp.capture_tensor_stats(mutant_graph, *args,
                                                        block_cache=cache)
    d = BlockEvidenceCache.delta(before, cache.snapshot())
    hit_keys = {t[1] for t in cache.trace
                if t[0] == "block" and t[4] == "hit"}
    miss_keys = {t[1] for t in cache.trace
                 if t[0] == "block" and t[4] == "miss"}

    # soundness: the cached capture is byte-identical to the uncached one
    # (a wrongly-served clean entry for the mutated block would corrupt
    # either the spliced stats or the downstream outputs)
    assert _out_bytes(warm_outs) == _out_bytes(ref_outs)
    assert _stats_equal(warm_stats, ref_stats)
    assert d.get("block_errors", 0) == 0

    # only clean entries are ever reused, and the mutated block's clean
    # entries are provably not among them (the mutation changed that
    # block's struct digest, so its windows fall out of the reuse set)
    assert hit_keys <= clean_keys
    assert clean_keys - hit_keys, "every clean entry was reused, including " \
        "the mutated block's"
    assert not (miss_keys & clean_keys)

    if preserving:
        # bitwise-output-preserving mutation: every block outside the
        # mutated window still hits (values are unchanged downstream)
        assert d.get("block_hits", 0) >= n_blocks - 6
        assert d.get("block_misses", 0) <= 6
    else:
        # value-changing mutation: blocks upstream of the site hit, the
        # chained input digests honestly miss everything downstream
        assert d.get("block_hits", 0) > 0
        assert d.get("block_misses", 0) > 0
        assert (d.get("block_hits", 0) + d.get("block_misses", 0)
                >= n_blocks - 6)


# ---------------------------------------------------------------------------
# Session-level warm == cold, persistence, prune pinning
# ---------------------------------------------------------------------------

def test_session_warm_capture_byte_identical_to_cold(tmp_path):
    fn, args = _dot_tanh_model()
    variant, _ = _dot_tanh_model(twist=20)

    cold = Session(store=str(tmp_path / "cold"), block_cache=False)
    cold_t = cold.capture(fn, args, name="target")
    cold_v = cold.capture(variant, args, name="variant")
    assert cold.block_cache_counters == {}

    warm = Session(store=str(tmp_path / "warm"))
    warm_t = warm.capture(fn, args, name="target")
    warm_v = warm.capture(variant, args, name="variant")

    # the delta capture reused the target's block evidence...
    assert warm_v.meta["block_cache"]["block_hits"] > 0
    assert warm.block_cache_counters["block_hits"] > 0
    # ...and stayed byte-identical to the cold capture: same content
    # address, same stats payload, same priced profile
    for c, w in ((cold_t, warm_t), (cold_v, warm_v)):
        assert c.key == w.key
        assert _stats_payload(c.sample_stats) == _stats_payload(w.sample_stats)
        assert _profile_payload(c.profile) == _profile_payload(w.profile)
        assert _out_bytes(c.outputs) == _out_bytes(w.outputs)
        assert c.total_energy_j == w.total_energy_j


def test_block_evidence_persists_across_sessions(tmp_path):
    fn, args = _dot_tanh_model()
    s1 = Session(store=str(tmp_path))
    s1.capture(fn, args, name="target")
    assert s1.block_cache_counters["block_misses"] > 0

    # evidence is store-backed: a FRESH session (new in-memory cache) on
    # the same store replays only the twisted block of a variant
    variant, _ = _dot_tanh_model(twist=20)
    s2 = Session(store=str(tmp_path))
    art = s2.capture(variant, args, name="variant")
    assert art.meta["block_cache"]["block_hits"] > 0
    assert s2.block_cache_counters["block_errors"] == 0

    # evidence entries are invisible to the artifact listing but counted
    # by stats()
    assert not any(is_block_evidence(k) for k in s2.store.keys())
    st = s2.store.stats()
    assert st["schema_version"] == 4
    assert st["block_entries"] > 0
    assert st["profile_entries"] >= 1


def test_gc_and_prune_keep_evidence_chunks(tmp_path):
    fn, args = _dot_tanh_model()
    s1 = Session(store=str(tmp_path))
    s1.capture(fn, args, name="target")

    # gc must not collect chunks that only evidence entries reference
    removed = s1.store.gc_chunks()
    assert removed == []

    # prune away every artifact: evidence-referenced chunks are pinned, so
    # a fresh session still gets clean block hits (get_block re-verifies
    # nbytes + digest per materialized chunk, so a collected or corrupted
    # chunk would surface as block_errors / misses, not silent reuse)
    s1.store.prune(max_bytes=0)
    assert s1.store.keys() == []
    s2 = Session(store=str(tmp_path))
    s2.capture(fn, args, name="target", use_cache=False)
    assert s2.block_cache_counters["block_hits"] > 0
    assert s2.block_cache_counters["block_errors"] == 0


# ---------------------------------------------------------------------------
# rank short-circuit + meta round-trip
# ---------------------------------------------------------------------------

def test_rank_short_circuits_identical_artifacts():
    fn, args = _dot_tanh_model(layers=6)
    variant, _ = _dot_tanh_model(layers=6, twist=3)
    s = Session()
    a1 = s.capture(fn, args, name="a")
    a2 = s.capture(fn, args, name="b")      # same content, new label
    c = s.capture(variant, args, name="c")
    assert a1.key == a2.key != c.key

    rank = s.rank([a1, a2, c])
    assert rank.meta["identical_pairs"] == 1
    assert rank.meta["compares"] == 2
    rep = rank.reports[(0, 1)]          # the a/b pair shares one key
    assert rep.meta.get("identical_artifacts") is True
    assert rep.findings == []

    rt = RankResult.from_json(rank.to_json())
    assert rt.meta == rank.meta


# ---------------------------------------------------------------------------
# per-op HLO cost memo
# ---------------------------------------------------------------------------

def test_per_op_costs_memoized():
    fn, args = _dot_tanh_model(layers=4)
    g = graph_mod.trace(fn, *args, name="memo")
    before = dict(hlo_costs.PER_OP_MEMO_COUNTERS)
    c1 = hlo_costs.per_op_costs(g, args)
    c2 = hlo_costs.per_op_costs(g, args)
    assert hlo_costs.PER_OP_MEMO_COUNTERS["hits"] == before["hits"] + 1
    assert c2 is c1
    # a re-traced twin of the same program memo-hits too (the key is
    # jaxpr fingerprint + const digests + avals, not object identity)
    g2 = graph_mod.trace(fn, *args, name="memo-twin")
    c3 = hlo_costs.per_op_costs(g2, args)
    assert c3.as_dict() == c1.as_dict()
    # opting out bypasses the memo but agrees
    c4 = hlo_costs.per_op_costs(g, args, memo=False)
    assert c4.as_dict() == c1.as_dict()


# ---------------------------------------------------------------------------
# heterogeneous stack: two distinct families share one graph
# ---------------------------------------------------------------------------

def test_blockstack_forms_two_families():
    fn, args = transformer_block_stack()
    g = graph_mod.trace(fn, *args, name="blockstack")
    assert len(g.nodes) >= 128
    bs = graph_mod.block_structure(g)
    assert len(bs.families) >= 2
    assert len({f.digest for f in bs.families}) >= 2
    assert bs.coverage() > 0.5

    cache = BlockEvidenceCache()
    outs_cold, stats_cold = interp.capture_tensor_stats(g, *args,
                                                        block_cache=cache)
    fam_hit = {t[2] for t in cache.trace if t[0] == "block"}
    assert len(fam_hit) >= 2            # both families went through the cache

    before = cache.snapshot()
    outs_warm, stats_warm = interp.capture_tensor_stats(g, *args,
                                                        block_cache=cache)
    d = BlockEvidenceCache.delta(before, cache.snapshot())
    assert d.get("block_misses", 0) == 0 and d.get("block_hits", 0) > 0
    assert _out_bytes(outs_warm) == _out_bytes(outs_cold)
    assert _stats_equal(stats_warm, stats_cold)
