"""Cost rules and the energy model: per-op pricing, ordering, replay."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.costs import graph_cost, node_cost
from repro.core.energy import AnalyticalEnergyModel, ReplayProfiler
from repro.core.graph import trace
from repro.hw.specs import TPU_V5E


def _graph(fn, *args):
    return trace(fn, *args)


def test_matmul_flops():
    g = _graph(lambda a, b: a @ b, jnp.ones((64, 128)), jnp.ones((128, 32)))
    c = graph_cost(g)
    assert c.flops == pytest.approx(2 * 64 * 128 * 32, rel=0.01)


def test_precision_highest_prices_fp32():
    def hi(a, b):
        return jax.lax.dot(a, b, precision=jax.lax.Precision.HIGHEST)
    g = _graph(hi, jnp.ones((32, 32), jnp.bfloat16), jnp.ones((32, 32), jnp.bfloat16))
    dot = next(n for n in g.nodes if n.primitive == "dot_general")
    assert node_cost(g, dot).fp32_fraction == 1.0


def test_scan_multiplies_body():
    def f(x):
        def body(c, _):
            return jnp.tanh(c @ c), None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out
    g = _graph(f, jnp.ones((16, 16)))
    c = graph_cost(g)
    single = 2 * 16**3
    assert c.flops >= 7 * single


def test_dynamic_update_slice_cheaper_than_concat():
    cache = jnp.zeros((4, 1024, 64))
    new = jnp.ones((4, 1, 64))

    def by_concat(cache, new):
        return jnp.concatenate([cache[:, :512], new, cache[:, 513:]], axis=1)

    def by_dus(cache, new):
        return jax.lax.dynamic_update_slice_in_dim(cache, new, 512, axis=1)

    c1 = graph_cost(_graph(by_concat, cache, new))
    c2 = graph_cost(_graph(by_dus, cache, new))
    assert c2.hbm_bytes < 0.01 * c1.hbm_bytes


def test_collective_priced_in_ici_bytes():
    from jax.sharding import Mesh, PartitionSpec as P
    try:  # JAX >= 0.6 exposes shard_map at top level
        shard_map = jax.shard_map
    except AttributeError:
        from jax.experimental.shard_map import shard_map
    mesh = Mesh(np.array(jax.devices()[:1]), ("dp",))

    def f(x):
        return shard_map(lambda y: jax.lax.psum(y, "dp"), mesh=mesh,
                         in_specs=P(), out_specs=P())(x)
    g = _graph(f, jnp.ones((128, 128)))
    c = graph_cost(g)
    assert c.ici_bytes >= 2 * 128 * 128 * 4


def test_pallas_call_priced_as_single_pass():
    from repro.kernels import ops as kops
    x = jnp.ones((256, 256))

    def unfused(x):
        c = 0.7978845608
        inner = c * (x + 0.044715 * x * x * x)
        return 0.5 * x * (1.0 + jnp.tanh(inner))

    g_fused = _graph(lambda x: kops.fused_gelu(x), x)
    g_unfused = _graph(unfused, x)
    b_fused = graph_cost(g_fused).hbm_bytes
    b_unfused = graph_cost(g_unfused).hbm_bytes
    assert b_fused < 0.5 * b_unfused


def test_energy_model_total_positive_and_ordered():
    model = AnalyticalEnergyModel(TPU_V5E)
    g_small = _graph(lambda a, b: a @ b, jnp.ones((32, 32)), jnp.ones((32, 32)))
    g_big = _graph(lambda a, b: a @ b, jnp.ones((256, 256)), jnp.ones((256, 256)))
    e_small = model.profile(g_small).total_energy_j
    e_big = model.profile(g_big).total_energy_j
    assert 0 < e_small < e_big


def test_replay_profiler_measures_wall_time():
    prof = ReplayProfiler(max_replay_iters=4)
    g = _graph(lambda a, b: jnp.tanh(a @ b), jnp.ones((128, 128)),
               jnp.ones((128, 128)))
    p = prof.profile(g, jnp.ones((128, 128)), jnp.ones((128, 128)))
    assert p.total_energy_j > 0
    assert all(op.time_s >= 0 for op in p.ops)
    assert {op.primitive for op in p.ops} >= {"dot_general", "tanh"}


def test_profile_top_k_and_breakdown():
    model = AnalyticalEnergyModel(TPU_V5E)
    g = _graph(lambda a, b: jnp.tanh(a @ b) + 1.0, jnp.ones((256, 256)),
               jnp.ones((256, 256)))
    p = model.profile(g)
    top = p.top_k(1)
    assert top[0].primitive == "dot_general"
    agg = p.by_primitive()
    assert set(agg) >= {"dot_general", "tanh", "add"}
