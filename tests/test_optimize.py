"""repro.optimize: the detect→transform→verify loop.

Acceptance properties:
  * the inverse-rewrite registry stays in lockstep with the mutation
    taxonomy and the diagnosis subkinds (one inverse per waste class),
  * round-trip property: ``inverse(mutation(clean))`` restores the clean
    program's semantics AND energy within each rewrite's declared
    ``roundtrip_rtol``, for all 8 classes,
  * the full loop (mutate → detect → diagnose subkind → optimize) verifies
    the diagnosed inverse as the best candidate,
  * dtype_upcast refuses bf16 programs with an actionable reason and has a
    genuine site on the bf16-with-f32-master-weights program (the PR 7 gap),
  * PatchReport round-trips through JSON and re-renders from the CLI.
"""

import json

import jax
import numpy as np
import pytest

from repro.core.diagnose import DIAGNOSIS_SUBKINDS, Diagnosis, infer_subkind
from repro.core.session import Session
from repro.optimize import (CANDIDATE_STATUSES, PatchCandidate, PatchReport,
                            REWRITES, build_candidate, optimize, rewrites_for)
from repro.testing.mutate import (MUTATIONS, InapplicableMutationError,
                                  clean_programs, make_mutant)

# one representative clean program per mutation class (the full cross
# product runs in the ci.sh optimize stage; tier-1 pins one pair per class)
PAIRS = [
    ("dtype_upcast", "mlp_swiglu"),
    ("redundant_recompute", "mlp_swiglu"),
    ("sync_in_loop", "rmsnorm_linear"),
    ("oversized_padding", "rmsnorm_linear"),
    ("op_split", "gelu_dense"),
    ("scan_body", "scan_mlp"),
    ("layout_thrash", "rmsnorm_linear"),
    ("storage_upcast", "act_chain_bf16"),
]


@pytest.fixture(scope="module")
def progs():
    return {p.name: p for p in clean_programs()}


# ---------------------------------------------------------------------------
# registry / engine units
# ---------------------------------------------------------------------------

def test_rewrite_registry_matches_taxonomy():
    assert set(REWRITES) == set(DIAGNOSIS_SUBKINDS) == set(MUTATIONS)
    for name, cls in REWRITES.items():
        rule = cls()
        assert rule.name == name
        assert rule.verify_rtol > 0
        assert rule.roundtrip_rtol > 0


def test_rewrites_for_orders_diagnosed_first():
    order = rewrites_for("op_split")
    assert order[0] == "op_split"
    assert sorted(order) == sorted(REWRITES)
    assert sorted(rewrites_for(None)) == sorted(REWRITES)
    assert rewrites_for("layout_thrash")[0] == "layout_thrash"


def test_build_candidate_returns_none_on_zero_sites(progs):
    prog = progs["mlp_swiglu"]                # no transposes to cancel
    args = prog.make_args()
    closed = jax.make_jaxpr(prog.fn)(*args)
    cand, sites = build_candidate(closed, REWRITES["layout_thrash"](), args,
                                  name="noop")
    assert cand is None and sites == 0


def test_rewrites_are_noops_on_clean_programs(progs):
    """No inverse rewrite may fire on (or corrupt) an already-clean
    program: zero false-positive sites across the clean zoo."""
    for mclass, pname in PAIRS:
        prog = progs[pname]
        args = prog.make_args()
        closed = jax.make_jaxpr(prog.fn)(*args)
        cand, sites = build_candidate(closed, REWRITES[mclass](), args,
                                      name=f"clean_{mclass}")
        assert sites == 0, (mclass, pname)


# ---------------------------------------------------------------------------
# subkind inference
# ---------------------------------------------------------------------------

def test_subkind_inference_api_paths():
    assert infer_subkind(
        "api_difference",
        ["add", "convert_element_type", "convert_element_type"],
        ["add"], []) == "storage_upcast"
    # op_split's inlined clip carries literal casts: mixed extras that
    # merely INCLUDE converts must not be read as a storage bounce
    assert infer_subkind(
        "api_difference",
        ["exp", "mul", "div", "sub", "add", "max", "min",
         "convert_element_type"],
        ["tanh"], []) == "op_split"
    assert infer_subkind(
        "api_difference",
        ["dot_general", "shard_map", "psum2", "pbroadcast"],
        ["dot_general"], []) == "sync_in_loop"
    assert infer_subkind(
        "api_difference",
        ["dot_general", "transpose", "transpose", "transpose", "transpose"],
        ["dot_general"], []) == "layout_thrash"
    assert infer_subkind(
        "api_difference", ["dot_general", "pad", "slice"],
        ["dot_general"], []) == "oversized_padding"
    assert infer_subkind(
        "api_difference", ["dot_general", "dot_general", "add", "mul"],
        ["dot_general"], []) == "redundant_recompute"
    assert infer_subkind("api_difference", ["a"], ["a"], []) is None


def test_subkind_inference_param_paths():
    kv = ["dot_general.precision: A=HIGHEST vs B=None"]
    assert infer_subkind("param_difference", [], [], kv) == "dtype_upcast"
    assert infer_subkind("param_difference", ["scan"], ["scan"],
                         ["scan.jaxpr: A=... vs B=..."]) == "scan_body"
    assert infer_subkind("param_difference", ["scan"], ["scan"],
                         []) == "scan_body"
    assert infer_subkind("param_difference", ["add"], ["add"], []) is None


# ---------------------------------------------------------------------------
# round-trip property: inverse(mutation(clean)) == clean, in value and energy
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("mclass,pname", PAIRS,
                         ids=[f"{m}:{p}" for m, p in PAIRS])
def test_inverse_restores_clean_program(mclass, pname, progs):
    prog = progs[pname]
    args = prog.make_args()
    mutant, msites = make_mutant(prog.fn, MUTATIONS[mclass](), args)
    rule = REWRITES[mclass]()
    closed = jax.make_jaxpr(mutant)(*args)
    cand, sites = build_candidate(closed, rule, args, name=f"fix_{mclass}")
    assert sites >= 1, f"{mclass} inverse found no site in its own mutant"

    want = np.asarray(prog.fn(*args), dtype=np.float32)
    got = np.asarray(cand(*args)[0], dtype=np.float32)
    np.testing.assert_allclose(got, want, rtol=rule.roundtrip_rtol,
                               atol=rule.roundtrip_rtol * 1e-2)

    session = Session()
    e_clean = session.capture(prog.fn, args, name=pname).total_energy_j
    e_cand = session.capture(cand, args,
                             name=f"{pname}__fix_{mclass}").total_energy_j
    gap = abs(e_cand - e_clean) / e_clean
    assert gap <= rule.roundtrip_rtol, (
        f"{mclass}:{pname}: inverse leaves a {gap:.1%} energy residue vs "
        f"the clean program (declared roundtrip_rtol "
        f"{rule.roundtrip_rtol:.1%}) — the rewrite did not fully remove "
        "the planted waste")


# ---------------------------------------------------------------------------
# the full loop: mutate -> detect -> diagnose -> optimize -> verify
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_optimize_full_loop_verifies_diagnosed_inverse(progs):
    prog = progs["rmsnorm_linear"]
    args = prog.make_args()
    mutant, _ = make_mutant(prog.fn, MUTATIONS["layout_thrash"](), args)
    session = Session()
    clean_art = session.capture(prog.fn, args, name=prog.name)
    mut_art = session.capture(mutant, args, name=mutant.__name__)
    rep = session.compare(mut_art, clean_art, output_rtol=1e-2)
    waste = [f for f in rep.waste_findings if f.wasteful_side == "A"]
    assert waste
    diag = next(f.diagnosis for f in waste
                if f.diagnosis and f.diagnosis.subkind)
    assert diag.subkind == "layout_thrash"

    patch = optimize(mutant, args, session=session, name=mutant.__name__,
                     diagnosis=diag)
    assert patch.subkind == "layout_thrash"
    assert all(c.status in CANDIDATE_STATUSES for c in patch.candidates)
    best = patch.best
    assert best is not None and best.inverts == "layout_thrash"
    assert best.win_j > 0 and best.energy_j < patch.target_energy_j
    # the diagnosed inverse is proposed first and lands first after sort
    assert patch.candidates[0].rewrite == "layout_thrash"
    assert "rank_matrix" in patch.meta
    names = patch.meta["rank_matrix"]["names"]
    assert patch.target in names
    assert f"{patch.target}__fix_{best.rewrite}" in names


# ---------------------------------------------------------------------------
# dtype_upcast on bf16 serving programs (the PR 7 gap)
# ---------------------------------------------------------------------------

def test_dtype_upcast_refuses_bf16_with_actionable_reason(progs):
    bf16 = progs["gelu_dense_bf16"]
    with pytest.raises(InapplicableMutationError,
                       match="master-precision") as ei:
        make_mutant(bf16.fn, MUTATIONS["dtype_upcast"](), bf16.make_args())
    assert ei.value.mutation_name == "dtype_upcast"
    assert ei.value.reasons


def test_dtype_upcast_has_site_on_master_precision_bf16(progs):
    """The bf16-storage / f32-master-weights program closes the gap: a bf16
    serving model where dtype_upcast genuinely applies (the dot runs f32)."""
    prog = progs["mlp_bf16_master"]
    args = prog.make_args()
    mutant, sites = make_mutant(prog.fn, MUTATIONS["dtype_upcast"](), args)
    assert sites == 1
    want = np.asarray(prog.fn(*args), dtype=np.float32)
    np.testing.assert_array_equal(
        np.asarray(mutant(*args), dtype=np.float32), want)


@pytest.mark.slow
def test_mlp_bf16_master_detects_and_inverts_end_to_end(progs):
    prog = progs["mlp_bf16_master"]
    args = prog.make_args()
    mutant, _ = make_mutant(prog.fn, MUTATIONS["dtype_upcast"](), args)
    session = Session()
    clean_art = session.capture(prog.fn, args, name=prog.name)
    mut_art = session.capture(mutant, args, name=mutant.__name__)
    rep = session.compare(mut_art, clean_art, output_rtol=1e-2)
    waste = [f for f in rep.waste_findings if f.wasteful_side == "A"]
    assert any(f.diagnosis and f.diagnosis.subkind == "dtype_upcast"
               for f in waste)
    patch = optimize(mutant, args, session=session, name=mutant.__name__,
                     subkind="dtype_upcast",
                     rewrite_names=["dtype_upcast"])
    assert patch.best is not None and patch.best.inverts == "dtype_upcast"


# ---------------------------------------------------------------------------
# PatchReport serialization + rendering
# ---------------------------------------------------------------------------

def _sample_patch() -> PatchReport:
    diag = Diagnosis(kind="api_difference", deviation_point="f.py:g:3",
                     detail="d", key_variables=[], ops_a=["transpose"],
                     ops_b=[], priced_by="tpu_v5e",
                     subkind="layout_thrash")
    return PatchReport(
        target="t", target_key="k123", target_energy_j=2e-4,
        subkind="layout_thrash", diagnosis=diag,
        candidates=[
            PatchCandidate(rewrite="layout_thrash", inverts="layout_thrash",
                           status="verified", sites=2, energy_j=1e-4,
                           win_j=1e-4, win_pct=50.0, key="c1"),
            PatchCandidate(rewrite="op_split", inverts="op_split",
                           status="inapplicable", sites=0,
                           reason="no applicable equation"),
        ],
        meta={"backend": "tpu_v5e", "n_proposed": 2, "n_verified": 1})


def test_patch_report_json_roundtrip():
    patch = _sample_patch()
    data = json.loads(patch.to_json())
    assert data["kind"] == "patch"
    again = PatchReport.from_json(data)
    assert again.target == patch.target
    assert again.subkind == "layout_thrash"
    assert again.diagnosis.subkind == "layout_thrash"
    assert len(again.candidates) == 2
    assert again.best.rewrite == "layout_thrash"
    assert again.best.win_pct == pytest.approx(50.0)
    assert again.candidates[1].status == "inapplicable"
    text = again.render()
    assert "layout_thrash" in text and "verified" in text


def test_patch_report_sort_and_best():
    patch = _sample_patch()
    patch.candidates.reverse()
    patch.sort()
    assert patch.candidates[0].status == "verified"
    assert patch.best is patch.candidates[0]
    no_win = PatchReport(target="t", target_key="k", target_energy_j=1.0,
                         subkind=None, candidates=[
                             PatchCandidate(rewrite="op_split",
                                            inverts="op_split",
                                            status="no_win", sites=1,
                                            energy_j=1.0)])
    assert no_win.best is None


def test_cli_report_renders_patch_json(tmp_path, capsys):
    from repro.cli import main
    path = tmp_path / "patch.json"
    path.write_text(_sample_patch().to_json())
    assert main(["report", str(path)]) == 0
    out = capsys.readouterr().out
    assert "patch report" in out and "layout_thrash" in out


@pytest.mark.slow
def test_cli_optimize_scenario_smoke(tmp_path, capsys):
    from repro.cli import main
    out_json = tmp_path / "patch.json"
    rc = main(["optimize", "layout_thrash:rmsnorm_linear",
               "--rewrite", "layout_thrash",
               "--store", str(tmp_path / "store"),
               "--json", str(out_json), "--expect-win"])
    assert rc == 0
    data = json.loads(out_json.read_text())
    assert data["kind"] == "patch"
    assert data["subkind"] == "layout_thrash"
    assert any(c["status"] == "verified" for c in data["candidates"])
    text = capsys.readouterr().out
    assert "verified" in text
