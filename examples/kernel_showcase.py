"""Kernel showcase: the four Pallas TPU kernels vs their XLA twins, with the
analytic energy model quantifying each fusion's HBM-traffic saving.

  PYTHONPATH=src python examples/kernel_showcase.py
"""

import jax
import jax.numpy as jnp

from repro.core.energy import AnalyticalEnergyModel
from repro.core.graph import trace
from repro.kernels import ops, ref


def energy(fn, *args):
    return AnalyticalEnergyModel().profile(trace(fn, *args)).total_energy_j


def main():
    key = jax.random.key(0)
    k1, k2, k3, k4 = jax.random.split(key, 4)

    print(f"{'kernel':<18}{'XLA twin (J)':>14}{'Pallas (J)':>14}{'saving':>9}")

    # flash attention
    q = jax.random.normal(k1, (1, 8, 512, 64))
    k = jax.random.normal(k2, (1, 8, 512, 64))
    v = jax.random.normal(k3, (1, 8, 512, 64))
    e0 = energy(lambda q, k, v: ref.attention(q, k, v), q, k, v)
    e1 = energy(lambda q, k, v: ops.flash_attention(q, k, v), q, k, v)
    print(f"{'flash_attention':<18}{e0:>14.5f}{e1:>14.5f}{1-e1/e0:>8.0%}")

    # rmsnorm
    x = jax.random.normal(k1, (4096, 1024))
    w = jax.random.normal(k2, (1024,))
    e0 = energy(ref.rmsnorm, x, w)
    e1 = energy(ops.fused_rmsnorm, x, w)
    print(f"{'fused_rmsnorm':<18}{e0:>14.5f}{e1:>14.5f}{1-e1/e0:>8.0%}")

    # swiglu
    g = jax.random.normal(k3, (4096, 1024))
    u = jax.random.normal(k4, (4096, 1024))
    e0 = energy(ref.swiglu, g, u)
    e1 = energy(ops.fused_swiglu, g, u)
    print(f"{'fused_swiglu':<18}{e0:>14.5f}{e1:>14.5f}{1-e1/e0:>8.0%}")

    # selective scan
    B, S, di, n = 1, 256, 128, 16
    a = jax.nn.sigmoid(jax.random.normal(k1, (B, S, di, n))) * 0.9
    b = jax.random.normal(k2, (B, S, di, n)) * 0.1
    c = jax.random.normal(k3, (B, S, n))
    h0 = jnp.zeros((B, di, n))
    e0 = energy(lambda *t: ref.ssm_scan(*t)[0], a, b, c, h0)
    e1 = energy(lambda *t: ops.fused_ssm_scan(*t)[0], a, b, c, h0)
    print(f"{'fused_ssm_scan':<18}{e0:>14.5f}{e1:>14.5f}{1-e1/e0:>8.0%}")

    print("\n(each saving is HBM-traffic energy the fused kernel avoids; "
          "validated vs ref.py oracles in tests/test_kernels.py)")


if __name__ == "__main__":
    main()
