"""N-way ranking: price N candidate implementations with N captures.

Three implementations of the same LayerNorm-style normalization are
captured once each; ``session.rank`` then builds the full pairwise waste
matrix from the artifacts — 3 captures + 3 artifact-level compares instead
of 3 end-to-end differential pipelines (the gap widens quadratically with
more candidates).

  PYTHONPATH=src python examples/rank_candidates.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.session import Session


def ln_nonminor(x, w):
    """Reduces over the non-minor axis through a transpose round-trip
    (the c12 / pytorch-76012 waste pattern)."""
    xt = x.T
    mu = jnp.mean(xt, axis=0, keepdims=True)
    var = jnp.mean((xt - mu) ** 2, axis=0, keepdims=True)
    return ((xt - mu) / jnp.sqrt(var + 1e-5)).T * w


def ln_moments(x, w):
    """Minor-axis reduction via E[x²]−E[x]²: never materializes a centered
    copy just for the variance."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(x * x, axis=-1, keepdims=True) - mu * mu
    return (x - mu) / jnp.sqrt(var + 1e-5) * w


def ln_centered(x, w):
    """Minor-axis reduction over an explicitly centered tensor."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * w


def main():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2048, 1024)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((1024,)), jnp.float32)

    session = Session()
    candidates = [ln_nonminor, ln_moments, ln_centered]
    artifacts = [session.capture(fn, (x, w), name=fn.__name__)
                 for fn in candidates]

    result = session.rank(artifacts, output_rtol=2e-2)
    print(result.render())
    print(f"\n--> best candidate: {result.best}")

    # the same matrix embeds into a regular report for rendering/JSON reuse
    print()
    print(result.summary_report().render())


if __name__ == "__main__":
    main()
