"""Quickstart: differential energy debugging in 30 lines.

Compare two implementations of the same computation; Magneton detects which
one wastes energy and explains why.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core.diff import DifferentialEnergyDebugger

VOCAB = 8192


def cross_entropy_onehot(logits, labels):
    """The inefficient twin: materializes a (B, S, V) one-hot tensor in HBM
    (pytorch-141822 class)."""
    onehot = jax.nn.one_hot(labels, VOCAB, dtype=logits.dtype)
    return -jnp.sum(onehot * jax.nn.log_softmax(logits, -1), axis=-1).mean()


def cross_entropy_gather(logits, labels):
    """The efficient twin: gathers the target logit directly."""
    logp = jax.nn.log_softmax(logits, -1)
    return -jnp.take_along_axis(logp, labels[..., None], axis=-1).mean()


def main():
    key = jax.random.key(0)
    logits = jax.random.normal(key, (8, 128, VOCAB))
    labels = jax.random.randint(jax.random.key(1), (8, 128), 0, VOCAB)

    debugger = DifferentialEnergyDebugger()
    report = debugger.compare(
        cross_entropy_onehot, cross_entropy_gather, (logits, labels),
        name_a="onehot-CE", name_b="gather-CE")
    print(report.render())

    waste = [f for f in report.findings if f.classification == "energy_waste"]
    assert waste, "expected the one-hot CE to be flagged"
    print(f"\n--> {len(waste)} energy-waste region(s) found; "
          f"the one-hot materialization costs "
          f"{report.total_energy_a_j / report.total_energy_b_j:.2f}x "
          "the gather implementation.")


if __name__ == "__main__":
    main()
