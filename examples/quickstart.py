"""Quickstart: capture-once differential energy debugging.

Capture each candidate implementation once into a content-addressed artifact
store, then compare the artifacts — re-running the script (or re-comparing
later, even from another process) hits the store and skips every
instrumented execution.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core.artifact import ArtifactStore
from repro.core.session import Session

VOCAB = 8192


def cross_entropy_onehot(logits, labels):
    """The inefficient twin: materializes a (B, S, V) one-hot tensor in HBM
    (pytorch-141822 class)."""
    onehot = jax.nn.one_hot(labels, VOCAB, dtype=logits.dtype)
    return -jnp.sum(onehot * jax.nn.log_softmax(logits, -1), axis=-1).mean()


def cross_entropy_gather(logits, labels):
    """The efficient twin: gathers the target logit directly."""
    logp = jax.nn.log_softmax(logits, -1)
    return -jnp.take_along_axis(logp, labels[..., None], axis=-1).mean()


def main():
    key = jax.random.key(0)
    logits = jax.random.normal(key, (8, 128, VOCAB))
    labels = jax.random.randint(jax.random.key(1), (8, 128), 0, VOCAB)

    # the per-user default store ($MAGNETON_STORE or ~/.cache/magneton/...):
    # RE-RUNNING this script hits the store and skips every re-execution
    store = ArtifactStore()
    session = Session(store=store)

    # -- capture once: trace + streamed signature capture + energy pricing.
    #    Each artifact is serializable and content-addressed in the store.
    art_onehot = session.capture(cross_entropy_onehot, (logits, labels),
                                 name="onehot-CE")
    art_gather = session.capture(cross_entropy_gather, (logits, labels),
                                 name="gather-CE")
    how = ("loaded from store (cache hit, no instrumented execution)"
           if art_onehot.meta.get("cache_hit") else "captured fresh")
    print(f"artifacts {art_onehot.key} / {art_gather.key} {how} "
          f"-> {store.root}")

    # -- compare runs matching + classification + diagnosis from artifacts
    report = session.compare(art_onehot, art_gather)
    print(report.render())

    waste = [f for f in report.findings if f.classification == "energy_waste"]
    assert waste, "expected the one-hot CE to be flagged"
    print(f"\n--> {len(waste)} energy-waste region(s) found; "
          f"the one-hot materialization costs "
          f"{report.total_energy_a_j / report.total_energy_b_j:.2f}x "
          "the gather implementation.")

    # -- re-compare entirely from the store: fresh session, cache-hit
    #    captures (no instrumented execution), identical findings.
    session2 = Session(store=store)
    art_onehot2 = session2.capture(cross_entropy_onehot, (logits, labels),
                                   name="onehot-CE")
    assert art_onehot2.meta.get("cache_hit"), "expected a store cache hit"
    art_gather2 = session2.capture(cross_entropy_gather, (logits, labels),
                                   name="gather-CE")
    report2 = session2.compare(art_onehot2, art_gather2)
    assert report2.to_json() == report.to_json(), "store round-trip changed findings"
    print("--> re-compare from the artifact store reproduced the report "
          "bit-identically (cache hit, no re-execution).")


if __name__ == "__main__":
    main()
