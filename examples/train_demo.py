"""End-to-end training driver: train a ~100M-param GPT-2-class model for a
few hundred steps on the synthetic LM stream, with checkpointing and the
Magneton energy audit enabled.

Full run (a few hours on this CPU container):
  PYTHONPATH=src python examples/train_demo.py --steps 300

Quick check (~2 min):
  PYTHONPATH=src python examples/train_demo.py --steps 30 --small
"""

import argparse
import dataclasses

from repro import configs
from repro.configs.base import ShapeConfig
from repro.train.loop import LoopConfig, run_training
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import TrainConfig


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--small", action="store_true",
                   help="2-layer model for a fast functional check")
    p.add_argument("--ckpt", default="/tmp/repro_train_demo")
    args = p.parse_args()

    # gpt2-small full config is ~124M params — the "~100M model" target.
    cfg = configs.get_config("gpt2-small")
    if args.small:
        cfg = cfg.reduced()
    shape = ShapeConfig("demo", seq_len=128 if not args.small else 32,
                        global_batch=8 if not args.small else 4,
                        kind="train")

    losses = []

    def on_step(step, metrics):
        losses.append(metrics["loss"])

    result = run_training(
        cfg, shape,
        opt_cfg=OptimizerConfig(lr=6e-4, warmup_steps=20,
                                total_steps=args.steps),
        tcfg=TrainConfig(remat=False),
        loop=LoopConfig(num_steps=args.steps, checkpoint_every=100,
                        checkpoint_dir=args.ckpt, log_every=10),
        on_step=on_step)

    first = sum(losses[:10]) / max(len(losses[:10]), 1)
    last = sum(losses[-10:]) / max(len(losses[-10:]), 1)
    print(f"\nloss {first:.3f} -> {last:.3f} over {len(losses)} steps "
          f"({'LEARNING' if last < first - 0.05 else 'check a longer run'})")
    print(f"checkpoints in {args.ckpt}: restartable with the same command")


if __name__ == "__main__":
    main()
