"""Serve a small model with batched requests, then run the Magneton energy
audit on the serving stack — the paper's profiler as a deployment feature.

  PYTHONPATH=src python examples/serving_energy_audit.py
"""

import time

import jax
import numpy as np

from repro import configs
from repro.models import transformer as tf
from repro.serve.engine import EngineConfig, Request, ServeEngine


def main():
    cfg = configs.get_config("llama3.2-3b").reduced()
    params = tf.model_init(cfg, jax.random.key(0))
    engine = ServeEngine(cfg, params,
                         ecfg=EngineConfig(batch_size=4, max_len=64))

    rng = np.random.default_rng(0)
    requests = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size, size=16,
                                            dtype=np.int32),
                        max_new_tokens=8)
                for i in range(8)]

    t0 = time.time()
    engine.generate(requests)
    dt = time.time() - t0
    toks = engine.stats["tokens_generated"]
    print(f"served {len(requests)} requests / {toks} tokens "
          f"in {dt:.1f}s ({toks/dt:.1f} tok/s)")

    print("\n=== Magneton audit of the decode step ===")
    report = engine.energy_report(prompt_len=16)
    print(report.render())


if __name__ == "__main__":
    main()
