"""Roofline table: aggregates results/dryrun/*.json into the §Roofline
report (one row per arch x shape x mesh cell)."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

HBM_PER_DEV = 16 * 1024**3           # v5e


def load_cells(out_dir: str = "results/dryrun") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def row(rec: dict) -> str:
    if rec.get("status") != "ok":
        return f"status=ERROR {rec.get('error', '')[:80]}"
    r = rec["roofline"]
    m = rec["memory"]
    used = m["argument_size_in_bytes"] + m["temp_size_in_bytes"]
    fits = "fits" if used <= HBM_PER_DEV else "OVER"
    return (f"compute={r['compute_s']*1e3:.1f}ms "
            f"memory={r['memory_s']*1e3:.1f}ms "
            f"collective={r['collective_s']*1e3:.1f}ms "
            f"dom={r['dominant'].replace('_s','')} "
            f"frac={rec['roofline_fraction']:.3f} "
            f"useful_flops={rec['model_flops_ratio']:.2f} "
            f"mem/dev={used/2**30:.1f}GiB({fits})")


def main() -> dict:
    cells = load_cells()
    if not cells:
        emit("roofline/none", 0.0, "no dry-run results found; run "
             "python -m repro.launch.dryrun --all --mesh both first")
        return {}
    ok = 0
    dominants = {"compute_s": 0, "memory_s": 0, "collective_s": 0}
    for rec in cells:
        name = f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        emit(name, rec.get("t_compile_s", 0) * 1e6, row(rec))
        if rec.get("status") == "ok":
            ok += 1
            dominants[rec["roofline"]["dominant"]] += 1
    emit("roofline/summary", 0.0,
         f"{ok}/{len(cells)} cells ok; dominant terms: "
         f"compute={dominants['compute_s']} memory={dominants['memory_s']} "
         f"collective={dominants['collective_s']}")
    return {"cells": len(cells), "ok": ok}


if __name__ == "__main__":
    main()
