"""Benchmark runner: one module per paper table/figure + the roofline table.

Prints ``name,us_per_call,derived`` CSV rows.  Usage:
  PYTHONPATH=src python -m benchmarks.run [--only fig5,table2,...]
"""

from __future__ import annotations

import argparse
import sys
import time

MODULES = ("table2_detection", "fig5_energy_gaps", "fig8_sensitivity",
           "fig9_scalability", "table4_accuracy", "fig10_overhead",
           "roofline")


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", type=str, default=None)
    args = p.parse_args()
    want = args.only.split(",") if args.only else None
    failures = []
    for name in MODULES:
        if want and not any(w in name for w in want):
            continue
        print(f"# === benchmarks.{name} ===", flush=True)
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
        except Exception as e:
            failures.append(name)
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}", flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if failures:
        print(f"# FAILURES: {failures}", flush=True)
        sys.exit(1)


if __name__ == "__main__":
    main()
