"""Shared helpers for the benchmark harness."""

from __future__ import annotations

import time
from typing import Callable


def time_us(fn: Callable, *args, repeats: int = 5, warmup: int = 1) -> float:
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / repeats * 1e6


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
