"""Shared helpers for the benchmark harness."""

from __future__ import annotations

import time
from typing import Callable


def time_us(fn: Callable, *args, repeats: int = 5, warmup: int = 1) -> float:
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / repeats * 1e6


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def emit_json(path: str, payload: dict) -> None:
    """Write a benchmark payload as JSON (e.g. BENCH_matcher.json) so future
    PRs can track the perf trajectory machine-readably."""
    import json

    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"wrote {path}")
