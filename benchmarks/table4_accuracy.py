"""Table 4 analogue: power-measurement accuracy of the replay profiler.

The paper compares NVML-based Zeus (~80% error) against Magneton's
operator-level replay (<5% error) and a physical meter.  Without hardware we
run the same three-way structure on this host:

  * 'ground truth'  — long-window direct measurement of each operator
                      (replay with a 50ms window: the 'physical meter' role);
  * 'zeus-like'     — a single coarse 10Hz-style sample over the whole graph
                      execution, attributed to ops by count (the failure mode
                      the paper describes: averages across many kernels);
  * 'magneton'      — the production ReplayProfiler (5ms replay windows).

Reported per-op relative error vs ground truth, for the paper's three
representative operators (arange / contiguous-copy / linear).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.energy import ReplayProfiler
from repro.core.graph import trace
from repro.hw.specs import CPU_HOST


def _model(x, w):
    r = jnp.arange(x.shape[0], dtype=jnp.float32)          # aten::arange
    y = jnp.transpose(x).copy().T                           # contiguous copy
    z = y @ w + r[:, None]                                  # linear
    return z


_OPS = {"iota": "arange", "transpose": "contiguous", "dot_general": "linear"}


def _per_op(profile):
    out = {}
    for op in profile.ops:
        label = _OPS.get(op.primitive)
        if label and label not in out:
            out[label] = op
    return out


def main() -> dict:
    key = jax.random.key(0)
    x = jax.random.normal(key, (1024, 1024))
    w = jax.random.normal(jax.random.key(1), (1024, 1024))
    g = trace(_model, x, w)

    truth = _per_op(ReplayProfiler(min_replay_time_s=5e-2,
                                   max_replay_iters=256).profile(g, x, w))
    magneton = _per_op(ReplayProfiler(min_replay_time_s=5e-3,
                                      max_replay_iters=64).profile(g, x, w))

    # zeus-like: one wall-clock sample over the whole run, split evenly
    t0 = time.perf_counter()
    jax.block_until_ready(jax.jit(_model)(x, w))
    total_t = time.perf_counter() - t0
    per_op_t = total_t / len(g.nodes)
    zeus_power = CPU_HOST.idle_watts + 0.5 * CPU_HOST.compute_watts

    rows = {}
    for label, t_op in truth.items():
        p_truth = t_op.energy_j / max(t_op.time_s, 1e-12)
        m = magneton[label]
        p_mag = m.energy_j / max(m.time_s, 1e-12)
        err_mag = (p_mag - p_truth) / p_truth * 100
        err_zeus = (zeus_power - p_truth) / p_truth * 100
        rows[label] = (p_truth, p_mag, err_mag, err_zeus)
        emit(f"table4/{label}", t_op.time_s * 1e6,
             f"truth={p_truth:.1f}W magneton={p_mag:.1f}W "
             f"err={err_mag:+.1f}% zeus-like_err={err_zeus:+.1f}% "
             f"(paper: zeus ~-80%, magneton <5%)")
    return rows


if __name__ == "__main__":
    main()
