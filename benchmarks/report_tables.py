"""Render EXPERIMENTS.md §Dry-run and §Roofline markdown tables from
results/dryrun/*.json.  Usage:
  PYTHONPATH=src python -m benchmarks.report_tables [outdir]
"""

from __future__ import annotations

import sys

from benchmarks.roofline import HBM_PER_DEV, load_cells


def fmt_bytes(b: float) -> str:
    return f"{b/2**30:.2f}"


def dryrun_table(cells: list[dict]) -> str:
    lines = ["| arch | shape | mesh | GiB/dev (args+temp) | fits 16G | "
             "per-dev GFLOP | per-dev GB | coll GB (ici/dcn) | collective mix |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in cells:
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERROR: {r.get('error','')[:60]} | | | | | |")
            continue
        m = r["memory"]
        used = m["argument_size_in_bytes"] + m["temp_size_in_bytes"]
        p = r["per_device"]
        ops = r.get("collective_ops", {})
        mix = " ".join(f"{k.split('-')[-1] if '-' in k else k}:{v}"
                       for k, v in sorted(ops.items()))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{fmt_bytes(used)} | {'yes' if used <= HBM_PER_DEV else 'NO'} | "
            f"{p['flops']/1e9:.0f} | {p['bytes']/1e9:.1f} | "
            f"{p['coll_ici']/1e9:.2f}/{p['coll_dcn']/1e9:.2f} | {mix} |")
    return "\n".join(lines)


def roofline_table(cells: list[dict]) -> str:
    lines = ["| arch | shape | mesh | compute (ms) | memory (ms) | "
             "collective (ms) | dominant | MODEL/HLO flops | roofline frac |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in cells:
        if r.get("status") != "ok":
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{rf['compute_s']*1e3:.1f} | {rf['memory_s']*1e3:.1f} | "
            f"{rf['collective_s']*1e3:.1f} | "
            f"{rf['dominant'].replace('_s','')} | "
            f"{r['model_flops_ratio']:.2f} | {r['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def main() -> None:
    outdir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    cells = load_cells(outdir)
    cells.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    print("## Dry-run table\n")
    print(dryrun_table(cells))
    print("\n## Roofline table\n")
    print(roofline_table(cells))


if __name__ == "__main__":
    main()
