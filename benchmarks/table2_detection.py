"""Table 2 analogue: detection + diagnosis over the full case zoo.

For every registered case: whether Magneton detects the waste, the
region-level energy difference, end-to-end dE, and the diagnosis kind.  The
paper diagnoses 15/16 known cases (c11 is the documented miss); this harness
must reproduce that score on the JAX adaptations.

Runs on the Session/artifact API: each side is captured once and the
comparison runs from artifacts, so the per-case wall time now separates
capture cost from compare cost.
"""

from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core.session import Session
from repro.zoo.cases import list_cases


def main() -> dict:
    session = Session()
    detected_known = 0
    total_known = 0
    detected_new = 0
    rows = []
    for c in list_cases():
        t0 = time.perf_counter()
        try:
            art_a = session.capture(c.inefficient, c.make_args(),
                                    name=c.id + "-ineff", config=c.config_a)
            art_b = session.capture(c.efficient, c.make_args(),
                                    name=c.id + "-eff", config=c.config_b)
            t_cap = time.perf_counter() - t0
            rep = session.compare(art_a, art_b, output_rtol=c.output_rtol)
            waste = [f for f in rep.findings
                     if f.classification == "energy_waste"
                     and f.wasteful_side == "A"]
            det = bool(waste)
            de = (rep.total_energy_a_j - rep.total_energy_b_j) \
                / max(rep.total_energy_b_j, 1e-12) * 100
            kind = waste[0].diagnosis.kind if waste and waste[0].diagnosis \
                else "-"
            region_de = max(((f.energy_a_j - f.energy_b_j)
                             / max(f.energy_b_j, 1e-12) * 100
                             for f in waste), default=0.0)
        except Exception as e:          # pragma: no cover
            det, de, kind, region_de = False, 0.0, f"ERROR:{type(e).__name__}", 0.0
            t_cap = 0.0
        dt = (time.perf_counter() - t0) * 1e6
        if c.known:
            total_known += 1
            detected_known += det
        else:
            detected_new += det
        ok = "ok" if det == c.expect_detect else "MISS"
        rows.append((c.id, c.paper_id, c.category, det, de, kind, ok))
        emit(f"table2/{c.id}", dt,
             f"detected={det} dE={de:+.1f}% region_dE={region_de:+.1f}% "
             f"kind={kind} capture={t_cap:.2f}s {ok}")
    emit("table2/summary", 0.0,
         f"known {detected_known}/{total_known} detected "
         f"(paper: 15/16); new {detected_new}/4")
    return {"detected_known": detected_known, "total_known": total_known,
            "detected_new": detected_new, "rows": rows}


if __name__ == "__main__":
    main()
