"""Figure 10 analogue: runtime overhead of Magneton's tracing module.

The paper attaches CUPTI tracing to a *running* process and measures 4.4%
(Transformers) / 5.9% (vLLM) end-to-end slowdown.  The JAX adaptation gets
the operator graph ahead-of-time from the jaxpr, so the steady-state overhead
model is different and better:

  * one-time cost: re-trace the step + build OpGraph + analytic energy
    profile (no execution involved);
  * steady-state cost: ZERO — the jitted step is untouched;
  * optional replay profiling runs offline (the paper's §5.2 replay mode),
    measured here as the offline diagnosis budget (paper: < 2 min/case).

We report the one-time cost amortized over a 100-step window next to the
paper's runtime-attach numbers, plus the op-by-op interpretation cost for
completeness (the JAX-side worst case, only paid in replay mode).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro import configs
from repro.core.energy import AnalyticalEnergyModel
from repro.core.graph import trace
from repro.models import transformer as T


def main() -> dict:
    cfg = configs.get_config("gpt2-small").reduced()
    params = T.model_init(cfg, jax.random.key(0))
    B, S = 4, 64
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)

    def fwd(params, tokens):
        return T.forward(cfg, params, tokens, remat=False)[0]

    jitted = jax.jit(fwd)
    jax.block_until_ready(jitted(params, tokens))
    t0 = time.perf_counter()
    for _ in range(5):
        jax.block_until_ready(jitted(params, tokens))
    base = (time.perf_counter() - t0) / 5

    # one-time attach cost: trace + graph + analytic profile
    t0 = time.perf_counter()
    g = trace(fwd, params, tokens)
    AnalyticalEnergyModel().profile(g)
    attach = time.perf_counter() - t0

    amortized = attach / (100 * base) * 100
    emit("fig10/baseline_step", base * 1e6, "jit step")
    emit("fig10/attach_once", attach * 1e6,
         f"trace+graph+profile ({len(g.nodes)} ops)")
    emit("fig10/steady_state", 0.0,
         f"0% (AOT jaxpr tracing; jitted step untouched). one-time cost "
         f"amortized over 100 steps = {amortized:.1f}% "
         f"(paper runtime-attach: 4.4-5.9%)")

    # offline diagnosis budget (paper: < 2 min for all cases)
    from repro.core.diff import DifferentialEnergyDebugger
    from repro.zoo import cases
    c = cases.by_id("c6-matpow")
    t0 = time.perf_counter()
    DifferentialEnergyDebugger().compare(c.inefficient, c.efficient,
                                         c.make_args(),
                                         output_rtol=c.output_rtol)
    diag = time.perf_counter() - t0
    emit("fig10/offline_diagnosis", diag * 1e6,
         f"{diag:.2f}s for one case incl. replay-free capture (paper: <2min)")
    return {"amortized_pct": amortized, "diagnosis_s": diag}


if __name__ == "__main__":
    main()
