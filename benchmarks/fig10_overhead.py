"""Figure 10 analogue: runtime overhead of Magneton's tracing module.

The paper attaches CUPTI tracing to a *running* process and measures 4.4%
(Transformers) / 5.9% (vLLM) end-to-end slowdown.  The JAX adaptation gets
the operator graph ahead-of-time from the jaxpr, so the steady-state overhead
model is different and better:

  * one-time cost: re-trace the step + build OpGraph + analytic energy
    profile (no execution involved);
  * steady-state cost: ZERO — the jitted step is untouched;
  * optional replay profiling runs offline (the paper's §5.2 replay mode),
    measured here as the offline diagnosis budget (paper: < 2 min/case).

Since PR 2 the diagnosis budget is a Session/artifact pipeline, so this
benchmark also prices artifact reuse: a cold capture+compare vs re-comparing
the same candidates from the content-addressed store (capture cache hits, no
instrumented execution).  Results land in ``BENCH_overhead.json`` next to
``BENCH_matcher.json`` so the session-API overhead is tracked PR over PR.
"""

from __future__ import annotations

import tempfile
import time

import jax

from benchmarks.common import emit, emit_json
from repro import configs
from repro.core.energy import AnalyticalEnergyModel
from repro.core.graph import trace
from repro.models import transformer as T


def main() -> dict:
    cfg = configs.get_config("gpt2-small").reduced()
    params = T.model_init(cfg, jax.random.key(0))
    B, S = 4, 64
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)

    def fwd(params, tokens):
        return T.forward(cfg, params, tokens, remat=False)[0]

    jitted = jax.jit(fwd)
    jax.block_until_ready(jitted(params, tokens))
    t0 = time.perf_counter()
    for _ in range(5):
        jax.block_until_ready(jitted(params, tokens))
    base = (time.perf_counter() - t0) / 5

    # one-time attach cost: trace + graph + analytic profile
    t0 = time.perf_counter()
    g = trace(fwd, params, tokens)
    AnalyticalEnergyModel().profile(g)
    attach = time.perf_counter() - t0

    amortized = attach / (100 * base) * 100
    emit("fig10/baseline_step", base * 1e6, "jit step")
    emit("fig10/attach_once", attach * 1e6,
         f"trace+graph+profile ({len(g.nodes)} ops)")
    emit("fig10/steady_state", 0.0,
         f"0% (AOT jaxpr tracing; jitted step untouched). one-time cost "
         f"amortized over 100 steps = {amortized:.1f}% "
         f"(paper runtime-attach: 4.4-5.9%)")

    # offline diagnosis budget (paper: < 2 min for all cases), now split into
    # the Session pipeline's phases: cold capture, artifact-level compare,
    # and store-backed re-comparison (capture cache hits).
    from repro.core.diff import DifferentialEnergyDebugger
    from repro.core.session import Session
    from repro.zoo.cases import get_case
    c = get_case("c6-matpow")

    t0 = time.perf_counter()
    DifferentialEnergyDebugger().compare(c.inefficient, c.efficient,
                                         c.make_args(),
                                         output_rtol=c.output_rtol)
    one_shot = time.perf_counter() - t0
    emit("fig10/offline_diagnosis", one_shot * 1e6,
         f"{one_shot:.2f}s one-shot legacy compare (paper: <2min)")

    with tempfile.TemporaryDirectory() as store:
        session = Session(store=store)
        t0 = time.perf_counter()
        art_a = session.capture(c.inefficient, c.make_args(),
                                name="ineff", config=c.config_a)
        art_b = session.capture(c.efficient, c.make_args(),
                                name="eff", config=c.config_b)
        capture_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        session.compare(art_a, art_b, output_rtol=c.output_rtol)
        compare_live = time.perf_counter() - t0

        # fresh session against the same store: captures are cache hits and
        # the comparison replays from persisted invariants + values
        session2 = Session(store=store)
        t0 = time.perf_counter()
        art_a2 = session2.capture(c.inefficient, c.make_args(),
                                  name="ineff", config=c.config_a)
        art_b2 = session2.capture(c.efficient, c.make_args(),
                                  name="eff", config=c.config_b)
        session2.compare(art_a2, art_b2, output_rtol=c.output_rtol)
        recompare = time.perf_counter() - t0

    reuse_speedup = (capture_cold + compare_live) / max(recompare, 1e-9)
    emit("fig10/session_capture_cold", capture_cold * 1e6,
         "trace+stream-capture+price, both sides")
    emit("fig10/session_compare", compare_live * 1e6,
         "match+classify+diagnose from artifacts")
    emit("fig10/session_recompare_store", recompare * 1e6,
         f"store cache hits; {reuse_speedup:.1f}x vs cold end-to-end")

    payload = {
        "baseline_step_s": base,
        "attach_once_s": attach,
        "amortized_pct_100_steps": amortized,
        "one_shot_compare_s": one_shot,
        "session_capture_cold_s": capture_cold,
        "session_compare_s": compare_live,
        "session_recompare_store_s": recompare,
        "artifact_reuse_speedup": reuse_speedup,
        "graph_nodes": len(g.nodes),
    }
    emit_json("BENCH_overhead.json", payload)
    return payload


if __name__ == "__main__":
    main()
