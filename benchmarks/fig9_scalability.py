"""Figure 9 analogue: matcher efficiency and scalability to 5k+ node graphs.

The paper matches vLLM-vs-Transformers GPT-2 graphs (757/408 nodes) in 167ms
and Llama-3-8B graphs in 1.4s while a brute-force strawman times out at 5
minutes.  We reproduce the scaling curve on synthetic deep networks of
increasing node count, comparing the production hierarchical pipeline
(block-stamped streaming capture -> two-phase match -> template-memoized
subgraph match) against the seed eager pipeline (full-value capture ->
exhaustive numel-bucketed match), and run the exponential strawman with a
small budget to show the combinatorial blow-up.

Bench model: each layer applies a block-diagonal 2x2 rotation scaled by
0.99, a tanh, a 0.5x residual and a 1.01x rescale.  An earlier version used
``tanh(x @ w_random) + x`` — at depth the saturating tanh drove activations
onto a fixed point, so thousands of tensors became bitwise duplicates and
both matchers degenerated into trivial multiset collapse (any "scaling"
measured on it was fiction).  The rotation keeps every layer's activation
distinct (the per-pair angles prevent fixed points; the 0.99/1.01 scalings
keep magnitudes drifting but bounded), verified to produce zero duplicate
tensors at 1280 layers.

Eager matching is quadratic, so it runs only up to ``EAGER_MAX_NODES`` and
is extrapolated as ``t_eager(N0) * (n / N0)**2`` beyond — the 5k-node config
must beat that bound by >= 10x.  Capture is timed eagerly at EVERY config
and the streaming capture must not be slower from 161 nodes up (the jit'd
fused replay loop; below that, compile-cache effects dominate either way).

Two memory numbers are reported per config.  The historical
``peak_captured_bytes_streaming`` is the *matcher's* phase-2 fetch watermark
(``MatchStats.peak_value_bytes``) — on graphs whose pairs all survive to
phase 2 it equals the eager resident set, which is correct but useless as a
capture metric (the old harness reported it as if it measured capture).  The
true capture watermark is ``capture_peak_live_bytes_streaming``: the
executor's high-water mark of live operator outputs with reference-counted
discard, which stays O(layer width), not O(graph).

All timed sections run with the garbage collector disabled, best-of-N —
GC pauses inside a 100ms region otherwise dominate the tail configs.

Emits ``BENCH_matcher.json`` via benchmarks.common.emit_json so future PRs
(and scripts/ci.sh's matcher-scaling gate) can track the perf trajectory.
"""

from __future__ import annotations

import gc
import itertools
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, emit_json
from repro.core.block_match import BlockStamper
from repro.core.graph import trace
from repro.core.interp import capture_tensor_stats, capture_tensor_values
from repro.core.subgraph_match import match_subgraphs
from repro.core.tensor_match import TensorMatcher, bijective_pairs

# layers -> nodes: 5*L + 1 (dot, tanh, mul, add, mul per layer + final sum)
CONFIGS = (8, 32, 128, 500, 1024)          # 41 / 161 / 641 / 2501 / 5121 nodes
EAGER_MAX_NODES = 641                      # quadratic path measured up to here
STREAM_LE_EAGER_MIN_NODES = 161            # capture assert active from here


def _inputs():
    R0 = np.zeros((32, 32), np.float32)
    for i in range(0, 32, 2):
        c, s = np.cos(1.0 + i * 0.1), np.sin(1.0 + i * 0.1)
        R0[i, i], R0[i, i + 1], R0[i + 1, i], R0[i + 1, i + 1] = c, s, -s, c
    w = jnp.asarray(0.99 * R0)
    x = jnp.arange(16 * 32, dtype=jnp.float32).reshape(16, 32) / 100.0
    return x, w


def _deep_model(layers):
    def fn(x, w):
        for _ in range(layers):
            x = (jnp.tanh(x @ w) + 0.5 * x) * 1.01
        return x.sum()
    return fn


def _best_of(n, thunk):
    """Best-of-n wall time with GC disabled inside the timed region."""
    best, out = None, None
    for _ in range(n):
        gc.collect()
        gc.disable()
        t0 = time.perf_counter()
        r = thunk()
        dt = time.perf_counter() - t0
        gc.enable()
        if best is None or dt < best:
            best, out = dt, r
    return best, out


def _best_of_paired(n, thunk_a, thunk_b):
    """Interleaved best-of-n for two thunks under comparison.

    Timing A's rounds and B's rounds back-to-back lets a load spike land
    entirely on one side and flip a close comparison; alternating within
    each round exposes both to the same ambient noise, so min-of-rounds
    compares the two paths' quiet-machine costs."""
    best_a = best_b = None
    out_a = out_b = None
    for _ in range(n):
        gc.collect()
        gc.disable()
        t0 = time.perf_counter()
        ra = thunk_a()
        ta = time.perf_counter() - t0
        t0 = time.perf_counter()
        rb = thunk_b()
        tb = time.perf_counter() - t0
        gc.enable()
        if best_a is None or ta < best_a:
            best_a, out_a = ta, ra
        if best_b is None or tb < best_b:
            best_b, out_b = tb, rb
    return best_a, out_a, best_b, out_b


def _run_eager_match(ga, gb, va, vb):
    """Seed pipeline: exhaustive signature match over materialized values."""
    def thunk():
        pairs = TensorMatcher().match_exhaustive([va], [vb])
        regions = match_subgraphs(ga, gb, pairs,
                                  block_memo=False)
        return pairs, regions
    t, (pairs, regions) = _best_of(2, thunk)
    return t, pairs, regions


def _run_hierarchical(ga, gb, x, w, sa, sb, samples, n_best=5):
    """Production pipeline: block stamping + streamed match + memoized
    subgraph match.  The TIMED region includes stamper construction and the
    matcher's selective phase-2 value re-captures — they are part of
    matching, not of capture."""
    fa = lambda k, tids: capture_tensor_values(ga, x, w, only_tids=tids)
    fb = lambda k, tids: capture_tensor_values(gb, x, w, only_tids=tids)

    def thunk():
        m = TensorMatcher()
        stamper = BlockStamper(ga, gb, samples, samples)
        pairs = m.match_streamed([sa], [sb], fa, fb, stamper=stamper)
        regions = match_subgraphs(ga, gb, pairs)
        return m, stamper, pairs, regions
    t, (m, stamper, pairs, regions) = _best_of(n_best, thunk)
    return t, m, stamper, pairs, regions


def _brute_force(ga, gb, eq_pairs, budget_s: float):
    """Strawman: enumerate subgraph-pair candidates between cut points by
    subset search (exponential); returns #pairs tried before the budget."""
    eq = bijective_pairs(eq_pairs)
    nodes_a = list(range(len(ga.nodes)))
    tried = 0
    t0 = time.perf_counter()
    for r in range(1, len(nodes_a) + 1):
        for comb in itertools.combinations(nodes_a, r):
            tried += 1
            if time.perf_counter() - t0 > budget_s:
                return tried, False
    return tried, True


def main() -> dict:
    results = {}
    bench = {"configs": {}}
    x, w = _inputs()
    samples = [(x, w)]
    eager_ref = None          # (nodes, t_eager) anchor for N^2 extrapolation

    for layers in CONFIGS:
        fn = _deep_model(layers)
        ga = trace(fn, x, w)
        gb = trace(fn, x, w)
        nodes = len(ga.nodes)

        # -- capture: eager at EVERY config, streaming with live watermark --
        # warm both graphs on both paths first: each graph owns its own
        # executor plan + jit cache, so an unwarmed side would bill one
        # compile to whichever path ran it first
        capture_tensor_values(ga, x, w)
        capture_tensor_values(gb, x, w)
        mem: dict = {}
        capture_tensor_stats(ga, x, w, mem=mem)
        capture_tensor_stats(gb, x, w)
        peak_live = mem.get("peak_live_bytes", 0)
        tc_eager, (va, vb), tc_fast, (sa, sb) = _best_of_paired(
            7,
            lambda: (capture_tensor_values(ga, x, w),
                     capture_tensor_values(gb, x, w)),
            lambda: (capture_tensor_stats(ga, x, w)[1],
                     capture_tensor_stats(gb, x, w)[1]))
        bytes_eager = sum(v.nbytes for v in va.values()) + \
            sum(v.nbytes for v in vb.values())
        if nodes >= STREAM_LE_EAGER_MIN_NODES:
            assert tc_fast <= tc_eager, (
                f"streaming capture slower than eager at {nodes} nodes "
                f"({tc_fast*1e3:.1f}ms > {tc_eager*1e3:.1f}ms)")

        # -- match: hierarchical pipeline, eager only up to the bound -------
        # cheap configs get more repetitions: a single scheduler hiccup in a
        # 4ms region otherwise swamps the nodes/sec curve
        n_best = 15 if nodes <= 200 else (9 if nodes <= 1000 else 6)
        t_fast, m, stamper, pairs_fast, regions = _run_hierarchical(
            ga, gb, x, w, sa, sb, samples, n_best=n_best)
        st = m.last_stats
        if nodes <= EAGER_MAX_NODES:
            t_eager, pairs_eager, _ = _run_eager_match(ga, gb, va, vb)
            assert set(pairs_fast) == set(pairs_eager), \
                f"stamped/exhaustive pair mismatch at {layers} layers"
            eager_ref = (nodes, t_eager)
            eager_extrapolated = False
        else:
            # beyond the bound, verify stamping against the plain streamed
            # matcher (same verdicts, no stamper) instead of O(N^2) eager
            plain = TensorMatcher().match_streamed(
                [sa], [sb],
                lambda k, tids: capture_tensor_values(ga, x, w,
                                                      only_tids=tids),
                lambda k, tids: capture_tensor_values(gb, x, w,
                                                      only_tids=tids))
            assert set(pairs_fast) == set(plain), \
                f"stamped/streamed pair mismatch at {layers} layers"
            n0, t0 = eager_ref
            t_eager = t0 * (nodes / n0) ** 2
            eager_extrapolated = True

        speedup = t_eager / max(t_fast, 1e-9)
        results[layers] = t_fast
        bench["configs"][str(nodes)] = {
            "layers": layers,
            "nodes": nodes,
            "match_s_streaming": t_fast,
            "match_s_eager": t_eager,
            "match_eager_extrapolated": eager_extrapolated,
            "capture_s_streaming": tc_fast,
            "capture_s_eager": tc_eager,
            "speedup": speedup,
            "nodes_per_sec": nodes / max(t_fast, 1e-9),
            "peak_captured_bytes_streaming":
                st.peak_value_bytes if st else 0,
            "peak_captured_bytes_eager": bytes_eager,
            "capture_peak_live_bytes_streaming": peak_live,
            "stamped_pairs": st.stamped_pairs if st else 0,
            "twin_reseeded": st.twin_reseeded if st else 0,
            "demoted_pairs": st.demoted_pairs if st else 0,
            "regions": len(regions),
            "pairs": len(pairs_fast),
        }
        emit(f"fig9/nodes={nodes}", t_fast * 1e6,
             f"regions={len(regions)} time={t_fast*1e3:.0f}ms "
             f"eager={'~' if eager_extrapolated else ''}{t_eager*1e3:.0f}ms "
             f"speedup={speedup:.1f}x stamped={st.stamped_pairs if st else 0} "
             f"capture={tc_fast*1e3:.1f}ms-vs-{tc_eager*1e3:.1f}ms "
             f"live_peak={peak_live}B")

    # 5k-node acceptance: >= 10x faster than the N^2 extrapolation
    big = bench["configs"][str(5 * CONFIGS[-1] + 1)]
    assert big["speedup"] >= 10.0, \
        f"5k-node config only {big['speedup']:.1f}x over N^2 extrapolation"

    # multi-sample peak memory at a mid config: the eager pipeline holds
    # every sample's full activation set on both sides for the whole match;
    # the streaming pipeline keeps invariants only and materializes at most
    # ONE sample's phase-2 survivors at a time.
    fn = _deep_model(128)
    ga, gb = trace(fn, x, w), trace(fn, x, w)
    x2 = x * 1.1
    vals_a = [capture_tensor_values(ga, x, w),
              capture_tensor_values(ga, x2, w)]
    vals_b = [capture_tensor_values(gb, x, w),
              capture_tensor_values(gb, x2, w)]
    eager_bytes = sum(v.nbytes for side in (vals_a, vals_b)
                      for d in side for v in d.values())
    pairs_eager = TensorMatcher().match_exhaustive(vals_a, vals_b)
    m = TensorMatcher()
    stats = [[capture_tensor_stats(g, xx, w)[1] for xx in (x, x2)]
             for g in (ga, gb)]
    pairs_fast = m.match_streamed(
        stats[0], stats[1],
        lambda k, tids: capture_tensor_values(ga, x if k == 0 else x2, w,
                                              only_tids=tids),
        lambda k, tids: capture_tensor_values(gb, x if k == 0 else x2, w,
                                              only_tids=tids))
    assert set(pairs_fast) == set(pairs_eager), "multi-sample pair mismatch"
    peak = m.last_stats.peak_value_bytes
    emit("fig9/peak_capture_2samples", 0.0,
         f"streaming_peak={peak}B eager_resident={eager_bytes}B "
         f"reduction={eager_bytes / max(peak, 1):.1f}x")
    bench["peak_capture_2samples"] = {
        "streaming_peak_bytes": peak,
        "eager_resident_bytes": eager_bytes,
    }

    # quadratic-vs-exponential check: strawman on the small graph only
    fn = _deep_model(CONFIGS[0])
    ga = trace(fn, x, w)
    va = capture_tensor_values(ga, x, w)
    pairs = TensorMatcher().match([va], [va])
    tried, finished = _brute_force(ga, ga, pairs, budget_s=2.0)
    emit("fig9/bruteforce", 2e6,
         f"subsets_tried={tried} finished={finished} "
         f"(paper strawman: timeout at 5min on Llama-3-8B)")

    # scaling summary: hierarchical matching must not lose throughput with
    # size.  Anchor every config against the 41-node head rate: mid-size
    # rates fluctuate 20-40% run to run (jit dispatch + allocator noise in
    # sub-100ms regions), so pairwise-adjacent monotonicity flakes, but a
    # genuine quadratic cliff puts the 5k tail at ~1/100 of the head — a
    # head-anchored floor separates the two cleanly.  ci.sh re-asserts the
    # hard floor rate(5k) >= rate(41) from the emitted JSON.
    rates = [bench["configs"][str(5 * L + 1)]["nodes_per_sec"]
             for L in CONFIGS]
    for i in range(1, len(rates)):
        assert rates[i] >= rates[0], (
            f"throughput cliff at {5*CONFIGS[i]+1} nodes: "
            f"{rates[i]:.0f} nodes/sec vs {rates[0]:.0f} at the head")
    ratio = results[CONFIGS[-1]] / max(results[CONFIGS[0]], 1e-9)
    emit("fig9/summary", 0.0,
         f"time({CONFIGS[-1]}L)/time({CONFIGS[0]}L)={ratio:.1f}x for "
         f"{(5*CONFIGS[-1]+1) / (5*CONFIGS[0]+1):.0f}x nodes; "
         f"nodes/sec={['%.0f' % r for r in rates]}")
    bench["scaling_ratio_tail_over_head"] = ratio
    bench["nodes_per_sec_by_config"] = rates

    # heterogeneous stack: the matcher machinery above runs on homogeneous
    # layer repeats; real models interleave block KINDS.  The tied-weight
    # transformer zoo model carries two distinct repeated-block families
    # (attention blocks, then norm+MLP blocks) in one graph — multi-family
    # stamping and the block-evidence cache must both engage, and a warm
    # re-capture must hit every block of both families.
    from repro.core.block_cache import BlockEvidenceCache
    from repro.core.graph import block_structure
    from repro.models.blockstack import transformer_block_stack

    hfn, hargs = transformer_block_stack()
    hg = trace(hfn, *hargs)
    bs = block_structure(hg)
    assert len(bs.families) >= 2, (
        f"hetero stack formed {len(bs.families)} block families (need >=2)")
    cache = BlockEvidenceCache()
    t_cold, _ = _best_of(1, lambda: capture_tensor_stats(
        hg, *hargs, block_cache=cache))
    probed_fams = {t[2] for t in cache.trace if t[0] == "block"}
    before = cache.snapshot()
    t_warm, _ = _best_of(3, lambda: capture_tensor_stats(
        hg, *hargs, block_cache=cache))
    d = BlockEvidenceCache.delta(before, cache.snapshot())
    hits, misses = d.get("block_hits", 0), d.get("block_misses", 0)
    assert misses == 0, f"warm hetero capture missed {misses} blocks"
    assert len(probed_fams) >= 2, "block cache engaged on < 2 families"
    emit("fig9/hetero_blockstack", t_warm * 1e6,
         f"nodes={len(hg.nodes)} families={len(bs.families)} "
         f"coverage={bs.coverage():.2f} cold={t_cold*1e3:.0f}ms "
         f"warm={t_warm*1e3:.0f}ms hits={hits}")
    bench["hetero"] = {
        "nodes": len(hg.nodes),
        "families": len(bs.families),
        "coverage": bs.coverage(),
        "capture_s_cold": t_cold,
        "capture_s_warm": t_warm,
        "warm_block_hits": hits,
    }

    emit_json("BENCH_matcher.json", bench)
    return results


if __name__ == "__main__":
    main()
