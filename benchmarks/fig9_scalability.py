"""Figure 9 analogue: matcher efficiency and scalability.

The paper matches vLLM-vs-Transformers GPT-2 graphs (757/408 nodes) in 167ms
and Llama-3-8B graphs in 1.4s while a brute-force strawman times out at 5
minutes.  We reproduce the scaling curve on synthetic deep networks of
increasing node count, comparing the production streaming+lazy pipeline
(capture_tensor_stats -> bucketed two-phase match) against the seed eager
pipeline (full-value capture -> exhaustive numel-bucketed match), and run the
exponential strawman with a small budget to show the combinatorial blow-up.

Emits ``BENCH_matcher.json`` (nodes/sec, peak captured bytes, wall time per
graph size, speedup vs the eager path) via benchmarks.common.emit_json so
future PRs can track the perf trajectory.
"""

from __future__ import annotations

import itertools
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, emit_json
from repro.core.graph import trace
from repro.core.interp import (capture_tensor_stats, capture_tensor_values)
from repro.core.subgraph_match import match_subgraphs
from repro.core.tensor_match import TensorMatcher, bijective_pairs


def _deep_model(layers):
    def fn(x, w):
        for i in range(layers):
            x = jnp.tanh(x @ w) + x
            x = x * 1.01
        return x.sum()
    return fn


def _run_eager(ga, gb, x, w):
    """Seed pipeline: materialize every tensor, exhaustive signature match.

    Matches the seed benchmark's timer placement: the value capture happens
    before the clock starts; the match + region extraction are timed.
    """
    tc0 = time.perf_counter()
    va = capture_tensor_values(ga, x, w)
    vb = capture_tensor_values(gb, x, w)
    t_capture = time.perf_counter() - tc0
    captured = sum(v.nbytes for v in va.values()) + \
        sum(v.nbytes for v in vb.values())
    t0 = time.perf_counter()
    pairs = TensorMatcher().match_exhaustive([va], [vb])
    regions = match_subgraphs(ga, gb, pairs)
    return time.perf_counter() - t0, t_capture, captured, pairs, regions


def _run_streaming(ga, gb, x, w):
    """Production pipeline: streamed invariants + lazy two-phase matching.

    The capture (outside the clock, like the eager run) retains only per-
    tensor invariants; the TIMED region includes the matcher's selective
    phase-2 value re-captures — they are part of matching, not of capture.
    """
    tc0 = time.perf_counter()
    _, sa = capture_tensor_stats(ga, x, w)
    _, sb = capture_tensor_stats(gb, x, w)
    t_capture = time.perf_counter() - tc0
    m = TensorMatcher()
    t0 = time.perf_counter()
    pairs = m.match_streamed(
        [sa], [sb],
        lambda k, tids: capture_tensor_values(ga, x, w, only_tids=tids),
        lambda k, tids: capture_tensor_values(gb, x, w, only_tids=tids))
    regions = match_subgraphs(ga, gb, pairs)
    dt = time.perf_counter() - t0
    captured = m.last_stats.peak_value_bytes if m.last_stats else 0
    return dt, t_capture, captured, pairs, regions


def _brute_force(ga, gb, eq_pairs, budget_s: float):
    """Strawman: enumerate subgraph-pair candidates between cut points by
    subset search (exponential); returns #pairs tried before the budget."""
    eq = bijective_pairs(eq_pairs)
    nodes_a = list(range(len(ga.nodes)))
    tried = 0
    t0 = time.perf_counter()
    for r in range(1, len(nodes_a) + 1):
        for comb in itertools.combinations(nodes_a, r):
            tried += 1
            if time.perf_counter() - t0 > budget_s:
                return tried, False
    return tried, True


def main() -> dict:
    results = {}
    bench = {"configs": {}}
    key = jax.random.key(0)
    x = jax.random.normal(key, (16, 32))
    w = jax.random.normal(jax.random.key(1), (32, 32)) * 0.1

    for layers in (10, 40, 80, 160):
        fn = _deep_model(layers)
        ga = trace(fn, x, w)
        gb = trace(fn, x, w)
        nodes = len(ga.nodes)

        # best-of-2 to damp shared-container timer noise (both paths equally)
        runs_e = [_run_eager(ga, gb, x, w) for _ in range(2)]
        runs_s = [_run_streaming(ga, gb, x, w) for _ in range(2)]
        t_eager, tc_eager, bytes_eager, pairs_eager, _ = \
            min(runs_e, key=lambda r: r[0])
        t_fast, tc_fast, bytes_fast, pairs_fast, regions = \
            min(runs_s, key=lambda r: r[0])
        assert set(pairs_fast) == set(pairs_eager), \
            f"fast/eager pair mismatch at {layers} layers"

        speedup = t_eager / max(t_fast, 1e-9)
        results[layers] = t_fast
        bench["configs"][str(nodes)] = {
            "layers": layers,
            "nodes": nodes,
            "match_s_streaming": t_fast,
            "match_s_eager": t_eager,
            "capture_s_streaming": tc_fast,
            "capture_s_eager": tc_eager,
            "speedup": speedup,
            "nodes_per_sec": nodes / max(t_fast, 1e-9),
            "peak_captured_bytes_streaming": bytes_fast,
            "peak_captured_bytes_eager": bytes_eager,
            "regions": len(regions),
            "pairs": len(pairs_fast),
        }
        emit(f"fig9/nodes={nodes}", t_fast * 1e6,
             f"regions={len(regions)} time={t_fast*1e3:.0f}ms "
             f"eager={t_eager*1e3:.0f}ms speedup={speedup:.1f}x "
             f"capture={bytes_fast}B-vs-{bytes_eager}B")

    # multi-sample peak memory at the deepest config: the eager pipeline
    # holds every sample's full activation set on both sides for the whole
    # match; the streaming pipeline keeps invariants only and materializes at
    # most ONE sample's phase-2 survivors at a time.
    fn = _deep_model(160)
    ga, gb = trace(fn, x, w), trace(fn, x, w)
    x2 = x * 1.1
    vals_a = [capture_tensor_values(ga, x, w),
              capture_tensor_values(ga, x2, w)]
    vals_b = [capture_tensor_values(gb, x, w),
              capture_tensor_values(gb, x2, w)]
    eager_bytes = sum(v.nbytes for side in (vals_a, vals_b)
                      for d in side for v in d.values())
    pairs_eager = TensorMatcher().match_exhaustive(vals_a, vals_b)
    m = TensorMatcher()
    stats = [[capture_tensor_stats(g, xx, w)[1] for xx in (x, x2)]
             for g in (ga, gb)]
    pairs_fast = m.match_streamed(
        stats[0], stats[1],
        lambda k, tids: capture_tensor_values(ga, x if k == 0 else x2, w,
                                              only_tids=tids),
        lambda k, tids: capture_tensor_values(gb, x if k == 0 else x2, w,
                                              only_tids=tids))
    assert set(pairs_fast) == set(pairs_eager), "multi-sample pair mismatch"
    peak = m.last_stats.peak_value_bytes
    emit("fig9/peak_capture_2samples", 0.0,
         f"streaming_peak={peak}B eager_resident={eager_bytes}B "
         f"reduction={eager_bytes / max(peak, 1):.1f}x")
    bench["peak_capture_2samples"] = {
        "streaming_peak_bytes": peak,
        "eager_resident_bytes": eager_bytes,
    }

    # quadratic-vs-exponential check: strawman on the small graph only
    fn = _deep_model(10)
    ga = trace(fn, x, w)
    va = capture_tensor_values(ga, x, w)
    pairs = TensorMatcher().match([va], [va])
    tried, finished = _brute_force(ga, ga, pairs, budget_s=2.0)
    emit("fig9/bruteforce", 2e6,
         f"subsets_tried={tried} finished={finished} "
         f"(paper strawman: timeout at 5min on Llama-3-8B)")

    # scaling ratio: 16x nodes should cost well under 256x (O(N^2) bound)
    ratio = results[160] / max(results[10], 1e-9)
    emit("fig9/summary", 0.0,
         f"time(160L)/time(10L)={ratio:.1f}x (O(N^2) bound: 256x)")
    bench["scaling_ratio_160L_over_10L"] = ratio
    emit_json("BENCH_matcher.json", bench)
    return results


if __name__ == "__main__":
    main()
