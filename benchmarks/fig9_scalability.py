"""Figure 9 analogue: matcher efficiency and scalability.

The paper matches vLLM-vs-Transformers GPT-2 graphs (757/408 nodes) in 167ms
and Llama-3-8B graphs in 1.4s while a brute-force strawman times out at 5
minutes.  We reproduce the scaling curve on synthetic deep networks of
increasing node count and run the exponential strawman with a small budget
to show the combinatorial blow-up.
"""

from __future__ import annotations

import itertools
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.graph import trace
from repro.core.interp import capture_tensor_values
from repro.core.subgraph_match import match_subgraphs
from repro.core.tensor_match import TensorMatcher, bijective_pairs


def _deep_model(layers):
    def fn(x, w):
        for i in range(layers):
            x = jnp.tanh(x @ w) + x
            x = x * 1.01
        return x.sum()
    return fn


def _brute_force(ga, gb, eq_pairs, budget_s: float):
    """Strawman: enumerate subgraph-pair candidates between cut points by
    subset search (exponential); returns #pairs tried before the budget."""
    eq = bijective_pairs(eq_pairs)
    nodes_a = list(range(len(ga.nodes)))
    tried = 0
    t0 = time.perf_counter()
    for r in range(1, len(nodes_a) + 1):
        for comb in itertools.combinations(nodes_a, r):
            tried += 1
            if time.perf_counter() - t0 > budget_s:
                return tried, False
    return tried, True


def main() -> dict:
    results = {}
    key = jax.random.key(0)
    x = jax.random.normal(key, (16, 32))
    w = jax.random.normal(jax.random.key(1), (32, 32)) * 0.1

    for layers in (10, 40, 80, 160):
        fn = _deep_model(layers)
        ga = trace(fn, x, w)
        gb = trace(fn, x, w)
        va = capture_tensor_values(ga, x, w)
        vb = capture_tensor_values(gb, x, w)
        t0 = time.perf_counter()
        pairs = TensorMatcher().match([va], [vb])
        regions = match_subgraphs(ga, gb, pairs)
        dt = time.perf_counter() - t0
        results[layers] = dt
        emit(f"fig9/nodes={len(ga.nodes)}", dt * 1e6,
             f"regions={len(regions)} time={dt*1e3:.0f}ms")

    # quadratic-vs-exponential check: strawman on the small graph only
    fn = _deep_model(10)
    ga = trace(fn, x, w)
    va = capture_tensor_values(ga, x, w)
    pairs = TensorMatcher().match([va], [va])
    tried, finished = _brute_force(ga, ga, pairs, budget_s=2.0)
    emit("fig9/bruteforce", 2e6,
         f"subsets_tried={tried} finished={finished} "
         f"(paper strawman: timeout at 5min on Llama-3-8B)")

    # scaling ratio: 16x nodes should cost well under 256x (O(N^2) bound)
    ratio = results[160] / max(results[10], 1e-9)
    emit("fig9/summary", 0.0,
         f"time(160L)/time(10L)={ratio:.1f}x (O(N^2) bound: 256x)")
    return results


if __name__ == "__main__":
    main()
