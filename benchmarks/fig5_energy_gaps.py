"""Figure 5 analogue: energy gaps between functionally-equivalent variants.

The paper compares vLLM / SGLang / HF Transformers per-token inference energy
(up to 2.97x), a conv op across PyTorch/TF/JAX (3.35x), and two image
pipelines.  On one substrate we reproduce the same phenomenon with variant
*implementations* of the same model step:

  (a) per-token serve-step energy: naive-attention+unfused-GELU decode stack
      vs flash+fused stack on a GPT-2-class model;
  (b) single-operator gap: the GELU operator, 5-op unfused vs Pallas-fused
      (paper: 77.4% operator energy reduction);
  (c) attention operator: S^2-materializing vs streaming flash.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.energy import AnalyticalEnergyModel
from repro.core.graph import trace
from repro.hw.specs import TPU_V5E


def _energy(fn, *args) -> float:
    model = AnalyticalEnergyModel(TPU_V5E)
    return model.profile(trace(fn, *args)).total_energy_j


def main() -> dict:
    key = jax.random.key(0)
    k1, k2, k3 = jax.random.split(key, 3)

    # (a) per-token "serving stack" gap: attention + GELU MLP, two builds
    B, H, S, D = 4, 12, 512, 64
    d_ff = 3072
    q = jax.random.normal(k1, (B, H, S, D))
    kk = jax.random.normal(k2, (B, H, S, D))
    v = jax.random.normal(k3, (B, H, S, D))
    w1 = jax.random.normal(k1, (H * D, d_ff)) * 0.02
    w2 = jax.random.normal(k2, (d_ff, H * D)) * 0.02

    def stack_naive(q, k, v, w1, w2):
        from repro.kernels import ref
        o = ref.attention(q, k, v, causal=True)
        h = o.transpose(0, 2, 1, 3).reshape(B, S, H * D) @ w1
        c = 0.7978845608
        h = 0.5 * h * (1.0 + jnp.tanh(c * (h + 0.044715 * h * h * h)))
        return h @ w2

    def stack_fused(q, k, v, w1, w2):
        from repro.kernels import ops
        o = ops.flash_attention(q, k, v, causal=True)
        h = o.transpose(0, 2, 1, 3).reshape(B, S, H * D) @ w1
        h = ops.fused_gelu(h)
        return h @ w2

    e_naive = _energy(stack_naive, q, kk, v, w1, w2)
    e_fused = _energy(stack_fused, q, kk, v, w1, w2)
    tokens = B * S
    emit("fig5/serve_stack_naive", 0.0,
         f"{e_naive/tokens*1e3:.4f} mJ/token")
    emit("fig5/serve_stack_fused", 0.0,
         f"{e_fused/tokens*1e3:.4f} mJ/token gap={e_naive/e_fused:.2f}x "
         f"(paper cross-system gap: up to 2.97x)")

    # (b) the GELU operator alone (paper: -77.4%)
    x = jax.random.normal(k1, (2048, 4096))

    def gelu_unfused(x):
        c = 0.7978845608
        return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))

    def gelu_fused(x):
        from repro.kernels import ops
        return ops.fused_gelu(x)

    e_u = _energy(gelu_unfused, x)
    e_f = _energy(gelu_fused, x)
    emit("fig5/gelu_op", 0.0,
         f"unfused={e_u*1e3:.3f}mJ fused={e_f*1e3:.3f}mJ "
         f"reduction={100*(1-e_f/e_u):.1f}% (paper: 77.4%)")

    # (c) prefill attention operator
    def attn_naive(q, k, v):
        from repro.kernels import ref
        return ref.attention(q, k, v, causal=True)

    def attn_flash(q, k, v):
        from repro.kernels import ops
        return ops.flash_attention(q, k, v, causal=True)

    e_n = _energy(attn_naive, q, kk, v)
    e_fl = _energy(attn_flash, q, kk, v)
    emit("fig5/prefill_attention", 0.0,
         f"naive={e_n*1e3:.3f}mJ flash={e_fl*1e3:.3f}mJ gap={e_n/e_fl:.2f}x")
    return {"stack_gap": e_naive / e_fused, "gelu_cut": 1 - e_f / e_u,
            "attn_gap": e_n / e_fl}


if __name__ == "__main__":
    main()
