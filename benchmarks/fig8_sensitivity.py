"""Figure 8 analogue: sensitivity of semantic-equivalence matching to the
comparison threshold epsilon.

Ground truth is annotated by construction: we build a GPT-2-class block pair
(split-QKV vs fused-QKV + layout permutes) where the equivalent tensor pairs
are known exactly, sweep epsilon over [1e-7, 0.2], and report F1.  The paper
finds F1 > 0.8 across 1e-4..1.8e-2 and ~1.0 in the optimal range.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.graph import trace
from repro.core.interp import capture_tensor_values
from repro.core.tensor_match import TensorMatcher

B, S, D, H = 2, 32, 64, 4
HD = D // H


def split_qkv(x, wq, wk, wv, wo):
    q = (x @ wq).reshape(B, S, H, HD)
    k = (x @ wk).reshape(B, S, H, HD)
    v = (x @ wv).reshape(B, S, H, HD)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(HD)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(B, S, D)
    return o @ wo


def fused_qkv(x, wq, wk, wv, wo):
    w = jnp.concatenate([wq, wk, wv], axis=1)
    qkv = x @ w
    q, k, v = jnp.split(qkv, 3, axis=1 + 1)
    # HND layout (the paper's HuggingFace-vs-SGLang example)
    q = q.reshape(B, S, H, HD).transpose(0, 2, 1, 3)
    k = k.reshape(B, S, H, HD).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, H, HD).transpose(0, 2, 1, 3)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(HD)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v).transpose(0, 2, 1, 3)
    return o.reshape(B, S, D) @ wo


def _ground_truth(ga, gb, va, vb):
    """True pairs: tensors whose values are equal up to layout (same sorted
    multiset of entries), computed exactly — the annotation oracle."""
    truth = set()
    for ta, xa in va.items():
        fa = np.sort(np.asarray(xa, np.float64).ravel())
        for tb, xb in vb.items():
            if np.size(xb) != fa.size or fa.size < 2:
                continue
            fb = np.sort(np.asarray(xb, np.float64).ravel())
            if np.allclose(fa, fb, rtol=1e-6, atol=1e-8):
                truth.add((ta, tb))
    return truth


def main() -> dict:
    key = jax.random.key(0)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, D))
    wq = jax.random.normal(ks[1], (D, D)) * 0.1
    wk = jax.random.normal(ks[2], (D, D)) * 0.1
    wv = jax.random.normal(ks[3], (D, D)) * 0.1
    wo = jax.random.normal(ks[4], (D, D)) * 0.1
    args = (x, wq, wk, wv, wo)

    ga = trace(split_qkv, *args)
    gb = trace(fused_qkv, *args)
    va = capture_tensor_values(ga, *args)
    vb = capture_tensor_values(gb, *args)
    truth = _ground_truth(ga, gb, va, vb)

    results = {}
    for eps in (1e-7, 1e-6, 1e-5, 1e-4, 1e-3, 5e-3, 1.8e-2, 5e-2, 0.2):
        pairs = set(TensorMatcher(rtol=eps).match([va], [vb]))
        tp = len(pairs & truth)
        fp = len(pairs - truth)
        fn = len(truth - pairs)
        prec = tp / max(tp + fp, 1)
        rec = tp / max(tp + fn, 1)
        f1 = 2 * prec * rec / max(prec + rec, 1e-12)
        results[eps] = f1
        emit(f"fig8/eps={eps:g}", 0.0,
             f"F1={f1:.3f} precision={prec:.3f} recall={rec:.3f} "
             f"(|truth|={len(truth)})")
    robust = [e for e, f1 in results.items() if 1e-4 <= e <= 1.8e-2]
    ok = all(results[e] >= 0.8 for e in robust)
    emit("fig8/summary", 0.0,
         f"F1>=0.8 across [1e-4,1.8e-2]: {ok} (paper: robust across that range)")
    return results


if __name__ == "__main__":
    main()
