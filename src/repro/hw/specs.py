"""Hardware specifications and energy coefficients.

All energy numbers are *model* coefficients, not measurements: the container
is CPU-only, so Joules are derived from a roofline-timed power model
(DESIGN.md §2).  The coefficients are chosen so that

  E_op = P_flops * t_compute + P_hbm * t_memory + P_ici * t_collective
         + P_static * t_op,       t_op = max(t_compute, t_memory, t_coll)

reproduces the public chip TDP at full utilization.  What matters for
differential energy debugging is the *relative* energy between two
implementations of the same task; the model preserves ordering because both
sides are priced by the same coefficients.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Per-chip capability + energy model for one accelerator generation."""

    name: str
    # --- capability (roofline denominators) ---
    peak_flops_bf16: float      # FLOP/s
    peak_flops_fp32: float      # FLOP/s (MXU fp32-accurate passes)
    hbm_bw: float               # bytes/s
    hbm_bytes: float            # capacity, bytes
    vmem_bytes: float           # on-chip vector memory, bytes
    ici_bw_per_link: float      # bytes/s per ICI link (one direction)
    ici_links: int              # links per chip participating in a 2D/3D torus
    dcn_bw: float               # bytes/s per chip for cross-pod (data-center net)
    # --- energy model ---
    tdp_watts: float            # package power at full load
    idle_watts: float           # static/idle floor
    compute_watts: float        # dynamic power attributable to MXU/VPU at peak
    hbm_watts: float            # dynamic power attributable to HBM at peak bw
    ici_watts: float            # dynamic power attributable to interconnect

    # Derived energy coefficients -------------------------------------------------
    @property
    def joules_per_flop(self) -> float:
        return self.compute_watts / self.peak_flops_bf16

    @property
    def joules_per_hbm_byte(self) -> float:
        return self.hbm_watts / self.hbm_bw

    @property
    def joules_per_ici_byte(self) -> float:
        return self.ici_watts / (self.ici_bw_per_link * self.ici_links)

    # Roofline times ---------------------------------------------------------------
    def compute_time(self, flops: float, *, fp32: bool = False) -> float:
        peak = self.peak_flops_fp32 if fp32 else self.peak_flops_bf16
        return flops / peak

    def memory_time(self, hbm_bytes: float) -> float:
        return hbm_bytes / self.hbm_bw

    def collective_time(self, ici_bytes: float) -> float:
        return ici_bytes / (self.ici_bw_per_link * self.ici_links)


# TPU v5e ("efficiency") — the primary target of this repro.
# 197 TFLOP/s bf16, 819 GB/s HBM, 16 GiB, ~50 GB/s/link ICI (4 links, 2D torus).
TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    peak_flops_fp32=197e12 / 3.0,   # fp32-accurate matmul ≈ 3 bf16 passes on MXU
    hbm_bw=819e9,
    hbm_bytes=16 * 1024**3,
    vmem_bytes=128 * 1024**2,
    ici_bw_per_link=50e9,
    ici_links=4,
    dcn_bw=6.25e9,                   # ~50 Gb/s effective per chip across pods
    tdp_watts=220.0,
    idle_watts=60.0,
    compute_watts=110.0,
    hbm_watts=35.0,
    ici_watts=15.0,
)

# TPU v5p ("performance") — used for what-if roofline comparisons.
TPU_V5P = HardwareSpec(
    name="tpu_v5p",
    peak_flops_bf16=459e12,
    peak_flops_fp32=459e12 / 3.0,
    hbm_bw=2765e9,
    hbm_bytes=95 * 1024**3,
    vmem_bytes=128 * 1024**2,
    ici_bw_per_link=100e9,
    ici_links=6,
    dcn_bw=6.25e9,
    tdp_watts=350.0,
    idle_watts=90.0,
    compute_watts=180.0,
    hbm_watts=55.0,
    ici_watts=25.0,
)

# The host this container actually runs on — used by the ReplayProfiler to
# convert measured wall time into model Joules so analytic and replayed
# numbers are comparable (benchmarks/bench_energy_accuracy.py).
CPU_HOST = HardwareSpec(
    name="cpu_host",
    peak_flops_bf16=5e11,
    peak_flops_fp32=2.5e11,
    hbm_bw=2.0e10,
    hbm_bytes=64 * 1024**3,
    vmem_bytes=32 * 1024**2,
    ici_bw_per_link=1e10,
    ici_links=1,
    dcn_bw=1e9,
    tdp_watts=120.0,
    idle_watts=40.0,
    compute_watts=60.0,
    hbm_watts=15.0,
    ici_watts=5.0,
)

_SPECS = {s.name: s for s in (TPU_V5E, TPU_V5P, CPU_HOST)}


def get_spec(name: str) -> HardwareSpec:
    try:
        return _SPECS[name]
    except KeyError:
        raise KeyError(f"unknown hardware spec {name!r}; have {sorted(_SPECS)}")
