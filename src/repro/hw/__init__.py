from repro.hw.specs import HardwareSpec, TPU_V5E, TPU_V5P, CPU_HOST, get_spec

__all__ = ["HardwareSpec", "TPU_V5E", "TPU_V5P", "CPU_HOST", "get_spec"]
