"""xLSTM-1.3B [ssm] — arXiv:2405.04517.

48L, d_model=2048, 4 heads (kv=4), d_ff=0 (projections live inside the
blocks), vocab=50304.  xLSTM[7:1] ratio: each period-8 superblock holds
7 mLSTM blocks (pre-up-projection, factor 2) and 1 sLSTM block (gated FFN,
factor 4/3).  Recurrent/chunked mixing is sub-quadratic -> runs long_500k.
"""

from repro.configs.base import LayerSpec, ModelConfig, register

_PATTERN = tuple([LayerSpec("mlstm", "none")] * 7 + [LayerSpec("slstm", "none")])


@register("xlstm-1.3b")
def xlstm_1_3b() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b",
        family="ssm",
        num_layers=48,
        d_model=2048,
        num_heads=4,
        num_kv_heads=4,
        head_dim=512,            # d_model / num_heads
        d_ff=0,
        vocab_size=50304,
        block_pattern=_PATTERN,
        mlstm_proj_factor=2.0,
        slstm_ffn_factor=4.0 / 3.0,
        sub_quadratic=True,
        tie_embeddings=False,
    )
