"""Phi-3-medium-14B [dense] — arXiv:2404.14219.

40L, d_model=5120, 40H (GQA kv=10), d_ff=17920, vocab=100352.
RoPE + SwiGLU + GQA.  Full attention -> long_500k skipped.
"""

from repro.configs.base import LayerSpec, ModelConfig, register


@register("phi3-medium-14b")
def phi3_medium() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b",
        family="dense",
        num_layers=40,
        d_model=5120,
        num_heads=40,
        num_kv_heads=10,
        d_ff=17920,
        vocab_size=100352,
        block_pattern=(LayerSpec("attn", "dense"),),
        rope_theta=10000.0,
    )
