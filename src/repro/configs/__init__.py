from repro.configs.base import (SHAPES, LayerSpec, ModelConfig, ShapeConfig,
                                get_config, list_archs, register,
                                supported_shapes)

__all__ = ["SHAPES", "LayerSpec", "ModelConfig", "ShapeConfig", "get_config",
           "list_archs", "register", "supported_shapes"]
