"""Llama-3.2-Vision-90B [vlm] — hf:meta-llama/Llama-3.2-90B-Vision.

100 decoder layers, d_model=8192, 64H (GQA kv=8), d_ff=28672, vocab=128256.
Every 5th layer is a gated cross-attention layer over image patch embeddings
(period-5 superblock: 4 self-attn + 1 cross-attn).  The vision tower is a
STUB per the assignment: input_specs() supplies precomputed patch embeddings
(4 tiles x 1025 patches = 4100 image tokens).  Full attention -> long_500k
skipped.
"""

from repro.configs.base import LayerSpec, ModelConfig, register

_PATTERN = tuple(
    LayerSpec("cross_attn" if i == 4 else "attn", "dense") for i in range(5)
)


@register("llama-3.2-vision-90b")
def llama_3_2_vision_90b() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        num_layers=100,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=28672,
        vocab_size=128256,
        cross_attn_period=5,
        num_image_tokens=4100,
        frontend="vision_patches",
        block_pattern=_PATTERN,
        rope_theta=500000.0,
    )
