"""Model configuration system and architecture registry.

Every assigned architecture is a ``ModelConfig`` built from its published
numbers (see the per-arch files in this package).  Layer stacks are described
by a repeating ``block_pattern`` (the *superblock*) so heterogeneous models
(jamba's 1:7 mamba:attn interleave, llama-3.2-vision's every-5th cross-attn)
scan over a fixed-period block — keeping HLO size O(1) in depth.
"""

from __future__ import annotations

import dataclasses
from typing import Callable


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer inside the repeating superblock."""

    mixer: str        # 'attn' | 'cross_attn' | 'mamba' | 'mlstm' | 'slstm'
    ffn: str          # 'dense' | 'moe' | 'none'


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads

    # --- attention variants ---
    rope_theta: float = 500000.0
    qk_norm: bool = False
    qkv_bias: bool = False
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 0         # MLA decoupled-RoPE dims
    v_head_dim: int = 0            # MLA value head dim (0 -> head_dim)
    is_causal: bool = True         # False for encoder-only (hubert)

    # --- MoE ---
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_num_shared: int = 0
    moe_d_ff: int = 0              # expert hidden dim (0 -> d_ff)
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01

    # --- SSM (mamba) ---
    ssm_state_dim: int = 16
    ssm_conv_dim: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0           # 0 -> ceil(d_model/16)
    ssm_chunk: int = 256

    # --- xLSTM ---
    mlstm_proj_factor: float = 2.0
    slstm_ffn_factor: float = 1.3333333

    # --- multimodal stubs ---
    cross_attn_period: int = 0     # vlm: every n-th layer is cross-attn
    num_image_tokens: int = 0      # patch-embedding count from the stub tower
    frontend: str = "none"         # 'none' | 'audio_frames' | 'vision_patches'

    # --- layer stack ---
    block_pattern: tuple[LayerSpec, ...] = (LayerSpec("attn", "dense"),)

    # --- numerics / misc ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    sub_quadratic: bool = False    # supports long_500k decode

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def resolved_v_head_dim(self) -> int:
        return self.v_head_dim or self.resolved_head_dim

    @property
    def resolved_moe_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def period(self) -> int:
        return len(self.block_pattern)

    @property
    def num_superblocks(self) -> int:
        assert self.num_layers % self.period == 0, \
            f"{self.name}: {self.num_layers} layers not divisible by period {self.period}"
        return self.num_layers // self.period

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    def param_count(self) -> int:
        """Total parameters (analytic; embeddings + blocks + head)."""
        from repro.models.transformer import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.transformer import count_params
        return count_params(self, active_only=True)

    def reduced(self, **overrides) -> "ModelConfig":
        """A small same-family config for CPU smoke tests."""
        small = dict(
            num_layers=self.period * 2,
            d_model=64,
            num_heads=max(4, min(self.num_heads, 4)),
            num_kv_heads=max(2, min(self.num_kv_heads, 2)),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            kv_lora_rank=32 if self.use_mla else 0,
            q_lora_rank=0,
            rope_head_dim=8 if self.use_mla else 0,
            v_head_dim=16 if self.use_mla else 0,
            moe_num_experts=min(self.moe_num_experts, 4),
            moe_top_k=min(self.moe_top_k, 2),
            moe_num_shared=min(self.moe_num_shared, 1),
            moe_d_ff=32 if self.moe_num_experts else 0,
            ssm_state_dim=8,
            ssm_chunk=16,
            num_image_tokens=8 if self.family == "vlm" else 0,
            name=self.name + "-smoke",
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    _ensure_imports()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> list[str]:
    _ensure_imports()
    return sorted(_REGISTRY)


def _ensure_imports() -> None:
    # import the per-arch modules for registration side effects
    import importlib
    for mod in ("xlstm_1_3b", "jamba_1_5_large_398b", "llama4_scout_17b_a16e",
                "deepseek_v2_236b", "qwen1_5_110b", "phi3_medium_14b",
                "qwen3_4b", "llama3_2_3b", "hubert_xlarge",
                "llama_3_2_vision_90b", "gpt2_small"):
        importlib.import_module(f"repro.configs.{mod}")


# shape sets assigned to LM-family archs -------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def supported_shapes(cfg: ModelConfig) -> list[str]:
    """Which assigned shapes are semantically valid for this arch.

    Skips (recorded in DESIGN.md §4): decode shapes for encoder-only archs;
    long_500k for pure full-attention archs (needs sub-quadratic mixing).
    """
    out = ["train_4k", "prefill_32k"]
    if cfg.is_causal:
        out.append("decode_32k")
        if cfg.sub_quadratic:
            out.append("long_500k")
    return out
