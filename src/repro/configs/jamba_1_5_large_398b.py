"""Jamba-1.5-Large (398B total / ~94B active) [hybrid] — arXiv:2403.19887.

72L, d_model=8192, 64H (GQA kv=8), d_ff=24576, vocab=65536, MoE 16e top-2.
Jamba block structure: attn:mamba ratio 1:7 (one attention layer per
period-8 superblock, placed mid-block) and MoE replacing the dense MLP on
every other layer.  Mamba mixing dominates -> sub-quadratic, runs long_500k
(the 9 attention layers decode against a sharded 512k cache).
"""

from repro.configs.base import LayerSpec, ModelConfig, register

# period-8 superblock: mamba ×3, attn, mamba ×4; MoE on odd layer indices.
_PATTERN = tuple(
    LayerSpec("attn" if i == 4 else "mamba", "moe" if i % 2 == 1 else "dense")
    for i in range(8)
)


@register("jamba-1.5-large-398b")
def jamba_1_5_large_398b() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        num_layers=72,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=24576,
        vocab_size=65536,
        block_pattern=_PATTERN,
        moe_num_experts=16,
        moe_top_k=2,
        moe_d_ff=24576,
        ssm_state_dim=16,
        ssm_conv_dim=4,
        ssm_expand=2,
        sub_quadratic=True,
    )
