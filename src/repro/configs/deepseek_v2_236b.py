"""DeepSeek-V2 (236B total / 21B active) [moe] — arXiv:2405.04434.

60L, d_model=5120, 128 heads, expert d_ff=1536, vocab=102400.
MLA: kv_lora_rank=512, q_lora_rank=1536, decoupled RoPE head dim 64,
per-head qk_nope/v dims 128.  MoE: 160 routed experts top-6 + 2 shared,
on every layer (matching the assigned d_ff=1536 expert width).
Full (latent) attention is still quadratic -> long_500k skipped; the MLA
compressed cache is what makes decode_32k cheap.
"""

from repro.configs.base import LayerSpec, ModelConfig, register


@register("deepseek-v2-236b")
def deepseek_v2() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        num_layers=60,
        d_model=5120,
        num_heads=128,
        num_kv_heads=128,        # MLA: heads share the latent cache
        head_dim=128,
        d_ff=1536,
        vocab_size=102400,
        block_pattern=(LayerSpec("attn", "moe"),),
        use_mla=True,
        kv_lora_rank=512,
        q_lora_rank=1536,
        rope_head_dim=64,
        v_head_dim=128,
        moe_num_experts=160,
        moe_top_k=6,
        moe_num_shared=2,
        moe_d_ff=1536,
        rope_theta=10000.0,
    )
