"""Qwen1.5-110B [dense] — hf:Qwen/Qwen1.5-110B family.

80L, d_model=8192, 64H (GQA kv=8), d_ff=49152, vocab=152064, QKV bias.
Full attention -> long_500k skipped.
"""

from repro.configs.base import LayerSpec, ModelConfig, register


@register("qwen1.5-110b")
def qwen1_5_110b() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b",
        family="dense",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=49152,
        vocab_size=152064,
        qkv_bias=True,
        block_pattern=(LayerSpec("attn", "dense"),),
        rope_theta=1000000.0,
    )
