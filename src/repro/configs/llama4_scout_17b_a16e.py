"""Llama-4-Scout-17B-16E [moe] — hf:meta-llama/Llama-4-Scout-17B-16E.

48L, d_model=5120, 40H (GQA kv=8), expert d_ff=8192, vocab=202048,
MoE 16 routed experts top-1 + 1 shared expert on every layer
(~17B active / ~109B total).  Full attention -> long_500k skipped.
"""

from repro.configs.base import LayerSpec, ModelConfig, register


@register("llama4-scout-17b-a16e")
def llama4_scout() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=202048,
        block_pattern=(LayerSpec("attn", "moe"),),
        moe_num_experts=16,
        moe_top_k=1,
        moe_num_shared=1,
        moe_d_ff=8192,
        rope_theta=500000.0,
    )
