"""Llama-3.2-3B [dense] — hf:meta-llama/Llama-3.2-3B.

28L, d_model=3072, 24H (GQA kv=8), d_ff=8192, vocab=128256.
Full attention -> long_500k skipped.
"""

from repro.configs.base import LayerSpec, ModelConfig, register


@register("llama3.2-3b")
def llama3_2_3b() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b",
        family="dense",
        num_layers=28,
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=128256,
        block_pattern=(LayerSpec("attn", "dense"),),
        rope_theta=500000.0,
        tie_embeddings=True,
    )
