"""HuBERT-XLarge [audio] — arXiv:2106.07447 (wav2vec2-style encoder).

48L, d_model=1280, 16H (kv=16), d_ff=5120, vocab=504 (masked-prediction
codebook targets).  Encoder-only: bidirectional attention, no KV cache ->
decode_32k / long_500k skipped.  The CNN waveform frontend is a STUB per
the assignment: input_specs() supplies precomputed frame embeddings.
"""

from repro.configs.base import LayerSpec, ModelConfig, register


@register("hubert-xlarge")
def hubert_xlarge() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge",
        family="audio",
        num_layers=48,
        d_model=1280,
        num_heads=16,
        num_kv_heads=16,
        d_ff=5120,
        vocab_size=504,
        is_causal=False,
        frontend="audio_frames",
        block_pattern=(LayerSpec("attn", "dense"),),
        rope_theta=10000.0,
    )
