"""GPT-2 small — the paper's own case-study model (§2.1, §6.4).

Not part of the assigned pool; used by the zoo/benchmarks to reproduce the
paper's HuggingFace-vs-vLLM GPT-2 experiments (matching sensitivity Fig. 8,
scalability Fig. 9, profiler accuracy Table 4).
"""

from repro.configs.base import LayerSpec, ModelConfig, register


@register("gpt2-small")
def gpt2_small() -> ModelConfig:
    return ModelConfig(
        name="gpt2-small",
        family="dense",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        d_ff=3072,
        vocab_size=50257,
        block_pattern=(LayerSpec("attn", "dense"),),
        rope_theta=10000.0,
        tie_embeddings=True,
    )
