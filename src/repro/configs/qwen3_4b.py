"""Qwen3-4B [dense] — hf:Qwen/Qwen3-4B (family config per Qwen3-8B card).

36L, d_model=2560, 32H (GQA kv=8), d_ff=9728, vocab=151936, qk_norm.
head_dim=128 (Qwen3 uses explicit 128-dim heads, not d_model/num_heads).
Full attention -> long_500k skipped.
"""

from repro.configs.base import LayerSpec, ModelConfig, register


@register("qwen3-4b")
def qwen3_4b() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b",
        family="dense",
        num_layers=36,
        d_model=2560,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=9728,
        vocab_size=151936,
        qk_norm=True,
        block_pattern=(LayerSpec("attn", "dense"),),
        rope_theta=1000000.0,
        tie_embeddings=True,
    )
