"""Magneton command-line interface.

Drives the Session/artifact API (core/session.py) from the shell::

  python -m repro.cli cases                         # list the case zoo
  python -m repro.cli capture c6-matpow:ineff       # capture one candidate
  python -m repro.cli compare c6-matpow:ineff c6-matpow:eff --json out.json
  python -m repro.cli rank c6-matpow:ineff c6-matpow:eff [SPEC ...]
  python -m repro.cli report out.json               # re-render stored JSON
  python -m repro.cli artifacts                     # list the store
  python -m repro.cli artifacts stats               # dedup / sketch coverage
  python -m repro.cli artifacts push --to file:///mnt/nfs/magneton
  python -m repro.cli artifacts pull --from http://mirror:8000
  python -m repro.cli artifacts migrate             # legacy .npz -> v3
  python -m repro.cli fleet status --store URI      # live-audit dashboard

Candidate SPECs are either zoo references ``<case-id>:<ineff|eff>``
(resolved through the registry in zoo/cases.py and captured on the case's
canonical inputs — repeated invocations hit the content-addressed store and
skip re-execution) or artifact keys / ``.npz`` paths produced by an earlier
``capture``.  The store root comes from ``--store`` (a path, ``file://``
URI, or readonly ``http(s)://`` mirror), ``$MAGNETON_STORE``, or
``~/.cache/magneton/artifacts``; ``--remote URI`` attaches a read-through
upstream so captures recorded elsewhere become local cache hits.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path

from repro.core.artifact import (ArtifactStore, ArtifactValueError,
                                 CandidateArtifact)
from repro.core.energy import backend_from_name
from repro.core.store import StoreReadOnlyError
from repro.core.report import Report
from repro.core.session import RankResult, Session
from repro.zoo import cases as zoo

_SIDES = zoo.SIDE_ALIASES


@dataclasses.dataclass
class _Resolved:
    artifact: CandidateArtifact
    output_rtol: float = 1e-2
    in_store: bool = True


def _maybe_attach_zoo(art: CandidateArtifact, session: Session
                      ) -> CandidateArtifact:
    """Re-attach a zoo-born loaded artifact to its case function so lazy
    phase-2 value fetches work (compare-by-key after a bare `capture`).

    Only when the session's backend matches the artifact's recorded one:
    re-capturing under a different backend would both ignore the stored
    pricing and pollute the store with a mismatched artifact.

    The provenance key check runs BEFORE any capture: the case is re-traced
    (cheap, no execution) and its content address compared against the
    artifact's recorded key.  A mismatch — stale provenance metadata, a
    changed case definition — returns the artifact untouched instead of
    capturing first and rejecting after, which used to leave the rejected
    re-capture orphaned in the store.
    """
    from repro.core.artifact import artifact_key
    from repro.core.graph import trace

    case_id = art.meta.get("zoo_case")
    side = art.meta.get("zoo_side")
    if (art.is_live or not case_id or side not in _SIDES
            or session.backend.id != art.backend_id):
        return art
    try:
        case = zoo.get_case(case_id)
    except KeyError:
        return art
    fn, _ = case.side(side)
    case_args = case.make_args()
    try:
        # one extra trace (capture re-traces internally on the accept path);
        # acceptable on this interactive, once-per-process CLI route — the
        # alternative is widening capture() to accept a pre-traced graph
        graph = trace(fn, *case_args, name=art.name)
    except Exception:
        return art
    if artifact_key(graph, case_args, art.sample_seeds,
                    session.backend.id) != art.key:
        return art
    return session.capture(fn, case_args, name=art.name,
                           config=art.config,
                           sample_seeds=art.sample_seeds,
                           extra_meta={"zoo_case": case_id,
                                       "zoo_side": side})


def _resolve_spec(spec: str, session: Session) -> _Resolved:
    """Resolve a candidate SPEC to an artifact (capturing zoo cases)."""
    if ":" in spec and not spec.endswith(".npz"):
        case_id, _, side = spec.rpartition(":")
        if side not in _SIDES:
            raise SystemExit(
                f"bad spec {spec!r}: side must be one of {sorted(_SIDES)}")
        try:
            case = zoo.get_case(case_id)
        except KeyError as e:
            raise SystemExit(f"error: {e.args[0]}") from None
        fn, config = case.side(side)
        art = session.capture(fn, case.make_args(),
                              name=f"{case.id}-{side}", config=config,
                              extra_meta={"zoo_case": case.id,
                                          "zoo_side": side})
        return _Resolved(art, output_rtol=case.output_rtol)
    if spec.endswith(".npz"):
        art = CandidateArtifact.load(Path(spec))
        return _Resolved(_maybe_attach_zoo(art, session), in_store=False)
    if session.store is not None and session.store.has(spec):
        art = session.store.load(spec)
        return _Resolved(_maybe_attach_zoo(art, session))
    raise SystemExit(
        f"cannot resolve {spec!r}: not a '<case>:<side>' zoo reference, "
        "an .npz path, or a key in the artifact store "
        f"({session.store.root if session.store else 'no store'})")


def _open_store(uri: str | None, remote: str | None = None,
                timeout: float | None = None) -> ArtifactStore:
    if remote and uri is not None and "://" in str(uri):
        # a URI store is itself remote-backed; silently ignoring --remote
        # would discard the user's read-through cache expectation
        raise SystemExit(
            "error: --remote needs a LOCAL --store path to cache into; "
            f"--store {uri!r} is already a remote URI")
    if uri is None:
        return (ArtifactStore(remote=remote, store_timeout=timeout)
                if remote else ArtifactStore())
    if remote:
        return ArtifactStore(uri, remote=remote, store_timeout=timeout)
    return ArtifactStore.from_uri(uri, store_timeout=timeout)


def _make_session(args) -> Session:
    return Session(backend=backend_from_name(args.backend),
                   store=_open_store(args.store,
                                     getattr(args, "remote", None),
                                     getattr(args, "store_timeout", None)),
                   num_input_samples=args.samples)


def _add_common(p: argparse.ArgumentParser) -> None:
    p.add_argument("--store", default=None,
                   help="artifact store root or URI (path, file:// or "
                        "readonly http(s):// mirror; default: "
                        "$MAGNETON_STORE or ~/.cache/magneton/artifacts)")
    p.add_argument("--remote", default=None, metavar="URI",
                   help="read-through upstream store: cache misses pull "
                        "manifests/chunks recorded elsewhere")
    p.add_argument("--store-timeout", type=float, default=None,
                   metavar="SECONDS",
                   help="connect/read deadline per http(s) store fetch "
                        "(default: $MAGNETON_STORE_TIMEOUT or 30); an "
                        "unreachable mirror fails typed instead of hanging")
    p.add_argument("--backend", default="analytic",
                   choices=("analytic", "replay", "hlo"))
    p.add_argument("--samples", type=int, default=2,
                   help="input samples per capture (Hypothesis 1 probes)")


def cmd_cases(args) -> int:
    listed = zoo.list_cases(category=args.category,
                            known=True if args.known else None)
    for c in listed:
        print(f"{c.id:24} {c.paper_id:16} {c.category:18} "
              f"{'known' if c.known else 'new':5}  {c.description}")
    print(f"{len(listed)} cases")
    return 0


def cmd_capture(args) -> int:
    session = _make_session(args)
    for spec in args.spec:
        res = _resolve_spec(spec, session)
        art = res.artifact
        hit = "cache-hit" if art.meta.get("cache_hit") else "captured"
        where = (session.store.path_for(art.key)
                 if res.in_store and session.store.has(art.key) else spec)
        print(f"{hit} {art.name}: key={art.key} nodes={len(art.graph.nodes)} "
              f"samples={art.num_samples} "
              f"energy={art.profile.total_energy_j:.4e} J -> {where}")
        if art.profile.hlo is not None:
            # attribution-quality monitoring: a rising residual fraction or
            # opaque-node count means per-op pricing is degrading
            s = art.profile.hlo.attribution_summary()
            print(f"  attribution: direct {s['direct_fraction']:.1%} of "
                  f"{s['instructions']} instrs, residual "
                  f"flops {s['residual_flop_fraction']:.2%} / "
                  f"bytes {s['residual_byte_fraction']:.2%}, "
                  f"opaque-nodes {s['opaque_nodes']}, "
                  f"fusion-splits {s['fusion_splits']}")
    return 0


def cmd_compare(args) -> int:
    session = _make_session(args)
    ra = _resolve_spec(args.spec_a, session)
    rb = _resolve_spec(args.spec_b, session)
    rtol = (args.output_rtol if args.output_rtol is not None
            else max(ra.output_rtol, rb.output_rtol))
    report = session.compare(ra.artifact, rb.artifact, output_rtol=rtol)
    print(report.render())
    if args.json:
        Path(args.json).write_text(report.to_json())
        print(f"wrote {args.json}")
    return 0 if not args.expect_waste or report.waste_findings else 1


def cmd_rank(args) -> int:
    session = _make_session(args)
    resolved = [_resolve_spec(s, session) for s in args.spec]
    if len(resolved) < 2:
        raise SystemExit("rank needs at least two candidate SPECs")
    rtol = (args.output_rtol if args.output_rtol is not None
            else max(r.output_rtol for r in resolved))
    result = session.rank([r.artifact for r in resolved], output_rtol=rtol)
    print(result.render())
    if args.json:
        Path(args.json).write_text(result.to_json())
        print(f"wrote {args.json}")
    return 0


def cmd_report(args) -> int:
    data = json.loads(Path(args.path).read_text())
    if data.get("kind") == "rank":
        print(RankResult.from_json(data).render())
    elif data.get("kind") == "patch":
        from repro.optimize import PatchReport
        print(PatchReport.from_json(data).render())
    else:
        print(Report.from_json(data).render(max_findings=args.max_findings))
    return 0


def cmd_optimize(args) -> int:
    """Propose + verify inverse rewrites for a wasteful program.

    SPEC is either ``<mutation>:<program>`` (a generated scenario: the
    clean program is mutated, compared for a diagnosis, and the mutant
    optimized — the full detect→transform→verify loop) or a zoo
    ``<case>:<side>`` reference (every rewrite is attempted; no prior
    diagnosis orients the proposal).
    """
    from repro.optimize import optimize
    from repro.testing.mutate import (MUTATIONS, InapplicableMutationError,
                                      clean_programs, make_mutant)

    session = _make_session(args)
    spec = args.spec
    if ":" not in spec:
        raise SystemExit(
            f"bad spec {spec!r}: expected '<mutation>:<program>' "
            f"(mutations: {sorted(MUTATIONS)}) or a zoo '<case>:<side>'")
    left, _, right = spec.partition(":")
    diagnosis = None
    config = None
    if left in MUTATIONS:
        progs = {p.name: p for p in clean_programs()}
        if right not in progs:
            raise SystemExit(
                f"unknown clean program {right!r}; one of {sorted(progs)}")
        prog = progs[right]
        fargs = prog.make_args()
        try:
            fn, sites = make_mutant(prog.fn, MUTATIONS[left](), fargs)
        except InapplicableMutationError as e:
            raise SystemExit(f"error: {e}") from None
        name = fn.__name__
        # diagnose first so the proposal is oriented the way a real run
        # would be: detector flags the waste, its subkind picks the rewrite
        clean_art = session.capture(prog.fn, fargs, name=prog.name)
        mut_art = session.capture(fn, fargs, name=name)
        rep = session.compare(mut_art, clean_art, output_rtol=1e-2)
        waste = [f for f in rep.waste_findings if f.wasteful_side == "A"]
        diagnosis = next(
            (f.diagnosis for f in waste
             if f.diagnosis and f.diagnosis.subkind),
            waste[0].diagnosis if waste else None)
        if diagnosis is None:
            print("note: detector found no waste region; trying every "
                  "rewrite without a diagnosis", file=sys.stderr)
    else:
        case_id, side = left, right
        if side not in _SIDES:
            raise SystemExit(
                f"bad spec {spec!r}: not a mutation in {sorted(MUTATIONS)} "
                f"and side {side!r} not in {sorted(_SIDES)}")
        try:
            case = zoo.get_case(case_id)
        except KeyError as e:
            raise SystemExit(f"error: {e.args[0]}") from None
        fn, config = case.side(side)
        fargs = case.make_args()
        name = f"{case.id}-{side}"
    patch = optimize(fn, fargs, session=session, name=name,
                     diagnosis=diagnosis,
                     rewrite_names=args.rewrite or None,
                     output_rtol=args.output_rtol, config=config)
    print(patch.render())
    if args.json:
        Path(args.json).write_text(patch.to_json())
        print(f"wrote {args.json}")
    return 0 if not args.expect_win or patch.best is not None else 1


def _parse_bytes(text: str) -> int:
    """'500K' / '10M' / '1G' / plain integer byte counts."""
    t = text.strip().upper()
    mult = {"K": 1024, "M": 1024 ** 2, "G": 1024 ** 3}.get(t[-1:], 1)
    return int(float(t[:-1] if mult > 1 else t) * mult)


def cmd_artifacts(args) -> int:
    store = _open_store(args.store,
                        timeout=getattr(args, "store_timeout", None))
    action = getattr(args, "action", None)
    if action == "prune":
        verb = "would delete" if args.dry_run else "deleted"
        if args.quarantine:
            try:
                evicted = store.prune_quarantine(
                    max_bytes=(_parse_bytes(args.max_bytes)
                               if args.max_bytes is not None else None),
                    dry_run=args.dry_run)
            except ValueError as e:
                raise SystemExit(f"error: {e}") from None
            for name in evicted:
                print(f"{verb} quarantine/{name}")
            print(f"{verb} {len(evicted)} quarantined files; quarantine now "
                  f"{store.quarantine_bytes() / 1024:.1f} KiB")
            return 0
        try:
            deleted = store.prune(
                max_bytes=(_parse_bytes(args.max_bytes)
                           if args.max_bytes is not None else None),
                keep_latest=args.keep_latest, dry_run=args.dry_run)
        except ValueError as e:
            raise SystemExit(f"error: {e}") from None
        for key in deleted:
            print(f"{verb} {key}")
        print(f"{verb} {len(deleted)} artifacts; store {store.root} now "
              f"{store.total_bytes() / 1024:.1f} KiB")
        return 0
    if action == "stats":
        s = store.stats()
        print(f"artifacts: {s['artifacts']} manifests "
              f"(+{s['legacy_npz']} legacy .npz)")
        print(f"chunks: {s['chunk_count']} "
              f"({s['chunk_bytes'] / 1024:.1f} KiB)")
        bc = s.get("block_cache", {})
        print(f"block evidence: {s.get('block_entries', 0)} block + "
              f"{s.get('profile_entries', 0)} profile + "
              f"{s.get('hlo_entries', 0)} hlo entries "
              f"({s.get('block_evidence_manifest_bytes', 0) / 1024:.1f} KiB "
              f"manifests)")
        print(f"block cache (this process): "
              f"{bc.get('block_hits', 0)} hits / "
              f"{bc.get('block_misses', 0)} misses; profile "
              f"{bc.get('profile_hits', 0)} hits / "
              f"{bc.get('profile_misses', 0)} misses")
        print(f"values: {s['values_total']} recorded, "
              f"{s['values_sketch_only']} sketch-only "
              f"({s['sketch_only_fraction']:.1%}); "
              f"{s['spectra_entries']} spectra entries")
        print(f"physical bytes: {s['physical_bytes'] / 1024:.1f} KiB; "
              f"monolithic-equivalent: "
              f"{s['monolithic_bytes'] / 1024:.1f} KiB")
        print(f"dedup ratio: {s['dedup_ratio']:.2f}x")
        if args.json:
            Path(args.json).write_text(json.dumps(s, indent=2))
            print(f"wrote {args.json}")
        return 0
    if action == "push":
        res = store.push(args.to, keys=args.key or None)
        print(f"pushed {res['manifests']} manifests to {args.to}: "
              f"{res['chunks_copied']} chunks copied "
              f"({res['bytes_copied'] / 1024:.1f} KiB), "
              f"{res['chunks_skipped']} already present")
        return 0
    if action == "pull":
        res = store.pull(getattr(args, "from"), keys=args.key or None)
        print(f"pulled {res['manifests']} manifests from "
              f"{getattr(args, 'from')}: {res['chunks_copied']} chunks "
              f"copied ({res['bytes_copied'] / 1024:.1f} KiB), "
              f"{res['chunks_skipped']} already present")
        return 0
    if action == "migrate":
        res = store.migrate(args.key or None,
                            delete_legacy=not args.keep_legacy)
        print(f"migrated {res['migrated']} legacy .npz artifacts to the "
              f"chunked v3 layout ({res['skipped']} skipped); "
              f"store {store.root} now {store.total_bytes() / 1024:.1f} KiB")
        return 0
    entries = store.entries()
    for e in entries:
        values = (f"values={e['cached_values']:4}"
                  if not e.get("sketch_only_values")
                  else f"values={e['cached_values']:4}"
                       f"+{e['sketch_only_values']}s")
        print(f"{e['key']:22} {e['name']:28} backend={e['backend']:12} "
              f"nodes={e['nodes']:5} samples={e['samples']} "
              f"{values} {e['bytes'] / 1024:.1f} KiB")
    print(f"{len(entries)} artifacts in {store.root}")
    return 0


def cmd_fleet(args) -> int:
    from repro.audit.fleet import fleet_status, render_fleet_status
    from repro.core.store import StoreError

    if args.store is None:
        raise SystemExit("error: fleet status needs --store URI "
                         "(the shared store your engines write to)")
    try:
        status = fleet_status(args.store,
                              timeout=getattr(args, "store_timeout", None))
    except StoreError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    if args.json:
        Path(args.json).write_text(json.dumps(status, indent=2,
                                              sort_keys=True))
        print(f"wrote {args.json}")
    print(render_fleet_status(status))
    if args.fail_on_alarm and status["total_alarms"]:
        return 1
    return 0


def _baseline_cases(names) -> list:
    if not names:
        return zoo.list_cases()
    try:
        return [zoo.get_case(n) for n in names]
    except KeyError as e:
        raise SystemExit(f"error: {e.args[0]}") from None


def cmd_baseline(args) -> int:
    from repro.testing.baselines import (DEFAULT_ENERGY_RTOL, BaselineError,
                                         BaselineStore)

    session = Session(backend=backend_from_name(args.backend),
                      num_input_samples=args.samples)
    artifact_store = (ArtifactStore.from_uri(
        args.store, store_timeout=getattr(args, "store_timeout", None))
        if args.store is not None else None)
    store = BaselineStore(
        args.dir, session=session, artifact_store=artifact_store,
        sketch_only=not getattr(args, "full_values", False))
    cases = _baseline_cases(args.case)
    if args.action == "record":
        rtol = (args.energy_rtol if args.energy_rtol is not None
                else DEFAULT_ENERGY_RTOL)
        for case in cases:
            res = store.record(case, energy_rtol=rtol)
            b = res.baseline
            kinds = sorted({w.kind for w in b.waste if w.kind}) or ["-"]
            print(f"recorded {case.id}: detected={b.detected} "
                  f"waste={len(b.waste)} kind={','.join(kinds)} "
                  f"E_A={b.total_energy_a_j:.4e} J "
                  f"E_B={b.total_energy_b_j:.4e} J")
        print(f"{len(cases)} baselines -> {store.root}")
        return 0
    # check: always visit every case — one missing/corrupt golden must not
    # mask the drift status of the cases after it
    drifted = errors = 0
    for case in cases:
        try:
            drifts = store.check(case, offline=args.offline)
        except BaselineError as e:
            errors += 1
            print(f"ERROR {case.id}: {e}", file=sys.stderr)
            continue
        except Exception as e:                # corrupt JSON/.npz and the like
            errors += 1
            print(f"ERROR {case.id}: {type(e).__name__}: {e}",
                  file=sys.stderr)
            continue
        if drifts:
            drifted += 1
            print(f"DRIFT {case.id}: {len(drifts)} fields")
            for d in drifts:
                print(f"    {d}")
        else:
            print(f"ok    {case.id}")
    mode = "offline replay" if args.offline else "live"
    print(f"baseline check ({mode}): "
          f"{len(cases) - drifted - errors}/{len(cases)} cases clean")
    return 2 if errors else (1 if drifted else 0)


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.cli",
        description="Magneton differential energy debugging CLI")
    sub = p.add_subparsers(dest="cmd", required=True)

    pc = sub.add_parser("cases", help="list the energy-waste case zoo")
    pc.add_argument("--category", default=None)
    pc.add_argument("--known", action="store_true",
                    help="only Table-1 (known) cases")
    pc.set_defaults(fn=cmd_cases)

    pcap = sub.add_parser("capture",
                          help="capture candidate artifacts into the store")
    pcap.add_argument("spec", nargs="+", metavar="SPEC")
    _add_common(pcap)
    pcap.set_defaults(fn=cmd_capture)

    pcm = sub.add_parser("compare", help="compare two candidate artifacts")
    pcm.add_argument("spec_a", metavar="SPEC_A")
    pcm.add_argument("spec_b", metavar="SPEC_B")
    pcm.add_argument("--json", default=None, help="also write Report JSON")
    pcm.add_argument("--output-rtol", type=float, default=None)
    pcm.add_argument("--expect-waste", action="store_true",
                     help="exit 1 if no energy-waste region is found")
    _add_common(pcm)
    pcm.set_defaults(fn=cmd_compare)

    pr = sub.add_parser("rank", help="N-way differential ranking")
    pr.add_argument("spec", nargs="+", metavar="SPEC")
    pr.add_argument("--json", default=None, help="also write RankResult JSON")
    pr.add_argument("--output-rtol", type=float, default=None)
    _add_common(pr)
    pr.set_defaults(fn=cmd_rank)

    prp = sub.add_parser("report",
                         help="re-render a stored compare/rank/patch JSON")
    prp.add_argument("path")
    prp.add_argument("--max-findings", type=int, default=10)
    prp.set_defaults(fn=cmd_report)

    po = sub.add_parser(
        "optimize",
        help="propose + verify inverse rewrites for a wasteful program")
    po.add_argument("spec", metavar="SPEC",
                    help="'<mutation>:<program>' scenario (diagnose the "
                         "mutant, then optimize it) or a zoo "
                         "'<case>:<side>' reference (try every rewrite)")
    po.add_argument("--rewrite", action="append", default=None,
                    metavar="NAME",
                    help="only attempt these rewrites (repeatable; "
                         "default: diagnosed subkind first, rest ride "
                         "along)")
    po.add_argument("--json", default=None, help="also write PatchReport "
                                                 "JSON")
    po.add_argument("--output-rtol", type=float, default=None,
                    help="override the per-rewrite functional-equivalence "
                         "tolerance")
    po.add_argument("--expect-win", action="store_true",
                    help="exit 1 unless some candidate verified strictly "
                         "cheaper")
    _add_common(po)
    po.set_defaults(fn=cmd_optimize)

    pa = sub.add_parser("artifacts",
                        help="list, GC, transfer or migrate the store")
    pa.add_argument("--store", default=None)
    pa.add_argument("--store-timeout", type=float, default=None,
                    metavar="SECONDS")
    pa.set_defaults(fn=cmd_artifacts, action=None)
    pasub = pa.add_subparsers(dest="action")

    def _store_sub(name: str, help_: str) -> argparse.ArgumentParser:
        px = pasub.add_parser(name, help=help_)
        # SUPPRESS: when --store is not given after the action, the
        # subparser must not plant its own default over a value parsed at
        # the `artifacts` level (`artifacts --store X prune` would
        # otherwise act on the DEFAULT store)
        px.add_argument("--store", default=argparse.SUPPRESS)
        px.set_defaults(fn=cmd_artifacts)
        return px

    pap = _store_sub("prune", "GC the store, oldest first (refcount-aware)")
    pap.add_argument("--max-bytes", default=None, metavar="N[K|M|G]",
                     help="prune oldest artifacts until the store fits "
                          "(with --quarantine: until the quarantine fits)")
    pap.add_argument("--keep-latest", type=int, default=0,
                     help="never prune the N most recent artifacts")
    pap.add_argument("--quarantine", action="store_true",
                     help="prune the corruption-quarantine directory "
                          "instead of the artifact store (oldest first; "
                          "no --max-bytes empties it)")
    pap.add_argument("--dry-run", action="store_true")

    pas = _store_sub("stats", "dedup / sketch-only accounting")
    pas.add_argument("--json", default=None, help="also write stats JSON")

    papu = _store_sub("push", "copy manifests + missing chunks to a mirror")
    papu.add_argument("--to", required=True, metavar="URI",
                      help="destination store (path or file:// URI)")
    papu.add_argument("key", nargs="*", metavar="KEY",
                      help="keys to push (default: everything)")

    papl = _store_sub("pull", "fetch manifests + missing chunks from a store")
    papl.add_argument("--from", required=True, metavar="URI", dest="from",
                      help="source store (path, file:// or http(s):// URI)")
    papl.add_argument("key", nargs="*", metavar="KEY",
                      help="keys to pull (default: everything)")

    pam = _store_sub("migrate",
                     "convert legacy .npz entries to the chunked v3 layout")
    pam.add_argument("--keep-legacy", action="store_true",
                     help="leave the source .npz files in place")
    pam.add_argument("key", nargs="*", metavar="KEY",
                     help="keys to migrate (default: every legacy entry)")

    pf = sub.add_parser(
        "fleet", help="cross-engine audit dashboard over a shared store")
    pfsub = pf.add_subparsers(dest="action", required=True)
    pfs = pfsub.add_parser(
        "status", help="per-class energy trend, drift alarms, sample counts "
                       "and degradation rungs across engines")
    pfs.add_argument("--store", default=None, metavar="URI",
                     help="the shared fleet store (path, file:// or "
                          "http(s):// URI) engines write audit state to")
    pfs.add_argument("--store-timeout", type=float, default=None,
                     metavar="SECONDS")
    pfs.add_argument("--json", default=None,
                     help="also write the aggregated status JSON")
    pfs.add_argument("--fail-on-alarm", action="store_true",
                     help="exit 1 when any engine reports a drift alarm")
    pfs.set_defaults(fn=cmd_fleet)

    pb = sub.add_parser(
        "baseline", help="golden energy baselines: record / check drift")
    pbsub = pb.add_subparsers(dest="action", required=True)
    for action in ("record", "check"):
        px = pbsub.add_parser(action)
        px.add_argument("case", nargs="*", metavar="CASE",
                        help="zoo case ids (default: every registered case)")
        px.add_argument("--dir", default="tests/baselines",
                        help="baseline root (JSON expectations + index.json; "
                             "golden artifacts default to <dir>/store)")
        px.add_argument("--store", default=None, metavar="URI",
                        help="golden artifact store override: a path, a "
                             "file:// NFS mirror, or a readonly http(s):// "
                             "mirror for offline checks")
        px.add_argument("--store-timeout", type=float, default=None,
                        metavar="SECONDS",
                        help="connect/read deadline per http(s) store fetch "
                             "(default: $MAGNETON_STORE_TIMEOUT or 30)")
        px.add_argument("--backend", default="analytic",
                        choices=("analytic", "replay", "hlo"))
        px.add_argument("--samples", type=int, default=2,
                        help="input samples per capture (Hypothesis 1 probes)")
        px.set_defaults(fn=cmd_baseline)
    pbsub.choices["record"].add_argument(
        "--energy-rtol", type=float, default=None,
        help="declared tolerance for the recorded energy fields")
    pbsub.choices["record"].add_argument(
        "--full-values", action="store_true",
        help="persist raw value chunks too (default: sketch-only manifests "
             "— digests + spectra replay every recorded match)")
    pbsub.choices["check"].add_argument(
        "--offline", action="store_true",
        help="replay from golden artifacts only; no instrumented execution")
    return p


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    try:
        return args.fn(args)
    except BrokenPipeError:      # e.g. `... | head` closed stdout
        return 0
    except ArtifactValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    except ValueError as e:
        # predictable user errors from compare/rank (backend or sample-seed
        # mismatch, not-the-same-task gate) — message, not a traceback
        print(f"error: {e}", file=sys.stderr)
        return 2
    except (PermissionError, StoreReadOnlyError) as e:
        # writes against a readonly (http mirror) store
        print(f"error: {e}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    sys.exit(main())
