"""Magneton command-line interface.

Drives the Session/artifact API (core/session.py) from the shell::

  python -m repro.cli cases                         # list the case zoo
  python -m repro.cli capture c6-matpow:ineff       # capture one candidate
  python -m repro.cli compare c6-matpow:ineff c6-matpow:eff --json out.json
  python -m repro.cli rank c6-matpow:ineff c6-matpow:eff [SPEC ...]
  python -m repro.cli report out.json               # re-render stored JSON
  python -m repro.cli artifacts                     # list the store

Candidate SPECs are either zoo references ``<case-id>:<ineff|eff>``
(resolved through the registry in zoo/cases.py and captured on the case's
canonical inputs — repeated invocations hit the content-addressed store and
skip re-execution) or artifact keys / ``.npz`` paths produced by an earlier
``capture``.  The store root comes from ``--store``, ``$MAGNETON_STORE``, or
``~/.cache/magneton/artifacts``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path

from repro.core.artifact import (ArtifactStore, ArtifactValueError,
                                 CandidateArtifact)
from repro.core.energy import backend_from_name
from repro.core.report import Report
from repro.core.session import RankResult, Session
from repro.zoo import cases as zoo

_SIDES = {"ineff": "inefficient", "inefficient": "inefficient",
          "a": "inefficient",
          "eff": "efficient", "efficient": "efficient", "b": "efficient"}


@dataclasses.dataclass
class _Resolved:
    artifact: CandidateArtifact
    output_rtol: float = 1e-2
    in_store: bool = True


def _maybe_attach_zoo(art: CandidateArtifact, session: Session
                      ) -> CandidateArtifact:
    """Re-attach a zoo-born loaded artifact to its case function so lazy
    phase-2 value fetches work (compare-by-key after a bare `capture`).

    Only when the session's backend matches the artifact's recorded one:
    re-capturing under a different backend would both ignore the stored
    pricing and pollute the store with a mismatched artifact.
    """
    case_id = art.meta.get("zoo_case")
    side = art.meta.get("zoo_side")
    if (art.is_live or not case_id or side not in _SIDES
            or session.backend.id != art.backend_id):
        return art
    try:
        case = zoo.get_case(case_id)
    except KeyError:
        return art
    fn = getattr(case, _SIDES[side])
    fresh = session.capture(fn, case.make_args(), name=art.name,
                            config=art.config,
                            sample_seeds=art.sample_seeds,
                            extra_meta={"zoo_case": case_id,
                                        "zoo_side": side})
    return fresh if fresh.key == art.key else art


def _resolve_spec(spec: str, session: Session) -> _Resolved:
    """Resolve a candidate SPEC to an artifact (capturing zoo cases)."""
    if ":" in spec and not spec.endswith(".npz"):
        case_id, _, side = spec.rpartition(":")
        if side not in _SIDES:
            raise SystemExit(
                f"bad spec {spec!r}: side must be one of {sorted(_SIDES)}")
        try:
            case = zoo.get_case(case_id)
        except KeyError as e:
            raise SystemExit(f"error: {e.args[0]}") from None
        fn = getattr(case, _SIDES[side])
        config = case.config_a if _SIDES[side] == "inefficient" else case.config_b
        art = session.capture(fn, case.make_args(),
                              name=f"{case.id}-{side}", config=config,
                              extra_meta={"zoo_case": case.id,
                                          "zoo_side": side})
        return _Resolved(art, output_rtol=case.output_rtol)
    if spec.endswith(".npz"):
        art = CandidateArtifact.load(Path(spec))
        return _Resolved(_maybe_attach_zoo(art, session), in_store=False)
    if session.store is not None and session.store.has(spec):
        art = session.store.load(spec)
        return _Resolved(_maybe_attach_zoo(art, session))
    raise SystemExit(
        f"cannot resolve {spec!r}: not a '<case>:<side>' zoo reference, "
        "an .npz path, or a key in the artifact store "
        f"({session.store.root if session.store else 'no store'})")


def _make_session(args) -> Session:
    return Session(backend=backend_from_name(args.backend),
                   store=ArtifactStore(args.store) if args.store
                   else ArtifactStore(),
                   num_input_samples=args.samples)


def _add_common(p: argparse.ArgumentParser) -> None:
    p.add_argument("--store", default=None,
                   help="artifact store root (default: $MAGNETON_STORE or "
                        "~/.cache/magneton/artifacts)")
    p.add_argument("--backend", default="analytic",
                   choices=("analytic", "replay", "hlo"))
    p.add_argument("--samples", type=int, default=2,
                   help="input samples per capture (Hypothesis 1 probes)")


def cmd_cases(args) -> int:
    listed = zoo.list_cases(category=args.category,
                            known=True if args.known else None)
    for c in listed:
        print(f"{c.id:24} {c.paper_id:16} {c.category:18} "
              f"{'known' if c.known else 'new':5}  {c.description}")
    print(f"{len(listed)} cases")
    return 0


def cmd_capture(args) -> int:
    session = _make_session(args)
    for spec in args.spec:
        res = _resolve_spec(spec, session)
        art = res.artifact
        hit = "cache-hit" if art.meta.get("cache_hit") else "captured"
        where = (session.store.path_for(art.key)
                 if res.in_store and session.store.has(art.key) else spec)
        print(f"{hit} {art.name}: key={art.key} nodes={len(art.graph.nodes)} "
              f"samples={art.num_samples} "
              f"energy={art.profile.total_energy_j:.4e} J -> {where}")
    return 0


def cmd_compare(args) -> int:
    session = _make_session(args)
    ra = _resolve_spec(args.spec_a, session)
    rb = _resolve_spec(args.spec_b, session)
    rtol = (args.output_rtol if args.output_rtol is not None
            else max(ra.output_rtol, rb.output_rtol))
    report = session.compare(ra.artifact, rb.artifact, output_rtol=rtol)
    print(report.render())
    if args.json:
        Path(args.json).write_text(report.to_json())
        print(f"wrote {args.json}")
    return 0 if not args.expect_waste or report.waste_findings else 1


def cmd_rank(args) -> int:
    session = _make_session(args)
    resolved = [_resolve_spec(s, session) for s in args.spec]
    if len(resolved) < 2:
        raise SystemExit("rank needs at least two candidate SPECs")
    rtol = (args.output_rtol if args.output_rtol is not None
            else max(r.output_rtol for r in resolved))
    result = session.rank([r.artifact for r in resolved], output_rtol=rtol)
    print(result.render())
    if args.json:
        Path(args.json).write_text(result.to_json())
        print(f"wrote {args.json}")
    return 0


def cmd_report(args) -> int:
    data = json.loads(Path(args.path).read_text())
    if data.get("kind") == "rank":
        print(RankResult.from_json(data).render())
    else:
        print(Report.from_json(data).render(max_findings=args.max_findings))
    return 0


def cmd_artifacts(args) -> int:
    store = ArtifactStore(args.store) if args.store else ArtifactStore()
    entries = store.entries()
    for e in entries:
        print(f"{e['key']:22} {e['name']:28} backend={e['backend']:12} "
              f"nodes={e['nodes']:5} samples={e['samples']} "
              f"values={e['cached_values']:4} {e['bytes'] / 1024:.1f} KiB")
    print(f"{len(entries)} artifacts in {store.root}")
    return 0


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.cli",
        description="Magneton differential energy debugging CLI")
    sub = p.add_subparsers(dest="cmd", required=True)

    pc = sub.add_parser("cases", help="list the energy-waste case zoo")
    pc.add_argument("--category", default=None)
    pc.add_argument("--known", action="store_true",
                    help="only Table-1 (known) cases")
    pc.set_defaults(fn=cmd_cases)

    pcap = sub.add_parser("capture",
                          help="capture candidate artifacts into the store")
    pcap.add_argument("spec", nargs="+", metavar="SPEC")
    _add_common(pcap)
    pcap.set_defaults(fn=cmd_capture)

    pcm = sub.add_parser("compare", help="compare two candidate artifacts")
    pcm.add_argument("spec_a", metavar="SPEC_A")
    pcm.add_argument("spec_b", metavar="SPEC_B")
    pcm.add_argument("--json", default=None, help="also write Report JSON")
    pcm.add_argument("--output-rtol", type=float, default=None)
    pcm.add_argument("--expect-waste", action="store_true",
                     help="exit 1 if no energy-waste region is found")
    _add_common(pcm)
    pcm.set_defaults(fn=cmd_compare)

    pr = sub.add_parser("rank", help="N-way differential ranking")
    pr.add_argument("spec", nargs="+", metavar="SPEC")
    pr.add_argument("--json", default=None, help="also write RankResult JSON")
    pr.add_argument("--output-rtol", type=float, default=None)
    _add_common(pr)
    pr.set_defaults(fn=cmd_rank)

    prp = sub.add_parser("report",
                         help="re-render a stored compare/rank JSON")
    prp.add_argument("path")
    prp.add_argument("--max-findings", type=int, default=10)
    prp.set_defaults(fn=cmd_report)

    pa = sub.add_parser("artifacts", help="list the artifact store")
    pa.add_argument("--store", default=None)
    pa.set_defaults(fn=cmd_artifacts)
    return p


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    try:
        return args.fn(args)
    except BrokenPipeError:      # e.g. `... | head` closed stdout
        return 0
    except ArtifactValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    sys.exit(main())
