"""The paper's energy-waste case catalog, adapted to JAX/TPU (DESIGN.md §6).

Each case is a pair of JAX callables computing the same function — the
inefficient twin reproduces the reported waste pattern, the efficient twin is
the developer fix.  The differential debugger (core/diff.py) must detect the
wasteful region and diagnose its root cause; benchmarks/table2_detection.py
replays the paper's Table 2 over this catalog.

Input sizes are chosen so every case runs in seconds on the CPU container
while keeping the energy asymmetry structurally forced (the Δ sign on TPU is
determined by FLOP/byte counts, not wall-clock noise).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

_KEY = jax.random.key(1234)


def _keys(n: int) -> list[jax.Array]:
    return list(jax.random.split(_KEY, n))


# Accepted spellings for the two sides of a case (CLI specs, artifact
# provenance metadata, baseline tooling) -> canonical attribute name.
SIDE_ALIASES: Mapping[str, str] = {
    "ineff": "inefficient", "inefficient": "inefficient", "a": "inefficient",
    "eff": "efficient", "efficient": "efficient", "b": "efficient",
}


@dataclasses.dataclass(frozen=True)
class Case:
    id: str                       # our id
    paper_id: str                 # the paper's Table 1/3 id
    category: str                 # misconfiguration | api_misuse | redundant
    description: str
    inefficient: Callable
    efficient: Callable
    make_args: Callable[[], tuple]
    config_a: Mapping[str, Any] | None = None   # config snapshot, wasteful side
    config_b: Mapping[str, Any] | None = None
    expect_detect: bool = True    # c11 is the documented miss (CPU-side waste)
    known: bool = True            # Table 1 (known) vs Table 3 (new)
    output_rtol: float = 1e-2
    match_rtol: float = 1e-3
    notes: str = ""

    def side(self, which: str) -> tuple[Callable, Mapping[str, Any] | None]:
        """``(fn, config)`` for one side, accepting any SIDE_ALIASES
        spelling (``ineff``/``a``/``efficient``/...)."""
        canon = SIDE_ALIASES.get(which)
        if canon is None:
            raise KeyError(f"unknown case side {which!r}; expected one of "
                           f"{sorted(SIDE_ALIASES)}")
        fn = getattr(self, canon)
        cfg = self.config_a if canon == "inefficient" else self.config_b
        return fn, cfg


# Registry: case id -> Case, insertion-ordered.  ``CASES`` is kept as the
# live list view for back-compat (existing callers iterate it directly).
_REGISTRY: dict[str, Case] = {}
CASES: list[Case] = []


def register_case(case):
    """Register a :class:`Case` in the zoo registry.

    Usable directly (``register_case(Case(...))``) or as a decorator over a
    zero-argument factory returning a Case::

        @register_case
        def _my_case() -> Case:
            return Case(id="c99-...", ...)

    The CLI (``python -m repro.cli cases``) and the Table-2 harness iterate
    :func:`list_cases` instead of hand-maintained lists, so registering here
    is all it takes to make a new case addressable everywhere.
    """
    made = case() if callable(case) and not isinstance(case, Case) else case
    if not isinstance(made, Case):
        raise TypeError(f"register_case expects a Case or a zero-arg factory "
                        f"returning one, got {type(made).__name__}")
    if made.id in _REGISTRY:
        raise ValueError(f"duplicate case id {made.id!r}")
    _REGISTRY[made.id] = made
    CASES.append(made)
    return case


def list_cases(*, category: str | None = None,
               known: bool | None = None) -> list[Case]:
    """All registered cases, optionally filtered by category / known flag."""
    out = list(_REGISTRY.values())
    if category is not None:
        out = [c for c in out if c.category == category]
    if known is not None:
        out = [c for c in out if c.known == known]
    return out


def get_case(name: str) -> Case:
    """Look up a case by our id or the paper's issue id."""
    c = _REGISTRY.get(name)
    if c is not None:
        return c
    for c in _REGISTRY.values():
        if c.paper_id == name:
            return c
    raise KeyError(f"unknown case {name!r}; known ids: "
                   f"{', '.join(sorted(_REGISTRY))}")


def _case(**kw):
    register_case(Case(**kw))


# ===========================================================================
# c1 / c8 — misconfiguration: matmul precision (tensor cores / TF32 analogue)
# TPU adaptation: precision=HIGHEST forces a 3-pass bf16-emulated fp32 matmul
# on the MXU; DEFAULT uses the native single-pass mode.  Same API, one flag.
# ===========================================================================

def _mk_matmul_args():
    k1, k2 = _keys(2)
    x = jax.random.normal(k1, (256, 512), jnp.bfloat16)
    w = jax.random.normal(k2, (512, 512), jnp.bfloat16)
    return x, w


def _matmul_highest(x, w):
    return jax.lax.dot(x, w, precision=jax.lax.Precision.HIGHEST)


def _matmul_default(x, w):
    return jax.lax.dot(x, w, precision=jax.lax.Precision.DEFAULT)


_case(id="c1-precision-prefill", paper_id="vllm-9471",
      category="misconfiguration",
      description="Prefill matmul runs with MXU fast path disabled "
                  "(precision=HIGHEST => 3-pass bf16 emulation).",
      inefficient=_matmul_highest, efficient=_matmul_default,
      make_args=_mk_matmul_args,
      config_a={"matmul_precision": "HIGHEST"},
      config_b={"matmul_precision": "DEFAULT"},
      output_rtol=3e-2,
      notes="c8/sd-279 is the same root cause at the application layer.")

_case(id="c8-tf32-linear", paper_id="sd-279", category="misconfiguration",
      description="Linear layers fail to use the energy-efficient MXU mode "
                  "(allow_tf32 analogue: precision flag).",
      inefficient=_matmul_highest, efficient=_matmul_default,
      make_args=_mk_matmul_args,
      config_a={"allow_fast_matmul": False},
      config_b={"allow_fast_matmul": True},
      output_rtol=3e-2)


# ===========================================================================
# c2 — redundant: decode-attention cache update via full copy
# ===========================================================================

_C2_LEN = 1024


def _mk_cache_args():
    k1, k2 = _keys(2)
    cache = jax.random.normal(k1, (4, _C2_LEN, 8, 64), jnp.bfloat16)
    new = jax.random.normal(k2, (4, 1, 8, 64), jnp.bfloat16)
    return cache, new


def _cache_update_copy(cache, new):
    # copies the whole cache through HBM to append one token
    pos = _C2_LEN // 2
    return jnp.concatenate(
        [cache[:, :pos], new, cache[:, pos + 1:]], axis=1)


def _cache_update_inplace(cache, new):
    pos = _C2_LEN // 2
    return jax.lax.dynamic_update_slice_in_dim(cache, new, pos, axis=1)


_case(id="c2-cache-copy", paper_id="vllm-10811", category="redundant",
      description="Decode attention appends to the KV cache via whole-cache "
                  "copy instead of an in-place slice update.",
      inefficient=_cache_update_copy, efficient=_cache_update_inplace,
      make_args=_mk_cache_args)


# ===========================================================================
# c3 — API misuse: top-k via full sort
# ===========================================================================

def _mk_topk_args():
    (k1,) = _keys(1)
    return (jax.random.normal(k1, (64, 32000), jnp.float32),)


def _topk_sort(logits):
    # two full O(V log V) passes (values + indices), like the reported issue;
    # outputs compared on values (index tie-breaks are implementation-defined)
    vals = jnp.sort(logits, axis=-1)[:, -8:]
    idx = jnp.argsort(logits, axis=-1)[:, -8:]
    return vals[:, ::-1] + 0.0 * idx.astype(logits.dtype)


def _topk_lax(logits):
    v, _ = jax.lax.top_k(logits, 8)
    return v


_case(id="c3-topk-sort", paper_id="sglang-5128", category="api_misuse",
      description="Sampler top-k implemented with two full O(V log V) sorts "
                  "instead of lax.top_k.",
      inefficient=_topk_sort, efficient=_topk_lax, make_args=_mk_topk_args,
      match_rtol=1e-5)


# ===========================================================================
# c4 — redundant: GQA repeat_interleave materialization
# ===========================================================================

def _mk_gqa_args():
    # 16x head-group ratio (H=32, KV=2), short sequence: the repeated K/V
    # materialization dominates HBM traffic, as in the Megatron report.
    k1, k2, k3 = _keys(3)
    q = jax.random.normal(k1, (2, 32, 128, 64), jnp.float32)   # (B,H,S,D)
    k = jax.random.normal(k2, (2, 2, 128, 64), jnp.float32)    # (B,KV,S,D)
    v = jax.random.normal(k3, (2, 2, 128, 64), jnp.float32)
    return q, k, v


def _gqa_repeat(q, k, v):
    g = q.shape[1] // k.shape[1]
    k = jnp.repeat(k, g, axis=1)          # materializes H-sized K/V in HBM
    v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhtd->bhqt", q, k) / np.sqrt(q.shape[-1])
    return jnp.einsum("bhqt,bhtd->bhqd", jax.nn.softmax(s, -1), v)


def _gqa_grouped(q, k, v):
    B, H, S, D = q.shape
    KV = k.shape[1]
    qg = q.reshape(B, KV, H // KV, S, D)
    s = jnp.einsum("bkgqd,bktd->bkgqt", qg, k) / np.sqrt(D)
    o = jnp.einsum("bkgqt,bktd->bkgqd", jax.nn.softmax(s, -1), v)
    return o.reshape(B, H, S, D)


_case(id="c4-gqa-repeat", paper_id="megatron-543", category="redundant",
      description="GQA K/V heads materialized with repeat_interleave instead "
                  "of group-broadcast einsum.",
      inefficient=_gqa_repeat, efficient=_gqa_grouped, make_args=_mk_gqa_args)


# ===========================================================================
# c5 — misconfiguration: layout transformations around attention
# ===========================================================================

def _mk_layout_args():
    k1, k2 = _keys(2)
    x = jax.random.normal(k1, (4, 512, 16, 64), jnp.float32)   # (B,S,H,D)
    w = jax.random.normal(k2, (16 * 64, 1024), jnp.float32)
    return x, w


def _layout_thrash(x, w):
    # HND storage forces transposes before and after the projection
    xt = jnp.transpose(x, (0, 2, 1, 3))                # to (B,H,S,D)
    xt = jnp.transpose(xt, (0, 2, 1, 3))               # back to (B,S,H,D)
    flat = xt.reshape(x.shape[0], x.shape[1], -1)
    return jnp.einsum("bsf,fo->bso", flat, w)


def _layout_clean(x, w):
    flat = x.reshape(x.shape[0], x.shape[1], -1)
    return jnp.einsum("bsf,fo->bso", flat, w)


_case(id="c5-layout", paper_id="hf-14450", category="misconfiguration",
      description="Default tensor format triggers energy-intensive layout "
                  "transformations (transpose round-trip) around attention.",
      inefficient=_layout_thrash, efficient=_layout_clean,
      make_args=_mk_layout_args)


# ===========================================================================
# c6 — API misuse: algorithm selection (matrix power)
# ===========================================================================

def _mk_matpow_args():
    (k1,) = _keys(1)
    a = jax.random.normal(k1, (256, 256), jnp.float32) / 16.0
    return (a,)


def _matpow_naive(a):
    out = a
    for _ in range(7):          # a^8 with 7 multiplies
        out = out @ a
    return out


def _matpow_binary(a):
    a2 = a @ a
    a4 = a2 @ a2
    return a4 @ a4              # 3 multiplies


_case(id="c6-matpow", paper_id="hf-34570", category="api_misuse",
      description="Repeated-multiplication matrix power instead of binary "
                  "exponentiation (kernel/algorithm selection class).",
      inefficient=_matpow_naive, efficient=_matpow_binary,
      make_args=_mk_matpow_args, output_rtol=5e-2, match_rtol=1e-2)


# ===========================================================================
# c7 — API misuse: unnecessary concat/split round-trip
# ===========================================================================

def _mk_qkv_args():
    k1, k2, k3, k4 = _keys(4)
    x = jax.random.normal(k1, (8, 512, 768), jnp.float32)
    wq = jax.random.normal(k2, (768, 768), jnp.float32) * 0.02
    wk = jax.random.normal(k3, (768, 768), jnp.float32) * 0.02
    wv = jax.random.normal(k4, (768, 768), jnp.float32) * 0.02
    return x, wq, wk, wv


def _qkv_concat_split(x, wq, wk, wv):
    w = jnp.concatenate([wq, wk, wv], axis=1)          # extra HBM writes
    qkv = jnp.einsum("bsd,df->bsf", x, w)
    q, k, v = jnp.split(qkv, 3, axis=-1)               # extra HBM reads
    return q + k + v


def _qkv_direct(x, wq, wk, wv):
    q = jnp.einsum("bsd,df->bsf", x, wq)
    k = jnp.einsum("bsd,df->bsf", x, wk)
    v = jnp.einsum("bsd,df->bsf", x, wv)
    return q + k + v


_case(id="c7-concat-split", paper_id="diffusers-12131", category="api_misuse",
      description="QKV projection concat->matmul->split round-trip pays "
                  "extra memory-access energy vs direct projections.",
      inefficient=_qkv_concat_split, efficient=_qkv_direct,
      make_args=_mk_qkv_args)


# ===========================================================================
# c9 — redundant: per-microbatch gradient all-reduce (dist.Join analogue)
# ===========================================================================

_C9_MB = 8


def _mk_grad_args():
    k1, k2 = _keys(2)
    grads = jax.random.normal(k1, (_C9_MB, 64, 1024), jnp.float32)
    w = jax.random.normal(k2, (1024, 1024), jnp.float32) * 0.02
    return grads, w


def _psum_per_microbatch(grads, w):
    def body(acc, g):
        gw = jnp.einsum("bd,df->df", g, w) / _C9_MB
        # all-reduce every microbatch: collective energy x microbatches
        gw = _fake_all_reduce(gw)
        return acc + gw, None
    out, _ = jax.lax.scan(body, jnp.zeros_like(w), grads)
    return out


def _psum_accumulated(grads, w):
    def body(acc, g):
        return acc + jnp.einsum("bd,df->df", g, w) / _C9_MB, None
    acc, _ = jax.lax.scan(body, jnp.zeros_like(w), grads)
    return _fake_all_reduce(acc)          # single all-reduce at the end


def _fake_all_reduce(x):
    """Stands in for psum on the data axis.

    Traced single-host: shard_map(psum) over a 1-device mesh produces the
    real psum eqn; costs.py prices its ici_bytes.  We use the shard_map form
    so the jaxpr carries a genuine collective.
    """
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    mesh = Mesh(np.array(jax.devices()[:1]), ("dp",))
    return shard_map(lambda y: jax.lax.psum(y, "dp"), mesh=mesh,
                     in_specs=P(), out_specs=P())(x)


_case(id="c9-join-psum", paper_id="pytorch-181115", category="redundant",
      description="dist.Join analogue: gradient all-reduce fired per "
                  "microbatch keeps the interconnect busy; accumulate-then-"
                  "reduce frees it (GPU can idle).",
      inefficient=_psum_per_microbatch, efficient=_psum_accumulated,
      make_args=_mk_grad_args)


# ===========================================================================
# c10 — API misuse: addmm kernel selection (fp32-accumulated fused form)
# ===========================================================================

def _mk_addmm_args():
    k1, k2, k3 = _keys(3)
    x = jax.random.normal(k1, (2048, 1024), jnp.bfloat16)
    w = jax.random.normal(k2, (1024, 1024), jnp.bfloat16)
    b = jax.random.normal(k3, (1024,), jnp.bfloat16)
    return x, w, b


def _addmm_fused_f32(x, w, b):
    # addmm-analogue: materializes a double-width fp32 logits buffer in HBM,
    # adds the bias in fp32, then downcasts — 2x the HBM write traffic.
    out = jax.lax.dot(x, w, preferred_element_type=jnp.float32)
    return (out + b.astype(jnp.float32)).astype(jnp.bfloat16)


def _add_mm_native(x, w, b):
    # same fp32 MXU accumulation, but the result is written back at native
    # width and the bias added in bf16: half the HBM bytes on the hot buffer.
    out = jax.lax.dot(x, w, preferred_element_type=jnp.float32)
    return out.astype(jnp.bfloat16) + b


_case(id="c10-addmm", paper_id="pytorch-141210", category="api_misuse",
      description="addmm analogue selects an fp32-accumulating kernel with "
                  "double-width HBM writes at large batch; add+mm in native "
                  "width is cheaper.",
      inefficient=_addmm_fused_f32, efficient=_add_mm_native,
      make_args=_mk_addmm_args, output_rtol=2e-2)


# ===========================================================================
# c11 — misconfiguration: CPU busy-waiting (DOCUMENTED MISS)
# The paper's Magneton also fails on c11: the waste is host-side polling,
# invisible at operator granularity.  On TPU/XLA there is no user-level
# busy-wait knob at all; we keep the case as the structural miss.  Both
# sides are the identical computation.
# ===========================================================================

def _mk_c11_args():
    (k1,) = _keys(1)
    return (jax.random.normal(k1, (512, 512), jnp.float32),)


def _c11_same(x):
    return jnp.tanh(x @ x)


_case(id="c11-busywait", paper_id="pytorch-28224", category="misconfiguration",
      description="CPU busy-wait (host-side polling): no operator-level "
                  "signature; documented miss mirroring the paper.",
      inefficient=_c11_same, efficient=_c11_same, make_args=_mk_c11_args,
      expect_detect=False,
      notes="host-side waste is invisible in the op graph; paper misses it too")


# ===========================================================================
# c12 — API misuse: non-contiguous LayerNorm (reduction over non-minor axis)
# ===========================================================================

def _mk_ln_args():
    k1, k2 = _keys(2)
    x = jax.random.normal(k1, (2048, 1024), jnp.float32)
    w = jax.random.normal(k2, (1024,), jnp.float32)
    return x, w


def _ln_nonminor(x, w):
    # stats over the non-minor axis: forces a transpose round-trip
    xt = x.T                                           # (d, rows)
    mu = jnp.mean(xt, axis=0, keepdims=True)
    var = jnp.mean((xt - mu) ** 2, axis=0, keepdims=True)
    return (((xt - mu) / jnp.sqrt(var + 1e-5)).T * w)


def _ln_minor(x, w):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * w


_case(id="c12-ln-layout", paper_id="pytorch-76012", category="api_misuse",
      description="LayerNorm on non-contiguous input: reduction over the "
                  "non-minor axis triggers transposes / inefficient access.",
      inefficient=_ln_nonminor, efficient=_ln_minor, make_args=_mk_ln_args)


# ===========================================================================
# c13 — API misuse: cross-entropy with materialized one-hot
# ===========================================================================

_C13_V = 8192


def _mk_ce_args():
    k1, k2 = _keys(2)
    logits = jax.random.normal(k1, (16, 128, _C13_V), jnp.float32)
    labels = jax.random.randint(k2, (16, 128), 0, _C13_V)
    return logits, labels


def _ce_onehot(logits, labels):
    oh = jax.nn.one_hot(labels, _C13_V, dtype=logits.dtype)   # B*S*V bytes!
    return -jnp.sum(oh * jax.nn.log_softmax(logits, -1), axis=-1).mean()


def _ce_gather(logits, labels):
    ls = jax.nn.log_softmax(logits, -1)
    picked = jnp.take_along_axis(ls, labels[..., None], axis=-1)[..., 0]
    return -picked.mean()


_case(id="c13-ce-onehot", paper_id="pytorch-141822", category="api_misuse",
      description="cross_entropy materializes a (B,S,V) one-hot and reduces "
                  "it; gather of the target logit avoids vocab-sized HBM "
                  "traffic.",
      inefficient=_ce_onehot, efficient=_ce_gather, make_args=_mk_ce_args)


# ===========================================================================
# c14 — API misuse: STFT via dense DFT matmul
# ===========================================================================

_C14_NFFT = 256
_C14_HOP = 128


def _mk_stft_args():
    (k1,) = _keys(1)
    return (jax.random.normal(k1, (8, 4096), jnp.float32),)


def _frame(x):
    n_frames = (x.shape[-1] - _C14_NFFT) // _C14_HOP + 1
    idx = (jnp.arange(n_frames)[:, None] * _C14_HOP
           + jnp.arange(_C14_NFFT)[None, :])
    return x[..., idx]                                  # (B, frames, nfft)


def _stft_dense(x):
    frames = _frame(x)
    n = _C14_NFFT
    t = jnp.arange(n)
    ang = -2.0 * np.pi * t[:, None] * t[None, :] / n
    # dense (n x n) DFT matrices: O(n^2) flops per frame
    re = jnp.einsum("bfn,nk->bfk", frames, jnp.cos(ang))[..., :n // 2 + 1]
    im = jnp.einsum("bfn,nk->bfk", frames, jnp.sin(ang))[..., :n // 2 + 1]
    return re * re + im * im


def _stft_fft(x):
    frames = _frame(x)
    spec = jnp.fft.rfft(frames, axis=-1)               # O(n log n)
    return jnp.real(spec) ** 2 + jnp.imag(spec) ** 2


_case(id="c14-stft", paper_id="jax-28614", category="api_misuse",
      description="STFT computed with dense DFT matmuls instead of an FFT "
                  "kernel (O(n^2) vs O(n log n)).",
      inefficient=_stft_dense, efficient=_stft_fft, make_args=_mk_stft_args,
      output_rtol=2e-2, match_rtol=1e-2)


# ===========================================================================
# c15 — redundant: expm recomputing matrix powers
# ===========================================================================

def _mk_expm_args():
    (k1,) = _keys(1)
    return (jax.random.normal(k1, (192, 192), jnp.float32) / 32.0,)


def _expm_redundant(a):
    # Taylor-6 with every power recomputed from scratch
    out = jnp.eye(a.shape[0], dtype=a.dtype)
    for k in range(1, 7):
        p = a
        for _ in range(k - 1):       # recompute a^k each term: O(k) matmuls
            p = p @ a
        out = out + p / float(math.factorial(k))
    return out


def _expm_shared(a):
    out = jnp.eye(a.shape[0], dtype=a.dtype)
    p = jnp.eye(a.shape[0], dtype=a.dtype)
    for k in range(1, 7):
        p = p @ a                    # share powers: 1 matmul per term
        out = out + p / float(math.factorial(k))
    return out


_case(id="c15-expm", paper_id="jax-9239", category="redundant",
      description="Matrix exponential recomputes A^k for every Taylor term "
                  "instead of sharing the running power.",
      inefficient=_expm_redundant, efficient=_expm_shared,
      make_args=_mk_expm_args, output_rtol=2e-2, match_rtol=1e-2)


# ===========================================================================
# c16 — API misuse: count_nonzero via materialized int copy
# ===========================================================================

def _mk_cnz_args():
    (k1,) = _keys(1)
    return (jax.random.normal(k1, (4096, 4096), jnp.float32),)


def _cnz_copy(x):
    # materializes a full-width f32 indicator copy (64 MiB) and reduces it
    ones = jnp.where(x != 0.0, jnp.ones_like(x), jnp.zeros_like(x))
    return ones.sum().astype(jnp.int32)


def _cnz_direct(x):
    return jnp.count_nonzero(x).astype(jnp.int32)   # 1-byte bool reduce


_case(id="c16-count-nonzero", paper_id="tf-60772", category="api_misuse",
      description="count_nonzero materializes an int32 copy of the operand "
                  "before reducing (implicit data-copy energy).",
      inefficient=_cnz_copy, efficient=_cnz_direct, make_args=_mk_cnz_args,
      match_rtol=1e-5)


# ===========================================================================
# NEW ISSUES (paper Table 3) — the ones our framework's design adopts
# ===========================================================================

def _mk_gelu_args():
    (k1,) = _keys(1)
    return (jax.random.normal(k1, (512, 2048), jnp.float32),)


def _gelu_unfused(x):
    # HuggingFace's 5-op tanh GELU: five HBM round-trips
    c = float(np.sqrt(2.0 / np.pi))
    inner = c * (x + 0.044715 * x * x * x)
    return 0.5 * x * (1.0 + jnp.tanh(inner))


def _gelu_fused(x):
    from repro.kernels import ops as kops
    return kops.fused_gelu(x)


@register_case
def _n1_gelu_backend() -> Case:
    return Case(id="n1-gelu-backend", paper_id="hf-39073",
                category="misconfiguration",
                description="Default GELU backend launches 5 unfused kernels; "
                            "the fused Pallas kernel is one HBM pass (paper: "
                            "-77.4% op energy, -12% end-to-end).",
                inefficient=_gelu_unfused, efficient=_gelu_fused,
                make_args=_mk_gelu_args, known=False)


_N2_V = 32000


def _mk_lmhead_args():
    k1, k2 = _keys(2)
    h = jax.random.normal(k1, (4, 512, 1024), jnp.float32)
    w = jax.random.normal(k2, (1024, _N2_V), jnp.float32) * 0.02
    return h, w


def _lmhead_all(h, w):
    logits = jnp.einsum("bsd,dv->bsv", h, w)   # logits for every position
    return logits[:, -1, :]


def _lmhead_last(h, w):
    return jnp.einsum("bd,dv->bv", h[:, -1, :], w)


_case(id="n2-lmhead-redundant", paper_id="hf-38977", category="redundant",
      description="LM head computes logits for all S positions during "
                  "single-token generation; only the last is needed.",
      inefficient=_lmhead_all, efficient=_lmhead_last,
      make_args=_mk_lmhead_args, known=False)


def _mk_prefill_attn_args():
    k1, k2, k3 = _keys(3)
    q = jax.random.normal(k1, (1, 8, 1024, 64), jnp.float32)
    k = jax.random.normal(k2, (1, 8, 1024, 64), jnp.float32)
    v = jax.random.normal(k3, (1, 8, 1024, 64), jnp.float32)
    return q, k, v


def _prefill_naive(q, k, v):
    from repro.kernels import ref
    return ref.attention(q, k, v, causal=True)


def _prefill_flash(q, k, v):
    from repro.kernels import ops as kops
    return kops.flash_attention(q, k, v, causal=True)


_case(id="n3-prefill-attn", paper_id="vllm-20174", category="api_misuse",
      description="Default prefill attention materializes the (S,S) score "
                  "matrix; the flash kernel streams it through VMEM.",
      inefficient=_prefill_naive, efficient=_prefill_flash,
      make_args=_mk_prefill_attn_args, known=False, output_rtol=2e-2)


_N4_T = 512
_N4_E, _N4_CAP = 8, _N4_T   # capacity == tokens: no drops, outputs identical


def _mk_moe_args():
    k1, k2 = _keys(2)
    x = jax.random.normal(k1, (_N4_T, 256), jnp.float32)
    router = jax.random.normal(k2, (256, _N4_E), jnp.float32) * 0.1
    return x, router


def _moe_onehot_dispatch(x, router):
    # GShard-style dense dispatch: tokens x experts x capacity einsum
    T = x.shape[0]
    logits = x @ router
    top = jnp.argmax(logits, axis=-1)
    onehot = jax.nn.one_hot(top, _N4_E, dtype=x.dtype)           # (T,E)
    pos = jnp.cumsum(onehot, axis=0) * onehot                    # (T,E)
    cap_oh = jax.nn.one_hot(pos.astype(jnp.int32) - 1, _N4_CAP,
                            dtype=x.dtype)                       # (T,E,C)
    dispatch = onehot[..., None] * cap_oh                        # (T,E,C)
    expert_in = jnp.einsum("tec,td->ecd", dispatch, x)           # dense!
    return expert_in.sum(axis=(0, 1)), top.astype(jnp.int32)


def _moe_gather_dispatch(x, router):
    logits = x @ router
    top = jnp.argmax(logits, axis=-1)
    order = jnp.argsort(top)
    sorted_x = jnp.take(x, order, axis=0)        # gather, no (T,E,C) tensor
    return sorted_x.sum(axis=0), top.astype(jnp.int32)


_case(id="n4-moe-dispatch", paper_id="ours-moe", category="api_misuse",
      description="MoE dispatch via dense one-hot (tokens x experts x "
                  "capacity einsum) vs sort/gather-based routing.",
      inefficient=_moe_onehot_dispatch, efficient=_moe_gather_dispatch,
      make_args=_mk_moe_args, known=False, output_rtol=2e-2,
      match_rtol=1e-4)


# ===========================================================================
# registry helpers
# ===========================================================================

def by_id(case_id: str) -> Case:
    """Back-compat alias for :func:`get_case`."""
    return get_case(case_id)


def known_cases() -> list[Case]:
    return list_cases(known=True)


def new_cases() -> list[Case]:
    return list_cases(known=False)
