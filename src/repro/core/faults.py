"""Deterministic fault injection for the artifact store stack.

Robustness claims are only as good as the failures they were tested
against, so this module makes failure a first-class, *seeded* input: a
:class:`FaultPlan` is a reproducible schedule of faults and a
:class:`FaultyStore` wraps any :class:`~repro.core.store.Store` and injects
them at the protocol boundary.  The same ``(plan, workload)`` pair always
injects the same faults at the same call sites, so chaos tests can diff a
faulted run against a fault-free run byte for byte.

Fault kinds
-----------

``io_error``
    Raise :class:`~repro.core.store.TransientStoreError` — a flaky mount or
    mirror blip.  The retry layer should absorb it.
``timeout``
    Raise :class:`~repro.core.store.StoreTimeoutError` — a read deadline
    expiring.  Also transient.
``hard_error``
    Raise :class:`~repro.core.store.StoreError` — a permanent failure the
    retry layer must *not* absorb.
``torn_write``
    On ``write_chunk``: write only a truncated prefix of the payload under
    the full content address, then report success — models a torn write on
    a filesystem without atomic rename.  Read-side digest verification is
    the intended defense.  On ``write_manifest``: drop the write entirely
    (a lost write), which models dying before the rename.
``bit_flip``
    On ``read_chunk``: flip one byte of the returned data (in-flight
    corruption).  On ``write_chunk``: flip one byte *before* handing it to
    the inner store (at-rest corruption under a correct address).
``stale_manifest``
    On ``read_manifest``: serve the payload this key held *before* its most
    recent write through this wrapper — a lagging replica.
``crash``
    Raise :class:`SimulatedCrash` — mid-operation process death.  It
    derives from ``BaseException`` so no ``except Exception`` handler in
    the code under test can accidentally swallow it; only the test harness
    catches it.

Every injected fault is appended to ``plan.log`` as ``(op, key, kind)``.
"""

from __future__ import annotations

import dataclasses
import random

from repro.core.store import (StoreError, StoreTimeoutError,
                              TransientStoreError, _fresh_counters)

FAULT_KINDS = ("io_error", "timeout", "hard_error", "torn_write",
               "bit_flip", "stale_manifest", "crash")

_WRITE_OPS = ("write_manifest", "write_chunk")


class SimulatedCrash(BaseException):
    """Process death at a crash point.  BaseException on purpose: the code
    under test must not be able to catch it, just like a real SIGKILL."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One rule in a fault schedule.

    ``op``           store method to target (``"read_chunk"``, ...) or ``"*"``.
    ``kind``         one of :data:`FAULT_KINDS`.
    ``probability``  chance of firing per matching call (seeded RNG).
    ``times``        stop firing after this many injections (None = forever).
    ``after``        skip this many matching calls first (crash points:
                     ``after=N`` kills the N+1-th write).
    ``match``        only fire when this substring appears in the key/digest.
    """

    op: str
    kind: str
    probability: float = 1.0
    times: int | None = None
    after: int = 0
    match: str | None = None

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")


class FaultPlan:
    """A seeded, reproducible schedule of :class:`FaultSpec` rules.

    Call counting and the probability RNG are both deterministic: replaying
    the same workload against the same plan injects the same faults.
    """

    def __init__(self, specs: "list[FaultSpec] | tuple[FaultSpec, ...]",
                 seed: int = 0):
        self.specs = list(specs)
        self.seed = seed
        self._rng = random.Random(seed)
        self._calls = [0] * len(self.specs)   # matching calls seen per spec
        self._fired = [0] * len(self.specs)   # injections done per spec
        self.log: list[tuple[str, str, str]] = []

    def draw(self, op: str, key: str = "") -> FaultSpec | None:
        """Return the first spec that fires for this call, if any."""
        for i, spec in enumerate(self.specs):
            if spec.op not in (op, "*"):
                continue
            if spec.match is not None and spec.match not in key:
                continue
            self._calls[i] += 1
            if self._calls[i] <= spec.after:
                continue
            if spec.times is not None and self._fired[i] >= spec.times:
                continue
            if spec.probability < 1.0 and self._rng.random() >= spec.probability:
                continue
            self._fired[i] += 1
            self.log.append((op, key, spec.kind))
            return spec
        return None

    @property
    def injected(self) -> int:
        return len(self.log)

    def flip_position(self, n: int) -> int:
        """Deterministic byte offset for a bit_flip over an n-byte payload."""
        return self._rng.randrange(n) if n else 0


def _flip_byte(data: bytes, pos: int) -> bytes:
    buf = bytearray(data)
    buf[pos] ^= 0xFF
    return bytes(buf)


class FaultyStore:
    """A :class:`~repro.core.store.Store` that injects a :class:`FaultPlan`.

    Wrap any store — a LocalStore, a file:// RemoteStore, or another
    FaultyStore — and pass it wherever a store is accepted (including as a
    LocalStore ``upstream``, which is how a *flaky mirror* is modeled).
    Reads and writes that don't draw a fault delegate unchanged, so a plan
    with no matching specs is a transparent proxy.
    """

    def __init__(self, inner, plan: FaultPlan):
        self.inner = inner
        self.plan = plan
        # stale_manifest support: remember the payload each key held before
        # its latest write through this wrapper.
        self._track_stale = any(s.kind == "stale_manifest" for s in plan.specs)
        self._prior_manifests: dict[str, dict] = {}

    # Delegate everything not explicitly intercepted (readonly, counters,
    # root, uri, bulk(), retry, ...) so the wrapper is drop-in.
    def __getattr__(self, name):
        return getattr(self.inner, name)

    def _maybe(self, op: str, key: str = "") -> FaultSpec | None:
        spec = self.plan.draw(op, key)
        if spec is None:
            return None
        if spec.kind == "io_error":
            raise TransientStoreError(f"injected io_error on {op}({key[:12]}…)")
        if spec.kind == "timeout":
            raise StoreTimeoutError(f"injected timeout on {op}({key[:12]}…)")
        if spec.kind == "hard_error":
            raise StoreError(f"injected hard_error on {op}({key[:12]}…)")
        if spec.kind == "crash":
            raise SimulatedCrash(f"injected crash at {op}({key[:12]}…)")
        return spec                           # data faults handled by caller

    # -- manifests ----------------------------------------------------------
    def has_manifest(self, key: str) -> bool:
        self._maybe("has_manifest", key)
        return self.inner.has_manifest(key)

    def read_manifest(self, key: str) -> dict:
        spec = self._maybe("read_manifest", key)
        if spec is not None and spec.kind == "stale_manifest":
            if key in self._prior_manifests:
                return self._prior_manifests[key]
        return self.inner.read_manifest(key)

    def write_manifest(self, key: str, payload: dict) -> None:
        spec = self._maybe("write_manifest", key)
        if self._track_stale:
            try:
                self._prior_manifests[key] = self.inner.read_manifest(key)
            except Exception:
                pass
        if spec is not None and spec.kind == "torn_write":
            return                            # lost write: died before rename
        self.inner.write_manifest(key, payload)

    def delete_manifest(self, key: str) -> None:
        self._maybe("delete_manifest", key)
        self.inner.delete_manifest(key)

    def manifest_keys(self) -> list[str]:
        self._maybe("manifest_keys")
        return self.inner.manifest_keys()

    def manifest_bytes(self, key: str) -> int:
        self._maybe("manifest_bytes", key)
        return self.inner.manifest_bytes(key)

    def manifest_mtime_ns(self, key: str) -> int:
        self._maybe("manifest_mtime_ns", key)
        return self.inner.manifest_mtime_ns(key)

    # -- chunks -------------------------------------------------------------
    def has_chunk(self, digest: str) -> bool:
        self._maybe("has_chunk", digest)
        return self.inner.has_chunk(digest)

    def read_chunk(self, digest: str) -> bytes:
        spec = self._maybe("read_chunk", digest)
        data = self.inner.read_chunk(digest)
        if spec is not None and spec.kind == "bit_flip" and data:
            data = _flip_byte(data, self.plan.flip_position(len(data)))
        return data

    def write_chunk(self, digest: str, data: bytes) -> None:
        spec = self._maybe("write_chunk", digest)
        if spec is not None and data:
            if spec.kind == "torn_write":
                data = data[:max(1, len(data) // 2)]
            elif spec.kind == "bit_flip":
                data = _flip_byte(data, self.plan.flip_position(len(data)))
        self.inner.write_chunk(digest, data)

    def delete_chunk(self, digest: str) -> None:
        self._maybe("delete_chunk", digest)
        self.inner.delete_chunk(digest)

    def chunk_keys(self) -> list[str]:
        self._maybe("chunk_keys")
        return self.inner.chunk_keys()

    def chunk_bytes(self, digest: str) -> int:
        self._maybe("chunk_bytes", digest)
        return self.inner.chunk_bytes(digest)
