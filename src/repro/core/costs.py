"""Per-operator cost rules: FLOPs, HBM bytes, ICI bytes for each primitive.

These rules price the *operator-level* execution model: each operator reads
its inputs from and writes its outputs to HBM.  That is exactly the execution
model of the eager frameworks the paper profiles, and it is what makes
differential energy debugging work — e.g. the unfused 5-op GELU pays five HBM
round-trips while the fused Pallas kernel pays one (paper case hf-39073).

``pallas_call`` nodes are priced as a single fused pass (inputs + outputs
once); higher-order nodes (scan/while/cond) are priced by recursing into
their body and multiplying by the trip count.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import numpy as np
from jax._src.core import ClosedJaxpr, Jaxpr

from repro.core.graph import OpGraph, OpNode


@dataclasses.dataclass
class OpCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    ici_bytes: float = 0.0
    fp32_fraction: float = 0.0   # fraction of flops running in fp32-accurate mode
    notes: str = ""

    def __add__(self, other: "OpCost") -> "OpCost":
        tot = self.flops + other.flops
        frac = 0.0
        if tot > 0:
            frac = (self.flops * self.fp32_fraction + other.flops * other.fp32_fraction) / tot
        return OpCost(tot, self.hbm_bytes + other.hbm_bytes,
                      self.ici_bytes + other.ici_bytes, frac)

    def scaled(self, k: float) -> "OpCost":
        return OpCost(self.flops * k, self.hbm_bytes * k, self.ici_bytes * k,
                      self.fp32_fraction, self.notes)


def _numel(shape) -> int:
    return int(np.prod(shape, dtype=np.int64)) if shape else 1


def _itemsize(dtype: str) -> int:
    try:
        return np.dtype(dtype).itemsize
    except TypeError:
        return 2 if "bfloat16" in str(dtype) else 4


def _tensor_bytes(shape, dtype) -> int:
    return _numel(shape) * _itemsize(dtype)


def _io_bytes(graph: OpGraph, node: OpNode) -> float:
    b = 0.0
    for t in node.invars:
        e = graph.tensors[t]
        b += _tensor_bytes(e.shape, e.dtype)
    for t in node.outvars:
        e = graph.tensors[t]
        b += _tensor_bytes(e.shape, e.dtype)
    return b


def _out_numel(graph: OpGraph, node: OpNode) -> int:
    return sum(_numel(graph.tensors[t].shape) for t in node.outvars)


def _in_numel(graph: OpGraph, node: OpNode) -> int:
    return sum(_numel(graph.tensors[t].shape) for t in node.invars)


# ---------------------------------------------------------------------------
# rule registry
# ---------------------------------------------------------------------------

CostRule = Callable[[OpGraph, OpNode], OpCost]
_RULES: dict[str, CostRule] = {}


def rule(*names: str):
    def deco(fn: CostRule):
        for n in names:
            _RULES[n] = fn
        return fn
    return deco


def _is_highest_precision(params: dict[str, Any]) -> bool:
    prec = params.get("precision")
    if prec is None:
        return False
    return "HIGHEST" in str(prec).upper()


@rule("dot_general")
def _dot_general(graph: OpGraph, node: OpNode) -> OpCost:
    lhs = graph.tensors[node.invars[0]]
    dnums = node.params["dimension_numbers"]
    (lc, _rc), (lb, _rb) = dnums
    m_dims = [d for d in range(len(lhs.shape)) if d not in set(lc) | set(lb)]
    k = _numel([lhs.shape[d] for d in lc])
    b = _numel([lhs.shape[d] for d in lb])
    m = _numel([lhs.shape[d] for d in m_dims])
    out = graph.tensors[node.outvars[0]]
    n = max(1, _numel(out.shape) // max(1, b * m))
    flops = 2.0 * b * m * n * k
    fp32 = 1.0 if (_is_highest_precision(node.params)
                   and "bfloat16" in (lhs.dtype,)) or (
        _is_highest_precision(node.params)) else 0.0
    return OpCost(flops=flops, hbm_bytes=_io_bytes(graph, node), fp32_fraction=fp32)


@rule("conv_general_dilated")
def _conv(graph: OpGraph, node: OpNode) -> OpCost:
    lhs = graph.tensors[node.invars[0]]
    rhs = graph.tensors[node.invars[1]]
    out = graph.tensors[node.outvars[0]]
    groups = node.params.get("feature_group_count", 1)
    # flops = 2 * out_numel * (k_spatial * C_in / groups)
    kernel_numel = _numel(rhs.shape)
    # kernel shape includes C_out; per-output-element MACs = kernel_numel / C_out
    dn = node.params.get("dimension_numbers")
    c_out = max(1, rhs.shape[dn.rhs_spec[0]] if dn is not None else rhs.shape[-1])
    flops = 2.0 * _numel(out.shape) * (kernel_numel / c_out)
    del lhs, groups
    return OpCost(flops=flops, hbm_bytes=_io_bytes(graph, node),
                  fp32_fraction=1.0 if _is_highest_precision(node.params) else 0.0)


_UNARY_CHEAP = ("neg", "abs", "sign", "floor", "ceil", "round", "is_finite",
                "not", "real", "imag", "copy", "population_count", "clz",
                "stop_gradient")
_UNARY_TRANS = ("exp", "log", "log1p", "expm1", "tanh", "sin", "cos", "tan",
                "asin", "acos", "atan", "sinh", "cosh", "erf", "erfc",
                "erf_inv", "rsqrt", "sqrt", "cbrt", "logistic", "exp2")
_BINARY = ("add", "sub", "mul", "div", "max", "min", "pow", "atan2", "rem",
           "and", "or", "xor", "shift_left", "shift_right_logical",
           "shift_right_arithmetic", "nextafter", "complex")
_COMPARE = ("eq", "ne", "lt", "le", "gt", "ge")


def _elementwise(factor: float) -> CostRule:
    def fn(graph: OpGraph, node: OpNode) -> OpCost:
        return OpCost(flops=factor * _out_numel(graph, node),
                      hbm_bytes=_io_bytes(graph, node))
    return fn


for _n in _UNARY_CHEAP:
    _RULES[_n] = _elementwise(1.0)
for _n in _UNARY_TRANS:
    _RULES[_n] = _elementwise(4.0)   # transcendental ≈ 4 VPU flops/elem
for _n in _BINARY:
    _RULES[_n] = _elementwise(1.0)
for _n in _COMPARE:
    _RULES[_n] = _elementwise(1.0)
_RULES["select_n"] = _elementwise(1.0)
_RULES["clamp"] = _elementwise(2.0)
_RULES["square"] = _elementwise(1.0)


@rule("integer_pow")
def _integer_pow(graph: OpGraph, node: OpNode) -> OpCost:
    y = abs(int(node.params.get("y", 2)))
    mults = max(1, math.ceil(math.log2(max(y, 2))))
    return OpCost(flops=mults * _out_numel(graph, node),
                  hbm_bytes=_io_bytes(graph, node))


# --- data movement (zero/low flops, bytes dominate) -------------------------
_MOVEMENT = ("reshape", "transpose", "broadcast_in_dim", "concatenate", "pad",
             "slice", "dynamic_slice", "dynamic_update_slice", "rev",
             "convert_element_type", "bitcast_convert_type", "squeeze",
             "expand_dims", "gather", "scatter", "scatter-add", "scatter_add",
             "iota", "reduce_precision", "copy_p", "device_put", "split",
             "optimization_barrier")


def _movement_rule(graph: OpGraph, node: OpNode) -> OpCost:
    return OpCost(flops=0.0, hbm_bytes=_io_bytes(graph, node))


for _n in _MOVEMENT:
    _RULES[_n] = _movement_rule

# reshape on contiguous data is free in XLA; but at operator granularity a
# standalone reshape of already-materialized data is metadata-only.  We price
# reshape/squeeze/expand_dims at zero bytes to avoid penalizing views.
def _view_rule(graph: OpGraph, node: OpNode) -> OpCost:
    return OpCost(0.0, 0.0)


for _n in ("reshape", "squeeze", "expand_dims"):
    _RULES[_n] = _view_rule


# In-place / windowed ops touch only the window, not the whole operand —
# XLA updates donated buffers in place.  This is the distinction that case
# c2 (vllm-10811: decode cache updated via full-copy concatenate instead of
# an in-place slice update) relies on.
@rule("dynamic_update_slice")
def _dus(graph: OpGraph, node: OpNode) -> OpCost:
    upd = graph.tensors[node.invars[1]]
    b = _tensor_bytes(upd.shape, upd.dtype)
    return OpCost(flops=0.0, hbm_bytes=2.0 * b, notes="in-place window update")


@rule("dynamic_slice")
def _ds(graph: OpGraph, node: OpNode) -> OpCost:
    out = graph.tensors[node.outvars[0]]
    b = _tensor_bytes(out.shape, out.dtype)
    return OpCost(flops=0.0, hbm_bytes=2.0 * b, notes="windowed read")


@rule("gather")
def _gather(graph: OpGraph, node: OpNode) -> OpCost:
    out_b = sum(_tensor_bytes(graph.tensors[t].shape, graph.tensors[t].dtype)
                for t in node.outvars)
    idx = graph.tensors[node.invars[1]]
    idx_b = _tensor_bytes(idx.shape, idx.dtype)
    return OpCost(flops=0.0, hbm_bytes=2.0 * out_b + idx_b,
                  notes="gathered elements only")


@rule("scatter", "scatter-add", "scatter_add")
def _scatter(graph: OpGraph, node: OpNode) -> OpCost:
    upd = graph.tensors[node.invars[2]] if len(node.invars) > 2 else \
        graph.tensors[node.invars[-1]]
    b = _tensor_bytes(upd.shape, upd.dtype)
    return OpCost(flops=float(_numel(upd.shape)), hbm_bytes=3.0 * b,
                  notes="scattered window only")


_REDUCE = ("reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
           "reduce_and", "reduce_or", "argmax", "argmin", "reduce")


def _reduce_rule(graph: OpGraph, node: OpNode) -> OpCost:
    return OpCost(flops=float(_in_numel(graph, node)),
                  hbm_bytes=_io_bytes(graph, node))


for _n in _REDUCE:
    _RULES[_n] = _reduce_rule


@rule("cumsum", "cumprod", "cummax", "cummin", "cumlogsumexp")
def _cumulative(graph: OpGraph, node: OpNode) -> OpCost:
    return OpCost(flops=float(_in_numel(graph, node)),
                  hbm_bytes=_io_bytes(graph, node))


@rule("sort")
def _sort(graph: OpGraph, node: OpNode) -> OpCost:
    e = graph.tensors[node.invars[0]]
    dim = node.params.get("dimension", len(e.shape) - 1)
    n = e.shape[dim] if e.shape else 1
    passes = max(1.0, math.log2(max(n, 2)))
    # bitonic-style sort: ~log^2 passes of compare/exchange through memory
    return OpCost(flops=_in_numel(graph, node) * passes,
                  hbm_bytes=_io_bytes(graph, node) * passes,
                  notes="multi-pass sort")


@rule("top_k")
def _top_k(graph: OpGraph, node: OpNode) -> OpCost:
    k = node.params.get("k", 1)
    n_in = _in_numel(graph, node)
    return OpCost(flops=n_in * max(1.0, math.log2(max(k, 2))),
                  hbm_bytes=_io_bytes(graph, node))


@rule("random_bits", "random_seed", "random_wrap", "random_fold_in", "random_unwrap",
      "threefry2x32")
def _rng(graph: OpGraph, node: OpNode) -> OpCost:
    return OpCost(flops=8.0 * _out_numel(graph, node),
                  hbm_bytes=_io_bytes(graph, node))


@rule("fft")
def _fft(graph: OpGraph, node: OpNode) -> OpCost:
    e = graph.tensors[node.invars[0]]
    lens = node.params.get("fft_lengths", (e.shape[-1],))
    n = _numel(lens)
    batch = max(1, _numel(e.shape) // max(1, n))
    return OpCost(flops=5.0 * batch * n * max(1.0, math.log2(max(n, 2))),
                  hbm_bytes=_io_bytes(graph, node))


# --- collectives (shard_map-level) ------------------------------------------
def _collective_rule(scale: float) -> CostRule:
    def fn(graph: OpGraph, node: OpNode) -> OpCost:
        b = sum(_tensor_bytes(graph.tensors[t].shape, graph.tensors[t].dtype)
                for t in node.outvars)
        return OpCost(flops=0.0, hbm_bytes=b, ici_bytes=scale * b)
    return fn


_RULES["psum"] = _collective_rule(2.0)          # ring all-reduce ≈ 2× data
_RULES["psum2"] = _collective_rule(2.0)          # JAX 0.4.x shard_map name
_RULES["psum_invariant"] = _collective_rule(2.0)  # JAX>=0.7 shard_map name
_RULES["pmean"] = _collective_rule(2.0)
_RULES["pmax"] = _collective_rule(2.0)
_RULES["pmin"] = _collective_rule(2.0)
_RULES["all_gather"] = _collective_rule(1.0)
_RULES["all_gather_invariant"] = _collective_rule(1.0)
_RULES["reduce_scatter"] = _collective_rule(1.0)
_RULES["all_to_all"] = _collective_rule(1.0)
_RULES["ppermute"] = _collective_rule(1.0)
_RULES["psum_scatter"] = _collective_rule(1.0)
_RULES["pvary"] = _view_rule                     # replication annotation only
_RULES["pbroadcast"] = _view_rule                # replication annotation only


# --- higher-order ------------------------------------------------------------

def _body_cost(closed: ClosedJaxpr | Jaxpr, trip: float) -> OpCost:
    from repro.core.graph import extract_graph
    if isinstance(closed, Jaxpr):
        closed = ClosedJaxpr(closed, ())
    sub = extract_graph(closed, name="body", inline_calls=True)
    total = OpCost()
    for n in sub.nodes:
        total = total + node_cost(sub, n)
    return total.scaled(trip)


@rule("scan")
def _scan(graph: OpGraph, node: OpNode) -> OpCost:
    length = node.params.get("length", 1)
    return _body_cost(node.params["jaxpr"], float(length))


@rule("while")
def _while(graph: OpGraph, node: OpNode) -> OpCost:
    c = _body_cost(node.params["body_jaxpr"], 1.0)
    c.notes = "while: trip count unknown, priced as 1 iteration"
    return c


@rule("cond")
def _cond(graph: OpGraph, node: OpNode) -> OpCost:
    branches = node.params.get("branches", ())
    costs = [_body_cost(b, 1.0) for b in branches]
    if not costs:
        return OpCost()
    return max(costs, key=lambda c: c.flops + c.hbm_bytes)


@rule("pjit", "jit", "closed_call", "custom_jvp_call", "custom_vjp_call",
      "remat", "checkpoint", "shard_map")
def _call(graph: OpGraph, node: OpNode) -> OpCost:
    from repro.core.graph import _nested_jaxpr  # noqa: PLC0415

    class _E:  # minimal shim so _nested_jaxpr can read params
        params = node.params
    inner = _nested_jaxpr(_E)
    if inner is None:
        return OpCost(hbm_bytes=_io_bytes(graph, node))
    return _body_cost(inner, 1.0)


@rule("pallas_call")
def _pallas_call(graph: OpGraph, node: OpNode) -> OpCost:
    """Fused kernel: single HBM pass over inputs+outputs, flops from body."""
    inner = node.params.get("jaxpr")
    flops = 0.0
    if inner is not None:
        try:
            flops = _body_cost(inner, 1.0).flops
        except Exception:
            flops = float(_out_numel(graph, node))
    grid = node.params.get("grid", ())
    trip = _numel(grid) if grid else 1
    return OpCost(flops=flops * max(1, trip),
                  hbm_bytes=_io_bytes(graph, node),
                  notes="fused pallas kernel: one HBM pass")


_UNKNOWN_SEEN: set[str] = set()


def node_cost(graph: OpGraph, node: OpNode) -> OpCost:
    """Cost of one operator; falls back to a bytes-dominant estimate."""
    rule_fn = _RULES.get(node.primitive)
    if rule_fn is None:
        _UNKNOWN_SEEN.add(node.primitive)
        return OpCost(flops=float(_out_numel(graph, node)),
                      hbm_bytes=_io_bytes(graph, node),
                      notes=f"fallback rule for {node.primitive}")
    return rule_fn(graph, node)


def graph_cost(graph: OpGraph) -> OpCost:
    total = OpCost()
    for n in graph.nodes:
        total = total + node_cost(graph, n)
    return total


def unknown_primitives_seen() -> set[str]:
    return set(_UNKNOWN_SEEN)
