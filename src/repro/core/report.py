"""Findings data model and rendering for the differential energy debugger.

Reports round-trip through JSON (``to_json`` / ``from_json``) so stored
comparisons — e.g. those written by ``python -m repro.cli compare --json``
— can be re-rendered later without re-running any pipeline.  N-way ranking
results (``Session.rank``) embed their waste matrix under
``meta['rank_matrix']``; ``Report.render`` picks it up automatically.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Mapping, Sequence

from repro.core.diagnose import Diagnosis


@dataclasses.dataclass
class Finding:
    """One detected software-energy-waste (or trade-off) region."""

    region_idx: int
    energy_a_j: float
    energy_b_j: float
    time_a_s: float
    time_b_s: float
    nodes_a: list[int]
    nodes_b: list[int]
    classification: str          # 'energy_waste' | 'tradeoff' | 'comparable'
    wasteful_side: str           # 'A' | 'B' | '-'
    diagnosis: Diagnosis | None = None

    @property
    def energy_delta_pct(self) -> float:
        lo = min(self.energy_a_j, self.energy_b_j)
        hi = max(self.energy_a_j, self.energy_b_j)
        if lo <= 0:
            return 0.0 if hi <= 0 else float("inf")
        return (hi - lo) / lo * 100.0

    @property
    def perf_delta_pct(self) -> float:
        lo = min(self.time_a_s, self.time_b_s)
        hi = max(self.time_a_s, self.time_b_s)
        if lo <= 0:
            return 0.0
        return (hi - lo) / lo * 100.0

    @classmethod
    def from_json(cls, data: str | Mapping[str, Any]) -> "Finding":
        d = json.loads(data) if isinstance(data, str) else dict(data)
        diag = d.get("diagnosis")
        if diag is not None:
            diag = Diagnosis.from_dict(diag)
        return cls(region_idx=d["region_idx"],
                   energy_a_j=d["energy_a_j"], energy_b_j=d["energy_b_j"],
                   time_a_s=d["time_a_s"], time_b_s=d["time_b_s"],
                   nodes_a=list(d["nodes_a"]), nodes_b=list(d["nodes_b"]),
                   classification=d["classification"],
                   wasteful_side=d["wasteful_side"], diagnosis=diag)


@dataclasses.dataclass
class Report:
    name_a: str
    name_b: str
    findings: list[Finding]
    total_energy_a_j: float
    total_energy_b_j: float
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def waste_findings(self) -> list[Finding]:
        return [f for f in self.findings if f.classification == "energy_waste"]

    @property
    def is_degraded(self) -> bool:
        """True when any rung of the degradation ladder fired — the result
        is honest but reduced-fidelity (see ``meta['degraded']``)."""
        return bool(self.meta.get("degraded"))

    def render(self, *, max_findings: int = 10) -> str:
        lines = []
        lines.append(f"=== Magneton differential energy report: "
                     f"A={self.name_a} vs B={self.name_b} ===")
        for note in self.meta.get("degraded", ()):
            lines.append(f"!!! DEGRADED: {note}")
        lines.append(f"total energy  A: {self.total_energy_a_j:.4e} J   "
                     f"B: {self.total_energy_b_j:.4e} J   "
                     f"(Δ {self._total_delta():+.1f}% A vs B)")
        waste = self.waste_findings
        lines.append(f"matched regions: {len(self.findings)}   "
                     f"energy-waste findings: {len(waste)}")
        for f in sorted(waste, key=lambda f: -abs(f.energy_a_j - f.energy_b_j))[:max_findings]:
            lines.append(f"--- region {f.region_idx}: wasteful side {f.wasteful_side}, "
                         f"ΔE {f.energy_delta_pct:.1f}% "
                         f"(A {f.energy_a_j:.3e} J vs B {f.energy_b_j:.3e} J), "
                         f"Δperf {f.perf_delta_pct:.2f}%")
            d = f.diagnosis
            if d is not None:
                lines.append(f"    kind: {d.kind}"
                             + (f" / {d.subkind}" if d.subkind else "")
                             + (f"  (priced by {d.priced_by})"
                                if d.priced_by else ""))
                lines.append(f"    deviation point: {d.deviation_point}")
                lines.append(f"    {d.detail}")
                for kv in d.key_variables[:6]:
                    lines.append(f"    key variable: {kv}")
        rank = self.meta.get("rank_matrix")
        if rank:
            lines.extend(render_rank_matrix(rank["names"],
                                            rank["total_energy_j"],
                                            rank["waste_matrix"]))
        return "\n".join(lines)

    def _total_delta(self) -> float:
        if self.total_energy_b_j <= 0:
            return 0.0
        return (self.total_energy_a_j - self.total_energy_b_j) / self.total_energy_b_j * 100.0

    def to_json(self) -> str:
        def enc(o):
            if dataclasses.is_dataclass(o) and not isinstance(o, type):
                return dataclasses.asdict(o)
            raise TypeError(type(o))
        return json.dumps(dataclasses.asdict(self), default=enc, indent=2)

    @classmethod
    def from_json(cls, data: str | Mapping[str, Any]) -> "Report":
        d = json.loads(data) if isinstance(data, str) else dict(data)
        return cls(name_a=d["name_a"], name_b=d["name_b"],
                   findings=[Finding.from_json(f) for f in d["findings"]],
                   total_energy_a_j=d["total_energy_a_j"],
                   total_energy_b_j=d["total_energy_b_j"],
                   meta=dict(d.get("meta", {})))


def render_rank_matrix(names: Sequence[str], totals: Sequence[float],
                       waste: Sequence[Sequence[float]]) -> list[str]:
    """Render an N-way waste matrix (``waste[i][j]`` = Joules candidate i
    wastes vs candidate j) as report lines, best candidate first."""
    n = len(names)
    order = sorted(range(n), key=lambda i: totals[i])
    tag = [f"[{k}]" for k in range(n)]
    lines = ["--- N-way waste matrix (J wasted by row candidate vs column; "
             "rows sorted best-first) ---"]
    for rank, i in enumerate(order):
        lines.append(f"    {tag[rank]} {names[i]}  "
                     f"(total {totals[i]:.4e} J)")
    header = "    waste[J]  " + " ".join(f"{tag[k]:>10}" for k in range(n))
    lines.append(header)
    for rank, i in enumerate(order):
        cells = " ".join(f"{waste[i][j]:>10.3e}" for j in order)
        lines.append(f"    {tag[rank]:>9} {cells}")
    return lines


def render_patch_report(patch: Any) -> str:
    """Render a ``repro.optimize.PatchReport`` (duck-typed so core stays
    import-free of the optimizer package).  Candidates are listed in the
    report's ranked order; the embedded N-way rank matrix, when present,
    is appended through ``render_rank_matrix``."""
    lines = [f"=== Magneton patch report: target={patch.target} ==="]
    lines.append(f"target energy: {patch.target_energy_j:.4e} J"
                 + (f"   diagnosed subkind: {patch.subkind}"
                    if patch.subkind else "   (no diagnosis — all rewrites tried)"))
    d = getattr(patch, "diagnosis", None)
    if d is not None:
        lines.append(f"diagnosis: {d.kind}"
                     + (f" / {d.subkind}" if d.subkind else "")
                     + f" at {d.deviation_point}")
    best = patch.best
    if best is None:
        lines.append("no verified-cheaper rewrite found "
                     f"({len(patch.candidates)} candidate(s) examined)")
    for i, c in enumerate(patch.candidates):
        mark = "*" if best is not None and c is best else " "
        head = (f" {mark}[{i}] {c.rewrite} (inverts {c.inverts}): "
                f"{c.status}")
        if c.status == "verified":
            head += (f", {c.sites} site(s), energy {c.energy_j:.4e} J, "
                     f"win {c.win_j:+.4e} J ({c.win_pct:+.2f}%)")
        elif c.energy_j is not None:
            head += f", energy {c.energy_j:.4e} J"
        lines.append(head)
        if c.reason:
            lines.append(f"      reason: {c.reason}")
    rank = patch.meta.get("rank_matrix") if patch.meta else None
    if rank:
        lines.extend(render_rank_matrix(rank["names"],
                                        rank["total_energy_j"],
                                        rank["waste_matrix"]))
    return "\n".join(lines)
