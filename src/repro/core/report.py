"""Findings data model and rendering for the differential energy debugger."""

from __future__ import annotations

import dataclasses
import json
from typing import Any

from repro.core.diagnose import Diagnosis


@dataclasses.dataclass
class Finding:
    """One detected software-energy-waste (or trade-off) region."""

    region_idx: int
    energy_a_j: float
    energy_b_j: float
    time_a_s: float
    time_b_s: float
    nodes_a: list[int]
    nodes_b: list[int]
    classification: str          # 'energy_waste' | 'tradeoff' | 'comparable'
    wasteful_side: str           # 'A' | 'B' | '-'
    diagnosis: Diagnosis | None = None

    @property
    def energy_delta_pct(self) -> float:
        lo = min(self.energy_a_j, self.energy_b_j)
        hi = max(self.energy_a_j, self.energy_b_j)
        if lo <= 0:
            return 0.0 if hi <= 0 else float("inf")
        return (hi - lo) / lo * 100.0

    @property
    def perf_delta_pct(self) -> float:
        lo = min(self.time_a_s, self.time_b_s)
        hi = max(self.time_a_s, self.time_b_s)
        if lo <= 0:
            return 0.0
        return (hi - lo) / lo * 100.0


@dataclasses.dataclass
class Report:
    name_a: str
    name_b: str
    findings: list[Finding]
    total_energy_a_j: float
    total_energy_b_j: float
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def waste_findings(self) -> list[Finding]:
        return [f for f in self.findings if f.classification == "energy_waste"]

    def render(self, *, max_findings: int = 10) -> str:
        lines = []
        lines.append(f"=== Magneton differential energy report: "
                     f"A={self.name_a} vs B={self.name_b} ===")
        lines.append(f"total energy  A: {self.total_energy_a_j:.4e} J   "
                     f"B: {self.total_energy_b_j:.4e} J   "
                     f"(Δ {self._total_delta():+.1f}% A vs B)")
        waste = self.waste_findings
        lines.append(f"matched regions: {len(self.findings)}   "
                     f"energy-waste findings: {len(waste)}")
        for f in sorted(waste, key=lambda f: -abs(f.energy_a_j - f.energy_b_j))[:max_findings]:
            lines.append(f"--- region {f.region_idx}: wasteful side {f.wasteful_side}, "
                         f"ΔE {f.energy_delta_pct:.1f}% "
                         f"(A {f.energy_a_j:.3e} J vs B {f.energy_b_j:.3e} J), "
                         f"Δperf {f.perf_delta_pct:.2f}%")
            d = f.diagnosis
            if d is not None:
                lines.append(f"    kind: {d.kind}")
                lines.append(f"    deviation point: {d.deviation_point}")
                lines.append(f"    {d.detail}")
                for kv in d.key_variables[:6]:
                    lines.append(f"    key variable: {kv}")
        return "\n".join(lines)

    def _total_delta(self) -> float:
        if self.total_energy_b_j <= 0:
            return 0.0
        return (self.total_energy_a_j - self.total_energy_b_j) / self.total_energy_b_j * 100.0

    def to_json(self) -> str:
        def enc(o):
            if dataclasses.is_dataclass(o) and not isinstance(o, type):
                return dataclasses.asdict(o)
            raise TypeError(type(o))
        return json.dumps(dataclasses.asdict(self), default=enc, indent=2)
