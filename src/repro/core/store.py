"""Shared-store protocol: content-addressed chunk + manifest transports.

The artifact layer (core/artifact.py) persists one capture as a small JSON
*manifest* plus a set of content-addressed *chunks* (sha256-keyed byte
blobs holding phase-2 tensor values and sample-0 outputs).  This module
defines the transport underneath that layout:

* :class:`Store` — the protocol: manifest get/put/list + chunk get/put/list,
  with read counters (``counters``) so tests and ``artifacts stats`` can
  assert e.g. *zero raw-value chunk reads* during a sketch-only offline
  replay.
* :class:`LocalStore` — on-disk store (``manifests/<key>.json`` +
  ``chunks/<dg[:2]>/<dg>``) with atomic writes (tmp + ``os.replace``; chunk
  writes are idempotent by content address, so two processes capturing the
  same key converge instead of corrupting each other) and an optional
  ``upstream`` remote it reads through: manifest/chunk misses are fetched
  from the upstream and cached locally, so a fleet machine pulls captures
  recorded elsewhere on first use and serves them locally afterwards.
* :class:`RemoteStore` — URI-addressed mirror: a plain path or ``file://``
  URI (NFS-style shared filesystem, read/write) or an ``http(s)://`` base
  URL (readonly; listing served from the ``index.json`` that
  ``ArtifactStore.push`` maintains).

``open_store(uri)`` maps a user-supplied ``--store`` value onto the right
implementation.  Everything above this layer (dedup, refcount GC, schema
migration) lives in :class:`~repro.core.artifact.ArtifactStore`.
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
from pathlib import Path
from typing import Protocol, runtime_checkable
from urllib.parse import urlparse
from urllib.request import url2pathname

# Chunk granularity for value payloads.  4 MiB keeps big activations in a
# handful of chunks (cheap manifests) while still deduplicating weights and
# repeated activations at sub-tensor granularity.
CHUNK_BYTES = 4 << 20

_INDEX_NAME = "index.json"       # remote listing for http mirrors


class StoreReadOnlyError(RuntimeError):
    """A write was attempted on a readonly store (e.g. an http mirror)."""


def chunk_digest(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


def split_chunks(buf: bytes, chunk_bytes: int = CHUNK_BYTES) -> list[bytes]:
    """Fixed-size chunking of one value buffer (last chunk may be short)."""
    if len(buf) <= chunk_bytes:
        return [buf]
    return [buf[i:i + chunk_bytes] for i in range(0, len(buf), chunk_bytes)]


def _fresh_counters() -> dict[str, int]:
    return {"manifest_reads": 0, "manifest_writes": 0,
            "chunk_reads": 0, "chunk_bytes_read": 0,
            "chunk_writes": 0, "chunk_bytes_written": 0,
            "chunk_dedup_hits": 0,
            "upstream_manifest_reads": 0, "upstream_chunk_reads": 0}


@runtime_checkable
class Store(Protocol):
    """Manifest + chunk transport for content-addressed artifacts."""

    readonly: bool
    counters: dict[str, int]

    def has_manifest(self, key: str) -> bool: ...
    def read_manifest(self, key: str) -> dict: ...
    def write_manifest(self, key: str, payload: dict) -> None: ...
    def delete_manifest(self, key: str) -> None: ...
    def manifest_keys(self) -> list[str]: ...
    def manifest_bytes(self, key: str) -> int: ...
    def manifest_mtime_ns(self, key: str) -> int: ...

    def has_chunk(self, digest: str) -> bool: ...
    def read_chunk(self, digest: str) -> bytes: ...
    def write_chunk(self, digest: str, data: bytes) -> None: ...
    def delete_chunk(self, digest: str) -> None: ...
    def chunk_keys(self) -> list[str]: ...
    def chunk_bytes(self, digest: str) -> int: ...


# ---------------------------------------------------------------------------
# filesystem layout helpers (shared by LocalStore and file:// RemoteStore)
# ---------------------------------------------------------------------------

def _atomic_write(path: Path, data: bytes) -> None:
    """Write-to-temp + rename: readers never observe a torn file, and two
    same-destination writers converge (last rename wins; for chunks both
    bodies are byte-identical by content address, so either is correct)."""
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


class _FsLayout:
    """``manifests/<key>.json`` + ``chunks/<dg[:2]>/<dg>`` under one root."""

    def __init__(self, root: Path):
        self.root = root

    def manifest_path(self, key: str) -> Path:
        return self.root / "manifests" / f"{key}.json"

    def chunk_path(self, digest: str) -> Path:
        return self.root / "chunks" / digest[:2] / digest

    def manifest_keys(self) -> list[str]:
        d = self.root / "manifests"
        if not d.exists():
            return []
        return sorted(p.stem for p in d.glob("*.json"))

    def chunk_keys(self) -> list[str]:
        d = self.root / "chunks"
        if not d.exists():
            return []
        return sorted(p.name for p in d.glob("??/*") if p.is_file())


class LocalStore:
    """On-disk store with atomic writes and an optional read-through upstream.

    ``upstream`` (any :class:`Store`, typically a :class:`RemoteStore`
    mirror) serves manifest/chunk misses; fetched entries are cached locally
    so the next read is local.  Writes always go to the local root.
    """

    readonly = False

    def __init__(self, root: str | Path, upstream: "Store | None" = None):
        self.root = Path(root).expanduser()
        self._fs = _FsLayout(self.root)
        self.upstream = upstream
        self.counters = _fresh_counters()

    # -- manifests ----------------------------------------------------------
    def has_manifest(self, key: str) -> bool:
        if self._fs.manifest_path(key).exists():
            return True
        return self.upstream is not None and self.upstream.has_manifest(key)

    def read_manifest(self, key: str) -> dict:
        path = self._fs.manifest_path(key)
        self.counters["manifest_reads"] += 1
        if not path.exists():
            if self.upstream is None or not self.upstream.has_manifest(key):
                raise KeyError(key)
            payload = self.upstream.read_manifest(key)
            self.counters["upstream_manifest_reads"] += 1
            _atomic_write(path, json.dumps(payload).encode())
            return payload
        return json.loads(path.read_text())

    def write_manifest(self, key: str, payload: dict) -> None:
        self.counters["manifest_writes"] += 1
        _atomic_write(self._fs.manifest_path(key), json.dumps(payload).encode())

    def delete_manifest(self, key: str) -> None:
        self._fs.manifest_path(key).unlink(missing_ok=True)

    def manifest_keys(self) -> list[str]:
        keys = set(self._fs.manifest_keys())
        if self.upstream is not None:
            keys.update(self.upstream.manifest_keys())
        return sorted(keys)

    def manifest_bytes(self, key: str) -> int:
        return self._fs.manifest_path(key).stat().st_size

    def manifest_mtime_ns(self, key: str) -> int:
        return self._fs.manifest_path(key).stat().st_mtime_ns

    # -- chunks -------------------------------------------------------------
    def has_chunk(self, digest: str) -> bool:
        if self._fs.chunk_path(digest).exists():
            return True
        return self.upstream is not None and self.upstream.has_chunk(digest)

    def read_chunk(self, digest: str) -> bytes:
        path = self._fs.chunk_path(digest)
        if not path.exists():
            if self.upstream is None or not self.upstream.has_chunk(digest):
                raise KeyError(digest)
            data = self.upstream.read_chunk(digest)
            self.counters["upstream_chunk_reads"] += 1
            _atomic_write(path, data)
        else:
            data = path.read_bytes()
        self.counters["chunk_reads"] += 1
        self.counters["chunk_bytes_read"] += len(data)
        return data

    def write_chunk(self, digest: str, data: bytes) -> None:
        path = self._fs.chunk_path(digest)
        if path.exists():                     # content-addressed: idempotent
            self.counters["chunk_dedup_hits"] += 1
            return
        self.counters["chunk_writes"] += 1
        self.counters["chunk_bytes_written"] += len(data)
        _atomic_write(path, data)

    def delete_chunk(self, digest: str) -> None:
        self._fs.chunk_path(digest).unlink(missing_ok=True)

    def chunk_keys(self) -> list[str]:
        return self._fs.chunk_keys()

    def chunk_bytes(self, digest: str) -> int:
        return self._fs.chunk_path(digest).stat().st_size


class RemoteStore:
    """URI-addressed shared store: a filesystem mirror or an http(s) one.

    * plain path / ``file://`` — NFS-style shared directory, read/write;
      the same on-disk layout as :class:`LocalStore`.
    * ``http(s)://`` — readonly mirror of that layout; ``manifest_keys``
      comes from the ``index.json`` that ``ArtifactStore.push`` writes.
    """

    def __init__(self, uri: str):
        self.uri = str(uri)
        parsed = urlparse(self.uri)
        self._http = parsed.scheme in ("http", "https")
        self.readonly = self._http
        self.counters = _fresh_counters()
        self._bulk_depth = 0
        if self._http:
            self._base = self.uri.rstrip("/")
            self._fs = None
        else:
            if parsed.scheme == "file":
                root = Path(url2pathname(parsed.path))
            elif parsed.scheme:
                raise ValueError(f"unsupported store scheme {parsed.scheme!r} "
                                 f"in {self.uri!r} (file:// or http(s)://)")
            else:
                root = Path(self.uri)
            self.root = root.expanduser()
            self._fs = _FsLayout(self.root)

    # -- http plumbing ------------------------------------------------------
    def _get(self, rel: str) -> bytes | None:
        from urllib.error import HTTPError, URLError
        from urllib.request import urlopen
        try:
            with urlopen(f"{self._base}/{rel}", timeout=30) as r:
                return r.read()
        except HTTPError as e:
            if e.code == 404:
                return None
            raise
        except URLError as e:
            raise IOError(f"remote store {self.uri} unreachable: {e}") from e

    def _deny_write(self) -> None:
        raise StoreReadOnlyError(
            f"store {self.uri} is readonly (http mirror); push from a "
            "writable store instead")

    # -- manifests ----------------------------------------------------------
    def has_manifest(self, key: str) -> bool:
        if self._fs is not None:
            return self._fs.manifest_path(key).exists()
        return self._get(f"manifests/{key}.json") is not None

    def read_manifest(self, key: str) -> dict:
        self.counters["manifest_reads"] += 1
        if self._fs is not None:
            path = self._fs.manifest_path(key)
            if not path.exists():
                raise KeyError(key)
            return json.loads(path.read_text())
        data = self._get(f"manifests/{key}.json")
        if data is None:
            raise KeyError(key)
        return json.loads(data.decode())

    def write_manifest(self, key: str, payload: dict) -> None:
        if self._fs is None:
            self._deny_write()
        self.counters["manifest_writes"] += 1
        _atomic_write(self._fs.manifest_path(key), json.dumps(payload).encode())
        self._update_index()

    def delete_manifest(self, key: str) -> None:
        if self._fs is None:
            self._deny_write()
        self._fs.manifest_path(key).unlink(missing_ok=True)
        self._update_index()

    def manifest_keys(self) -> list[str]:
        if self._fs is not None:
            return self._fs.manifest_keys()
        data = self._get(_INDEX_NAME)
        if data is None:
            return []
        return sorted(json.loads(data.decode()).get("manifests", []))

    def manifest_bytes(self, key: str) -> int:
        if self._fs is not None:
            return self._fs.manifest_path(key).stat().st_size
        data = self._get(f"manifests/{key}.json")
        if data is None:
            raise KeyError(key)
        return len(data)

    def manifest_mtime_ns(self, key: str) -> int:
        if self._fs is not None:
            return self._fs.manifest_path(key).stat().st_mtime_ns
        return 0                              # http mirrors don't expose mtime

    def bulk(self):
        """Context manager deferring the ``index.json`` rewrite to exit —
        one directory scan per bulk transfer instead of one per manifest."""
        import contextlib

        @contextlib.contextmanager
        def _bulk():
            self._bulk_depth += 1
            try:
                yield self
            finally:
                self._bulk_depth -= 1
                if self._bulk_depth == 0 and self._fs is not None:
                    self._update_index(force=True)
        return _bulk()

    def _update_index(self, force: bool = False) -> None:
        """Maintain ``index.json`` so http consumers of this mirror can list."""
        if self._bulk_depth > 0 and not force:
            return
        payload = {"manifests": self._fs.manifest_keys()}
        _atomic_write(self.root / _INDEX_NAME,
                      json.dumps(payload, indent=1).encode())

    # -- chunks -------------------------------------------------------------
    def has_chunk(self, digest: str) -> bool:
        if self._fs is not None:
            return self._fs.chunk_path(digest).exists()
        return self._get(f"chunks/{digest[:2]}/{digest}") is not None

    def read_chunk(self, digest: str) -> bytes:
        if self._fs is not None:
            path = self._fs.chunk_path(digest)
            if not path.exists():
                raise KeyError(digest)
            data = path.read_bytes()
        else:
            got = self._get(f"chunks/{digest[:2]}/{digest}")
            if got is None:
                raise KeyError(digest)
            data = got
        self.counters["chunk_reads"] += 1
        self.counters["chunk_bytes_read"] += len(data)
        return data

    def write_chunk(self, digest: str, data: bytes) -> None:
        if self._fs is None:
            self._deny_write()
        path = self._fs.chunk_path(digest)
        if path.exists():
            self.counters["chunk_dedup_hits"] += 1
            return
        self.counters["chunk_writes"] += 1
        self.counters["chunk_bytes_written"] += len(data)
        _atomic_write(path, data)

    def delete_chunk(self, digest: str) -> None:
        if self._fs is None:
            self._deny_write()
        self._fs.chunk_path(digest).unlink(missing_ok=True)

    def chunk_keys(self) -> list[str]:
        if self._fs is not None:
            return self._fs.chunk_keys()
        raise NotImplementedError("http mirrors do not enumerate chunks")

    def chunk_bytes(self, digest: str) -> int:
        if self._fs is not None:
            return self._fs.chunk_path(digest).stat().st_size
        data = self._get(f"chunks/{digest[:2]}/{digest}")
        if data is None:
            raise KeyError(digest)
        return len(data)


def open_store(uri: "str | Path | Store") -> "Store":
    """Map a ``--store`` value onto a Store: an existing Store passes
    through; a URI (``file://``, ``http(s)://``) opens a RemoteStore; a
    plain path opens a LocalStore rooted there."""
    if isinstance(uri, (LocalStore, RemoteStore)):
        return uri
    if not isinstance(uri, (str, Path)):
        # duck-typed Store implementations (e.g. test doubles)
        if isinstance(uri, Store):
            return uri
        raise TypeError(f"cannot open a store from {type(uri).__name__}")
    text = str(uri)
    if "://" in text:
        return RemoteStore(text)
    return LocalStore(text)
