"""Shared-store protocol: content-addressed chunk + manifest transports.

The artifact layer (core/artifact.py) persists one capture as a small JSON
*manifest* plus a set of content-addressed *chunks* (sha256-keyed byte
blobs holding phase-2 tensor values and sample-0 outputs).  This module
defines the transport underneath that layout:

* :class:`Store` — the protocol: manifest get/put/list + chunk get/put/list,
  with read counters (``counters``) so tests and ``artifacts stats`` can
  assert e.g. *zero raw-value chunk reads* during a sketch-only offline
  replay.
* :class:`LocalStore` — on-disk store (``manifests/<key>.json`` +
  ``chunks/<dg[:2]>/<dg>``) with atomic writes (tmp + ``os.replace``; chunk
  writes are idempotent by content address, so two processes capturing the
  same key converge instead of corrupting each other) and an optional
  ``upstream`` remote it reads through: manifest/chunk misses are fetched
  from the upstream and cached locally, so a fleet machine pulls captures
  recorded elsewhere on first use and serves them locally afterwards.
* :class:`RemoteStore` — URI-addressed mirror: a plain path or ``file://``
  URI (NFS-style shared filesystem, read/write) or an ``http(s)://`` base
  URL (listing served from the ``index.json`` that writers maintain).
  http mirrors are readonly by default; opened with ``writable=True`` they
  speak an S3/GCS-style conditional-put dialect — chunk puts are
  create-only (``If-None-Match: *``, idempotent by content address) and
  the shared ``index.json`` is updated by compare-and-swap on its ETag,
  so many engines can record into one store without losing each other's
  writes (see docs/serving.md).

``open_store(uri)`` maps a user-supplied ``--store`` value onto the right
implementation.  Everything above this layer (dedup, refcount GC, schema
migration) lives in :class:`~repro.core.artifact.ArtifactStore`.
"""

from __future__ import annotations

import dataclasses
import errno
import hashlib
import json
import os
import random
import tempfile
import time
from pathlib import Path
from typing import Callable, Protocol, Sequence, runtime_checkable
from urllib.parse import urlparse
from urllib.request import url2pathname

# Chunk granularity for value payloads.  4 MiB keeps big activations in a
# handful of chunks (cheap manifests) while still deduplicating weights and
# repeated activations at sub-tensor granularity.
CHUNK_BYTES = 4 << 20

_INDEX_NAME = "index.json"       # remote listing for http mirrors

# Fallback read timeout (seconds) for http(s) mirrors; --store-timeout and
# the RemoteStore(timeout=...) kwarg override it.
_TIMEOUT_ENV = "MAGNETON_STORE_TIMEOUT"
DEFAULT_STORE_TIMEOUT_S = 30.0


# ---------------------------------------------------------------------------
# error taxonomy
# ---------------------------------------------------------------------------

class StoreError(RuntimeError):
    """Base class for typed store failures (transport, policy, integrity)."""


class StoreReadOnlyError(StoreError):
    """A write was attempted on a readonly store (e.g. an http mirror)."""


class TransientStoreError(StoreError):
    """A failure worth retrying: flaky transport, busy mount, 5xx mirror."""


class StorePreconditionError(StoreError):
    """A conditional put lost its race (http 412): the object changed under
    us.  Not transient for :class:`RetryPolicy` — blindly re-sending the
    same stale write cannot succeed; callers must re-read and re-merge
    (the CAS loop in :meth:`RemoteStore._cas_update_index` does)."""


class StoreTimeoutError(TransientStoreError):
    """A read exceeded its deadline (still transient: retry may succeed)."""


class StoreCorruptionError(StoreError):
    """Stored bytes failed integrity verification and no good copy remains."""


class ChunkCorruptionError(StoreCorruptionError):
    """A chunk's bytes no longer hash to its content address.

    Raised only after the local copy has been quarantined and (when an
    ``upstream`` exists) a verified re-fetch has been attempted — callers
    never observe silently-wrong chunk bytes.
    """

    def __init__(self, digest: str, detail: str):
        super().__init__(f"chunk {digest[:12]}… corrupt: {detail}")
        self.digest = digest


# errno values that indicate a retryable filesystem/transport hiccup rather
# than a permanent condition (missing file, permission, bad argument).
_TRANSIENT_ERRNOS = frozenset(
    getattr(errno, name) for name in
    ("EIO", "EAGAIN", "EBUSY", "ETIMEDOUT", "ECONNRESET", "ECONNABORTED",
     "ECONNREFUSED", "ENETUNREACH", "ENETRESET", "EHOSTUNREACH", "ESTALE")
    if hasattr(errno, name))

_TRANSIENT_HTTP_CODES = frozenset({408, 425, 429, 500, 502, 503, 504})


def is_transient_error(exc: BaseException) -> bool:
    """Classify an exception as transient (retry) vs permanent (surface).

    Transient: :class:`TransientStoreError` (incl. timeouts), socket/OS
    timeouts, connection resets, NFS ``ESTALE``, http 408/429/5xx, and
    non-HTTP ``URLError`` (DNS blips, refused connections).  Permanent:
    missing keys, readonly stores, corruption, and everything else.
    """
    from urllib.error import HTTPError, URLError
    if isinstance(exc, (StoreCorruptionError, StoreReadOnlyError)):
        return False
    if isinstance(exc, (TransientStoreError, TimeoutError)):
        return True
    if isinstance(exc, HTTPError):
        return exc.code in _TRANSIENT_HTTP_CODES
    if isinstance(exc, URLError):
        return True
    if isinstance(exc, ConnectionError):
        return True
    if isinstance(exc, OSError):
        return exc.errno in _TRANSIENT_ERRNOS
    return False


@dataclasses.dataclass
class RetryPolicy:
    """Exponential backoff with jitter and a per-policy retry budget.

    ``call`` runs a thunk, retrying on exceptions ``classify`` deems
    transient.  Delays follow ``base_delay_s * 2**attempt`` capped at
    ``max_delay_s``, each multiplied by a seeded jitter factor in
    ``[1-jitter, 1+jitter]`` so fleets don't retry in lockstep yet test
    schedules stay deterministic.  ``budget`` bounds the *total* number of
    retries over the policy's lifetime — a store stuck behind a dead mirror
    degrades to fast typed failures instead of retrying forever on every
    read.
    """

    max_attempts: int = 4
    base_delay_s: float = 0.05
    max_delay_s: float = 2.0
    jitter: float = 0.5
    budget: int = 64
    seed: int = 0
    sleep: Callable[[float], None] = time.sleep

    def __post_init__(self):
        self._rng = random.Random(self.seed)
        self.retries_spent = 0

    def delay_for(self, attempt: int) -> float:
        base = min(self.base_delay_s * (2 ** attempt), self.max_delay_s)
        if self.jitter <= 0:
            return base
        return base * self._rng.uniform(1 - self.jitter, 1 + self.jitter)

    def call(self, fn: Callable[[], "object"], *, what: str = "store read",
             classify: Callable[[BaseException], bool] = is_transient_error,
             counters: dict[str, int] | None = None):
        last: BaseException | None = None
        for attempt in range(self.max_attempts):
            try:
                return fn()
            except Exception as e:            # SimulatedCrash (BaseException) passes through
                if not classify(e):
                    raise
                last = e
                if (attempt + 1 >= self.max_attempts
                        or self.retries_spent >= self.budget):
                    break
                self.retries_spent += 1
                if counters is not None:
                    counters["retries"] = counters.get("retries", 0) + 1
                self.sleep(self.delay_for(attempt))
        if isinstance(last, StoreError):
            raise last
        raise TransientStoreError(
            f"{what} failed after {self.max_attempts} attempt(s): {last}") from last


# Manifest keys under these prefixes are not CandidateArtifact manifests:
# ``audit-`` carries audit-subsystem state (per-engine audit logs,
# per-class golden records — repro.audit); ``block--``/``profile--``/
# ``hlo--`` carry schema-v4 block-evidence cache entries
# (core/block_cache.py).  They ride the same manifest transport (and
# index.json) so one shared store carries everything, but ArtifactStore's
# artifact-shaped walks (stats entry listing, entries, prune) skip them —
# chunk refcounting does NOT skip block evidence, since those entries
# reference chunks (see ArtifactStore._chunk_refs).
RESERVED_MANIFEST_PREFIX = "audit-"        # back-compat alias
RESERVED_MANIFEST_PREFIXES = ("audit-", "block--", "profile--", "hlo--")


def is_reserved_manifest(key: str) -> bool:
    """True for non-artifact manifest keys (audit state + block evidence)."""
    return key.startswith(RESERVED_MANIFEST_PREFIXES)


def chunk_digest(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


def split_chunks(buf: bytes, chunk_bytes: int = CHUNK_BYTES) -> list[bytes]:
    """Fixed-size chunking of one value buffer (last chunk may be short)."""
    if len(buf) <= chunk_bytes:
        return [buf]
    return [buf[i:i + chunk_bytes] for i in range(0, len(buf), chunk_bytes)]


def _fresh_counters() -> dict[str, int]:
    return {"manifest_reads": 0, "manifest_writes": 0,
            "chunk_reads": 0, "chunk_bytes_read": 0,
            "chunk_writes": 0, "chunk_bytes_written": 0,
            "chunk_dedup_hits": 0,
            "upstream_manifest_reads": 0, "upstream_chunk_reads": 0,
            "retries": 0, "chunks_quarantined": 0, "verify_failures": 0,
            "quarantine_evictions": 0, "index_cas_conflicts": 0,
            "block_hits": 0, "block_misses": 0,
            "profile_hits": 0, "profile_misses": 0}


# The quarantine directory holds corrupt-at-rest files for forensics, but a
# store under sustained corruption (a failing disk, a bit-flipping mirror)
# would otherwise grow it without bound — every quarantined chunk is dead
# weight that nothing ever reads back automatically.  The cap bounds the
# directory; oldest casualties are evicted first (the newest corruption is
# the most likely to still be under investigation).
QUARANTINE_MAX_BYTES_ENV = "MAGNETON_QUARANTINE_MAX_BYTES"
DEFAULT_QUARANTINE_MAX_BYTES = 64 * 1024 * 1024


def quarantine_cap_bytes() -> int:
    """The quarantine size cap: ``$MAGNETON_QUARANTINE_MAX_BYTES`` (<= 0
    disables the cap) or the 64 MiB default.  An unparsable value falls back
    to the default rather than raising — the cap is enforced on corruption
    error paths, where a config typo must not mask the real failure."""
    raw = os.environ.get(QUARANTINE_MAX_BYTES_ENV)
    if raw is None:
        return DEFAULT_QUARANTINE_MAX_BYTES
    try:
        return int(raw)
    except ValueError:
        return DEFAULT_QUARANTINE_MAX_BYTES


@runtime_checkable
class Store(Protocol):
    """Manifest + chunk transport for content-addressed artifacts."""

    readonly: bool
    counters: dict[str, int]

    def has_manifest(self, key: str) -> bool: ...
    def read_manifest(self, key: str) -> dict: ...
    def write_manifest(self, key: str, payload: dict) -> None: ...
    def delete_manifest(self, key: str) -> None: ...
    def manifest_keys(self) -> list[str]: ...
    def manifest_bytes(self, key: str) -> int: ...
    def manifest_mtime_ns(self, key: str) -> int: ...

    def has_chunk(self, digest: str) -> bool: ...
    def read_chunk(self, digest: str) -> bytes: ...
    def write_chunk(self, digest: str, data: bytes) -> None: ...
    def delete_chunk(self, digest: str) -> None: ...
    def chunk_keys(self) -> list[str]: ...
    def chunk_bytes(self, digest: str) -> int: ...


# ---------------------------------------------------------------------------
# filesystem layout helpers (shared by LocalStore and file:// RemoteStore)
# ---------------------------------------------------------------------------

def _atomic_write(path: Path, data: bytes) -> None:
    """Write-to-temp + rename: readers never observe a torn file, and two
    same-destination writers converge (last rename wins; for chunks both
    bodies are byte-identical by content address, so either is correct)."""
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


class _FsLayout:
    """``manifests/<key>.json`` + ``chunks/<dg[:2]>/<dg>`` under one root."""

    def __init__(self, root: Path):
        self.root = root

    def manifest_path(self, key: str) -> Path:
        return self.root / "manifests" / f"{key}.json"

    def chunk_path(self, digest: str) -> Path:
        return self.root / "chunks" / digest[:2] / digest

    def manifest_keys(self) -> list[str]:
        d = self.root / "manifests"
        if not d.exists():
            return []
        return sorted(p.stem for p in d.glob("*.json"))

    def chunk_keys(self) -> list[str]:
        d = self.root / "chunks"
        if not d.exists():
            return []
        return sorted(p.name for p in d.glob("??/*") if p.is_file())

    def quarantine_dir(self) -> Path:
        return self.root / "quarantine"

    def quarantine(self, path: Path,
                   counters: dict[str, int] | None = None) -> Path:
        """Move a failed-verification file out of the serving tree.

        The original name is kept (content addresses are unique), so a
        later forensic diff against a good copy is a plain file compare.
        Enforces the quarantine size cap afterwards (oldest files evicted;
        the file just moved in is never evicted, even when it alone exceeds
        the cap — ``os.replace`` keeps its original mtime, which can be
        arbitrarily old).  Evictions are tallied into ``counters``.
        """
        dest = self.quarantine_dir() / path.name
        dest.parent.mkdir(parents=True, exist_ok=True)
        os.replace(path, dest)
        cap = quarantine_cap_bytes()
        if cap > 0:
            evicted = self.prune_quarantine(cap, protect=(dest,))
            if counters is not None:
                counters["quarantine_evictions"] = (
                    counters.get("quarantine_evictions", 0) + len(evicted))
        return dest

    def quarantine_entries(self) -> list[tuple[int, Path, int]]:
        """(mtime_ns, path, size) per quarantined file, oldest first."""
        d = self.quarantine_dir()
        if not d.exists():
            return []
        out = []
        for p in d.iterdir():
            if not p.is_file():
                continue
            try:
                st = p.stat()
            except OSError:
                continue
            out.append((st.st_mtime_ns, p, st.st_size))
        out.sort()
        return out

    def prune_quarantine(self, max_bytes: int, *,
                         protect: Sequence[Path] = (),
                         dry_run: bool = False) -> list[Path]:
        """Evict oldest quarantined files until the directory holds at most
        ``max_bytes``.  Returns the (would-be-)evicted paths, oldest first."""
        entries = self.quarantine_entries()
        protected = {Path(p) for p in protect}
        total = sum(size for _, _, size in entries)
        evicted: list[Path] = []
        for _, p, size in entries:
            if total <= max_bytes:
                break
            if p in protected:
                continue
            if not dry_run:
                p.unlink(missing_ok=True)
            evicted.append(p)
            total -= size
        return evicted


class LocalStore:
    """On-disk store with atomic writes and an optional read-through upstream.

    ``upstream`` (any :class:`Store`, typically a :class:`RemoteStore`
    mirror) serves manifest/chunk misses; fetched entries are cached locally
    so the next read is local.  Writes always go to the local root.
    """

    readonly = False

    def __init__(self, root: str | Path, upstream: "Store | None" = None,
                 retry: "RetryPolicy | None" = None):
        self.root = Path(root).expanduser()
        self._fs = _FsLayout(self.root)
        self.upstream = upstream
        self.retry = retry if retry is not None else RetryPolicy()
        self.counters = _fresh_counters()

    def _pull(self, fn, what: str):
        """Upstream fetch with transient-error retry (backoff + jitter)."""
        return self.retry.call(fn, what=what, counters=self.counters)

    def _quarantine(self, path: Path) -> Path:
        self.counters["chunks_quarantined"] += 1
        self.counters["verify_failures"] += 1
        return self._fs.quarantine(path, self.counters)

    # -- manifests ----------------------------------------------------------
    def has_manifest(self, key: str) -> bool:
        if self._fs.manifest_path(key).exists():
            return True
        return self.upstream is not None and self._pull(
            lambda: self.upstream.has_manifest(key), f"has_manifest({key[:12]}…)")

    def read_manifest(self, key: str) -> dict:
        path = self._fs.manifest_path(key)
        self.counters["manifest_reads"] += 1
        quarantined = None
        if path.exists():
            try:
                return json.loads(path.read_text())
            except json.JSONDecodeError:
                # torn/garbled at rest: move it aside, fall through to the
                # upstream (a later retry of the whole operation sees a
                # clean miss and re-captures/re-pulls — convergent).
                quarantined = self._quarantine(path)
        if self.upstream is None or not self._pull(
                lambda: self.upstream.has_manifest(key),
                f"has_manifest({key[:12]}…)"):
            if quarantined is not None:
                raise StoreCorruptionError(
                    f"manifest {key} failed to parse and no upstream holds a "
                    f"replacement; bad copy quarantined at {quarantined}")
            raise KeyError(key)
        payload = self._pull(lambda: self.upstream.read_manifest(key),
                             f"manifest {key[:12]}…")
        self.counters["upstream_manifest_reads"] += 1
        _atomic_write(path, json.dumps(payload).encode())
        return payload

    def write_manifest(self, key: str, payload: dict) -> None:
        self.counters["manifest_writes"] += 1
        _atomic_write(self._fs.manifest_path(key), json.dumps(payload).encode())

    def delete_manifest(self, key: str) -> None:
        self._fs.manifest_path(key).unlink(missing_ok=True)

    def manifest_keys(self) -> list[str]:
        keys = set(self._fs.manifest_keys())
        if self.upstream is not None:
            keys.update(self._pull(lambda: self.upstream.manifest_keys(),
                                   "manifest listing"))
        return sorted(keys)

    def manifest_bytes(self, key: str) -> int:
        return self._fs.manifest_path(key).stat().st_size

    def manifest_mtime_ns(self, key: str) -> int:
        return self._fs.manifest_path(key).stat().st_mtime_ns

    # -- chunks -------------------------------------------------------------
    def has_chunk(self, digest: str) -> bool:
        if self._fs.chunk_path(digest).exists():
            return True
        return self.upstream is not None and self._pull(
            lambda: self.upstream.has_chunk(digest), f"has_chunk({digest[:12]}…)")

    def _verified_upstream_chunk(self, digest: str) -> bytes:
        """Fetch a chunk from upstream and verify it hashes to its address.

        Transport errors are retried by policy; a digest mismatch gets one
        fresh fetch (the bad read may itself have been a transport artifact)
        before the typed corruption error escapes.
        """
        for _ in range(2):
            data = self._pull(lambda: self.upstream.read_chunk(digest),
                              f"chunk {digest[:12]}…")
            if chunk_digest(data) == digest:
                return data
            self.counters["verify_failures"] += 1
        raise ChunkCorruptionError(
            digest, f"upstream {getattr(self.upstream, 'uri', self.upstream)} "
                    "served bytes that failed digest verification twice")

    def read_chunk(self, digest: str) -> bytes:
        path = self._fs.chunk_path(digest)
        data = None
        corrupt_local = False
        if path.exists():
            data = path.read_bytes()
            if chunk_digest(data) != digest:
                # at-rest corruption (bit rot, torn write on a non-atomic
                # filesystem): quarantine, then re-fetch a good copy.
                self._quarantine(path)
                data, corrupt_local = None, True
        if data is None:
            if self.upstream is None or not self._pull(
                    lambda: self.upstream.has_chunk(digest),
                    f"has_chunk({digest[:12]}…)"):
                if corrupt_local:
                    raise ChunkCorruptionError(
                        digest, "local copy quarantined under "
                        f"{self._fs.quarantine_dir()} and no upstream holds "
                        "a replacement")
                raise KeyError(digest)
            data = self._verified_upstream_chunk(digest)
            self.counters["upstream_chunk_reads"] += 1
            _atomic_write(path, data)
        self.counters["chunk_reads"] += 1
        self.counters["chunk_bytes_read"] += len(data)
        return data

    def write_chunk(self, digest: str, data: bytes) -> None:
        path = self._fs.chunk_path(digest)
        if path.exists():                     # content-addressed: idempotent
            self.counters["chunk_dedup_hits"] += 1
            return
        self.counters["chunk_writes"] += 1
        self.counters["chunk_bytes_written"] += len(data)
        _atomic_write(path, data)

    def delete_chunk(self, digest: str) -> None:
        self._fs.chunk_path(digest).unlink(missing_ok=True)

    def chunk_keys(self) -> list[str]:
        return self._fs.chunk_keys()

    def chunk_bytes(self, digest: str) -> int:
        return self._fs.chunk_path(digest).stat().st_size


class RemoteStore:
    """URI-addressed shared store: a filesystem mirror or an http(s) one.

    * plain path / ``file://`` — NFS-style shared directory, read/write;
      the same on-disk layout as :class:`LocalStore`.
    * ``http(s)://`` — mirror of that layout; ``manifest_keys`` comes from
      the ``index.json`` that writers maintain.  Readonly by default;
      with ``writable=True`` writes go over S3/GCS-style conditional
      puts: chunks are created with ``If-None-Match: *`` (a lost race
      means another writer already published the identical bytes — content
      addressing makes that a dedup hit, not a conflict), manifests are
      last-writer-wins (same-key manifests describe the same capture), and
      the shared ``index.json`` listing is updated by a compare-and-swap
      loop on its ETag so concurrent writers merge instead of clobbering.
    """

    # bound on index.json CAS round-trips per write before the contention
    # is surfaced as a (retryable) typed error.  CAS races are lock-free —
    # every lost round means some other writer's update landed — so the
    # bound must exceed the foreign progress one call can plausibly
    # observe, not just a retry count
    _CAS_ATTEMPTS = 32

    def __init__(self, uri: str, timeout: float | None = None,
                 retry: "RetryPolicy | None" = None,
                 writable: bool = False):
        self.uri = str(uri)
        parsed = urlparse(self.uri)
        self._http = parsed.scheme in ("http", "https")
        self.readonly = self._http and not writable
        self.counters = _fresh_counters()
        self._pending_index_adds: set[str] = set()
        self._pending_index_removes: set[str] = set()
        self.retry = retry if retry is not None else RetryPolicy()
        if timeout is None:
            timeout = float(os.environ.get(_TIMEOUT_ENV,
                                           DEFAULT_STORE_TIMEOUT_S))
        self.timeout = timeout
        self._bulk_depth = 0
        if self._http:
            self._base = self.uri.rstrip("/")
            self._fs = None
        else:
            if parsed.scheme == "file":
                root = Path(url2pathname(parsed.path))
            elif parsed.scheme:
                raise ValueError(f"unsupported store scheme {parsed.scheme!r} "
                                 f"in {self.uri!r} (file:// or http(s)://)")
            else:
                root = Path(self.uri)
            self.root = root.expanduser()
            self._fs = _FsLayout(self.root)

    # -- http plumbing ------------------------------------------------------
    def _request_once(self, method: str, rel: str,
                      data: bytes | None = None,
                      headers: dict[str, str] | None = None
                      ) -> tuple[bytes | None, str | None]:
        """One http round-trip; returns ``(body, etag)``, ``(None, None)``
        on 404.  Maps transport failures onto the store error taxonomy:
        412 → :class:`StorePreconditionError` (conditional put lost its
        race), 403/405 on a write → :class:`StoreReadOnlyError` (the
        server genuinely refuses writes), 408/429/5xx and timeouts →
        transient."""
        import socket
        from urllib.error import HTTPError, URLError
        from urllib.request import Request, urlopen
        req = Request(f"{self._base}/{rel}", data=data, method=method,
                      headers=dict(headers or {}))
        try:
            with urlopen(req, timeout=self.timeout) as r:
                return r.read(), r.headers.get("ETag")
        except HTTPError as e:
            if e.code == 404:
                return None, None
            if e.code == 412:
                raise StorePreconditionError(
                    f"remote store {self.uri}: conditional {method} {rel} "
                    "lost its race (http 412)") from e
            if e.code in (403, 405) and method in ("PUT", "DELETE"):
                raise StoreReadOnlyError(
                    f"remote store {self.uri} rejected {method} {rel} "
                    f"(http {e.code}); the mirror does not accept writes"
                ) from e
            if e.code in _TRANSIENT_HTTP_CODES:
                raise TransientStoreError(
                    f"remote store {self.uri}: http {e.code} on "
                    f"{method} {rel}") from e
            raise
        except socket.timeout as e:
            raise StoreTimeoutError(
                f"remote store {self.uri}: {method} {rel} timed out "
                f"after {self.timeout:g}s") from e
        except URLError as e:
            if isinstance(e.reason, (socket.timeout, TimeoutError)):
                raise StoreTimeoutError(
                    f"remote store {self.uri}: {method} {rel} timed out "
                    f"after {self.timeout:g}s") from e
            raise TransientStoreError(
                f"remote store {self.uri} unreachable: {e}") from e

    def _get_once(self, rel: str) -> bytes | None:
        return self._request_once("GET", rel)[0]

    def _get(self, rel: str) -> bytes | None:
        return self.retry.call(lambda: self._get_once(rel),
                               what=f"{self.uri}/{rel}",
                               counters=self.counters)

    def _put(self, rel: str, data: bytes,
             headers: dict[str, str] | None = None) -> None:
        """PUT with transient-error retry.  Precondition failures (412) are
        not retried here — they need a re-read, which the caller owns."""
        self.retry.call(lambda: self._request_once("PUT", rel, data, headers),
                        what=f"PUT {self.uri}/{rel}", counters=self.counters)

    def _delete(self, rel: str) -> None:
        self.retry.call(lambda: self._request_once("DELETE", rel),
                        what=f"DELETE {self.uri}/{rel}",
                        counters=self.counters)

    def _deny_write(self) -> None:
        raise StoreReadOnlyError(
            f"store {self.uri} is readonly (http mirror); open it with "
            "writable=True for a conditional-put server, or push from a "
            "writable store instead")

    # -- manifests ----------------------------------------------------------
    def has_manifest(self, key: str) -> bool:
        if self._fs is not None:
            return self._fs.manifest_path(key).exists()
        return self._get(f"manifests/{key}.json") is not None

    def read_manifest(self, key: str) -> dict:
        self.counters["manifest_reads"] += 1
        if self._fs is not None:
            path = self._fs.manifest_path(key)
            if not path.exists():
                raise KeyError(key)
            try:
                return json.loads(path.read_text())
            except json.JSONDecodeError as e:
                self.counters["verify_failures"] += 1
                dest = self._fs.quarantine(path, self.counters)
                raise StoreCorruptionError(
                    f"manifest {key} on mirror {self.uri} failed to parse "
                    f"({e}); quarantined at {dest}") from e
        data = self._get(f"manifests/{key}.json")
        if data is None:
            raise KeyError(key)
        return json.loads(data.decode())

    def write_manifest(self, key: str, payload: dict) -> None:
        if self._fs is None:
            if self.readonly:
                self._deny_write()
            # last-writer-wins is safe for the manifest object itself:
            # manifest keys are content-derived, so two writers racing on
            # one key are publishing descriptions of the same capture
            self._put(f"manifests/{key}.json", json.dumps(payload).encode())
            self.counters["manifest_writes"] += 1
            self._index_changed(add={key})
            return
        self.counters["manifest_writes"] += 1
        _atomic_write(self._fs.manifest_path(key), json.dumps(payload).encode())
        self._update_index()

    def delete_manifest(self, key: str) -> None:
        if self._fs is None:
            if self.readonly:
                self._deny_write()
            self._delete(f"manifests/{key}.json")
            self._index_changed(remove={key})
            return
        self._fs.manifest_path(key).unlink(missing_ok=True)
        self._update_index()

    def manifest_keys(self) -> list[str]:
        if self._fs is not None:
            return self._fs.manifest_keys()
        data = self._get(_INDEX_NAME)
        if data is None:
            return []
        return sorted(json.loads(data.decode()).get("manifests", []))

    def manifest_bytes(self, key: str) -> int:
        if self._fs is not None:
            return self._fs.manifest_path(key).stat().st_size
        data = self._get(f"manifests/{key}.json")
        if data is None:
            raise KeyError(key)
        return len(data)

    def manifest_mtime_ns(self, key: str) -> int:
        if self._fs is not None:
            return self._fs.manifest_path(key).stat().st_mtime_ns
        return 0                              # http mirrors don't expose mtime

    def bulk(self):
        """Context manager deferring the ``index.json`` rewrite to exit —
        one directory scan (fs) / one CAS round (http) per bulk transfer
        instead of one per manifest."""
        import contextlib

        @contextlib.contextmanager
        def _bulk():
            self._bulk_depth += 1
            try:
                yield self
            finally:
                self._bulk_depth -= 1
                if self._bulk_depth == 0:
                    if self._fs is not None:
                        self._update_index(force=True)
                    elif (self._pending_index_adds
                          or self._pending_index_removes):
                        adds = set(self._pending_index_adds)
                        removes = set(self._pending_index_removes)
                        self._pending_index_adds.clear()
                        self._pending_index_removes.clear()
                        self._cas_update_index(add=adds, remove=removes)
        return _bulk()

    def _update_index(self, force: bool = False) -> None:
        """Maintain ``index.json`` so http consumers of this mirror can list."""
        if self._bulk_depth > 0 and not force:
            return
        payload = {"manifests": self._fs.manifest_keys()}
        _atomic_write(self.root / _INDEX_NAME,
                      json.dumps(payload, indent=1).encode())

    def _index_changed(self, add: set[str] = frozenset(),
                       remove: set[str] = frozenset()) -> None:
        """Route an http index delta: defer inside bulk(), else CAS now."""
        if self._bulk_depth > 0:
            self._pending_index_adds |= set(add) - set(remove)
            self._pending_index_removes |= set(remove)
            self._pending_index_adds -= set(remove)
            return
        self._cas_update_index(add=add, remove=remove)

    def _cas_update_index(self, add: set[str] = frozenset(),
                          remove: set[str] = frozenset()) -> None:
        """Compare-and-swap merge of this writer's delta into ``index.json``.

        Read the current listing with its ETag, merge (set union/difference
        — each writer only ever contributes its own keys, so merges from
        any interleaving converge to the same sorted list), then PUT back
        conditionally: ``If-Match: <etag>`` against the copy we read, or
        ``If-None-Match: *`` when the index does not exist yet.  A 412
        means another writer won the slot; re-read and re-merge.  Bounded
        by ``_CAS_ATTEMPTS``; persistent contention surfaces as a
        :class:`TransientStoreError` (the caller's write itself landed —
        only the listing update should be retried)."""
        for _ in range(self._CAS_ATTEMPTS):
            body, etag = self.retry.call(
                lambda: self._request_once("GET", _INDEX_NAME),
                what=f"{self.uri}/{_INDEX_NAME}", counters=self.counters)
            if body is None:
                current: list[str] = []
                cond = {"If-None-Match": "*"}
            else:
                current = list(json.loads(body.decode()).get("manifests", []))
                # no ETag from the server: unconditional replace is the
                # best available (still read-merge-write, just unfenced)
                cond = {"If-Match": etag} if etag else {}
            merged = sorted((set(current) | set(add)) - set(remove))
            if body is not None and merged == sorted(set(current)):
                return                       # already up to date
            payload = json.dumps({"manifests": merged}, indent=1).encode()
            try:
                self._put(_INDEX_NAME, payload, cond)
                return
            except StorePreconditionError:
                self.counters["index_cas_conflicts"] += 1
                # brief yield so racing writers interleave instead of
                # re-colliding in lock-step (no-op sleep under test)
                self.retry.sleep(self.retry.base_delay_s)
                continue
        raise TransientStoreError(
            f"index.json on {self.uri} lost {self._CAS_ATTEMPTS} CAS races; "
            "the manifest write itself landed — retry to repair the listing")

    # -- chunks -------------------------------------------------------------
    def has_chunk(self, digest: str) -> bool:
        if self._fs is not None:
            return self._fs.chunk_path(digest).exists()
        return self._get(f"chunks/{digest[:2]}/{digest}") is not None

    def read_chunk(self, digest: str) -> bytes:
        if self._fs is not None:
            path = self._fs.chunk_path(digest)
            if not path.exists():
                raise KeyError(digest)
            data = path.read_bytes()
            if chunk_digest(data) != digest:
                self.counters["verify_failures"] += 1
                self.counters["chunks_quarantined"] += 1
                dest = self._fs.quarantine(path, self.counters)
                raise ChunkCorruptionError(
                    digest, f"mirror copy on {self.uri} failed digest "
                            f"verification; quarantined at {dest}")
        else:
            data = None
            for _ in range(2):                # one fresh fetch on mismatch
                got = self._get(f"chunks/{digest[:2]}/{digest}")
                if got is None:
                    raise KeyError(digest)
                if chunk_digest(got) == digest:
                    data = got
                    break
                self.counters["verify_failures"] += 1
            if data is None:
                raise ChunkCorruptionError(
                    digest, f"http mirror {self.uri} served bytes that "
                            "failed digest verification twice")
        self.counters["chunk_reads"] += 1
        self.counters["chunk_bytes_read"] += len(data)
        return data

    def write_chunk(self, digest: str, data: bytes) -> None:
        if self._fs is None:
            if self.readonly:
                self._deny_write()
            # idempotent-by-address conditional create: If-None-Match: *
            # makes the PUT a no-op race-safely — a 412 means another
            # writer already published this content address, and content
            # addressing guarantees its bytes equal ours
            try:
                self._put(f"chunks/{digest[:2]}/{digest}", data,
                          {"If-None-Match": "*"})
            except StorePreconditionError:
                self.counters["chunk_dedup_hits"] += 1
                return
            self.counters["chunk_writes"] += 1
            self.counters["chunk_bytes_written"] += len(data)
            return
        path = self._fs.chunk_path(digest)
        if path.exists():
            self.counters["chunk_dedup_hits"] += 1
            return
        self.counters["chunk_writes"] += 1
        self.counters["chunk_bytes_written"] += len(data)
        _atomic_write(path, data)

    def delete_chunk(self, digest: str) -> None:
        if self._fs is None:
            if self.readonly:
                self._deny_write()
            self._delete(f"chunks/{digest[:2]}/{digest}")
            return
        self._fs.chunk_path(digest).unlink(missing_ok=True)

    def chunk_keys(self) -> list[str]:
        if self._fs is not None:
            return self._fs.chunk_keys()
        raise NotImplementedError("http mirrors do not enumerate chunks")

    def chunk_bytes(self, digest: str) -> int:
        if self._fs is not None:
            return self._fs.chunk_path(digest).stat().st_size
        data = self._get(f"chunks/{digest[:2]}/{digest}")
        if data is None:
            raise KeyError(digest)
        return len(data)


def open_store(uri: "str | Path | Store", *, timeout: float | None = None,
               retry: "RetryPolicy | None" = None,
               writable: bool = False) -> "Store":
    """Map a ``--store`` value onto a Store: an existing Store passes
    through; a URI (``file://``, ``http(s)://``) opens a RemoteStore; a
    plain path opens a LocalStore rooted there.  ``timeout`` (http read
    deadline, seconds), ``retry`` and ``writable`` (conditional-put writes
    against http(s) servers that support them) apply only when a new
    RemoteStore / LocalStore is constructed here."""
    if isinstance(uri, (LocalStore, RemoteStore)):
        return uri
    if not isinstance(uri, (str, Path)):
        # duck-typed Store implementations (e.g. test doubles)
        if isinstance(uri, Store):
            return uri
        raise TypeError(f"cannot open a store from {type(uri).__name__}")
    text = str(uri)
    if "://" in text:
        return RemoteStore(text, timeout=timeout, retry=retry,
                           writable=writable)
    return LocalStore(text, retry=retry)
