"""Root-cause diagnosis (the paper's Algorithm 2, adapted to JAX/XLA).

FINDDEVIATIONPOINT: compare the call paths (user stack frames recorded by the
JAX tracer per equation) of the operators in a matched-but-unequal region and
report the last common frame before they diverge.

FINDKEYVAR: the paper re-runs with basic-block instrumentation to find the
branch variable that selects a different GPU kernel.  In JAX the kernel
selection is driven by *declarative* operator attributes and global config,
so the key variable is recovered by diffing (1) the jaxpr equation params of
corresponding operators — ``precision``, ``preferred_element_type``, dtypes,
``dimension_numbers`` — and (2) a registered configuration snapshot
(jax.config flags / model-config dataclasses).  See DESIGN.md §2 for why
basic-block tracing has no TPU analogue.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

from repro.core.graph import OpGraph, OpNode

# The closed root-cause taxonomy.  Everything downstream — report
# serialization, golden baselines (repro.testing.baselines), and the
# mutation engine's expected-classification table (repro.testing.mutate) —
# validates against this tuple instead of re-spelling the strings.
DIAGNOSIS_KINDS = ("api_difference", "param_difference", "config_difference")

# Finer-grained waste classes, one per mutation in the engine's taxonomy
# (repro.testing.mutate.MUTATIONS).  The 3 coarse DIAGNOSIS_KINDS say *how*
# the sides differ; the subkind says *which inverse rewrite* would remove
# the waste (repro.optimize keys its rewrite registry on these names).
# ``Diagnosis.subkind`` is None when no class fits — reports and golden
# baselines serialized before the field existed load unchanged.
DIAGNOSIS_SUBKINDS = (
    "dtype_upcast",         # param: dot precision forced to HIGHEST
    "redundant_recompute",  # api: an identical contraction appears twice
    "sync_in_loop",         # api: collective inside the hot region
    "oversized_padding",    # api: pad + slice round-trip around an op
    "op_split",             # api: fused transcendental decomposed by hand
    "scan_body",            # param: scan body jaxpr diverges
    "layout_thrash",        # api: transpose round-trips around an op
    "storage_upcast",       # api: bf16 values bounced through f32
)

# Primitive families used to refine a coarse kind into a subkind.  Closed
# world by design: these mirror what the mutation taxonomy can plant (and
# what the inverse rewrites can remove), not everything XLA can emit.
_COLLECTIVE_PRIMS = frozenset({
    "psum", "psum2", "pbroadcast", "all_reduce", "all_gather", "all_to_all",
    "ppermute", "reduce_scatter", "shard_map", "pmin", "pmax"})
_CONTRACTION_PRIMS = ("dot_general", "conv_general_dilated")
_ELEMENTWISE_PRIMS = frozenset({
    "add", "sub", "mul", "div", "neg", "exp", "log", "log1p", "expm1",
    "tanh", "logistic", "rsqrt", "sqrt", "clamp", "max", "min", "pow",
    "integer_pow", "abs", "sign", "erf", "floor", "ceil", "select_n",
    "broadcast_in_dim", "convert_element_type"})


@dataclasses.dataclass
class Diagnosis:
    kind: str                       # one of DIAGNOSIS_KINDS
    deviation_point: str            # last common call frame
    detail: str
    key_variables: list[str]        # differing eqn params / config keys
    ops_a: list[str]
    ops_b: list[str]
    # which energy backend's numbers this diagnosis rests on (the session
    # backend label, e.g. 'tpu_v5e' / 'hlo+tpu_v5e' / 'replay'); None on
    # reports serialized before the field existed.  A ' [degraded]' suffix
    # means some rung of the session's degradation ladder fired — the
    # report's meta['degraded'] lists exactly what was downgraded.
    priced_by: str | None = None
    # one of DIAGNOSIS_SUBKINDS, or None when the region does not match any
    # known waste class (or the report predates the field)
    subkind: str | None = None

    @property
    def degraded(self) -> bool:
        from repro.core.session import DEGRADED_MARK
        return bool(self.priced_by) and DEGRADED_MARK in self.priced_by

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "Diagnosis":
        return cls(kind=d["kind"], deviation_point=d["deviation_point"],
                   detail=d["detail"],
                   key_variables=list(d["key_variables"]),
                   ops_a=list(d["ops_a"]), ops_b=list(d["ops_b"]),
                   priced_by=d.get("priced_by"),
                   subkind=d.get("subkind"))


def _common_prefix(p1: Sequence[str], p2: Sequence[str]) -> int:
    n = 0
    for a, b in zip(p1, p2):
        if a != b:
            break
        n += 1
    return n


def find_deviation_point(paths_a: Sequence[tuple[str, ...]],
                         paths_b: Sequence[tuple[str, ...]]) -> str:
    """Last common frame across the two sides' operator call paths."""
    best_frame = "<program entry>"
    best_len = -1
    for pa in paths_a:
        for pb in paths_b:
            n = _common_prefix(pa, pb)
            if n > best_len and n > 0:
                best_len = n
                best_frame = pa[n - 1]
    return best_frame


_KEY_PARAMS = ("precision", "preferred_element_type", "dimension_numbers",
               "new_dtype", "dtype", "dimensions", "permutation", "axes",
               "feature_group_count", "window_strides", "k", "is_stable",
               "exhaustively", "accum_dtype")


def _param_repr(v: Any) -> str:
    s = repr(v)
    return s if len(s) <= 80 else s[:77] + "..."


def diff_eqn_params(node_a: OpNode, node_b: OpNode) -> list[str]:
    out = []
    keys = set(node_a.params) | set(node_b.params)
    for k in sorted(keys):
        if k not in _KEY_PARAMS and not (k in node_a.params and k in node_b.params):
            continue
        va, vb = node_a.params.get(k), node_b.params.get(k)
        if _param_repr(va) != _param_repr(vb):
            out.append(f"{k}: A={_param_repr(va)} vs B={_param_repr(vb)}")
    return out


def diff_config(config_a: Mapping[str, Any] | None,
                config_b: Mapping[str, Any] | None) -> list[str]:
    if not config_a or not config_b:
        return []
    out = []
    for k in sorted(set(config_a) | set(config_b)):
        va, vb = config_a.get(k), config_b.get(k)
        if va != vb:
            out.append(f"config[{k!r}]: A={va!r} vs B={vb!r}")
    return out


def _op_multiset(graph: OpGraph, idxs: Sequence[int]) -> list[str]:
    return sorted(graph.nodes[i].primitive for i in idxs)


def infer_subkind(kind: str, ops_wasteful: Sequence[str],
                  ops_efficient: Sequence[str],
                  key_variables: Sequence[str]) -> str | None:
    """Refine a coarse diagnosis kind into a DIAGNOSIS_SUBKINDS entry.

    ``ops_wasteful``/``ops_efficient`` are the region op multisets oriented
    by which side the energy backend flagged.  Returns None when the region
    does not look like any known waste class — callers must treat that as
    "no automated rewrite available", not as an error.
    """
    if kind == "api_difference":
        from collections import Counter
        extra = Counter(ops_wasteful) - Counter(ops_efficient)
        if not extra:
            return None
        # ordered from most to least specific: a collective beats any
        # structural tell, movement ops beat the elementwise catch-all
        if any(p in _COLLECTIVE_PRIMS for p in extra):
            return "sync_in_loop"
        if extra.get("transpose", 0) >= 2:
            return "layout_thrash"
        if extra.get("pad", 0):
            return "oversized_padding"
        # storage bounces add *only* converts (the bounced ops keep their
        # primitive); a mixed bag of extras that merely includes converts
        # (e.g. an inlined clip's literal casts) is not a storage upcast
        if extra.get("convert_element_type", 0) and \
                set(extra) <= {"convert_element_type", "broadcast_in_dim"}:
            return "storage_upcast"
        if any(extra.get(p, 0) for p in _CONTRACTION_PRIMS):
            return "redundant_recompute"
        if all(p in _ELEMENTWISE_PRIMS for p in extra):
            return "op_split"
        return None
    # param/config difference: op multisets agree, so the tell is *which*
    # attribute diverged
    if any(".precision" in kv or "precision" in kv.split(":", 1)[0]
           for kv in key_variables):
        return "dtype_upcast"
    if any(kv.startswith("scan.") for kv in key_variables):
        return "scan_body"
    if any(".preferred_element_type" in kv or ".accum_dtype" in kv
           for kv in key_variables):
        return "dtype_upcast"
    # diverging scan bodies can evade the param diff when the truncated
    # jaxpr reprs share a prefix; the scan super-node itself is the tell
    if "scan" in ops_wasteful and "scan" in ops_efficient:
        return "scan_body"
    return None


def diagnose_region(graph_a: OpGraph, nodes_a: Sequence[int],
                    graph_b: OpGraph, nodes_b: Sequence[int],
                    *,
                    config_a: Mapping[str, Any] | None = None,
                    config_b: Mapping[str, Any] | None = None,
                    priced_by: str | None = None,
                    wasteful_side: str = "A") -> Diagnosis:
    """Explain why two equivalent regions consume different energy.

    ``priced_by`` names the energy backend whose numbers flagged the region
    (recorded on the diagnosis so reports can cite their pricing source).
    ``wasteful_side`` ('A' or 'B') orients the subkind inference toward the
    side the backend says burns more energy.
    """
    ops_a = _op_multiset(graph_a, nodes_a)
    ops_b = _op_multiset(graph_b, nodes_b)
    paths_a = [graph_a.nodes[i].call_path for i in nodes_a if graph_a.nodes[i].call_path]
    paths_b = [graph_b.nodes[i].call_path for i in nodes_b if graph_b.nodes[i].call_path]
    deviation = find_deviation_point(paths_a, paths_b)

    cfg_diffs = diff_config(config_a, config_b)

    ops_w, ops_e = ((ops_a, ops_b) if wasteful_side == "A"
                    else (ops_b, ops_a))

    if ops_a != ops_b:
        only_a = sorted(set(ops_a) - set(ops_b))
        only_b = sorted(set(ops_b) - set(ops_a))
        extra_a = len(ops_a) - len(ops_b)
        detail = (f"different operator combinations: A uses {only_a or '(same set)'} "
                  f"({len(ops_a)} ops), B uses {only_b or '(same set)'} "
                  f"({len(ops_b)} ops, Δ{extra_a:+d})")
        return Diagnosis(kind="api_difference", deviation_point=deviation,
                         detail=detail,
                         key_variables=cfg_diffs, ops_a=ops_a, ops_b=ops_b,
                         priced_by=priced_by,
                         subkind=infer_subkind("api_difference", ops_w,
                                               ops_e, cfg_diffs))

    # same operator multiset -> same API, look for param/config differences
    # pair same-primitive ops in topological order and diff params
    key_vars: list[str] = list(cfg_diffs)
    by_prim_a: dict[str, list[int]] = {}
    by_prim_b: dict[str, list[int]] = {}
    for i in nodes_a:
        by_prim_a.setdefault(graph_a.nodes[i].primitive, []).append(i)
    for i in nodes_b:
        by_prim_b.setdefault(graph_b.nodes[i].primitive, []).append(i)
    for prim, ia_list in by_prim_a.items():
        for ia, ib in zip(ia_list, by_prim_b.get(prim, [])):
            key_vars.extend(f"{prim}.{d}" for d in
                            diff_eqn_params(graph_a.nodes[ia], graph_b.nodes[ib]))
    kind = "config_difference" if cfg_diffs else "param_difference"
    detail = ("same operators, diverging attributes/configuration"
              if key_vars else
              "same operators and attributes; energy difference stems from "
              "tensor shapes/layouts feeding this region")
    key_vars = sorted(set(key_vars))
    return Diagnosis(kind=kind, deviation_point=deviation, detail=detail,
                     key_variables=key_vars, ops_a=ops_a,
                     ops_b=ops_b, priced_by=priced_by,
                     subkind=infer_subkind(kind, ops_w, ops_e, key_vars))
