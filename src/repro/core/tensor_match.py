"""Semantically-equivalent tensor matching via multi-mode SVD invariants.

Implements the paper's §4.2 tensor matcher: layout transformations (permute,
reshape) reorder entries but preserve (a) every entry-symmetric statistic and
(b) the singular-value spectra of the *corresponding* tensor unfoldings.  Two
tensors are declared equivalent when their cheap symmetric invariants agree
within tolerance AND at least one pair of equal-length unfolding spectra
matches (Hypothesis 1 requires this to hold for every probed model input).

For tensors too large for dense SVDs we fall back to the symmetric invariants
only, which are still exact under permute/reshape (they are functions of the
entry multiset).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable, Sequence

import numpy as np


@dataclasses.dataclass
class TensorSignature:
    numel: int
    dtype: str
    # entry-symmetric invariants (exact under any permute/reshape)
    l1: float
    l2: float
    mean: float
    amax: float
    amin: float
    # invariant SET S(T): spectra of ALL unfoldings, keyed by sorted matrix
    # dims (rows, cols) with rows <= cols so transposed unfoldings compare
    # equal.  Each key holds the list of spectra for that unfolding shape —
    # a permutation of axes permutes WHICH unfolding produces WHICH spectrum,
    # so matching is set-wise per key.
    spectra: dict[tuple[int, int], list[np.ndarray]] | None

    def is_degenerate(self) -> bool:
        return self.numel < 2 or not np.isfinite(self.l2)


def _unfoldings(shape: tuple[int, ...]) -> list[tuple[tuple[int, ...], tuple[int, ...]]]:
    r = len(shape)
    axes = list(range(r))
    outs = []
    seen = set()
    for k in range(1, r):
        for G in itertools.combinations(axes, k):
            Gc = tuple(a for a in axes if a not in G)
            key = frozenset((G, Gc))
            if key in seen:
                continue
            seen.add(key)
            outs.append((G, Gc))
    return outs


def signature(arr: np.ndarray, *, max_svd_numel: int = 1 << 20,
              max_order: int = 5, max_unfoldings: int = 16) -> TensorSignature:
    a = np.asarray(arr)
    if a.dtype.kind == "c":
        a = np.abs(a).astype(np.float64)   # complex: layout-invariant modulus
    elif a.dtype.kind in "biu?":
        a = a.astype(np.float64)
    else:
        a = a.astype(np.float64, copy=False)
    flat = a.ravel()
    numel = flat.size
    l1 = float(np.sum(np.abs(flat))) if numel else 0.0
    l2 = float(np.sqrt(np.sum(flat * flat))) if numel else 0.0
    mean = float(np.mean(flat)) if numel else 0.0
    amax = float(np.max(flat)) if numel else 0.0
    amin = float(np.min(flat)) if numel else 0.0

    spectra: dict[tuple[int, int], list[np.ndarray]] | None = None
    shape = tuple(int(s) for s in np.shape(arr))
    r = len(shape)
    if 2 <= numel <= max_svd_numel and 1 <= r <= max_order:
        spectra = {}
        unfs = _unfoldings(shape) if r >= 2 else [((0,), ())]
        if r == 1:
            m = a.reshape(1, -1)
            s = np.linalg.svd(m, compute_uv=False)
            spectra[(1, numel)] = [s]
        else:
            for G, Gc in unfs[:max_unfoldings]:
                rows = int(np.prod([shape[i] for i in G], dtype=np.int64))
                cols = int(np.prod([shape[i] for i in Gc], dtype=np.int64))
                m = np.transpose(a, G + Gc).reshape(rows, cols)
                if rows > cols:
                    rows, cols = cols, rows
                try:
                    s = np.linalg.svd(m, compute_uv=False)
                except np.linalg.LinAlgError:
                    continue
                spectra.setdefault((rows, cols), []).append(np.sort(s)[::-1])
    return TensorSignature(numel=numel, dtype=str(np.asarray(arr).dtype),
                           l1=l1, l2=l2, mean=mean, amax=amax, amin=amin,
                           spectra=spectra)


def _close(x: float, y: float, rtol: float) -> bool:
    scale = max(abs(x), abs(y), 1e-30)
    return abs(x - y) <= rtol * scale


def signatures_match(a: TensorSignature, b: TensorSignature, *,
                     rtol: float = 1e-3) -> bool:
    """Hypothesis-1 equivalence test for one input sample."""
    if a.is_degenerate() or b.is_degenerate():
        return False
    if a.numel != b.numel:
        return False
    for xa, xb in ((a.l1, b.l1), (a.l2, b.l2), (a.mean, b.mean),
                   (a.amax, b.amax), (a.amin, b.amin)):
        if not _close(xa, xb, rtol):
            return False
    if a.spectra is None or b.spectra is None:
        return True  # symmetric invariants only (large tensors)
    shared = set(a.spectra) & set(b.spectra)
    if not shared:
        # No unfolding with common matrix dims (exotic reshape): fall back to
        # the symmetric invariants, which already passed.
        return True

    def spec_close(sa: np.ndarray, sb: np.ndarray) -> bool:
        n = min(len(sa), len(sb))
        denom = float(np.linalg.norm(sa[:n])) + 1e-30
        return float(np.linalg.norm(sa[:n] - sb[:n])) / denom <= rtol * 10

    # set-wise match per key (the paper's invariant set S(T)): every spectrum
    # on the smaller side must find a distinct partner on the other side.
    for key in shared:
        la, lb = a.spectra[key], b.spectra[key]
        small, big = (la, lb) if len(la) <= len(lb) else (lb, la)
        used: set[int] = set()
        for sa in small:
            hit = None
            for j, sb in enumerate(big):
                if j not in used and spec_close(sa, sb):
                    hit = j
                    break
            if hit is None:
                return False
            used.add(hit)
    return True


@dataclasses.dataclass
class TensorMatcher:
    """Matches tensors across two graphs from one or more value captures."""

    rtol: float = 1e-3
    max_svd_numel: int = 1 << 20
    min_numel: int = 2

    def _sig_table(self, values: dict[int, np.ndarray]) -> dict[int, TensorSignature]:
        out = {}
        for tid, val in values.items():
            if np.size(val) < self.min_numel:
                continue
            out[tid] = signature(val, max_svd_numel=self.max_svd_numel)
        return out

    def match(self, values_a: Sequence[dict[int, np.ndarray]],
              values_b: Sequence[dict[int, np.ndarray]]) -> list[tuple[int, int]]:
        """Return (tid_a, tid_b) pairs equivalent under EVERY input sample.

        ``values_a[k]`` / ``values_b[k]`` are tensor-id -> value maps captured
        from the two graphs on the k-th identical model input.
        """
        if len(values_a) != len(values_b) or not values_a:
            raise ValueError("need the same nonzero number of captures per side")
        sig_a = [self._sig_table(v) for v in values_a]
        sig_b = [self._sig_table(v) for v in values_b]
        tids_a = set(sig_a[0])
        tids_b = set(sig_b[0])
        for t in sig_a[1:]:
            tids_a &= set(t)
        for t in sig_b[1:]:
            tids_b &= set(t)

        # bucket by numel to avoid the full cross product in practice
        by_numel: dict[int, list[int]] = {}
        for tb in tids_b:
            by_numel.setdefault(sig_b[0][tb].numel, []).append(tb)

        pairs: list[tuple[int, int]] = []
        for ta in sorted(tids_a):
            for tb in by_numel.get(sig_a[0][ta].numel, ()):  # candidates
                ok = all(signatures_match(sa[ta], sb[tb], rtol=self.rtol)
                         for sa, sb in zip(sig_a, sig_b))
                if ok:
                    pairs.append((ta, tb))
        return pairs


def bijective_pairs(pairs: Iterable[tuple[int, int]]) -> list[tuple[int, int]]:
    """Keep only pairs whose endpoints match exactly one partner each.

    Ambiguous matches (a tensor numerically equal to several peers, e.g. a
    value and its copy) cannot serve as cut points; Algorithm 1 needs
    unambiguous correspondences.
    """
    count_a: dict[int, int] = {}
    count_b: dict[int, int] = {}
    plist = list(pairs)
    for a, b in plist:
        count_a[a] = count_a.get(a, 0) + 1
        count_b[b] = count_b.get(b, 0) + 1
    return [(a, b) for a, b in plist if count_a[a] == 1 and count_b[b] == 1]
