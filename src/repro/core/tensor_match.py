"""Semantically-equivalent tensor matching via multi-mode SVD invariants.

Implements the paper's §4.2 tensor matcher: layout transformations (permute,
reshape) reorder entries but preserve (a) every entry-symmetric statistic and
(b) the singular-value spectra of the *corresponding* tensor unfoldings.  Two
tensors are declared equivalent when their cheap symmetric invariants agree
within tolerance AND at least one pair of equal-length unfolding spectra
matches (Hypothesis 1 requires this to hold for every probed model input).

Two matching engines live here:

* ``TensorMatcher.match`` / ``match_streamed`` — the production two-phase
  path.  Phase 1 buckets candidates by ``(numel, quantized-l2 key)`` with
  neighbour-bucket probing (exhaustive-equivalent: any pair within ``rtol``
  lands in the same or an adjacent bucket) and applies the cheap symmetric
  gate, collapsing the per-numel cross product.  Phase 2 computes unfolding
  SVD spectra *lazily*, memoized per ``(tid, unfolding-key)``, only for pairs
  that survive the cheap gate — fetching tensor values through a selective
  capture callback so nothing is materialized up front.  Tensors above
  ``max_svd_numel`` get a randomized-sketch spectral test (top-k singular
  values via a randomized range finder) instead of the historical
  invariants-only fallback.

* ``TensorMatcher.match_exhaustive`` — the original eager matcher, kept as
  the reference oracle: it materializes every signature (all unfolding SVDs)
  up front and compares all numel-bucketed pairs.  ``tests/test_matcher_fast``
  asserts the two return identical pair sets on the pipeline workloads.
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
import math
import time
from typing import Callable, Iterable, Protocol, Sequence

import numpy as np


@dataclasses.dataclass
class TensorSignature:
    numel: int
    dtype: str
    # entry-symmetric invariants (exact under any permute/reshape)
    l1: float
    l2: float
    mean: float
    amax: float
    amin: float
    # invariant SET S(T): spectra of ALL unfoldings, keyed by sorted matrix
    # dims (rows, cols) with rows <= cols so transposed unfoldings compare
    # equal.  Each key holds the list of spectra for that unfolding shape —
    # a permutation of axes permutes WHICH unfolding produces WHICH spectrum,
    # so matching is set-wise per key.  None for streamed (cheap-only)
    # signatures: the lazy matcher computes spectra on demand instead.
    spectra: dict[tuple[int, int], list[np.ndarray]] | None
    shape: tuple[int, ...] | None = None

    def is_degenerate(self) -> bool:
        return self.numel < 2 or not np.isfinite(self.l2)


def _unfoldings(shape: tuple[int, ...]) -> list[tuple[tuple[int, ...], tuple[int, ...]]]:
    r = len(shape)
    axes = list(range(r))
    outs = []
    seen = set()
    for k in range(1, r):
        for G in itertools.combinations(axes, k):
            Gc = tuple(a for a in axes if a not in G)
            key = frozenset((G, Gc))
            if key in seen:
                continue
            seen.add(key)
            outs.append((G, Gc))
    return outs


@functools.lru_cache(maxsize=4096)
def _unfolding_key_map(
    shape: tuple[int, ...], limit: int,
) -> dict[tuple[int, int], tuple[tuple[tuple[int, ...], tuple[int, ...]], ...]]:
    """Unfolding (rows, cols) keys -> axis splits, truncated like signature().

    Pure function of the shape, so it is memoized globally: the lazy matcher
    consults it to know which spectra a pair COULD share before computing any.
    """
    numel = int(np.prod(shape, dtype=np.int64)) if shape else 1
    if len(shape) <= 1:
        return {(1, numel): (((0,), ()),)}
    out: dict[tuple[int, int], list] = {}
    for G, Gc in _unfoldings(shape)[:limit]:
        rows = int(np.prod([shape[i] for i in G], dtype=np.int64))
        cols = int(np.prod([shape[i] for i in Gc], dtype=np.int64))
        key = (rows, cols) if rows <= cols else (cols, rows)
        out.setdefault(key, []).append((G, Gc))
    return {k: tuple(v) for k, v in out.items()}


# ---------------------------------------------------------------------------
# cheap symmetric invariants
# ---------------------------------------------------------------------------

def _to_float64(a: np.ndarray) -> np.ndarray:
    if a.dtype.kind == "c":
        return np.abs(a).astype(np.float64)   # complex: layout-invariant modulus
    if a.dtype.kind in "biu?":
        return a.astype(np.float64)
    return a.astype(np.float64, copy=False)


def _cheap_stats_np(a: np.ndarray) -> tuple[float, float, float, float, float]:
    """(l1, l2, mean, amax, amin) in float64 — the oracle's exact formulas."""
    flat = _to_float64(a).ravel()
    numel = flat.size
    l1 = float(np.sum(np.abs(flat))) if numel else 0.0
    l2 = float(np.sqrt(np.sum(flat * flat))) if numel else 0.0
    mean = float(np.mean(flat)) if numel else 0.0
    amax = float(np.max(flat)) if numel else 0.0
    amin = float(np.min(flat)) if numel else 0.0
    return l1, l2, mean, amax, amin


_JITTED_STATS = None
_JIT_STATS_MIN_NUMEL = 4096
_JIT_DTYPES = ("float32", "bfloat16", "float16")


def _jitted_stats_fn():
    """Fused one-pass reduction (l1, sum(x^2), mean, max, min), jit-cached."""
    global _JITTED_STATS
    if _JITTED_STATS is None:
        import jax
        import jax.numpy as jnp

        @jax.jit
        def _stats(x):
            flat = x.astype(jnp.float32).ravel()
            return (jnp.sum(jnp.abs(flat)), jnp.sum(flat * flat),
                    jnp.mean(flat), jnp.max(flat), jnp.min(flat))

        _JITTED_STATS = _stats
    return _JITTED_STATS


def stats_signature(arr, *, use_jit: bool = True) -> TensorSignature:
    """Cheap symmetric invariants of one tensor; no spectra computed.

    This is the streaming-capture reduction: for float tensors of at least
    ``_JIT_STATS_MIN_NUMEL`` elements the five invariants come from one fused
    jitted pass (float32 accumulation); everything else uses the same float64
    numpy formulas as the exhaustive ``signature()`` so the cheap gate is
    bit-compatible with the oracle.
    """
    shape = tuple(int(s) for s in np.shape(arr))
    numel = int(np.prod(shape, dtype=np.int64)) if shape else 1
    dtype = str(getattr(arr, "dtype", np.asarray(arr).dtype))
    if numel == 0:
        return TensorSignature(numel=0, dtype=dtype, l1=0.0, l2=0.0, mean=0.0,
                               amax=0.0, amin=0.0, spectra=None, shape=shape)
    if use_jit and numel >= _JIT_STATS_MIN_NUMEL and dtype in _JIT_DTYPES:
        l1, l2sq, mean, amax, amin = (float(np.asarray(v))
                                      for v in _jitted_stats_fn()(arr))
        l2 = math.sqrt(max(l2sq, 0.0))
    else:
        l1, l2, mean, amax, amin = _cheap_stats_np(np.asarray(arr))
    return TensorSignature(numel=numel, dtype=dtype, l1=l1, l2=l2, mean=mean,
                           amax=amax, amin=amin, spectra=None, shape=shape)


def signature(arr: np.ndarray, *, max_svd_numel: int = 1 << 20,
              max_order: int = 5, max_unfoldings: int = 16) -> TensorSignature:
    """Full (eager) signature: cheap invariants + all unfolding SVD spectra."""
    a = _to_float64(np.asarray(arr))
    numel = a.size
    l1, l2, mean, amax, amin = _cheap_stats_np(np.asarray(arr))

    spectra: dict[tuple[int, int], list[np.ndarray]] | None = None
    shape = tuple(int(s) for s in np.shape(arr))
    r = len(shape)
    if 2 <= numel <= max_svd_numel and 1 <= r <= max_order:
        spectra = {}
        unfs = _unfoldings(shape) if r >= 2 else [((0,), ())]
        if r == 1:
            m = a.reshape(1, -1)
            s = np.linalg.svd(m, compute_uv=False)
            spectra[(1, numel)] = [s]
        else:
            for G, Gc in unfs[:max_unfoldings]:
                rows = int(np.prod([shape[i] for i in G], dtype=np.int64))
                cols = int(np.prod([shape[i] for i in Gc], dtype=np.int64))
                m = np.transpose(a, G + Gc).reshape(rows, cols)
                if rows > cols:
                    rows, cols = cols, rows
                try:
                    s = np.linalg.svd(m, compute_uv=False)
                except np.linalg.LinAlgError:
                    continue
                spectra.setdefault((rows, cols), []).append(np.sort(s)[::-1])
    return TensorSignature(numel=numel, dtype=str(np.asarray(arr).dtype),
                           l1=l1, l2=l2, mean=mean, amax=amax, amin=amin,
                           spectra=spectra, shape=shape)


# ---------------------------------------------------------------------------
# matching predicates (shared by the oracle and the lazy path)
# ---------------------------------------------------------------------------

def _close(x: float, y: float, rtol: float) -> bool:
    scale = max(abs(x), abs(y), 1e-30)
    return abs(x - y) <= rtol * scale


def _invariants_match(a: TensorSignature, b: TensorSignature,
                      rtol: float) -> bool:
    """The cheap symmetric-invariant gate (phase 1)."""
    if a.is_degenerate() or b.is_degenerate():
        return False
    if a.numel != b.numel:
        return False
    for xa, xb in ((a.l1, b.l1), (a.l2, b.l2), (a.mean, b.mean),
                   (a.amax, b.amax), (a.amin, b.amin)):
        if not _close(xa, xb, rtol):
            return False
    return True


def _spec_close(sa: np.ndarray, sb: np.ndarray, tol: float) -> bool:
    n = min(len(sa), len(sb))
    denom = float(np.linalg.norm(sa[:n])) + 1e-30
    return float(np.linalg.norm(sa[:n] - sb[:n])) / denom <= tol


def _setwise_match(la: Sequence[np.ndarray], lb: Sequence[np.ndarray],
                   tol: float) -> bool:
    """Set-wise spectra match for one unfolding key (the paper's S(T)):
    every spectrum on the smaller side must find a distinct partner."""
    small, big = (la, lb) if len(la) <= len(lb) else (lb, la)
    used: set[int] = set()
    for sa in small:
        hit = None
        for j, sb in enumerate(big):
            if j not in used and _spec_close(sa, sb, tol):
                hit = j
                break
        if hit is None:
            return False
        used.add(hit)
    return True


def signatures_match(a: TensorSignature, b: TensorSignature, *,
                     rtol: float = 1e-3) -> bool:
    """Hypothesis-1 equivalence test for one input sample (eager spectra)."""
    if not _invariants_match(a, b, rtol):
        return False
    if a.spectra is None or b.spectra is None:
        return True  # symmetric invariants only (large tensors)
    shared = set(a.spectra) & set(b.spectra)
    if not shared:
        # No unfolding with common matrix dims (exotic reshape): fall back to
        # the symmetric invariants, which already passed.
        return True
    for key in shared:
        if not _setwise_match(a.spectra[key], b.spectra[key], rtol * 10):
            return False
    return True


# ---------------------------------------------------------------------------
# lazy spectra (phase 2)
# ---------------------------------------------------------------------------

def _sketch_spectrum(m: np.ndarray, rank: int, oversample: int,
                     n_iter: int = 2, seed: int = 0) -> np.ndarray:
    """Randomized top-``rank`` singular values of ``m`` (Halko et al.).

    A Gaussian range finder with ``n_iter`` power iterations: O(numel * k)
    instead of a dense SVD, giving tensors above ``max_svd_numel`` a real
    spectral test.  Deterministic (fixed seed) so repeated queries agree.
    """
    rows, cols = m.shape
    if rows > cols:
        m = m.T
        rows, cols = cols, rows
    k = min(rank + oversample, rows)
    rng = np.random.default_rng(seed)
    omega = rng.standard_normal((cols, k)).astype(m.dtype)
    y = m @ omega
    for _ in range(n_iter):
        y = m @ (m.T @ y)
        y, _ = np.linalg.qr(y)
    q, _ = np.linalg.qr(y)
    b = q.T @ m
    s = np.linalg.svd(b, compute_uv=False)
    return np.sort(s)[::-1][:rank].astype(np.float64)


def _svd_mode(sig: TensorSignature, m: "TensorMatcher") -> str:
    """'dense' | 'sketch' | 'none' spectral test for this tensor (by shape)."""
    shape = sig.shape or ()
    r = len(shape)
    if 2 <= sig.numel <= m.max_svd_numel and 1 <= r <= m.max_order:
        return "dense"
    if m.sketch_large and sig.numel > m.max_svd_numel and r >= 2:
        return "sketch"
    return "none"


def _sig_keys(sig: TensorSignature, m: "TensorMatcher") -> set[tuple[int, int]]:
    mode = _svd_mode(sig, m)
    if mode == "none":
        return set()
    limit = m.max_unfoldings if mode == "dense" else m.sketch_unfoldings
    return set(_unfolding_key_map(sig.shape or (), limit))


class SpectraProvider(Protocol):
    """Persisted phase-2 replay evidence for one artifact side.

    ``digest`` returns the recorded sha256 of a value's raw bytes (or None
    when unknown); ``record_digest`` derives + persists it from an
    in-memory value.  ``spectra``/``record_spectra`` round-trip memoized
    unfolding spectra keyed ``(sample, tid, (rows, cols))``.  Artifacts
    implement this (``CandidateArtifact.spectra_provider``) so matching
    decisions survive into the manifest and replay without raw values.
    """

    def digest(self, k: int, tid: int) -> str | None: ...
    def record_digest(self, k: int, tid: int, value: np.ndarray) -> str: ...
    def spectra(self, k: int, tid: int,
                key: tuple[int, int]) -> list[np.ndarray] | None: ...
    def record_spectra(self, k: int, tid: int, key: tuple[int, int],
                       spectra: list[np.ndarray]) -> None: ...


class _MemoProvider:
    """Run-local SpectraProvider for provider-less matches (in-memory
    ``match()``, oracle comparisons): same interface, nothing persisted."""

    def __init__(self):
        self._digests: dict[tuple[int, int], str] = {}
        self._spectra: dict[tuple[int, int, tuple[int, int]],
                            list[np.ndarray]] = {}

    def digest(self, k, tid):
        return self._digests.get((k, tid))

    def record_digest(self, k, tid, value):
        import hashlib
        d = self._digests.get((k, tid))
        if d is None:
            d = hashlib.sha256(
                np.ascontiguousarray(value).tobytes()).hexdigest()
            self._digests[(k, tid)] = d
        return d

    def spectra(self, k, tid, key):
        return self._spectra.get((k, tid, key))

    def record_spectra(self, k, tid, key, spectra):
        self._spectra[(k, tid, key)] = spectra


class _LazySpectra:
    """Per-sample memoized unfolding spectra with selective value fetch.

    Holds the streamed cheap signatures of one graph side on one input
    sample, plus a ``fetch(tids) -> {tid: value}`` callback (a selective
    re-capture).  Spectra are computed on first use and memoized per
    ``(tid, unfolding-key)``; values are fetched in one batch via
    :meth:`prefetch` so the capture runs at most once per sample.

    A :class:`SpectraProvider` (persisted digests + spectra from a prior
    run of the same comparison) is consulted first: when it can answer,
    no value is fetched at all — the sketch-only offline replay path.
    """

    def __init__(self, sigs: dict[int, TensorSignature],
                 fetch: Callable[[Sequence[int]], dict[int, np.ndarray]],
                 matcher: "TensorMatcher",
                 provider: "SpectraProvider | None" = None,
                 sample: int = 0):
        self._sigs = sigs
        self._fetch = fetch
        self._m = matcher
        self._provider = provider if provider is not None else _MemoProvider()
        self._k = sample
        self._values: dict[int, np.ndarray] = {}
        self._spectra: dict[tuple[int, tuple[int, int]], list[np.ndarray]] = {}
        self.fetched_bytes = 0
        self.dense_svds = 0
        self.sketch_svds = 0

    def mode(self, tid: int) -> str:
        return _svd_mode(self._sigs[tid], self._m)

    def keys(self, tid: int) -> set[tuple[int, int]]:
        return _sig_keys(self._sigs[tid], self._m)

    def prefetch(self, tids: Iterable[int]) -> None:
        missing = sorted(t for t in tids if t not in self._values)
        if not missing:
            return
        got = self._fetch(missing)
        for t in missing:
            v = np.asarray(got[t])
            self._values[t] = v
            self.fetched_bytes += v.nbytes

    def _value(self, tid: int) -> np.ndarray:
        if tid not in self._values:
            self.prefetch([tid])
        return self._values[tid]

    def digest(self, tid: int, *, compute: bool = True) -> str | None:
        """Recorded value digest; with ``compute``, derive (and persist via
        the provider) from the value — fetching it if necessary."""
        d = self._provider.digest(self._k, tid)
        if d is not None or not compute:
            return d
        return self._provider.record_digest(self._k, tid, self._value(tid))

    def spectra(self, tid: int, key: tuple[int, int], *,
                compute: bool = True) -> list[np.ndarray] | None:
        memo = self._spectra.get((tid, key))
        if memo is not None:
            return memo
        memo = self._provider.spectra(self._k, tid, key)
        if memo is not None:
            self._spectra[(tid, key)] = memo
            return memo
        if not compute:
            return None
        sig = self._sigs[tid]
        shape = sig.shape or ()
        mode = self.mode(tid)
        limit = (self._m.max_unfoldings if mode == "dense"
                 else self._m.sketch_unfoldings)
        splits = _unfolding_key_map(shape, limit).get(key, ())
        a = _to_float64(np.asarray(self._value(tid)))
        out: list[np.ndarray] = []
        if len(shape) <= 1:
            s = np.linalg.svd(a.reshape(1, -1), compute_uv=False)
            self.dense_svds += 1
            out.append(s)
        else:
            for G, Gc in splits:
                rows = int(np.prod([shape[i] for i in G], dtype=np.int64))
                cols = int(np.prod([shape[i] for i in Gc], dtype=np.int64))
                mat = np.transpose(a, G + Gc).reshape(rows, cols)
                if mode == "dense":
                    try:
                        s = np.linalg.svd(mat, compute_uv=False)
                    except np.linalg.LinAlgError:
                        continue
                    self.dense_svds += 1
                    out.append(np.sort(s)[::-1])
                else:
                    m = self._m
                    out.append(_sketch_spectrum(
                        mat.astype(np.float32), m.sketch_rank,
                        m.sketch_oversample))
                    self.sketch_svds += 1
        self._spectra[(tid, key)] = out
        self._provider.record_spectra(self._k, tid, key, out)
        return out


# Above this candidate-product size, the phase-1 bucket gate switches from
# the dense cross-product to the l1-sorted window prefilter (same survivors,
# near-linear cost for buckets holding thousands of layer activations).
_PHASE1_DENSE_MAX = 1 << 16


@dataclasses.dataclass
class MatchStats:
    """Instrumentation of one fast-matcher run (read by fig9_scalability)."""

    n_tids_a: int = 0
    n_tids_b: int = 0
    phase1_pairs: int = 0          # candidates surviving the cheap gate
    pairs: int = 0                 # final equivalent pairs
    dense_svds: int = 0
    sketch_svds: int = 0
    fetched_bytes: int = 0         # total values materialized in phase 2
    peak_value_bytes: int = 0      # peak resident values (one sample's worth)
    decided_dry: int = 0           # pair-verdicts served by persisted evidence
    undecided_dropped: int = 0     # dry_only: pairs undecidable without values
    stamped_pairs: int = 0         # pairs accepted via block-stamped twins
    twin_reseeded: int = 0         # boundary pairs re-proven by resolve_pending
    demoted_pairs: int = 0         # boundary pairs refuted -> full matcher
    phase1_s: float = 0.0
    phase2_s: float = 0.0


# ---------------------------------------------------------------------------
# the matcher
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TensorMatcher:
    """Matches tensors across two graphs from one or more value captures."""

    rtol: float = 1e-3
    max_svd_numel: int = 1 << 20
    min_numel: int = 2
    max_order: int = 5
    max_unfoldings: int = 16
    # randomized-sketch spectral test for tensors above max_svd_numel
    sketch_large: bool = True
    sketch_rank: int = 16
    sketch_oversample: int = 8
    sketch_unfoldings: int = 4
    sketch_rtol: float = 0.05
    last_stats: MatchStats | None = dataclasses.field(
        default=None, repr=False, compare=False)

    # -- public API ---------------------------------------------------------
    def match(self, values_a: Sequence[dict[int, np.ndarray]],
              values_b: Sequence[dict[int, np.ndarray]]) -> list[tuple[int, int]]:
        """Return (tid_a, tid_b) pairs equivalent under EVERY input sample.

        ``values_a[k]`` / ``values_b[k]`` are tensor-id -> value maps captured
        from the two graphs on the k-th identical model input.  This is the
        fast two-phase path running over in-memory values; the historical
        eager implementation survives as :meth:`match_exhaustive`.
        """
        self._check_samples(values_a, values_b)
        stats_a = [self._stats_table(v) for v in values_a]
        stats_b = [self._stats_table(v) for v in values_b]

        def fetch(vals):
            return lambda k, tids: {t: np.asarray(vals[k][t]) for t in tids}

        return self.match_streamed(stats_a, stats_b,
                                   fetch(values_a), fetch(values_b))

    def match_streamed(
        self,
        stats_a: Sequence[dict[int, TensorSignature]],
        stats_b: Sequence[dict[int, TensorSignature]],
        fetch_a: Callable[[int, Sequence[int]], dict[int, np.ndarray]],
        fetch_b: Callable[[int, Sequence[int]], dict[int, np.ndarray]],
        *,
        provider_a: "SpectraProvider | None" = None,
        provider_b: "SpectraProvider | None" = None,
        dry_only: bool = False,
        stamper: "Any | None" = None,
    ) -> list[tuple[int, int]]:
        """Two-phase match from streamed cheap signatures.

        ``stats_*[k]`` come from ``interp.capture_tensor_stats`` on the k-th
        sample; ``fetch_*(k, tids)`` selectively re-captures the named tensor
        values for phase 2 (``interp.capture_tensor_values(..., only_tids=)``).
        ``provider_*`` supply persisted phase-2 evidence (value digests +
        memoized spectra): pairs whose verdict they decide never fetch a
        value — a replay of a recorded comparison is sketch-only.

        ``dry_only=True`` is the degraded mode for unreachable value stores:
        no fetch is ever issued, pairs the persisted evidence cannot decide
        are conservatively *dropped* (counted in
        ``last_stats.undecided_dropped``) instead of being fetched — the
        result under-matches rather than guesses.

        ``stamper`` (a ``block_match.BlockStamper``) supplies twin pairs
        proven bitwise-identical by block-digest induction: twin survivors
        of phase 1 are accepted without fetches or SVDs (they are equivalent
        by construction, so the pair set stays exhaustive-equivalent), and
        unproven boundary pairs are digest-resolved once up front so a
        bitwise-preserving rewrite demotes only its own pairs.  Twins still
        pass through phase 1 so coincidental cross-layer matches are kept.
        """
        self._check_samples(stats_a, stats_b)
        n = len(stats_a)
        t0 = time.perf_counter()
        tids_a = sorted(self._usable_tids(stats_a))
        tids_b = sorted(self._usable_tids(stats_b))

        # ---- phase 1: bucketed + vectorized cheap gate --------------------
        # Quantize log2(l2) so any pair within rtol lands in the same or an
        # adjacent bucket (probe +-1): |log2 va - log2 vb| <= log2(1/(1-rtol))
        # < W for every rtol < 0.5.  Larger tolerances degrade to numel-only
        # buckets rather than risk splitting a matching pair.
        W = max(0.5, 8.0 * math.log2(1.0 + self.rtol))
        quantize = self.rtol < 0.5

        def bkey(sig: TensorSignature) -> int:
            if not quantize:
                return 0
            return math.floor(math.log2(max(sig.l2, 1e-30)) / W)

        # (n_samples, n_tids, 5) invariant tensors per side; the gate below
        # broadcasts |x - y| <= rtol * max(|x|, |y|, 1e-30) over whole bucket
        # groups at once — float64 arithmetic identical to _close().
        def inv_matrix(stats_list, tids):
            arr = np.empty((n, len(tids), 5))
            for k, table in enumerate(stats_list):
                for i, t in enumerate(tids):
                    s = table[t]
                    arr[k, i, 0] = s.l1
                    arr[k, i, 1] = s.l2
                    arr[k, i, 2] = s.mean
                    arr[k, i, 3] = s.amax
                    arr[k, i, 4] = s.amin
            return arr

        inv_a = inv_matrix(stats_a, tids_a)
        inv_b = inv_matrix(stats_b, tids_b)

        groups_a: dict[tuple[int, int], list[int]] = {}
        for i, ta in enumerate(tids_a):
            s0 = stats_a[0][ta]
            groups_a.setdefault((s0.numel, bkey(s0)), []).append(i)
        groups_b: dict[tuple[int, int], list[int]] = {}
        for j, tb in enumerate(tids_b):
            s0 = stats_b[0][tb]
            groups_b.setdefault((s0.numel, bkey(s0)), []).append(j)

        cand: list[tuple[int, int]] = []
        probes = (-1, 0, 1) if quantize else (0,)
        for (numel, q), ia in groups_a.items():
            jb: list[int] = []
            for dq in probes:
                jb.extend(groups_b.get((numel, q + dq), ()))
            if not jb:
                continue
            if len(ia) * len(jb) > _PHASE1_DENSE_MAX:
                # Giant bucket (thousands of same-shape layer activations in
                # one narrow l2 band): the dense |ia| x |jb| gate would cost
                # O(n^2) memory/time.  Sort side B by sample-0 l1 and gate
                # only the rtol window around each A tensor — a sound
                # overapproximation of the full gate (any matching pair has
                # l1 within rtol on sample 0), so the surviving set is
                # identical to the dense path's.
                ia_arr = np.asarray(ia)
                jb_arr = np.asarray(jb)
                l1b = inv_b[0, jb_arr, 0]
                order = np.argsort(l1b, kind="stable")
                sb = l1b[order]
                l1a = inv_a[0, ia_arr, 0]
                lo = np.searchsorted(sb, l1a * (1.0 - self.rtol) - 1e-30,
                                     side="left")
                hi = np.searchsorted(sb, l1a * (1.0 + 2.0 * self.rtol)
                                     + 1e-30, side="right")
                counts = hi - lo
                ii = np.repeat(np.arange(len(ia)), counts)
                if not ii.size:
                    continue
                jj = np.concatenate(
                    [order[l:h] for l, h in zip(lo, hi) if h > l])
                # staged gate: the (decorrelated) mean column alone rejects
                # almost every window candidate before the full 5-column pass
                ma = inv_a[:, ia_arr[ii], 2]          # (n, m)
                mb = inv_b[:, jb_arr[jj], 2]
                md = np.abs(ma - mb)
                ms = np.maximum(np.maximum(np.abs(ma), np.abs(mb)), 1e-30)
                keep = (md <= self.rtol * ms).all(axis=0)
                ii, jj = ii[keep], jj[keep]
                if not ii.size:
                    continue
                da = inv_a[:, ia_arr[ii], :]          # (n, m, 5)
                db = inv_b[:, jb_arr[jj], :]
                diff = np.abs(da - db)
                scale = np.maximum(np.maximum(np.abs(da), np.abs(db)), 1e-30)
                ok = (diff <= self.rtol * scale).all(axis=(0, 2))    # (m,)
                for t in np.nonzero(ok)[0]:
                    cand.append((tids_a[ia[ii[t]]], tids_b[jb[jj[t]]]))
                continue
            xa = inv_a[:, ia, :]                      # (n, |ia|, 5)
            xb = inv_b[:, jb, :]                      # (n, |jb|, 5)
            diff = np.abs(xa[:, :, None, :] - xb[:, None, :, :])
            scale = np.maximum(np.maximum(np.abs(xa[:, :, None, :]),
                                          np.abs(xb[:, None, :, :])), 1e-30)
            ok = (diff <= self.rtol * scale).all(axis=(0, 3))   # (|ia|, |jb|)
            for ii, jj in zip(*np.nonzero(ok)):
                cand.append((tids_a[ia[ii]], tids_b[jb[jj]]))
        cand.sort()
        t1 = time.perf_counter()

        # ---- phase 2: lazy memoized spectra on survivors ------------------
        # One sample at a time: pairs rejected on sample k never cost a fetch
        # or SVD on sample k+1, and at most one sample's survivor values per
        # side are resident at any moment (the peak-memory bound).  Each
        # sample runs the gate twice: a *dry* pass decides every pair the
        # persisted evidence (digests + spectra) already covers without
        # touching values, then one batched prefetch materializes the
        # remaining pairs' tensors for the *wet* pass.
        st = MatchStats(n_tids_a=len(tids_a), n_tids_b=len(tids_b),
                        phase1_pairs=len(cand), phase1_s=t1 - t0)
        if stamper is not None and not dry_only and stamper.pending and \
                any(not stamper.is_twin(ta, tb) for ta, tb in cand):
            # boundary re-seed: digest-verify unproven pairs once so a
            # bitwise-preserving rewrite demotes only its own pairs
            stamper.resolve_pending(fetch_a, fetch_b, n)
        surviving = cand
        for k in range(n):
            if not surviving:
                break
            la = _LazySpectra(stats_a[k], functools.partial(fetch_a, k), self,
                              provider=provider_a, sample=k)
            lb = _LazySpectra(stats_b[k], functools.partial(fetch_b, k), self,
                              provider=provider_b, sample=k)
            decided: dict[tuple[int, int], bool] = {}
            need_a: set[int] = set()
            need_b: set[int] = set()
            twins = stamper.twins if stamper is not None else frozenset()
            for p in surviving:
                if p in twins:
                    # proven bitwise-identical: accepted with no fetch/SVD
                    if k == 0:
                        st.stamped_pairs += 1
                    continue
                ta, tb = p
                verdict = self._spectra_gate(la, ta, lb, tb, dry=True)
                if verdict is None:
                    if dry_only:
                        decided[p] = False
                        st.undecided_dropped += 1
                        continue
                    need_a.add(ta)
                    need_b.add(tb)
                else:
                    decided[p] = verdict
                    st.decided_dry += 1
            la.prefetch(need_a)
            lb.prefetch(need_b)
            surviving = [
                p for p in surviving
                if p in twins or (decided[p] if p in decided
                                  else self._spectra_gate(la, p[0], lb, p[1]))]
            st.dense_svds += la.dense_svds + lb.dense_svds
            st.sketch_svds += la.sketch_svds + lb.sketch_svds
            st.fetched_bytes += la.fetched_bytes + lb.fetched_bytes
            st.peak_value_bytes = max(st.peak_value_bytes,
                                      la.fetched_bytes + lb.fetched_bytes)
        st.pairs = len(surviving)
        if stamper is not None:
            st.twin_reseeded = stamper.reseeded
            st.demoted_pairs = stamper.demoted
        st.phase2_s = time.perf_counter() - t1
        self.last_stats = st
        return surviving

    def match_exhaustive(self, values_a: Sequence[dict[int, np.ndarray]],
                         values_b: Sequence[dict[int, np.ndarray]]
                         ) -> list[tuple[int, int]]:
        """Reference oracle: eager signatures, numel-bucketed cross product.

        This is the seed implementation, kept verbatim so equivalence tests
        can assert the fast path returns the identical pair set.
        """
        self._check_samples(values_a, values_b)
        sig_a = [self._sig_table(v) for v in values_a]
        sig_b = [self._sig_table(v) for v in values_b]
        tids_a = set(sig_a[0])
        tids_b = set(sig_b[0])
        for t in sig_a[1:]:
            tids_a &= set(t)
        for t in sig_b[1:]:
            tids_b &= set(t)

        # bucket by numel to avoid the full cross product in practice
        by_numel: dict[int, list[int]] = {}
        for tb in tids_b:
            by_numel.setdefault(sig_b[0][tb].numel, []).append(tb)

        pairs: list[tuple[int, int]] = []
        for ta in sorted(tids_a):
            for tb in by_numel.get(sig_a[0][ta].numel, ()):  # candidates
                ok = all(signatures_match(sa[ta], sb[tb], rtol=self.rtol)
                         for sa, sb in zip(sig_a, sig_b))
                if ok:
                    pairs.append((ta, tb))
        return pairs

    # -- internals ----------------------------------------------------------
    @staticmethod
    def _check_samples(a, b) -> None:
        if len(a) != len(b) or not a:
            raise ValueError("need the same nonzero number of captures per side")

    def _sig_table(self, values: dict[int, np.ndarray]) -> dict[int, TensorSignature]:
        out = {}
        for tid, val in values.items():
            if np.size(val) < self.min_numel:
                continue
            out[tid] = signature(val, max_svd_numel=self.max_svd_numel,
                                 max_order=self.max_order,
                                 max_unfoldings=self.max_unfoldings)
        return out

    def _stats_table(self, values: dict[int, np.ndarray]) -> dict[int, TensorSignature]:
        # float64 numpy stats (use_jit=False) so the in-memory fast path is
        # bit-identical to the oracle's cheap gate.
        out = {}
        for tid, val in values.items():
            if np.size(val) < self.min_numel:
                continue
            out[tid] = stats_signature(val, use_jit=False)
        return out

    def _usable_tids(self, stats: Sequence[dict[int, TensorSignature]]) -> set[int]:
        tids = set(stats[0])
        for t in stats[1:]:
            tids &= set(t)
        # A tensor degenerate on ANY sample can never match (the oracle's
        # signatures_match fails on that sample) — drop it up front.
        return {t for t in tids
                if stats[0][t].numel >= self.min_numel
                and all(not s[t].is_degenerate() for s in stats)}

    def _spectra_gate(self, la: _LazySpectra, ta: int,
                      lb: _LazySpectra, tb: int, *,
                      dry: bool = False) -> bool | None:
        """Phase-2 verdict for one pair; ``dry`` answers only from already-
        available evidence (persisted digests/spectra, in-run memos) and
        returns ``None`` when deciding would require fetching a value."""
        ma, mb = la.mode(ta), lb.mode(tb)
        if ma == "dense" and mb == "dense":
            tol = self.rtol * 10
        elif ma == "sketch" and mb == "sketch":
            tol = self.sketch_rtol
        else:
            # Mixed/no spectral test available: symmetric invariants already
            # passed (the oracle's large-tensor / high-order fallback).
            return True
        shared = la.keys(ta) & lb.keys(tb)
        if not shared:
            return True
        # Identical-value fast path: equal-shape, bitwise-equal tensors
        # (equal sha256 digests) pass the full spectral test by construction
        # — both sides would compute the exact same spectra — so skip the
        # SVDs.  Real A/B workloads rarely hit this; self-comparisons,
        # copied values, and matched activations across twin captures do.
        if la._sigs[ta].shape == lb._sigs[tb].shape:
            da = la.digest(ta, compute=not dry)
            db = lb.digest(tb, compute=not dry)
            if da is not None and db is not None and da == db:
                return True
        for key in sorted(shared):
            sa = la.spectra(ta, key, compute=not dry)
            sb = lb.spectra(tb, key, compute=not dry)
            if sa is None or sb is None:
                return None        # dry pass: this pair needs real values
            if not _setwise_match(sa, sb, tol):
                return False
        return True


def bijective_pairs(pairs: Iterable[tuple[int, int]]) -> list[tuple[int, int]]:
    """Keep only pairs whose endpoints match exactly one partner each.

    Ambiguous matches (a tensor numerically equal to several peers, e.g. a
    value and its copy) cannot serve as cut points; Algorithm 1 needs
    unambiguous correspondences.
    """
    count_a: dict[int, int] = {}
    count_b: dict[int, int] = {}
    plist = list(pairs)
    for a, b in plist:
        count_a[a] = count_a.get(a, 0) + 1
        count_b[b] = count_b.get(b, 0) + 1
    return [(a, b) for a, b in plist if count_a[a] == 1 and count_b[b] == 1]
