"""Computational-graph extraction from jaxprs.

This is Magneton's trace substrate, adapted to JAX (DESIGN.md §2): instead of
reconstructing an operator DAG from CUPTI kernel traces + correlation IDs, we
take the dataflow DAG JAX already has — the jaxpr.  Nodes are equations
(operators), edges are tensors (jaxpr variables), and every node carries the
user call path recorded by the tracer (the analogue of the libunwind /
PyEval_SetProfile stacks in the paper's §5.1).
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Iterable, Sequence

import jax
import numpy as np
from jax._src.core import ClosedJaxpr, Jaxpr, Literal, Var

try:  # C-speed BFS for large-graph region queries; pure-python fallback kept
    from scipy import sparse as _sparse
    from scipy.sparse.csgraph import breadth_first_order as _bfs_order
except Exception:  # pragma: no cover - scipy ships with the jax toolchain
    _sparse = None
    _bfs_order = None

# Higher-order primitives whose inner jaxpr we inline during flattening.
# scan / while / cond are kept as super-nodes (their bodies execute a
# data-dependent or repeated number of times; costs.py prices them).
_INLINE_PRIMITIVES = ("pjit", "jit", "closed_call", "custom_jvp_call",
                      "custom_vjp_call", "remat", "checkpoint",
                      "custom_vjp_call_jaxpr", "shard_map")


def _nested_jaxpr(eqn) -> ClosedJaxpr | None:
    """Return the callee jaxpr of a call-like equation, if any."""
    p = eqn.params
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        if key in p:
            val = p[key]
            if isinstance(val, ClosedJaxpr):
                return val
            if isinstance(val, Jaxpr):
                return ClosedJaxpr(val, ())
    return None


@dataclasses.dataclass
class OpNode:
    """One operator (jaxpr equation) in the graph."""

    idx: int
    primitive: str
    params: dict[str, Any]
    invars: list[int]          # tensor ids
    outvars: list[int]         # tensor ids
    call_path: tuple[str, ...]  # user stack frames, outermost first
    scope: tuple[str, ...] = ()  # names of inlined call frames (e.g. remat)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"OpNode({self.idx}:{self.primitive})"


@dataclasses.dataclass
class TensorEdge:
    """One tensor (jaxpr variable) in the graph."""

    tid: int
    shape: tuple[int, ...]
    dtype: str
    producer: int | None = None        # OpNode idx, None for graph inputs
    consumers: list[int] = dataclasses.field(default_factory=list)
    is_input: bool = False
    is_output: bool = False
    is_const: bool = False

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)) * np.dtype(self.dtype).itemsize


@dataclasses.dataclass
class OpGraph:
    """Operator-level computational graph of one traced program."""

    name: str
    nodes: list[OpNode]
    tensors: dict[int, TensorEdge]
    inputs: list[int]                   # tensor ids in call order
    outputs: list[int]
    closed_jaxpr: ClosedJaxpr | None = None
    # Memoized flattened extraction of closed_jaxpr.  Graphs built by
    # extract_graph ARE their own flattening, so repeated instrumented runs
    # (multi-sample capture, ReplayProfiler) never re-extract.
    _flat_cache: "OpGraph | None" = dataclasses.field(
        default=None, repr=False, compare=False)
    # Flat tid-space program recorded during extraction: one leaf equation
    # per node (aligned with ``nodes``), the concrete values of every
    # const/literal tensor, and per-node mesh axis sizes (for collectives
    # inlined out of shard_map bodies).  None for graphs rebuilt from
    # persisted artifacts — those cannot execute anyway.
    _eqns: "list | None" = dataclasses.field(
        default=None, repr=False, compare=False)
    _const_vals: "dict[int, Any] | None" = dataclasses.field(
        default=None, repr=False, compare=False)
    _node_axis_sizes: "list[dict[str, int]] | None" = dataclasses.field(
        default=None, repr=False, compare=False)
    # Memoized BlockStructure (per-node digests + repeated-block families).
    _block_cache: "BlockStructure | None" = dataclasses.field(
        default=None, repr=False, compare=False)

    def flat_graph(self) -> "OpGraph":
        """The flattened (inline_calls=True) extraction of this graph's jaxpr.

        Memoized on the instance: extract_graph() seeds the cache with the
        graph itself, and manually constructed OpGraphs pay the extraction
        cost exactly once instead of on every instrumented run.
        """
        if self._flat_cache is None:
            if self.closed_jaxpr is None:
                raise ValueError("OpGraph was built without a ClosedJaxpr")
            self._flat_cache = extract_graph(self.closed_jaxpr, name=self.name,
                                             inline_calls=True)
        return self._flat_cache

    # ---- structural helpers -------------------------------------------------
    def successors(self, node_idx: int) -> list[int]:
        out: list[int] = []
        for tid in self.nodes[node_idx].outvars:
            out.extend(self.tensors[tid].consumers)
        return sorted(set(out))

    def predecessors(self, node_idx: int) -> list[int]:
        out: list[int] = []
        for tid in self.nodes[node_idx].invars:
            p = self.tensors[tid].producer
            if p is not None:
                out.append(p)
        return sorted(set(out))

    def topo_order(self) -> list[int]:
        # jaxpr equations are already topologically sorted.
        return list(range(len(self.nodes)))

    def subgraph_nodes_between(self, src_tids: set[int], dst_tids: set[int]) -> list[int]:
        """Node idxs on any path from the src tensors to the dst tensors.

        Traversal does NOT stop at frontier tensors: a sink tensor may have
        further consumers that feed *another* sink (multi-output graphs), and
        those nodes belong to the region too.  Because the graph is a DAG,
        the fwd∩bwd intersection still yields exactly the between-set.

        The backward sweep runs first (it is bounded by the src frontier) and
        the forward sweep only expands inside the backward set: every node on
        a src→dst path is backward-reachable from dst, so restricting the
        forward frontier this way keeps each region query O(|region|) instead
        of walking the whole downstream graph.

        Large graphs run the same two sweeps as C-speed sparse BFS over the
        memoized edge arrays (scipy); the python implementation below is the
        semantic reference and the fallback, and a dedicated test asserts the
        two agree.
        """
        if _bfs_order is not None and len(self.nodes) >= 512:
            try:
                return self._between_sparse(src_tids, dst_tids)
            except Exception:  # pragma: no cover - defensive fallback
                pass
        # backward reachable from dst (stops at src tensors)
        bwd: set[int] = set()
        frontier = [self.tensors[t].producer for t in dst_tids
                    if self.tensors[t].producer is not None]
        while frontier:
            n = frontier.pop()
            if n is None or n in bwd:
                continue
            bwd.add(n)
            for tid in self.nodes[n].invars:
                if tid in src_tids:
                    continue
                p = self.tensors[tid].producer
                if p is not None:
                    frontier.append(p)
        # forward reachable from src, restricted to the backward set
        fwd: set[int] = set()
        frontier = [c for t in src_tids for c in self.tensors[t].consumers
                    if c in bwd]
        while frontier:
            n = frontier.pop()
            if n in fwd:
                continue
            fwd.add(n)
            for tid in self.nodes[n].outvars:
                frontier.extend(c for c in self.tensors[tid].consumers
                                if c in bwd)
        return sorted(fwd)

    def _between_sparse(self, src_tids: set[int], dst_tids: set[int]) -> list[int]:
        """C-speed ``subgraph_nodes_between`` (identical semantics).

        bwd = nodes reaching a dst producer over reversed edges that avoid
        src tensors; fwd = nodes reachable from the src tensors' consumers
        through edges whose BOTH endpoints lie in bwd.  Multi-source BFS is
        expressed with a virtual root (row ``n``) fanning out to the seeds.
        """
        e_p, e_c, e_t, _, _ = edge_arrays(self)
        n = len(self.nodes)
        seeds_b = {self.tensors[t].producer for t in dst_tids}
        seeds_b.discard(None)
        if not seeds_b:
            return []
        seeds_b_arr = np.fromiter(seeds_b, dtype=np.int32)
        if src_tids:
            keep = ~np.isin(e_t, np.fromiter(src_tids, dtype=np.int32))
            rp, rc = e_p[keep], e_c[keep]
        else:
            rp, rc = e_p, e_c
        rows = np.concatenate([rc, np.full(len(seeds_b_arr), n, np.int32)])
        cols = np.concatenate([rp, seeds_b_arr])
        m = _sparse.csr_matrix(
            (np.ones(len(rows), np.int8), (rows, cols)), shape=(n + 1, n + 1))
        order = _bfs_order(m, n, directed=True, return_predecessors=False)
        bwd = np.zeros(n + 1, dtype=bool)
        bwd[order] = True
        bwd[n] = False

        seeds_f = {c for t in src_tids for c in self.tensors[t].consumers
                   if bwd[c]}
        if not seeds_f:
            return []
        seeds_f_arr = np.fromiter(seeds_f, dtype=np.int32)
        keep = bwd[e_p] & bwd[e_c]
        rows = np.concatenate([e_p[keep], np.full(len(seeds_f_arr), n, np.int32)])
        cols = np.concatenate([e_c[keep], seeds_f_arr])
        m = _sparse.csr_matrix(
            (np.ones(len(rows), np.int8), (rows, cols)), shape=(n + 1, n + 1))
        order = _bfs_order(m, n, directed=True, return_predecessors=False)
        return sorted(int(v) for v in order if v != n)


def edge_arrays(graph: OpGraph) -> tuple[np.ndarray, ...]:
    """Flat int32 edge/outvar arrays for ``graph``, memoized on the instance.

    ``(e_p, e_c, e_t)`` hold one row per producer->consumer tensor edge
    (producer node, consumer node, tensor id); ``(o_n, o_t)`` hold one row
    per node outvar.  These back the C-speed region BFS and the piecewise
    dominator sweep — graphs are immutable, so the cache never invalidates.
    """
    cached = getattr(graph, "_edge_arrays_cache", None)
    if cached is not None:
        return cached
    e_p: list[int] = []
    e_c: list[int] = []
    e_t: list[int] = []
    o_n: list[int] = []
    o_t: list[int] = []
    tensors = graph.tensors
    for node in graph.nodes:
        for t in node.outvars:
            o_n.append(node.idx)
            o_t.append(t)
            for c in tensors[t].consumers:
                e_p.append(node.idx)
                e_c.append(c)
                e_t.append(t)
    out = (np.asarray(e_p, dtype=np.int32), np.asarray(e_c, dtype=np.int32),
           np.asarray(e_t, dtype=np.int32), np.asarray(o_n, dtype=np.int32),
           np.asarray(o_t, dtype=np.int32))
    graph._edge_arrays_cache = out
    return out


def _call_path(eqn, max_frames: int = 12) -> tuple[str, ...]:
    """User-code call path of an equation, outermost first."""
    si = eqn.source_info
    tb = getattr(si, "traceback", None)
    if tb is None:
        return ()
    try:
        import jax._src.source_info_util as siu
        frames = list(siu.user_frames(tb))
    except Exception:
        frames = [f for f in tb.frames
                  if "site-packages/jax" not in f.file_name]
    out = []
    for f in frames[:max_frames]:
        fname = f.file_name.rsplit("/", 1)[-1]
        line = getattr(f, "start_line", None) or getattr(f, "line_num", 0)
        out.append(f"{fname}:{f.function_name}:{line}")
    # user_frames yields innermost first; we want outermost first so common
    # prefixes correspond to shared high-level call sites (Algorithm 2).
    return tuple(reversed(out))


def extract_graph(closed_jaxpr: ClosedJaxpr, *, name: str = "graph",
                  inline_calls: bool = True) -> OpGraph:
    """Build an OpGraph from a ClosedJaxpr, optionally inlining call prims."""

    nodes: list[OpNode] = []
    tensors: dict[int, TensorEdge] = {}
    var_ids: dict[Any, int] = {}
    next_tid = [0]
    eqn_list: list[Any] = []                 # leaf eqn per node, node order
    const_vals: dict[int, Any] = {}          # const/literal tid -> value
    node_axes: list[dict[str, int]] = []     # per node mesh axis sizes

    def tid_for(v, *, scope_suffix: str = "") -> int:
        key = (id(v), scope_suffix)
        if key not in var_ids:
            t = next_tid[0]
            next_tid[0] += 1
            var_ids[key] = t
            aval = v.aval
            tensors[t] = TensorEdge(
                tid=t, shape=tuple(getattr(aval, "shape", ())),
                dtype=str(getattr(aval, "dtype", "float32")))
        return var_ids[key]

    def lit_tid(v) -> int:
        t = next_tid[0]
        next_tid[0] += 1
        arr = np.asarray(v.val)
        tensors[t] = TensorEdge(tid=t, shape=tuple(arr.shape), dtype=str(arr.dtype),
                                is_const=True)
        const_vals[t] = v.val
        return t

    def walk(jaxpr: Jaxpr, env: dict[Var, int], scope: tuple[str, ...],
             axes: dict[str, int]):
        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            inner = _nested_jaxpr(eqn) if inline_calls else None
            if inner is not None and prim in _INLINE_PRIMITIVES:
                # Inline: map callee invars to caller tensor ids.
                inner_env: dict[Var, int] = {}
                for cv, cval in zip(inner.jaxpr.constvars, inner.consts):
                    t = next_tid[0]
                    next_tid[0] += 1
                    arr = np.asarray(cval) if not hasattr(cval, "aval") else cval
                    tensors[t] = TensorEdge(
                        tid=t, shape=tuple(np.shape(arr)), dtype=str(np.asarray(arr).dtype)
                        if not hasattr(arr, "dtype") else str(arr.dtype), is_const=True)
                    const_vals[t] = cval
                    inner_env[cv] = t
                for iv, outer_v in zip(inner.jaxpr.invars, eqn.invars):
                    inner_env[iv] = (lit_tid(outer_v) if isinstance(outer_v, Literal)
                                     else env[outer_v])
                sub_scope = scope + (prim,)
                sub_axes = axes
                if prim == "shard_map":
                    mesh = eqn.params.get("mesh")
                    if mesh is not None:
                        sub_axes = dict(axes)
                        sub_axes.update({str(k): int(v)
                                         for k, v in mesh.shape.items()})
                walk(inner.jaxpr, inner_env, sub_scope, sub_axes)
                for ov, inner_ov in zip(eqn.outvars, inner.jaxpr.outvars):
                    if isinstance(inner_ov, Literal):
                        env[ov] = lit_tid(inner_ov)
                    else:
                        env[ov] = inner_env[inner_ov]
                continue

            in_tids = [lit_tid(v) if isinstance(v, Literal) else env[v]
                       for v in eqn.invars]
            out_tids = []
            for v in eqn.outvars:
                t = next_tid[0]
                next_tid[0] += 1
                aval = v.aval
                tensors[t] = TensorEdge(tid=t, shape=tuple(getattr(aval, "shape", ())),
                                        dtype=str(getattr(aval, "dtype", "float32")))
                env[v] = t
                out_tids.append(t)

            idx = len(nodes)
            node = OpNode(idx=idx, primitive=prim, params=dict(eqn.params),
                          invars=in_tids, outvars=out_tids,
                          call_path=_call_path(eqn), scope=scope)
            nodes.append(node)
            eqn_list.append(eqn)
            node_axes.append(axes)
            for t in in_tids:
                tensors[t].consumers.append(idx)
            for t in out_tids:
                tensors[t].producer = idx

    env: dict[Var, int] = {}
    jaxpr = closed_jaxpr.jaxpr
    for cv, cval in zip(jaxpr.constvars, closed_jaxpr.consts):
        t = next_tid[0]
        next_tid[0] += 1
        shape = tuple(np.shape(cval))
        dtype = str(cval.dtype) if hasattr(cval, "dtype") else str(np.asarray(cval).dtype)
        tensors[t] = TensorEdge(tid=t, shape=shape, dtype=dtype, is_const=True)
        const_vals[t] = cval
        env[cv] = t
    inputs = []
    for iv in jaxpr.invars:
        t = next_tid[0]
        next_tid[0] += 1
        aval = iv.aval
        tensors[t] = TensorEdge(tid=t, shape=tuple(getattr(aval, "shape", ())),
                                dtype=str(getattr(aval, "dtype", "float32")),
                                is_input=True)
        env[iv] = t
        inputs.append(t)

    walk(jaxpr, env, (), {})

    outputs = []
    for ov in jaxpr.outvars:
        t = lit_tid(ov) if isinstance(ov, Literal) else env[ov]
        tensors[t].is_output = True
        outputs.append(t)

    g = OpGraph(name=name, nodes=nodes, tensors=tensors, inputs=inputs,
                outputs=outputs, closed_jaxpr=closed_jaxpr,
                _eqns=eqn_list, _const_vals=const_vals,
                _node_axis_sizes=node_axes)
    if inline_calls:
        g._flat_cache = g   # the extraction is its own flattening
    return g


def trace(fn: Callable, *example_args, name: str | None = None,
          inline_calls: bool = True, **example_kwargs) -> OpGraph:
    """Trace ``fn`` on example args and return its operator graph."""
    closed = jax.make_jaxpr(fn)(*example_args, **example_kwargs)
    return extract_graph(closed, name=name or getattr(fn, "__name__", "graph"),
                         inline_calls=inline_calls)


# ---------------------------------------------------------------------------
# block-isomorphism detection (hierarchical matching substrate)
# ---------------------------------------------------------------------------
#
# Production graphs repeat one transformer layer 10-160x.  Each node gets two
# canonical digests:
#
#   * op_digest     — the node's *semantics*: primitive name, canonically
#     tokenized params (nested jaxprs fingerprinted structurally, arrays by
#     value hash, unknown objects by identity so collisions are impossible),
#     and input/output shapes/dtypes.  Two nodes with equal op_digests and
#     bitwise-identical inputs produce bitwise-identical outputs (the twin-
#     propagation invariant core/block_match.py relies on).
#   * struct_digest — op_digest plus local wiring: relative producer offsets
#     for internal edges, value digests for const/literal inputs, tensor ids
#     for shared graph inputs.  Periodic runs of equal struct_digests are
#     repeated layer blocks.
#
# ``block_structure`` rolls the struct_digest sequence into BlockFamily spans
# (start, period, count) used by the fused block capture (interp.py), twin
# stamping (block_match.py) and region memoization (subgraph_match.py).

_MIN_REPEATS = 3        # a family needs >= 3 repeats to be worth stamping
_MIN_SPAN = 6           # ... and >= 6 nodes total
_MAX_PERIOD = 2048


def _value_digest(v) -> str:
    a = np.asarray(v)
    h = hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()
    return f"{a.dtype}:{a.shape}:{h}"


def _jaxpr_fingerprint(jaxpr: Jaxpr, consts: tuple, memo: dict) -> str:
    """Structural fingerprint of a nested jaxpr: canonical var numbering,
    exact literal/const value hashes — no reliance on pretty-printed floats."""
    key = id(jaxpr)
    hit = memo.get(key)
    if hit is not None:
        return hit
    varid: dict[Any, int] = {}

    def vid(v) -> str:
        if isinstance(v, Literal):
            return "lit:" + _value_digest(v.val)
        if v not in varid:
            varid[v] = len(varid)
        return f"v{varid[v]}:{v.aval}"

    parts = ["in:" + ",".join(vid(v) for v in
                              list(jaxpr.constvars) + list(jaxpr.invars))]
    for eqn in jaxpr.eqns:
        ptok = ",".join(f"{k}={_param_token(p, memo)}"
                        for k, p in sorted(eqn.params.items()))
        parts.append(f"{eqn.primitive.name}[{ptok}]"
                     f"({','.join(vid(v) for v in eqn.invars)})->"
                     f"({','.join(vid(v) for v in eqn.outvars)})")
    parts.append("out:" + ",".join(vid(v) for v in jaxpr.outvars))
    for c in consts:
        parts.append("const:" + _param_token(c, memo))
    fp = "jaxpr:" + hashlib.sha256("|".join(parts).encode()).hexdigest()
    memo[key] = fp
    return fp


def _param_token(v, memo: dict) -> str:
    """Canonical token for one equation param.

    Conservative by construction: objects we cannot canonicalize get an
    identity-unique token, so unequal params can never alias — a digest
    collision would let the matcher stamp a false equivalence.
    """
    import enum
    if v is None or isinstance(v, (bool, int, str, bytes)):
        return repr(v)
    if isinstance(v, float):
        return repr(v)
    if isinstance(v, enum.Enum):
        return f"{type(v).__qualname__}.{v.name}"
    if isinstance(v, ClosedJaxpr):
        return _jaxpr_fingerprint(v.jaxpr, tuple(v.consts), memo)
    if isinstance(v, Jaxpr):
        return _jaxpr_fingerprint(v, (), memo)
    if isinstance(v, (tuple, list)):
        return "(" + ",".join(_param_token(x, memo) for x in v) + ")"
    if isinstance(v, dict):
        items = sorted(v.items(), key=lambda kv: str(kv[0]))
        return "{" + ",".join(f"{k}:{_param_token(x, memo)}"
                              for k, x in items) + "}"
    if isinstance(v, np.dtype):
        return f"dtype[{v}]"
    if isinstance(v, type):
        try:
            return f"dtype[{np.dtype(v)}]"
        except TypeError:
            return f"type[{v.__module__}.{v.__qualname__}]"
    if isinstance(v, (np.ndarray, np.generic)):
        return "arr:" + _value_digest(v)
    if hasattr(v, "dtype") and hasattr(v, "shape") and hasattr(v, "__array__"):
        return "arr:" + _value_digest(v)        # jax arrays in params
    r = repr(v)
    if " at 0x" in r or " object at" in r:
        return f"!opaque:{type(v).__module__}.{type(v).__qualname__}:{id(v)}"
    return f"{type(v).__name__}:{r}"


@dataclasses.dataclass
class BlockFamily:
    """One repeated-block span: nodes [start, start + period*count)."""

    start: int
    period: int
    count: int
    digest: str                 # combined struct_digest of one block

    @property
    def end(self) -> int:
        return self.start + self.period * self.count

    def window(self, repeat: int) -> tuple[int, int]:
        lo = self.start + repeat * self.period
        return lo, lo + self.period


@dataclasses.dataclass
class BlockStructure:
    """Per-node digests + repeated-block families of one graph."""

    graph: OpGraph
    op_digests: list[str]
    struct_digests: list[str]
    families: list[BlockFamily]
    # node idx -> (family idx, repeat, offset within block)
    node_family: dict[int, tuple[int, int, int]]
    _const_digests: dict[int, str] = dataclasses.field(default_factory=dict)

    def const_digest(self, tid: int) -> str:
        """Value digest of a const/literal tensor (identity token when the
        value is unavailable, e.g. graphs rebuilt from persisted artifacts)."""
        d = self._const_digests.get(tid)
        if d is None:
            vals = self.graph._const_vals or {}
            v = vals.get(tid)
            d = f"c?:{tid}" if v is None else _value_digest(v)
            self._const_digests[tid] = d
        return d

    def locate_node(self, idx: int) -> tuple[int, int, int] | None:
        return self.node_family.get(idx)

    def locate_tid(self, tid: int) -> tuple[int, int, int, int] | None:
        """(family, repeat, block offset, outvar slot) of a produced tensor,
        or None when its producer is outside every family."""
        p = self.graph.tensors[tid].producer
        if p is None:
            return None
        loc = self.node_family.get(p)
        if loc is None:
            return None
        return loc + (self.graph.nodes[p].outvars.index(tid),)

    def coverage(self) -> float:
        covered = sum(f.period * f.count for f in self.families)
        return covered / max(len(self.graph.nodes), 1)


def _find_families(struct: list[str]) -> list[BlockFamily]:
    """Greedy periodic-run detection over the struct_digest sequence.

    Candidate periods come from the distance between consecutive occurrences
    of equal digests; smaller periods are claimed first (a period-p layer
    stack also matches period 2p — we want the maximal repeat count)."""
    n = len(struct)
    last: dict[str, int] = {}
    gaps: dict[int, int] = {}
    for i, d in enumerate(struct):
        j = last.get(d)
        if j is not None and i - j <= _MAX_PERIOD:
            g = i - j
            gaps[g] = gaps.get(g, 0) + 1
        last[d] = i
    periods = sorted(sorted(gaps, key=lambda p: -gaps[p])[:8])

    claimed = np.zeros(n, dtype=bool)
    families: list[BlockFamily] = []
    for p in periods:
        if p < 1:
            continue
        i = p
        while i < n:
            if (claimed[i] or claimed[i - p] or struct[i] != struct[i - p]):
                i += 1
                continue
            s = i - p
            e = i
            while (e < n and not claimed[e] and not claimed[e - p]
                   and struct[e] == struct[e - p]):
                e += 1
            # canonical phase anchoring: a run's start is wherever
            # periodicity happened to begin, so two graphs sharing the
            # same repeated content can carve rotated (incompatible)
            # windows — e.g. a program and its single-block rewrite,
            # whose post-rewrite run starts mid-layer.  Re-anchor on the
            # lexicographically least rotation of the period content:
            # the window phase becomes a pure function of the CONTENT,
            # so the block-evidence cache (core/block_cache.py) keys
            # align across such graphs.  Costs at most one repeat.
            s += min(range(p), key=lambda o: struct[s + o:s + o + p])
            count = (e - s) // p
            # trim any partial overlap with an earlier family
            while count >= _MIN_REPEATS and claimed[s:s + count * p].any():
                count -= 1
            if count >= _MIN_REPEATS and count * p >= _MIN_SPAN:
                digest = hashlib.sha256(
                    "".join(struct[s:s + p]).encode()).hexdigest()
                families.append(BlockFamily(start=s, period=p, count=count,
                                            digest=digest))
                claimed[s:s + count * p] = True
            i = e + 1
    families.sort(key=lambda f: f.start)
    return families


def block_structure(graph: OpGraph) -> BlockStructure:
    """Digests + block families of ``graph`` (memoized on the instance)."""
    if graph._block_cache is not None:
        return graph._block_cache
    tensors = graph.tensors
    jmemo: dict = {}
    cdig: dict[int, str] = {}
    const_vals = graph._const_vals or {}

    def const_digest(t: int) -> str:
        d = cdig.get(t)
        if d is None:
            v = const_vals.get(t)
            d = f"c?:{t}" if v is None else _value_digest(v)
            cdig[t] = d
        return d

    axes_list = graph._node_axis_sizes
    op_digests: list[str] = []
    struct_digests: list[str] = []
    for node in graph.nodes:
        ptoks = ",".join(f"{k}={_param_token(v, jmemo)}"
                         for k, v in sorted(node.params.items()))
        ind = ",".join(f"{tensors[t].shape}:{tensors[t].dtype}"
                       for t in node.invars)
        outd = ",".join(f"{tensors[t].shape}:{tensors[t].dtype}"
                        for t in node.outvars)
        ax = ""
        if axes_list is not None and node.idx < len(axes_list) \
                and axes_list[node.idx]:
            ax = repr(sorted(axes_list[node.idx].items()))
        op = hashlib.sha256(
            f"{node.primitive}[{ptoks}]({ind})->({outd})@{ax}"
            .encode()).hexdigest()
        op_digests.append(op)
        ctx: list[str] = []
        for t in node.invars:
            e = tensors[t]
            if e.producer is not None:
                ctx.append(f"r{node.idx - e.producer}")
            elif e.is_const:
                ctx.append("c" + const_digest(t))
            else:
                ctx.append(f"i{t}")
        struct_digests.append(hashlib.sha256(
            (op + ";" + ",".join(ctx)).encode()).hexdigest())

    families = _find_families(struct_digests)
    node_family: dict[int, tuple[int, int, int]] = {}
    for fi, fam in enumerate(families):
        for r in range(fam.count):
            base = fam.start + r * fam.period
            for o in range(fam.period):
                node_family[base + o] = (fi, r, o)

    bs = BlockStructure(graph=graph, op_digests=op_digests,
                        struct_digests=struct_digests, families=families,
                        node_family=node_family, _const_digests=cdig)
    graph._block_cache = bs
    return bs
