"""Computational-graph extraction from jaxprs.

This is Magneton's trace substrate, adapted to JAX (DESIGN.md §2): instead of
reconstructing an operator DAG from CUPTI kernel traces + correlation IDs, we
take the dataflow DAG JAX already has — the jaxpr.  Nodes are equations
(operators), edges are tensors (jaxpr variables), and every node carries the
user call path recorded by the tracer (the analogue of the libunwind /
PyEval_SetProfile stacks in the paper's §5.1).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Sequence

import jax
import numpy as np
from jax._src.core import ClosedJaxpr, Jaxpr, Literal, Var

# Higher-order primitives whose inner jaxpr we inline during flattening.
# scan / while / cond are kept as super-nodes (their bodies execute a
# data-dependent or repeated number of times; costs.py prices them).
_INLINE_PRIMITIVES = ("pjit", "jit", "closed_call", "custom_jvp_call",
                      "custom_vjp_call", "remat", "checkpoint",
                      "custom_vjp_call_jaxpr", "shard_map")


def _nested_jaxpr(eqn) -> ClosedJaxpr | None:
    """Return the callee jaxpr of a call-like equation, if any."""
    p = eqn.params
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        if key in p:
            val = p[key]
            if isinstance(val, ClosedJaxpr):
                return val
            if isinstance(val, Jaxpr):
                return ClosedJaxpr(val, ())
    return None


@dataclasses.dataclass
class OpNode:
    """One operator (jaxpr equation) in the graph."""

    idx: int
    primitive: str
    params: dict[str, Any]
    invars: list[int]          # tensor ids
    outvars: list[int]         # tensor ids
    call_path: tuple[str, ...]  # user stack frames, outermost first
    scope: tuple[str, ...] = ()  # names of inlined call frames (e.g. remat)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"OpNode({self.idx}:{self.primitive})"


@dataclasses.dataclass
class TensorEdge:
    """One tensor (jaxpr variable) in the graph."""

    tid: int
    shape: tuple[int, ...]
    dtype: str
    producer: int | None = None        # OpNode idx, None for graph inputs
    consumers: list[int] = dataclasses.field(default_factory=list)
    is_input: bool = False
    is_output: bool = False
    is_const: bool = False

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)) * np.dtype(self.dtype).itemsize


@dataclasses.dataclass
class OpGraph:
    """Operator-level computational graph of one traced program."""

    name: str
    nodes: list[OpNode]
    tensors: dict[int, TensorEdge]
    inputs: list[int]                   # tensor ids in call order
    outputs: list[int]
    closed_jaxpr: ClosedJaxpr | None = None
    # Memoized flattened extraction of closed_jaxpr.  Graphs built by
    # extract_graph ARE their own flattening, so repeated instrumented runs
    # (multi-sample capture, ReplayProfiler) never re-extract.
    _flat_cache: "OpGraph | None" = dataclasses.field(
        default=None, repr=False, compare=False)

    def flat_graph(self) -> "OpGraph":
        """The flattened (inline_calls=True) extraction of this graph's jaxpr.

        Memoized on the instance: extract_graph() seeds the cache with the
        graph itself, and manually constructed OpGraphs pay the extraction
        cost exactly once instead of on every instrumented run.
        """
        if self._flat_cache is None:
            if self.closed_jaxpr is None:
                raise ValueError("OpGraph was built without a ClosedJaxpr")
            self._flat_cache = extract_graph(self.closed_jaxpr, name=self.name,
                                             inline_calls=True)
        return self._flat_cache

    # ---- structural helpers -------------------------------------------------
    def successors(self, node_idx: int) -> list[int]:
        out: list[int] = []
        for tid in self.nodes[node_idx].outvars:
            out.extend(self.tensors[tid].consumers)
        return sorted(set(out))

    def predecessors(self, node_idx: int) -> list[int]:
        out: list[int] = []
        for tid in self.nodes[node_idx].invars:
            p = self.tensors[tid].producer
            if p is not None:
                out.append(p)
        return sorted(set(out))

    def topo_order(self) -> list[int]:
        # jaxpr equations are already topologically sorted.
        return list(range(len(self.nodes)))

    def subgraph_nodes_between(self, src_tids: set[int], dst_tids: set[int]) -> list[int]:
        """Node idxs on any path from the src tensors to the dst tensors.

        Traversal does NOT stop at frontier tensors: a sink tensor may have
        further consumers that feed *another* sink (multi-output graphs), and
        those nodes belong to the region too.  Because the graph is a DAG,
        the fwd∩bwd intersection still yields exactly the between-set.

        The backward sweep runs first (it is bounded by the src frontier) and
        the forward sweep only expands inside the backward set: every node on
        a src→dst path is backward-reachable from dst, so restricting the
        forward frontier this way keeps each region query O(|region|) instead
        of walking the whole downstream graph.
        """
        # backward reachable from dst (stops at src tensors)
        bwd: set[int] = set()
        frontier = [self.tensors[t].producer for t in dst_tids
                    if self.tensors[t].producer is not None]
        while frontier:
            n = frontier.pop()
            if n is None or n in bwd:
                continue
            bwd.add(n)
            for tid in self.nodes[n].invars:
                if tid in src_tids:
                    continue
                p = self.tensors[tid].producer
                if p is not None:
                    frontier.append(p)
        # forward reachable from src, restricted to the backward set
        fwd: set[int] = set()
        frontier = [c for t in src_tids for c in self.tensors[t].consumers
                    if c in bwd]
        while frontier:
            n = frontier.pop()
            if n in fwd:
                continue
            fwd.add(n)
            for tid in self.nodes[n].outvars:
                frontier.extend(c for c in self.tensors[tid].consumers
                                if c in bwd)
        return sorted(fwd)


def _call_path(eqn, max_frames: int = 12) -> tuple[str, ...]:
    """User-code call path of an equation, outermost first."""
    si = eqn.source_info
    tb = getattr(si, "traceback", None)
    if tb is None:
        return ()
    try:
        import jax._src.source_info_util as siu
        frames = list(siu.user_frames(tb))
    except Exception:
        frames = [f for f in tb.frames
                  if "site-packages/jax" not in f.file_name]
    out = []
    for f in frames[:max_frames]:
        fname = f.file_name.rsplit("/", 1)[-1]
        line = getattr(f, "start_line", None) or getattr(f, "line_num", 0)
        out.append(f"{fname}:{f.function_name}:{line}")
    # user_frames yields innermost first; we want outermost first so common
    # prefixes correspond to shared high-level call sites (Algorithm 2).
    return tuple(reversed(out))


def extract_graph(closed_jaxpr: ClosedJaxpr, *, name: str = "graph",
                  inline_calls: bool = True) -> OpGraph:
    """Build an OpGraph from a ClosedJaxpr, optionally inlining call prims."""

    nodes: list[OpNode] = []
    tensors: dict[int, TensorEdge] = {}
    var_ids: dict[Any, int] = {}
    next_tid = [0]

    def tid_for(v, *, scope_suffix: str = "") -> int:
        key = (id(v), scope_suffix)
        if key not in var_ids:
            t = next_tid[0]
            next_tid[0] += 1
            var_ids[key] = t
            aval = v.aval
            tensors[t] = TensorEdge(
                tid=t, shape=tuple(getattr(aval, "shape", ())),
                dtype=str(getattr(aval, "dtype", "float32")))
        return var_ids[key]

    def lit_tid(v) -> int:
        t = next_tid[0]
        next_tid[0] += 1
        arr = np.asarray(v.val)
        tensors[t] = TensorEdge(tid=t, shape=tuple(arr.shape), dtype=str(arr.dtype),
                                is_const=True)
        return t

    def walk(jaxpr: Jaxpr, env: dict[Var, int], scope: tuple[str, ...]):
        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            inner = _nested_jaxpr(eqn) if inline_calls else None
            if inner is not None and prim in _INLINE_PRIMITIVES:
                # Inline: map callee invars to caller tensor ids.
                inner_env: dict[Var, int] = {}
                for cv, cval in zip(inner.jaxpr.constvars, inner.consts):
                    t = next_tid[0]
                    next_tid[0] += 1
                    arr = np.asarray(cval) if not hasattr(cval, "aval") else cval
                    tensors[t] = TensorEdge(
                        tid=t, shape=tuple(np.shape(arr)), dtype=str(np.asarray(arr).dtype)
                        if not hasattr(arr, "dtype") else str(arr.dtype), is_const=True)
                    inner_env[cv] = t
                for iv, outer_v in zip(inner.jaxpr.invars, eqn.invars):
                    inner_env[iv] = (lit_tid(outer_v) if isinstance(outer_v, Literal)
                                     else env[outer_v])
                sub_scope = scope + (prim,)
                walk(inner.jaxpr, inner_env, sub_scope)
                for ov, inner_ov in zip(eqn.outvars, inner.jaxpr.outvars):
                    if isinstance(inner_ov, Literal):
                        env[ov] = lit_tid(inner_ov)
                    else:
                        env[ov] = inner_env[inner_ov]
                continue

            in_tids = [lit_tid(v) if isinstance(v, Literal) else env[v]
                       for v in eqn.invars]
            out_tids = []
            for v in eqn.outvars:
                t = next_tid[0]
                next_tid[0] += 1
                aval = v.aval
                tensors[t] = TensorEdge(tid=t, shape=tuple(getattr(aval, "shape", ())),
                                        dtype=str(getattr(aval, "dtype", "float32")))
                env[v] = t
                out_tids.append(t)

            idx = len(nodes)
            node = OpNode(idx=idx, primitive=prim, params=dict(eqn.params),
                          invars=in_tids, outvars=out_tids,
                          call_path=_call_path(eqn), scope=scope)
            nodes.append(node)
            for t in in_tids:
                tensors[t].consumers.append(idx)
            for t in out_tids:
                tensors[t].producer = idx

    env: dict[Var, int] = {}
    jaxpr = closed_jaxpr.jaxpr
    for cv, cval in zip(jaxpr.constvars, closed_jaxpr.consts):
        t = next_tid[0]
        next_tid[0] += 1
        shape = tuple(np.shape(cval))
        dtype = str(cval.dtype) if hasattr(cval, "dtype") else str(np.asarray(cval).dtype)
        tensors[t] = TensorEdge(tid=t, shape=shape, dtype=dtype, is_const=True)
        env[cv] = t
    inputs = []
    for iv in jaxpr.invars:
        t = next_tid[0]
        next_tid[0] += 1
        aval = iv.aval
        tensors[t] = TensorEdge(tid=t, shape=tuple(getattr(aval, "shape", ())),
                                dtype=str(getattr(aval, "dtype", "float32")),
                                is_input=True)
        env[iv] = t
        inputs.append(t)

    walk(jaxpr, env, ())

    outputs = []
    for ov in jaxpr.outvars:
        t = lit_tid(ov) if isinstance(ov, Literal) else env[ov]
        tensors[t].is_output = True
        outputs.append(t)

    g = OpGraph(name=name, nodes=nodes, tensors=tensors, inputs=inputs,
                outputs=outputs, closed_jaxpr=closed_jaxpr)
    if inline_calls:
        g._flat_cache = g   # the extraction is its own flattening
    return g


def trace(fn: Callable, *example_args, name: str | None = None,
          inline_calls: bool = True, **example_kwargs) -> OpGraph:
    """Trace ``fn`` on example args and return its operator graph."""
    closed = jax.make_jaxpr(fn)(*example_args, **example_kwargs)
    return extract_graph(closed, name=name or getattr(fn, "__name__", "graph"),
                         inline_calls=inline_calls)
