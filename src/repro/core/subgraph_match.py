"""Topology-aware subgraph matching (the paper's Algorithm 1).

Builds an op/tensor bipartite flow graph per side, computes dominator trees
(Cooper–Harvey–Kennedy over the DAG's reverse-post-order), extracts the
dominator path from the virtual source to the virtual sink, and uses
bijectively-matched equivalent tensors that appear on BOTH dominator paths as
cut points.  Regions between consecutive cut points are recursively matched
(divide and conquer), giving O(N²) overall as in the paper.

Weights/constants are side inputs: they do not participate in domination
(otherwise every parameter edge would destroy the dominator chain — in the
paper's Figure 7 the cut points are activations, with weights entering each
region from the side).  Ops reachable only from side inputs (e.g. a weight
transpose) are assigned to the region of their first activation-consumer.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.graph import OpGraph

# Flow vertices are encoded as ints for dict/set speed: op node n -> 2n,
# tensor t -> 2t+1 (odd), virtual source/sink -> negative sentinels.
_SRC = -2
_SNK = -4


def _build_flow(graph: OpGraph, src_tids: Sequence[int],
                snk_tids: Sequence[int],
                nodes: list[int] | None = None,
                ) -> tuple[dict[int, list[int]], list[int]]:
    """Adjacency of the op/tensor flow graph between given tensor frontiers.

    Also returns the between-set node list so callers don't recompute the
    (BFS-heavy) ``subgraph_nodes_between`` for the same frontier; a caller
    that already has it can pass it in via ``nodes``.
    """
    succ: dict[int, list[int]] = {_SRC: [], _SNK: []}
    src_set, snk_set = set(src_tids), set(snk_tids)
    if nodes is None:
        nodes = graph.subgraph_nodes_between(src_set, snk_set)
    node_set = set(nodes)

    interior_tids: set[int] = set()
    for n in nodes:
        for t in graph.nodes[n].outvars:
            if t not in snk_set:
                interior_tids.add(t)

    for t in src_set:
        succ[_SRC].append(2 * t + 1)
        succ[2 * t + 1] = []
    for t in snk_set:
        succ.setdefault(2 * t + 1, []).append(_SNK)
    for t in interior_tids:
        succ.setdefault(2 * t + 1, [])

    for n in nodes:
        v = 2 * n
        succ[v] = []
        for t in graph.nodes[n].outvars:
            if t in snk_set or t in interior_tids:
                succ[v].append(2 * t + 1)
    for t in list(src_set) + list(interior_tids):
        for c in graph.tensors[t].consumers:
            if c in node_set:
                succ[2 * t + 1].append(2 * c)
    return succ, nodes


def _rpo(succ: dict[int, list[int]]) -> list[int]:
    """Reverse post-order from _SRC (iterative DFS)."""
    visited: set[int] = set()
    post: list[int] = []
    stack: list[tuple[int, int]] = [(_SRC, 0)]
    visited.add(_SRC)
    while stack:
        v, i = stack.pop()
        kids = succ.get(v, [])
        if i < len(kids):
            stack.append((v, i + 1))
            k = kids[i]
            if k not in visited:
                visited.add(k)
                stack.append((k, 0))
        else:
            post.append(v)
    return list(reversed(post))


def _dominator_path_reference(succ: dict[int, list[int]]) -> list[int]:
    """Vertices dominating _SNK, from _SRC to _SNK (seed implementation).

    Dict-based Cooper–Harvey–Kennedy fixpoint, kept verbatim as the
    equivalence oracle for the vectorized solve below
    (tests/test_subgraph_match.py asserts identical paths).
    """
    rpo = _rpo(succ)
    order = {v: i for i, v in enumerate(rpo)}
    preds: dict[int, list[int]] = {v: [] for v in rpo}
    for v in rpo:
        for k in succ.get(v, []):
            if k in order:
                preds[k].append(v)

    idom: dict[int, int | None] = {v: None for v in rpo}
    idom[_SRC] = _SRC

    def intersect(a: int, b: int) -> int:
        while a != b:
            while order[a] > order[b]:
                a = idom[a]  # type: ignore[assignment]
            while order[b] > order[a]:
                b = idom[b]  # type: ignore[assignment]
        return a

    changed = True
    while changed:
        changed = False
        for v in rpo:
            if v == _SRC:
                continue
            new = None
            for p in preds[v]:
                if idom[p] is not None:
                    new = p if new is None else intersect(new, p)
            if new is not None and idom[v] != new:
                idom[v] = new
                changed = True

    if _SNK not in idom or idom[_SNK] is None:
        return []
    path = [_SNK]
    v = _SNK
    while v != _SRC:
        v = idom[v]  # type: ignore[assignment]
        if v is None:
            return []
        path.append(v)
    return list(reversed(path))


def _dominator_path(succ: dict[int, list[int]]) -> list[int]:
    """Vertices dominating _SNK, from _SRC to _SNK (vectorized solve).

    The flow graph is a DAG, so in reverse post-order every predecessor of
    a vertex precedes it — one RPO sweep with the Cooper–Harvey–Kennedy
    intersect computes final idoms (no fixpoint iteration).  All state
    lives in RPO-indexed numpy int32 arrays: predecessor lists in CSR form
    (one ``np.argsort`` over the edge array instead of per-vertex dict
    appends) and idom chain walks over a flat array.  Semantically
    identical to :func:`_dominator_path_reference`; the matcher tests and
    a dedicated oracle test assert equal paths.
    """
    rpo = _rpo(succ)
    n = len(rpo)
    order = {v: i for i, v in enumerate(rpo)}
    if _SNK not in order:
        return []

    # CSR predecessor lists in RPO index space, built by one argsort over
    # the flat (dst, src) edge pairs
    dst: list[int] = []
    src: list[int] = []
    for v in rpo:
        vi = order[v]
        for k in succ.get(v, []):
            ki = order.get(k)
            if ki is not None:
                dst.append(ki)
                src.append(vi)
    if dst:
        dst_a = np.asarray(dst, dtype=np.int32)
        src_a = np.asarray(src, dtype=np.int32)
        perm = np.argsort(dst_a, kind="stable")
        dst_a = dst_a[perm]
        src_a = src_a[perm]
        starts = np.searchsorted(dst_a, np.arange(n + 1, dtype=np.int32))
    else:
        src_a = np.empty(0, dtype=np.int32)
        starts = np.zeros(n + 1, dtype=np.int64)

    NONE = np.int32(-1)
    idom = np.full(n, NONE, dtype=np.int32)
    idom[0] = 0                               # _SRC is rpo[0] by construction

    for vi in range(1, n):
        new = NONE
        for pi in src_a[starts[vi]:starts[vi + 1]]:
            if idom[pi] == NONE:
                continue                      # unreachable-from-_SRC pred
            if new == NONE:
                new = pi
                continue
            # CHK intersect: walk both chains up to the common ancestor
            a, b = int(new), int(pi)
            while a != b:
                while a > b:
                    a = int(idom[a])
                while b > a:
                    b = int(idom[b])
            new = np.int32(a)
        idom[vi] = new

    snk = order[_SNK]
    if idom[snk] == NONE:
        return []
    path = [snk]
    v = snk
    while v != 0:
        v = int(idom[v])
        if v < 0:
            return []
        path.append(v)
    return [rpo[i] for i in reversed(path)]


# Regions smaller than this solve their dominator path monolithically; above
# it the piecewise path (single-crossing pre-cuts + segment memo) amortizes
# repeated-block spans.
_PIECEWISE_MIN_NODES = 192

_SEG_MISS = object()


def _segment_dom(graph: OpGraph, sd: list[str], seg_memo: dict,
                 src_t: list[int], snk_t: list[int]) -> list[int] | None:
    """Interior dominator-path tensors of one path segment (endpoints
    excluded), memoized over identical struct-digest spans.

    A segment between two produced frontier tensors is keyed by its span
    length, endpoint struct digests, and endpoint outvar slots; a hit is
    verified by full digest-slice equality, then the recorded path is
    translated by node-index delta.  Templates are only recorded when the
    segment's between-set lies inside the span (so translation is sound);
    anything else simply re-solves.  Returns None when the segment has no
    src->snk path (caller falls back to the monolithic solve).
    """
    key = None
    ps = pk = None
    if len(src_t) == 1 and len(snk_t) == 1:
        ps = graph.tensors[src_t[0]].producer
        pk = graph.tensors[snk_t[0]].producer
        if ps is not None and pk is not None and pk > ps:
            key = (pk - ps, sd[ps], sd[pk],
                   graph.nodes[ps].outvars.index(src_t[0]),
                   graph.nodes[pk].outvars.index(snk_t[0]))
            hit = seg_memo.get(key, _SEG_MISS)
            if hit is not None and hit is not _SEG_MISS \
                    and sd[ps:pk + 1] == hit[0]:
                return [graph.nodes[ps + d].outvars[s] for d, s in hit[1]]
        else:
            key = None
    flow, seg_nodes = _build_flow(graph, src_t, snk_t)
    path = _dominator_path(flow)
    if not path:
        return None
    ends = set(src_t) | set(snk_t)
    seg = [v >> 1 for v in path if v > 0 and v & 1 and (v >> 1) not in ends]
    if key is not None:
        rel: list[tuple[int, int]] | None = []
        if all(ps < nn <= pk for nn in seg_nodes):
            for tid in seg:
                p = graph.tensors[tid].producer
                if p is None or not ps < p <= pk:
                    rel = None
                    break
                rel.append((p - ps, graph.nodes[p].outvars.index(tid)))
        else:
            rel = None
        seg_memo[key] = None if rel is None else (sd[ps:pk + 1], rel)
    return seg


def _piecewise_dom(graph: OpGraph, sd: list[str], seg_memo: dict,
                   src_t: list[int], snk_t: list[int],
                   nodes: list[int]) -> list[int] | None:
    """Dominator-path tensors of a region via single-crossing pre-cuts.

    Any flow path from the region's sources to its sinks that moves past a
    topological boundary must do so through a produced tensor whose live
    interval crosses that boundary; when exactly one tensor crosses, every
    path passes through it, so it lies on the dominator path.  The dominator
    chain decomposes exactly at its own vertices, so solving each inter-cut
    segment independently (:func:`_segment_dom`, with the repeated-block
    memo) and concatenating reproduces the monolithic solve's path — this is
    how block spans turn the top-level O(N) dominator solve into one
    representative-segment solve plus O(repeats) digest-verified
    translations.  Returns the frontier-excluded tensor list in path order,
    or None when the region does not fit (caller runs the monolithic solve).
    """
    tensors = graph.tensors
    gnodes = graph.nodes
    src_set, snk_set = set(src_t), set(snk_t)
    pos = {nn: i for i, nn in enumerate(nodes)}
    n = len(nodes)
    for t in src_set:
        if tensors[t].producer in pos:
            return None                     # source produced inside region
    for t in snk_set:
        if tensors[t].producer not in pos and t not in src_set:
            return None                     # stray sink: unreachable vertex

    # live interval sweep: tensor crossing boundaries b in [lo, hi), where
    # boundary b separates node positions < b from >= b.  Vectorized over
    # the graph's memoized flat edge arrays (one C pass per reduction).
    from repro.core.graph import edge_arrays
    e_p, e_c, e_t, o_n, o_t = edge_arrays(graph)
    cnt = np.zeros(n + 1, dtype=np.int64)
    acc = np.zeros(n + 1, dtype=np.int64)

    n_all = len(gnodes)
    pos_of = np.full(n_all, -1, dtype=np.int64)
    pos_of[np.asarray(nodes, dtype=np.int64)] = np.arange(n, dtype=np.int64)
    # max in-region consumer position per tensor
    maxq = np.full(int(o_t.max()) + 2 if len(o_t) else 1, -1, dtype=np.int64)
    if len(e_t):
        np.maximum.at(maxq, e_t, pos_of[e_c])

    def mark(tid: int, lo: int, hi: int) -> None:
        cnt[lo] += 1
        cnt[hi] -= 1
        acc[lo] += tid
        acc[hi] -= tid

    for t in src_set:
        q = -1
        for c in tensors[t].consumers:
            i = pos.get(c)
            if i is not None and i > q:
                q = i
        hi = n if t in snk_set else q + 1
        if hi > 0:
            mark(t, 0, hi)
    p_pos = pos_of[o_n]
    sel = p_pos >= 0
    ts = o_t[sel]
    ps = p_pos[sel]
    if len(ts):
        is_snk = np.isin(ts, np.fromiter(snk_set, dtype=np.int32))
        his = np.where(is_snk, n, maxq[ts] + 1)
        los = ps + 1
        keep = his > los
        ts, los, his = ts[keep], los[keep], his[keep]
        np.add.at(cnt, los, 1)
        np.add.at(cnt, his, -1)
        np.add.at(acc, los, ts)
        np.add.at(acc, his, -ts)

    ccnt = np.cumsum(cnt[:n])
    cacc = np.cumsum(acc[:n])
    cuts: list[int] = []
    last = -1
    for b in np.nonzero(ccnt[1:] == 1)[0] + 1:
        tid = int(cacc[b])
        if tid != last and tid not in src_set and tid not in snk_set:
            cuts.append(tid)
            last = tid
    if len(cuts) < 2:
        return None

    dom: list[int] = []
    fr = [list(src_t)] + [[c] for c in cuts] + [list(snk_t)]
    for k in range(len(fr) - 1):
        seg = _segment_dom(graph, sd, seg_memo, fr[k], fr[k + 1])
        if seg is None:
            return None
        dom.extend(seg)
        if k < len(fr) - 2:
            dom.append(fr[k + 1][0])
    return dom


@dataclasses.dataclass
class MatchedRegion:
    """A pair of semantically equivalent subgraphs, one per side."""

    nodes_a: list[int]
    nodes_b: list[int]
    in_pair: tuple[int, int] | None    # (tid_a, tid_b) entry cut point
    out_pair: tuple[int, int] | None   # exit cut point
    depth: int = 0

    def size(self) -> int:
        return max(len(self.nodes_a), len(self.nodes_b))


def _attach_side_ops(graph: OpGraph, region_nodes: list[int],
                     claimed: set[int]) -> list[int]:
    """Pull in unclaimed producers of side inputs (weight preprocessing)."""
    out = set(region_nodes)
    frontier = list(region_nodes)
    while frontier:
        n = frontier.pop()
        for t in graph.nodes[n].invars:
            p = graph.tensors[t].producer
            if p is not None and p not in out and p not in claimed:
                out.add(p)
                frontier.append(p)
    return sorted(out)


@dataclasses.dataclass
class _RegionTemplate:
    """Memoized recursion result for one repeated-block subproblem.

    Node indices and tensor references are stored as deltas from the span
    start so the template can be re-emitted (translated) for every later
    repeat of the same block, after digest verification.
    """

    digests_a: list[str]           # struct digests over the a-side span
    digests_b: list[str]
    norm_pairs: frozenset          # normalized eq-pair layout inside the span
    regions: list[tuple]           # (nodes_a deltas, nodes_b deltas,
    #                                 in_ref, out_ref, depth delta)


def match_subgraphs(
    graph_a: OpGraph, graph_b: OpGraph,
    eq_pairs: Sequence[tuple[int, int]],
    *,
    stream_inputs_a: Sequence[int] | None = None,
    stream_inputs_b: Sequence[int] | None = None,
    block_memo: bool | None = None,
) -> list[MatchedRegion]:
    """Algorithm 1: recursively match equivalent regions of two graphs.

    ``eq_pairs`` are equivalent-tensor pairs from TensorMatcher (they will be
    reduced to bijective pairs here).  ``stream_inputs_*`` select which graph
    inputs carry the activation stream (default: all inputs shared by an
    equivalent pair, falling back to all inputs).

    ``block_memo`` (default: auto-on at >=64 nodes) memoizes the recursion
    over repeated-block spans: the first repeat of a layer stack runs the
    full dominator solve and records a translation template; every later
    repeat whose span's struct digests AND normalized eq-pair layout are
    identical re-emits the translated regions directly — region growth
    costs one representative block plus O(period) digest verification per
    repeat, and any divergent repeat (mismatched digests or pair layout)
    falls back to the full solve for just that span.
    """
    from repro.core.tensor_match import bijective_pairs
    eq = bijective_pairs(eq_pairs)
    eq_a2b = dict(eq)
    eq_b_tids = set(eq_a2b.values())

    def default_stream(graph: OpGraph, side_is_a: bool) -> list[int]:
        tids = []
        for t in graph.inputs:
            if side_is_a and t in eq_a2b:
                tids.append(t)
            elif not side_is_a and t in eq_b_tids:
                tids.append(t)
        # Weights are side inputs (paper Fig. 7): an input consumed by a
        # large fraction of all operators (a weight matrix feeding every
        # layer) gives every op a bypass path from _SRC and destroys the
        # dominator chain.  Keep only low-fan-out inputs as stream sources
        # when that leaves any — the adaptive retry below still covers the
        # cases this heuristic gets wrong.
        cap = max(8, len(graph.nodes) // 16)
        low = [t for t in tids if len(graph.tensors[t].consumers) <= cap]
        if low:
            tids = low
        return tids or list(graph.inputs)

    src_a = list(stream_inputs_a) if stream_inputs_a else default_stream(graph_a, True)
    src_b = list(stream_inputs_b) if stream_inputs_b else default_stream(graph_b, False)

    regions: list[MatchedRegion] = []

    # -- repeated-block recursion memo --------------------------------------
    n_nodes_total = max(len(graph_a.nodes), len(graph_b.nodes))
    use_memo = (block_memo if block_memo is not None
                else n_nodes_total >= 64)
    sd_a: list[str] | None = None
    sd_b: list[str] | None = None
    if use_memo:
        from repro.core.graph import block_structure
        try:
            sd_a = block_structure(graph_a).struct_digests
            sd_b = block_structure(graph_b).struct_digests
        except Exception:
            use_memo = False
    memo: dict[tuple, "_RegionTemplate | None"] = {}
    seg_memo_a: dict = {}
    seg_memo_b: dict = {}
    _MISS = object()

    def _dom_and_nodes(graph: OpGraph, sd: "list[str] | None",
                       seg_memo: dict, src_t: list[int], snk_t: list[int]
                       ) -> tuple[list[int], list[int]]:
        """Frontier-excluded dominator-path tensors + between-set nodes."""
        src_set, snk_set = set(src_t), set(snk_t)
        nodes = graph.subgraph_nodes_between(src_set, snk_set)
        if sd is not None and len(nodes) >= _PIECEWISE_MIN_NODES:
            dom = _piecewise_dom(graph, sd, seg_memo, src_t, snk_t, nodes)
            if dom is not None:
                return dom, nodes
        flow, _ = _build_flow(graph, src_t, snk_t, nodes=nodes)
        path = _dominator_path(flow)
        ends = src_set | snk_set
        return [v >> 1 for v in path if v > 0 and v & 1
                and (v >> 1) not in ends], nodes

    def _span(graph: OpGraph, src: int, snk: int) -> tuple[int, int] | None:
        """Inclusive node-index span between two produced frontier tensors
        (every between-node lies in it: node order is topological)."""
        ps = graph.tensors[src].producer
        pk = graph.tensors[snk].producer
        if ps is None or pk is None or pk <= ps:
            return None
        return ps + 1, pk

    def _norm_pairs(spa: tuple[int, int], spb: tuple[int, int]) -> frozenset:
        """Normalized eq-pair layout of a span pair: for every a-side output
        slot, the (delta, slot) of its partner when that partner is produced
        inside the b-side span (only such pairs can become cut points)."""
        out = set()
        for idx in range(spa[0], spa[1] + 1):
            for slot, ta in enumerate(graph_a.nodes[idx].outvars):
                tb = eq_a2b.get(ta)
                entry = None
                if tb is not None:
                    pb = graph_b.tensors[tb].producer
                    if pb is not None and spb[0] <= pb <= spb[1]:
                        entry = (pb - spb[0],
                                 graph_b.nodes[pb].outvars.index(tb))
                out.add((idx - spa[0], slot, entry))
        return frozenset(out)

    def _make_template(emitted: list[MatchedRegion],
                       spa, spb, in_pair, out_pair, depth
                       ) -> "_RegionTemplate | None":
        def tid_ref(graph, span, tid, side):
            if in_pair is not None and tid == in_pair[side]:
                return ("in",)
            if out_pair is not None and tid == out_pair[side]:
                return ("out",)
            p = graph.tensors[tid].producer
            if p is None or not span[0] <= p <= span[1]:
                return None
            return ("t", p - span[0], graph.nodes[p].outvars.index(tid))

        def pair_ref(pair):
            if pair is None:
                return ("none",)
            ra = tid_ref(graph_a, spa, pair[0], 0)
            rb = tid_ref(graph_b, spb, pair[1], 1)
            return None if ra is None or rb is None else (ra, rb)

        tpl_regions = []
        for r in emitted:
            if any(not spa[0] <= x <= spa[1] for x in r.nodes_a) or \
                    any(not spb[0] <= x <= spb[1] for x in r.nodes_b):
                return None
            ri = pair_ref(r.in_pair)
            ro = pair_ref(r.out_pair)
            if ri is None or ro is None:
                return None
            tpl_regions.append(
                ([x - spa[0] for x in r.nodes_a],
                 [x - spb[0] for x in r.nodes_b], ri, ro, r.depth - depth))
        return _RegionTemplate(
            digests_a=sd_a[spa[0]:spa[1] + 1],
            digests_b=sd_b[spb[0]:spb[1] + 1],
            norm_pairs=_norm_pairs(spa, spb), regions=tpl_regions)

    def _emit_template(tpl: _RegionTemplate, spa, spb,
                       in_pair, out_pair, depth) -> None:
        def resolve(ref, span, graph):
            if ref[0] == "t":
                return graph.nodes[span[0] + ref[1]].outvars[ref[2]]
            raise AssertionError(ref)

        def resolve_pair(ref, boundary_in, boundary_out):
            if ref == ("none",):
                return None
            ra, rb = ref
            if ra[0] == "in" or rb[0] == "in":
                return boundary_in
            if ra[0] == "out" or rb[0] == "out":
                return boundary_out
            return (resolve(ra, spa, graph_a), resolve(rb, spb, graph_b))

        for da, db, ri, ro, ddepth in tpl.regions:
            regions.append(MatchedRegion(
                nodes_a=[spa[0] + x for x in da],
                nodes_b=[spb[0] + x for x in db],
                in_pair=resolve_pair(ri, in_pair, out_pair),
                out_pair=resolve_pair(ro, in_pair, out_pair),
                depth=depth + ddepth))

    def _memo_recurse(src_ta, snk_ta, src_tb, snk_tb,
                      in_pair, out_pair, depth) -> bool:
        """Serve one recursion step from the block memo.  Returns True when
        the step was handled (template emitted, or recorded on first miss)."""
        if not (len(src_ta) == 1 and len(snk_ta) == 1
                and len(src_tb) == 1 and len(snk_tb) == 1):
            return False
        spa = _span(graph_a, src_ta[0], snk_ta[0])
        spb = _span(graph_b, src_tb[0], snk_tb[0])
        if spa is None or spb is None:
            return False
        pa, pk = graph_a.tensors[src_ta[0]].producer, \
            graph_a.tensors[snk_ta[0]].producer
        pb, pl = graph_b.tensors[src_tb[0]].producer, \
            graph_b.tensors[snk_tb[0]].producer
        key = (spa[1] - spa[0], spb[1] - spb[0], sd_a[spa[0]], sd_b[spb[0]],
               graph_a.nodes[pa].outvars.index(src_ta[0]),
               graph_a.nodes[pk].outvars.index(snk_ta[0]),
               graph_b.nodes[pb].outvars.index(src_tb[0]),
               graph_b.nodes[pl].outvars.index(snk_tb[0]))
        tpl = memo.get(key, _MISS)
        if tpl is _MISS:
            base = len(regions)
            _recurse_body(src_ta, snk_ta, src_tb, snk_tb,
                          in_pair, out_pair, depth)
            memo[key] = _make_template(regions[base:], spa, spb,
                                       in_pair, out_pair, depth)
            return True
        if tpl is None:
            return False
        # verify the translated span is byte-for-byte the template's shape:
        # identical struct digests and identical eq-pair layout — a mutated
        # repeat fails here and falls through to the full dominator solve
        if sd_a[spa[0]:spa[1] + 1] != tpl.digests_a or \
                sd_b[spb[0]:spb[1] + 1] != tpl.digests_b or \
                _norm_pairs(spa, spb) != tpl.norm_pairs:
            return False
        _emit_template(tpl, spa, spb, in_pair, out_pair, depth)
        return True

    def recurse(src_ta: list[int], snk_ta: list[int],
                src_tb: list[int], snk_tb: list[int],
                in_pair, out_pair, depth: int):
        if use_memo and _memo_recurse(src_ta, snk_ta, src_tb, snk_tb,
                                      in_pair, out_pair, depth):
            return
        _recurse_body(src_ta, snk_ta, src_tb, snk_tb,
                      in_pair, out_pair, depth)

    def _recurse_body(src_ta: list[int], snk_ta: list[int],
                      src_tb: list[int], snk_tb: list[int],
                      in_pair, out_pair, depth: int):
        # dominator-path tensor tids per side (frontier tensors excluded);
        # large regions use the piecewise block-span path, small ones the
        # monolithic flow solve — both produce the identical path
        dom_a, na = _dom_and_nodes(graph_a, sd_a if use_memo else None,
                                   seg_memo_a, src_ta, snk_ta)
        dom_b, nb = _dom_and_nodes(graph_b, sd_b if use_memo else None,
                                   seg_memo_b, src_tb, snk_tb)
        dom_b_order = {t: i for i, t in enumerate(dom_b)}
        # ordered, order-consistent cut pairs (strictly increasing in B)
        cuts: list[tuple[int, int]] = []
        last_b = -1
        for ta in dom_a:
            tb = eq_a2b.get(ta)
            if tb is None or tb not in dom_b_order:
                continue
            if dom_b_order[tb] > last_b:
                cuts.append((ta, tb))
                last_b = dom_b_order[tb]
        if not cuts:  # |E| = 1 base case: the whole region matches
            if na or nb:
                regions.append(MatchedRegion(nodes_a=na, nodes_b=nb,
                                             in_pair=in_pair, out_pair=out_pair,
                                             depth=depth))
            return
        # divide and conquer on the cut points
        bounds_a = [src_ta] + [[ta] for ta, _ in cuts] + [snk_ta]
        bounds_b = [src_tb] + [[tb] for _, tb in cuts] + [snk_tb]
        pair_bounds = [in_pair] + cuts + [out_pair]
        for k in range(len(bounds_a) - 1):
            recurse(bounds_a[k], bounds_a[k + 1],
                    bounds_b[k], bounds_b[k + 1],
                    pair_bounds[k], pair_bounds[k + 1], depth + 1)

    recurse(src_a, list(graph_a.outputs), src_b, list(graph_b.outputs),
            None, None, 0)

    # Adaptive source selection: a heavily-shared side input (e.g. a weight
    # matrix reused by every layer) in the source set gives every operator a
    # bypass path from _SRC, destroying the dominator chain (no cut points,
    # one giant region).  If the first pass is degenerate and there are
    # several matched inputs, retry with each input pair as the sole stream
    # source and keep the most fine-grained (paper Fig. 7 treats weights as
    # side inputs for exactly this reason).
    n_nodes = max(len(graph_a.nodes), len(graph_b.nodes))
    degenerate = len(regions) <= max(2, n_nodes // 50)
    if (degenerate and stream_inputs_a is None and len(src_a) > 1
            and n_nodes >= 10):
        best = regions
        src_b_set = set(src_b)
        for ta in src_a:
            tb = eq_a2b.get(ta)
            if tb is None or tb not in src_b_set:
                continue
            regions = []
            recurse([ta], list(graph_a.outputs), [tb],
                    list(graph_b.outputs), None, None, 0)
            if len(regions) > len(best):
                best = regions
        regions = best

    # attach weight-only side ops to their consuming region (a region's own
    # nodes seed ``out`` inside _attach_side_ops, so they never hit the
    # claimed check — passing the full claimed set is equivalent to
    # subtracting them, without rebuilding an O(N) set per region)
    claimed_a = {n for r in regions for n in r.nodes_a}
    claimed_b = {n for r in regions for n in r.nodes_b}
    for r in regions:
        r.nodes_a = _attach_side_ops(graph_a, r.nodes_a, claimed_a)
        r.nodes_b = _attach_side_ops(graph_b, r.nodes_b, claimed_b)
        claimed_a.update(r.nodes_a)
        claimed_b.update(r.nodes_b)
    return regions
