"""Topology-aware subgraph matching (the paper's Algorithm 1).

Builds an op/tensor bipartite flow graph per side, computes dominator trees
(Cooper–Harvey–Kennedy over the DAG's reverse-post-order), extracts the
dominator path from the virtual source to the virtual sink, and uses
bijectively-matched equivalent tensors that appear on BOTH dominator paths as
cut points.  Regions between consecutive cut points are recursively matched
(divide and conquer), giving O(N²) overall as in the paper.

Weights/constants are side inputs: they do not participate in domination
(otherwise every parameter edge would destroy the dominator chain — in the
paper's Figure 7 the cut points are activations, with weights entering each
region from the side).  Ops reachable only from side inputs (e.g. a weight
transpose) are assigned to the region of their first activation-consumer.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.graph import OpGraph

# Flow vertices are encoded as ints for dict/set speed: op node n -> 2n,
# tensor t -> 2t+1 (odd), virtual source/sink -> negative sentinels.
_SRC = -2
_SNK = -4


def _build_flow(graph: OpGraph, src_tids: Sequence[int],
                snk_tids: Sequence[int]
                ) -> tuple[dict[int, list[int]], list[int]]:
    """Adjacency of the op/tensor flow graph between given tensor frontiers.

    Also returns the between-set node list so callers don't recompute the
    (BFS-heavy) ``subgraph_nodes_between`` for the same frontier.
    """
    succ: dict[int, list[int]] = {_SRC: [], _SNK: []}
    src_set, snk_set = set(src_tids), set(snk_tids)
    nodes = graph.subgraph_nodes_between(src_set, snk_set)
    node_set = set(nodes)

    interior_tids: set[int] = set()
    for n in nodes:
        for t in graph.nodes[n].outvars:
            if t not in snk_set:
                interior_tids.add(t)

    for t in src_set:
        succ[_SRC].append(2 * t + 1)
        succ[2 * t + 1] = []
    for t in snk_set:
        succ.setdefault(2 * t + 1, []).append(_SNK)
    for t in interior_tids:
        succ.setdefault(2 * t + 1, [])

    for n in nodes:
        v = 2 * n
        succ[v] = []
        for t in graph.nodes[n].outvars:
            if t in snk_set or t in interior_tids:
                succ[v].append(2 * t + 1)
    for t in list(src_set) + list(interior_tids):
        for c in graph.tensors[t].consumers:
            if c in node_set:
                succ[2 * t + 1].append(2 * c)
    return succ, nodes


def _rpo(succ: dict[int, list[int]]) -> list[int]:
    """Reverse post-order from _SRC (iterative DFS)."""
    visited: set[int] = set()
    post: list[int] = []
    stack: list[tuple[int, int]] = [(_SRC, 0)]
    visited.add(_SRC)
    while stack:
        v, i = stack.pop()
        kids = succ.get(v, [])
        if i < len(kids):
            stack.append((v, i + 1))
            k = kids[i]
            if k not in visited:
                visited.add(k)
                stack.append((k, 0))
        else:
            post.append(v)
    return list(reversed(post))


def _dominator_path_reference(succ: dict[int, list[int]]) -> list[int]:
    """Vertices dominating _SNK, from _SRC to _SNK (seed implementation).

    Dict-based Cooper–Harvey–Kennedy fixpoint, kept verbatim as the
    equivalence oracle for the vectorized solve below
    (tests/test_subgraph_match.py asserts identical paths).
    """
    rpo = _rpo(succ)
    order = {v: i for i, v in enumerate(rpo)}
    preds: dict[int, list[int]] = {v: [] for v in rpo}
    for v in rpo:
        for k in succ.get(v, []):
            if k in order:
                preds[k].append(v)

    idom: dict[int, int | None] = {v: None for v in rpo}
    idom[_SRC] = _SRC

    def intersect(a: int, b: int) -> int:
        while a != b:
            while order[a] > order[b]:
                a = idom[a]  # type: ignore[assignment]
            while order[b] > order[a]:
                b = idom[b]  # type: ignore[assignment]
        return a

    changed = True
    while changed:
        changed = False
        for v in rpo:
            if v == _SRC:
                continue
            new = None
            for p in preds[v]:
                if idom[p] is not None:
                    new = p if new is None else intersect(new, p)
            if new is not None and idom[v] != new:
                idom[v] = new
                changed = True

    if _SNK not in idom or idom[_SNK] is None:
        return []
    path = [_SNK]
    v = _SNK
    while v != _SRC:
        v = idom[v]  # type: ignore[assignment]
        if v is None:
            return []
        path.append(v)
    return list(reversed(path))


def _dominator_path(succ: dict[int, list[int]]) -> list[int]:
    """Vertices dominating _SNK, from _SRC to _SNK (vectorized solve).

    The flow graph is a DAG, so in reverse post-order every predecessor of
    a vertex precedes it — one RPO sweep with the Cooper–Harvey–Kennedy
    intersect computes final idoms (no fixpoint iteration).  All state
    lives in RPO-indexed numpy int32 arrays: predecessor lists in CSR form
    (one ``np.argsort`` over the edge array instead of per-vertex dict
    appends) and idom chain walks over a flat array.  Semantically
    identical to :func:`_dominator_path_reference`; the matcher tests and
    a dedicated oracle test assert equal paths.
    """
    rpo = _rpo(succ)
    n = len(rpo)
    order = {v: i for i, v in enumerate(rpo)}
    if _SNK not in order:
        return []

    # CSR predecessor lists in RPO index space, built by one argsort over
    # the flat (dst, src) edge pairs
    dst: list[int] = []
    src: list[int] = []
    for v in rpo:
        vi = order[v]
        for k in succ.get(v, []):
            ki = order.get(k)
            if ki is not None:
                dst.append(ki)
                src.append(vi)
    if dst:
        dst_a = np.asarray(dst, dtype=np.int32)
        src_a = np.asarray(src, dtype=np.int32)
        perm = np.argsort(dst_a, kind="stable")
        dst_a = dst_a[perm]
        src_a = src_a[perm]
        starts = np.searchsorted(dst_a, np.arange(n + 1, dtype=np.int32))
    else:
        src_a = np.empty(0, dtype=np.int32)
        starts = np.zeros(n + 1, dtype=np.int64)

    NONE = np.int32(-1)
    idom = np.full(n, NONE, dtype=np.int32)
    idom[0] = 0                               # _SRC is rpo[0] by construction

    for vi in range(1, n):
        new = NONE
        for pi in src_a[starts[vi]:starts[vi + 1]]:
            if idom[pi] == NONE:
                continue                      # unreachable-from-_SRC pred
            if new == NONE:
                new = pi
                continue
            # CHK intersect: walk both chains up to the common ancestor
            a, b = int(new), int(pi)
            while a != b:
                while a > b:
                    a = int(idom[a])
                while b > a:
                    b = int(idom[b])
            new = np.int32(a)
        idom[vi] = new

    snk = order[_SNK]
    if idom[snk] == NONE:
        return []
    path = [snk]
    v = snk
    while v != 0:
        v = int(idom[v])
        if v < 0:
            return []
        path.append(v)
    return [rpo[i] for i in reversed(path)]


@dataclasses.dataclass
class MatchedRegion:
    """A pair of semantically equivalent subgraphs, one per side."""

    nodes_a: list[int]
    nodes_b: list[int]
    in_pair: tuple[int, int] | None    # (tid_a, tid_b) entry cut point
    out_pair: tuple[int, int] | None   # exit cut point
    depth: int = 0

    def size(self) -> int:
        return max(len(self.nodes_a), len(self.nodes_b))


def _attach_side_ops(graph: OpGraph, region_nodes: list[int],
                     claimed: set[int]) -> list[int]:
    """Pull in unclaimed producers of side inputs (weight preprocessing)."""
    out = set(region_nodes)
    frontier = list(region_nodes)
    while frontier:
        n = frontier.pop()
        for t in graph.nodes[n].invars:
            p = graph.tensors[t].producer
            if p is not None and p not in out and p not in claimed:
                out.add(p)
                frontier.append(p)
    return sorted(out)


def match_subgraphs(
    graph_a: OpGraph, graph_b: OpGraph,
    eq_pairs: Sequence[tuple[int, int]],
    *,
    stream_inputs_a: Sequence[int] | None = None,
    stream_inputs_b: Sequence[int] | None = None,
) -> list[MatchedRegion]:
    """Algorithm 1: recursively match equivalent regions of two graphs.

    ``eq_pairs`` are equivalent-tensor pairs from TensorMatcher (they will be
    reduced to bijective pairs here).  ``stream_inputs_*`` select which graph
    inputs carry the activation stream (default: all inputs shared by an
    equivalent pair, falling back to all inputs).
    """
    from repro.core.tensor_match import bijective_pairs
    eq = bijective_pairs(eq_pairs)
    eq_a2b = dict(eq)
    eq_b_tids = set(eq_a2b.values())

    def default_stream(graph: OpGraph, side_is_a: bool) -> list[int]:
        tids = []
        for t in graph.inputs:
            if side_is_a and t in eq_a2b:
                tids.append(t)
            elif not side_is_a and t in eq_b_tids:
                tids.append(t)
        return tids or list(graph.inputs)

    src_a = list(stream_inputs_a) if stream_inputs_a else default_stream(graph_a, True)
    src_b = list(stream_inputs_b) if stream_inputs_b else default_stream(graph_b, False)

    regions: list[MatchedRegion] = []

    def recurse(src_ta: list[int], snk_ta: list[int],
                src_tb: list[int], snk_tb: list[int],
                in_pair, out_pair, depth: int):
        flow_a, na = _build_flow(graph_a, src_ta, snk_ta)
        flow_b, nb = _build_flow(graph_b, src_tb, snk_tb)
        path_a = _dominator_path(flow_a)
        path_b = _dominator_path(flow_b)
        # interior tensor vertices on the dominator paths (exclude frontiers);
        # tensor vertices are the odd-encoded ints (2*t + 1)
        ends_a = set(src_ta) | set(snk_ta)
        ends_b = set(src_tb) | set(snk_tb)
        dom_a = [v >> 1 for v in path_a if v > 0 and v & 1
                 and (v >> 1) not in ends_a]
        dom_b = [v >> 1 for v in path_b if v > 0 and v & 1
                 and (v >> 1) not in ends_b]
        dom_b_order = {t: i for i, t in enumerate(dom_b)}
        # ordered, order-consistent cut pairs (strictly increasing in B)
        cuts: list[tuple[int, int]] = []
        last_b = -1
        for ta in dom_a:
            tb = eq_a2b.get(ta)
            if tb is None or tb not in dom_b_order:
                continue
            if dom_b_order[tb] > last_b:
                cuts.append((ta, tb))
                last_b = dom_b_order[tb]
        if not cuts:  # |E| = 1 base case: the whole region matches
            if na or nb:
                regions.append(MatchedRegion(nodes_a=na, nodes_b=nb,
                                             in_pair=in_pair, out_pair=out_pair,
                                             depth=depth))
            return
        # divide and conquer on the cut points
        bounds_a = [src_ta] + [[ta] for ta, _ in cuts] + [snk_ta]
        bounds_b = [src_tb] + [[tb] for _, tb in cuts] + [snk_tb]
        pair_bounds = [in_pair] + cuts + [out_pair]
        for k in range(len(bounds_a) - 1):
            recurse(bounds_a[k], bounds_a[k + 1],
                    bounds_b[k], bounds_b[k + 1],
                    pair_bounds[k], pair_bounds[k + 1], depth + 1)

    recurse(src_a, list(graph_a.outputs), src_b, list(graph_b.outputs),
            None, None, 0)

    # Adaptive source selection: a heavily-shared side input (e.g. a weight
    # matrix reused by every layer) in the source set gives every operator a
    # bypass path from _SRC, destroying the dominator chain (no cut points,
    # one giant region).  If the first pass is degenerate and there are
    # several matched inputs, retry with each input pair as the sole stream
    # source and keep the most fine-grained (paper Fig. 7 treats weights as
    # side inputs for exactly this reason).
    n_nodes = max(len(graph_a.nodes), len(graph_b.nodes))
    degenerate = len(regions) <= max(2, n_nodes // 50)
    if (degenerate and stream_inputs_a is None and len(src_a) > 1
            and n_nodes >= 10):
        best = regions
        src_b_set = set(src_b)
        for ta in src_a:
            tb = eq_a2b.get(ta)
            if tb is None or tb not in src_b_set:
                continue
            regions = []
            recurse([ta], list(graph_a.outputs), [tb],
                    list(graph_b.outputs), None, None, 0)
            if len(regions) > len(best):
                best = regions
        regions = best

    # attach weight-only side ops to their consuming region
    claimed_a = {n for r in regions for n in r.nodes_a}
    claimed_b = {n for r in regions for n in r.nodes_b}
    for r in regions:
        r.nodes_a = _attach_side_ops(graph_a, r.nodes_a, claimed_a - set(r.nodes_a))
        r.nodes_b = _attach_side_ops(graph_b, r.nodes_b, claimed_b - set(r.nodes_b))
        claimed_a |= set(r.nodes_a)
        claimed_b |= set(r.nodes_b)
    return regions
