"""DifferentialEnergyDebugger — legacy one-shot facade over the Session API.

Historically this module WAS the end-to-end Magneton pipeline; PR 2 moved
the pipeline into ``core/session.py`` (capture-once artifacts, pluggable
energy backends, N-way ranking) and left this class as a thin compatibility
wrapper: ``compare(fn_a, fn_b, args)`` captures both sides into an
in-memory (store-less) session and compares the two artifacts, reproducing
the historical behavior and report bytes exactly.

Pipeline (now in session.py):
  1. trace both to operator graphs (graph.py),
  2. STREAM-capture per-tensor signatures on n input samples (interp.py):
     the sample-0 execution's outputs double as the functional equivalence
     gate, so neither side is ever executed just for the gate,
  3. match semantically equivalent tensors (tensor_match.py, Hypothesis 1)
     with the lazy two-phase matcher; on live graphs a block stamper
     (block_match.py) first proves repeated-block pairs bitwise-identical
     from canonical structural digests, so a deep stack costs one
     representative block of spectral checks — stamped verdicts are
     exhaustive-equivalent, and a mutated layer demotes only its own pairs,
  4. match semantically equivalent subgraphs (subgraph_match.py, Algorithm 1)
     with repeated-region template memoization and piecewise dominator-path
     decomposition on large graphs (identical regions, ~linear scaling),
  5. price every region with the selected energy backend (energy.py),
  6. detect: regions whose energy differs by more than ``energy_threshold``
     while performance stays within ``perf_tolerance`` are software energy
     waste (paper §6.1: 10% energy threshold, 1% perf tolerance),
  7. diagnose each waste region (diagnose.py, Algorithm 2).  Every
     diagnosis records which backend's numbers it rests on
     (``Diagnosis.priced_by`` — the session backend's label), so a report
     priced by the per-op HLO backend is distinguishable from an analytic
     one after the fact.

Energy backends: prefer constructing a :class:`~repro.core.session.Session`
with an explicit ``EnergyBackend`` (``AnalyticalBackend(spec)``,
``ReplayBackend()``, ``HloCostBackend(spec)``); the ``use_replay`` flag here
survives only for legacy callers and maps onto ``ReplayBackend()``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Sequence

from repro.core.energy import (AnalyticalBackend, EnergyBackend,
                               ReplayBackend)
from repro.core.report import Report
# Re-exported for back-compat: these helpers lived here before the Session
# refactor and are imported by tests/benchmarks.
from repro.core.session import (Session, _check_same_task,  # noqa: F401
                                _perturb)
from repro.hw.specs import TPU_V5E, HardwareSpec


@dataclasses.dataclass
class DifferentialEnergyDebugger:
    energy_threshold: float = 0.10       # paper default: 10% (robust down to 5%)
    perf_tolerance: float = 0.01         # 1% — beyond that it's a trade-off
    match_rtol: float = 1e-3
    num_input_samples: int = 2           # Hypothesis 1: "across all model inputs"
    spec: HardwareSpec = TPU_V5E
    use_replay: bool = False             # legacy alias for ReplayBackend()
    backend: EnergyBackend | None = None  # explicit backend wins over use_replay
    sample_seeds: tuple[int, ...] | None = None   # perturbation seeds, recorded

    def _session(self) -> Session:
        backend = self.backend
        if backend is None:
            backend = (ReplayBackend() if self.use_replay
                       else AnalyticalBackend(self.spec))
        return Session(backend=backend, store=None,
                       energy_threshold=self.energy_threshold,
                       perf_tolerance=self.perf_tolerance,
                       match_rtol=self.match_rtol,
                       num_input_samples=self.num_input_samples)

    def compare(self, fn_a: Callable, fn_b: Callable, args: Sequence[Any],
                *, name_a: str = "A", name_b: str = "B",
                config_a: Mapping[str, Any] | None = None,
                config_b: Mapping[str, Any] | None = None,
                output_rtol: float = 1e-2) -> Report:
        """One-shot comparison: capture both sides, compare the artifacts.

        Side A is captured in full first (the capture-once model); the
        functional-equivalence gate then runs as soon as side B's sample-0
        outputs exist, so a different-task mismatch raises before B's
        remaining samples are captured or B is energy-priced.
        """
        session = self._session()
        art_a = session.capture(fn_a, args, name=name_a, config=config_a,
                                sample_seeds=self.sample_seeds)
        art_b = session.capture(fn_b, args, name=name_b, config=config_b,
                                sample_seeds=self.sample_seeds,
                                gate_against=art_a, output_rtol=output_rtol)
        return session.compare(art_a, art_b, output_rtol=output_rtol)
