"""DifferentialEnergyDebugger — the end-to-end Magneton pipeline.

Given two callables implementing the same task and identical example inputs:
  1. trace both to operator graphs (graph.py),
  2. STREAM-capture per-tensor signatures on n input samples (interp.py):
     one instrumented execution per side per sample reduces every
     intermediate tensor to its cheap symmetric invariants and discards the
     values — the sample-0 execution's outputs double as the functional
     equivalence gate, so neither side is ever executed just for the gate,
  3. match semantically equivalent tensors (tensor_match.py, Hypothesis 1)
     with the lazy two-phase matcher: values are re-captured selectively
     only for pairs that survive the cheap gate,
  4. match semantically equivalent subgraphs (subgraph_match.py, Algorithm 1),
  5. price every region with the energy model (energy.py),
  6. detect: regions whose energy differs by more than ``energy_threshold``
     while performance stays within ``perf_tolerance`` are software energy
     waste (paper §6.1: 10% energy threshold, 1% perf tolerance); regions
     where the cheaper side is also slower are performance-energy trade-offs,
  7. diagnose each waste region (diagnose.py, Algorithm 2).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Sequence

import jax
import numpy as np

from repro.core.diagnose import diagnose_region
from repro.core.energy import (AnalyticalEnergyModel, EnergyProfile,
                               ReplayProfiler, subgraph_energy, subgraph_time)
from repro.core.graph import OpGraph, trace
from repro.core.interp import capture_tensor_stats, capture_tensor_values
from repro.core.report import Finding, Report
from repro.core.subgraph_match import MatchedRegion, match_subgraphs
from repro.core.tensor_match import TensorMatcher
from repro.hw.specs import TPU_V5E, HardwareSpec


def _perturb(args, seed: int):
    """Fresh input sample with the same pytree structure/shapes/dtypes."""
    rng = np.random.default_rng(seed)

    def one(x):
        x = np.asarray(x)
        if x.dtype.kind in "f":
            return (rng.standard_normal(x.shape) * (np.std(x) + 0.1)
                    + np.mean(x)).astype(x.dtype)
        if x.dtype.kind in "iu":
            lo, hi = int(x.min()), int(x.max()) + 1
            return rng.integers(lo, max(hi, lo + 1), size=x.shape).astype(x.dtype)
        return x
    return jax.tree_util.tree_map(one, args)


def _max_abs(x: np.ndarray) -> float:
    """max|x| as a float; 0.0 for zero-size leaves (np.max would raise)."""
    return float(np.max(np.abs(x))) if x.size else 0.0


def _check_same_task(out_a, out_b, output_rtol: float) -> None:
    """Functional-equivalence gate (paper: <=1% element-wise rel. difference).

    Handles scalar and zero-size output leaves; the max-norm relative
    difference measures elementwise |a-b| against the magnitude of the
    outputs, so near-zero elements don't produce spurious "different task"
    verdicts.
    """
    leaves_a = jax.tree_util.tree_leaves(out_a)
    leaves_b = jax.tree_util.tree_leaves(out_b)
    if len(leaves_a) != len(leaves_b):
        raise ValueError(
            f"implementations disagree in output structure "
            f"({len(leaves_a)} vs {len(leaves_b)} leaves); not the same task")
    for xa, xb in zip(leaves_a, leaves_b):
        xa64 = np.asarray(xa, dtype=np.float64)
        xb64 = np.asarray(xb, dtype=np.float64)
        if xa64.shape != xb64.shape:
            raise ValueError(
                f"implementations disagree in output shapes "
                f"({xa64.shape} vs {xb64.shape}); not the same task")
        if xa64.size == 0:
            continue
        scale = max(_max_abs(xa64), _max_abs(xb64), 1e-6)
        rel = _max_abs(xa64 - xb64) / scale
        if rel > output_rtol:
            raise ValueError(
                f"implementations disagree (max rel diff {rel:.3e} > "
                f"{output_rtol}); not the same task")


@dataclasses.dataclass
class DifferentialEnergyDebugger:
    energy_threshold: float = 0.10       # paper default: 10% (robust down to 5%)
    perf_tolerance: float = 0.01         # 1% — beyond that it's a trade-off
    match_rtol: float = 1e-3
    num_input_samples: int = 2           # Hypothesis 1: "across all model inputs"
    spec: HardwareSpec = TPU_V5E
    use_replay: bool = False             # measure real host wall time instead

    def compare(self, fn_a: Callable, fn_b: Callable, args: Sequence[Any],
                *, name_a: str = "A", name_b: str = "B",
                config_a: Mapping[str, Any] | None = None,
                config_b: Mapping[str, Any] | None = None,
                output_rtol: float = 1e-2) -> Report:
        args = tuple(args)
        graph_a = trace(fn_a, *args, name=name_a)
        graph_b = trace(fn_b, *args, name=name_b)

        # -- multi-sample STREAMING signature capture.  The sample-0
        #    executions also produce each side's outputs, which feed the
        #    functional equivalence gate below — no separate full execution
        #    of either side just to compare outputs.
        samples = [args] + [_perturb(args, seed=17 + k)
                            for k in range(self.num_input_samples - 1)]
        outs_a, st_a0 = capture_tensor_stats(graph_a, *samples[0])
        outs_b, st_b0 = capture_tensor_stats(graph_b, *samples[0])

        # -- functional equivalence gate (the two sides must do the same task;
        #    paper enforces <=1% element-wise relative output difference).
        #    Gate BEFORE capturing further samples so a mismatch fails fast.
        _check_same_task(outs_a, outs_b, output_rtol)

        stats_a, stats_b = [st_a0], [st_b0]
        for s in samples[1:]:
            stats_a.append(capture_tensor_stats(graph_a, *s)[1])
            stats_b.append(capture_tensor_stats(graph_b, *s)[1])

        # -- lazy two-phase tensor matching: values are re-captured
        #    selectively, only for tensors whose pairs survive the cheap gate
        matcher = TensorMatcher(rtol=self.match_rtol)

        def fetch(graph):
            return lambda k, tids: capture_tensor_values(
                graph, *samples[k], only_tids=tids)

        eq_pairs = matcher.match_streamed(stats_a, stats_b,
                                          fetch(graph_a), fetch(graph_b))
        regions = match_subgraphs(graph_a, graph_b, eq_pairs)

        # -- energy profiles
        if self.use_replay:
            profiler = ReplayProfiler()
            prof_a = profiler.profile(graph_a, *args)
            prof_b = profiler.profile(graph_b, *args)
        else:
            model = AnalyticalEnergyModel(self.spec)
            prof_a = model.profile(graph_a)
            prof_b = model.profile(graph_b)

        findings = [self._classify(i, r, graph_a, graph_b, prof_a, prof_b,
                                   config_a, config_b)
                    for i, r in enumerate(regions)]
        return Report(name_a=name_a, name_b=name_b, findings=findings,
                      total_energy_a_j=prof_a.total_energy_j,
                      total_energy_b_j=prof_b.total_energy_j,
                      meta={"regions": len(regions),
                            "eq_tensor_pairs": len(eq_pairs),
                            "nodes_a": len(graph_a.nodes),
                            "nodes_b": len(graph_b.nodes),
                            "energy_model": "replay" if self.use_replay
                            else self.spec.name})

    # ------------------------------------------------------------------
    def _classify(self, idx: int, region: MatchedRegion,
                  graph_a: OpGraph, graph_b: OpGraph,
                  prof_a: EnergyProfile, prof_b: EnergyProfile,
                  config_a, config_b) -> Finding:
        e_a = subgraph_energy(prof_a, region.nodes_a)
        e_b = subgraph_energy(prof_b, region.nodes_b)
        t_a = subgraph_time(prof_a, region.nodes_a)
        t_b = subgraph_time(prof_b, region.nodes_b)
        lo, hi = min(e_a, e_b), max(e_a, e_b)
        delta = (hi - lo) / lo if lo > 0 else (0.0 if hi <= 0 else float("inf"))
        wasteful = "A" if e_a > e_b else ("B" if e_b > e_a else "-")
        if delta <= self.energy_threshold:
            cls = "comparable"
        else:
            # efficient side must not be slower by more than perf_tolerance
            t_waste, t_eff = (t_a, t_b) if wasteful == "A" else (t_b, t_a)
            if t_eff <= t_waste * (1.0 + self.perf_tolerance):
                cls = "energy_waste"
            else:
                cls = "tradeoff"
        diag = None
        if cls == "energy_waste":
            diag = diagnose_region(graph_a, region.nodes_a,
                                   graph_b, region.nodes_b,
                                   config_a=config_a, config_b=config_b)
        return Finding(region_idx=idx, energy_a_j=e_a, energy_b_j=e_b,
                       time_a_s=t_a, time_b_s=t_b,
                       nodes_a=list(region.nodes_a), nodes_b=list(region.nodes_b),
                       classification=cls, wasteful_side=wasteful, diagnosis=diag)
