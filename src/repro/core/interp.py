"""Instrumenting jaxpr interpreter.

The JAX analogue of the paper's CUPTI Callback tracing (§5.1): executes a
traced program operator by operator, firing a callback with each operator's
inputs/outputs.  Used for
  * capturing intermediate tensor VALUES (tensor_match.py needs them),
  * replay-based per-operator wall-time measurement (energy.py ReplayProfiler,
    the paper's §5.2 software profiling mode),
  * runtime overhead benchmarking (Fig. 10 analogue).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence

import jax
import numpy as np
from jax._src.core import ClosedJaxpr, Literal

from repro.core.graph import OpGraph


@dataclasses.dataclass
class OpRecord:
    node_idx: int
    primitive: str
    out_values: list[Any] | None      # only kept if capture_values
    wall_time_s: float | None          # only set if measure (replay) enabled
    replay_iters: int = 0


def _bind(eqn, invals):
    subfuns, bind_params = eqn.primitive.get_bind_params(eqn.params)
    out = eqn.primitive.bind(*subfuns, *invals, **bind_params)
    if not eqn.primitive.multiple_results:
        out = [out]
    return out


# Collectives appearing inside an inlined shard_map body.  The interpreter
# executes with *global* values; on this single-host container every mesh
# axis has size 1, so each collective is semantically the identity (and
# axis_index is 0).  Multi-shard interpretation is impossible off-cluster and
# raises.
_COLLECTIVES = {"psum", "pmax", "pmin", "pmean", "all_gather", "all_to_all",
                "ppermute", "pbroadcast", "psum_scatter", "reduce_scatter",
                "psum_invariant", "all_gather_invariant", "pvary"}


def _collective_passthrough(eqn, invals, axis_sizes: dict[str, int]):
    name = eqn.primitive.name
    axes = eqn.params.get("axes", eqn.params.get("axis_name", ()))
    if not isinstance(axes, (tuple, list)):
        axes = (axes,)
    sizes = [axis_sizes.get(a, 1) for a in axes]
    if any(s != 1 for s in sizes):
        raise NotImplementedError(
            f"cannot interpret {name} over axes {axes} with sizes {sizes} "
            "on a single-host container")
    if name == "axis_index":
        return [np.int32(0)]
    return list(invals)


def run_instrumented(
    graph: OpGraph,
    *args,
    capture_values: bool = False,
    measure: bool = False,
    min_replay_time_s: float = 5e-3,
    max_replay_iters: int = 64,
    on_op: Callable[[OpRecord], None] | None = None,
) -> tuple[list[Any], list[OpRecord]]:
    """Execute the graph's jaxpr operator-by-operator with instrumentation.

    When ``measure`` is set, each operator is re-executed until at least
    ``min_replay_time_s`` of wall time accumulates — the replay trick from the
    paper's §5.2 that averages out timer/counter noise for microsecond ops.
    Note the instrumented path executes the *unfused* operator stream, which
    is exactly the operator-level execution model priced by costs.py.
    """
    closed = graph.closed_jaxpr
    if closed is None:
        raise ValueError("OpGraph was built without a ClosedJaxpr; cannot execute")
    # Re-extract with the same flattening used to build `graph` so node idxs line up.
    from repro.core.graph import extract_graph
    flat = extract_graph(closed, name=graph.name, inline_calls=True)
    if len(flat.nodes) != len(graph.nodes):
        raise ValueError("graph/node mismatch; rebuild graph with extract_graph")

    jaxpr = closed.jaxpr
    env: dict[Any, Any] = {}

    def read(v):
        return v.val if isinstance(v, Literal) else env[v]

    def write(v, val):
        env[v] = val

    for v, val in zip(jaxpr.constvars, closed.consts):
        write(v, val)
    flat_args = jax.tree_util.tree_leaves(args)
    if len(flat_args) != len(jaxpr.invars):
        raise ValueError(f"expected {len(jaxpr.invars)} args, got {len(flat_args)}")
    for v, val in zip(jaxpr.invars, flat_args):
        write(v, val)

    records: list[OpRecord] = []
    node_idx = 0

    def exec_eqns(eqns, inner_env, read_fn, write_fn,
                  axis_sizes: dict[str, int] | None = None):
        nonlocal node_idx
        from repro.core.graph import _INLINE_PRIMITIVES, _nested_jaxpr
        axis_sizes = axis_sizes or {}
        for eqn in eqns:
            inner = _nested_jaxpr(eqn)
            if inner is not None and eqn.primitive.name in _INLINE_PRIMITIVES:
                sub_env: dict[Any, Any] = {}

                def sread(v, _se=sub_env):
                    return v.val if isinstance(v, Literal) else _se[v]

                def swrite(v, val, _se=sub_env):
                    _se[v] = val

                sub_axes = dict(axis_sizes)
                if eqn.primitive.name == "shard_map":
                    mesh = eqn.params.get("mesh")
                    if mesh is not None:
                        sub_axes.update({str(k): int(v)
                                         for k, v in mesh.shape.items()})
                for cv, cval in zip(inner.jaxpr.constvars, inner.consts):
                    swrite(cv, cval)
                for iv, ov in zip(inner.jaxpr.invars, eqn.invars):
                    swrite(iv, read_fn(ov))
                exec_eqns(inner.jaxpr.eqns, sub_env, sread, swrite, sub_axes)
                for ov, iv in zip(eqn.outvars, inner.jaxpr.outvars):
                    write_fn(ov, sread(iv))
                continue

            invals = [read_fn(v) for v in eqn.invars]
            wall = None
            iters = 0
            if eqn.primitive.name in _COLLECTIVES or \
                    eqn.primitive.name == "axis_index":
                out = _collective_passthrough(eqn, invals, axis_sizes)
            elif measure:
                # warmup once (compile path), then replay until stable
                out = _bind(eqn, invals)
                jax.block_until_ready(out)
                t0 = time.perf_counter()
                elapsed = 0.0
                while elapsed < min_replay_time_s and iters < max_replay_iters:
                    out = _bind(eqn, invals)
                    jax.block_until_ready(out)
                    iters += 1
                    elapsed = time.perf_counter() - t0
                wall = elapsed / max(iters, 1)
            else:
                out = _bind(eqn, invals)
            for v, val in zip(eqn.outvars, out):
                write_fn(v, val)
            rec = OpRecord(
                node_idx=node_idx,
                primitive=eqn.primitive.name,
                out_values=[np.asarray(o) for o in out] if capture_values else None,
                wall_time_s=wall,
                replay_iters=iters,
            )
            records.append(rec)
            if on_op is not None:
                on_op(rec)
            node_idx += 1

    exec_eqns(jaxpr.eqns, env, read, write, {})
    outs = [read(v) for v in jaxpr.outvars]
    return outs, records


def capture_tensor_values(graph: OpGraph, *args) -> dict[int, np.ndarray]:
    """Map tensor-id -> concrete value for every edge in the graph."""
    values: dict[int, np.ndarray] = {}
    flat_args = jax.tree_util.tree_leaves(args)
    for tid, val in zip(graph.inputs, flat_args):
        values[tid] = np.asarray(val)
    outs, records = run_instrumented(graph, *args, capture_values=True)
    for rec in records:
        node = graph.nodes[rec.node_idx]
        for tid, val in zip(node.outvars, rec.out_values or []):
            values[tid] = val
    return values
