"""Instrumenting jaxpr interpreter.

The JAX analogue of the paper's CUPTI Callback tracing (§5.1): executes a
traced program operator by operator, firing a callback with each operator's
inputs/outputs.  Used for
  * STREAMING tensor-signature capture (capture_tensor_stats): each operator's
    outputs are reduced to their cheap symmetric invariants inside the on_op
    callback and the values are discarded immediately, so multi-sample capture
    holds O(tensors) scalars instead of O(activations x samples) float64
    arrays — the default matching path,
  * selective tensor-VALUE capture (capture_tensor_values with only_tids) for
    the matcher's lazy phase-2 spectral checks — now dead-code-sliced: only
    the backward closure of the requested tensors executes,
  * replay-based per-operator wall-time measurement (energy.py ReplayProfiler,
    the paper's §5.2 software profiling mode),
  * runtime overhead benchmarking (Fig. 10 analogue).

Graphs extracted by ``extract_graph`` carry a flat tid-space program (one
leaf equation per node + const/literal values), which enables the fast
executor here: a single flat loop over int-keyed environments instead of the
nested Var-keyed interpreters, reference-counted per-op value discard (the
true streaming-memory watermark), and — for graphs with repeated-block
families — FUSED BLOCK STATS capture: one ``jax.jit``-compiled function per
block family computes every block tensor's five invariants on device in a
single dispatch per repeat, so streaming capture stops paying one host
round-trip per operator (the PR 1 follow-up: per-op invariant reduction no
longer retraces/re-dispatches per op).
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Sequence

import jax
import numpy as np
from jax._src.core import ClosedJaxpr, Literal

from repro.core.graph import OpGraph


@dataclasses.dataclass
class OpRecord:
    node_idx: int
    primitive: str
    # kept if capture_values; with stream_values they are present only for
    # the duration of the on_op callback and dropped right after.  None for
    # ops covered by a fused block-stats dispatch (their invariants are
    # delivered through the ``fused_stats`` dict instead).
    out_values: list[Any] | None
    wall_time_s: float | None          # only set if measure (replay) enabled
    replay_iters: int = 0


def _bind(eqn, invals):
    subfuns, bind_params = eqn.primitive.get_bind_params(eqn.params)
    out = eqn.primitive.bind(*subfuns, *invals, **bind_params)
    if not eqn.primitive.multiple_results:
        out = [out]
    return out


# Collectives appearing inside an inlined shard_map body.  The interpreter
# executes with *global* values; on this single-host container every mesh
# axis has size 1, so each collective is semantically the identity (and
# axis_index is 0).  Multi-shard interpretation is impossible off-cluster and
# raises.
_COLLECTIVES = {"psum", "psum2", "pmax", "pmin", "pmean", "all_gather",
                "all_to_all", "ppermute", "pbroadcast", "psum_scatter",
                "reduce_scatter", "psum_invariant", "all_gather_invariant",
                "pvary"}


def _collective_passthrough(eqn, invals, axis_sizes: dict[str, int]):
    name = eqn.primitive.name
    axes = eqn.params.get("axes", eqn.params.get("axis_name", ()))
    if not isinstance(axes, (tuple, list)):
        axes = (axes,)
    sizes = [axis_sizes.get(a, 1) for a in axes]
    if any(s != 1 for s in sizes):
        raise NotImplementedError(
            f"cannot interpret {name} over axes {axes} with sizes {sizes} "
            "on a single-host container")
    if name == "axis_index":
        return [np.int32(0)]
    return list(invals)


# ---------------------------------------------------------------------------
# execution plan (memoized per graph)
# ---------------------------------------------------------------------------

# Fused block-stats capture engages only above this node count: the per-block
# jitted reduction accumulates in float32 for EVERY float tensor (the plain
# path uses float64 numpy below tensor_match._JIT_STATS_MIN_NUMEL), so small
# graphs — including every committed zoo baseline — keep the historical
# bit-exact path.
_FUSED_STATS_MIN_NODES = 128


class _BlockExec:
    """One jit-compiled block family: executes the representative block's
    equations under trace and returns (external outputs, (F, 5) float32
    invariant rows for float tensors, raw values for the rest)."""

    def __init__(self, graph: OpGraph, plan: "_ExecPlan", fam):
        import jax.numpy as jnp
        from repro.core.tensor_match import _JIT_DTYPES

        self.fam = fam
        period, count = fam.period, fam.count
        nodes = graph.nodes
        tensors = graph.tensors

        # external inputs per repeat, in first-occurrence (offset, slot) order
        self.ext_in: list[list[int]] = []
        for r in range(count):
            lo, hi = fam.window(r)
            seen: set[int] = set()
            order: list[int] = []
            for o in range(period):
                for t in nodes[lo + o].invars:
                    e = tensors[t]
                    internal = e.producer is not None and lo <= e.producer < hi
                    if internal or t in seen:
                        continue
                    seen.add(t)
                    order.append(t)
            self.ext_in.append(order)
        self.ok = all(len(x) == len(self.ext_in[0]) for x in self.ext_in)
        if not self.ok:
            return

        # outputs needed OUTSIDE the block in ANY repeat (union keeps the
        # jitted return structure identical across repeats: one compile)
        ext_out: set[tuple[int, int]] = set()
        for r in range(count):
            lo, hi = fam.window(r)
            for o in range(period):
                for slot, t in enumerate(nodes[lo + o].outvars):
                    e = tensors[t]
                    if e.is_output or any(c < lo or c >= hi
                                          for c in e.consumers):
                        ext_out.add((o, slot))
        self.ext_out = sorted(ext_out)

        rep_lo = fam.start
        rep_nodes = [nodes[rep_lo + o] for o in range(period)]
        rep_eqns = [plan.eqns[rep_lo + o] for o in range(period)]
        self.float_offsets: list[tuple[int, int]] = []
        self.raw_offsets: list[tuple[int, int]] = []
        # (offset, slot, numel, dtype, shape) per float output: block repeats
        # share avals (families are keyed on structural digests), so the
        # representative's metadata holds for every repeat — precomputing it
        # keeps np.prod/dtype lookups out of the per-repeat dispatch loop
        self.float_meta: list[tuple[int, int, int, str, tuple]] = []
        for o in range(period):
            for slot, t in enumerate(rep_nodes[o].outvars):
                e = tensors[t]
                numel = int(np.prod(e.shape, dtype=np.int64)) if e.shape else 1
                if numel > 0 and e.dtype in _JIT_DTYPES:
                    self.float_offsets.append((o, slot))
                    self.float_meta.append((o, slot, numel, e.dtype, e.shape))
                else:
                    self.raw_offsets.append((o, slot))

        rep_ext_in = tuple(self.ext_in[0])
        ext_out_tids = [rep_nodes[o].outvars[slot] for o, slot in self.ext_out]
        float_tids = [rep_nodes[o].outvars[slot]
                      for o, slot in self.float_offsets]
        raw_tids = [rep_nodes[o].outvars[slot] for o, slot in self.raw_offsets]

        def block(*ext_vals):
            benv = dict(zip(rep_ext_in, ext_vals))
            for eqn, node in zip(rep_eqns, rep_nodes):
                out = _bind(eqn, [benv[t] for t in node.invars])
                for t, v in zip(node.outvars, out):
                    benv[t] = v
            rows = []
            for t in float_tids:
                x = benv[t].astype(jnp.float32).ravel()
                rows.append(jnp.stack([jnp.sum(jnp.abs(x)), jnp.sum(x * x),
                                       jnp.mean(x), jnp.max(x), jnp.min(x)]))
            stats = (jnp.stack(rows) if rows
                     else jnp.zeros((0, 5), jnp.float32))
            return ([benv[t] for t in ext_out_tids], stats,
                    [benv[t] for t in raw_tids])

        self.fn = jax.jit(block)


class _ExecPlan:
    """Per-graph execution plan: flat equations, const values, per-node mesh
    axes, per-node free lists (refcounted discard), lazy fused blocks."""

    def __init__(self, graph: OpGraph):
        self.has_program = (
            graph._eqns is not None
            and len(graph._eqns) == len(graph.nodes))
        if not self.has_program:
            return
        self.eqns = graph._eqns
        self.consts = graph._const_vals or {}
        axes = graph._node_axis_sizes
        self.axes = (axes if axes is not None and len(axes) == len(graph.nodes)
                     else [{}] * len(graph.nodes))
        keep = set(graph.outputs)
        last_use: dict[int, int] = {}
        for node in graph.nodes:
            for t in node.invars:
                last_use[t] = node.idx
        free_after: list[list[int]] = [[] for _ in graph.nodes]
        for node in graph.nodes:       # dead outputs: free immediately
            for t in node.outvars:
                if not graph.tensors[t].consumers and t not in keep:
                    free_after[node.idx].append(t)
        for t, idx in last_use.items():
            e = graph.tensors[t]
            if e.is_const or e.is_input or t in keep:
                continue
            free_after[idx].append(t)
        self.free_after = free_after
        self.nbytes = {t: e.nbytes for t, e in graph.tensors.items()}
        self._blocks: dict[int, _BlockExec] | None = None

    def fused_blocks(self, graph: OpGraph) -> dict[int, _BlockExec]:
        """Block families eligible for fused stats capture, keyed by their
        start node (built + compiled lazily, memoized on the plan)."""
        if self._blocks is not None:
            return self._blocks
        from repro.core.graph import block_structure
        blocks: dict[int, _BlockExec] = {}
        for fam in block_structure(graph).families:
            lo, hi = fam.start, fam.start + fam.period
            if any(graph.nodes[i].primitive in _COLLECTIVES
                   or graph.nodes[i].primitive == "axis_index"
                   or self.axes[i] for i in range(lo, hi)):
                continue
            be = _BlockExec(graph, self, fam)
            if be.ok:
                blocks[fam.start] = be
        self._blocks = blocks
        return blocks


def _exec_plan(graph: OpGraph) -> _ExecPlan:
    plan = getattr(graph, "_interp_plan", None)
    if plan is None:
        plan = _ExecPlan(graph)
        graph._interp_plan = plan
    return plan


def run_instrumented(
    graph: OpGraph,
    *args,
    capture_values: bool = False,
    stream_values: bool = False,
    measure: bool = False,
    min_replay_time_s: float = 5e-3,
    max_replay_iters: int = 64,
    on_op: Callable[[OpRecord], None] | None = None,
    only_nodes: "set[int] | None" = None,
    fused_stats: "dict[int, Any] | None" = None,
    mem: "dict[str, int] | None" = None,
    block_cache=None,
) -> tuple[list[Any], list[OpRecord]]:
    """Execute the graph's jaxpr operator-by-operator with instrumentation.

    When ``measure`` is set, each operator is re-executed until at least
    ``min_replay_time_s`` of wall time accumulates — the replay trick from the
    paper's §5.2 that averages out timer/counter noise for microsecond ops.
    Note the instrumented path executes the *unfused* operator stream, which
    is exactly the operator-level execution model priced by costs.py.

    ``capture_values`` retains every operator's outputs on its OpRecord
    (O(activations) extra memory, per sample).  ``stream_values`` instead
    exposes the raw outputs to the ``on_op`` callback ONLY for the duration
    of the call and drops them afterwards: the callback can reduce each
    tensor to a signature so nothing beyond the interpreter's own live
    values is ever retained, across however many samples are captured.

    ``only_nodes`` restricts execution to the given node set (the caller is
    responsible for closure under data dependencies — see
    ``capture_tensor_values``); unexecuted ops fire no records.

    ``fused_stats`` switches large repeated-block graphs to fused block
    capture: covered operators execute inside one jit-compiled function per
    block family (one dispatch per repeat) and their five symmetric
    invariants land in the dict as ``{tid: TensorSignature}``; their
    OpRecords carry ``out_values=None``.  Uncovered operators stream
    normally.

    ``mem``, when provided, receives ``peak_live_bytes``: the high-water
    mark of operator outputs resident in the interpreter environment, with
    per-op reference-counted discard (tensors are dropped after their last
    consumer).  Only the fast tid-space executor tracks this.

    ``block_cache`` (a block_cache.BlockEvidenceCache) makes the fused
    block path INCREMENTAL: each repeat's dispatch is keyed by its family
    digest + external-input value digests; hits splice the cached stats
    and rematerialize the cached external outputs without executing the
    block, misses execute normally and record their evidence.  Only active
    when the fused path is (``fused_stats`` set, graph large enough).
    """
    closed = graph.closed_jaxpr
    if closed is None:
        raise ValueError("OpGraph was built without a ClosedJaxpr; cannot execute")
    # Same flattening used to build `graph` so node idxs line up (memoized on
    # the graph: repeated multi-sample/replay runs stop re-extracting).
    flat = graph.flat_graph()
    if len(flat.nodes) != len(graph.nodes):
        raise ValueError("graph/node mismatch; rebuild graph with extract_graph")

    # Fast tid-space executor: only when the graph IS its own flattening
    # (every extract_graph/trace product), so tids in records/env/fused_stats
    # are the caller's tids.
    plan = _exec_plan(graph) if flat is graph else None
    if plan is not None and plan.has_program:
        return _run_flat(graph, plan, args,
                         capture_values=capture_values,
                         stream_values=stream_values, measure=measure,
                         min_replay_time_s=min_replay_time_s,
                         max_replay_iters=max_replay_iters, on_op=on_op,
                         only_nodes=only_nodes, fused_stats=fused_stats,
                         mem=mem, block_cache=block_cache)

    jaxpr = closed.jaxpr
    env: dict[Any, Any] = {}

    def read(v):
        return v.val if isinstance(v, Literal) else env[v]

    def write(v, val):
        env[v] = val

    for v, val in zip(jaxpr.constvars, closed.consts):
        write(v, val)
    flat_args = jax.tree_util.tree_leaves(args)
    if len(flat_args) != len(jaxpr.invars):
        raise ValueError(f"expected {len(jaxpr.invars)} args, got {len(flat_args)}")
    for v, val in zip(jaxpr.invars, flat_args):
        write(v, val)

    records: list[OpRecord] = []
    node_idx = 0

    def exec_eqns(eqns, inner_env, read_fn, write_fn,
                  axis_sizes: dict[str, int] | None = None):
        nonlocal node_idx
        from repro.core.graph import _INLINE_PRIMITIVES, _nested_jaxpr
        axis_sizes = axis_sizes or {}
        for eqn in eqns:
            inner = _nested_jaxpr(eqn)
            if inner is not None and eqn.primitive.name in _INLINE_PRIMITIVES:
                sub_env: dict[Any, Any] = {}

                def sread(v, _se=sub_env):
                    return v.val if isinstance(v, Literal) else _se[v]

                def swrite(v, val, _se=sub_env):
                    _se[v] = val

                sub_axes = dict(axis_sizes)
                if eqn.primitive.name == "shard_map":
                    mesh = eqn.params.get("mesh")
                    if mesh is not None:
                        sub_axes.update({str(k): int(v)
                                         for k, v in mesh.shape.items()})
                for cv, cval in zip(inner.jaxpr.constvars, inner.consts):
                    swrite(cv, cval)
                for iv, ov in zip(inner.jaxpr.invars, eqn.invars):
                    swrite(iv, read_fn(ov))
                exec_eqns(inner.jaxpr.eqns, sub_env, sread, swrite, sub_axes)
                for ov, iv in zip(eqn.outvars, inner.jaxpr.outvars):
                    write_fn(ov, sread(iv))
                continue

            invals = [read_fn(v) for v in eqn.invars]
            wall = None
            iters = 0
            if eqn.primitive.name in _COLLECTIVES or \
                    eqn.primitive.name == "axis_index":
                out = _collective_passthrough(eqn, invals, axis_sizes)
            elif measure:
                # warmup once (compile path), then replay until stable
                out = _bind(eqn, invals)
                jax.block_until_ready(out)
                t0 = time.perf_counter()
                elapsed = 0.0
                while elapsed < min_replay_time_s and iters < max_replay_iters:
                    out = _bind(eqn, invals)
                    jax.block_until_ready(out)
                    iters += 1
                    elapsed = time.perf_counter() - t0
                wall = elapsed / max(iters, 1)
            else:
                out = _bind(eqn, invals)
            for v, val in zip(eqn.outvars, out):
                write_fn(v, val)
            if capture_values:
                out_values = [np.asarray(o) for o in out]
            elif stream_values:
                out_values = list(out)   # raw, handed to on_op then dropped
            else:
                out_values = None
            rec = OpRecord(
                node_idx=node_idx,
                primitive=eqn.primitive.name,
                out_values=out_values,
                wall_time_s=wall,
                replay_iters=iters,
            )
            records.append(rec)
            if on_op is not None:
                on_op(rec)
            if stream_values and not capture_values:
                rec.out_values = None
            node_idx += 1

    exec_eqns(jaxpr.eqns, env, read, write, {})
    outs = [read(v) for v in jaxpr.outvars]
    return outs, records


def _run_flat(graph: OpGraph, plan: _ExecPlan, args, *,
              capture_values: bool, stream_values: bool, measure: bool,
              min_replay_time_s: float, max_replay_iters: int,
              on_op, only_nodes, fused_stats, mem, block_cache=None
              ) -> tuple[list[Any], list[OpRecord]]:
    """Flat tid-space executor (see run_instrumented for semantics)."""
    nodes = graph.nodes
    tensors = graph.tensors
    consts = plan.consts
    env: dict[int, Any] = {}
    flat_args = jax.tree_util.tree_leaves(args)
    if len(flat_args) != len(graph.inputs):
        raise ValueError(
            f"expected {len(graph.inputs)} args, got {len(flat_args)}")
    for t, val in zip(graph.inputs, flat_args):
        env[t] = val

    live = 0
    peak = 0
    nbytes = plan.nbytes
    track_mem = mem is not None and not capture_values

    def write_out(t, val):
        nonlocal live, peak
        env[t] = val
        if track_mem:
            live += nbytes[t]
            if live > peak:
                peak = live

    def free_after(idx):
        nonlocal live
        for t in plan.free_after[idx]:
            if env.pop(t, None) is not None and track_mem:
                live -= nbytes[t]

    use_fused = (fused_stats is not None and not measure
                 and not capture_values and only_nodes is None
                 and len(nodes) >= _FUSED_STATS_MIN_NODES)
    blocks = plan.fused_blocks(graph) if use_fused else {}
    cache = block_cache if use_fused else None
    # run-local tid -> value digest memo: seeded by cache hits/misses so
    # chained blocks never re-hash intermediate values
    run_digests: dict[int, str] = {} if cache is not None else None

    records: list[OpRecord] = []
    idx = 0
    n = len(nodes)
    while idx < n:
        be = blocks.get(idx) if use_fused else None
        if be is not None:
            _run_block(graph, be, env, write_out, free_after, records,
                       on_op, fused_stats, cache=cache,
                       run_digests=run_digests)
            idx = be.fam.end
            continue
        node = nodes[idx]
        if only_nodes is not None and idx not in only_nodes:
            idx += 1
            continue
        eqn = plan.eqns[idx]
        invals = [env[t] if t in env else consts[t] for t in node.invars]
        wall = None
        iters = 0
        if node.primitive in _COLLECTIVES or node.primitive == "axis_index":
            out = _collective_passthrough(eqn, invals, plan.axes[idx])
        elif measure:
            out = _bind(eqn, invals)
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            elapsed = 0.0
            while elapsed < min_replay_time_s and iters < max_replay_iters:
                out = _bind(eqn, invals)
                jax.block_until_ready(out)
                iters += 1
                elapsed = time.perf_counter() - t0
            wall = elapsed / max(iters, 1)
        else:
            out = _bind(eqn, invals)
        for t, val in zip(node.outvars, out):
            write_out(t, val)
        if capture_values:
            out_values = [np.asarray(o) for o in out]
        elif stream_values:
            out_values = list(out)
        else:
            out_values = None
        rec = OpRecord(node_idx=idx, primitive=node.primitive,
                       out_values=out_values, wall_time_s=wall,
                       replay_iters=iters)
        records.append(rec)
        if on_op is not None:
            on_op(rec)
        if stream_values and not capture_values:
            rec.out_values = None
        free_after(idx)
        idx += 1

    if only_nodes is None:
        outs = [env[t] if t in env else consts[t] for t in graph.outputs]
    else:   # sliced run: outputs outside the slice were never produced
        outs = [env.get(t, consts.get(t)) for t in graph.outputs]
    if mem is not None:
        mem["peak_live_bytes"] = peak
    return outs, records


def _run_block(graph: OpGraph, be: _BlockExec, env, write_out, free_after,
               records, on_op, fused_stats, cache=None,
               run_digests=None) -> None:
    """Dispatch one fused block family: one compiled call per repeat — or,
    with ``cache``, zero calls for repeats whose evidence key hits."""
    from repro.core.tensor_match import TensorSignature, stats_signature

    nodes = graph.nodes
    tensors = graph.tensors
    consts = getattr(graph, "_interp_plan").consts
    fam = be.fam
    bs = None
    if cache is not None:
        from repro.core.graph import _value_digest, block_structure
        bs = block_structure(graph)

    def in_digest(t: int) -> str:
        d = run_digests.get(t)
        if d is None:
            d = (bs.const_digest(t) if tensors[t].is_const
                 else _value_digest(env[t]))
            run_digests[t] = d
        return d

    def emit_records(lo: int) -> None:
        for o in range(fam.period):
            i = lo + o
            rec = OpRecord(node_idx=i, primitive=nodes[i].primitive,
                           out_values=None, wall_time_s=None)
            records.append(rec)
            if on_op is not None:
                on_op(rec)
            free_after(i)

    for r in range(fam.count):
        lo, _ = fam.window(r)

        entry_key = None
        if cache is not None:
            from repro.core.block_cache import (block_entry_key,
                                                format_value_digest)
            digs = [in_digest(t) for t in be.ext_in[r]]
            entry_key = block_entry_key(fam.digest, fam.period,
                                        be.ext_out, digs)
            hit = cache.get_block(entry_key, fam_digest=fam.digest, lo=lo)
            if hit is not None:
                payload, arrays = hit
                for rec_d, v in zip(payload["ext_out"], arrays):
                    t = nodes[lo + rec_d["o"]].outvars[rec_d["slot"]]
                    write_out(t, v)
                    run_digests[t] = format_value_digest(
                        rec_d["dtype"], rec_d["shape"], rec_d["digest"])
                for row in payload["stats"]:
                    t = nodes[lo + row[0]].outvars[row[1]]
                    fused_stats[t] = TensorSignature(
                        numel=row[2], dtype=row[3],
                        l1=row[4], l2=row[5], mean=row[6],
                        amax=row[7], amin=row[8],
                        spectra=None, shape=tuple(row[9]))
                emit_records(lo)
                continue

        args = [env[t] if t in env else consts[t] for t in be.ext_in[r]]
        ext_vals, stats_arr, raws = be.fn(*args)
        ext_np = ([np.asarray(v) for v in ext_vals]
                  if cache is not None else None)
        for (o, slot), v in zip(be.ext_out, ext_vals):
            write_out(nodes[lo + o].outvars[slot], v)
        # ONE host transfer per repeat, ONE C pass to python floats
        rows = np.asarray(stats_arr).tolist()
        for row, (o, slot, numel, dtype, shape) in zip(rows, be.float_meta):
            t = nodes[lo + o].outvars[slot]
            fused_stats[t] = TensorSignature(
                numel=numel, dtype=dtype,
                l1=row[0], l2=math.sqrt(max(row[1], 0.0)),
                mean=row[2], amax=row[3], amin=row[4],
                spectra=None, shape=shape)
        for v, (o, slot) in zip(raws, be.raw_offsets):
            t = nodes[lo + o].outvars[slot]
            fused_stats[t] = stats_signature(np.asarray(v))

        if cache is not None:
            ext_recs = []
            for (o, slot), a in zip(be.ext_out, ext_np):
                rec_d = cache.value_record(a)
                rec_d["o"], rec_d["slot"] = o, slot
                ext_recs.append(rec_d)
                t = nodes[lo + o].outvars[slot]
                run_digests[t] = format_value_digest(
                    rec_d["dtype"], rec_d["shape"], rec_d["digest"])
            stat_rows = []
            for o, slot, numel, dtype, shape in be.float_meta:
                s = fused_stats[nodes[lo + o].outvars[slot]]
                stat_rows.append([o, slot, numel, dtype,
                                  float(s.l1), float(s.l2), float(s.mean),
                                  float(s.amax), float(s.amin), list(shape)])
            for o, slot in be.raw_offsets:
                s = fused_stats[nodes[lo + o].outvars[slot]]
                stat_rows.append([o, slot, int(s.numel), s.dtype,
                                  float(s.l1), float(s.l2), float(s.mean),
                                  float(s.amax), float(s.amin),
                                  list(s.shape or ())])
            from repro.core.block_cache import BLOCK_SCHEMA_VERSION
            cache.put_block(entry_key, {
                "schema": BLOCK_SCHEMA_VERSION, "kind": "block-evidence",
                "family_digest": fam.digest, "period": fam.period,
                "stats": stat_rows, "ext_out": ext_recs}, ext_np)
        emit_records(lo)


def _needed_nodes(graph: OpGraph, want: set[int]) -> set[int]:
    """Backward closure of the producers of the requested tensors."""
    needed: set[int] = set()
    frontier = [graph.tensors[t].producer for t in want
                if t in graph.tensors and graph.tensors[t].producer is not None]
    while frontier:
        nidx = frontier.pop()
        if nidx is None or nidx in needed:
            continue
        needed.add(nidx)
        for t in graph.nodes[nidx].invars:
            p = graph.tensors[t].producer
            if p is not None and p not in needed:
                frontier.append(p)
    return needed


def capture_tensor_values(
    graph: OpGraph, *args,
    only_tids: "set[int] | Sequence[int] | None" = None,
) -> dict[int, np.ndarray]:
    """Map tensor-id -> concrete value for edges in the graph.

    With ``only_tids`` the run retains ONLY the requested tensors (the
    matcher's phase-2 selective fetch) and — on graphs carrying a flat
    program — executes ONLY the backward closure of their producers
    (dead-code slicing): fetching one early-layer tensor from a 5k-node
    graph costs a few operators, not a full forward pass.
    """
    want = None if only_tids is None else set(only_tids)
    values: dict[int, np.ndarray] = {}
    flat_args = jax.tree_util.tree_leaves(args)
    for tid, val in zip(graph.inputs, flat_args):
        if want is None or tid in want:
            values[tid] = np.asarray(val)

    def on_op(rec: OpRecord) -> None:
        node = graph.nodes[rec.node_idx]
        for tid, val in zip(node.outvars, rec.out_values or []):
            if want is None or tid in want:
                values[tid] = np.asarray(val)

    only_nodes = None if want is None else _needed_nodes(graph, want)
    run_instrumented(graph, *args, stream_values=True, on_op=on_op,
                     only_nodes=only_nodes)
    return values


def capture_tensor_stats(graph: OpGraph, *args,
                         mem: "dict[str, int] | None" = None,
                         block_cache=None):
    """Streaming capture: outputs + tensor-id -> cheap symmetric invariants.

    One instrumented execution computes each intermediate tensor's
    entry-symmetric invariants (l1/l2/mean/amax/amin) in the on_op callback
    — or, for large repeated-block graphs, inside one fused jitted reduction
    per block repeat — and discards the values immediately.  Returns
    ``(graph_outputs, {tid: TensorSignature})`` so callers (diff.py's
    functional-equivalence gate) can reuse the same execution's outputs
    instead of running the program again.  ``mem`` (optional dict) receives
    the executor's ``peak_live_bytes`` watermark.  ``block_cache`` (a
    block_cache.BlockEvidenceCache) makes fused-block capture incremental:
    repeats whose evidence key hits splice cached invariants and outputs
    instead of executing (byte-identical to a cold capture).
    """
    from repro.core.tensor_match import stats_signature

    stats: dict[int, Any] = {}
    flat_args = jax.tree_util.tree_leaves(args)
    for tid, val in zip(graph.inputs, flat_args):
        stats[tid] = stats_signature(val)

    def on_op(rec: OpRecord) -> None:
        node = graph.nodes[rec.node_idx]
        for tid, val in zip(node.outvars, rec.out_values or []):
            stats[tid] = stats_signature(val)

    outs, _ = run_instrumented(graph, *args, stream_values=True, on_op=on_op,
                               fused_stats=stats, mem=mem,
                               block_cache=block_cache)
    return outs, stats
