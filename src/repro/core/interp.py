"""Instrumenting jaxpr interpreter.

The JAX analogue of the paper's CUPTI Callback tracing (§5.1): executes a
traced program operator by operator, firing a callback with each operator's
inputs/outputs.  Used for
  * STREAMING tensor-signature capture (capture_tensor_stats): each operator's
    outputs are reduced to their cheap symmetric invariants inside the on_op
    callback and the values are discarded immediately, so multi-sample capture
    holds O(tensors) scalars instead of O(activations x samples) float64
    arrays — the default matching path,
  * selective tensor-VALUE capture (capture_tensor_values with only_tids) for
    the matcher's lazy phase-2 spectral checks,
  * replay-based per-operator wall-time measurement (energy.py ReplayProfiler,
    the paper's §5.2 software profiling mode),
  * runtime overhead benchmarking (Fig. 10 analogue).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence

import jax
import numpy as np
from jax._src.core import ClosedJaxpr, Literal

from repro.core.graph import OpGraph


@dataclasses.dataclass
class OpRecord:
    node_idx: int
    primitive: str
    # kept if capture_values; with stream_values they are present only for
    # the duration of the on_op callback and dropped right after
    out_values: list[Any] | None
    wall_time_s: float | None          # only set if measure (replay) enabled
    replay_iters: int = 0


def _bind(eqn, invals):
    subfuns, bind_params = eqn.primitive.get_bind_params(eqn.params)
    out = eqn.primitive.bind(*subfuns, *invals, **bind_params)
    if not eqn.primitive.multiple_results:
        out = [out]
    return out


# Collectives appearing inside an inlined shard_map body.  The interpreter
# executes with *global* values; on this single-host container every mesh
# axis has size 1, so each collective is semantically the identity (and
# axis_index is 0).  Multi-shard interpretation is impossible off-cluster and
# raises.
_COLLECTIVES = {"psum", "psum2", "pmax", "pmin", "pmean", "all_gather",
                "all_to_all", "ppermute", "pbroadcast", "psum_scatter",
                "reduce_scatter", "psum_invariant", "all_gather_invariant",
                "pvary"}


def _collective_passthrough(eqn, invals, axis_sizes: dict[str, int]):
    name = eqn.primitive.name
    axes = eqn.params.get("axes", eqn.params.get("axis_name", ()))
    if not isinstance(axes, (tuple, list)):
        axes = (axes,)
    sizes = [axis_sizes.get(a, 1) for a in axes]
    if any(s != 1 for s in sizes):
        raise NotImplementedError(
            f"cannot interpret {name} over axes {axes} with sizes {sizes} "
            "on a single-host container")
    if name == "axis_index":
        return [np.int32(0)]
    return list(invals)


def run_instrumented(
    graph: OpGraph,
    *args,
    capture_values: bool = False,
    stream_values: bool = False,
    measure: bool = False,
    min_replay_time_s: float = 5e-3,
    max_replay_iters: int = 64,
    on_op: Callable[[OpRecord], None] | None = None,
) -> tuple[list[Any], list[OpRecord]]:
    """Execute the graph's jaxpr operator-by-operator with instrumentation.

    When ``measure`` is set, each operator is re-executed until at least
    ``min_replay_time_s`` of wall time accumulates — the replay trick from the
    paper's §5.2 that averages out timer/counter noise for microsecond ops.
    Note the instrumented path executes the *unfused* operator stream, which
    is exactly the operator-level execution model priced by costs.py.

    ``capture_values`` retains every operator's outputs on its OpRecord
    (O(activations) extra memory, per sample).  ``stream_values`` instead
    exposes the raw outputs to the ``on_op`` callback ONLY for the duration
    of the call and drops them afterwards: the callback can reduce each
    tensor to a signature so nothing beyond the interpreter's own live
    values is ever retained, across however many samples are captured.
    """
    closed = graph.closed_jaxpr
    if closed is None:
        raise ValueError("OpGraph was built without a ClosedJaxpr; cannot execute")
    # Same flattening used to build `graph` so node idxs line up (memoized on
    # the graph: repeated multi-sample/replay runs stop re-extracting).
    flat = graph.flat_graph()
    if len(flat.nodes) != len(graph.nodes):
        raise ValueError("graph/node mismatch; rebuild graph with extract_graph")

    jaxpr = closed.jaxpr
    env: dict[Any, Any] = {}

    def read(v):
        return v.val if isinstance(v, Literal) else env[v]

    def write(v, val):
        env[v] = val

    for v, val in zip(jaxpr.constvars, closed.consts):
        write(v, val)
    flat_args = jax.tree_util.tree_leaves(args)
    if len(flat_args) != len(jaxpr.invars):
        raise ValueError(f"expected {len(jaxpr.invars)} args, got {len(flat_args)}")
    for v, val in zip(jaxpr.invars, flat_args):
        write(v, val)

    records: list[OpRecord] = []
    node_idx = 0

    def exec_eqns(eqns, inner_env, read_fn, write_fn,
                  axis_sizes: dict[str, int] | None = None):
        nonlocal node_idx
        from repro.core.graph import _INLINE_PRIMITIVES, _nested_jaxpr
        axis_sizes = axis_sizes or {}
        for eqn in eqns:
            inner = _nested_jaxpr(eqn)
            if inner is not None and eqn.primitive.name in _INLINE_PRIMITIVES:
                sub_env: dict[Any, Any] = {}

                def sread(v, _se=sub_env):
                    return v.val if isinstance(v, Literal) else _se[v]

                def swrite(v, val, _se=sub_env):
                    _se[v] = val

                sub_axes = dict(axis_sizes)
                if eqn.primitive.name == "shard_map":
                    mesh = eqn.params.get("mesh")
                    if mesh is not None:
                        sub_axes.update({str(k): int(v)
                                         for k, v in mesh.shape.items()})
                for cv, cval in zip(inner.jaxpr.constvars, inner.consts):
                    swrite(cv, cval)
                for iv, ov in zip(inner.jaxpr.invars, eqn.invars):
                    swrite(iv, read_fn(ov))
                exec_eqns(inner.jaxpr.eqns, sub_env, sread, swrite, sub_axes)
                for ov, iv in zip(eqn.outvars, inner.jaxpr.outvars):
                    write_fn(ov, sread(iv))
                continue

            invals = [read_fn(v) for v in eqn.invars]
            wall = None
            iters = 0
            if eqn.primitive.name in _COLLECTIVES or \
                    eqn.primitive.name == "axis_index":
                out = _collective_passthrough(eqn, invals, axis_sizes)
            elif measure:
                # warmup once (compile path), then replay until stable
                out = _bind(eqn, invals)
                jax.block_until_ready(out)
                t0 = time.perf_counter()
                elapsed = 0.0
                while elapsed < min_replay_time_s and iters < max_replay_iters:
                    out = _bind(eqn, invals)
                    jax.block_until_ready(out)
                    iters += 1
                    elapsed = time.perf_counter() - t0
                wall = elapsed / max(iters, 1)
            else:
                out = _bind(eqn, invals)
            for v, val in zip(eqn.outvars, out):
                write_fn(v, val)
            if capture_values:
                out_values = [np.asarray(o) for o in out]
            elif stream_values:
                out_values = list(out)   # raw, handed to on_op then dropped
            else:
                out_values = None
            rec = OpRecord(
                node_idx=node_idx,
                primitive=eqn.primitive.name,
                out_values=out_values,
                wall_time_s=wall,
                replay_iters=iters,
            )
            records.append(rec)
            if on_op is not None:
                on_op(rec)
            if stream_values and not capture_values:
                rec.out_values = None
            node_idx += 1

    exec_eqns(jaxpr.eqns, env, read, write, {})
    outs = [read(v) for v in jaxpr.outvars]
    return outs, records


def capture_tensor_values(
    graph: OpGraph, *args,
    only_tids: "set[int] | Sequence[int] | None" = None,
) -> dict[int, np.ndarray]:
    """Map tensor-id -> concrete value for edges in the graph.

    With ``only_tids`` the run retains ONLY the requested tensors (the
    matcher's phase-2 selective fetch): every other operator output is
    discarded as soon as its consumers have run, bounding peak memory by the
    requested set instead of the whole activation footprint.
    """
    want = None if only_tids is None else set(only_tids)
    values: dict[int, np.ndarray] = {}
    flat_args = jax.tree_util.tree_leaves(args)
    for tid, val in zip(graph.inputs, flat_args):
        if want is None or tid in want:
            values[tid] = np.asarray(val)

    def on_op(rec: OpRecord) -> None:
        node = graph.nodes[rec.node_idx]
        for tid, val in zip(node.outvars, rec.out_values or []):
            if want is None or tid in want:
                values[tid] = np.asarray(val)

    run_instrumented(graph, *args, stream_values=True, on_op=on_op)
    return values


def capture_tensor_stats(graph: OpGraph, *args):
    """Streaming capture: outputs + tensor-id -> cheap symmetric invariants.

    One instrumented execution computes each intermediate tensor's
    entry-symmetric invariants (l1/l2/mean/amax/amin, via jitted fused
    reductions for float tensors) in the on_op callback and discards the
    values immediately.  Returns ``(graph_outputs, {tid: TensorSignature})``
    so callers (diff.py's functional-equivalence gate) can reuse the same
    execution's outputs instead of running the program again.
    """
    from repro.core.tensor_match import stats_signature

    stats: dict[int, Any] = {}
    flat_args = jax.tree_util.tree_leaves(args)
    for tid, val in zip(graph.inputs, flat_args):
        stats[tid] = stats_signature(val)

    def on_op(rec: OpRecord) -> None:
        node = graph.nodes[rec.node_idx]
        for tid, val in zip(node.outvars, rec.out_values or []):
            stats[tid] = stats_signature(val)

    outs, _ = run_instrumented(graph, *args, stream_values=True, on_op=on_op)
    return outs, stats
