"""Capture-once candidate artifacts and their content-addressed store.

A :class:`CandidateArtifact` is the persistent product of one
``Session.capture`` run (the paper's capture→match→price pipeline decomposed,
MLPerf-Power-style, into standardized measurement artifacts): the operator
graph, per-sample streamed tensor invariants, the sample-0 outputs (the
functional-equivalence gate's evidence), the energy profile, and provenance
metadata.  ``Session.compare`` / ``Session.rank`` run matching +
classification + diagnosis *from artifacts only* — comparing N candidates
costs N captures, not N² end-to-end pipelines.

Artifacts round-trip through :class:`ArtifactStore`, a content-addressed
on-disk store keyed by ``sha256(jaxpr ‖ input shapes/dtypes ‖ sample seeds ‖
backend id)``; re-capturing an identical (function, inputs, seeds, backend)
combination is a cache hit that skips every instrumented execution.

Lazy phase-2 values: the streaming matcher re-captures concrete tensor
values only for pairs surviving the cheap invariant gate.  A *live* artifact
(fresh capture, or cache hit re-attached to its traced jaxpr) serves those
fetches by selective re-execution; every fetched value is memoized on the
artifact and persisted on save, so artifacts *loaded* from the store can
re-run past comparisons offline — entirely from disk, bit-identically.  A
loaded artifact asked for a value it has never materialized raises
:class:`ArtifactValueError` (re-attach the callable via ``Session.capture``
or ``CandidateArtifact.attach`` to extend it).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from pathlib import Path
from typing import Any, Callable, Mapping, Sequence

import numpy as np

from repro.core.energy import EnergyProfile, OpEnergy
from repro.core.graph import OpGraph, OpNode, TensorEdge
from repro.core.hlo_costs import PerOpCosts
from repro.core.tensor_match import TensorSignature

# v2 added the per-op HLO cost attribution block on the energy profile
# (profile.hlo -> PerOpCosts).  v1 artifacts still load: their per-op HLO
# costs are marked absent (None) and can be recomputed by re-capturing
# under an HloCostBackend session.
ARTIFACT_FORMAT_VERSION = 2
_READABLE_VERSIONS = (1, 2)

_STORE_ENV = "MAGNETON_STORE"
_DEFAULT_STORE = "~/.cache/magneton/artifacts"


class ArtifactValueError(RuntimeError):
    """A loaded artifact was asked for tensor values it never materialized."""


class _ReprStr(str):
    """A string whose repr() is itself.

    Jaxpr equation params survive serialization only as their repr strings.
    Diagnosis (core/diagnose.py) compares params via ``repr(...)``; wrapping
    loaded params in _ReprStr makes a loaded artifact's param reprs compare
    equal to a live artifact's, so mixed live/loaded comparisons diagnose
    identically to live/live ones.
    """

    def __repr__(self) -> str:  # noqa: D105
        return str.__str__(self)


def _param_payload(params: Mapping[str, Any]) -> dict[str, str]:
    from repro.core.diagnose import _param_repr
    return {k: _param_repr(v) for k, v in params.items()}


# ---------------------------------------------------------------------------
# cache keys
# ---------------------------------------------------------------------------

def artifact_key(graph: OpGraph, args: Sequence[Any],
                 sample_seeds: Sequence[int], backend_id: str) -> str:
    """Content address of one capture: jaxpr ‖ inputs ‖ seeds ‖ backend.

    The jaxpr pretty-print is deterministic for a given trace, so two
    processes capturing the same function on the same inputs agree on the
    key.  Input *values* (not just shapes/dtypes) are part of the address:
    the captured outputs and per-sample invariants depend on them, so
    same-shaped captures on different data must never alias in the store.
    """
    import jax

    def hash_arr(leaf) -> None:
        try:
            arr = np.asarray(leaf)
        except Exception:
            arr = None
        if arr is None or arr.dtype == object:   # non-numeric const
            h.update(repr(leaf).encode())
            return
        h.update(f"{arr.shape}:{arr.dtype}".encode())
        h.update(np.ascontiguousarray(arr).tobytes())

    h = hashlib.sha256()
    h.update(f"v{ARTIFACT_FORMAT_VERSION}".encode())
    h.update(str(graph.closed_jaxpr).encode())
    # str(jaxpr) prints constvars by NAME only — closed-over constant VALUES
    # (e.g. model weights captured by a lambda) must be hashed explicitly or
    # two models with identical architecture would alias in the store.
    for const in graph.closed_jaxpr.consts:
        hash_arr(const)
    for leaf in jax.tree_util.tree_leaves(tuple(args)):
        hash_arr(leaf)
    h.update(f"seeds={tuple(int(s) for s in sample_seeds)}".encode())
    h.update(backend_id.encode())
    return h.hexdigest()[:20]


# ---------------------------------------------------------------------------
# (de)serialization helpers
# ---------------------------------------------------------------------------

def _graph_payload(g: OpGraph) -> dict[str, Any]:
    return {
        "name": g.name,
        "nodes": [{
            "idx": n.idx, "primitive": n.primitive,
            "params": _param_payload(n.params),
            "invars": list(n.invars), "outvars": list(n.outvars),
            "call_path": list(n.call_path), "scope": list(n.scope),
        } for n in g.nodes],
        "tensors": [{
            "tid": t.tid, "shape": list(t.shape), "dtype": t.dtype,
            "producer": t.producer, "consumers": list(t.consumers),
            "is_input": t.is_input, "is_output": t.is_output,
            "is_const": t.is_const,
        } for t in g.tensors.values()],
        "inputs": list(g.inputs),
        "outputs": list(g.outputs),
    }


def _graph_from_payload(d: Mapping[str, Any]) -> OpGraph:
    nodes = [OpNode(idx=n["idx"], primitive=n["primitive"],
                    params={k: _ReprStr(v) for k, v in n["params"].items()},
                    invars=list(n["invars"]), outvars=list(n["outvars"]),
                    call_path=tuple(n["call_path"]), scope=tuple(n["scope"]))
             for n in d["nodes"]]
    tensors = {t["tid"]: TensorEdge(
        tid=t["tid"], shape=tuple(t["shape"]), dtype=t["dtype"],
        producer=t["producer"], consumers=list(t["consumers"]),
        is_input=t["is_input"], is_output=t["is_output"],
        is_const=t["is_const"]) for t in d["tensors"]}
    return OpGraph(name=d["name"], nodes=nodes, tensors=tensors,
                   inputs=list(d["inputs"]), outputs=list(d["outputs"]),
                   closed_jaxpr=None)


def _stats_payload(stats: Sequence[Mapping[int, TensorSignature]]
                   ) -> list[list[list[Any]]]:
    out = []
    for table in stats:
        rows = []
        for tid in sorted(table):
            s = table[tid]
            rows.append([tid, s.numel, s.dtype, s.l1, s.l2, s.mean,
                         s.amax, s.amin,
                         list(s.shape) if s.shape is not None else None])
        out.append(rows)
    return out


def _stats_from_payload(payload: Sequence[Sequence[Sequence[Any]]]
                        ) -> list[dict[int, TensorSignature]]:
    out: list[dict[int, TensorSignature]] = []
    for rows in payload:
        table: dict[int, TensorSignature] = {}
        for tid, numel, dtype, l1, l2, mean, amax, amin, shape in rows:
            table[tid] = TensorSignature(
                numel=numel, dtype=dtype, l1=l1, l2=l2, mean=mean,
                amax=amax, amin=amin, spectra=None,
                shape=tuple(shape) if shape is not None else None)
        out.append(table)
    return out


def _profile_payload(p: EnergyProfile) -> dict[str, Any]:
    out: dict[str, Any] = {
        "graph_name": p.graph_name,
        "ops": [[o.node_idx, o.primitive, o.energy_j, o.time_s, o.flops,
                 o.hbm_bytes, o.ici_bytes, o.bound] for o in p.ops]}
    if p.hlo is not None:
        out["hlo"] = p.hlo.as_dict()
    return out


def _profile_from_payload(d: Mapping[str, Any]) -> EnergyProfile:
    ops = [OpEnergy(node_idx=r[0], primitive=r[1], energy_j=r[2], time_s=r[3],
                    flops=r[4], hbm_bytes=r[5], ici_bytes=r[6], bound=r[7])
           for r in d["ops"]]
    hlo = PerOpCosts.from_dict(d["hlo"]) if d.get("hlo") else None
    return EnergyProfile(graph_name=d["graph_name"], ops=ops, hlo=hlo)


def _array_buffer(arr: np.ndarray) -> np.ndarray:
    """Raw little-endian-agnostic byte view (handles ml_dtypes like bf16 that
    np.save cannot describe without pickling)."""
    return np.frombuffer(np.ascontiguousarray(arr).tobytes(), dtype=np.uint8)


def _array_from_buffer(buf: np.ndarray, dtype: str,
                       shape: Sequence[int]) -> np.ndarray:
    return np.frombuffer(buf.tobytes(), dtype=np.dtype(dtype)).reshape(
        tuple(shape))


# ---------------------------------------------------------------------------
# the artifact
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CandidateArtifact:
    """One captured candidate implementation, comparable after the fact."""

    name: str
    key: str
    graph: OpGraph
    sample_stats: list[dict[int, TensorSignature]]
    outputs: list[np.ndarray]            # flat sample-0 output leaves
    profile: EnergyProfile
    backend_id: str
    backend_label: str
    sample_seeds: tuple[int, ...]        # perturbation seeds for samples 1..n-1
    config: dict[str, Any] | None = None
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)
    # phase-2 value memo, persisted on save: (sample_idx, tid) -> value
    values: dict[tuple[int, int], np.ndarray] = dataclasses.field(
        default_factory=dict, repr=False)
    # runtime-only: concrete input samples for selective re-execution
    _samples: tuple | None = dataclasses.field(
        default=None, repr=False, compare=False)
    _dirty: bool = dataclasses.field(default=False, repr=False, compare=False)

    @property
    def num_samples(self) -> int:
        return len(self.sample_stats)

    @property
    def is_live(self) -> bool:
        """Whether phase-2 values can still be fetched by re-execution."""
        return (self.graph.closed_jaxpr is not None
                and self._samples is not None)

    @property
    def total_energy_j(self) -> float:
        return self.profile.total_energy_j

    def attach(self, graph: OpGraph, args: Sequence[Any]) -> None:
        """Re-attach a freshly traced graph + capture inputs to a loaded
        artifact so lazy phase-2 fetches can execute again (cache-hit path)."""
        if graph.closed_jaxpr is None:
            raise ValueError("attach() needs a graph with a ClosedJaxpr")
        if len(graph.nodes) != len(self.graph.nodes):
            raise ValueError(
                f"attach(): graph has {len(graph.nodes)} nodes, artifact "
                f"recorded {len(self.graph.nodes)}; not the same program")
        from repro.core.session import make_samples
        self.graph = graph
        self._samples = make_samples(tuple(args), self.sample_seeds)

    def fetcher(self) -> Callable[[int, Sequence[int]], dict[int, np.ndarray]]:
        """``fetch(sample_idx, tids)`` for the lazy two-phase matcher.

        Serves memoized values first; misses trigger one selective
        re-execution (live artifacts only) and are memoized + marked dirty so
        the store can persist them for offline re-comparison.
        """
        def fetch(k: int, tids: Sequence[int]) -> dict[int, np.ndarray]:
            out: dict[int, np.ndarray] = {}
            missing = [t for t in tids if (k, t) not in self.values]
            for t in tids:
                if (k, t) in self.values:
                    out[t] = self.values[(k, t)]
            if missing:
                if not self.is_live:
                    raise ArtifactValueError(
                        f"artifact {self.name!r} ({self.key}) has no stored "
                        f"values for tensors {sorted(missing)[:8]} on sample "
                        f"{k} and no attached program to re-execute; "
                        "re-capture via Session.capture (cache hit "
                        "re-attaches) or call CandidateArtifact.attach")
                from repro.core import interp
                got = interp.capture_tensor_values(
                    self.graph, *self._samples[k], only_tids=missing)
                for t in missing:
                    v = np.asarray(got[t])
                    self.values[(k, t)] = v
                    out[t] = v
                self._dirty = True
            return out
        return fetch

    def materialize(self, *, sample_idxs: Sequence[int] | None = None,
                    tids: Sequence[int] | None = None) -> int:
        """Fetch + memoize concrete tensor values (default: every tensor on
        every sample) so the saved artifact replays *any* future comparison
        offline — not just the pairs a past compare happened to touch.

        Used by pytest-plugin baseline recording (repro.testing): a gate
        baseline must serve phase-2 fetches against candidate captures that
        do not exist yet, so its fetch set is unknowable at record time.
        Costs one selective re-execution per sample; requires a live
        artifact.  Returns the number of values now memoized.
        """
        fetch = self.fetcher()
        for k in (sample_idxs if sample_idxs is not None
                  else range(self.num_samples)):
            # default to the streamed-signature key set: exactly the tensors
            # the instrumented run exposes (inputs + op outputs; closure
            # constants are not part of the stream and cannot be fetched)
            want = (sorted(tids) if tids is not None
                    else sorted(self.sample_stats[int(k)]))
            fetch(int(k), want)
        return len(self.values)

    # -- serialization ------------------------------------------------------
    def save(self, path: str | Path) -> Path:
        path = Path(path)
        meta = {
            "format_version": ARTIFACT_FORMAT_VERSION,
            "name": self.name,
            "key": self.key,
            "backend_id": self.backend_id,
            "backend_label": self.backend_label,
            "sample_seeds": list(self.sample_seeds),
            "config": self.config,
            "meta": self.meta,
            "graph": _graph_payload(self.graph),
            "stats": _stats_payload(self.sample_stats),
            "profile": _profile_payload(self.profile),
            "outputs": [{"dtype": str(o.dtype), "shape": list(o.shape)}
                        for o in self.outputs],
            "values": [{"k": k, "tid": t, "dtype": str(v.dtype),
                        "shape": list(v.shape)}
                       for (k, t), v in sorted(self.values.items())],
        }
        arrays: dict[str, np.ndarray] = {
            "meta": np.frombuffer(json.dumps(meta).encode(), dtype=np.uint8)}
        for i, o in enumerate(self.outputs):
            arrays[f"o{i}"] = _array_buffer(o)
        for (k, t), v in self.values.items():
            arrays[f"v{k}_{t}"] = _array_buffer(v)
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".npz.tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, **arrays)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        self._dirty = False
        return path

    @classmethod
    def load(cls, path: str | Path) -> "CandidateArtifact":
        with np.load(Path(path), allow_pickle=False) as z:
            meta = json.loads(z["meta"].tobytes().decode())
            if meta["format_version"] not in _READABLE_VERSIONS:
                raise ValueError(
                    f"artifact {path} has format v{meta['format_version']}, "
                    f"this build reads "
                    f"v{'/v'.join(str(v) for v in _READABLE_VERSIONS)}")
            outputs = [_array_from_buffer(z[f"o{i}"], d["dtype"], d["shape"])
                       for i, d in enumerate(meta["outputs"])]
            values = {(d["k"], d["tid"]): _array_from_buffer(
                z[f"v{d['k']}_{d['tid']}"], d["dtype"], d["shape"])
                for d in meta["values"]}
        return cls(
            name=meta["name"], key=meta["key"],
            graph=_graph_from_payload(meta["graph"]),
            sample_stats=_stats_from_payload(meta["stats"]),
            outputs=outputs,
            profile=_profile_from_payload(meta["profile"]),
            backend_id=meta["backend_id"],
            backend_label=meta["backend_label"],
            sample_seeds=tuple(meta["sample_seeds"]),
            config=meta["config"], meta=meta["meta"], values=values)


# ---------------------------------------------------------------------------
# the store
# ---------------------------------------------------------------------------

class ArtifactStore:
    """Content-addressed on-disk artifact store (one ``<key>.npz`` per
    capture).  The root defaults to ``$MAGNETON_STORE`` or
    ``~/.cache/magneton/artifacts``."""

    def __init__(self, root: str | Path | None = None):
        if root is None:
            root = os.environ.get(_STORE_ENV, _DEFAULT_STORE)
        self.root = Path(root).expanduser()

    def path_for(self, key: str) -> Path:
        return self.root / f"{key}.npz"

    def has(self, key: str) -> bool:
        return self.path_for(key).exists()

    def save(self, artifact: CandidateArtifact) -> Path:
        return artifact.save(self.path_for(artifact.key))

    def load(self, key: str) -> CandidateArtifact:
        path = self.path_for(key)
        if not path.exists():
            raise KeyError(f"no artifact {key!r} in store {self.root}")
        return CandidateArtifact.load(path)

    def keys(self) -> list[str]:
        if not self.root.exists():
            return []
        return sorted(p.stem for p in self.root.glob("*.npz"))

    def delete(self, key: str) -> None:
        self.path_for(key).unlink(missing_ok=True)

    def total_bytes(self) -> int:
        return sum(self.path_for(k).stat().st_size for k in self.keys()
                   if self.path_for(k).exists())

    def prune(self, *, max_bytes: int | None = None, keep_latest: int = 0,
              keep: Sequence[str] = (), dry_run: bool = False) -> list[str]:
        """Garbage-collect the store, oldest artifacts first.

        Deletes least-recently-written artifacts until the store holds at
        most ``max_bytes`` (``None``: no size bound — everything unprotected
        goes, i.e. ``prune(keep_latest=n)`` keeps exactly the ``n`` newest).
        The ``keep_latest`` most recent artifacts and any key in ``keep``
        are never deleted.  Content addressing makes pruning always safe:
        a pruned capture is simply re-captured on next use, and surviving
        keys keep hitting the cache.  Returns the deleted (or, with
        ``dry_run``, would-be-deleted) keys, oldest first.
        """
        if max_bytes is None and keep_latest <= 0:
            raise ValueError("prune() needs max_bytes and/or keep_latest; "
                             "refusing to silently empty the store")
        entries = []
        for key in self.keys():
            try:
                st = self.path_for(key).stat()
            except OSError:
                continue
            # ns resolution: same-second writes (coarse-mtime filesystems,
            # rapid captures) must not fall through to hash-ordered ties
            entries.append((st.st_mtime_ns, key, st.st_size))
        entries.sort()                       # oldest first
        protected = set(keep)
        if keep_latest > 0:
            protected.update(key for _, key, _ in entries[-keep_latest:])
        total = sum(size for _, _, size in entries)
        deleted: list[str] = []
        for _, key, size in entries:
            if max_bytes is not None and total <= max_bytes:
                break
            if key in protected:
                continue
            if not dry_run:
                self.delete(key)
            deleted.append(key)
            total -= size
        return deleted

    def entries(self) -> list[dict[str, Any]]:
        """Lightweight listing (name/key/backend/size) without full loads."""
        out = []
        for key in self.keys():
            path = self.path_for(key)
            try:
                size = path.stat().st_size
            except OSError:                  # deleted since keys() globbed
                continue
            try:
                with np.load(path, allow_pickle=False) as z:
                    meta = json.loads(z["meta"].tobytes().decode())
                out.append({"key": key, "name": meta["name"],
                            "backend": meta["backend_label"],
                            "nodes": len(meta["graph"]["nodes"]),
                            "samples": len(meta["stats"]),
                            "cached_values": len(meta["values"]),
                            "bytes": size})
            except Exception as e:           # corrupt entry: list, don't die
                out.append({"key": key, "name": f"<unreadable: {e}>",
                            "backend": "?", "nodes": 0, "samples": 0,
                            "cached_values": 0, "bytes": size})
        return out
