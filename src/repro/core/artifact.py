"""Capture-once candidate artifacts and their content-addressed store.

A :class:`CandidateArtifact` is the persistent product of one
``Session.capture`` run (the paper's capture→match→price pipeline decomposed,
MLPerf-Power-style, into standardized measurement artifacts): the operator
graph, per-sample streamed tensor invariants, the sample-0 outputs (the
functional-equivalence gate's evidence), the energy profile, and provenance
metadata.  ``Session.compare`` / ``Session.rank`` run matching +
classification + diagnosis *from artifacts only* — comparing N candidates
costs N captures, not N² end-to-end pipelines.

Storage is two-tier (schema v3):

* a small JSON **manifest** per capture key — graph, streamed signatures,
  energy profile, per-op HLO costs, phase-2 *value digests* and *unfolding
  spectra* (so offline replay can re-decide every recorded match without
  touching raw values), and content references into
* a **content-addressed chunk store** — every phase-2 tensor value and
  sample-0 output is chunked (``store.CHUNK_BYTES``) and keyed by sha256,
  so identical values shared across candidates / samples / baselines (twin
  captures share inputs; matched activations are bitwise equal across
  sides) are stored exactly once.

Raw chunks are fetched lazily: a loaded artifact materializes a value only
when a comparison actually needs it, and a *sketch-only* manifest (golden
baselines by default) records digests + spectra but no raw chunks at all —
replaying a recorded comparison then performs **zero** raw-value reads.

The transport underneath (:class:`~repro.core.store.LocalStore` read-through
cache, :class:`~repro.core.store.RemoteStore` ``file://``/``http://``
mirrors) is pluggable via the :class:`~repro.core.store.Store` protocol, so
a fleet can pull captures recorded elsewhere.

v1/v2 monolithic ``.npz`` artifacts still load (per-op HLO costs absent for
v1; digests/spectra recomputed from their eagerly-stored values), and
``ArtifactStore.migrate`` converts them to the chunked layout in place.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from pathlib import Path
from typing import Any, Callable, Mapping, Sequence

import numpy as np

from repro.core.energy import EnergyProfile, OpEnergy
from repro.core.graph import OpGraph, OpNode, TensorEdge
from repro.core.hlo_costs import PerOpCosts
from repro.core.store import (LocalStore, RemoteStore, Store, StoreError,
                              is_reserved_manifest, open_store, chunk_digest,
                              split_chunks)
from repro.core.tensor_match import TensorSignature

# v3 split the monolithic per-key .npz into a JSON manifest + sha256-chunked
# value store, and added phase-1/phase-2 replay evidence (value digests +
# unfolding spectra) to the manifest.  v2 added the per-op HLO cost block
# (profile.hlo); v1 artifacts load with those costs marked absent.  The
# monolithic .npz container (CandidateArtifact.save/load) remains the v2
# format and stays readable — ArtifactStore.migrate converts it.
ARTIFACT_FORMAT_VERSION = 3
_READABLE_VERSIONS = (1, 2, 3)
_NPZ_FORMAT_VERSION = 2          # what CandidateArtifact.save(path) writes

# Store schema v4 = v3 artifact manifests + block-evidence sibling entries
# (``block--``/``profile--``/``hlo--`` manifest keys, core/block_cache.py)
# sharing the chunk space.  Artifact manifests themselves are UNCHANGED —
# ARTIFACT_FORMAT_VERSION stays 3 (it is hashed into every artifact_key, so
# bumping it would rotate all content addresses) and a v3 store reads a v4
# store's artifacts verbatim; the extra entries are advisory cache state.
STORE_SCHEMA_VERSION = 4

_STORE_ENV = "MAGNETON_STORE"
_DEFAULT_STORE = "~/.cache/magneton/artifacts"

# Ephemeral capture meta: wall-clock timings and block-cache hit/miss
# deltas describe the *run that produced* the artifact, not its content.
# They stay on the in-memory object but are stripped from every persisted
# form — manifests must be deterministic functions of the capture key so
# racing writers of one key converge byte-identically (the fleet-store
# convergence invariant, scripts/serve_audit_check.py).
_EPHEMERAL_META = ("timings", "block_cache")


def _persistable_meta(meta: Mapping[str, Any]) -> dict[str, Any]:
    return {k: v for k, v in meta.items() if k not in _EPHEMERAL_META}


class ArtifactValueError(RuntimeError):
    """A loaded artifact was asked for tensor values it never materialized."""


class _ReprStr(str):
    """A string whose repr() is itself.

    Jaxpr equation params survive serialization only as their repr strings.
    Diagnosis (core/diagnose.py) compares params via ``repr(...)``; wrapping
    loaded params in _ReprStr makes a loaded artifact's param reprs compare
    equal to a live artifact's, so mixed live/loaded comparisons diagnose
    identically to live/live ones.
    """

    def __repr__(self) -> str:  # noqa: D105
        return str.__str__(self)


def _param_payload(params: Mapping[str, Any]) -> dict[str, str]:
    from repro.core.diagnose import _param_repr
    return {k: _param_repr(v) for k, v in params.items()}


# ---------------------------------------------------------------------------
# cache keys
# ---------------------------------------------------------------------------

def artifact_key(graph: OpGraph, args: Sequence[Any],
                 sample_seeds: Sequence[int], backend_id: str) -> str:
    """Content address of one capture: jaxpr ‖ inputs ‖ seeds ‖ backend.

    The jaxpr pretty-print is deterministic for a given trace, so two
    processes capturing the same function on the same inputs agree on the
    key.  Input *values* (not just shapes/dtypes) are part of the address:
    the captured outputs and per-sample invariants depend on them, so
    same-shaped captures on different data must never alias in the store.
    """
    import jax

    def hash_arr(leaf) -> None:
        try:
            arr = np.asarray(leaf)
        except Exception:
            arr = None
        if arr is None or arr.dtype == object:   # non-numeric const
            h.update(repr(leaf).encode())
            return
        h.update(f"{arr.shape}:{arr.dtype}".encode())
        h.update(np.ascontiguousarray(arr).tobytes())

    h = hashlib.sha256()
    h.update(f"v{ARTIFACT_FORMAT_VERSION}".encode())
    h.update(str(graph.closed_jaxpr).encode())
    # str(jaxpr) prints constvars by NAME only — closed-over constant VALUES
    # (e.g. model weights captured by a lambda) must be hashed explicitly or
    # two models with identical architecture would alias in the store.
    for const in graph.closed_jaxpr.consts:
        hash_arr(const)
    for leaf in jax.tree_util.tree_leaves(tuple(args)):
        hash_arr(leaf)
    h.update(f"seeds={tuple(int(s) for s in sample_seeds)}".encode())
    h.update(backend_id.encode())
    return h.hexdigest()[:20]


# ---------------------------------------------------------------------------
# (de)serialization helpers
# ---------------------------------------------------------------------------

def _graph_payload(g: OpGraph) -> dict[str, Any]:
    return {
        "name": g.name,
        "nodes": [{
            "idx": n.idx, "primitive": n.primitive,
            "params": _param_payload(n.params),
            "invars": list(n.invars), "outvars": list(n.outvars),
            "call_path": list(n.call_path), "scope": list(n.scope),
        } for n in g.nodes],
        "tensors": [{
            "tid": t.tid, "shape": list(t.shape), "dtype": t.dtype,
            "producer": t.producer, "consumers": list(t.consumers),
            "is_input": t.is_input, "is_output": t.is_output,
            "is_const": t.is_const,
        } for t in g.tensors.values()],
        "inputs": list(g.inputs),
        "outputs": list(g.outputs),
    }


def _graph_from_payload(d: Mapping[str, Any]) -> OpGraph:
    nodes = [OpNode(idx=n["idx"], primitive=n["primitive"],
                    params={k: _ReprStr(v) for k, v in n["params"].items()},
                    invars=list(n["invars"]), outvars=list(n["outvars"]),
                    call_path=tuple(n["call_path"]), scope=tuple(n["scope"]))
             for n in d["nodes"]]
    tensors = {t["tid"]: TensorEdge(
        tid=t["tid"], shape=tuple(t["shape"]), dtype=t["dtype"],
        producer=t["producer"], consumers=list(t["consumers"]),
        is_input=t["is_input"], is_output=t["is_output"],
        is_const=t["is_const"]) for t in d["tensors"]}
    return OpGraph(name=d["name"], nodes=nodes, tensors=tensors,
                   inputs=list(d["inputs"]), outputs=list(d["outputs"]),
                   closed_jaxpr=None)


def _stats_payload(stats: Sequence[Mapping[int, TensorSignature]]
                   ) -> list[list[list[Any]]]:
    out = []
    for table in stats:
        rows = []
        for tid in sorted(table):
            s = table[tid]
            rows.append([tid, s.numel, s.dtype, s.l1, s.l2, s.mean,
                         s.amax, s.amin,
                         list(s.shape) if s.shape is not None else None])
        out.append(rows)
    return out


def _stats_from_payload(payload: Sequence[Sequence[Sequence[Any]]]
                        ) -> list[dict[int, TensorSignature]]:
    out: list[dict[int, TensorSignature]] = []
    for rows in payload:
        table: dict[int, TensorSignature] = {}
        for tid, numel, dtype, l1, l2, mean, amax, amin, shape in rows:
            table[tid] = TensorSignature(
                numel=numel, dtype=dtype, l1=l1, l2=l2, mean=mean,
                amax=amax, amin=amin, spectra=None,
                shape=tuple(shape) if shape is not None else None)
        out.append(table)
    return out


def _profile_payload(p: EnergyProfile) -> dict[str, Any]:
    out: dict[str, Any] = {
        "graph_name": p.graph_name,
        "ops": [[o.node_idx, o.primitive, o.energy_j, o.time_s, o.flops,
                 o.hbm_bytes, o.ici_bytes, o.bound] for o in p.ops]}
    if p.hlo is not None:
        out["hlo"] = p.hlo.as_dict()
    return out


def _profile_from_payload(d: Mapping[str, Any]) -> EnergyProfile:
    ops = [OpEnergy(node_idx=r[0], primitive=r[1], energy_j=r[2], time_s=r[3],
                    flops=r[4], hbm_bytes=r[5], ici_bytes=r[6], bound=r[7])
           for r in d["ops"]]
    hlo = PerOpCosts.from_dict(d["hlo"]) if d.get("hlo") else None
    return EnergyProfile(graph_name=d["graph_name"], ops=ops, hlo=hlo)


def _array_buffer(arr: np.ndarray) -> np.ndarray:
    """Raw little-endian-agnostic byte view (handles ml_dtypes like bf16 that
    np.save cannot describe without pickling)."""
    return np.frombuffer(np.ascontiguousarray(arr).tobytes(), dtype=np.uint8)


def _array_from_buffer(buf: np.ndarray, dtype: str,
                       shape: Sequence[int]) -> np.ndarray:
    return np.frombuffer(buf.tobytes(), dtype=np.dtype(dtype)).reshape(
        tuple(shape))


def _array_bytes(arr: np.ndarray) -> bytes:
    return np.ascontiguousarray(arr).tobytes()


def value_digest(arr: np.ndarray) -> str:
    """sha256 of a tensor value's raw bytes — bitwise equality evidence.

    The matcher's identical-value fast path compares digests: equal digests
    mean bitwise-equal buffers, so the full spectral test would pass by
    construction and is skipped.  (Phase-2 values are NaN-free by the
    degenerate-signature gate, so bitwise equality and elementwise equality
    coincide up to the sign of zero, where the spectral test agrees anyway.)
    """
    return chunk_digest(_array_bytes(np.asarray(arr)))


@dataclasses.dataclass
class ValueRef:
    """Manifest record of one phase-2 value: identity always, bytes maybe.

    ``chunks`` is the ordered chunk-digest list reconstructing the raw
    buffer, or ``None`` for sketch-only entries (digest + dtype/shape known,
    raw bytes never persisted — offline replay decides from the digest and
    the manifest spectra instead).
    """

    dtype: str
    shape: tuple[int, ...]
    nbytes: int
    digest: str
    chunks: list[str] | None

    def to_dict(self) -> dict[str, Any]:
        return {"dtype": self.dtype, "shape": list(self.shape),
                "nbytes": self.nbytes, "digest": self.digest,
                "chunks": self.chunks}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ValueRef":
        return cls(dtype=d["dtype"], shape=tuple(d["shape"]),
                   nbytes=int(d["nbytes"]), digest=d["digest"],
                   chunks=list(d["chunks"]) if d.get("chunks") is not None
                   else None)


def _spectra_payload(memo: Mapping[tuple[int, int, tuple[int, int]],
                                   list[np.ndarray]]) -> list[dict[str, Any]]:
    out = []
    for (k, tid, (rows, cols)) in sorted(memo):
        lst = memo[(k, tid, (rows, cols))]
        out.append({"k": k, "tid": tid, "rows": rows, "cols": cols,
                    # float() -> repr-based JSON floats: exact round-trip,
                    # so replayed _setwise_match is bit-identical
                    "spectra": [[float(v) for v in s] for s in lst]})
    return out


def _spectra_from_payload(payload: Sequence[Mapping[str, Any]]
                          ) -> dict[tuple[int, int, tuple[int, int]],
                                    list[np.ndarray]]:
    return {(int(d["k"]), int(d["tid"]), (int(d["rows"]), int(d["cols"]))):
            [np.asarray(s, dtype=np.float64) for s in d["spectra"]]
            for d in payload}


# ---------------------------------------------------------------------------
# the artifact
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CandidateArtifact:
    """One captured candidate implementation, comparable after the fact."""

    name: str
    key: str
    graph: OpGraph
    sample_stats: list[dict[int, TensorSignature]]
    outputs: list[np.ndarray]            # flat sample-0 output leaves
    profile: EnergyProfile
    backend_id: str
    backend_label: str
    sample_seeds: tuple[int, ...]        # perturbation seeds for samples 1..n-1
    config: dict[str, Any] | None = None
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)
    # phase-2 value memo, persisted on save: (sample_idx, tid) -> value
    values: dict[tuple[int, int], np.ndarray] = dataclasses.field(
        default_factory=dict, repr=False)
    # phase-2 replay evidence (manifest-persisted): value identity records
    # and memoized unfolding spectra, keyed (sample, tid[, (rows, cols)])
    value_index: dict[tuple[int, int], ValueRef] = dataclasses.field(
        default_factory=dict, repr=False)
    spectra_memo: dict[tuple[int, int, tuple[int, int]], list[np.ndarray]] = \
        dataclasses.field(default_factory=dict, repr=False)
    # runtime-only: concrete input samples for selective re-execution
    _samples: tuple | None = dataclasses.field(
        default=None, repr=False, compare=False)
    _dirty: bool = dataclasses.field(default=False, repr=False, compare=False)
    # runtime-only: chunk transport for lazy raw-value reads (set on load)
    _chunk_source: Store | None = dataclasses.field(
        default=None, repr=False, compare=False)
    # runtime-only: store failures downgraded to fetch misses (see
    # _fetch_from_chunks); Session.compare reads these to declare degraded
    # provenance instead of silently re-executing around a broken store
    fetch_errors: list[str] = dataclasses.field(
        default_factory=list, repr=False, compare=False)

    @property
    def num_samples(self) -> int:
        return len(self.sample_stats)

    @property
    def is_live(self) -> bool:
        """Whether phase-2 values can still be fetched by re-execution."""
        return (self.graph.closed_jaxpr is not None
                and self._samples is not None)

    @property
    def total_energy_j(self) -> float:
        return self.profile.total_energy_j

    def attach(self, graph: OpGraph, args: Sequence[Any]) -> None:
        """Re-attach a freshly traced graph + capture inputs to a loaded
        artifact so lazy phase-2 fetches can execute again (cache-hit path)."""
        if graph.closed_jaxpr is None:
            raise ValueError("attach() needs a graph with a ClosedJaxpr")
        if len(graph.nodes) != len(self.graph.nodes):
            raise ValueError(
                f"attach(): graph has {len(graph.nodes)} nodes, artifact "
                f"recorded {len(self.graph.nodes)}; not the same program")
        from repro.core.session import make_samples
        self.graph = graph
        self._samples = make_samples(tuple(args), self.sample_seeds)

    def _fetch_from_chunks(self, k: int, tid: int) -> np.ndarray | None:
        """Reconstruct one value from the chunk store, if it is there."""
        ref = self.value_index.get((k, tid))
        if ref is None or ref.chunks is None or self._chunk_source is None:
            return None
        try:
            buf = b"".join(self._chunk_source.read_chunk(d)
                           for d in ref.chunks)
        except KeyError:
            return None          # chunk pruned / partial mirror: treat as miss
        except (StoreError, OSError) as e:
            # store unreachable/corrupt beyond repair: record why and treat
            # as a miss — live artifacts re-execute, loaded artifacts raise
            # the typed ArtifactValueError (never silent wrong values)
            self.fetch_errors.append(f"s{k}/t{tid}: {type(e).__name__}: {e}")
            return None
        return np.frombuffer(buf, dtype=np.dtype(ref.dtype)).reshape(ref.shape)

    def fetcher(self) -> Callable[[int, Sequence[int]], dict[int, np.ndarray]]:
        """``fetch(sample_idx, tids)`` for the lazy two-phase matcher.

        Resolution order per value: the in-memory memo, then the chunk store
        (loaded artifacts pull raw chunks lazily — and only for tensors a
        comparison actually still needs), then one selective re-execution
        (live artifacts only; fetched values are memoized + marked dirty so
        the store can persist them for offline re-comparison).
        """
        def fetch(k: int, tids: Sequence[int]) -> dict[int, np.ndarray]:
            out: dict[int, np.ndarray] = {}
            missing: list[int] = []
            for t in tids:
                if (k, t) in self.values:
                    out[t] = self.values[(k, t)]
                    continue
                v = self._fetch_from_chunks(k, t)
                if v is not None:
                    self.values[(k, t)] = v   # chunk-backed: not dirty
                    out[t] = v
                else:
                    missing.append(t)
            if missing:
                if not self.is_live:
                    sketch = [t for t in missing
                              if (k, t) in self.value_index]
                    detail = (f" ({len(sketch)} recorded sketch-only: "
                              "digests+spectra persisted, raw chunks not)"
                              if sketch else "")
                    raise ArtifactValueError(
                        f"artifact {self.name!r} ({self.key}) has no stored "
                        f"values for tensors {sorted(missing)[:8]} on sample "
                        f"{k}{detail} and no attached program to re-execute; "
                        "re-capture via Session.capture (cache hit "
                        "re-attaches) or call CandidateArtifact.attach")
                from repro.core import interp
                got = interp.capture_tensor_values(
                    self.graph, *self._samples[k], only_tids=missing)
                for t in missing:
                    v = np.asarray(got[t])
                    self.values[(k, t)] = v
                    out[t] = v
                self._dirty = True
            return out
        return fetch

    def spectra_provider(self) -> "_ArtifactSpectraProvider":
        """Replay-evidence accessor for the lazy matcher: persisted value
        digests and unfolding spectra, written back on first computation so
        a comparison once run is replayable with zero raw-value reads."""
        return _ArtifactSpectraProvider(self)

    def materialize(self, *, sample_idxs: Sequence[int] | None = None,
                    tids: Sequence[int] | None = None) -> int:
        """Fetch + memoize concrete tensor values (default: every tensor on
        every sample) so the saved artifact replays *any* future comparison
        offline — not just the pairs a past compare happened to touch.

        Used by pytest-plugin baseline recording (repro.testing): a gate
        baseline must serve phase-2 fetches against candidate captures that
        do not exist yet, so its fetch set is unknowable at record time.
        Costs one selective re-execution per sample; requires a live
        artifact.  Returns the number of values now memoized.
        """
        fetch = self.fetcher()
        for k in (sample_idxs if sample_idxs is not None
                  else range(self.num_samples)):
            # default to the streamed-signature key set: exactly the tensors
            # the instrumented run exposes (inputs + op outputs; closure
            # constants are not part of the stream and cannot be fetched)
            want = (sorted(tids) if tids is not None
                    else sorted(self.sample_stats[int(k)]))
            fetch(int(k), want)
        return len(self.values)

    # -- monolithic .npz container (legacy v2 format) -----------------------
    def save(self, path: str | Path) -> Path:
        """Write the monolithic ``.npz`` container (the legacy v2 layout:
        every memoized value stored eagerly inline).  Standalone exports and
        pytest-plugin kernel baselines use this; store-backed persistence
        goes through ``ArtifactStore.save`` (chunked manifest, v3)."""
        path = Path(path)
        # self-contained export: chunk-backed values are materialized inline
        # (sketch-only entries have no raw bytes anywhere and are skipped)
        for (k, t) in sorted(self.value_index):
            if (k, t) not in self.values:
                v = self._fetch_from_chunks(k, t)
                if v is not None:
                    self.values[(k, t)] = v
        meta = {
            "format_version": _NPZ_FORMAT_VERSION,
            "name": self.name,
            "key": self.key,
            "backend_id": self.backend_id,
            "backend_label": self.backend_label,
            "sample_seeds": list(self.sample_seeds),
            "config": self.config,
            "meta": _persistable_meta(self.meta),
            "graph": _graph_payload(self.graph),
            "stats": _stats_payload(self.sample_stats),
            "profile": _profile_payload(self.profile),
            "outputs": [{"dtype": str(o.dtype), "shape": list(o.shape)}
                        for o in self.outputs],
            "values": [{"k": k, "tid": t, "dtype": str(v.dtype),
                        "shape": list(v.shape)}
                       for (k, t), v in sorted(self.values.items())],
        }
        arrays: dict[str, np.ndarray] = {
            "meta": np.frombuffer(json.dumps(meta).encode(), dtype=np.uint8)}
        for i, o in enumerate(self.outputs):
            arrays[f"o{i}"] = _array_buffer(o)
        for (k, t), v in self.values.items():
            arrays[f"v{k}_{t}"] = _array_buffer(v)
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".npz.tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, **arrays)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        self._dirty = False
        return path

    @classmethod
    def load(cls, path: str | Path) -> "CandidateArtifact":
        with np.load(Path(path), allow_pickle=False) as z:
            meta = json.loads(z["meta"].tobytes().decode())
            if meta["format_version"] not in _READABLE_VERSIONS:
                raise ValueError(
                    f"artifact {path} has format v{meta['format_version']}, "
                    f"this build reads "
                    f"v{'/v'.join(str(v) for v in _READABLE_VERSIONS)}")
            outputs = [_array_from_buffer(z[f"o{i}"], d["dtype"], d["shape"])
                       for i, d in enumerate(meta["outputs"])]
            values = {(d["k"], d["tid"]): _array_from_buffer(
                z[f"v{d['k']}_{d['tid']}"], d["dtype"], d["shape"])
                for d in meta["values"]}
        return cls(
            name=meta["name"], key=meta["key"],
            graph=_graph_from_payload(meta["graph"]),
            sample_stats=_stats_from_payload(meta["stats"]),
            outputs=outputs,
            profile=_profile_from_payload(meta["profile"]),
            backend_id=meta["backend_id"],
            backend_label=meta["backend_label"],
            sample_seeds=tuple(meta["sample_seeds"]),
            config=meta["config"], meta=meta["meta"], values=values)

    # -- v3 manifest (used by ArtifactStore) --------------------------------
    def to_manifest(self, *, persist_values: bool,
                    write_chunk: Callable[[str, bytes], None],
                    has_chunk: Callable[[str], bool]) -> dict[str, Any]:
        """Build the v3 manifest payload, writing chunks through the given
        callbacks.  With ``persist_values=False`` (sketch-only) raw value
        chunks are skipped — only digests + spectra go into the manifest —
        while sample-0 outputs are always chunked (the functional-
        equivalence gate reads them on every load)."""
        out_refs: list[dict[str, Any]] = []
        for o in self.outputs:
            buf = _array_bytes(o)
            chunks = []
            for c in split_chunks(buf):
                d = chunk_digest(c)
                write_chunk(d, c)
                chunks.append(d)
            out_refs.append(ValueRef(
                dtype=str(o.dtype), shape=tuple(int(s) for s in o.shape),
                nbytes=len(buf), digest=chunk_digest(buf),
                chunks=chunks).to_dict())

        val_refs: list[dict[str, Any]] = []
        for (k, t) in sorted(set(self.values) | set(self.value_index)):
            ref = self.value_index.get((k, t))
            if ref is None:
                v = self.values[(k, t)]
                buf = _array_bytes(v)
                ref = ValueRef(dtype=str(v.dtype),
                               shape=tuple(int(s) for s in v.shape),
                               nbytes=len(buf), digest=chunk_digest(buf),
                               chunks=None)
                self.value_index[(k, t)] = ref
            chunks = ref.chunks
            if persist_values and (chunks is None
                                   or not all(has_chunk(d) for d in chunks)):
                # materialize the bytes (memory, else the source chunk
                # store) and write them into the target; chunk lists are
                # content-derived, so the same value has the same list in
                # every store — only availability differs
                v = self.values.get((k, t))
                if v is None:
                    v = self._fetch_from_chunks(k, t)
                if v is not None:
                    chunks = []
                    for c in split_chunks(_array_bytes(v)):
                        d = chunk_digest(c)
                        write_chunk(d, c)
                        chunks.append(d)
                    if ref.chunks is None:
                        ref = dataclasses.replace(ref, chunks=chunks)
                        self.value_index[(k, t)] = ref
            if chunks is not None and not all(has_chunk(d) for d in chunks):
                # never advertise chunks the target cannot serve (e.g. a
                # sketch-only target, or bytes no store can produce
                # anymore): a digest-only record is the honest state
                chunks = None
            rec = ref.to_dict()
            rec["chunks"] = chunks
            val_refs.append({"k": k, "tid": t, **rec})

        return {
            "format_version": ARTIFACT_FORMAT_VERSION,
            "name": self.name,
            "key": self.key,
            "backend_id": self.backend_id,
            "backend_label": self.backend_label,
            "sample_seeds": list(self.sample_seeds),
            "config": self.config,
            "meta": _persistable_meta(self.meta),
            "graph": _graph_payload(self.graph),
            "stats": _stats_payload(self.sample_stats),
            "profile": _profile_payload(self.profile),
            "outputs": out_refs,
            "values": val_refs,
            "spectra": _spectra_payload(self.spectra_memo),
        }

    @classmethod
    def from_manifest(cls, manifest: Mapping[str, Any],
                      chunk_source: Store | None) -> "CandidateArtifact":
        version = manifest["format_version"]
        if version not in _READABLE_VERSIONS:
            raise ValueError(
                f"artifact manifest has format v{version}, this build reads "
                f"v{'/v'.join(str(v) for v in _READABLE_VERSIONS)}")
        outputs = []
        for d in manifest["outputs"]:
            ref = ValueRef.from_dict(d)
            if chunk_source is None:
                raise ValueError("manifest-backed artifact needs a chunk "
                                 "source for its outputs")
            buf = b"".join(chunk_source.read_chunk(c) for c in ref.chunks)
            outputs.append(np.frombuffer(buf, dtype=np.dtype(ref.dtype))
                           .reshape(ref.shape))
        value_index = {(int(d["k"]), int(d["tid"])): ValueRef.from_dict(d)
                       for d in manifest["values"]}
        art = cls(
            name=manifest["name"], key=manifest["key"],
            graph=_graph_from_payload(manifest["graph"]),
            sample_stats=_stats_from_payload(manifest["stats"]),
            outputs=outputs,
            profile=_profile_from_payload(manifest["profile"]),
            backend_id=manifest["backend_id"],
            backend_label=manifest["backend_label"],
            sample_seeds=tuple(manifest["sample_seeds"]),
            config=manifest["config"], meta=manifest["meta"],
            value_index=value_index,
            spectra_memo=_spectra_from_payload(manifest.get("spectra", ())))
        art._chunk_source = chunk_source
        return art


class _ArtifactSpectraProvider:
    """Persisted replay evidence: value digests + unfolding spectra.

    The lazy matcher consults this before touching raw values and records
    everything it computes, so offline replay of a recorded comparison
    needs zero raw-value chunk reads (digest equality decides the
    identical-value fast path; persisted spectra decide the rest).
    """

    def __init__(self, art: CandidateArtifact):
        self._art = art

    def digest(self, k: int, tid: int) -> str | None:
        ref = self._art.value_index.get((k, tid))
        return ref.digest if ref is not None else None

    def record_digest(self, k: int, tid: int, value: np.ndarray) -> str:
        ref = self._art.value_index.get((k, tid))
        if ref is not None:
            return ref.digest
        buf = _array_bytes(value)
        ref = ValueRef(dtype=str(value.dtype),
                       shape=tuple(int(s) for s in value.shape),
                       nbytes=len(buf), digest=chunk_digest(buf), chunks=None)
        self._art.value_index[(k, tid)] = ref
        self._art._dirty = True
        return ref.digest

    def spectra(self, k: int, tid: int,
                key: tuple[int, int]) -> list[np.ndarray] | None:
        return self._art.spectra_memo.get((k, tid, key))

    def record_spectra(self, k: int, tid: int, key: tuple[int, int],
                       spectra: list[np.ndarray]) -> None:
        self._art.spectra_memo[(k, tid, key)] = spectra
        self._art._dirty = True


# ---------------------------------------------------------------------------
# the store
# ---------------------------------------------------------------------------

class ArtifactStore:
    """Content-addressed artifact store: v3 chunked manifests over a
    pluggable :class:`~repro.core.store.Store` transport.

    The root defaults to ``$MAGNETON_STORE`` or ``~/.cache/magneton/
    artifacts``.  ``remote`` attaches a read-through upstream (URI or
    Store): manifest/chunk misses are pulled from it and cached locally, so
    a cache hit on a capture recorded elsewhere still skips every
    instrumented execution.  Legacy monolithic ``<key>.npz`` entries in the
    root keep loading (and count as store hits) until ``migrate()`` converts
    them.
    """

    def __init__(self, root: str | Path | None = None, *,
                 backend: Store | None = None,
                 remote: "Store | str | None" = None,
                 persist_raw_values: bool = True,
                 store_timeout: float | None = None):
        if backend is not None:
            self.backend = backend
            self.root = Path(getattr(backend, "root", ".")) \
                if getattr(backend, "root", None) is not None else None
        else:
            if root is None:
                root = os.environ.get(_STORE_ENV, _DEFAULT_STORE)
            self.root = Path(root).expanduser()
            upstream = (open_store(remote, timeout=store_timeout)
                        if remote is not None else None)
            self.backend = LocalStore(self.root, upstream=upstream)
        self.persist_raw_values = persist_raw_values

    @classmethod
    def from_uri(cls, uri: "str | Path | ArtifactStore | None",
                 *, store_timeout: float | None = None,
                 writable: bool = False,
                 **kwargs) -> "ArtifactStore":
        """``--store`` resolution: plain paths open a LocalStore-backed
        store; ``file://``/``http(s)://`` URIs open a RemoteStore-backed
        one.  http mirrors are readonly unless ``writable`` is set, which
        enables the conditional-put write dialect against servers that
        support it (S3/GCS-style; see docs/serving.md).  ``store_timeout``
        bounds http reads (seconds; the ``--store-timeout`` CLI flag)."""
        if isinstance(uri, ArtifactStore):
            return uri
        if uri is None:
            return cls(store_timeout=store_timeout, **kwargs)
        if "://" in str(uri):
            return cls(backend=RemoteStore(str(uri), timeout=store_timeout,
                                           writable=writable),
                       **kwargs)
        return cls(uri, **kwargs)

    @property
    def readonly(self) -> bool:
        return bool(getattr(self.backend, "readonly", False))

    @property
    def counters(self) -> dict[str, int]:
        return self.backend.counters

    # -- paths / membership -------------------------------------------------
    def path_for(self, key: str) -> Path:
        """Where this key's v3 manifest lives (informational)."""
        if self.root is None:
            return Path(f"manifests/{key}.json")
        return self.root / "manifests" / f"{key}.json"

    def _legacy_path(self, key: str) -> Path | None:
        if self.root is None:
            return None
        p = self.root / f"{key}.npz"
        return p if p.exists() else None

    def legacy_keys(self) -> list[str]:
        """Keys still stored as monolithic v1/v2 ``.npz`` files."""
        if self.root is None or not self.root.exists():
            return []
        return sorted(p.stem for p in self.root.glob("*.npz"))

    def has(self, key: str) -> bool:
        return (self.backend.has_manifest(key)
                or self._legacy_path(key) is not None)

    def keys(self) -> list[str]:
        # reserved (audit-state) manifests share the transport but are not
        # CandidateArtifact entries; repro.audit.fleet reads them directly
        keys = {k for k in self.backend.manifest_keys()
                if not is_reserved_manifest(k)}
        return sorted(keys | set(self.legacy_keys()))

    # -- save / load --------------------------------------------------------
    def save(self, artifact: CandidateArtifact,
             *, persist_values: bool | None = None) -> Path:
        """Persist one artifact as manifest + chunks (atomic: chunks land
        before the manifest rename publishes them, so a crash mid-save
        leaves a clean miss, never a torn entry)."""
        if self.readonly:
            raise PermissionError(
                f"store {getattr(self.backend, 'uri', self.root)} is "
                "readonly; cannot save artifacts into a mirror")
        if persist_values is None:
            persist_values = self.persist_raw_values
        manifest = artifact.to_manifest(
            persist_values=persist_values,
            write_chunk=self.backend.write_chunk,
            has_chunk=self.backend.has_chunk)
        self.backend.write_manifest(artifact.key, manifest)
        if artifact._chunk_source is None:
            artifact._chunk_source = self.backend
        artifact._dirty = False
        return self.path_for(artifact.key)

    def load(self, key: str) -> CandidateArtifact:
        if self.backend.has_manifest(key):
            return CandidateArtifact.from_manifest(
                self.backend.read_manifest(key), self.backend)
        legacy = self._legacy_path(key)
        if legacy is not None:
            return CandidateArtifact.load(legacy)
        raise KeyError(f"no artifact {key!r} in store "
                       f"{getattr(self.backend, 'uri', self.root)}")

    def delete(self, key: str) -> None:
        self.backend.delete_manifest(key)
        legacy = self._legacy_path(key)
        if legacy is not None:
            legacy.unlink(missing_ok=True)

    # -- sizes --------------------------------------------------------------
    def _chunk_refs(self, manifest: Mapping[str, Any]) -> list[str]:
        # .get: reserved audit-state manifests have none of these fields
        # and reference no chunks; block-evidence entries reference theirs
        # through "ext_out"
        out: list[str] = []
        for rec in (list(manifest.get("outputs", ()))
                    + list(manifest.get("values", ()))
                    + list(manifest.get("ext_out", ()))):
            if rec.get("chunks"):
                out.extend(rec["chunks"])
        return out

    def _evidence_chunk_refs(self) -> set[str]:
        """Chunks referenced by block-evidence entries: pinned against
        artifact-walk GC (prune only sees artifact manifests)."""
        from repro.core.block_cache import is_block_evidence
        pinned: set[str] = set()
        for key in self.backend.manifest_keys():
            if not is_block_evidence(key):
                continue
            try:
                manifest = self.backend.read_manifest(key)
            except (KeyError, OSError, StoreError):
                continue
            pinned.update(self._chunk_refs(manifest))
        return pinned

    def entry_bytes(self, key: str) -> int:
        """One entry's footprint: manifest + referenced chunks (shared
        chunks counted in full for every referent) or the legacy npz size."""
        if self.backend.has_manifest(key):
            manifest = self.backend.read_manifest(key)
            total = self.backend.manifest_bytes(key)
            for d in set(self._chunk_refs(manifest)):
                try:
                    total += self.backend.chunk_bytes(d)
                except (KeyError, OSError, StoreError):
                    pass
            return total
        legacy = self._legacy_path(key)
        if legacy is not None:
            return legacy.stat().st_size
        raise KeyError(key)

    def total_bytes(self) -> int:
        """Physical on-disk bytes: manifests + chunks + legacy npz files."""
        total = 0
        for key in self.backend.manifest_keys():
            try:
                total += self.backend.manifest_bytes(key)
            except (KeyError, OSError, StoreError):
                continue
        for d in self.backend.chunk_keys():
            try:
                total += self.backend.chunk_bytes(d)
            except (KeyError, OSError, StoreError):
                continue
        for key in self.legacy_keys():
            legacy = self._legacy_path(key)
            if legacy is not None:
                try:
                    total += legacy.stat().st_size
                except OSError:
                    continue
        return total

    # -- GC -----------------------------------------------------------------
    def _refcounts(self) -> dict[str, int]:
        from repro.core.block_cache import is_block_evidence
        refs: dict[str, int] = {}
        for key in self.backend.manifest_keys():
            # audit state references no chunks; block evidence does, and a
            # live entry must keep its chunks out of gc_chunks' dead set
            if is_reserved_manifest(key) and not is_block_evidence(key):
                continue
            try:
                manifest = self.backend.read_manifest(key)
            except (KeyError, OSError, StoreError):
                continue
            for d in self._chunk_refs(manifest):
                refs[d] = refs.get(d, 0) + 1
        return refs

    def gc_chunks(self, *, dry_run: bool = False) -> list[str]:
        """Delete chunks no surviving manifest references.  Returns the
        (would-be-)deleted digests."""
        refs = self._refcounts()
        dead = [d for d in self.backend.chunk_keys() if d not in refs]
        if not dry_run:
            for d in dead:
                self.backend.delete_chunk(d)
        return dead

    def prune(self, *, max_bytes: int | None = None, keep_latest: int = 0,
              keep: Sequence[str] = (), dry_run: bool = False) -> list[str]:
        """Garbage-collect the store, oldest artifacts first.

        Deletes least-recently-written artifacts until the store holds at
        most ``max_bytes`` (``None``: no size bound — everything unprotected
        goes, i.e. ``prune(keep_latest=n)`` keeps exactly the ``n`` newest).
        The ``keep_latest`` most recent artifacts and any key in ``keep``
        are never deleted.  Refcount-aware: deleting a manifest frees only
        the chunks no surviving manifest still references (shared weights /
        activations stay as long as one referent lives).  Content addressing
        makes pruning always safe: a pruned capture is simply re-captured on
        next use, and surviving keys keep hitting the cache.  Returns the
        deleted (or, with ``dry_run``, would-be-deleted) keys, oldest first.
        """
        if max_bytes is None and keep_latest <= 0:
            raise ValueError("prune() needs max_bytes and/or keep_latest; "
                             "refusing to silently empty the store")
        entries = []       # (mtime_ns, key, manifest_or_npz_bytes, chunkrefs)
        for key in self.keys():
            try:
                if self.backend.has_manifest(key):
                    mtime = self.backend.manifest_mtime_ns(key)
                    size = self.backend.manifest_bytes(key)
                    refs = self._chunk_refs(self.backend.read_manifest(key))
                else:
                    st = self._legacy_path(key).stat()
                    mtime, size, refs = st.st_mtime_ns, st.st_size, []
            except (OSError, KeyError, AttributeError, StoreError):
                continue
            # ns resolution: same-second writes (coarse-mtime filesystems,
            # rapid captures) must not fall through to hash-ordered ties
            entries.append((mtime, key, size, refs))
        entries.sort()                       # oldest first

        refcount: dict[str, int] = {}
        chunk_size: dict[str, int] = {}
        for _, _, _, refs in entries:
            for d in refs:
                refcount[d] = refcount.get(d, 0) + 1
        for d in refcount:
            try:
                chunk_size[d] = self.backend.chunk_bytes(d)
            except (KeyError, OSError, StoreError):
                chunk_size[d] = 0

        # chunks shared with block-evidence entries survive their last
        # artifact referent: the evidence entry still rematerializes them
        pinned = self._evidence_chunk_refs()

        protected = set(keep)
        if keep_latest > 0:
            protected.update(key for _, key, _, _ in entries[-keep_latest:])
        total = (sum(size for _, _, size, _ in entries)
                 + sum(chunk_size.values()))
        deleted: list[str] = []
        for _, key, size, refs in entries:
            if max_bytes is not None and total <= max_bytes:
                break
            if key in protected:
                continue
            freed = size
            for d in refs:
                refcount[d] -= 1
                if refcount[d] == 0 and d not in pinned:
                    freed += chunk_size.get(d, 0)
                    if not dry_run:
                        self.backend.delete_chunk(d)
            if not dry_run:
                self.backend.delete_manifest(key)
                legacy = self._legacy_path(key)
                if legacy is not None:
                    legacy.unlink(missing_ok=True)
            deleted.append(key)
            total -= freed
        return deleted

    def _quarantine_fs(self):
        fs = getattr(self.backend, "_fs", None)
        if fs is None:
            raise ValueError(
                f"store {getattr(self.backend, 'uri', self.root)} has no "
                "local quarantine directory (http mirrors quarantine "
                "nothing); prune the quarantine on the serving host")
        return fs

    def quarantine_bytes(self) -> int:
        """Total bytes held in the store's corruption-quarantine directory."""
        return sum(size for _, _, size in
                   self._quarantine_fs().quarantine_entries())

    def prune_quarantine(self, *, max_bytes: int | None = None,
                         dry_run: bool = False) -> list[str]:
        """Evict quarantined (corrupt-at-rest) files, oldest first, until
        the quarantine directory fits ``max_bytes`` (``None``: empty it).
        Returns the (would-be-)evicted file names.  Unlike :meth:`prune`,
        nothing here is re-creatable — quarantined files exist only for
        forensics — so the eviction is a plain size-bounded FIFO."""
        fs = self._quarantine_fs()
        evicted = fs.prune_quarantine(max_bytes if max_bytes is not None
                                      else 0, dry_run=dry_run)
        if not dry_run and evicted:
            counters = getattr(self.backend, "counters", None)
            if counters is not None:
                counters["quarantine_evictions"] = (
                    counters.get("quarantine_evictions", 0) + len(evicted))
        return [p.name for p in evicted]

    # -- fleet transfer -----------------------------------------------------
    def push(self, dest: "ArtifactStore | Store | str",
             keys: Sequence[str] | None = None) -> dict[str, int]:
        """Copy manifests + missing chunks into another store (dedup-aware:
        chunks the destination already holds are skipped)."""
        import contextlib

        # push is inherently a write: URI destinations open writable, so
        # http(s) mirrors with conditional-put support accept the copy (a
        # genuinely readonly server still fails typed, per-request)
        dst = dest.backend if isinstance(dest, ArtifactStore) \
            else open_store(dest, writable=True)
        todo = list(keys) if keys is not None else self.keys()
        # a key counts as legacy only while it has no v3 manifest yet —
        # `migrate --keep-legacy` leaves the npz behind, and those entries
        # push fine through their manifest
        unmigrated = sorted(k for k in todo
                            if not self.backend.has_manifest(k)
                            and self._legacy_path(k) is not None)
        if unmigrated:
            raise ValueError(
                f"{len(unmigrated)} legacy .npz entries cannot be pushed "
                f"(e.g. {unmigrated[:3]}); run `artifacts migrate` first")
        stats = {"manifests": 0, "chunks_copied": 0, "chunks_skipped": 0,
                 "bytes_copied": 0}
        # bulk mode defers the mirror's per-write index.json rewrite (an
        # O(N²) directory rescan otherwise) to one update at the end
        bulk = getattr(dst, "bulk", None)
        with bulk() if bulk is not None else contextlib.nullcontext():
            for key in todo:
                manifest = self.backend.read_manifest(key)
                for d in dict.fromkeys(self._chunk_refs(manifest)):
                    if dst.has_chunk(d):
                        stats["chunks_skipped"] += 1
                        continue
                    data = self.backend.read_chunk(d)
                    dst.write_chunk(d, data)
                    stats["chunks_copied"] += 1
                    stats["bytes_copied"] += len(data)
                dst.write_manifest(key, manifest)
                stats["manifests"] += 1
        return stats

    def pull(self, src: "ArtifactStore | Store | str",
             keys: Sequence[str] | None = None) -> dict[str, int]:
        """Fetch manifests + missing chunks from another store into this
        one (the explicit bulk counterpart of the lazy ``remote=`` path)."""
        source = src if isinstance(src, ArtifactStore) \
            else ArtifactStore(backend=open_store(src))
        return source.push(self, keys=keys)

    # -- migration ----------------------------------------------------------
    def migrate(self, keys: Sequence[str] | None = None, *,
                delete_legacy: bool = True,
                persist_values: bool = True) -> dict[str, int]:
        """One-shot conversion of legacy monolithic ``.npz`` entries to the
        chunked v3 layout.  Values stored eagerly in the npz are carried
        into the chunk store (``persist_values=True``, the default) so
        offline checks keep replaying byte-identically; digests are derived
        from the stored buffers."""
        todo = list(keys) if keys is not None else self.legacy_keys()
        stats = {"migrated": 0, "skipped": 0}
        for key in todo:
            legacy = self._legacy_path(key)
            if legacy is None or self.backend.has_manifest(key):
                stats["skipped"] += 1
                continue
            art = CandidateArtifact.load(legacy)
            self.save(art, persist_values=persist_values)
            if delete_legacy:
                legacy.unlink(missing_ok=True)
            stats["migrated"] += 1
        return stats

    # -- reporting ----------------------------------------------------------
    def stats(self) -> dict[str, Any]:
        """Dedup / sketch-only accounting for ``artifacts stats`` and CI.

        ``monolithic_bytes`` reconstructs what the legacy one-npz-per-key
        layout would hold (per-entry metadata + every output and memoized
        value stored inline, duplicates and all); ``dedup_ratio`` divides it
        by the physical chunked footprint.
        """
        from repro.core.block_cache import (BLOCK_PREFIX, HLO_PREFIX,
                                            PROFILE_PREFIX, is_block_evidence)
        manifest_bytes = chunkrefs = 0
        logical_values = logical_outputs = meta_bytes = 0
        values_total = values_sketch_only = spectra_entries = 0
        n_manifests = n_audit = 0
        n_block = n_profile = n_hlo = 0
        evidence_bytes = 0
        for key in self.backend.manifest_keys():
            if is_block_evidence(key):
                if key.startswith(BLOCK_PREFIX):
                    n_block += 1
                elif key.startswith(PROFILE_PREFIX):
                    n_profile += 1
                elif key.startswith(HLO_PREFIX):
                    n_hlo += 1
                try:
                    evidence_bytes += self.backend.manifest_bytes(key)
                except (KeyError, OSError, StoreError):
                    pass
                continue
            if is_reserved_manifest(key):
                n_audit += 1
                continue
            try:
                manifest = self.backend.read_manifest(key)
                msize = self.backend.manifest_bytes(key)
            except (KeyError, OSError, StoreError):
                continue
            n_manifests += 1
            manifest_bytes += msize
            base = dict(manifest)
            base.pop("spectra", None)
            meta_bytes += len(json.dumps(base).encode())
            for rec in manifest["outputs"]:
                logical_outputs += int(rec["nbytes"])
            for rec in manifest["values"]:
                values_total += 1
                if rec.get("chunks"):
                    logical_values += int(rec["nbytes"])
                else:
                    values_sketch_only += 1
                    logical_values += int(rec["nbytes"])
            spectra_entries += len(manifest.get("spectra", ()))
            chunkrefs += len(set(self._chunk_refs(manifest)))
        chunk_count = 0
        chunk_bytes = 0
        for d in self.backend.chunk_keys():
            try:
                chunk_bytes += self.backend.chunk_bytes(d)
            except (KeyError, OSError, StoreError):
                continue
            chunk_count += 1
        legacy = self.legacy_keys()
        legacy_bytes = 0
        for key in legacy:
            p = self._legacy_path(key)
            if p is not None:
                try:
                    legacy_bytes += p.stat().st_size
                except OSError:
                    pass
        physical = manifest_bytes + chunk_bytes + legacy_bytes
        monolithic = meta_bytes + logical_outputs + logical_values \
            + legacy_bytes
        counters = self.backend.counters
        return {
            "schema_version": STORE_SCHEMA_VERSION,
            "artifacts": n_manifests,
            "audit_entries": n_audit,
            "block_entries": n_block,
            "profile_entries": n_profile,
            "hlo_entries": n_hlo,
            "block_evidence_manifest_bytes": evidence_bytes,
            "block_cache": {
                "block_hits": counters.get("block_hits", 0),
                "block_misses": counters.get("block_misses", 0),
                "profile_hits": counters.get("profile_hits", 0),
                "profile_misses": counters.get("profile_misses", 0)},
            "legacy_npz": len(legacy),
            "manifest_bytes": manifest_bytes,
            "chunk_count": chunk_count,
            "chunk_bytes": chunk_bytes,
            "physical_bytes": physical,
            "logical_value_bytes": logical_values,
            "logical_output_bytes": logical_outputs,
            "monolithic_bytes": monolithic,
            "dedup_ratio": (monolithic / physical) if physical else 0.0,
            "values_total": values_total,
            "values_sketch_only": values_sketch_only,
            "sketch_only_fraction": (values_sketch_only / values_total
                                     if values_total else 0.0),
            "spectra_entries": spectra_entries,
        }

    def entries(self) -> list[dict[str, Any]]:
        """Lightweight listing (name/key/backend/size) without value loads."""
        out = []
        for key in self.keys():
            try:
                if self.backend.has_manifest(key):
                    meta = self.backend.read_manifest(key)
                    size = self.entry_bytes(key)
                    cached = sum(1 for rec in meta["values"]
                                 if rec.get("chunks"))
                    sketch = sum(1 for rec in meta["values"]
                                 if not rec.get("chunks"))
                else:
                    path = self._legacy_path(key)
                    size = path.stat().st_size
                    with np.load(path, allow_pickle=False) as z:
                        meta = json.loads(z["meta"].tobytes().decode())
                    cached, sketch = len(meta["values"]), 0
                out.append({"key": key, "name": meta["name"],
                            "backend": meta["backend_label"],
                            "nodes": len(meta["graph"]["nodes"]),
                            "samples": len(meta["stats"]),
                            "cached_values": cached,
                            "sketch_only_values": sketch,
                            "bytes": size})
            except OSError:                  # deleted since keys() listed
                continue
            except Exception as e:           # corrupt entry: list, don't die
                out.append({"key": key, "name": f"<unreadable: {e}>",
                            "backend": "?", "nodes": 0, "samples": 0,
                            "cached_values": 0, "sketch_only_values": 0,
                            "bytes": 0})
        return out
