"""Magneton core: differential energy debugging for JAX programs."""

from repro.core.artifact import (ArtifactStore, ArtifactValueError,
                                 CandidateArtifact, artifact_key)
from repro.core.diff import DifferentialEnergyDebugger
from repro.core.energy import (AnalyticalBackend, AnalyticalEnergyModel,
                               EnergyBackend, EnergyProfile, HloCostBackend,
                               ReplayBackend, ReplayProfiler,
                               backend_from_name)
from repro.core.graph import OpGraph, extract_graph, trace
from repro.core.report import Finding, Report, render_rank_matrix
from repro.core.interp import capture_tensor_stats, capture_tensor_values
from repro.core.session import RankResult, Session
from repro.core.subgraph_match import MatchedRegion, match_subgraphs
from repro.core.tensor_match import (MatchStats, TensorMatcher, signature,
                                     signatures_match, stats_signature)

__all__ = [
    "DifferentialEnergyDebugger",
    "Session",
    "RankResult",
    "CandidateArtifact",
    "ArtifactStore",
    "ArtifactValueError",
    "artifact_key",
    "EnergyBackend",
    "AnalyticalBackend",
    "ReplayBackend",
    "HloCostBackend",
    "backend_from_name",
    "AnalyticalEnergyModel",
    "ReplayProfiler",
    "EnergyProfile",
    "OpGraph",
    "extract_graph",
    "trace",
    "Finding",
    "Report",
    "render_rank_matrix",
    "MatchedRegion",
    "match_subgraphs",
    "TensorMatcher",
    "MatchStats",
    "signature",
    "signatures_match",
    "stats_signature",
    "capture_tensor_stats",
    "capture_tensor_values",
]
