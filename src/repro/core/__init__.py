"""Magneton core: differential energy debugging for JAX programs."""

from repro.core.diff import DifferentialEnergyDebugger
from repro.core.energy import AnalyticalEnergyModel, EnergyProfile, ReplayProfiler
from repro.core.graph import OpGraph, extract_graph, trace
from repro.core.report import Finding, Report
from repro.core.interp import capture_tensor_stats, capture_tensor_values
from repro.core.subgraph_match import MatchedRegion, match_subgraphs
from repro.core.tensor_match import (MatchStats, TensorMatcher, signature,
                                     signatures_match, stats_signature)

__all__ = [
    "DifferentialEnergyDebugger",
    "AnalyticalEnergyModel",
    "ReplayProfiler",
    "EnergyProfile",
    "OpGraph",
    "extract_graph",
    "trace",
    "Finding",
    "Report",
    "MatchedRegion",
    "match_subgraphs",
    "TensorMatcher",
    "MatchStats",
    "signature",
    "signatures_match",
    "stats_signature",
    "capture_tensor_stats",
    "capture_tensor_values",
]
