"""Per-op cost attribution from compiled XLA artifacts.

Two layers:

* **Module totals** (``extract_costs``): ``compiled.cost_analysis()`` reports
  FLOPs/bytes of the per-device module but does NOT multiply while-loop
  (lax.scan) bodies by their trip count — verified empirically (a scanned
  72-layer stack reports ~72x fewer FLOPs than the same stack unrolled).
  The dry-run therefore uses *segmented* analysis (compile one superblock +
  the ends separately and scale by depth, launch/dryrun.py) with the
  full-program numbers kept as a cross-check.  Collective bytes are not in
  cost_analysis at all: we parse the post-optimization HLO text and sum the
  result-shape bytes of every all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute, pricing rings as: ag/rs/a2a ~ 1x result
  bytes, ar ~ 2x.

* **Per-op attribution** (``per_op_costs``): the jaxpr is replayed with every
  equation bound under a ``magop<idx>`` name scope (idx = the equation's
  OpGraph node index), jitted, and compiled.  XLA threads the name stack into
  every HLO instruction's ``metadata={op_name=...}`` — *including*
  instructions inside fused computations and while bodies — so walking the
  optimized module instruction-by-instruction recovers a true per-operator
  cost breakdown:

  - each instruction's FLOPs / transcendentals / bytes are computed from its
    opcode and printed operand/result shapes and credited to the jaxpr
    equation named in its metadata;
  - a fusion's HBM traffic is its operands + results (interior values never
    touch HBM); when the fusion merges instructions from several equations
    the traffic is split proportionally over those equations' interior
    footprints — the *only* place a proportional split happens;
  - while bodies are multiplied by XLA's ``known_trip_count`` (fixing the
    cost_analysis scan undercount), and collectives inside them are credited
    to the owning scan equation per iteration;
  - opcodes whose cost the HLO text does not expose (custom-call — Pallas
    interpret callbacks, TopK, FFT —, convolution, sort, conditional) fall
    back to the *analytic* rule for the equation they map to;
  - XLA-introduced instructions with no provenance (tuple plumbing copies,
    layout ops) land in a residual bucket that is distributed proportionally
    over the attributed columns.

  shard_map bodies cannot be replayed equation-by-equation outside their mesh
  context, so the whole region is bound under a ``maggrp<i>_<j>`` scope and
  its costs are split over nodes ``i..j`` by analytic weight (the same
  merged-fusion fallback).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Sequence

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_numel(dims: str) -> int:
    if not dims:
        return 1
    return int(np.prod([int(d) for d in dims.split(",") if d],
                       dtype=np.int64))


def _shape_bytes(segment: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(segment):
        if dt not in _DTYPE_BYTES:
            continue
        total += _shape_numel(dims) * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict[str, int]
    result_bytes: dict[str, float]

    @property
    def total_traffic_bytes(self) -> float:
        """ICI traffic estimate: all-reduce rings move ~2x the data."""
        t = 0.0
        for kind, b in self.result_bytes.items():
            t += b * (2.0 if kind == "all-reduce" else 1.0)
        return t


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict[str, int] = {}
    bytes_: dict[str, float] = {}
    for line in hlo_text.splitlines():
        for kind in _COLLECTIVES:
            # match the op invocation, not metadata mentions
            marker = f" {kind}("
            marker2 = f" {kind}-start("
            if marker not in line and marker2 not in line:
                continue
            if "=" not in line:
                continue
            result_part = line.split("=", 1)[1]
            result_part = result_part.split(kind, 1)[0]
            b = _shape_bytes(result_part)
            counts[kind] = counts.get(kind, 0) + 1
            bytes_[kind] = bytes_.get(kind, 0.0) + b
            break
    return CollectiveStats(counts=counts, result_bytes=bytes_)


@dataclasses.dataclass
class CompiledCosts:
    flops: float                 # per-device, loop bodies counted once
    bytes_accessed: float
    argument_bytes: float
    output_bytes: float
    temp_bytes: float
    peak_bytes: float
    collectives: CollectiveStats

    def as_dict(self) -> dict[str, Any]:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "argument_bytes": self.argument_bytes,
            "output_bytes": self.output_bytes,
            "temp_bytes": self.temp_bytes,
            "peak_bytes": self.peak_bytes,
            "collective_counts": self.collectives.counts,
            "collective_result_bytes": self.collectives.result_bytes,
            "collective_traffic_bytes": self.collectives.total_traffic_bytes,
        }


def extract_costs(compiled) -> CompiledCosts:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # jaxlib < 0.5: one dict per program
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    text = compiled.as_text()
    colls = parse_collectives(text)
    return CompiledCosts(
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        argument_bytes=float(getattr(ma, "argument_size_in_bytes", 0)),
        output_bytes=float(getattr(ma, "output_size_in_bytes", 0)),
        temp_bytes=float(getattr(ma, "temp_size_in_bytes", 0)),
        peak_bytes=float(getattr(ma, "argument_size_in_bytes", 0)
                         + getattr(ma, "output_size_in_bytes", 0)
                         + getattr(ma, "temp_size_in_bytes", 0)),
        collectives=colls,
    )


# ---------------------------------------------------------------------------
# annotated lowering: thread jaxpr eqn ids through to HLO metadata
# ---------------------------------------------------------------------------

_TAG_RE = re.compile(r"magop(\d+)")
_GRP_RE = re.compile(r"maggrp(\d+)_(\d+)")


def _bind(eqn, invals):
    subfuns, bind_params = eqn.primitive.get_bind_params(eqn.params)
    out = eqn.primitive.bind(*subfuns, *invals, **bind_params)
    return out if eqn.primitive.multiple_results else [out]


def _count_nodes(closed) -> int:
    """Node count of a (closed) jaxpr under graph.py's flattening rules."""
    from repro.core.graph import _INLINE_PRIMITIVES, _nested_jaxpr
    n = 0
    for eqn in closed.jaxpr.eqns:
        inner = _nested_jaxpr(eqn)
        if inner is not None and eqn.primitive.name in _INLINE_PRIMITIVES:
            n += _count_nodes(inner)
        else:
            n += 1
    return n


def annotated_fn(graph):
    """Replay ``graph.closed_jaxpr`` with each equation bound under a
    ``magop<idx>`` name scope, idx matching ``graph.nodes`` order.

    The walk mirrors :func:`repro.core.graph.extract_graph` exactly (same
    inline set, same DFS order), so the scope index IS the OpGraph node
    index.  shard_map regions are bound whole under a ``maggrp<i>_<j>``
    span scope (their bodies need the mesh context to re-bind)."""
    import jax
    from jax._src.core import Literal

    from repro.core.graph import _INLINE_PRIMITIVES, _nested_jaxpr

    closed = graph.closed_jaxpr
    if closed is None:
        raise ValueError("annotated lowering needs a live graph "
                         "(with a ClosedJaxpr)")

    def run(jaxpr, consts, invals, counter):
        env: dict[Any, Any] = {}

        def read(v):
            return v.val if isinstance(v, Literal) else env[v]

        def write(v, val):
            if type(v).__name__ != "DropVar":
                env[v] = val

        for cv, cval in zip(jaxpr.constvars, consts):
            env[cv] = cval
        for iv, val in zip(jaxpr.invars, invals):
            env[iv] = val
        for eqn in jaxpr.eqns:
            inner = _nested_jaxpr(eqn)
            if inner is not None and eqn.primitive.name in _INLINE_PRIMITIVES:
                if eqn.primitive.name == "shard_map":
                    start = counter[0]
                    end = start + _count_nodes(inner) - 1
                    with jax.named_scope(f"maggrp{start}_{end}"):
                        out = _bind(eqn, [read(v) for v in eqn.invars])
                    counter[0] = end + 1
                    for v, val in zip(eqn.outvars, out):
                        write(v, val)
                    continue
                sub_out = run(inner.jaxpr, inner.consts,
                              [read(v) for v in eqn.invars], counter)
                for ov, val in zip(eqn.outvars, sub_out):
                    write(ov, val)
                continue
            idx = counter[0]
            counter[0] += 1
            with jax.named_scope(f"magop{idx}"):
                out = _bind(eqn, [read(v) for v in eqn.invars])
            for v, val in zip(eqn.outvars, out):
                write(v, val)
        return [read(v) for v in jaxpr.outvars]

    # invariant vs the ACTUAL extraction, not our own count: a walk that
    # diverges from extract_graph must fail loudly, never smear attribution
    expected = len(graph.nodes)

    def fn(*flat_args):
        counter = [0]
        out = run(closed.jaxpr, closed.consts, list(flat_args), counter)
        if counter[0] != expected:
            raise AssertionError(
                f"annotated replay emitted {counter[0]} node scopes but the "
                f"graph flattening has {expected} nodes — annotated_fn's "
                "walk diverged from extract_graph; fix the inline rules "
                "before trusting any attribution")
        return out

    return fn


def annotated_compile(graph, args: Sequence[Any] = ()):
    """Lower + compile the graph's jaxpr with eqn-id metadata preserved."""
    import jax
    flat = jax.tree_util.tree_leaves(tuple(args))
    return jax.jit(annotated_fn(graph)).lower(*flat).compile()


# ---------------------------------------------------------------------------
# optimized-HLO text parsing
# ---------------------------------------------------------------------------

_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(.*\{\s*$")
_INSTR_RE = re.compile(r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s+=\s+(.*)$")


@dataclasses.dataclass
class HloInstruction:
    name: str
    opcode: str
    line: str                                   # full raw text (attrs)
    shapes_out: list[tuple[str, int]]           # (dtype, numel)
    shapes_in: list[tuple[str, int]]
    op_name: str
    trip: int | None

    @property
    def result_numel(self) -> float:
        return float(sum(n for _, n in self.shapes_out))

    @property
    def result_bytes(self) -> float:
        return float(sum(n * _DTYPE_BYTES.get(dt, 4)
                         for dt, n in self.shapes_out))

    @property
    def operand_bytes(self) -> float:
        return float(sum(n * _DTYPE_BYTES.get(dt, 4)
                         for dt, n in self.shapes_in))


def _shapes(segment: str) -> list[tuple[str, int]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(segment):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, _shape_numel(dims)))
    return out


def _parse_instruction(line: str) -> HloInstruction | None:
    m = _INSTR_RE.match(line)
    if m is None:
        return None
    name, rhs = m.groups()
    if rhs.startswith("("):                      # tuple-typed result
        depth = 0
        i = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        type_str, rest = rhs[:i + 1], rhs[i + 1:].lstrip()
    else:
        parts = rhs.split(" ", 1)
        if len(parts) != 2:
            return None
        type_str, rest = parts
    p = rest.find("(")
    if p < 0:
        return None
    opcode = rest[:p].strip()
    depth = 0
    end = p
    for j in range(p, len(rest)):
        if rest[j] == "(":
            depth += 1
        elif rest[j] == ")":
            depth -= 1
            if depth == 0:
                end = j
                break
    operands = rest[p + 1:end]
    mm = re.search(r"op_name=\"([^\"]*)\"", rest)
    mt = re.search(r"\"known_trip_count\":\{\"n\":\"(\d+)\"\}", rest)
    return HloInstruction(
        name=name, opcode=opcode, line=rest,
        shapes_out=_shapes(type_str), shapes_in=_shapes(operands),
        op_name=mm.group(1) if mm else "",
        trip=int(mt.group(1)) if mt else None)


def parse_hlo_module(text: str
                     ) -> tuple[str | None, dict[str, list[HloInstruction]]]:
    """Split optimized HLO text into computations; returns (entry, comps)."""
    comps: dict[str, list[HloInstruction]] = {}
    entry: str | None = None
    cur: list[HloInstruction] | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith((" ", "\t")):
            m = _COMP_HEADER_RE.match(line)
            if m is not None:
                cur = comps.setdefault(m.group(2), [])
                if m.group(1):
                    entry = m.group(2)
            elif line.startswith("}"):
                cur = None
            continue
        if line.lstrip().startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        ins = _parse_instruction(line)
        if ins is not None:
            cur.append(ins)
    return entry, comps


# ---------------------------------------------------------------------------
# per-instruction cost rules
# ---------------------------------------------------------------------------

_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id", "domain",
    "get-dimension-size", "opt-barrier", "optimization-barrier",
    # async completion halves: the paired -start op carries the full cost
    "all-reduce-done", "all-gather-done", "reduce-scatter-done",
    "all-to-all-done", "collective-permute-done", "copy-done", "send-done",
    "recv-done", "async-done", "async-update",
}
_TRANS_OPS = {
    "exponential", "exponential-minus-one", "log", "log-plus-one",
    "logistic", "tanh", "sine", "cosine", "tan", "power", "sqrt", "rsqrt",
    "cbrt", "erf", "erf-inv", "erfc", "atan2", "expm1",
}
# HLO opcodes whose true cost the text does not expose; the equation they
# attribute to falls back to its analytic operator rule (costs.py).
_OPAQUE_OPS = {
    "custom-call", "convolution", "conditional", "sort", "rng",
    "rng-bit-generator", "rng-get-and-update-state", "fft", "map",
    "triangular-solve", "cholesky", "infeed", "outfeed", "select-and-scatter",
}
_COLLECTIVE_OPS = {k: (2.0 if k == "all-reduce" else 1.0)
                   for k in _COLLECTIVES}
_COLLECTIVE_OPS.update({f"{k}-start": v for k, v in
                        list(_COLLECTIVE_OPS.items())})


def _dot_flops(ins: HloInstruction) -> float:
    mm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
    cdims = [int(d) for d in mm.group(1).split(",") if d] if mm else []
    # lhs shape: first operand shape inside the operand parens
    lhs_shape: tuple[int, ...] = ()
    sm = _SHAPE_RE.search(ins.line[ins.line.find("(") + 1:])
    if sm is not None:
        lhs_shape = tuple(int(d) for d in sm.group(2).split(",") if d)
    k = 1
    for d in cdims:
        if d < len(lhs_shape):
            k *= lhs_shape[d]
    return 2.0 * ins.result_numel * float(max(k, 1))


def _instr_cost(ins: HloInstruction) -> tuple[float, float, float, float]:
    """(flops, transcendentals, hbm_bytes, ici_bytes) of one instruction."""
    op = ins.opcode
    out_n = ins.result_numel
    io = ins.operand_bytes + ins.result_bytes
    if op == "dot":
        return _dot_flops(ins), 0.0, io, 0.0
    if op in _TRANS_OPS:
        # transcendental ≈ 4 VPU flops/elem (matches costs.py's weighting)
        return 4.0 * out_n, out_n, io, 0.0
    if op in _COLLECTIVE_OPS:
        return 0.0, 0.0, io, ins.result_bytes * _COLLECTIVE_OPS[op]
    if op in ("copy", "copy-start"):
        return 0.0, 0.0, 2.0 * ins.result_bytes, 0.0
    if op == "dynamic-update-slice":
        # in-place window update: read+write the update window only; any
        # buffer duplication XLA inserts shows up as explicit copy instrs
        upd = (ins.shapes_in[1] if len(ins.shapes_in) > 1
               else (ins.shapes_out[0] if ins.shapes_out else ("f32", 0)))
        return 0.0, 0.0, 2.0 * upd[1] * _DTYPE_BYTES.get(upd[0], 4), 0.0
    if op == "dynamic-slice":
        return 0.0, 0.0, 2.0 * ins.result_bytes, 0.0
    if op == "gather":
        idx_b = (ins.shapes_in[1][1] * _DTYPE_BYTES.get(ins.shapes_in[1][0], 4)
                 if len(ins.shapes_in) > 1 else 0.0)
        return 0.0, 0.0, 2.0 * ins.result_bytes + idx_b, 0.0
    if op == "scatter":
        upd = ins.shapes_in[-1] if ins.shapes_in else ("f32", 0)
        b = upd[1] * _DTYPE_BYTES.get(upd[0], 4)
        return float(upd[1]), 0.0, 3.0 * b, 0.0
    if op in ("reduce", "reduce-window"):
        return float(sum(n for _, n in ins.shapes_in)), 0.0, io, 0.0
    if op in ("broadcast", "iota"):
        return 0.0, 0.0, io, 0.0
    if op in ("reshape", "transpose", "slice", "concatenate", "pad",
              "reverse", "reduce-precision"):
        return 0.0, 0.0, io, 0.0
    if op == "while":                            # handled by the walker
        return 0.0, 0.0, 0.0, 0.0
    # default: cheap elementwise (add/multiply/compare/select/convert/...)
    return out_n, 0.0, io, 0.0


# ---------------------------------------------------------------------------
# attribution
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PerOpCosts:
    """Per-OpGraph-node costs attributed from the compiled module."""

    flops: np.ndarray
    hbm_bytes: np.ndarray
    ici_bytes: np.ndarray
    transcendentals: np.ndarray
    fp32_fraction: np.ndarray
    module: dict[str, Any]          # compiled module totals (cross-check)
    attribution: dict[str, Any]     # direct/group/residual diagnostics

    @property
    def num_nodes(self) -> int:
        return int(self.flops.shape[0])

    def as_dict(self) -> dict[str, Any]:
        return {
            "flops": [float(x) for x in self.flops],
            "hbm_bytes": [float(x) for x in self.hbm_bytes],
            "ici_bytes": [float(x) for x in self.ici_bytes],
            "transcendentals": [float(x) for x in self.transcendentals],
            "fp32_fraction": [float(x) for x in self.fp32_fraction],
            "module": self.module,
            "attribution": self.attribution,
        }

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "PerOpCosts":
        return cls(
            flops=np.asarray(d["flops"], dtype=np.float64),
            hbm_bytes=np.asarray(d["hbm_bytes"], dtype=np.float64),
            ici_bytes=np.asarray(d["ici_bytes"], dtype=np.float64),
            transcendentals=np.asarray(d["transcendentals"],
                                       dtype=np.float64),
            fp32_fraction=np.asarray(d["fp32_fraction"], dtype=np.float64),
            module=dict(d.get("module", {})),
            attribution=dict(d.get("attribution", {})))

    def attribution_summary(self) -> dict[str, Any]:
        """Attribution-quality diagnostics for monitoring (CLI ``capture``).

        * ``residual_flop_fraction`` / ``residual_byte_fraction`` — share of
          the attributed column that came from provenance-free (XLA-
          introduced) instructions and was redistributed proportionally; a
          rising residual means the metadata op_name chain is degrading.
        * ``direct_fraction`` — instructions credited straight to their
          originating equation (neither grouped nor residual).
        * ``opaque_nodes`` — nodes priced by their analytic rule because the
          HLO text hides or distorts their cost (custom-call / conv /
          pallas emulation).
        * ``fusion_splits`` — fusions whose HBM traffic was split over
          several genuinely-merged equations (proportional attribution at
          work; artifacts recorded before this counter report 0).
        """
        att = self.attribution or {}
        mod = self.module or {}
        a_flops = float(mod.get("attributed_flops", 0.0))
        a_bytes = float(mod.get("attributed_bytes", 0.0))
        instrs = int(att.get("instructions", 0))
        return {
            "residual_flop_fraction":
                float(att.get("residual_flops", 0.0)) / a_flops
                if a_flops > 0 else 0.0,
            "residual_byte_fraction":
                float(att.get("residual_bytes", 0.0)) / a_bytes
                if a_bytes > 0 else 0.0,
            "direct_fraction":
                int(att.get("direct", 0)) / instrs if instrs else 0.0,
            "opaque_nodes": int(att.get("opaque_nodes", 0)),
            "fusion_splits": int(att.get("fusion_splits", 0)),
            "instructions": instrs,
        }


_COLUMNS = ("flops", "hbm", "ici", "trans")


def _target(op_name: str):
    m = _TAG_RE.search(op_name or "")
    if m is not None:
        return ("node", int(m.group(1)))
    g = _GRP_RE.search(op_name or "")
    if g is not None:
        return ("group", (int(g.group(1)), int(g.group(2))))
    return ("residual", None)


def attribute_costs(graph, compiled) -> PerOpCosts:
    """Walk the compiled module and credit per-instruction costs to the
    OpGraph nodes named in the instruction metadata."""
    from repro.core import costs as costs_mod

    n = len(graph.nodes)
    analytic = [costs_mod.node_cost(graph, nd) for nd in graph.nodes]
    a_cols = {
        "flops": np.array([c.flops for c in analytic], dtype=np.float64),
        "hbm": np.array([c.hbm_bytes for c in analytic], dtype=np.float64),
        "ici": np.array([c.ici_bytes for c in analytic], dtype=np.float64),
        "trans": np.zeros(n),
    }
    text = compiled.as_text()
    entry, comps = parse_hlo_module(text)

    cols = {k: np.zeros(n) for k in _COLUMNS}
    groups: dict[tuple[int, int], dict[str, float]] = {}
    residual = dict.fromkeys(_COLUMNS, 0.0)
    # pallas_call is opaque by construction: on this container it lowers in
    # interpret mode (loops + dynamic slices emulating the kernel), whose
    # instruction stream is an artifact of emulation, not the fused kernel's
    # real traffic — its analytic single-HBM-pass rule is the honest price
    opaque: set[int] = {i for i, nd in enumerate(graph.nodes)
                        if nd.primitive == "pallas_call"}
    stats = {"instructions": 0, "direct": 0, "grouped": 0,
             "residual_instrs": 0, "opaque_nodes": 0, "fusion_splits": 0}

    def add(tgt, kind: str, amount: float) -> None:
        if amount <= 0.0:
            return
        if tgt[0] == "node":
            if not 0 <= tgt[1] < n:
                raise AssertionError(
                    f"instruction attributed to node {tgt[1]} but the graph "
                    f"has {n} nodes — annotated_fn's walk diverged from "
                    "extract_graph")
            cols[kind][tgt[1]] += amount
        elif tgt[0] == "group":
            groups.setdefault(tgt[1], dict.fromkeys(_COLUMNS, 0.0))
            groups[tgt[1]][kind] += amount
        else:
            residual[kind] += amount

    def _called(ins: HloInstruction) -> list[str]:
        if ins.opcode == "fusion":
            m = re.search(r"calls=%([\w.\-]+)", ins.line)
            return [m.group(1)] if m else []
        if ins.opcode == "while":
            out = []
            for key in ("body", "condition"):
                m = re.search(key + r"=%([\w.\-]+)", ins.line)
                if m:
                    out.append(m.group(1))
            return out
        if ins.opcode == "call":
            m = re.search(r"to_apply=%([\w.\-]+)", ins.line)
            return [m.group(1)] if m else []
        return []

    def walk(comp: str, mult: float,
             fusion_weights: dict | None = None) -> None:
        for ins in comps.get(comp, ()):
            op = ins.opcode
            if op in _FREE_OPS:
                continue
            stats["instructions"] += 1
            tgt = _target(ins.op_name)
            if tgt[0] == "node":
                stats["direct"] += 1
            elif tgt[0] == "group":
                stats["grouped"] += 1
            else:
                stats["residual_instrs"] += 1
            if op == "fusion":
                called = _called(ins)
                weights: dict = {}
                if called:
                    walk(called[0], mult, weights)
                fus_bytes = (ins.operand_bytes + ins.result_bytes) * mult
                total_w = sum(weights.values())
                if total_w > 0:
                    # genuinely merged constituents: proportional split
                    # over each equation's interior footprint
                    if len(weights) > 1:
                        stats["fusion_splits"] += 1
                    for t2, w in weights.items():
                        add(t2, "hbm", fus_bytes * w / total_w)
                else:
                    add(tgt, "hbm", fus_bytes)
                continue
            if op == "while":
                trips = float(ins.trip or 1)
                for c in _called(ins):
                    walk(c, mult * trips, fusion_weights)
                continue
            if op == "call":
                for c in _called(ins):
                    walk(c, mult, fusion_weights)
                continue
            if op in _OPAQUE_OPS:
                if tgt[0] == "node" and 0 <= tgt[1] < n:
                    opaque.add(tgt[1])
                elif tgt[0] == "group":
                    opaque.update(i for i in range(tgt[1][0], tgt[1][1] + 1)
                                  if i < n)
                continue
            flops, trans, hbm, ici = _instr_cost(ins)
            add(tgt, "flops", flops * mult)
            add(tgt, "trans", trans * mult)
            add(tgt, "ici", ici * mult)
            if fusion_weights is not None:
                # interior of a fusion: no HBM traffic, but remember each
                # equation's footprint as its share of the fusion's traffic
                key = tgt if tgt[0] != "residual" else ("residual", None)
                fusion_weights[key] = (fusion_weights.get(key, 0.0)
                                       + max(hbm, ins.result_bytes, 1.0))
            else:
                add(tgt, "hbm", hbm * mult)

    if entry is not None:
        walk(entry, 1.0)

    # shard_map group spans: split by analytic weight over the members
    for (g0, g1), kinds in groups.items():
        idxs = [i for i in range(g0, g1 + 1) if i < n]
        if not idxs:
            continue
        for kind, amount in kinds.items():
            if amount <= 0:
                continue
            w = a_cols[kind][idxs] if kind != "trans" else a_cols["flops"][idxs]
            w = np.asarray(w, dtype=np.float64)
            if w.sum() <= 0:
                w = np.ones(len(idxs))
            cols[kind][idxs] += amount * w / w.sum()

    # opaque nodes (custom-call / convolution / pallas emulation / ...):
    # the HLO text hides or distorts their cost; use the analytic rule.
    # Applied BEFORE the residual distribution so emulation-inflated
    # accumulations cannot skew the residual weights.
    for i in opaque:
        cols["flops"][i] = a_cols["flops"][i]
        cols["hbm"][i] = a_cols["hbm"][i]
        cols["ici"][i] = a_cols["ici"][i]
        cols["trans"][i] = 0.0
    stats["opaque_nodes"] = len(opaque)

    # residual (XLA-introduced, provenance-free instructions): distribute
    # proportionally over the attributed column, falling back to the
    # analytic column when nothing was attributed at all.  Opaque nodes are
    # excluded: their analytically-priced cost must not be re-inflated by
    # the plumbing of their own emulation (pallas interpret mode), so when
    # every node is opaque the residual is dropped (recorded in stats).
    opaque_idx = sorted(opaque)
    for kind, amount in residual.items():
        if amount <= 0:
            continue
        w = cols[kind].copy()
        w[opaque_idx] = 0.0
        if w.sum() <= 0:
            w = (a_cols[kind] if kind != "trans" else a_cols["flops"]).copy()
            w[opaque_idx] = 0.0
        if w.sum() <= 0:
            stats[f"dropped_residual_{kind}"] = float(amount)
            continue
        cols[kind] += amount * w / w.sum()

    cc = extract_costs(compiled)
    module = cc.as_dict()
    module["attributed_flops"] = float(cols["flops"].sum())
    module["attributed_bytes"] = float(cols["hbm"].sum())
    module["attributed_ici_bytes"] = float(cols["ici"].sum())
    stats["residual_flops"] = float(residual["flops"])
    stats["residual_bytes"] = float(residual["hbm"])

    return PerOpCosts(
        flops=cols["flops"], hbm_bytes=cols["hbm"], ici_bytes=cols["ici"],
        transcendentals=cols["trans"],
        fp32_fraction=np.array([c.fp32_fraction for c in analytic],
                               dtype=np.float64),
        module=module, attribution=stats)


# annotated_compile + attribute_costs memo, keyed by (jaxpr fingerprint,
# const value digests, input avals) — a rewrite candidate sharing its
# target's program text reuses the compile + walk outright, and repeated
# pricing of the same graph (optimize's verify loop, recurring serving
# audits) is free.  Bounded FIFO: compiled-module attributions are a few
# hundred KB each and an unbounded process-wide dict would leak across
# long sweeps.  Results are treated as immutable by every consumer
# (EnergyProfile.hlo holds the same instance).
_PER_OP_MEMO: "dict[str, PerOpCosts]" = {}
_PER_OP_MEMO_MAX = 16
PER_OP_MEMO_COUNTERS = {"hits": 0, "misses": 0}


def _per_op_memo_key(graph, args) -> str:
    import hashlib

    import jax

    from repro.core.graph import _jaxpr_fingerprint, _value_digest
    closed = graph.closed_jaxpr
    h = hashlib.sha256()
    h.update(_jaxpr_fingerprint(closed.jaxpr, tuple(closed.consts),
                                {}).encode())
    for t in sorted((graph._const_vals or {})):
        h.update(_value_digest(graph._const_vals[t]).encode())
    for a in jax.tree_util.tree_leaves(args):
        arr = np.asarray(a)
        h.update(f"{arr.dtype}:{arr.shape}\x00".encode())
    return h.hexdigest()


def per_op_costs(graph, args: Sequence[Any] = (), *,
                 memo: bool = True) -> PerOpCosts:
    """Compile the graph with eqn-id metadata and attribute per-op costs
    (memoized per content digest — see ``_PER_OP_MEMO``)."""
    key = _per_op_memo_key(graph, args) if memo else None
    if key is not None:
        hit = _PER_OP_MEMO.get(key)
        if hit is not None:
            PER_OP_MEMO_COUNTERS["hits"] += 1
            return hit
        PER_OP_MEMO_COUNTERS["misses"] += 1
    compiled = annotated_compile(graph, args)
    poc = attribute_costs(graph, compiled)
    if key is not None:
        while len(_PER_OP_MEMO) >= _PER_OP_MEMO_MAX:
            _PER_OP_MEMO.pop(next(iter(_PER_OP_MEMO)))
        _PER_OP_MEMO[key] = poc
    return poc
