"""Cost extraction from compiled XLA artifacts.

``compiled.cost_analysis()`` reports FLOPs/bytes of the per-device module but
does NOT multiply while-loop (lax.scan) bodies by their trip count — verified
empirically (a scanned 72-layer stack reports ~72x fewer FLOPs than the same
stack unrolled).  The dry-run therefore uses *segmented* analysis (compile
one superblock + the ends separately and scale by depth, launch/dryrun.py)
with the full-program numbers kept as a cross-check.

Collective bytes are not in cost_analysis at all: we parse the
post-optimization HLO text and sum the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
pricing rings as: ag/rs/a2a ~ 1x result bytes, ar ~ 2x.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(segment: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(segment):
        if dt not in _DTYPE_BYTES:
            continue
        numel = 1
        if dims:
            numel = int(np.prod([int(d) for d in dims.split(",") if d],
                                dtype=np.int64))
        total += numel * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict[str, int]
    result_bytes: dict[str, float]

    @property
    def total_traffic_bytes(self) -> float:
        """ICI traffic estimate: all-reduce rings move ~2x the data."""
        t = 0.0
        for kind, b in self.result_bytes.items():
            t += b * (2.0 if kind == "all-reduce" else 1.0)
        return t


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict[str, int] = {}
    bytes_: dict[str, float] = {}
    for line in hlo_text.splitlines():
        for kind in _COLLECTIVES:
            # match the op invocation, not metadata mentions
            marker = f" {kind}("
            marker2 = f" {kind}-start("
            if marker not in line and marker2 not in line:
                continue
            if "=" not in line:
                continue
            result_part = line.split("=", 1)[1]
            result_part = result_part.split(kind, 1)[0]
            b = _shape_bytes(result_part)
            counts[kind] = counts.get(kind, 0) + 1
            bytes_[kind] = bytes_.get(kind, 0.0) + b
            break
    return CollectiveStats(counts=counts, result_bytes=bytes_)


@dataclasses.dataclass
class CompiledCosts:
    flops: float                 # per-device, loop bodies counted once
    bytes_accessed: float
    argument_bytes: float
    output_bytes: float
    temp_bytes: float
    peak_bytes: float
    collectives: CollectiveStats

    def as_dict(self) -> dict[str, Any]:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "argument_bytes": self.argument_bytes,
            "output_bytes": self.output_bytes,
            "temp_bytes": self.temp_bytes,
            "peak_bytes": self.peak_bytes,
            "collective_counts": self.collectives.counts,
            "collective_result_bytes": self.collectives.result_bytes,
            "collective_traffic_bytes": self.collectives.total_traffic_bytes,
        }


def extract_costs(compiled) -> CompiledCosts:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # jaxlib < 0.5: one dict per program
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    text = compiled.as_text()
    colls = parse_collectives(text)
    return CompiledCosts(
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        argument_bytes=float(getattr(ma, "argument_size_in_bytes", 0)),
        output_bytes=float(getattr(ma, "output_size_in_bytes", 0)),
        temp_bytes=float(getattr(ma, "temp_size_in_bytes", 0)),
        peak_bytes=float(getattr(ma, "argument_size_in_bytes", 0)
                         + getattr(ma, "output_size_in_bytes", 0)
                         + getattr(ma, "temp_size_in_bytes", 0)),
        collectives=colls,
    )
