"""Block-granular incremental capture/pricing cache (store schema v4).

PR 9's ``block_structure`` digests prove that two blocks with equal
structural digests and bitwise-identical external inputs produce
bitwise-identical outputs (the twin-propagation invariant).  This module
turns that proof into *reuse*: every fused-block dispatch of the
instrumented interpreter (interp.py) is keyed by

    sha256("blockev4" || family digest || period || ext-out structure
           || ordered external-input value digests)

and its evidence — the five streamed invariants of every block tensor plus
the raw bytes of every externally-consumed output — is persisted as a
first-class content-addressed entry next to the artifact manifests
(``block--<hash>`` manifest + sha256 chunks).  A warm capture of a rewrite
candidate that differs from an already-captured model in one layer then
replays exactly that layer: every other block's key hits, its stats are
spliced verbatim and its external outputs are rematerialized from chunks,
so downstream blocks see bitwise-identical inputs and chain-hit in turn.

Keying on external-input VALUE digests is deliberately stronger than the
"input avals + sample seeds" a whole-graph key would use: a mid-graph
block's inputs depend on everything upstream, so value digests are the
only key that keeps reuse byte-identical by construction — a mutated
block changes its own key (different family digest) and, if its outputs
change, every downstream key too; if its rewrite is bitwise-preserving,
downstream blocks keep hitting (same re-seeding discipline as PR 9's
``resolve_pending``).

Digests chain without re-hashing: on a hit the cached entry's output
digests seed the run-local digest memo; on a miss the freshly computed
bytes are hashed once.  Only graph inputs and consts are ever hashed
outside that chain (consts via ``BlockStructure.const_digest``, memoized).

``profile--`` entries give the same treatment to whole-graph energy
pricing: a deterministic backend's EnergyProfile (including per-op HLO
costs) is keyed by (jaxpr fingerprint, const value digests, input avals,
backend id) and replayed from the store instead of re-profiled.

Schema v4 = v3 artifact manifests + these sibling entries; a v3 store
reads back unchanged (entries are additive), and every entry is advisory
cache state — deleting one merely makes the next capture cold.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any

import numpy as np

from repro.core.store import (Store, StoreError, chunk_digest, split_chunks)

BLOCK_SCHEMA_VERSION = 4

BLOCK_PREFIX = "block--"
PROFILE_PREFIX = "profile--"
HLO_PREFIX = "hlo--"
EVIDENCE_PREFIXES = (BLOCK_PREFIX, PROFILE_PREFIX, HLO_PREFIX)

# store errors that demote a cache probe to a miss / skip a write — the
# cache must never fail a capture that would succeed cold
_SOFT_ERRORS = (StoreError, KeyError, OSError, ValueError)


def is_block_evidence(key: str) -> bool:
    """True for block-evidence manifest keys (schema v4 cache entries)."""
    return key.startswith(EVIDENCE_PREFIXES)


def _fresh_block_counters() -> dict[str, int]:
    return {"block_hits": 0, "block_misses": 0,
            "profile_hits": 0, "profile_misses": 0,
            "block_errors": 0}


def format_value_digest(dtype: str, shape, sha: str) -> str:
    """The graph._value_digest format for a value known only by metadata."""
    return f"{dtype}:{tuple(shape)}:{sha}"


def block_entry_key(fam_digest: str, period: int, ext_out, in_digests) -> str:
    """Content address of one block repeat's evidence.

    ``ext_out`` (the (offset, slot) union of externally-consumed outputs)
    is part of the key because two graphs can share a family digest but
    consume different slots outside the block — the cached entry must
    carry every output the *reader* needs.
    """
    h = hashlib.sha256()
    h.update(b"blockev4\x00")
    h.update(fam_digest.encode())
    h.update(f"\x00{period}\x00{ext_out!r}\x00".encode())
    for d in in_digests:
        h.update(d.encode())
        h.update(b"\x00")
    return BLOCK_PREFIX + h.hexdigest()[:40]


@dataclasses.dataclass
class BlockEvidenceCache:
    """In-memory memo + optional persistent Store backend for block-level
    capture evidence and whole-graph pricing entries.

    Thread-compatible with Session's parallel per-sample captures: entries
    are immutable once written, dict/get/set are atomic, and backend writes
    are atomic-rename (or conditional-put) by construction — concurrent
    writers of the same key converge on byte-identical bodies.
    """

    backend: Store | None = None
    counters: dict[str, int] = dataclasses.field(
        default_factory=_fresh_block_counters)
    # entry key -> (payload, materialized ext-out arrays, by ext_out order)
    memo: dict[str, tuple[dict, list[np.ndarray]]] = dataclasses.field(
        default_factory=dict)
    profiles: dict[str, dict] = dataclasses.field(default_factory=dict)
    # (kind, key, family digest, window lo, "hit"|"miss") per probe — the
    # invalidation tests' ground truth
    trace: list[tuple] = dataclasses.field(default_factory=list)

    # -- counters -----------------------------------------------------------

    def _count(self, name: str, n: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + n
        if self.backend is not None:
            c = self.backend.counters
            c[name] = c.get(name, 0) + n

    def snapshot(self) -> dict[str, int]:
        return dict(self.counters)

    @staticmethod
    def delta(before: dict[str, int], after: dict[str, int]) -> dict[str, int]:
        return {k: after.get(k, 0) - before.get(k, 0)
                for k in after if after.get(k, 0) != before.get(k, 0)}

    # -- block entries ------------------------------------------------------

    def get_block(self, key: str, *, fam_digest: str = "",
                  lo: int = -1) -> tuple[dict, list[np.ndarray]] | None:
        """The cached (payload, ext-out arrays) for ``key``, or None."""
        hit = self.memo.get(key)
        if hit is None and self.backend is not None:
            hit = self._load_block(key)
            if hit is not None:
                self.memo[key] = hit
        if hit is not None:
            self._count("block_hits")
            self.trace.append(("block", key, fam_digest, lo, "hit"))
            return hit
        self._count("block_misses")
        self.trace.append(("block", key, fam_digest, lo, "miss"))
        return None

    def _load_block(self, key: str) -> tuple[dict, list[np.ndarray]] | None:
        try:
            if not self.backend.has_manifest(key):
                return None
            payload = self.backend.read_manifest(key)
            if payload.get("kind") != "block-evidence":
                return None
            arrays = [self._materialize(rec) for rec in payload["ext_out"]]
        except _SOFT_ERRORS:
            self._count("block_errors")
            return None
        return payload, arrays

    def _materialize(self, rec: dict) -> np.ndarray:
        buf = b"".join(self.backend.read_chunk(c) for c in rec["chunks"])
        if len(buf) != rec["nbytes"] or chunk_digest(buf) != rec["digest"]:
            raise StoreError(f"block evidence value corrupt: {rec['digest']}")
        a = np.frombuffer(buf, dtype=np.dtype(rec["dtype"]))
        return a.reshape(tuple(rec["shape"]))

    def put_block(self, key: str, payload: dict,
                  arrays: list[np.ndarray]) -> None:
        """Record one block repeat's evidence (memo always; store when
        writable).  ``arrays`` follow ``payload["ext_out"]`` order."""
        self.memo[key] = (payload, arrays)
        if self.backend is None or self.backend.readonly:
            return
        try:
            for rec, a in zip(payload["ext_out"], arrays):
                buf = np.ascontiguousarray(a).tobytes()
                for chunk in split_chunks(buf):
                    dg = chunk_digest(chunk)
                    if not self.backend.has_chunk(dg):
                        self.backend.write_chunk(dg, chunk)
            self.backend.write_manifest(key, payload)
        except _SOFT_ERRORS:
            self._count("block_errors")

    @staticmethod
    def value_record(a: np.ndarray) -> dict:
        """ValueRef-shaped record of one external output (chunk digests
        computed here; bytes written by put_block)."""
        buf = np.ascontiguousarray(a).tobytes()
        return {"dtype": str(a.dtype), "shape": list(a.shape),
                "nbytes": len(buf), "digest": chunk_digest(buf),
                "chunks": [chunk_digest(c) for c in split_chunks(buf)]}

    # -- profile entries ----------------------------------------------------

    def get_profile(self, key: str) -> dict | None:
        """The cached profile payload for ``key``, or None."""
        payload = self.profiles.get(key)
        if payload is None and self.backend is not None:
            try:
                if self.backend.has_manifest(key):
                    payload = self.backend.read_manifest(key)
                    if payload.get("kind") != "profile":
                        payload = None
                    else:
                        self.profiles[key] = payload
            except _SOFT_ERRORS:
                self._count("block_errors")
                payload = None
        if payload is not None:
            self._count("profile_hits")
            self.trace.append(("profile", key, "", -1, "hit"))
            return payload
        self._count("profile_misses")
        self.trace.append(("profile", key, "", -1, "miss"))
        return None

    def put_profile(self, key: str, payload: dict) -> None:
        self.profiles[key] = payload
        if self.backend is None or self.backend.readonly:
            return
        try:
            self.backend.write_manifest(key, payload)
        except _SOFT_ERRORS:
            self._count("block_errors")


def profile_entry_key(graph, args, backend_id: str) -> str:
    """Content address of a deterministic backend's EnergyProfile.

    Const VALUES are part of the key (XLA folds them into the compiled
    module, so HLO costs depend on them); arg values are not — only their
    avals matter to pricing.
    """
    from repro.core.graph import _jaxpr_fingerprint, _value_digest
    closed = graph.closed_jaxpr
    h = hashlib.sha256()
    h.update(b"profilev4\x00")
    if closed is not None:
        h.update(_jaxpr_fingerprint(closed.jaxpr, tuple(closed.consts),
                                    {}).encode())
    else:   # rebuilt graphs: fall back to the structural node digests
        from repro.core.graph import block_structure
        bs = block_structure(graph)
        for d in bs.struct_digests:
            h.update(d.encode())
    for t in sorted((graph._const_vals or {})):
        h.update(_value_digest(graph._const_vals[t]).encode())
    import jax
    for a in jax.tree_util.tree_leaves(args):
        arr = np.asarray(a)
        h.update(f"{arr.dtype}:{arr.shape}\x00".encode())
    h.update(backend_id.encode())
    return PROFILE_PREFIX + h.hexdigest()[:40]
