"""Energy models: analytic (roofline-timed), replay-measured, HLO-calibrated.

Mirrors the paper's modular profiler (§5.2): a physical power meter when you
have one, replay-based software profiling when you don't.  On this CPU-only
container the 'physical meter' role is played by the analytic TPU-v5e model
(DESIGN.md §2); the ReplayProfiler measures real per-operator wall time on the
host and converts it through the host power model, preserving orderings and
relative differences that can be cross-checked against the analytic numbers
(benchmarks/bench_energy_accuracy.py, Table-4 analogue).

Sessions (core/session.py) select between these through the ``EnergyBackend``
protocol: an object with an ``id`` (feeds the artifact cache key), a ``label``
(the ``Report.meta['energy_model']`` string) and a ``profile(graph, args)``
method returning an :class:`EnergyProfile`.  ``AnalyticalBackend`` wraps
:class:`AnalyticalEnergyModel`, ``ReplayBackend`` wraps
:class:`ReplayProfiler`, and ``HloCostBackend`` prices each operator from
XLA's compiled module via per-instruction cost attribution
(core/hlo_costs.py): eqn ids are threaded through the lowering as name
scopes and each optimized-HLO instruction is credited back to its
originating jaxpr equation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core import costs as costs_mod
from repro.core import hlo_costs as hlo_costs_mod
from repro.core.graph import OpGraph
from repro.hw.specs import CPU_HOST, TPU_V5E, HardwareSpec


@dataclasses.dataclass
class OpEnergy:
    node_idx: int
    primitive: str
    energy_j: float
    time_s: float
    flops: float
    hbm_bytes: float
    ici_bytes: float
    bound: str          # 'compute' | 'memory' | 'collective'


@dataclasses.dataclass
class EnergyProfile:
    graph_name: str
    ops: list[OpEnergy]
    # per-op costs attributed from the compiled module (HloCostBackend only);
    # persisted with the artifact so loaded captures keep their attribution.
    # compare=False: PerOpCosts holds ndarrays, whose __eq__ is elementwise
    hlo: "hlo_costs_mod.PerOpCosts | None" = dataclasses.field(
        default=None, compare=False)
    # node-indexed energy/time arrays, built lazily once so per-region
    # queries (subgraph_energy/subgraph_time) are O(|region|) array gathers
    # instead of a Python set rebuild + full scan per query.
    _energy_by_node: np.ndarray | None = dataclasses.field(
        default=None, repr=False, compare=False)
    _time_by_node: np.ndarray | None = dataclasses.field(
        default=None, repr=False, compare=False)

    def _index(self) -> tuple[np.ndarray, np.ndarray]:
        if self._energy_by_node is None:
            n = max((o.node_idx for o in self.ops), default=-1) + 1
            e = np.zeros(n)
            t = np.zeros(n)
            for o in self.ops:
                e[o.node_idx] += o.energy_j
                t[o.node_idx] += o.time_s
            self._energy_by_node = e
            self._time_by_node = t
        return self._energy_by_node, self._time_by_node

    @staticmethod
    def _gather(arr: np.ndarray, node_idxs: Sequence[int]) -> float:
        idxs = np.unique(np.fromiter(node_idxs, dtype=np.int64))
        # unknown idxs are ignored, matching the historical set-filter scan
        idxs = idxs[(idxs >= 0) & (idxs < arr.size)]
        return float(arr[idxs].sum()) if idxs.size else 0.0

    def energy_of(self, node_idxs: Sequence[int]) -> float:
        return self._gather(self._index()[0], node_idxs)

    def time_of(self, node_idxs: Sequence[int]) -> float:
        return self._gather(self._index()[1], node_idxs)

    @property
    def total_energy_j(self) -> float:
        return float(self._index()[0].sum())

    @property
    def total_time_s(self) -> float:
        return float(self._index()[1].sum())

    def top_k(self, k: int = 5) -> list[OpEnergy]:
        return sorted(self.ops, key=lambda o: -o.energy_j)[:k]

    def by_primitive(self) -> dict[str, float]:
        agg: dict[str, float] = {}
        for o in self.ops:
            agg[o.primitive] = agg.get(o.primitive, 0.0) + o.energy_j
        return dict(sorted(agg.items(), key=lambda kv: -kv[1]))


class AnalyticalEnergyModel:
    """Prices every operator from cost rules + hardware energy coefficients.

    E_op = e_flop·FLOPs + e_hbm·HBM_bytes + e_ici·ICI_bytes + P_static·t_op,
    with t_op the roofline max of the three terms.  fp32-accurate matmuls
    (precision=HIGHEST) run at peak_flops_fp32 — the TF32/tensor-core
    misconfiguration cases (c1/c8) fall out of this term.
    """

    def __init__(self, spec: HardwareSpec = TPU_V5E):
        self.spec = spec

    def _price(self, costs: "list[costs_mod.OpCost]"):
        """Roofline + energy math over a batch of OpCosts, as array ops.

        Single implementation shared by op_energy and profile: returns
        (flops, hbm, ici, energy, t_op, bound) arrays of len(costs).
        """
        s = self.spec
        n = len(costs)
        flops = np.fromiter((c.flops for c in costs), dtype=np.float64, count=n)
        frac = np.fromiter((c.fp32_fraction for c in costs), dtype=np.float64,
                           count=n)
        hbm = np.fromiter((c.hbm_bytes for c in costs), dtype=np.float64,
                          count=n)
        ici = np.fromiter((c.ici_bytes for c in costs), dtype=np.float64,
                          count=n)
        fp32 = flops * frac
        bf16 = flops - fp32
        t_compute = (bf16 / s.peak_flops_bf16) + (fp32 / s.peak_flops_fp32)
        t_mem = hbm / s.hbm_bw
        t_coll = ici / (s.ici_bw_per_link * s.ici_links)
        t_op = np.maximum(np.maximum(t_compute, t_mem),
                          np.maximum(t_coll, 0.0))
        bound = np.where((t_op == t_compute) & (t_compute > 0), "compute",
                         np.where((t_op == t_coll) & (t_coll > 0),
                                  "collective", "memory"))
        energy = (bf16 * s.joules_per_flop
                  + fp32 * 3.0 * s.joules_per_flop
                  + hbm * s.joules_per_hbm_byte
                  + ici * s.joules_per_ici_byte
                  + s.idle_watts * t_op)
        return flops, hbm, ici, energy, t_op, bound

    def op_energy(self, graph: OpGraph, node_idx: int) -> OpEnergy:
        node = graph.nodes[node_idx]
        c = costs_mod.node_cost(graph, node)
        flops, hbm, ici, energy, t_op, bound = self._price([c])
        return OpEnergy(node_idx=node_idx, primitive=node.primitive,
                        energy_j=float(energy[0]), time_s=float(t_op[0]),
                        flops=float(flops[0]), hbm_bytes=float(hbm[0]),
                        ici_bytes=float(ici[0]), bound=str(bound[0]))

    def profile(self, graph: OpGraph) -> EnergyProfile:
        """Price every node, with the roofline/energy math batched over the
        whole graph as array ops (one pass instead of per-node scalar math)."""
        costs = [costs_mod.node_cost(graph, node) for node in graph.nodes]
        flops, hbm, ici, energy, t_op, bound = self._price(costs)
        ops = [OpEnergy(node_idx=i, primitive=graph.nodes[i].primitive,
                        energy_j=float(energy[i]), time_s=float(t_op[i]),
                        flops=float(flops[i]), hbm_bytes=float(hbm[i]),
                        ici_bytes=float(ici[i]), bound=str(bound[i]))
               for i in range(len(costs))]
        return EnergyProfile(graph_name=graph.name, ops=ops)


class ReplayProfiler:
    """Measures real per-operator wall time by replaying each operator.

    The paper's fallback when no power meter is attached: replay each operator
    long enough to average out sampling noise, then convert time to energy via
    the power model.  On this host the measurement is real CPU time; the power
    conversion uses the host spec so analytic and measured Joules live on the
    same scale.
    """

    def __init__(self, spec: HardwareSpec = CPU_HOST,
                 min_replay_time_s: float = 5e-3, max_replay_iters: int = 64):
        self.spec = spec
        self.min_replay_time_s = min_replay_time_s
        self.max_replay_iters = max_replay_iters

    def profile(self, graph: OpGraph, *args) -> EnergyProfile:
        from repro.core.interp import run_instrumented
        _, records = run_instrumented(
            graph, *args, measure=True,
            min_replay_time_s=self.min_replay_time_s,
            max_replay_iters=self.max_replay_iters)
        ops = []
        for rec in records:
            node = graph.nodes[rec.node_idx]
            c = costs_mod.node_cost(graph, node)
            t = rec.wall_time_s or 0.0
            # dynamic power scales with achieved intensity; static always on
            util = min(1.0, (c.flops / max(t, 1e-12)) / self.spec.peak_flops_bf16)
            p_dyn = self.spec.compute_watts * util + self.spec.hbm_watts * min(
                1.0, (c.hbm_bytes / max(t, 1e-12)) / self.spec.hbm_bw)
            energy = (self.spec.idle_watts + p_dyn) * t
            ops.append(OpEnergy(node_idx=rec.node_idx, primitive=rec.primitive,
                                energy_j=energy, time_s=t, flops=c.flops,
                                hbm_bytes=c.hbm_bytes, ici_bytes=c.ici_bytes,
                                bound="measured"))
        return EnergyProfile(graph_name=graph.name, ops=ops)


def subgraph_energy(profile: EnergyProfile, node_idxs: Sequence[int]) -> float:
    return profile.energy_of(node_idxs)


def subgraph_time(profile: EnergyProfile, node_idxs: Sequence[int]) -> float:
    return profile.time_of(node_idxs)


# ---------------------------------------------------------------------------
# pluggable backends (the session-level replacement for `use_replay: bool`)
# ---------------------------------------------------------------------------

def _spec_digest(spec: HardwareSpec) -> str:
    """Stable digest of a spec's coefficients, folded into backend ids so
    artifact cache keys change when pricing constants change (a renamed-only
    or retuned spec must never serve stale cached energy profiles)."""
    import hashlib
    payload = repr(sorted(dataclasses.asdict(spec).items()))
    return hashlib.sha256(payload.encode()).hexdigest()[:8]


@runtime_checkable
class EnergyBackend(Protocol):
    """Per-session energy pricing strategy.

    * ``id`` — stable identifier mixed into artifact cache keys, so captures
      priced by different backends never alias in the store;
    * ``label`` — human-readable name surfaced as
      ``Report.meta['energy_model']`` (the analytic backend keeps the legacy
      hardware-spec name, the replay backend the legacy ``"replay"``);
    * ``profile(graph, args)`` — price one traced graph.  ``args`` are the
      concrete capture inputs; analytic backends ignore them, measuring
      backends (replay) execute on them.
    """

    @property
    def id(self) -> str: ...

    @property
    def label(self) -> str: ...

    def profile(self, graph: OpGraph,
                args: Sequence[Any] = ()) -> EnergyProfile: ...


@dataclasses.dataclass(frozen=True)
class AnalyticalBackend:
    """Roofline/analytic pricing on a hardware spec (no execution)."""

    spec: HardwareSpec = TPU_V5E

    # a pure function of (graph, input avals): profiles are cacheable as
    # content-addressed ``profile--`` entries (core/block_cache.py)
    deterministic = True

    @property
    def id(self) -> str:
        return f"analytic:{self.spec.name}:{_spec_digest(self.spec)}"

    @property
    def label(self) -> str:
        return self.spec.name

    def profile(self, graph: OpGraph,
                args: Sequence[Any] = ()) -> EnergyProfile:
        return AnalyticalEnergyModel(self.spec).profile(graph)


@dataclasses.dataclass(frozen=True)
class ReplayBackend:
    """Replay-measured wall time on the host, converted through its power
    model (the paper's software-profiling fallback)."""

    spec: HardwareSpec = CPU_HOST
    min_replay_time_s: float = 5e-3
    max_replay_iters: int = 64

    # measured wall time is not a pure function of the program: never
    # replayed from a profile cache entry
    deterministic = False

    @property
    def id(self) -> str:
        return (f"replay:{self.spec.name}:{_spec_digest(self.spec)}"
                f":{self.min_replay_time_s}:{self.max_replay_iters}")

    @property
    def label(self) -> str:
        return "replay"

    def profile(self, graph: OpGraph,
                args: Sequence[Any] = ()) -> EnergyProfile:
        profiler = ReplayProfiler(self.spec,
                                  min_replay_time_s=self.min_replay_time_s,
                                  max_replay_iters=self.max_replay_iters)
        return profiler.profile(graph, *args)


@dataclasses.dataclass(frozen=True)
class HloCostBackend:
    """Per-instruction pricing from XLA's compiled module.

    The captured jaxpr is re-lowered with every equation bound under a
    ``magop<idx>`` name scope (hlo_costs.annotated_compile), so each HLO
    instruction in the optimized module — including instructions inside
    fused computations and while bodies — carries its originating OpGraph
    node id in its metadata.  Walking that module per instruction yields a
    true per-operator FLOP/byte/collective breakdown under XLA's fusion,
    CSE, and layout decisions (hlo_costs.attribute_costs); proportional
    splitting only happens inside fusions whose constituents are genuinely
    merged.  The resulting per-node columns are priced through the same
    roofline/energy math as the analytic model, and the attribution is kept
    on ``EnergyProfile.hlo`` so artifacts persist it.
    """

    spec: HardwareSpec = TPU_V5E

    # XLA cost analysis of a fixed module is deterministic: cacheable
    deterministic = True

    @property
    def id(self) -> str:
        # 'perop' marks the per-instruction attribution engine: captures
        # priced by the old module-total rescaling must not alias in stores
        return f"hlo:perop:{self.spec.name}:{_spec_digest(self.spec)}"

    @property
    def label(self) -> str:
        return f"hlo+{self.spec.name}"

    def profile(self, graph: OpGraph,
                args: Sequence[Any] = ()) -> EnergyProfile:
        if graph.closed_jaxpr is None:
            raise ValueError(
                "HloCostBackend needs a live graph (with a ClosedJaxpr); "
                "loaded artifacts carry their capture-time profile instead")
        poc = hlo_costs_mod.per_op_costs(graph, args)
        costs = [costs_mod.OpCost(
            flops=float(poc.flops[i]), hbm_bytes=float(poc.hbm_bytes[i]),
            ici_bytes=float(poc.ici_bytes[i]),
            fp32_fraction=float(poc.fp32_fraction[i]))
            for i in range(len(graph.nodes))]
        model = AnalyticalEnergyModel(self.spec)
        flops, hbm, ici, energy, t_op, bound = model._price(costs)
        ops = [OpEnergy(node_idx=i, primitive=graph.nodes[i].primitive,
                        energy_j=float(energy[i]), time_s=float(t_op[i]),
                        flops=float(flops[i]), hbm_bytes=float(hbm[i]),
                        ici_bytes=float(ici[i]), bound=str(bound[i]))
               for i in range(len(costs))]
        return EnergyProfile(graph_name=graph.name, ops=ops, hlo=poc)


def backend_from_name(name: str, *, spec: HardwareSpec = TPU_V5E
                      ) -> EnergyBackend:
    """Resolve a CLI-style backend name ('analytic' | 'replay' | 'hlo')."""
    if name in ("analytic", "analytical"):
        return AnalyticalBackend(spec)
    if name == "replay":
        return ReplayBackend()
    if name == "hlo":
        return HloCostBackend(spec)
    raise ValueError(f"unknown energy backend {name!r} "
                     "(expected 'analytic', 'replay' or 'hlo')")
