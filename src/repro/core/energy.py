"""Energy models: analytic (roofline-timed) and replay-measured.

Mirrors the paper's modular profiler (§5.2): a physical power meter when you
have one, replay-based software profiling when you don't.  On this CPU-only
container the 'physical meter' role is played by the analytic TPU-v5e model
(DESIGN.md §2); the ReplayProfiler measures real per-operator wall time on the
host and converts it through the host power model, preserving orderings and
relative differences that can be cross-checked against the analytic numbers
(benchmarks/bench_energy_accuracy.py, Table-4 analogue).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import costs as costs_mod
from repro.core.graph import OpGraph
from repro.hw.specs import CPU_HOST, TPU_V5E, HardwareSpec


@dataclasses.dataclass
class OpEnergy:
    node_idx: int
    primitive: str
    energy_j: float
    time_s: float
    flops: float
    hbm_bytes: float
    ici_bytes: float
    bound: str          # 'compute' | 'memory' | 'collective'


@dataclasses.dataclass
class EnergyProfile:
    graph_name: str
    ops: list[OpEnergy]

    @property
    def total_energy_j(self) -> float:
        return sum(o.energy_j for o in self.ops)

    @property
    def total_time_s(self) -> float:
        return sum(o.time_s for o in self.ops)

    def top_k(self, k: int = 5) -> list[OpEnergy]:
        return sorted(self.ops, key=lambda o: -o.energy_j)[:k]

    def by_primitive(self) -> dict[str, float]:
        agg: dict[str, float] = {}
        for o in self.ops:
            agg[o.primitive] = agg.get(o.primitive, 0.0) + o.energy_j
        return dict(sorted(agg.items(), key=lambda kv: -kv[1]))


class AnalyticalEnergyModel:
    """Prices every operator from cost rules + hardware energy coefficients.

    E_op = e_flop·FLOPs + e_hbm·HBM_bytes + e_ici·ICI_bytes + P_static·t_op,
    with t_op the roofline max of the three terms.  fp32-accurate matmuls
    (precision=HIGHEST) run at peak_flops_fp32 — the TF32/tensor-core
    misconfiguration cases (c1/c8) fall out of this term.
    """

    def __init__(self, spec: HardwareSpec = TPU_V5E):
        self.spec = spec

    def op_energy(self, graph: OpGraph, node_idx: int) -> OpEnergy:
        node = graph.nodes[node_idx]
        c = costs_mod.node_cost(graph, node)
        s = self.spec
        fp32_flops = c.flops * c.fp32_fraction
        bf16_flops = c.flops - fp32_flops
        t_compute = s.compute_time(bf16_flops) + s.compute_time(fp32_flops, fp32=True)
        t_mem = s.memory_time(c.hbm_bytes)
        t_coll = s.collective_time(c.ici_bytes)
        t_op = max(t_compute, t_mem, t_coll, 0.0)
        if t_op == t_compute and t_compute > 0:
            bound = "compute"
        elif t_op == t_coll and t_coll > 0:
            bound = "collective"
        else:
            bound = "memory"
        energy = (bf16_flops * s.joules_per_flop
                  + fp32_flops * 3.0 * s.joules_per_flop
                  + c.hbm_bytes * s.joules_per_hbm_byte
                  + c.ici_bytes * s.joules_per_ici_byte
                  + s.idle_watts * t_op)
        return OpEnergy(node_idx=node_idx, primitive=node.primitive,
                        energy_j=energy, time_s=t_op, flops=c.flops,
                        hbm_bytes=c.hbm_bytes, ici_bytes=c.ici_bytes, bound=bound)

    def profile(self, graph: OpGraph) -> EnergyProfile:
        return EnergyProfile(graph_name=graph.name,
                             ops=[self.op_energy(graph, i)
                                  for i in range(len(graph.nodes))])


class ReplayProfiler:
    """Measures real per-operator wall time by replaying each operator.

    The paper's fallback when no power meter is attached: replay each operator
    long enough to average out sampling noise, then convert time to energy via
    the power model.  On this host the measurement is real CPU time; the power
    conversion uses the host spec so analytic and measured Joules live on the
    same scale.
    """

    def __init__(self, spec: HardwareSpec = CPU_HOST,
                 min_replay_time_s: float = 5e-3, max_replay_iters: int = 64):
        self.spec = spec
        self.min_replay_time_s = min_replay_time_s
        self.max_replay_iters = max_replay_iters

    def profile(self, graph: OpGraph, *args) -> EnergyProfile:
        from repro.core.interp import run_instrumented
        _, records = run_instrumented(
            graph, *args, measure=True,
            min_replay_time_s=self.min_replay_time_s,
            max_replay_iters=self.max_replay_iters)
        ops = []
        for rec in records:
            node = graph.nodes[rec.node_idx]
            c = costs_mod.node_cost(graph, node)
            t = rec.wall_time_s or 0.0
            # dynamic power scales with achieved intensity; static always on
            util = min(1.0, (c.flops / max(t, 1e-12)) / self.spec.peak_flops_bf16)
            p_dyn = self.spec.compute_watts * util + self.spec.hbm_watts * min(
                1.0, (c.hbm_bytes / max(t, 1e-12)) / self.spec.hbm_bw)
            energy = (self.spec.idle_watts + p_dyn) * t
            ops.append(OpEnergy(node_idx=rec.node_idx, primitive=rec.primitive,
                                energy_j=energy, time_s=t, flops=c.flops,
                                hbm_bytes=c.hbm_bytes, ici_bytes=c.ici_bytes,
                                bound="measured"))
        return EnergyProfile(graph_name=graph.name, ops=ops)


def subgraph_energy(profile: EnergyProfile, node_idxs: Sequence[int]) -> float:
    idxs = set(node_idxs)
    return sum(o.energy_j for o in profile.ops if o.node_idx in idxs)


def subgraph_time(profile: EnergyProfile, node_idxs: Sequence[int]) -> float:
    idxs = set(node_idxs)
    return sum(o.time_s for o in profile.ops if o.node_idx in idxs)
