"""Block-stamped matching: prove tensor pairs bitwise-identical by induction.

The hierarchical matcher's stamping layer.  ``graph.block_structure`` finds
repeated-block families and canonical per-node digests; this module turns
them into *twin pairs* — cross-graph tensor pairs (tid_a, tid_b) PROVEN
bitwise-identical without ever touching their values:

  base case    graph-input pairs whose captured value digests are equal on
               every sample (inputs are the only tensors whose bytes we must
               actually look at);
  induction    a node pair with equal op digests (same primitive, params,
               avals, mesh axes), whose produced/input operand slots pair up
               as twins and whose const/literal operand slots have equal
               value digests, produces twin outputs — single-device XLA
               execution is deterministic, so identical ops over identical
               bytes yield identical bytes.

Twins let ``TensorMatcher.match_streamed`` STAMP phase-2 verdicts: a twin
pair is equivalent by construction, on every sample, with zero fetches and
zero SVDs — so matching a 160-layer stack costs one representative block's
worth of spectral checks plus O(nodes) digest propagation, instead of
O(nodes) SVD work.  Crucially the stamp can only *accept* pairs the
exhaustive matcher would also accept (bitwise identity implies equal
signatures); pairs it cannot prove fall through to the full two-phase
pipeline unchanged, which keeps the fast path exhaustive-equivalent — the
digest-demotion invariant: a mutated layer mid-stack demotes only its own
pairs.

``resolve_pending`` closes the boundary case: when a demoted (or simply
unproven) pair blocks downstream induction, its actual values are batch-
fetched ONCE per side, digest-compared across all samples, and — when a
bitwise-preserving rewrite merely re-expressed the op — re-seeded as a twin
so stamping resumes below the rewrite instead of degrading for the whole
suffix of the stack.
"""

from __future__ import annotations

import hashlib
from collections import deque
from typing import Any, Callable, Sequence

import jax
import numpy as np

from repro.core.graph import OpGraph, _value_digest, block_structure

# Propagation is O(twin pairs x consumer fan-out); degenerate graphs (long
# chains of bitwise-identical tensors on BOTH axes) could in principle pair
# every tensor with every other.  Cap proven node-pair work far above any
# real stack so pathological inputs degrade to partial stamping, never hang.
_MAX_NODE_PAIRS = 500_000

# A twin with huge fan-out on both sides (a weight matrix consumed by every
# layer) would enumerate a quadratic consumer cross product, almost all of it
# cross-layer node pairs that can never prove.  Skip enumeration for such
# twins: any node pair worth checking is also triggered by its low-fan-out
# activation operands, and the ubiquitous operand is then verified by a plain
# twin-set lookup inside the check.
_FANOUT_CAP = 64

_PROVEN, _FAILED, _BLOCKED = 1, 2, 3


class BlockStamper:
    """Twin-pair prover over two live graphs and their captured samples.

    ``samples_*`` are the per-sample argument tuples the graphs were captured
    with (``Session`` keeps them on live artifacts).  Graphs rebuilt from
    persisted artifacts carry stringified params whose digests are not
    canonical across traces — the stamper refuses them (no twins) and the
    matcher silently falls back to the full pipeline.
    """

    def __init__(self, graph_a: OpGraph, graph_b: OpGraph,
                 samples_a: Sequence[Sequence[Any]],
                 samples_b: Sequence[Sequence[Any]]):
        self.graph_a = graph_a
        self.graph_b = graph_b
        self.twins: set[tuple[int, int]] = set()
        self.pending: set[tuple[int, int]] = set()
        self.reseeded = 0
        self.demoted = 0
        self._status: dict[tuple[int, int], int] = {}
        self._waiting: dict[tuple[int, int], set[tuple[int, int]]] = {}
        self._refuted: set[tuple[int, int]] = set()
        self._queue: deque[tuple[int, int]] = deque()
        self._checks = 0

        live = (graph_a._eqns is not None and graph_b._eqns is not None
                and len(samples_a) == len(samples_b) and samples_a)
        if not live:
            self._bs_a = self._bs_b = None
            return
        self._bs_a = block_structure(graph_a)
        self._bs_b = block_structure(graph_b)
        self._meta_a, roots_a = _node_meta(graph_a, self._bs_a)
        self._meta_b, roots_b_list = _node_meta(graph_b, self._bs_b)

        # base case: input pairs bitwise-equal on every sample
        dig_a = _input_digests(graph_a, samples_a)
        dig_b = _input_digests(graph_b, samples_b)
        n = len(samples_a)
        for ta in graph_a.inputs:
            for tb in graph_b.inputs:
                if all(dig_a[k].get(ta) == dig_b[k].get(tb)
                       and dig_a[k].get(ta) is not None for k in range(n)):
                    self._add_twin(ta, tb)
        # nodes with no produced/input operands (const-only) have no twin
        # trigger: seed their pairs directly, grouped by op digest
        roots_b: dict[str, list[int]] = {}
        for nb in roots_b_list:
            roots_b.setdefault(self._bs_b.op_digests[nb], []).append(nb)
        for na in roots_a:
            for nb in roots_b.get(self._bs_a.op_digests[na], ()):
                self._consider(na, nb)
        self._drain()

    # -- public --------------------------------------------------------------
    def is_twin(self, ta: int, tb: int) -> bool:
        return (ta, tb) in self.twins

    @property
    def stamped(self) -> int:
        return len(self.twins)

    def resolve_pending(self, fetch_a: Callable, fetch_b: Callable,
                        n_samples: int, budget: int = 512) -> int:
        """Digest-verify pending boundary pairs and re-seed twins from them.

        ``fetch_*(k, tids) -> {tid: ndarray}`` are the matcher's phase-2
        fetchers.  Each examined pair costs one sliced value fetch per side
        per sample; ``budget`` bounds the total examined.  Returns the number
        of pairs re-seeded.  Fetch errors abort resolution quietly — the
        unresolved pairs simply stay with the full matcher.
        """
        before = self.reseeded
        examined = 0
        while examined < budget:
            todo = sorted(p for p in self.pending
                          if p not in self.twins and p not in self._refuted)
            todo = todo[:budget - examined]
            if not todo:
                break
            tids_a = sorted({p[0] for p in todo})
            tids_b = sorted({p[1] for p in todo})
            try:
                dig_a = [_digest_values(fetch_a(k, tids_a))
                         for k in range(n_samples)]
                dig_b = [_digest_values(fetch_b(k, tids_b))
                         for k in range(n_samples)]
            except Exception:
                break
            for p in todo:
                ta, tb = p
                examined += 1
                self.pending.discard(p)
                ok = all(dig_a[k].get(ta) is not None
                         and dig_a[k].get(ta) == dig_b[k].get(tb)
                         for k in range(n_samples))
                if ok:
                    self.reseeded += 1
                    self._add_twin(ta, tb)
                else:
                    self.demoted += 1
                    self._refuted.add(p)
            self._drain()
        return self.reseeded - before

    # -- internals -----------------------------------------------------------
    def _add_twin(self, ta: int, tb: int) -> None:
        p = (ta, tb)
        if p in self.twins:
            return
        self.twins.add(p)
        self._queue.append(p)

    def _drain(self) -> None:
        while self._queue:
            ta, tb = self._queue.popleft()
            w = self._waiting.pop((ta, tb), None)
            if w:
                for key in sorted(w):
                    self._consider(*key)
            cons_a = self.graph_a.tensors[ta].consumers
            cons_b = self.graph_b.tensors[tb].consumers
            if len(cons_a) * len(cons_b) > _FANOUT_CAP:
                continue
            for na in cons_a:
                for nb in cons_b:
                    self._consider(na, nb)

    def _consider(self, na: int, nb: int) -> None:
        key = (na, nb)
        st = self._status.get(key)
        if st in (_PROVEN, _FAILED):
            return
        if self._checks >= _MAX_NODE_PAIRS:
            return
        self._checks += 1
        # precomputed (op_digest, slot kinds, const digests, live slots):
        # equal tuples cover primitive/params/avals, operand arity, per-slot
        # const/input/produced classification and const-value equality
        ma = self._meta_a[na]
        mb = self._meta_b[nb]
        if ma[0] != mb[0] or ma[1] != mb[1] or ma[2] != mb[2]:
            self._status[key] = _FAILED
            return
        node_a = self.graph_a.nodes[na]
        node_b = self.graph_b.nodes[nb]
        if len(node_a.outvars) != len(node_b.outvars):
            self._status[key] = _FAILED
            return
        twins = self.twins
        kinds = ma[1]
        missing: list[tuple[int, int]] = []
        for si in ma[3]:
            p = (node_a.invars[si], node_b.invars[si])
            if p in twins:
                continue
            if kinds[si] == 1:
                # input digests are complete up front: non-twin means
                # genuinely different bytes
                self._status[key] = _FAILED
                return
            missing.append(p)
        if missing:
            self._status[key] = _BLOCKED
            for p in missing:
                self._waiting.setdefault(p, set()).add(key)
                if p not in self._refuted:
                    self.pending.add(p)
            return
        self._status[key] = _PROVEN
        for oa, ob in zip(node_a.outvars, node_b.outvars):
            self._add_twin(oa, ob)


def _node_meta(graph: OpGraph, bs) -> tuple[list[tuple], list[int]]:
    """Per-node operand metadata, memoized on the graph instance.

    Each entry is ``(op_digest, slot kinds, const digests, live slots)``
    where kinds are 0=produced / 1=input / 2=const per invar slot and live
    slots are the non-const slot indices (the ones needing twin checks).
    Also returns the const-only node list (no live slots — induction roots).
    """
    cached = getattr(graph, "_stamp_meta", None)
    if cached is not None:
        return cached
    tensors = graph.tensors
    metas: list[tuple] = []
    roots: list[int] = []
    for node in graph.nodes:
        kinds: list[int] = []
        cdigs: list[str] = []
        live: list[int] = []
        for si, t in enumerate(node.invars):
            e = tensors[t]
            if e.is_const:
                kinds.append(2)
                cdigs.append(bs.const_digest(t))
            elif e.is_input:
                kinds.append(1)
                live.append(si)
            else:
                kinds.append(0)
                live.append(si)
        metas.append((bs.op_digests[node.idx], tuple(kinds),
                      tuple(cdigs), tuple(live)))
        if not live:
            roots.append(node.idx)
    out = (metas, roots)
    graph._stamp_meta = out
    return out


def _input_digests(graph: OpGraph, samples) -> list[dict[int, str]]:
    out = []
    for sample in samples:
        flat = jax.tree_util.tree_leaves(tuple(sample))
        out.append({t: _value_digest(np.asarray(v))
                    for t, v in zip(graph.inputs, flat)})
    return out


def _digest_values(values: dict[int, np.ndarray]) -> dict[int, str]:
    return {t: _value_digest(np.asarray(v)) for t, v in values.items()}
