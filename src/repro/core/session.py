"""Session API: capture-once differential energy debugging.

The public entry point for Magneton-style analysis, decomposed so that
expensive work happens exactly once per candidate (capture) and comparisons
are cheap post-hoc queries over persistent artifacts:

  * ``session.capture(fn, args, name=...)`` — trace, stream per-sample
    tensor-signature capture, and energy-price ONE candidate implementation;
    returns a serializable :class:`~repro.core.artifact.CandidateArtifact`.
    With a store attached the capture is content-addressed (jaxpr hash +
    input shapes/dtypes/values + sample seeds + backend id) and an
    identical re-capture is a cache hit that skips every instrumented
    execution.
  * ``session.compare(art_a, art_b)`` — functional-equivalence gate, lazy
    two-phase tensor matching, subgraph matching, classification and
    diagnosis, all from the artifacts; no end-to-end re-execution.
  * ``session.rank([art_1..art_N])`` — N-way waste matrix from N captures
    (N·(N-1)/2 artifact-level compares) instead of N² full pipelines.

Energy pricing is pluggable through the ``EnergyBackend`` protocol
(core/energy.py): an object with ``id`` (mixed into cache keys), ``label``
(the ``Report.meta['energy_model']`` string) and ``profile(graph, args)``.
Ship-with backends: ``AnalyticalBackend(spec)`` (roofline model, the
default), ``ReplayBackend()`` (replay-measured host wall time), and
``HloCostBackend(spec)`` (analytic breakdown calibrated to XLA's compiled
cost analysis).  The legacy boolean (``DifferentialEnergyDebugger(
use_replay=True)``) maps onto ``ReplayBackend`` for back-compat.

The classic one-shot flow survives as ``DifferentialEnergyDebugger.compare``
(core/diff.py), now a thin wrapper over a store-less session.
"""

from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Any, Callable, Mapping, Sequence

import jax
import numpy as np

from repro.core import interp
from repro.core.artifact import (ArtifactStore, ArtifactValueError,
                                 CandidateArtifact, artifact_key)
from repro.core.diagnose import diagnose_region
from repro.core.energy import (AnalyticalBackend, EnergyBackend,
                               EnergyProfile, subgraph_energy, subgraph_time)
from repro.core.graph import OpGraph, trace
from repro.core.report import Finding, Report
from repro.core.store import StoreError
from repro.core.subgraph_match import MatchedRegion, match_subgraphs
from repro.core.tensor_match import TensorMatcher

# The marker appended to ``priced_by`` / ``energy_model`` labels when any
# rung of the degradation ladder fired — a report always declares fidelity.
DEGRADED_MARK = "[degraded]"

DEFAULT_SEED_BASE = 17     # legacy perturbation seeds: 17, 18, ...

# Graph size from which the hierarchical machinery (block stamping in
# compare, parallel per-sample captures) switches on by default: below it
# the setup cost outweighs the win and small recorded goldens keep the
# legacy fetch-and-persist evidence trail byte-for-byte.
_STAMP_MIN_NODES = 128


def _perturb(args, seed: int):
    """Fresh input sample with the same pytree structure/shapes/dtypes.

    Integer leaves that cannot be meaningfully resampled — zero-size arrays
    (``min()`` raises) and constant arrays (``min == max`` would regenerate
    the same constant while still consuming RNG draws) — pass through
    unchanged; non-degenerate leaves keep the historical distribution.

    ml_dtypes floats (bfloat16, float8) report numpy kind 'V', not 'f' —
    they are detected by name so bf16 models get real Hypothesis-1 probes
    instead of a silent sample-0 passthrough (which would leave
    permutation-symmetric duplicates undisambiguated across samples).
    """
    rng = np.random.default_rng(seed)

    _ML_FLOATS = ("bfloat16", "float8_e4m3fn", "float8_e5m2", "float16")

    def one(x):
        x = np.asarray(x)
        if x.dtype.kind in "f" or x.dtype.name in _ML_FLOATS:
            stats = x.astype(np.float64) if x.dtype.kind != "f" else x
            return (rng.standard_normal(x.shape) * (np.std(stats) + 0.1)
                    + np.mean(stats)).astype(x.dtype)
        if x.dtype.kind in "iu":
            if x.size == 0:
                return x
            lo, hi = int(x.min()), int(x.max()) + 1
            if hi - lo <= 1:       # constant integer leaf: nothing to vary
                return x
            return rng.integers(lo, hi, size=x.shape).astype(x.dtype)
        return x
    return jax.tree_util.tree_map(one, args)


def default_sample_seeds(num_input_samples: int) -> tuple[int, ...]:
    """Perturbation seeds for samples 1..n-1 (sample 0 is the given args)."""
    return tuple(DEFAULT_SEED_BASE + k
                 for k in range(max(num_input_samples - 1, 0)))


def make_samples(args: tuple, sample_seeds: Sequence[int]) -> tuple:
    """Concrete input samples: the given args plus one perturbation per seed."""
    return (args,) + tuple(_perturb(args, seed=int(s)) for s in sample_seeds)


def _raise_uncapturable(fn: Callable, args: tuple, name: str,
                        err: Exception) -> None:
    """Re-raise a trace failure, upgrading it to an actionable TypeError when
    ``fn``'s return value is not a pytree of arrays.

    Tracing a candidate that returns a generator (or any non-array leaf)
    fails deep inside JAX's pytree/aval machinery with a traceback that
    never mentions the candidate.  The probe re-traces ``fn`` abstractly
    (eval_shape: no FLOPs, no buffers) with the raw return value smuggled
    out before JAX flattens it, so even a huge model is diagnosed for free;
    if ``fn`` itself raises under tracing, the original error was genuine
    and is re-raised untouched.
    """
    import inspect
    from collections.abc import Iterator

    seen: dict[str, Any] = {}

    def probe_fn(*a):
        seen["out"] = fn(*a)
        return 0

    try:
        jax.eval_shape(probe_fn, *args)
    except Exception:
        raise err
    probe = seen.get("out")
    if inspect.isgenerator(probe) or isinstance(probe, Iterator):
        raise TypeError(
            f"Session.capture: candidate {name!r} returned a "
            f"{type(probe).__name__}, which cannot be traced; capture needs "
            "a function returning arrays (or pytrees of arrays) — "
            "materialize the iterator first, e.g. `return tuple(...)`"
        ) from None
    bad = sorted({type(leaf).__name__
                  for leaf in jax.tree_util.tree_leaves(probe)
                  if not (hasattr(leaf, "shape") and hasattr(leaf, "dtype"))
                  and not isinstance(leaf, (int, float, complex, bool))})
    if bad:
        raise TypeError(
            f"Session.capture: candidate {name!r} returned non-array leaves "
            f"of type {', '.join(bad)}; capture needs a function returning "
            "arrays (or pytrees of arrays)") from None
    raise err


def _max_abs(x: np.ndarray) -> float:
    """max|x| as a float; 0.0 for zero-size leaves (np.max would raise)."""
    return float(np.max(np.abs(x))) if x.size else 0.0


def _check_same_task(out_a, out_b, output_rtol: float) -> None:
    """Functional-equivalence gate (paper: <=1% element-wise rel. difference).

    Handles scalar and zero-size output leaves; the max-norm relative
    difference measures elementwise |a-b| against the magnitude of the
    outputs, so near-zero elements don't produce spurious "different task"
    verdicts.
    """
    leaves_a = jax.tree_util.tree_leaves(out_a)
    leaves_b = jax.tree_util.tree_leaves(out_b)
    if len(leaves_a) != len(leaves_b):
        raise ValueError(
            f"implementations disagree in output structure "
            f"({len(leaves_a)} vs {len(leaves_b)} leaves); not the same task")
    for xa, xb in zip(leaves_a, leaves_b):
        xa64 = np.asarray(xa, dtype=np.float64)
        xb64 = np.asarray(xb, dtype=np.float64)
        if xa64.shape != xb64.shape:
            raise ValueError(
                f"implementations disagree in output shapes "
                f"({xa64.shape} vs {xb64.shape}); not the same task")
        if xa64.size == 0:
            continue
        scale = max(_max_abs(xa64), _max_abs(xb64), 1e-6)
        rel = _max_abs(xa64 - xb64) / scale
        if rel > output_rtol:
            raise ValueError(
                f"implementations disagree (max rel diff {rel:.3e} > "
                f"{output_rtol}); not the same task")


# ---------------------------------------------------------------------------
# N-way ranking result
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RankResult:
    """N-way differential ranking built from N capture artifacts.

    ``waste_matrix[i][j]`` is the total Joules candidate *i* wastes in
    regions where it is the confirmed-wasteful side against candidate *j*
    (0 on the diagonal and wherever *i* is the efficient side).  Pairwise
    reports are kept for drill-down; ``order()`` ranks candidates by total
    modeled energy, cheapest first.
    """

    names: list[str]
    keys: list[str]
    total_energy_j: list[float]
    waste_matrix: list[list[float]]
    reports: dict[tuple[int, int], Report]   # (i, j) with i < j
    # e.g. identical_pairs (content-address short-circuits), compares
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)

    def order(self) -> list[int]:
        return sorted(range(len(self.names)),
                      key=lambda i: self.total_energy_j[i])

    @property
    def best(self) -> str:
        return self.names[self.order()[0]]

    def render(self) -> str:
        from repro.core.report import render_rank_matrix
        lines = [f"=== Magneton N-way ranking: {len(self.names)} candidates, "
                 f"{len(self.reports)} artifact-level compares ==="]
        lines.extend(render_rank_matrix(self.names, self.total_energy_j,
                                        self.waste_matrix))
        for rank, i in enumerate(self.order(), start=1):
            waste_vs = sum(self.waste_matrix[i])
            lines.append(f"#{rank} {self.names[i]}: "
                         f"{self.total_energy_j[i]:.4e} J total, "
                         f"{waste_vs:.4e} J wasted vs the field")
        return "\n".join(lines)

    def summary_report(self) -> Report:
        """The best-vs-worst pairwise report with the full N-way matrix
        embedded under ``meta['rank_matrix']`` (Report.render shows it)."""
        order = self.order()
        i, j = order[0], order[-1]
        base = self.reports[(min(i, j), max(i, j))]
        meta = dict(base.meta)
        meta["rank_matrix"] = {"names": self.names,
                               "total_energy_j": self.total_energy_j,
                               "waste_matrix": self.waste_matrix}
        return Report(name_a=base.name_a, name_b=base.name_b,
                      findings=base.findings,
                      total_energy_a_j=base.total_energy_a_j,
                      total_energy_b_j=base.total_energy_b_j, meta=meta)

    def to_json(self) -> str:
        return json.dumps({
            "kind": "rank",
            "names": self.names,
            "keys": self.keys,
            "total_energy_j": self.total_energy_j,
            "waste_matrix": self.waste_matrix,
            "reports": [{"i": i, "j": j, "report": json.loads(rep.to_json())}
                        for (i, j), rep in sorted(self.reports.items())],
            "meta": self.meta,
        }, indent=2)

    @classmethod
    def from_json(cls, data: str | Mapping[str, Any]) -> "RankResult":
        d = json.loads(data) if isinstance(data, str) else data
        reports = {(int(r["i"]), int(r["j"])): Report.from_json(r["report"])
                   for r in d["reports"]}
        return cls(names=list(d["names"]), keys=list(d["keys"]),
                   total_energy_j=list(d["total_energy_j"]),
                   waste_matrix=[list(row) for row in d["waste_matrix"]],
                   reports=reports, meta=dict(d.get("meta", {})))


# ---------------------------------------------------------------------------
# the session
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Session:
    """Capture-once differential energy debugging session.

    Detection thresholds follow the paper (§6.1): regions whose modeled
    energy differs by more than ``energy_threshold`` while the efficient
    side is no more than ``perf_tolerance`` slower are software energy
    waste; cheaper-but-slower regions are trade-offs.
    """

    backend: EnergyBackend = dataclasses.field(
        default_factory=AnalyticalBackend)
    store: ArtifactStore | str | None = None
    energy_threshold: float = 0.10
    perf_tolerance: float = 0.01
    match_rtol: float = 1e-3
    num_input_samples: int = 2
    # Graceful-degradation ladder (docs/robustness.md).  When True, a
    # capture whose backend fails to price falls back to
    # ``fallback_backend`` (default: an AnalyticalBackend on the same
    # hardware spec), and a compare whose raw-value store is unreachable
    # retries sketch-only; every downgrade is declared in the result's
    # ``degraded`` provenance.  When False, those failures raise instead —
    # BaselineStore forces False so goldens are never silently degraded.
    allow_degraded: bool = True
    fallback_backend: EnergyBackend | None = None
    # Capture samples 1..n-1 concurrently (sample 0 always runs first and
    # serially so the ``gate_against`` equivalence gate still fails fast
    # before any further instrumented work).  Replay is jit-compiled and
    # releases the GIL inside XLA, so threads overlap compute; every sample
    # still runs through ``interp.capture_tensor_stats`` exactly once and
    # per-sample stats stay in seed order, so store keys and digests are
    # byte-identical to a serial capture.  None (default) auto-enables for
    # graphs with >= 128 nodes.
    parallel_samples: bool | None = None
    # URI stores only: open http(s) mirrors with the conditional-put write
    # dialect so live captures persist straight into a shared fleet store
    # (repro.audit).  file:// and plain paths are always writable.
    store_writable: bool = False
    # Incremental block-level capture & pricing (core/block_cache.py).
    # None (default): auto — a BlockEvidenceCache backed by the session's
    # store (in-memory only when store-less) engages for graphs on the
    # fused-block capture path.  False disables; an explicit
    # BlockEvidenceCache shares evidence across sessions in-process.
    # Every reuse is byte-identical to a cold capture by construction.
    block_cache: Any = None

    def __post_init__(self):
        if isinstance(self.store, (str, Path)):
            # plain path -> local store; file:// and http(s):// URIs -> remote
            # mirror (a hit on either skips all instrumented execution)
            self.store = ArtifactStore.from_uri(self.store,
                                                writable=self.store_writable)
        elif self.store is not None and not isinstance(self.store,
                                                       ArtifactStore):
            from repro.core.store import Store
            if isinstance(self.store, Store):
                self.store = ArtifactStore(backend=self.store)

    def _block_evidence(self):
        """The session's BlockEvidenceCache (lazily built), or None."""
        if self.block_cache is False:
            return None
        from repro.core.block_cache import BlockEvidenceCache
        if isinstance(self.block_cache, BlockEvidenceCache):
            return self.block_cache
        backend = (self.store.backend
                   if isinstance(self.store, ArtifactStore) else None)
        self.block_cache = BlockEvidenceCache(backend=backend)
        return self.block_cache

    @property
    def block_cache_counters(self) -> dict[str, int]:
        """Cumulative block/profile cache hit-miss counters (zeros when the
        cache is disabled or never engaged)."""
        from repro.core.block_cache import BlockEvidenceCache
        if isinstance(self.block_cache, BlockEvidenceCache):
            return dict(self.block_cache.counters)
        return {}

    # -- capture ------------------------------------------------------------
    def capture(self, fn: Callable, args: Sequence[Any], *,
                name: str | None = None,
                config: Mapping[str, Any] | None = None,
                sample_seeds: Sequence[int] | None = None,
                use_cache: bool = True,
                gate_against: CandidateArtifact | None = None,
                output_rtol: float = 1e-2,
                extra_meta: Mapping[str, Any] | None = None
                ) -> CandidateArtifact:
        """Run trace + streaming signature capture + energy pricing once.

        ``sample_seeds`` are the perturbation seeds for input samples
        1..n-1 (sample 0 is ``args`` itself) and are recorded on the
        artifact — they are part of its content address, so captures probed
        on different samples never alias in the store.  On a store cache
        hit no instrumented execution happens at all; the loaded artifact
        is re-attached to the fresh trace so lazy phase-2 value fetches
        keep working.

        ``gate_against`` runs the functional-equivalence gate against an
        earlier capture as soon as this side's sample-0 outputs exist —
        failing fast BEFORE further samples are captured, the graph is
        energy-priced, or anything is persisted (the historical one-shot
        pipeline's gate ordering).
        """
        args = tuple(args)
        if sample_seeds is None:
            sample_seeds = default_sample_seeds(self.num_input_samples)
        sample_seeds = tuple(int(s) for s in sample_seeds)
        name = name or getattr(fn, "__name__", "candidate")

        t0 = time.perf_counter()
        try:
            graph = trace(fn, *args, name=name)
        except Exception as e:
            _raise_uncapturable(fn, args, name, e)
        trace_s = time.perf_counter() - t0
        key = artifact_key(graph, args, sample_seeds, self.backend.id)

        store_warnings: list[str] = []
        if use_cache and self.store is not None:
            try:
                hit = self.store.has(key)
            except (StoreError, OSError) as e:
                # unreachable store: fall through to a fresh live capture
                # (full fidelity — only the cache shortcut is lost)
                if not self.allow_degraded:
                    raise
                hit = False
                store_warnings.append(
                    f"cache probe failed ({type(e).__name__}: {e}); "
                    "re-capturing live")
            if hit:
                art = self.store.load(key)
                art.name = name        # names are labels, not identity
                art.config = dict(config) if config is not None else art.config
                art.attach(graph, args)
                art.meta["cache_hit"] = True
                if gate_against is not None:
                    _check_same_task(gate_against.outputs, art.outputs,
                                     output_rtol)
                return art

        bc = self._block_evidence()
        bc_before = bc.snapshot() if bc is not None else None
        t0 = time.perf_counter()
        samples = make_samples(args, sample_seeds)
        outs0, stats0 = interp.capture_tensor_stats(graph, *samples[0],
                                                    block_cache=bc)
        if gate_against is not None:
            _check_same_task(gate_against.outputs, outs0, output_rtol)
        sample_stats = [stats0]
        rest = samples[1:]
        par = self.parallel_samples
        if par is None:
            par = len(graph.nodes) >= _STAMP_MIN_NODES
        if par and len(rest) > 1:
            from concurrent.futures import ThreadPoolExecutor
            with ThreadPoolExecutor(max_workers=min(len(rest), 4)) as ex:
                futs = [ex.submit(interp.capture_tensor_stats, graph, *s,
                                  block_cache=bc)
                        for s in rest]
                sample_stats.extend(f.result()[1] for f in futs)
        else:
            for s in rest:
                sample_stats.append(interp.capture_tensor_stats(
                    graph, *s, block_cache=bc)[1])
        outputs = [np.asarray(o) for o in jax.tree_util.tree_leaves(outs0)]
        stats_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        backend = self.backend
        degraded: list[str] = []
        try:
            profile = self._cached_profile(backend, graph, args, bc)
        except Exception as e:
            fallback = self._fallback_for(backend)
            if not self.allow_degraded or fallback is None:
                raise
            profile = self._cached_profile(fallback, graph, args, bc)
            degraded.append(
                f"energy backend {backend.label!r} failed "
                f"({type(e).__name__}: {e}); re-priced with fallback "
                f"{fallback.label!r}")
            backend = fallback
            # the price changed identity: re-address under the backend that
            # actually produced it, so the degraded capture never aliases a
            # healthy one in the store
            key = artifact_key(graph, args, sample_seeds, backend.id)
        price_s = time.perf_counter() - t0

        art = CandidateArtifact(
            name=name, key=key, graph=graph, sample_stats=sample_stats,
            outputs=outputs, profile=profile,
            backend_id=backend.id, backend_label=backend.label,
            sample_seeds=sample_seeds,
            config=dict(config) if config is not None else None,
            meta={"nodes": len(graph.nodes),
                  "num_samples": len(samples),
                  "timings": {"trace_s": trace_s, "stats_s": stats_s,
                              "price_s": price_s},
                  **(dict(extra_meta) if extra_meta else {})})
        if bc is not None:
            delta = bc.delta(bc_before, bc.snapshot())
            if delta:
                art.meta["block_cache"] = delta
        if degraded:
            art.meta["degraded"] = degraded
        if store_warnings:
            art.meta["store_warnings"] = store_warnings
        art._samples = samples
        if self.store is not None and not self.store.readonly:
            try:
                self.store.save(art)
            except (StoreError, OSError) as e:
                if not self.allow_degraded:
                    raise
                # the result itself is full-fidelity, but it is no longer
                # replayable offline — a downgrade worth declaring
                art.meta.setdefault("degraded", []).append(
                    f"artifact not persisted ({type(e).__name__}: {e}); "
                    "offline replay unavailable for this capture")
        return art

    def _cached_profile(self, backend: EnergyBackend, graph: OpGraph,
                        args, bc) -> EnergyProfile:
        """Energy-price ``graph``, replaying a cached ``profile--`` entry
        when the backend is deterministic (analytic / HLO-calibrated — a
        function of graph + avals, so the entry is exact by construction).
        Replay-measured backends are never cached: wall time is not a pure
        function of the program."""
        if (bc is None or not getattr(backend, "deterministic", False)
                or len(graph.nodes) < _STAMP_MIN_NODES):
            return backend.profile(graph, args)
        from repro.core.artifact import (_profile_from_payload,
                                         _profile_payload)
        from repro.core.block_cache import profile_entry_key
        key = profile_entry_key(graph, args, backend.id)
        payload = bc.get_profile(key)
        if payload is not None:
            profile = _profile_from_payload(payload["profile"])
            profile.graph_name = graph.name    # labels, not identity
            return profile
        profile = backend.profile(graph, args)
        bc.put_profile(key, {"schema": 4, "kind": "profile",
                             "backend_id": backend.id,
                             "profile": _profile_payload(profile)})
        return profile

    def _fallback_for(self, backend: EnergyBackend) -> EnergyBackend | None:
        """The next rung down the pricing ladder, or None at the bottom."""
        if self.fallback_backend is not None:
            if self.fallback_backend.id != backend.id:
                return self.fallback_backend
            return None
        if isinstance(backend, AnalyticalBackend):
            return None                      # already the bottom rung
        spec = getattr(backend, "spec", None)
        return (AnalyticalBackend(spec=spec) if spec is not None
                else AnalyticalBackend())

    def load(self, key: str) -> CandidateArtifact:
        if self.store is None:
            raise ValueError("session has no artifact store")
        return self.store.load(key)

    # -- compare ------------------------------------------------------------
    def compare(self, art_a: CandidateArtifact, art_b: CandidateArtifact, *,
                output_rtol: float = 1e-2, persist: bool = True,
                allow_degraded: bool | None = None) -> Report:
        """Match + classify + diagnose two artifacts; no re-capture.

        Works on any mix of live and loaded artifacts.  Phase-2 tensor
        values fetched during matching are memoized on the artifacts and
        (with ``persist``, the default) persisted back to the store, so a
        comparison once run live can be re-run offline from disk
        bit-identically.  ``rank()`` passes ``persist=False`` and saves
        each artifact once at exit instead of once per pairwise compare.

        ``allow_degraded`` (default: the session's setting) controls the
        degradation ladder: when raw phase-2 values are unreachable the
        match is retried sketch-only — pairs the persisted digests/spectra
        cannot decide are conservatively dropped — and the report's
        ``degraded`` provenance declares exactly what was downgraded.  With
        it off, the underlying typed error propagates instead.
        """
        if allow_degraded is None:
            allow_degraded = self.allow_degraded
        if art_a.backend_id != art_b.backend_id:
            raise ValueError(
                f"artifacts were priced by different energy backends "
                f"({art_a.backend_id} vs {art_b.backend_id}); energies are "
                "not comparable — re-capture one side")
        if art_a.sample_seeds != art_b.sample_seeds:
            raise ValueError(
                f"artifacts were captured on different sample seeds "
                f"({art_a.sample_seeds} vs {art_b.sample_seeds}); "
                "Hypothesis-1 matching needs identical probes")

        _check_same_task(art_a.outputs, art_b.outputs, output_rtol)

        # capture-time downgrades carry into every report built from the
        # artifact — fidelity provenance is transitive
        degraded: list[str] = []
        for side, art in (("A", art_a), ("B", art_b)):
            degraded.extend(f"{side}: {note}"
                            for note in art.meta.get("degraded", ()))

        # hierarchical fast path: when both sides are live (or re-attached)
        # graphs with their concrete samples, a BlockStamper proves repeated
        # blocks' tensor pairs bitwise-identical so the matcher can stamp
        # them without fetches or SVDs; any failure just means no stamping
        # (the full pipeline is exhaustive-equivalent either way).  Gated to
        # large graphs: a stamped pair leaves no fetched values / digests
        # behind, and small recorded goldens rely on that evidence trail for
        # byte-identical offline replay (tests/test_artifact_migration.py) —
        # at the sizes where stamping pays, artifacts are not golden-recorded
        stamper = None
        if (len(art_a.graph.nodes) >= _STAMP_MIN_NODES
                and len(art_b.graph.nodes) >= _STAMP_MIN_NODES
                and getattr(art_a.graph, "_eqns", None) is not None
                and getattr(art_b.graph, "_eqns", None) is not None
                and art_a._samples is not None and art_b._samples is not None):
            try:
                from repro.core.block_match import BlockStamper
                stamper = BlockStamper(art_a.graph, art_b.graph,
                                       art_a._samples, art_b._samples)
            except Exception:
                stamper = None

        matcher = TensorMatcher(rtol=self.match_rtol)
        try:
            eq_pairs = matcher.match_streamed(
                art_a.sample_stats, art_b.sample_stats,
                art_a.fetcher(), art_b.fetcher(),
                provider_a=art_a.spectra_provider(),
                provider_b=art_b.spectra_provider(),
                stamper=stamper)
        except (ArtifactValueError, StoreError, OSError) as e:
            if not allow_degraded:
                raise
            # raw chunks unreachable: sketch-only retry — persisted digests
            # + spectra decide what they can, the rest is dropped (the
            # result under-matches rather than guesses)
            matcher = TensorMatcher(rtol=self.match_rtol)
            eq_pairs = matcher.match_streamed(
                art_a.sample_stats, art_b.sample_stats,
                art_a.fetcher(), art_b.fetcher(),
                provider_a=art_a.spectra_provider(),
                provider_b=art_b.spectra_provider(),
                stamper=stamper, dry_only=True)
            dropped = (matcher.last_stats.undecided_dropped
                       if matcher.last_stats else 0)
            degraded.append(
                f"sketch-only compare: raw tensor values unreachable "
                f"({type(e).__name__}: {e}); {dropped} undecidable pair(s) "
                "treated as unmatched")
        regions = match_subgraphs(art_a.graph, art_b.graph, eq_pairs)

        priced_by = art_a.backend_label + (f" {DEGRADED_MARK}" if degraded
                                           else "")
        findings = [self._classify(i, r, art_a.graph, art_b.graph,
                                   art_a.profile, art_b.profile,
                                   art_a.config, art_b.config,
                                   priced_by=priced_by)
                    for i, r in enumerate(regions)]
        meta = {"regions": len(regions),
                "eq_tensor_pairs": len(eq_pairs),
                "nodes_a": len(art_a.graph.nodes),
                "nodes_b": len(art_b.graph.nodes),
                "energy_model": priced_by}
        if matcher.last_stats is not None:
            st = matcher.last_stats
            meta["stamped_pairs"] = st.stamped_pairs
            meta["twin_reseeded"] = st.twin_reseeded
            meta["demoted_pairs"] = st.demoted_pairs
        if degraded:
            meta["degraded"] = degraded
        store_warnings = list(art_a.fetch_errors) + list(art_b.fetch_errors)
        if persist and self.store is not None and not self.store.readonly:
            for art in (art_a, art_b):
                if art._dirty:
                    try:
                        self.store.save(art)
                    except (StoreError, OSError) as e:
                        if not allow_degraded:
                            raise
                        store_warnings.append(
                            f"persist of {art.name!r} failed "
                            f"({type(e).__name__}: {e}); this comparison "
                            "will re-fetch values when replayed")
        if store_warnings:
            meta["store_warnings"] = store_warnings
        return Report(
            name_a=art_a.name, name_b=art_b.name, findings=findings,
            total_energy_a_j=art_a.profile.total_energy_j,
            total_energy_b_j=art_b.profile.total_energy_j,
            meta=meta)

    # -- rank ---------------------------------------------------------------
    def rank(self, artifacts: Sequence[CandidateArtifact], *,
             output_rtol: float = 1e-2) -> RankResult:
        """N-way waste matrix from N captures (not N² end-to-end runs).

        Every unordered candidate pair is compared at the artifact level;
        ``waste_matrix[i][j]`` accumulates the energy candidate *i* wastes
        in regions where it is the confirmed-wasteful side vs candidate *j*.

        Store persistence is deferred to rank exit: each artifact that went
        dirty (memoized new phase-2 values) is saved exactly once, instead
        of re-writing its full ``.npz`` after every pairwise compare it
        appears in (which made store-backed rank O(N²) in full rewrites).
        """
        arts = list(artifacts)
        n = len(arts)
        if n < 2:
            raise ValueError("rank() needs at least two artifacts")
        waste = [[0.0] * n for _ in range(n)]
        reports: dict[tuple[int, int], Report] = {}
        identical = 0
        try:
            for i in range(n):
                for j in range(i + 1, n):
                    if arts[i].key == arts[j].key:
                        # same content address = same jaxpr, inputs, seeds
                        # and backend: zero waste by construction, no
                        # compare needed
                        identical += 1
                        reports[(i, j)] = Report(
                            name_a=arts[i].name, name_b=arts[j].name,
                            findings=[],
                            total_energy_a_j=arts[i].profile.total_energy_j,
                            total_energy_b_j=arts[j].profile.total_energy_j,
                            meta={"identical_artifacts": True,
                                  "key": arts[i].key,
                                  "nodes_a": len(arts[i].graph.nodes)
                                  if arts[i].graph is not None else None,
                                  "energy_model": arts[i].backend_label})
                        continue
                    rep = self.compare(arts[i], arts[j],
                                       output_rtol=output_rtol,
                                       persist=False)
                    reports[(i, j)] = rep
                    for f in rep.waste_findings:
                        if f.wasteful_side == "A":
                            waste[i][j] += f.energy_a_j - f.energy_b_j
                        elif f.wasteful_side == "B":
                            waste[j][i] += f.energy_b_j - f.energy_a_j
        finally:
            # one save per dirty artifact, even if a later compare raised —
            # values fetched so far stay replayable offline
            if self.store is not None and not self.store.readonly:
                for art in arts:
                    if art._dirty:
                        self.store.save(art)
        return RankResult(
            names=[a.name for a in arts],
            keys=[a.key for a in arts],
            total_energy_j=[a.profile.total_energy_j for a in arts],
            waste_matrix=waste,
            reports=reports,
            meta={"identical_pairs": identical,
                  "compares": len(reports) - identical})

    # -- classification (paper §6.1) ----------------------------------------
    def _classify(self, idx: int, region: MatchedRegion,
                  graph_a: OpGraph, graph_b: OpGraph,
                  prof_a: EnergyProfile, prof_b: EnergyProfile,
                  config_a, config_b, *,
                  priced_by: str | None = None) -> Finding:
        e_a = subgraph_energy(prof_a, region.nodes_a)
        e_b = subgraph_energy(prof_b, region.nodes_b)
        t_a = subgraph_time(prof_a, region.nodes_a)
        t_b = subgraph_time(prof_b, region.nodes_b)
        lo, hi = min(e_a, e_b), max(e_a, e_b)
        delta = (hi - lo) / lo if lo > 0 else (0.0 if hi <= 0 else float("inf"))
        wasteful = "A" if e_a > e_b else ("B" if e_b > e_a else "-")
        if delta <= self.energy_threshold:
            cls = "comparable"
        else:
            # efficient side must not be slower by more than perf_tolerance
            t_waste, t_eff = (t_a, t_b) if wasteful == "A" else (t_b, t_a)
            if t_eff <= t_waste * (1.0 + self.perf_tolerance):
                cls = "energy_waste"
            else:
                cls = "tradeoff"
        diag = None
        if cls == "energy_waste":
            diag = diagnose_region(graph_a, region.nodes_a,
                                   graph_b, region.nodes_b,
                                   config_a=config_a, config_b=config_b,
                                   priced_by=priced_by,
                                   wasteful_side=wasteful)
        return Finding(region_idx=idx, energy_a_j=e_a, energy_b_j=e_b,
                       time_a_s=t_a, time_b_s=t_b,
                       nodes_a=list(region.nodes_a), nodes_b=list(region.nodes_b),
                       classification=cls, wasteful_side=wasteful, diagnosis=diag)
