"""repro.optimize — the detect→transform→verify loop.

Inverts the mutation taxonomy (``repro.testing.mutate``) into verified
rewrite candidates: a :class:`~repro.core.diagnose.Diagnosis` (its
``subkind``) selects an inverse rewrite, the target's captured jaxpr is
replayed under it, and the result is re-captured, equivalence-gated, and
energy-ranked before being reported.  See docs/optimizer.md.
"""

from repro.optimize.engine import (RewriteContext, RewriteRule,
                                   build_candidate, replay_jaxpr)
from repro.optimize.optimizer import optimize, propose
from repro.optimize.patch import (CANDIDATE_STATUSES, PatchCandidate,
                                  PatchReport)
from repro.optimize.rewrites import REWRITES, Rewrite, rewrites_for

__all__ = [
    "CANDIDATE_STATUSES", "PatchCandidate", "PatchReport", "REWRITES",
    "Rewrite", "RewriteContext", "RewriteRule", "build_candidate",
    "optimize", "propose", "replay_jaxpr", "rewrites_for",
]
