"""Ranked patch reports: the optimizer's output artifact.

A :class:`PatchReport` records, for one wasteful target, every inverse
rewrite that was tried: whether it applied (``sites``), whether the
candidate survived the functional-equivalence gate, what it cost, and the
energy win vs the target.  Reports round-trip through JSON
(``kind: "patch"``) so ``python -m repro.cli report`` can re-render them,
and embed the N-way rank matrix under ``meta['rank_matrix']`` exactly like
``Session.rank`` reports do.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Mapping

from repro.core.diagnose import Diagnosis
from repro.core.report import render_patch_report

# candidate lifecycle:
#   verified     passed the equivalence gate AND priced strictly cheaper
#   no_win       passed the gate but did not price cheaper
#   rejected     failed the functional-equivalence gate
#   failed       the rewritten program could not be built/captured
#   inapplicable the rewrite found no site in the target jaxpr
CANDIDATE_STATUSES = ("verified", "no_win", "rejected", "failed",
                      "inapplicable")

_STATUS_ORDER = {s: i for i, s in enumerate(CANDIDATE_STATUSES)}


@dataclasses.dataclass
class PatchCandidate:
    rewrite: str                 # rewrite registry name
    inverts: str                 # mutation class this rewrite inverts
    status: str                  # one of CANDIDATE_STATUSES
    sites: int = 0
    reason: str | None = None    # why rejected/failed/inapplicable
    energy_j: float | None = None
    win_j: float | None = None   # target energy - candidate energy
    win_pct: float | None = None
    key: str | None = None       # candidate artifact key, when captured

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "PatchCandidate":
        return cls(rewrite=d["rewrite"], inverts=d["inverts"],
                   status=d["status"], sites=d.get("sites", 0),
                   reason=d.get("reason"), energy_j=d.get("energy_j"),
                   win_j=d.get("win_j"), win_pct=d.get("win_pct"),
                   key=d.get("key"))


@dataclasses.dataclass
class PatchReport:
    target: str                  # target candidate name
    target_key: str | None
    target_energy_j: float
    subkind: str | None          # diagnosed subkind that drove the proposal
    candidates: list[PatchCandidate]
    diagnosis: Diagnosis | None = None
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def best(self) -> PatchCandidate | None:
        """The cheapest verified candidate, or None."""
        verified = [c for c in self.candidates if c.status == "verified"]
        if not verified:
            return None
        return min(verified, key=lambda c: c.energy_j)

    @property
    def verified(self) -> list[PatchCandidate]:
        return [c for c in self.candidates if c.status == "verified"]

    def sort(self) -> None:
        """Rank in place: verified by ascending energy, then the also-rans
        grouped by how far they got."""
        self.candidates.sort(key=lambda c: (
            _STATUS_ORDER.get(c.status, len(_STATUS_ORDER)),
            c.energy_j if c.energy_j is not None else float("inf")))

    def render(self) -> str:
        return render_patch_report(self)

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        d["kind"] = "patch"
        return json.dumps(d, indent=2)

    @classmethod
    def from_json(cls, data: str | Mapping[str, Any]) -> "PatchReport":
        d = json.loads(data) if isinstance(data, str) else dict(data)
        diag = d.get("diagnosis")
        if diag is not None:
            diag = Diagnosis.from_dict(diag)
        return cls(target=d["target"], target_key=d.get("target_key"),
                   target_energy_j=d["target_energy_j"],
                   subkind=d.get("subkind"),
                   candidates=[PatchCandidate.from_dict(c)
                               for c in d["candidates"]],
                   diagnosis=diag, meta=dict(d.get("meta", {})))
