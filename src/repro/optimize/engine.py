"""Bidirectional jaxpr rewrite engine shared by the mutation injector and
the inverse-rewrite optimizer.

This generalizes the replay interpreter that ``repro.testing.mutate`` grew
for *injecting* waste: a (closed) jaxpr is walked equation by equation, each
equation's input values are resolved, and a :class:`RewriteRule` gets the
first shot at producing the outputs — returning ``None`` means "bind the
equation unchanged".  Two additions make the same machinery run *backwards*
(removing waste instead of planting it):

* **provenance** — a :class:`RewriteContext` records, for every value the
  replay produces, the equation and input values that produced it.  Inverse
  rewrites need this to recognize multi-equation waste idioms from their
  *last* equation (e.g. the ``div`` that finishes a hand-split tanh, the
  down-convert that finishes a bf16→f32→bf16 storage bounce) and substitute
  the fused/original computation.
* **dead-code elimination** — a rewrite that routes around earlier
  equations (cancelling a transpose round-trip, fusing a split
  transcendental) leaves those equations dead in the retraced candidate;
  :func:`build_candidate` runs XLA-independent DCE over the retrace so the
  candidate is priced without the orphaned work.

Layering: this module depends only on ``jax`` and ``repro.core.graph``;
``repro.testing.mutate`` (forward direction) and ``repro.optimize.rewrites``
(inverse direction) both build on it.
"""

from __future__ import annotations

from typing import Any, Callable, Sequence

import jax

# Call-like higher-order primitives whose bodies the replay inlines so
# rules can see the equations inside (jnp.einsum / jnp.matmul are jitted
# and would otherwise hide their dot_general behind a pjit eqn).  shard_map
# is NOT inlined: its collectives need the mesh context, so it is re-bound
# as-is, matching graph.py's treatment of scan/while/cond super-nodes.
_INLINE_PRIMITIVES = ("pjit", "jit", "closed_call", "custom_jvp_call",
                      "custom_vjp_call", "remat", "checkpoint",
                      "custom_vjp_call_jaxpr")


def nested_jaxpr(eqn):
    from repro.core.graph import _nested_jaxpr as nj
    return nj(eqn)


def bind_eqn(eqn, invals):
    """Re-bind an equation unchanged on new input values."""
    subfuns, bind_params = eqn.primitive.get_bind_params(eqn.params)
    out = eqn.primitive.bind(*subfuns, *invals, **bind_params)
    return out if eqn.primitive.multiple_results else [out]


def bind_eqn_with_params(eqn, invals, params):
    """Re-bind an equation with overridden params."""
    subfuns, bind_params = eqn.primitive.get_bind_params(params)
    out = eqn.primitive.bind(*subfuns, *invals, **bind_params)
    return out if eqn.primitive.multiple_results else [out]


class RewriteRule:
    """Base for both waste-injecting mutations and waste-removing rewrites.

    Subclasses override :meth:`on_eqn` (or a higher-level ``rewrite``) to
    return replacement output values for an equation, or ``None`` to leave
    it untouched.  ``max_sites`` bounds how many applicable sites are
    rewritten (default: all); ``applied`` counts the sites actually
    rewritten in the last trace; ``skipped`` collects human-readable
    reasons recorded via :meth:`decline` for near-miss sites, surfaced when
    a rule turns out to have zero applicable sites.
    """

    name: str = "?"

    def __init__(self, max_sites: int | None = None):
        self.max_sites = max_sites
        self.applied = 0
        self.skipped: list[str] = []

    def reset(self) -> None:
        self.applied = 0
        self.skipped = []

    def decline(self, why: str) -> None:
        if why not in self.skipped:
            self.skipped.append(why)

    def skip_summary(self) -> str:
        return "; ".join(self.skipped) if self.skipped else \
            "no applicable equation in the jaxpr"

    def _take(self) -> bool:
        if self.max_sites is not None and self.applied >= self.max_sites:
            return False
        self.applied += 1
        return True

    def on_eqn(self, eqn, invals, ctx: "RewriteContext | None" = None
               ) -> list[Any] | None:
        raise NotImplementedError


class RewriteContext:
    """Dataflow provenance for one replay.

    Maps each value the replay produced back to ``(eqn, invals)`` — the
    equation that produced it and the resolved input values it was bound
    on.  Keys are object identities, which is sound because the context
    keeps every noted value alive for the duration of the replay and the
    first (true) producer wins.
    """

    def __init__(self):
        self._prov: dict[int, tuple[Any, list[Any]]] = {}
        self._keep: list[Any] = []

    def note(self, eqn, invals: Sequence[Any], outvals: Sequence[Any]) -> None:
        in_ids = {id(v) for v in invals}
        for o in outvals:
            # a rewrite that passes an input through (or re-returns an
            # earlier value) must not masquerade as that value's producer
            if id(o) in in_ids or id(o) in self._prov:
                continue
            self._prov[id(o)] = (eqn, list(invals))
            self._keep.append(o)

    def producer(self, val) -> tuple[Any, list[Any]] | None:
        """``(eqn, invals)`` that produced ``val`` in this replay, or None
        (inputs, constants, and literal operands have no producer)."""
        return self._prov.get(id(val))


def replay_jaxpr(closed, flat_args: Sequence[Any],
                 rule: RewriteRule | None, *,
                 ctx: RewriteContext | None = None) -> list[Any]:
    """Replay a closed jaxpr, giving ``rule`` first shot at every equation.

    Call-like primitives in ``_INLINE_PRIMITIVES`` are inlined so the rule
    sees their body equations.  With a ``ctx``, provenance is recorded for
    every produced value (inlined bodies included).
    """
    from jax._src.core import Literal

    jaxpr = closed.jaxpr
    if len(flat_args) != len(jaxpr.invars):
        raise ValueError(f"replay expected {len(jaxpr.invars)} input leaves, "
                         f"got {len(flat_args)}")

    def run(eqns, env):
        def read(v):
            return v.val if isinstance(v, Literal) else env[v]

        for eqn in eqns:
            inner = nested_jaxpr(eqn)
            if inner is not None and eqn.primitive.name in _INLINE_PRIMITIVES:
                sub_env = dict(zip(inner.jaxpr.constvars, inner.consts))
                sub_env.update(zip(inner.jaxpr.invars,
                                   [read(v) for v in eqn.invars]))
                run(inner.jaxpr.eqns, sub_env)
                for ov, iv in zip(eqn.outvars, inner.jaxpr.outvars):
                    env[ov] = (iv.val if isinstance(iv, Literal)
                               else sub_env[iv])
                continue
            invals = [read(v) for v in eqn.invars]
            out = rule.on_eqn(eqn, invals, ctx) if rule is not None else None
            if out is None:
                out = bind_eqn(eqn, invals)
            if ctx is not None:
                ctx.note(eqn, invals, out)
            for v, val in zip(eqn.outvars, out):
                if type(v).__name__ != "DropVar":
                    env[v] = val
        return env

    env = dict(zip(jaxpr.constvars, closed.consts))
    env.update(zip(jaxpr.invars, flat_args))
    run(jaxpr.eqns, env)
    return [v.val if isinstance(v, Literal) else env[v]
            for v in jaxpr.outvars]


def dce_closed(closed):
    """Dead-code-eliminate a closed jaxpr.

    Returns ``(jaxpr, consts, used)``: an open jaxpr whose invars are the
    original ``[*constvars, *invars]`` filtered by the ``used`` mask, plus
    the matching constant values.  Scan/while/pjit bodies are pruned too
    (partial_eval registers DCE rules for them).
    """
    from jax._src.interpreters import partial_eval as pe

    jaxpr = closed.jaxpr
    consts = list(closed.consts)
    if jaxpr.constvars:
        jaxpr = pe.convert_constvars_jaxpr(jaxpr)
    dced, used = pe.dce_jaxpr(jaxpr, [True] * len(jaxpr.outvars))
    return dced, consts, list(used)


def build_candidate(closed, rule: RewriteRule, example_args: Sequence[Any],
                    *, name: str) -> tuple[Callable, int]:
    """Apply ``rule`` to a captured jaxpr and package the result.

    Replays ``closed`` under ``rule`` with provenance, retraces the result,
    DCEs equations the rewrites orphaned, and returns ``(candidate,
    sites)`` where ``candidate`` is an ordinary callable over the same
    argument pytree (returning the flat output leaves as a tuple) and
    ``sites`` counts the equations the rule actually rewrote.
    """
    example_args = tuple(example_args)

    def raw(*args):
        ctx = RewriteContext()
        outs = replay_jaxpr(closed, jax.tree_util.tree_leaves(args), rule,
                            ctx=ctx)
        return tuple(outs)

    rule.reset()
    retraced = jax.make_jaxpr(raw)(*example_args)
    sites = rule.applied
    if sites == 0:
        return None, 0

    dced, consts, used = dce_closed(retraced)

    def candidate(*args):
        leaves = [*consts, *jax.tree_util.tree_leaves(args)]
        kept = [v for v, u in zip(leaves, used) if u]
        return tuple(jax.core.eval_jaxpr(dced, [], *kept))

    candidate.__name__ = name
    return candidate, sites
