"""Inverse rewrites: one waste-removing transform per mutation class.

Each :class:`Rewrite` is the inverse of one entry in the mutation taxonomy
(``repro.testing.mutate.MUTATIONS``) and is keyed by the same name, which is
also the ``Diagnosis.subkind`` the classifier emits for that waste pattern —
so a diagnosis selects its inverse directly:

=====================  =====================================================
rewrite (= subkind)    inverse transform
=====================  =====================================================
``dtype_upcast``       rebind ``precision=HIGHEST`` dots with the default
                       fast path
``redundant_recompute``  CSE duplicated contractions; fold the
                       ``0.5*a + 0.5*a`` average of identical values
``sync_in_loop``       drop collectives that are the identity on their
                       mesh (size-1 all-reduces)
``oversized_padding``  elide zero-pads on free dims and the identity
                       slices they leave behind
``op_split``           re-fuse hand-split transcendentals (tanh /
                       logistic / exp) from their multi-op formulas
``scan_body``          re-bind ``lax.scan`` with the body replayed under
                       the CSE rewrite (per-iteration recompute)
``layout_thrash``      cancel transpose round-trips that compose to the
                       identity permutation
``storage_upcast``     recompute bf16→f32→bf16 storage bounces directly
                       in bf16
=====================  =====================================================

Rewrites are *candidate generators*, not proofs: each proposed candidate is
re-captured and must pass the functional-equivalence gate and price strictly
cheaper before the optimizer reports it (see ``repro.optimize.optimizer``).
A rewrite that cannot tell whether a transform is safe simply proposes it
and lets verification reject it.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.optimize.engine import (RewriteContext, RewriteRule, bind_eqn,
                                   bind_eqn_with_params, replay_jaxpr)

# collectives as they appear in traced jaxprs on this jax version (shard_map
# bodies bind psum as psum2 + pbroadcast)
_COLLECTIVE_BODY_PRIMS = frozenset({
    "psum", "psum2", "pbroadcast", "all_gather", "all_to_all", "ppermute",
    "reduce_scatter", "pmin", "pmax"})


def _scalar(x, ctx: "RewriteContext | None" = None) -> float | None:
    """Concrete scalar value of a replay input, or None (tracer/array).

    With a ``ctx``, scalars staged behind ``convert_element_type`` chains
    (omnistaging traces even constant casts, e.g. clip bounds) are resolved
    through provenance.
    """
    if isinstance(x, jax.core.Tracer):
        if ctx is None:
            return None
        seen = 0
        while seen < 4:
            prov = ctx.producer(x)
            if not (prov and prov[0].primitive.name == "convert_element_type"
                    and len(prov[1]) == 1):
                return None
            x = prov[1][0]
            if not isinstance(x, jax.core.Tracer):
                break
            seen += 1
        if isinstance(x, jax.core.Tracer):
            return None
    try:
        arr = np.asarray(x)
    except Exception:
        return None
    if arr.ndim != 0:
        return None
    return float(arr)


class Rewrite(RewriteRule):
    """One inverse rewrite.  ``name`` doubles as the registry key and the
    ``Diagnosis.subkind`` it answers; ``roundtrip_rtol`` is the declared
    bound on the residual energy gap of ``inverse(mutation(clean))`` vs
    ``clean`` (small helper ops the inverse cannot remove)."""

    name: str = "?"
    roundtrip_rtol: float = 0.05
    # functional-equivalence tolerance the verifier should use for this
    # rewrite's candidates (bf16 recomputation rounds differently)
    verify_rtol: float = 1e-2

    def rewrite(self, eqn, invals, ctx: RewriteContext) -> list[Any] | None:
        raise NotImplementedError

    def on_eqn(self, eqn, invals, ctx: RewriteContext | None = None):
        if ctx is None:
            raise ValueError(f"rewrite {self.name!r} needs a RewriteContext")
        out = self.rewrite(eqn, invals, ctx)
        if out is not None and not isinstance(out, (list, tuple)):
            out = [out]
        return list(out) if out is not None else None


class DropPrecisionUpcast(Rewrite):
    """Inverse of ``DtypeUpcast``: rebind HIGHEST-precision dots with the
    default (fast-path) precision.  On accelerators this drops the 3-pass
    fp32 MXU emulation; the analytic backend prices it as the
    ``fp32_fraction`` falling back to the native matmul rate."""

    name = "dtype_upcast"
    roundtrip_rtol = 0.02

    def rewrite(self, eqn, invals, ctx):
        if eqn.primitive.name != "dot_general":
            return None
        prec = eqn.params.get("precision")
        if prec is None or "HIGHEST" not in str(prec).upper():
            self.decline("dot_general already on default precision")
            return None
        if not self._take():
            return None
        params = dict(eqn.params)
        params["precision"] = None
        return bind_eqn_with_params(eqn, invals, params)


class CseDuplicates(Rewrite):
    """Inverse of ``RedundantRecompute``: share the first binding of any
    contraction that reappears with identical inputs and params, and fold
    the ``0.5*a + 0.5*a`` average the mutation used to consume both copies
    back to ``a`` (the orphaned muls die in DCE)."""

    name = "redundant_recompute"
    roundtrip_rtol = 0.02

    _CSE_PRIMS = ("dot_general", "conv_general_dilated")

    def __init__(self, max_sites=None):
        super().__init__(max_sites)
        self._memo: dict[tuple, list[Any]] = {}

    def reset(self):
        super().reset()
        self._memo = {}

    def rewrite(self, eqn, invals, ctx):
        prim = eqn.primitive.name
        if prim in self._CSE_PRIMS:
            key = (prim, repr(sorted(eqn.params.items(), key=lambda kv: kv[0])),
                   tuple(id(v) for v in invals))
            hit = self._memo.get(key)
            if hit is not None:
                if not self._take():
                    return None
                return list(hit)
            out = bind_eqn(eqn, invals)
            self._memo[key] = list(out)
            return out
        if prim == "add":
            a, b = invals
            pa, pb = ctx.producer(a), ctx.producer(b)
            if pa and pb and pa[0].primitive.name == "mul" \
                    and pb[0].primitive.name == "mul":
                xa = self._half_of(pa[1])
                xb = self._half_of(pb[1])
                if xa is not None and xa is xb and self._take():
                    return [xa]
        return None

    @staticmethod
    def _half_of(mul_invals):
        a, b = mul_invals
        if _scalar(a) == 0.5:
            return b
        if _scalar(b) == 0.5:
            return a
        return None


class DropIdentityCollective(Rewrite):
    """Inverse of ``SyncInLoop``: remove collectives that are the identity
    on their mesh — a ``shard_map`` whose body is nothing but psum-style
    reductions over a size-1 mesh moves no data and changes no values.
    Collectives on real multi-device meshes are left alone (hoisting those
    needs mesh-aware replay; see ROADMAP)."""

    name = "sync_in_loop"
    roundtrip_rtol = 0.02

    def rewrite(self, eqn, invals, ctx):
        if eqn.primitive.name != "shard_map":
            return None
        mesh = eqn.params.get("mesh")
        body = eqn.params.get("jaxpr")
        if mesh is None or body is None:
            return None
        if getattr(mesh, "size", None) != 1:
            self.decline("collective runs on a >1-device mesh; identity "
                         "elimination does not apply")
            return None
        body_jaxpr = body.jaxpr if hasattr(body, "jaxpr") else body
        prims = {e.primitive.name for e in body_jaxpr.eqns}
        if not prims <= _COLLECTIVE_BODY_PRIMS:
            self.decline(f"shard_map body is not purely collective: "
                         f"{sorted(prims - _COLLECTIVE_BODY_PRIMS)}")
            return None
        if len(eqn.outvars) != len(invals) or not self._take():
            return None
        return list(invals)


class TrimPadding(Rewrite):
    """Inverse of ``OversizedPadding``: elide zero-interior pads that only
    grow trailing rows of a free dimension, and the identity slices left
    once the padded rows are gone.  Downstream consumers re-bind on the
    unpadded shapes; if any consumer genuinely needed the padding the
    retrace fails and the candidate is reported as failed."""

    name = "oversized_padding"
    roundtrip_rtol = 0.02

    def rewrite(self, eqn, invals, ctx):
        prim = eqn.primitive.name
        if prim == "pad":
            operand = invals[0]
            cfg = eqn.params.get("padding_config", ())
            if not all(lo == 0 and inner == 0 for lo, _, inner in cfg):
                self.decline("pad has leading/interior padding (not a "
                             "trailing overallocation)")
                return None
            if not any(hi > 0 for _, hi, _ in cfg):
                return None
            if not self._take():
                return None
            return [operand]
        if prim == "slice":
            (operand,) = invals
            starts = eqn.params.get("start_indices", ())
            limits = eqn.params.get("limit_indices", ())
            strides = eqn.params.get("strides") or (1,) * len(starts)
            shape = getattr(operand, "shape", None)
            if shape is None:
                return None
            if all(s == 0 for s in starts) and tuple(limits) == tuple(shape) \
                    and all(s == 1 for s in strides):
                # identity slice (the counterpart of an elided pad) — drop
                # it without consuming a site
                return [operand]
        return None


class FuseSplitOps(Rewrite):
    """Inverse of ``OpSplit``: recognize the eager multi-op formulas for
    tanh / logistic / exp from their final equation and substitute the
    fused primitive; the formula's intermediate chain dies in DCE.

    Patterns (matched on replay provenance):

    * ``(t-1)/(t+1)`` with ``t = exp(2*clip(x,-c,c))``  →  ``tanh(x)``
    * ``1/(1+exp(-x))``                                 →  ``logistic(x)``
    * ``h*h`` with ``h = exp(0.5*x)``                   →  ``exp(x)``
    """

    name = "op_split"
    roundtrip_rtol = 0.05

    def rewrite(self, eqn, invals, ctx):
        prim = eqn.primitive.name
        if prim == "div":
            return self._fuse_div(invals, ctx)
        if prim == "mul":
            return self._fuse_square_exp(invals, ctx)
        return None

    def _fuse_div(self, invals, ctx):
        num, den = invals
        # logistic: 1 / (1 + exp(-x))
        if _scalar(num) == 1.0:
            pd = ctx.producer(den)
            if pd and pd[0].primitive.name == "add":
                e = self._other_of(pd[1], 1.0)
                pe_ = ctx.producer(e) if e is not None else None
                if pe_ and pe_[0].primitive.name == "exp":
                    pn = ctx.producer(pe_[1][0])
                    if pn and pn[0].primitive.name == "neg" and self._take():
                        return [jax.lax.logistic(pn[1][0])]
            return None
        # tanh: (t - 1) / (t + 1) with t = exp(2 * x)
        ps, pa = ctx.producer(num), ctx.producer(den)
        if not (ps and pa and ps[0].primitive.name == "sub"
                and pa[0].primitive.name == "add"):
            return None
        t1, one1 = ps[1]
        if _scalar(one1) != 1.0:
            return None
        t2 = self._other_of(pa[1], 1.0)
        if t2 is None or t1 is not t2:
            return None
        pt = ctx.producer(t1)
        if not (pt and pt[0].primitive.name == "exp"):
            return None
        pm = ctx.producer(pt[1][0])
        if not (pm and pm[0].primitive.name == "mul"):
            return None
        x = self._other_of(pm[1], 2.0)
        if x is None:
            return None
        if not self._take():
            return None
        return [jax.lax.tanh(self._unwrap_clip(x, ctx))]

    def _fuse_square_exp(self, invals, ctx):
        a, b = invals
        if a is not b:
            return None
        ph = ctx.producer(a)
        if not (ph and ph[0].primitive.name == "exp"):
            return None
        pm = ctx.producer(ph[1][0])
        if not (pm and pm[0].primitive.name == "mul"):
            return None
        x = self._other_of(pm[1], 0.5)
        if x is None or not self._take():
            return None
        return [jax.lax.exp(x)]

    @staticmethod
    def _other_of(pair, lit):
        a, b = pair
        if _scalar(a) == lit:
            return b
        if _scalar(b) == lit:
            return a
        return None

    @staticmethod
    def _unwrap_clip(x, ctx):
        """tanh saturates far inside the mutation's ±20 overflow clip, so
        ``tanh(clip(x, -c, c)) == tanh(x)`` for c >= 10 — unwrap the clip
        (traced as min(max(x, -c), c)) so it dies in DCE."""
        pmin = ctx.producer(x)
        if not (pmin and pmin[0].primitive.name == "min"):
            return x
        hi_candidates = [(v, _scalar(w, ctx)) for v, w in
                         ((pmin[1][0], pmin[1][1]), (pmin[1][1], pmin[1][0]))]
        for inner, hi in hi_candidates:
            if hi is not None and hi >= 10.0:
                pmax = ctx.producer(inner)
                if pmax and pmax[0].primitive.name == "max":
                    for orig, lo in ((pmax[1][0], _scalar(pmax[1][1], ctx)),
                                     (pmax[1][1], _scalar(pmax[1][0], ctx))):
                        if lo is not None and lo <= -10.0:
                            return orig
        return x


def _static_duplicate_contraction(jaxpr) -> bool:
    """Whether a jaxpr binds the same contraction twice on the same invars
    (the static signature of planted recompute inside a scan body)."""
    seen = set()
    for eqn in jaxpr.eqns:
        if eqn.primitive.name not in CseDuplicates._CSE_PRIMS:
            continue
        key = (eqn.primitive.name,
               tuple(str(v) for v in eqn.invars),
               repr(sorted(eqn.params.items(), key=lambda kv: kv[0])))
        if key in seen:
            return True
        seen.add(key)
    return False


class CseScanBody(Rewrite):
    """Inverse of ``ScanBodyWaste``: re-bind ``lax.scan`` with the body
    replayed under :class:`CseDuplicates`, removing per-iteration recompute
    (trip-count-scaled, so the win multiplies by ``length``)."""

    name = "scan_body"
    roundtrip_rtol = 0.05

    def rewrite(self, eqn, invals, ctx):
        if eqn.primitive.name != "scan":
            return None
        body = eqn.params["jaxpr"]
        body_jaxpr = body.jaxpr if hasattr(body, "jaxpr") else body
        if not _static_duplicate_contraction(body_jaxpr):
            self.decline("scan body has no duplicated contraction")
            return None
        if not self._take():
            return None
        num_consts = eqn.params["num_consts"]
        num_carry = eqn.params["num_carry"]
        consts = list(invals[:num_consts])
        init = list(invals[num_consts:num_consts + num_carry])
        xs = tuple(invals[num_consts + num_carry:])
        inner = CseDuplicates()

        def body_fn(carry, x):
            inner.reset()
            ictx = RewriteContext()
            x_leaves = [] if x is None else list(x)
            outs = replay_jaxpr(body, [*consts, *list(carry), *x_leaves],
                                inner, ctx=ictx)
            return tuple(outs[:num_carry]), tuple(outs[num_carry:])

        carry_out, ys = jax.lax.scan(
            body_fn, tuple(init), xs if xs else None,
            length=eqn.params.get("length"),
            reverse=eqn.params.get("reverse", False),
            unroll=eqn.params.get("unroll", 1))
        return [*carry_out, *ys]


class CancelTransposeRoundTrip(Rewrite):
    """Inverse of ``LayoutThrash``: a transpose whose input was itself
    produced by a transpose composing to the identity permutation is
    replaced by the original value; the inner transpose dies in DCE."""

    name = "layout_thrash"
    roundtrip_rtol = 0.02

    def rewrite(self, eqn, invals, ctx):
        if eqn.primitive.name != "transpose":
            return None
        (v,) = invals
        p = eqn.params["permutation"]
        prov = ctx.producer(v)
        if not (prov and prov[0].primitive.name == "transpose"):
            self.decline("transpose is not part of a round-trip")
            return None
        q = prov[0].params["permutation"]
        if len(p) != len(q) or any(q[p[i]] != i for i in range(len(p))):
            self.decline("adjacent transposes do not compose to identity")
            return None
        if not self._take():
            return None
        return [prov[1][0]]


class DropStorageUpcast(Rewrite):
    """Inverse of ``StorageUpcast``: a down-convert to bf16 whose producer
    is an elementwise op fed (partly) by up-converts from bf16 is replaced
    by the op recomputed directly on the original bf16 values — halving the
    storage traffic; the f32 op and its up-converts die in DCE.

    bf16 recomputation rounds once instead of rounding an f32 result, so
    candidates from this rewrite verify within bf16 epsilon (~0.4%/op);
    ``verify_rtol`` is widened accordingly."""

    name = "storage_upcast"
    roundtrip_rtol = 0.05
    verify_rtol = 0.05

    _TARGET_FNS = {
        "tanh": jnp.tanh,
        "logistic": jax.nn.sigmoid,
        "exp": jnp.exp,
        "add": jnp.add,
        "mul": jnp.multiply,
    }

    def rewrite(self, eqn, invals, ctx):
        if eqn.primitive.name != "convert_element_type":
            return None
        if eqn.params.get("new_dtype") != jnp.bfloat16:
            return None
        (v,) = invals
        prov = ctx.producer(v)
        if prov is None or prov[0].primitive.name not in self._TARGET_FNS:
            self.decline("down-convert does not follow a supported "
                         "elementwise op")
            return None
        op_eqn, op_invals = prov
        orig, unwrapped = [], 0
        for w in op_invals:
            p = ctx.producer(w)
            if p is not None and p[0].primitive.name == "convert_element_type" \
                    and getattr(w, "dtype", None) == jnp.float32 \
                    and getattr(p[1][0], "dtype", None) == jnp.bfloat16:
                orig.append(p[1][0])
                unwrapped += 1
            else:
                orig.append(w)
        if unwrapped == 0:
            self.decline("elementwise op has no bf16-sourced operands")
            return None
        # jaxpr literals read back as *strong* f32 scalars, which would
        # re-promote the bf16 recomputation; demote them to weak floats
        orig = [s if (s := _scalar(o)) is not None else o for o in orig]
        # any operand that stays f32 (beyond weak scalars) would re-promote
        if not all(_scalar(o) is not None
                   or getattr(o, "dtype", None) == jnp.bfloat16
                   for o in orig):
            self.decline("mixed-precision operands; bf16 recomputation "
                         "would change the op's input dtypes")
            return None
        if not self._take():
            return None
        out = self._TARGET_FNS[op_eqn.primitive.name](*orig)
        if getattr(out, "dtype", None) != jnp.bfloat16:
            out = out.astype(jnp.bfloat16)
        return [out]


REWRITES: dict[str, type[Rewrite]] = {
    r.name: r for r in (DropPrecisionUpcast, CseDuplicates,
                        DropIdentityCollective, TrimPadding, FuseSplitOps,
                        CseScanBody, CancelTransposeRoundTrip,
                        DropStorageUpcast)
}


def rewrites_for(subkind: str | None) -> list[str]:
    """Rewrite names to try for a diagnosis, most specific first.

    A known subkind proposes its inverse first, then every other rewrite
    (the verifier ranks all survivors, so extra candidates only add rank
    columns); ``None`` proposes everything in registry order."""
    names = list(REWRITES)
    if subkind in REWRITES:
        names.remove(subkind)
        names.insert(0, subkind)
    return names
